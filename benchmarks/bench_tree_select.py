"""Hierarchical tree selection benchmark (DESIGN.md §6).

Sections
--------
1. ``tree/bytes_on_wire`` — candidate-feature bytes every non-leaf gather
   ships, int8 wire vs fp32, per depth.  Static accounting
   (``wire_bytes_plan``: int8 payload + fp32 per-row scales vs 4·r·d), so
   the number is exact, not sampled.  Gated: reduction ≥ ``BYTES_GATE``
   (3.5×) at the bench's d=64 (the ratio is 4d/(d+4) → 3.76×; proxy
   feature dims below ~32 cannot clear 3.5× and should use the fp32
   escape hatch anyway).
2. ``tree/objective_ratio`` — F(int8 tree) / F(fp32 tree) on the same
   pool, per depth.  Gated: ≥ ``OBJ_GATE`` (0.95) — the per-row
   quantization error (≤ scale/2 per candidate) must not move the merge
   greedy enough to degrade the selected set.  The fp32-tree /
   lazy-greedy ratio is reported alongside (ungated here — the
   depth-composition gate lives in test_selection_properties.py).
3. ``tree/host_select`` — host-driver wall-clock per depth (context for
   the ratios; the collective path is exercised by the tier-2 lanes).

Every run writes ``BENCH_tree.json``; ``--smoke`` keeps CI-on-CPU scale.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import facility_location as fl
from repro.core.craig import pairwise_distances
from repro.distributed.tree_select import (
    TreeTopology,
    default_r_node,
    tree_select_host,
    wire_bytes_plan,
)

BYTES_GATE = 3.5  # fp32/int8 candidate-feature bytes, floor
OBJ_GATE = 0.95  # F(int8 tree)/F(fp32 tree), floor
_RECORDS: list[dict] = []


def _emit(name: str, us: float, derived: str, **rec) -> None:
    emit(name, us, derived)
    _RECORDS.append({"name": name, "us_per_call": us, "derived": derived, **rec})


def _pool(n: int, d: int, seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    centers = rng.randn(max(8, n // 64), d).astype(np.float32) * 4.0
    return (
        centers[rng.randint(0, len(centers), n)]
        + 0.5 * rng.randn(n, d).astype(np.float32)
    ).astype(np.float32)


def _objective(feats: np.ndarray, idx: np.ndarray) -> float:
    dist = np.asarray(pairwise_distances(jnp.asarray(feats)))
    sim = dist.max() + 1e-6 - dist
    mask = np.zeros(len(feats), bool)
    mask[np.asarray(idx)] = True
    return float(
        fl.facility_location_value(jnp.asarray(sim), jnp.asarray(mask))
    )


def _bytes_section(fanouts: tuple[int, ...], r_local: int, r_final: int,
                   d: int) -> None:
    topo = TreeTopology(fanouts)
    r_node = default_r_node(r_local, r_final)
    plan = wire_bytes_plan(topo, r_local, r_node, d, "int8")
    reduction = plan["reduction"]
    ok = reduction >= BYTES_GATE
    _emit(
        f"tree/bytes_on_wire/f{'x'.join(map(str, fanouts))}_d{d}",
        0.0,
        f"int8={plan['gathered_feature_bytes']}B "
        f"fp32={plan['fp32_feature_bytes']}B reduction={reduction:.2f}x "
        f"gate={BYTES_GATE} {'ok' if ok else 'FAIL'}",
        fanouts=list(fanouts), d=d, r_local=r_local, r_node=r_node,
        int8_bytes=plan["gathered_feature_bytes"],
        fp32_bytes=plan["fp32_feature_bytes"],
        per_level=plan["per_level"], reduction=reduction, gate=BYTES_GATE,
    )
    if not ok:
        raise AssertionError(
            f"int8 candidate wire reduces bytes only {reduction:.2f}x at "
            f"d={d}, below the {BYTES_GATE}x gate"
        )


def _objective_section(feats: np.ndarray, fanouts: tuple[int, ...],
                       r_local: int, r_final: int) -> None:
    topo = TreeTopology(fanouts)
    jf = jnp.asarray(feats)
    sels, times = {}, {}
    for compress in ("int8", "none"):
        t0 = time.perf_counter()
        sel = tree_select_host(jf, topo, r_local, r_final, compress=compress)
        jax.block_until_ready(sel.indices)
        times[compress] = time.perf_counter() - t0
        sels[compress] = sel
    f_int8 = _objective(feats, np.asarray(sels["int8"].indices))
    f_fp32 = _objective(feats, np.asarray(sels["none"].indices))
    ratio = f_int8 / max(f_fp32, 1e-9)
    ok = ratio >= OBJ_GATE
    # context: how far the fp32 tree itself sits from host lazy greedy
    dist = np.asarray(pairwise_distances(jf))
    sim = dist.max() + 1e-6 - dist
    f_lazy = _objective(feats, np.asarray(
        fl.lazy_greedy_fl(sim, r_final).indices))
    tag = "x".join(map(str, fanouts))
    _emit(
        f"tree/objective_ratio/f{tag}_n{len(feats)}_k{r_final}",
        times["int8"] * 1e6,
        f"int8/fp32={ratio:.4f} gate={OBJ_GATE} "
        f"fp32/lazy={f_fp32 / max(f_lazy, 1e-9):.4f} "
        f"{'ok' if ok else 'FAIL'}",
        fanouts=list(fanouts), n=len(feats), r_local=r_local,
        r_final=r_final, f_int8=f_int8, f_fp32=f_fp32, f_lazy=f_lazy,
        ratio=ratio, gate=OBJ_GATE, fp32_vs_lazy=f_fp32 / max(f_lazy, 1e-9),
    )
    _emit(
        f"tree/host_select/f{tag}_n{len(feats)}_k{r_final}",
        times["none"] * 1e6,
        f"int8_s={times['int8']:.3f} fp32_s={times['none']:.3f}",
        fanouts=list(fanouts), n=len(feats), int8_s=times["int8"],
        fp32_s=times["none"],
    )
    if not ok:
        raise AssertionError(
            f"compressed tree objective ratio {ratio:.4f} below the "
            f"{OBJ_GATE} gate (fanouts={fanouts})"
        )


def _write_json(smoke: bool) -> None:
    with open("BENCH_tree.json", "w") as f:
        json.dump(
            {
                "schema": 1,
                "smoke": smoke,
                "backend": jax.default_backend(),
                "gates": {
                    "bytes_reduction": BYTES_GATE,
                    "objective_ratio": OBJ_GATE,
                },
                "records": _RECORDS,
            },
            f, indent=1,
        )


def run(smoke: bool = False) -> None:
    n, d = (2048, 64) if smoke else (16384, 64)
    r_final = max(16, n // 128)
    r_local = max(8, r_final // 2)
    feats = _pool(n, d)
    try:
        for fanouts in [(8,), (4, 2)]:
            _bytes_section(fanouts, r_local, r_final, d)
            _objective_section(feats, fanouts, r_local, r_final)
    finally:
        _write_json(smoke)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    run(smoke=ap.parse_args().smoke)
