"""Paper Fig 5 (scaled down): test accuracy vs fraction of data selected,
CRAIG vs random, subsets re-selected every epoch (Fig 5a protocol).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_mlp import _init, _logits, _step
from benchmarks.common import emit
from repro.core.craig import CraigConfig, CraigSelector
from repro.core.proxy import classifier_last_layer_proxy
from repro.data.synthetic import make_classification

N, DIM, CLASSES, BATCH, EPOCHS = 600, 10, 8, 10, 8


def _one(x, y, xt, yt, frac, mode, seed=0):
    rng = np.random.RandomState(seed)
    p = _init(jax.random.PRNGKey(seed), dim=DIM, n_classes=CLASSES)
    for _ in range(EPOCHS):
        if mode == "craig":
            proxies = classifier_last_layer_proxy(_logits(p, jnp.asarray(x)), y)
            sel = CraigSelector(CraigConfig(fraction=frac, per_class=True))
            cs = sel.select(np.asarray(proxies), y)
            idx, w = cs.indices, cs.normalized_weights()
        else:
            idx = rng.choice(N, max(BATCH, int(N * frac)), replace=False)
            w = np.ones(len(idx), np.float32)
        order = rng.permutation(len(idx))
        idx, w = idx[order], w[order]
        for lo in range(0, len(idx) - BATCH + 1, BATCH):
            sl = idx[lo : lo + BATCH]
            p = _step(
                p, jnp.asarray(x[sl]), jnp.asarray(y[sl]),
                jnp.asarray(w[lo : lo + BATCH]),
            )
    return float(
        jnp.mean(jnp.argmax(_logits(p, jnp.asarray(xt)), -1) == jnp.asarray(yt))
    )


def run() -> None:
    # 8 imbalanced classes, short training — the regime where coverage of
    # rare modes matters (paper Fig 5's small-fraction separation)
    x, y = make_classification(N + 200, DIM, CLASSES, seed=4)
    xt, yt = x[N:], y[N:]
    x, y = x[:N], y[:N]
    t0 = time.perf_counter()
    wins = 0
    parts = []
    for frac in (0.05, 0.1, 0.2):
        acc_c = _one(x, y, xt, yt, frac, "craig")
        acc_r = float(np.mean([_one(x, y, xt, yt, frac, "random", s) for s in (0, 1)]))
        wins += acc_c >= acc_r
        parts.append(f"{int(frac*100)}pct:craig={acc_c:.3f},rand={acc_r:.3f}")
    us = (time.perf_counter() - t0) * 1e6 / 6
    emit("fig5_data_efficiency", us, ";".join(parts) + f";craig_wins={wins}/3")


if __name__ == "__main__":
    run()
