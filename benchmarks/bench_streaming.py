"""Streaming sieve engine benchmark (DESIGN.md §10).

Sections
--------
1. Ingest throughput over k sequential deltas WITHOUT re-sweep: per-delta
   wall-clock must stay FLAT as the seen-pool grows (the O(Δn·k) claim —
   sieve-streaming touches only the arriving delta, never the prior pool).
   Gated: median of the last deltas ≤ ``_FLAT_TOL`` × median of the first
   post-compile deltas.  A full re-sweep comparator (features-engine greedy
   over the whole seen pool at every delta) is *extrapolated* from a small-k
   timing — running it for real at full k would dwarf the bench — and
   labeled ``extrapolated=True`` in the JSON record.
2. Objective-ratio gate on CI CPU: multi-delta streaming selection vs host
   lazy greedy on the same pool must clear ``OBJ_GATE = 0.45`` (the
   (1/2 − ε) guarantee leaves headroom; empirically it lands ≥ 0.9).

Every run writes ``BENCH_streaming.json`` (CI uploads it next to
``BENCH_selection.json``); ``--smoke`` keeps CI-on-CPU scale.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import facility_location as fl
from repro.core.craig import pairwise_distances
from repro.core.engines import FeaturesConfig, make_engine
from repro.core.engines.streaming import StreamingSelector

OBJ_GATE = 0.45  # CI floor on F(streaming)/F(lazy greedy)
_FLAT_TOL = 1.75  # late-delta / early-delta wall-clock ceiling (CI noise pad)
_RECORDS: list[dict] = []


def _emit(name: str, us: float, derived: str, **rec) -> None:
    emit(name, us, derived)
    _RECORDS.append({"name": name, "us_per_call": us, "derived": derived, **rec})


def _pool(n: int, d: int, seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    centers = rng.randn(max(8, n // 64), d).astype(np.float32) * 4.0
    return (
        centers[rng.randint(0, len(centers), n)]
        + 0.5 * rng.randn(n, d).astype(np.float32)
    ).astype(np.float32)


def _ingest_throughput(n: int, chunk: int, d: int) -> None:
    budget = max(32, n // 20)
    feats = _pool(n, d)
    sel = StreamingSelector(budget, d)
    per_delta = []
    for lo in range(0, n, chunk):
        t0 = time.perf_counter()
        sel.ingest(feats[lo : lo + chunk])
        jax.block_until_ready(sel._states)
        per_delta.append(time.perf_counter() - t0)
    t0 = time.perf_counter()
    res = sel.result(feats)
    jax.block_until_ready(res.indices)
    finalize_s = time.perf_counter() - t0

    # delta 0 pays the XLA compile; the flatness claim is about steady state
    steady = per_delta[1:]
    head = float(np.median(steady[: max(1, len(steady) // 3)]))
    tail = float(np.median(steady[-max(1, len(steady) // 3):]))
    flat = tail <= _FLAT_TOL * head
    _emit(
        f"streaming/ingest/n{n}_dn{chunk}_k{budget}",
        float(np.median(steady)) * 1e6,
        f"deltas={len(per_delta)} head_s={head:.3f} tail_s={tail:.3f} "
        f"flat={'ok' if flat else 'FAIL'} finalize_s={finalize_s:.3f}",
        n=n, chunk=chunk, budget=budget, per_delta_s=per_delta,
        finalize_s=finalize_s, flat=flat,
    )
    if not flat:
        raise AssertionError(
            f"per-delta ingest grew with the seen pool: head {head:.3f}s → "
            f"tail {tail:.3f}s (O(Δn·k) no-re-sweep claim violated)"
        )

    # re-sweep comparator: features-engine greedy over the FULL seen pool at
    # every delta boundary.  Timed at a small budget and extrapolated
    # linearly in k (blocked greedy is k sweeps of the same pool scan).
    k_small = min(64, budget)
    eng = make_engine(FeaturesConfig())
    jf = jnp.asarray(feats)
    jax.block_until_ready(eng.select(jf, k_small).indices)  # compile
    t0 = time.perf_counter()
    jax.block_until_ready(eng.select(jf, k_small).indices)
    t_small = time.perf_counter() - t0
    resweep_s = t_small * (budget / k_small) * (n // chunk)
    stream_s = float(np.sum(steady)) + finalize_s
    _emit(
        f"streaming/vs_resweep/n{n}_k{budget}",
        resweep_s * 1e6,
        f"stream_total_s={stream_s:.2f} resweep_total_s={resweep_s:.2f} "
        f"speedup={resweep_s / max(stream_s, 1e-9):.1f}x extrapolated=True",
        n=n, budget=budget, stream_total_s=stream_s,
        resweep_total_s=resweep_s, extrapolated=True,
    )


def _objective_gate(n: int, chunk: int, d: int) -> None:
    budget = max(16, n // 20)
    feats = _pool(n, d, seed=1)
    sel = StreamingSelector(budget, d)
    for lo in range(0, n, chunk):
        sel.ingest(feats[lo : lo + chunk])
    res = sel.result(feats)

    dist = np.asarray(pairwise_distances(jnp.asarray(feats)))
    sim = dist.max() + 1e-6 - dist

    def obj(idx):
        mask = np.zeros(n, bool)
        mask[np.asarray(idx)] = True
        return float(
            fl.facility_location_value(jnp.asarray(sim), jnp.asarray(mask))
        )

    ref = fl.lazy_greedy_fl(sim, budget)
    ratio = obj(res.indices) / obj(ref.indices)
    ok = ratio >= OBJ_GATE
    _emit(
        f"streaming/objective_ratio/n{n}_k{budget}",
        0.0,
        f"ratio={ratio:.3f} gate={OBJ_GATE} {'ok' if ok else 'FAIL'}",
        n=n, budget=budget, ratio=ratio, gate=OBJ_GATE,
    )
    if not ok:
        raise AssertionError(
            f"streaming objective ratio {ratio:.3f} below the {OBJ_GATE} gate"
        )


def _write_json(smoke: bool) -> None:
    with open("BENCH_streaming.json", "w") as f:
        json.dump(
            {
                "schema": 1,
                "smoke": smoke,
                "backend": jax.default_backend(),
                "gates": {"objective_ratio": OBJ_GATE, "flat_tol": _FLAT_TOL},
                "records": _RECORDS,
            },
            f, indent=1,
        )


def run(smoke: bool = False) -> None:
    try:
        if smoke:
            _ingest_throughput(n=8192, chunk=1024, d=16)
        else:
            _ingest_throughput(n=50_000, chunk=2048, d=32)
        _objective_gate(n=4096, chunk=1024, d=16)
    finally:
        _write_json(smoke)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    run(smoke=ap.parse_args().smoke)
