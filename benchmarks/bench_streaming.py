"""Streaming sieve engine benchmark (DESIGN.md §10).

Sections
--------
1. Ingest throughput over k sequential deltas WITHOUT re-sweep: per-delta
   wall-clock must stay FLAT as the seen-pool grows (the O(Δn·k) claim —
   sieve-streaming touches only the arriving delta, never the prior pool).
   Gated: median of the last deltas ≤ ``_FLAT_TOL`` × median of the first
   post-compile deltas.  A full re-sweep comparator (features-engine greedy
   over the whole seen pool at every delta) is *extrapolated* from a small-k
   timing — running it for real at full k would dwarf the bench — and
   labeled ``extrapolated=True`` in the JSON record.
   The finalize sweep is gated too: a steady-state ``result()`` call (the
   blocked replay — compile excluded by timing the second call) must cost
   ≤ ``_FINALIZE_TOL`` × the median steady per-delta ingest, so finalizing
   at every drain never dominates the ingest path it amortizes.
2. Objective-ratio gate on CI CPU: multi-delta streaming selection vs host
   lazy greedy on the same pool must clear ``OBJ_GATE = 0.45`` (the
   (1/2 − ε) guarantee leaves headroom; empirically it lands ≥ 0.9) —
   checked both with the full pool retained and with sieve-pool eviction
   (``evict=True``) bounding live rows to what the sieves reference.

Every run writes ``BENCH_streaming.json`` (CI uploads it next to
``BENCH_selection.json``); ``--smoke`` keeps CI-on-CPU scale.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import facility_location as fl
from repro.core.craig import pairwise_distances
from repro.core.engines import FeaturesConfig, make_engine
from repro.core.engines.streaming import StreamingSelector

OBJ_GATE = 0.45  # CI floor on F(streaming)/F(lazy greedy)
_FLAT_TOL = 1.75  # late-delta / early-delta wall-clock ceiling (CI noise pad)
_FINALIZE_TOL = 2.0  # finalize_s ceiling, × median steady per-delta ingest
_WARMUP = 2  # leading deltas discarded before the flatness ratio (XLA
# compile on delta 0, dispatch-cache warm-up on delta 1)
_RECORDS: list[dict] = []


def _emit(name: str, us: float, derived: str, **rec) -> None:
    emit(name, us, derived)
    _RECORDS.append({"name": name, "us_per_call": us, "derived": derived, **rec})


def _pool(n: int, d: int, seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    centers = rng.randn(max(8, n // 64), d).astype(np.float32) * 4.0
    return (
        centers[rng.randint(0, len(centers), n)]
        + 0.5 * rng.randn(n, d).astype(np.float32)
    ).astype(np.float32)


def _ingest_throughput(n: int, chunk: int, d: int) -> None:
    budget = max(32, n // 20)
    feats = _pool(n, d)
    sel = StreamingSelector(budget, d)
    per_delta = []
    for lo in range(0, n, chunk):
        t0 = time.perf_counter()
        sel.ingest(feats[lo : lo + chunk])
        jax.block_until_ready(sel._states)
        per_delta.append(time.perf_counter() - t0)
    # finalize twice: the first call pays the blocked-replay compile; the
    # gated number is the steady-state finalize a service repeats per drain
    t0 = time.perf_counter()
    res = sel.result(feats)
    jax.block_until_ready(res.indices)
    finalize_warm_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    res = sel.result(feats)
    jax.block_until_ready(res.indices)
    finalize_s = time.perf_counter() - t0

    # warm-up deltas pay XLA compile + dispatch-cache misses; the flatness
    # claim is about steady state only
    steady = per_delta[_WARMUP:]
    head = float(np.median(steady[: max(1, len(steady) // 3)]))
    tail = float(np.median(steady[-max(1, len(steady) // 3):]))
    flat = tail <= _FLAT_TOL * head
    delta_med = float(np.median(steady))
    fin_ok = finalize_s <= _FINALIZE_TOL * max(delta_med, 1e-9)
    _emit(
        f"streaming/ingest/n{n}_dn{chunk}_k{budget}",
        delta_med * 1e6,
        f"deltas={len(per_delta)} head_s={head:.3f} tail_s={tail:.3f} "
        f"flat={'ok' if flat else 'FAIL'} finalize_s={finalize_s:.3f} "
        f"finalize={'ok' if fin_ok else 'FAIL'}",
        n=n, chunk=chunk, budget=budget, per_delta_s=per_delta,
        finalize_s=finalize_s, finalize_warm_s=finalize_warm_s,
        flat=flat, finalize_ok=fin_ok,
    )
    if not flat:
        raise AssertionError(
            f"per-delta ingest grew with the seen pool: head {head:.3f}s → "
            f"tail {tail:.3f}s (O(Δn·k) no-re-sweep claim violated)"
        )
    if not fin_ok:
        raise AssertionError(
            f"steady-state finalize {finalize_s:.3f}s exceeds "
            f"{_FINALIZE_TOL}× the median per-delta ingest {delta_med:.3f}s "
            "(blocked-replay finalize must not dominate the ingest path)"
        )

    # re-sweep comparator: features-engine greedy over the FULL seen pool at
    # every delta boundary.  Timed at a small budget and extrapolated
    # linearly in k (blocked greedy is k sweeps of the same pool scan).
    k_small = min(64, budget)
    eng = make_engine(FeaturesConfig())
    jf = jnp.asarray(feats)
    jax.block_until_ready(eng.select(jf, k_small).indices)  # compile
    t0 = time.perf_counter()
    jax.block_until_ready(eng.select(jf, k_small).indices)
    t_small = time.perf_counter() - t0
    resweep_s = t_small * (budget / k_small) * (n // chunk)
    stream_s = float(np.sum(steady)) + finalize_s
    _emit(
        f"streaming/vs_resweep/n{n}_k{budget}",
        resweep_s * 1e6,
        f"stream_total_s={stream_s:.2f} resweep_total_s={resweep_s:.2f} "
        f"speedup={resweep_s / max(stream_s, 1e-9):.1f}x extrapolated=True",
        n=n, budget=budget, stream_total_s=stream_s,
        resweep_total_s=resweep_s, extrapolated=True,
    )


def _objective_gate(n: int, chunk: int, d: int) -> None:
    budget = max(16, n // 20)
    feats = _pool(n, d, seed=1)
    sel = StreamingSelector(budget, d)
    for lo in range(0, n, chunk):
        sel.ingest(feats[lo : lo + chunk])
    res = sel.result(feats)

    dist = np.asarray(pairwise_distances(jnp.asarray(feats)))
    sim = dist.max() + 1e-6 - dist

    def obj(idx):
        mask = np.zeros(n, bool)
        mask[np.asarray(idx)] = True
        return float(
            fl.facility_location_value(jnp.asarray(sim), jnp.asarray(mask))
        )

    ref = fl.lazy_greedy_fl(sim, budget)
    ref_val = obj(ref.indices)
    ratio = obj(res.indices) / ref_val
    ok = ratio >= OBJ_GATE
    _emit(
        f"streaming/objective_ratio/n{n}_k{budget}",
        0.0,
        f"ratio={ratio:.3f} gate={OBJ_GATE} {'ok' if ok else 'FAIL'}",
        n=n, budget=budget, ratio=ratio, gate=OBJ_GATE,
    )
    if not ok:
        raise AssertionError(
            f"streaming objective ratio {ratio:.3f} below the {OBJ_GATE} gate"
        )

    # same stream, bounded memory: sieve-pool eviction after every delta
    # (live rows = what the sieves reference) must clear the same gate —
    # indices map back to global arrival positions through live_ids
    sel_e = StreamingSelector(budget, d, evict=True)
    pool = np.zeros((0, d), np.float32)
    for lo in range(0, n, chunk):
        delta = feats[lo : lo + chunk]
        sel_e.ingest(delta)
        pool = np.concatenate([pool, delta])
        pool = pool[sel_e.compact()]
    res_e = sel_e.result(pool)
    idx_e = sel_e.live_ids[np.asarray(res_e.indices, np.int64)]
    ratio_e = obj(idx_e) / ref_val
    ok_e = ratio_e >= OBJ_GATE
    _emit(
        f"streaming/objective_ratio_evict/n{n}_k{budget}",
        0.0,
        f"ratio={ratio_e:.3f} gate={OBJ_GATE} n_live={sel_e.n_rows}/{n} "
        f"{'ok' if ok_e else 'FAIL'}",
        n=n, budget=budget, ratio=ratio_e, gate=OBJ_GATE,
        n_live=sel_e.n_rows,
    )
    if not ok_e:
        raise AssertionError(
            f"evicted streaming objective ratio {ratio_e:.3f} below the "
            f"{OBJ_GATE} gate"
        )


def _write_json(smoke: bool) -> None:
    with open("BENCH_streaming.json", "w") as f:
        json.dump(
            {
                "schema": 1,
                "smoke": smoke,
                "backend": jax.default_backend(),
                "gates": {
                    "objective_ratio": OBJ_GATE,
                    "flat_tol": _FLAT_TOL,
                    "finalize_tol": _FINALIZE_TOL,
                },
                "records": _RECORDS,
            },
            f, indent=1,
        )


def run(smoke: bool = False) -> None:
    try:
        if smoke:
            _ingest_throughput(n=8192, chunk=1024, d=16)
        else:
            _ingest_throughput(n=50_000, chunk=2048, d=32)
        _objective_gate(n=4096, chunk=1024, d=16)
    finally:
        _write_json(smoke)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    run(smoke=ap.parse_args().smoke)
