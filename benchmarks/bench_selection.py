"""Selection-cost scaling: exact matrix vs lazy vs stochastic vs matrix-free
(§3.2's complexity ladder O(n·r) → O(n)), plus coverage-quality parity.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.craig import CraigConfig, CraigSelector


def run() -> None:
    rng = np.random.RandomState(0)
    for n in (512, 2048):
        feats = rng.randn(n, 32).astype(np.float32)
        base_cov = None
        for engine in ("matrix", "lazy", "stochastic", "features"):
            sel = CraigSelector(
                CraigConfig(fraction=0.05, engine=engine, per_class=False)
            )
            t0 = time.perf_counter()
            cs = sel.select(feats)
            jax.effects_barrier()
            dt = time.perf_counter() - t0
            if engine == "matrix":
                base_cov = cs.coverage
            emit(
                f"selection_{engine}_n{n}",
                dt * 1e6,
                f"coverage_ratio={cs.coverage/max(base_cov,1e-9):.3f};r={cs.size}",
            )


if __name__ == "__main__":
    run()
