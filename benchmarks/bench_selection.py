"""Selection-cost scaling across every registered engine (§3.2's complexity
ladder O(n·r) → O(n) → O(n·k); engine guide in README §Engines,
EXPERIMENTS.md §Selection), plus coverage-quality parity and a large-n
sparse run that the dense engines cannot hold.

Engines come from the SelectionEngine registry (``repro.core.engines``):
the ladder iterates ``list_engines()`` — a newly registered engine shows up
here with zero bench edits — and every record stamps the resolved
``EngineConfig`` dict into ``BENCH_selection.json``, so the perf trajectory
records exactly what ran.

Sections
--------
1. Ladder: every registered engine at moderate n, coverage ratio vs exact
   greedy (the matrix engine anchors the baseline).
2. Parity: sparse-vs-exact selection overlap and gradient-estimate error
   (γ-weighted proxy-feature sum vs the full-pool sum — the quantity the
   paper's Eq. 8 bounds) as SparseConfig.k grows.
3. Device ladder (DESIGN.md §3.6): `greedy_fl_device` vs `greedy_fl_features`
   on the same pool — q=1 exact-parity gate at moderate n, then wall-clock at
   n ≥ 20k where block greedy (q>1) amortizes the per-round sweep.  The
   derived column carries the speedup; the acceptance bar is ≥ 2×.
4. Large-n: sparse engine at REPRO_BENCH_LARGE_N points (default 200_000) —
   O(n·k) memory, no dense (n, n); dense engines are reported as skipped at
   this scale (a fp32 (n, n) matrix would need n²·4 bytes ≈ 160 GB).

``--engine SPEC`` (repeatable; typed form, e.g. ``device:q=16`` or
``sparse:k=64``) replaces the full suite with a focused ladder over the
given configs at ``--n`` points.  ``--smoke`` shrinks pool sizes to
CI-on-CPU scale (n=20k for the device ladder — the smallest size the
acceptance bar speaks about).  Every run writes ``BENCH_selection.json``
next to the CSV stdout so CI can upload the perf trajectory as an artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import facility_location as fl
from repro.core.craig import CraigConfig, CraigSelector
from repro.core.engines import (
    DeviceConfig,
    EngineConfig,
    SparseConfig,
    get_engine,
    list_engines,
    parse_engine_spec,
)

_RECORDS: list[dict] = []


def _emit(
    name: str, us_per_call: float, derived: str,
    engine: EngineConfig | None = None,
) -> None:
    emit(name, us_per_call, derived)
    _RECORDS.append(
        {
            "name": name,
            "us_per_call": us_per_call,
            "derived": derived,
            "engine": None if engine is None else engine.to_dict(),
        }
    )


def _default_config(name: str) -> EngineConfig:
    """Registry default config — no per-engine special-casing, so a newly
    registered engine rides the ladder with zero bench edits."""
    return get_engine(name).config_cls()


def _select(engine_cfg: EngineConfig, feats: np.ndarray, fraction: float):
    sel = CraigSelector(
        CraigConfig(fraction=fraction, engine=engine_cfg, per_class=False)
    )
    t0 = time.perf_counter()
    cs = sel.select(feats)
    jax.effects_barrier()
    return cs, time.perf_counter() - t0


def _timed(fn):
    """(result, seconds) with one same-shape warmup so jit compile time does
    not pollute the engine comparison."""
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn())
    return out, time.perf_counter() - t0


def _ladder(rng: np.random.RandomState) -> None:
    # matrix first: it anchors the coverage-ratio baseline
    names = sorted(list_engines(), key=lambda s: s != "matrix")
    for n in (512, 2048):
        feats = rng.randn(n, 32).astype(np.float32)
        base_cov = None
        for name in names:
            ec = _default_config(name)
            cs, dt = _select(ec, feats, 0.05)
            if name == "matrix":
                base_cov = cs.coverage
            _emit(
                f"selection_{name}_n{n}",
                dt * 1e6,
                f"coverage_ratio={cs.coverage/max(base_cov,1e-9):.3f};r={cs.size}",
                engine=ec,
            )


def _spec_ladder(specs: list[EngineConfig], n: int) -> None:
    """Focused --engine run: the given typed configs on one (n, 32) pool.

    The exact-greedy coverage baseline is only computed where the dense
    matrix engine is cheap (n ≤ 4096) — a focused run at device-ladder
    scale must not pay the O(r·n²) dense sweep it exists to avoid — and is
    reused from the spec list when the user already asked for matrix.
    """
    rng = np.random.RandomState(0)
    feats = rng.randn(n, 32).astype(np.float32)
    results = [(ec,) + _select(ec, feats, 0.05) for ec in specs]
    base_cov = next(
        (cs.coverage for ec, cs, _ in results if ec == _default_config("matrix")),
        None,
    )
    if base_cov is None and n <= 4096:
        base, _ = _select(_default_config("matrix"), feats, 0.05)
        base_cov = base.coverage
    for ec, cs, dt in results:
        ratio = (
            "n/a" if base_cov is None
            else f"{cs.coverage / max(base_cov, 1e-9):.3f}"
        )
        _emit(
            f"selection_{ec.name}_n{n}",
            dt * 1e6,
            f"coverage_ratio={ratio};r={cs.size}",
            engine=ec,
        )


def _sparse_parity(rng: np.random.RandomState) -> None:
    """Sparse-vs-exact: selection overlap + gradient-estimate error."""
    n = 2048
    centers = rng.randn(32, 32).astype(np.float32) * 4.0
    feats = centers[rng.randint(0, 32, n)] + rng.randn(n, 32).astype(
        np.float32
    )
    exact, _ = _select(_default_config("matrix"), feats, 0.05)
    full_grad = feats.sum(axis=0)

    def grad_err(cs) -> float:
        est = (cs.weights[:, None] * feats[cs.indices]).sum(axis=0)
        return float(
            np.linalg.norm(est - full_grad) / max(np.linalg.norm(full_grad), 1e-9)
        )

    err_exact = grad_err(exact)
    exact_set = set(exact.indices.tolist())
    for k in (16, 64, 256):
        ec = SparseConfig(k=k)
        cs, dt = _select(ec, feats, 0.05)
        overlap = len(exact_set & set(cs.indices.tolist())) / len(exact_set)
        _emit(
            f"sparse_parity_k{k}_n{n}",
            dt * 1e6,
            f"overlap={overlap:.3f};grad_err={grad_err(cs):.4f};"
            f"grad_err_exact={err_exact:.4f};"
            f"coverage_ratio={cs.coverage/max(exact.coverage,1e-9):.3f}",
            engine=ec,
        )


def _device_ladder(rng: np.random.RandomState, smoke: bool) -> None:
    """Device engine vs the features engine (DESIGN.md §3.6).

    Parity gate: at moderate n, device q=1 selections are identical to exact
    greedy (the features engine).  Throughput gate: at n ≥ 20k, block greedy
    (q>1) must be ≥ 2× the features engine — the `speedup=` field is the
    acceptance number.
    """
    # -- exact-parity gate (q=1) --
    n_par = 2048
    feats = jax.numpy.asarray(rng.randn(n_par, 16).astype(np.float32))
    r_par = 32
    ref, _ = _timed(lambda: fl.greedy_fl_features(feats, r_par))
    for q in (1, 8):
        res, dt = _timed(lambda q=q: fl.greedy_fl_device(feats, r_par, q=q))
        ident = bool(
            np.array_equal(np.asarray(ref.indices), np.asarray(res.indices))
        )
        cov = float(res.coverage) / max(float(ref.coverage), 1e-9)
        _emit(
            f"device_parity_q{q}_n{n_par}",
            dt * 1e6,
            f"identical_to_exact={ident};coverage_ratio={cov:.4f}",
            engine=DeviceConfig(q=q),
        )
        if q == 1:
            assert ident, "device q=1 must reproduce exact greedy"

    # -- throughput gate (n >= 20k) --
    n = 20_000 if smoke else int(os.environ.get("REPRO_BENCH_DEVICE_N", 50_000))
    d = 8
    r = 16 if smoke else 64
    q = 16
    feats = jax.numpy.asarray(rng.randn(n, d).astype(np.float32))
    _, t_feat = _timed(lambda: fl.greedy_fl_features(feats, r))
    _emit(
        f"selection_features_n{n}", t_feat * 1e6, f"r={r}",
        engine=_default_config("features"),
    )
    for qq in (1, q):
        _, t_dev = _timed(
            lambda qq=qq: fl.greedy_fl_device(feats, r, q=qq)
        )
        _emit(
            f"selection_device_q{qq}_n{n}",
            t_dev * 1e6,
            f"r={r};speedup={t_feat / max(t_dev, 1e-9):.2f}x",
            engine=DeviceConfig(q=qq),
        )
    # bf16 tiles: same sweep with half the MXU/memory traffic per tile
    _, t_bf = _timed(
        lambda: fl.greedy_fl_device(feats, r, q=q, tile_dtype="bfloat16")
    )
    _emit(
        f"selection_device_q{q}_bf16_n{n}",
        t_bf * 1e6,
        f"r={r};speedup={t_feat / max(t_bf, 1e-9):.2f}x",
        engine=DeviceConfig(q=q, tile_dtype="bfloat16"),
    )


def _large_n(rng: np.random.RandomState, smoke: bool) -> None:
    default_n = 30_000 if smoke else 200_000
    n = int(os.environ.get("REPRO_BENCH_LARGE_N", default_n))
    k = int(os.environ.get("REPRO_BENCH_LARGE_K", "32"))
    feats = rng.randn(n, 16).astype(np.float32)
    # Dense/stochastic both materialize (n, n) sim; report why they're out.
    dense_gb = n * n * 4 / 2**30
    _emit(f"selection_matrix_n{n}", float("nan"), f"skipped_dense_{dense_gb:.0f}GB")
    _emit(f"selection_stochastic_n{n}", float("nan"), f"skipped_dense_{dense_gb:.0f}GB")
    ec = SparseConfig(k=k)
    cs, dt = _select(ec, feats, 50 / n)
    _emit(
        f"selection_sparse_n{n}",
        dt * 1e6,
        f"r={cs.size};k={k};mem_nk_mb={n*k*8/2**20:.0f}",
        engine=ec,
    )


def _write_json(smoke: bool) -> None:
    with open("BENCH_selection.json", "w") as f:
        json.dump(
            {
                "benchmark": "bench_selection",
                "schema": 2,  # records carry the resolved EngineConfig dict
                "smoke": smoke,
                "backend": jax.default_backend(),
                "engines": list(list_engines()),
                "records": _RECORDS,
            },
            f,
            indent=2,
        )


def run(smoke: bool = False, engine_specs: list[str] | None = None,
        n: int = 4096) -> None:
    _RECORDS.clear()
    if engine_specs:
        _spec_ladder([parse_engine_spec(s) for s in engine_specs], n)
        _write_json(smoke)
        return
    rng = np.random.RandomState(0)
    _ladder(rng)
    _sparse_parity(rng)
    _device_ladder(rng, smoke)
    _large_n(rng, smoke)
    _write_json(smoke)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-on-CPU scale: n=20k device ladder, 30k sparse large-n",
    )
    ap.add_argument(
        "--engine", action="append", metavar="SPEC",
        help="typed engine spec (repeatable), e.g. device:q=16 or "
             "sparse:k=64 — runs a focused ladder at --n instead of the "
             "full suite",
    )
    ap.add_argument(
        "--n", type=int, default=4096,
        help="pool size for the --engine focused ladder",
    )
    args = ap.parse_args()
    run(smoke=args.smoke, engine_specs=args.engine, n=args.n)
