"""Selection-cost scaling: exact matrix vs lazy vs stochastic vs matrix-free
vs sparse top-k (§3.2's complexity ladder O(n·r) → O(n) → O(n·k); engine
guide in README §Engines, EXPERIMENTS.md §Selection), plus coverage-quality
parity and a large-n sparse run that the dense engines cannot hold.

Sections
--------
1. Ladder: every engine at moderate n, coverage ratio vs exact greedy.
2. Parity: sparse-vs-exact selection overlap and gradient-estimate error
   (γ-weighted proxy-feature sum vs the full-pool sum — the quantity the
   paper's Eq. 8 bounds) as topk_k grows.
3. Large-n: sparse engine at REPRO_BENCH_LARGE_N points (default 200_000) —
   O(n·k) memory, no dense (n, n); dense engines are reported as skipped at
   this scale (a fp32 (n, n) matrix would need n²·4 bytes ≈ 160 GB).
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.craig import CraigConfig, CraigSelector


def _select(engine: str, feats: np.ndarray, fraction: float, **kw):
    sel = CraigSelector(
        CraigConfig(fraction=fraction, engine=engine, per_class=False, **kw)
    )
    t0 = time.perf_counter()
    cs = sel.select(feats)
    jax.effects_barrier()
    return cs, time.perf_counter() - t0


def _ladder(rng: np.random.RandomState) -> None:
    for n in (512, 2048):
        feats = rng.randn(n, 32).astype(np.float32)
        base_cov = None
        for engine in ("matrix", "lazy", "stochastic", "features", "sparse"):
            cs, dt = _select(engine, feats, 0.05, topk_k=min(64, n))
            if engine == "matrix":
                base_cov = cs.coverage
            emit(
                f"selection_{engine}_n{n}",
                dt * 1e6,
                f"coverage_ratio={cs.coverage/max(base_cov,1e-9):.3f};r={cs.size}",
            )


def _sparse_parity(rng: np.random.RandomState) -> None:
    """Sparse-vs-exact: selection overlap + gradient-estimate error."""
    n = 2048
    centers = rng.randn(32, 32).astype(np.float32) * 4.0
    feats = centers[rng.randint(0, 32, n)] + rng.randn(n, 32).astype(
        np.float32
    )
    exact, _ = _select("matrix", feats, 0.05)
    full_grad = feats.sum(axis=0)

    def grad_err(cs) -> float:
        est = (cs.weights[:, None] * feats[cs.indices]).sum(axis=0)
        return float(
            np.linalg.norm(est - full_grad) / max(np.linalg.norm(full_grad), 1e-9)
        )

    err_exact = grad_err(exact)
    exact_set = set(exact.indices.tolist())
    for k in (16, 64, 256):
        cs, dt = _select("sparse", feats, 0.05, topk_k=k)
        overlap = len(exact_set & set(cs.indices.tolist())) / len(exact_set)
        emit(
            f"sparse_parity_k{k}_n{n}",
            dt * 1e6,
            f"overlap={overlap:.3f};grad_err={grad_err(cs):.4f};"
            f"grad_err_exact={err_exact:.4f};"
            f"coverage_ratio={cs.coverage/max(exact.coverage,1e-9):.3f}",
        )


def _large_n(rng: np.random.RandomState) -> None:
    n = int(os.environ.get("REPRO_BENCH_LARGE_N", "200000"))
    k = int(os.environ.get("REPRO_BENCH_LARGE_K", "32"))
    feats = rng.randn(n, 16).astype(np.float32)
    # Dense/stochastic both materialize (n, n) sim; report why they're out.
    dense_gb = n * n * 4 / 2**30
    emit(f"selection_matrix_n{n}", float("nan"), f"skipped_dense_{dense_gb:.0f}GB")
    emit(f"selection_stochastic_n{n}", float("nan"), f"skipped_dense_{dense_gb:.0f}GB")
    cs, dt = _select("sparse", feats, 50 / n, topk_k=k)
    emit(
        f"selection_sparse_n{n}",
        dt * 1e6,
        f"r={cs.size};k={k};mem_nk_mb={n*k*8/2**20:.0f}",
    )


def run() -> None:
    rng = np.random.RandomState(0)
    _ladder(rng)
    _sparse_parity(rng)
    _large_n(rng)


if __name__ == "__main__":
    run()
