"""Selection-cost scaling: exact matrix vs lazy vs stochastic vs matrix-free
vs sparse top-k vs device-resident fused greedy (§3.2's complexity ladder
O(n·r) → O(n) → O(n·k); engine guide in README §Engines, EXPERIMENTS.md
§Selection), plus coverage-quality parity and a large-n sparse run that the
dense engines cannot hold.

Sections
--------
1. Ladder: every engine at moderate n, coverage ratio vs exact greedy.
2. Parity: sparse-vs-exact selection overlap and gradient-estimate error
   (γ-weighted proxy-feature sum vs the full-pool sum — the quantity the
   paper's Eq. 8 bounds) as topk_k grows.
3. Device ladder (DESIGN.md §3.6): `greedy_fl_device` vs `greedy_fl_features`
   on the same pool — q=1 exact-parity gate at moderate n, then wall-clock at
   n ≥ 20k where block greedy (q>1) amortizes the per-round sweep.  The
   derived column carries the speedup; the acceptance bar is ≥ 2×.
4. Large-n: sparse engine at REPRO_BENCH_LARGE_N points (default 200_000) —
   O(n·k) memory, no dense (n, n); dense engines are reported as skipped at
   this scale (a fp32 (n, n) matrix would need n²·4 bytes ≈ 160 GB).

``--smoke`` shrinks pool sizes to CI-on-CPU scale (n=20k for the device
ladder — the smallest size the acceptance bar speaks about) and every run
writes ``BENCH_selection.json`` next to the CSV stdout so CI can upload the
perf trajectory as an artifact.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import facility_location as fl
from repro.core.craig import CraigConfig, CraigSelector

_RECORDS: list[dict] = []


def _emit(name: str, us_per_call: float, derived: str) -> None:
    emit(name, us_per_call, derived)
    _RECORDS.append(
        {"name": name, "us_per_call": us_per_call, "derived": derived}
    )


def _select(engine: str, feats: np.ndarray, fraction: float, **kw):
    sel = CraigSelector(
        CraigConfig(fraction=fraction, engine=engine, per_class=False, **kw)
    )
    t0 = time.perf_counter()
    cs = sel.select(feats)
    jax.effects_barrier()
    return cs, time.perf_counter() - t0


def _timed(fn):
    """(result, seconds) with one same-shape warmup so jit compile time does
    not pollute the engine comparison."""
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    out = jax.block_until_ready(fn())
    return out, time.perf_counter() - t0


def _ladder(rng: np.random.RandomState) -> None:
    for n in (512, 2048):
        feats = rng.randn(n, 32).astype(np.float32)
        base_cov = None
        for engine in (
            "matrix", "lazy", "stochastic", "features", "sparse", "device"
        ):
            cs, dt = _select(engine, feats, 0.05, topk_k=min(64, n))
            if engine == "matrix":
                base_cov = cs.coverage
            _emit(
                f"selection_{engine}_n{n}",
                dt * 1e6,
                f"coverage_ratio={cs.coverage/max(base_cov,1e-9):.3f};r={cs.size}",
            )


def _sparse_parity(rng: np.random.RandomState) -> None:
    """Sparse-vs-exact: selection overlap + gradient-estimate error."""
    n = 2048
    centers = rng.randn(32, 32).astype(np.float32) * 4.0
    feats = centers[rng.randint(0, 32, n)] + rng.randn(n, 32).astype(
        np.float32
    )
    exact, _ = _select("matrix", feats, 0.05)
    full_grad = feats.sum(axis=0)

    def grad_err(cs) -> float:
        est = (cs.weights[:, None] * feats[cs.indices]).sum(axis=0)
        return float(
            np.linalg.norm(est - full_grad) / max(np.linalg.norm(full_grad), 1e-9)
        )

    err_exact = grad_err(exact)
    exact_set = set(exact.indices.tolist())
    for k in (16, 64, 256):
        cs, dt = _select("sparse", feats, 0.05, topk_k=k)
        overlap = len(exact_set & set(cs.indices.tolist())) / len(exact_set)
        _emit(
            f"sparse_parity_k{k}_n{n}",
            dt * 1e6,
            f"overlap={overlap:.3f};grad_err={grad_err(cs):.4f};"
            f"grad_err_exact={err_exact:.4f};"
            f"coverage_ratio={cs.coverage/max(exact.coverage,1e-9):.3f}",
        )


def _device_ladder(rng: np.random.RandomState, smoke: bool) -> None:
    """Device engine vs the features engine (DESIGN.md §3.6).

    Parity gate: at moderate n, device q=1 selections are identical to exact
    greedy (the features engine).  Throughput gate: at n ≥ 20k, block greedy
    (q>1) must be ≥ 2× the features engine — the `speedup=` field is the
    acceptance number.
    """
    # -- exact-parity gate (q=1) --
    n_par = 2048
    feats = jax.numpy.asarray(rng.randn(n_par, 16).astype(np.float32))
    r_par = 32
    ref, _ = _timed(lambda: fl.greedy_fl_features(feats, r_par))
    for q in (1, 8):
        res, dt = _timed(lambda q=q: fl.greedy_fl_device(feats, r_par, q=q))
        ident = bool(
            np.array_equal(np.asarray(ref.indices), np.asarray(res.indices))
        )
        cov = float(res.coverage) / max(float(ref.coverage), 1e-9)
        _emit(
            f"device_parity_q{q}_n{n_par}",
            dt * 1e6,
            f"identical_to_exact={ident};coverage_ratio={cov:.4f}",
        )
        if q == 1:
            assert ident, "device q=1 must reproduce exact greedy"

    # -- throughput gate (n >= 20k) --
    n = 20_000 if smoke else int(os.environ.get("REPRO_BENCH_DEVICE_N", 50_000))
    d = 8
    r = 16 if smoke else 64
    q = 16
    feats = jax.numpy.asarray(rng.randn(n, d).astype(np.float32))
    _, t_feat = _timed(lambda: fl.greedy_fl_features(feats, r))
    _emit(f"selection_features_n{n}", t_feat * 1e6, f"r={r}")
    for qq in (1, q):
        _, t_dev = _timed(
            lambda qq=qq: fl.greedy_fl_device(feats, r, q=qq)
        )
        _emit(
            f"selection_device_q{qq}_n{n}",
            t_dev * 1e6,
            f"r={r};speedup={t_feat / max(t_dev, 1e-9):.2f}x",
        )
    # bf16 tiles: same sweep with half the MXU/memory traffic per tile
    _, t_bf = _timed(
        lambda: fl.greedy_fl_device(feats, r, q=q, tile_dtype="bfloat16")
    )
    _emit(
        f"selection_device_q{q}_bf16_n{n}",
        t_bf * 1e6,
        f"r={r};speedup={t_feat / max(t_bf, 1e-9):.2f}x",
    )


def _large_n(rng: np.random.RandomState, smoke: bool) -> None:
    default_n = 30_000 if smoke else 200_000
    n = int(os.environ.get("REPRO_BENCH_LARGE_N", default_n))
    k = int(os.environ.get("REPRO_BENCH_LARGE_K", "32"))
    feats = rng.randn(n, 16).astype(np.float32)
    # Dense/stochastic both materialize (n, n) sim; report why they're out.
    dense_gb = n * n * 4 / 2**30
    _emit(f"selection_matrix_n{n}", float("nan"), f"skipped_dense_{dense_gb:.0f}GB")
    _emit(f"selection_stochastic_n{n}", float("nan"), f"skipped_dense_{dense_gb:.0f}GB")
    cs, dt = _select("sparse", feats, 50 / n, topk_k=k)
    _emit(
        f"selection_sparse_n{n}",
        dt * 1e6,
        f"r={cs.size};k={k};mem_nk_mb={n*k*8/2**20:.0f}",
    )


def run(smoke: bool = False) -> None:
    _RECORDS.clear()
    rng = np.random.RandomState(0)
    _ladder(rng)
    _sparse_parity(rng)
    _device_ladder(rng, smoke)
    _large_n(rng, smoke)
    with open("BENCH_selection.json", "w") as f:
        json.dump(
            {
                "benchmark": "bench_selection",
                "smoke": smoke,
                "backend": jax.default_backend(),
                "records": _RECORDS,
            },
            f,
            indent=2,
        )


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-on-CPU scale: n=20k device ladder, 30k sparse large-n",
    )
    run(smoke=ap.parse_args().smoke)
