"""Refresh-off-critical-path benchmark (DESIGN.md §4 lifecycle).

Sections
--------
1. Steps/s with CRAIG refresh disabled / sync (selection blocks the step
   loop) / async (selection overlapped with training, installed at the next
   epoch boundary).  The derived column reports the share of selection
   wall-clock removed from the critical path — the async run should keep
   ≥80% of it off the loop and land within ~10% of the refresh-disabled
   steps/s.
2. Warm vs cold greedy selection wall-clock on fixed features, with the
   exact-parity check (warm-started indices == cold indices — prefix
   consistency of exact greedy).

``--engine SPEC`` runs the refresh loop with any registered engine in the
typed spec form (e.g. ``device:q=16``, ``sparse:k=32``); the default is the
host lazy greedy.  ``--smoke`` shrinks everything to CI-on-CPU scale
(seconds); the GitHub Actions workflow runs it on every PR so the overlap
path stays exercised.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.craig import CraigConfig
from repro.core.engines import (
    EngineConfig,
    LazyConfig,
    get_engine,
    make_engine,
    parse_engine_spec,
)
from repro.data.synthetic import TokenStream
from repro.models import ModelConfig, init_params
from repro.optim import adamw, constant
from repro.train import Trainer, TrainerConfig

_CFG = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv_heads=2, d_ff=64, vocab_size=128, logit_chunk=16,
)


def _trainer(
    mode: str, use_craig: bool, n_docs: int, pool_batches: int,
    engine_cfg: EngineConfig,
):
    ds = TokenStream(n_docs=n_docs, seq_len=24, vocab_size=128, n_topics=8)
    tcfg = TrainerConfig(
        batch_size=8,
        select_every_epochs=1,
        use_craig=use_craig,
        refresh_mode=mode,  # ignored when use_craig=False
        # fraction 0.5 keeps coreset epochs longer than one selection pass,
        # so the async window fully hides extraction + greedy
        craig=CraigConfig(fraction=0.5, per_class=False, engine=engine_cfg),
        proxy_pool_batches=pool_batches,
    )
    return Trainer(
        _CFG, tcfg, ds, adamw(constant(2e-3)),
        lambda: init_params(jax.random.PRNGKey(0), _CFG),
    )


def _critical_path_s(log: list[dict], mode: str, min_version: int) -> float:
    """Selection seconds the step loop actually waited on inside the timed
    window.

    sync: the whole selection runs inline at the trigger boundary — count
    only versions submitted inside the window (``> min_version``; the
    warmup-era selection's work predates the timer even though its install
    event lands inside it).  The window's last submitted selection installs
    after the window and goes uncounted, which *under*states the sync
    critical path — the removal metric is conservative.  async: only the
    residual wait at each install boundary blocks.
    """
    refreshes = [m for m in log if m["event"] == "craig_refresh"]
    if mode == "sync":
        return float(
            sum(
                m["select_time_s"]
                for m in refreshes
                if m["version"] > min_version
            )
        )
    return float(sum(m["install_stall_s"] for m in refreshes))


def _steps_per_s(
    n_docs: int, pool_batches: int, n_steps: int, engine_cfg: EngineConfig
) -> None:
    runs: dict[str, tuple[float, float]] = {}
    for name, mode, use_craig in (
        ("disabled", "sync", False),
        ("sync", "sync", True),
        ("async", "async", True),
    ):
        t = _trainer(mode, use_craig, n_docs, pool_batches, engine_cfg)
        t.run(2)  # compile train_step (+ select_step on the refresh paths)
        t.refresher.wait()
        base = len(t.metrics_log)  # run() logs cumulatively — slice to the
        v0 = t.refresher.version   # events/versions of the timed window only
        t0 = time.perf_counter()
        log = t.run(n_steps)[base:]
        wall = time.perf_counter() - t0
        t.refresher.wait()  # drain so the worker can't bleed into later runs
        runs[name] = (n_steps / wall, _critical_path_s(log, mode, v0))
        n_refresh = len(
            [m for m in log if m["event"] == "craig_refresh"]
        )
        emit(
            f"refresh/steps_per_s/{name}/n{n_docs}",
            wall / n_steps * 1e6,
            f"steps_per_s={n_steps / wall:.2f} refreshes={n_refresh} "
            f"critical_path_select_s={runs[name][1]:.3f}",
        )
    sync_crit, async_crit = runs["sync"][1], runs["async"][1]
    removed = 1.0 - async_crit / sync_crit if sync_crit > 0 else float("nan")
    ratio = runs["async"][0] / runs["disabled"][0]
    emit(
        f"refresh/overlap/n{n_docs}",
        0.0,
        f"selection_removed_from_critical_path={removed:.1%} "
        f"async_vs_disabled_steps_per_s={ratio:.2f}",
    )


def _warm_vs_cold(n: int, r: int, engine_cfg: EngineConfig) -> None:
    feats = np.random.RandomState(0).randn(n, 32).astype(np.float32)
    eng = make_engine(engine_cfg)

    def run_once(init=None):
        t0 = time.perf_counter()
        res = eng.select(feats, r, init_selected=init, rng=0)
        np.asarray(res.indices)  # sync
        return res, time.perf_counter() - t0

    run_once()  # warm up jit for the device/features engines
    cold, t_cold = run_once()
    warm, t_warm = run_once(np.asarray(cold.indices)[: r // 2])
    parity = bool(
        np.array_equal(np.asarray(cold.indices), np.asarray(warm.indices))
    )
    # warm == cold holds only for deterministic exact greedy (prefix
    # consistency) — registry-driven via Capabilities.exact (which speaks
    # for the default config), tightened by the block-greedy knobs: q>1
    # with stale_tol<1 re-checks bounds in a different order after the
    # prefix, so parity is not promised there
    expect_parity = get_engine(engine_cfg.name).capabilities.exact and (
        getattr(engine_cfg, "q", 1) == 1
        or getattr(engine_cfg, "stale_tol", 1.0) == 1.0
    )
    emit(
        f"refresh/warm_vs_cold/{engine_cfg.name}/n{n}_r{r}",
        t_warm * 1e6,
        f"cold_us={t_cold * 1e6:.0f} speedup={t_cold / max(t_warm, 1e-9):.2f}x "
        f"parity={'ok' if parity else ('FAIL' if expect_parity else 'n/a')}",
    )
    if expect_parity and not parity:
        raise AssertionError("warm-started selection diverged from cold")


def _engine_tag(ec: EngineConfig) -> str:
    """Comma-free provenance tag for the CSV derived column:
    ``device[q=16;stale_tol=0.8;...]``."""
    knobs = ";".join(
        f"{k}={v}" for k, v in ec.to_dict().items() if k != "name"
    )
    return ec.name + (f"[{knobs}]" if knobs else "")


def run(smoke: bool = False, engine_spec: str | None = None) -> None:
    engine_cfg = (
        LazyConfig() if engine_spec is None else parse_engine_spec(engine_spec)
    )
    # provenance rides the CSV contract (name,us_per_call,derived) via
    # emit(), not a raw print that would corrupt benchmarks/run.py's stream
    emit("refresh/engine", 0.0, f"engine={_engine_tag(engine_cfg)}")
    if smoke:
        _steps_per_s(n_docs=96, pool_batches=12, n_steps=48,
                     engine_cfg=engine_cfg)
        _warm_vs_cold(n=300, r=30, engine_cfg=engine_cfg)
    else:
        _steps_per_s(n_docs=512, pool_batches=64, n_steps=128,
                     engine_cfg=engine_cfg)
        _warm_vs_cold(n=2000, r=200, engine_cfg=engine_cfg)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true",
        help="CI-sized run (CPU, seconds)",
    )
    ap.add_argument(
        "--engine", metavar="SPEC", default=None,
        help="typed engine spec for the refresh selection, e.g. "
             "device:q=16 or sparse:k=32 (default: the host lazy greedy)",
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke, engine_spec=args.engine)
