"""Paper §3.2 / Eq. 13: the greedy order concentrates approximation quality
in the prefix — the first elements of the CRAIG ordering reduce the gradient
estimation error the most, so early IG updates approach w* fastest.

Measures normalized gradient-estimation error of greedy-order prefixes vs
random-order prefixes of the same CRAIG subset.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, logreg_problem
from repro.core import facility_location as fl
from repro.core.craig import pairwise_distances
from repro.core.proxy import exact_per_example_grads


def run() -> None:
    X, ybin, y, _, _, _ = logreg_problem(n=400, d=12)
    n = X.shape[0]
    lam = 1e-5

    def loss_one(w, xi, yi):
        return jnp.log1p(jnp.exp(-yi * (xi @ w))) + 0.5 * lam * w @ w

    t0 = time.perf_counter()
    dist = pairwise_distances(X)
    sim = jnp.max(dist) + 1e-6 - dist
    res = fl.greedy_fl_matrix(sim, 60)  # greedy order (nested prefixes)
    sel_us = (time.perf_counter() - t0) * 1e6

    w = jax.random.normal(jax.random.PRNGKey(0), (X.shape[1],)) * 0.5
    grads = exact_per_example_grads(loss_one, w, X, ybin)
    full = jnp.sum(grads, axis=0)
    norm = float(jnp.linalg.norm(full))

    rng = np.random.RandomState(0)
    shuffled = rng.permutation(np.asarray(res.indices))
    parts = []
    for k in (10, 20, 40, 60):
        def err(idx):
            idxj = jnp.asarray(np.asarray(idx[:k]), jnp.int32)
            _, wts = fl.assign_and_weights(dist[:, idxj])
            g = jnp.sum(grads[idxj] * wts[:, None], 0)
            return float(jnp.linalg.norm(full - g)) / norm

        e_g = err(np.asarray(res.indices))
        e_r = err(shuffled)
        parts.append(f"k{k}:greedy={e_g:.3f},shuf={e_r:.3f}")
    emit("eq13_greedy_order_prefix", sel_us, ";".join(parts))


if __name__ == "__main__":
    run()
