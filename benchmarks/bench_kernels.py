"""Kernel micro-benchmarks: Pallas (interpret on CPU) vs pure-jnp oracle.

On CPU the interpreter is *slower* than jnp — the number that matters here is
correctness-at-scale + the analytic VMEM/MXU accounting printed as `derived`;
real speed comes from the TPU backend (interpret=False).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels import ops, ref


def run() -> None:
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)

    # fl_gains at a realistic per-shard selection step
    n, m, d = 2048, 512, 128
    x = jax.random.normal(k1, (n, d))
    e = jax.random.normal(k2, (m, d))
    cur = jnp.zeros(n)
    sqx, sqe = jnp.sum(x * x, 1), jnp.sum(e * e, 1)
    dmax = jnp.float32(50.0)
    t_ref = time_fn(
        jax.jit(lambda: ref.fl_gains_ref(x, e, cur, dmax).block_until_ready()
                if False else ref.fl_gains_ref(x, e, cur, dmax))
    )
    t_pal = time_fn(lambda: ops.fl_gains(x, e, cur, sqx, sqe, dmax))
    vmem_mb = (512 * d + 256 * d + 512 * 256) * 4 / 2**20
    emit(
        "kernel_fl_gains_2048x512x128",
        t_pal,
        f"ref_us={t_ref:.0f};tile=(512,256);vmem_tile_mb={vmem_mb:.1f};"
        f"mxu_dims_128_aligned=True",
    )

    # pairwise_l2
    t_ref = time_fn(jax.jit(lambda: ref.pairwise_l2_ref(x, e)))
    t_pal = time_fn(lambda: ops.pairwise_l2(x, e))
    emit("kernel_pairwise_l2_2048x512x128", t_pal, f"ref_us={t_ref:.0f}")

    # ce_proxy at LM-ish head shape (scaled for CPU)
    T, D, V = 256, 128, 4096
    h = jax.random.normal(k3, (T, D)) * 0.3
    w = jax.random.normal(k1, (D, V)) * 0.05
    y = jax.random.randint(k2, (T,), 0, V)
    t_ref = time_fn(jax.jit(lambda: ref.ce_proxy_ref(h, w, y)))
    t_pal = time_fn(lambda: ops.ce_proxy(h, w, y))
    emit(
        "kernel_ce_proxy_256x128x4096",
        t_pal,
        f"ref_us={t_ref:.0f};no_TV_materialization=True;"
        f"vocab_blocks={V//512}",
    )


if __name__ == "__main__":
    run()
