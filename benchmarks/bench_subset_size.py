"""Paper Fig 3: speedup (gradient evaluations to target) vs subset size
10%..90% on the Ijcnn1-like synthetic problem.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import craig_subset, emit, logreg_problem
from repro.optim import ig_run

EPOCHS = 25


def run() -> None:
    X, ybin, y, grad_one, full_loss, _ = logreg_problem(n=1000, d=22, seed=1)
    n, d = X.shape
    sched = lambda k: 0.5 / (n * (1 + 0.2 * k))
    _, tr_full = ig_run(
        grad_one, jnp.zeros(d), jnp.arange(n), jnp.ones(n), sched, EPOCHS
    )
    losses_full = [full_loss(w) for w in tr_full]
    target = losses_full[-1] * 1.01
    k_full = next((k + 1 for k, l in enumerate(losses_full) if l <= target), EPOCHS)

    best = (0.0, None)
    for frac in (0.1, 0.3, 0.5, 0.7, 0.9):
        cs, sel_s = craig_subset(X, y, frac)
        _, tr = ig_run(
            grad_one, jnp.zeros(d), jnp.asarray(cs.indices, jnp.int32),
            jnp.asarray(cs.weights), sched, int(EPOCHS * 1.8),
        )
        losses = [full_loss(w) for w in tr]
        k = next((i + 1 for i, l in enumerate(losses) if l <= target), None)
        if k is None:
            emit(f"fig3_subset_{int(frac*100)}pct", sel_s * 1e6, "speedup=dnf")
            continue
        speedup = (k_full * n) / (k * cs.size)
        if speedup > best[0]:
            best = (speedup, frac)
        emit(
            f"fig3_subset_{int(frac*100)}pct",
            sel_s * 1e6,
            f"speedup_gradevals={speedup:.2f}x;epochs={k};final={losses[-1]:.4f}",
        )
    emit(
        "fig3_best",
        0.0,
        f"best_speedup={best[0]:.2f}x@{int((best[1] or 0)*100)}pct",
    )


if __name__ == "__main__":
    run()
