"""Paper Fig 2: normed full-gradient estimation error — CRAIG subset vs
random subsets vs the ε̂ bound (Eq. 15), sampled at random parameter points,
normalized by the largest full-gradient norm.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import craig_subset, emit, logreg_problem
from repro.core.proxy import exact_per_example_grads

FRACTION = 0.1


def run() -> None:
    X, ybin, y, _, _, _ = logreg_problem(n=800, d=16)
    n, d = X.shape
    lam = 1e-5

    def loss_one(w, xi, yi):
        return jnp.log1p(jnp.exp(-yi * (xi @ w))) + 0.5 * lam * w @ w

    t0 = time.perf_counter()
    cs, _ = craig_subset(X, y, FRACTION)
    sel_us = (time.perf_counter() - t0) * 1e6

    rng = np.random.RandomState(0)
    errs_craig, errs_rand, full_norms, w_norms = [], [], [], []
    for seed in range(8):
        w = jax.random.normal(jax.random.PRNGKey(seed), (d,)) * 0.5
        w_norms.append(float(jnp.linalg.norm(w)))
        grads = exact_per_example_grads(loss_one, w, X, ybin)
        full = jnp.sum(grads, 0)
        full_norms.append(float(jnp.linalg.norm(full)))
        g_c = jnp.sum(
            grads[jnp.asarray(cs.indices)] * jnp.asarray(cs.weights)[:, None], 0
        )
        errs_craig.append(float(jnp.linalg.norm(full - g_c)))
        r_errs = []
        for _ in range(4):
            ridx = rng.choice(n, cs.size, replace=False)
            g_r = jnp.sum(grads[ridx], 0) * (n / cs.size)
            r_errs.append(float(jnp.linalg.norm(full - g_r)))
        errs_rand.append(float(np.mean(r_errs)))

    norm = max(full_norms)
    emit(
        "fig2_grad_error",
        sel_us,
        f"craig_err={np.mean(errs_craig)/norm:.4f};"
        f"rand_err={np.mean(errs_rand)/norm:.4f};"
        f"ratio={np.mean(errs_rand)/max(np.mean(errs_craig),1e-9):.2f}x;"
        f"eps_hat_normalized={cs.epsilon_hat/norm:.4f};"
        # Eq. 9: err ≤ O(‖w‖)·L(S); the constant here is sup ‖w‖ (‖x‖≤1)
        f"bound_holds={np.mean(errs_craig) <= max(w_norms) * cs.epsilon_hat * 1.05}",
    )


if __name__ == "__main__":
    run()
