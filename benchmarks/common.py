"""Shared benchmark utilities: timing, CSV emission, convex problem setup."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.craig import CraigConfig, CraigSelector
from repro.data.synthetic import make_classification
from repro.optim import ig_run

LAM = 1e-5


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall time (µs) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times) * 1e6)


# ---------------------------------------------------------------------------
# Convex experiment substrate (covtype-like synthetic, paper §5.1 scale-down)
# ---------------------------------------------------------------------------


def logreg_problem(n=2000, d=24, seed=0):
    x, y = make_classification(n, d, 2, seed=seed)
    x = x / np.abs(x).max()
    ybin = jnp.asarray(y * 2.0 - 1.0)
    X = jnp.asarray(x)

    def grad_one(w, i):
        xi, yi = X[i], ybin[i]
        s = jax.nn.sigmoid(-yi * (xi @ w))
        return -s * yi * xi + LAM * w

    def full_loss(w):
        z = -ybin * (X @ w)
        return float(jnp.mean(jnp.log1p(jnp.exp(z))) + 0.5 * LAM * w @ w)

    def test_error(w, Xt, yt):
        pred = jnp.sign(Xt @ w)
        return float(jnp.mean(pred != yt))

    return X, ybin, y, grad_one, full_loss, test_error


def craig_subset(X, labels, fraction, engine=None):
    """CRAIG per-class selection; ``engine`` is a typed EngineConfig
    (default: the dense exact matrix engine)."""
    from repro.core.engines import MatrixConfig

    sel = CraigSelector(
        CraigConfig(
            fraction=fraction, per_class=True,
            engine=MatrixConfig() if engine is None else engine,
        )
    )
    t0 = time.perf_counter()
    cs = sel.select(X, labels)
    return cs, time.perf_counter() - t0


def sgd_curve(grad_one, X, ybin, idx, weights, full_loss, epochs, lr0=0.5, b=0.2):
    """Returns (losses per epoch, grad evals per epoch)."""
    n = X.shape[0]
    _, trace = ig_run(
        grad_one,
        jnp.zeros(X.shape[1]),
        jnp.asarray(idx, jnp.int32),
        jnp.asarray(weights, jnp.float32),
        lambda k: lr0 / (n * (1 + b * k)),
        epochs,
    )
    return [full_loss(w) for w in trace], len(idx)
