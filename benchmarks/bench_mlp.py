"""Paper Fig 4 (scaled down): 1-hidden-layer MLP on clustered classification,
CRAIG 50% per-epoch re-selection vs random 50% vs full data — compares loss
reached per gradient evaluation and test accuracy.

Uses the §3.4 last-layer gradient proxy (p − y) with per-class selection —
exactly the paper's deep-net recipe.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.craig import CraigConfig, CraigSelector
from repro.core.proxy import classifier_last_layer_proxy
from repro.data.synthetic import make_classification

H, CLASSES, N, DIM = 32, 4, 600, 12
FRACTION = 0.5
EPOCHS = 30
BATCH = 10
LR = 0.05


def _init(key, dim=DIM, n_classes=CLASSES):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (dim, H)) * (1 / np.sqrt(dim)),
        "b1": jnp.zeros(H),
        "w2": jax.random.normal(k2, (H, n_classes)) * (1 / np.sqrt(H)),
        "b2": jnp.zeros(n_classes),
    }


def _logits(p, x):
    h = jax.nn.sigmoid(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def _loss(p, x, y, w):
    lp = jax.nn.log_softmax(_logits(p, x))
    nll = -jnp.take_along_axis(lp, y[:, None], 1)[:, 0]
    return jnp.sum(nll * w) / jnp.maximum(jnp.sum(w), 1e-6) + 1e-4 * (
        jnp.sum(p["w1"] ** 2) + jnp.sum(p["w2"] ** 2)
    )


@jax.jit
def _step(p, x, y, w):
    g = jax.grad(_loss)(p, x, y, w)
    return jax.tree.map(lambda a, b: a - LR * b, p, g)


def _train(x, y, xt, yt, mode, seed=0):
    rng = np.random.RandomState(seed)
    p = _init(jax.random.PRNGKey(seed))
    evals = 0
    for epoch in range(EPOCHS):
        if mode == "full":
            idx = rng.permutation(N)
            w = np.ones(N, np.float32)
        elif mode == "random":
            idx = rng.choice(N, int(N * FRACTION), replace=False)
            w = np.full(len(idx), 1.0, np.float32)
        else:  # craig, re-selected every epoch from last-layer proxies (§3.4)
            proxies = classifier_last_layer_proxy(_logits(p, jnp.asarray(x)), y)
            sel = CraigSelector(CraigConfig(fraction=FRACTION, per_class=True))
            cs = sel.select(np.asarray(proxies), y)
            idx = cs.indices
            w = cs.normalized_weights()
            order = rng.permutation(len(idx))
            idx, w = idx[order], w[order]
        for lo in range(0, len(idx) - BATCH + 1, BATCH):
            sl = idx[lo : lo + BATCH]
            p = _step(p, jnp.asarray(x[sl]), jnp.asarray(y[sl]), jnp.asarray(w[lo : lo + BATCH]))
            evals += BATCH
    acc = float(
        jnp.mean(jnp.argmax(_logits(p, jnp.asarray(xt)), -1) == jnp.asarray(yt))
    )
    loss = float(_loss(p, jnp.asarray(x), jnp.asarray(y), jnp.ones(N)))
    return loss, acc, evals


def run() -> None:
    x, y = make_classification(N + 200, DIM, CLASSES, seed=2)
    xt, yt = x[N:], y[N:]
    x, y = x[:N], y[:N]
    t0 = time.perf_counter()
    results = {m: _train(x, y, xt, yt, m) for m in ("full", "craig", "random")}
    us = (time.perf_counter() - t0) * 1e6 / 3
    lf, af, ef = results["full"]
    lc, ac, ec = results["craig"]
    lr_, ar, er = results["random"]
    emit(
        "fig4_mlp",
        us,
        f"loss_full={lf:.4f}@{ef}ev;loss_craig={lc:.4f}@{ec}ev;"
        f"loss_rand={lr_:.4f}@{er}ev;acc_full={af:.3f};acc_craig={ac:.3f};"
        f"acc_rand={ar:.3f};data_speedup={ef/ec:.2f}x;"
        f"craig_beats_rand={ac >= ar}",
    )


if __name__ == "__main__":
    run()
