"""Paper Fig 1: SGD/SVRG/SAGA on 10% CRAIG vs 10% random vs full data.

Protocol follows §5.1: each (method × arm) is tuned separately over a small
lr grid (k-inverse schedule), then we report epochs/grad-evaluations to a
common target loss = 1.01× the worse of the two tuned final losses.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import craig_subset, emit, logreg_problem
from repro.optim import ig_run, saga_run, svrg_run

RUNNERS = {"sgd": ig_run, "svrg": svrg_run, "saga": saga_run}
FRACTION = 0.1
EPOCHS = 30
LR_GRID = (0.5, 2.0, 8.0, 24.0)


def _tuned_curve(runner, grad_one, d, idx, weights, full_loss, n):
    best = None
    for lr0 in LR_GRID:
        sched = lambda k: lr0 / (n * (1 + 0.2 * k))
        _, tr = runner(
            grad_one, jnp.zeros(d), jnp.asarray(idx, jnp.int32),
            jnp.asarray(weights, jnp.float32), sched, EPOCHS,
        )
        losses = [full_loss(w) for w in tr]
        if not np.isfinite(losses[-1]):
            continue
        if best is None or losses[-1] < best[0]:
            best = (losses[-1], losses, lr0)
    return best  # (final, curve, lr0)


def run() -> None:
    X, ybin, y, grad_one, full_loss, _ = logreg_problem(n=1200, d=24)
    n, d = X.shape

    t0 = time.perf_counter()
    cs, sel_time = craig_subset(X, y, FRACTION)
    rw = np.full(cs.size, n / cs.size, np.float32)

    for name, runner in RUNNERS.items():
        f_full, c_full, lr_f = _tuned_curve(
            runner, grad_one, d, np.arange(n), np.ones(n), full_loss, n
        )
        t0 = time.perf_counter()
        f_craig, c_craig, lr_c = _tuned_curve(
            runner, grad_one, d, cs.indices, cs.weights, full_loss, n
        )
        t_craig = (time.perf_counter() - t0) / len(LR_GRID) + sel_time
        rand_finals = []
        for s_ in range(3):
            ridx_s = np.random.RandomState(s_).choice(n, cs.size, replace=False)
            fr, _, _ = _tuned_curve(
                runner, grad_one, d, ridx_s, rw, full_loss, n
            )
            rand_finals.append(fr)
        f_rand = float(np.mean(rand_finals))

        target = max(f_full, f_craig) * 1.01
        k_full = next(k + 1 for k, l in enumerate(c_full) if l <= target)
        k_craig = next(k + 1 for k, l in enumerate(c_craig) if l <= target)
        speedup = (k_full * n) / (k_craig * cs.size)
        emit(
            f"fig1_convex_{name}",
            t_craig / EPOCHS * 1e6,
            f"speedup_gradevals={speedup:.2f}x;"
            f"loss_full={f_full:.4f};loss_craig={f_craig:.4f};"
            f"loss_rand={f_rand:.4f};craig_beats_rand={f_craig < f_rand};"
            f"lr_full={lr_f};lr_craig={lr_c}",
        )


if __name__ == "__main__":
    run()
