"""Fault-tolerance benchmark (DESIGN.md §12): what robustness costs.

Sections
--------
1. ``faults/refresh_retry`` — trainer steps/s with every refresh job
   failing once (injected ``refresh.worker`` fault, ``every=2``) and
   retried under ``FailurePolicy(max_retries=1)``, vs the clean run.
   Gated: ratio ≥ ``RETRY_GATE`` (0.9) — retries ride the async worker,
   so a transient failure per job must not touch the step loop.  The
   run also asserts every selection eventually installed (no
   ``craig_refresh_failed`` events — the retry actually recovered).
2. ``faults/degraded_objective`` — facility-location objective of a
   quorum-degraded tree (3 of 4 leaves survive, selection over the
   surviving 3/4 of the pool) vs the full tree, BOTH evaluated on the
   FULL pool.  Gated: ratio ≥ ``DEGRADED_GATE`` (0.9) — losing one leaf
   at quorum 3/4 must not collapse coverage (CREST's subset-selection
   observation, PAPERS.md).  This is the host-driver model of what the
   tier-2 chaos lane exercises with real SIGKILLed processes.

Every run writes ``BENCH_faults.json``; ``--smoke`` keeps CI-on-CPU scale.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import facility_location as fl
from repro.core.craig import CraigConfig, pairwise_distances
from repro.distributed.tree_select import TreeTopology, tree_select_host
from repro.faults import FailurePolicy, FaultPlan, FaultSpec, injected

RETRY_GATE = 0.9  # injected/clean steps-per-s, floor
DEGRADED_GATE = 0.9  # F(3-of-4-leaf tree)/F(full tree) on the full pool
_RECORDS: list[dict] = []


def _emit(name: str, us: float, derived: str, **rec) -> None:
    emit(name, us, derived)
    _RECORDS.append({"name": name, "us_per_call": us, "derived": derived, **rec})


def _steps_per_s(n_docs: int, pool_batches: int, n_steps: int,
                 policy: FailurePolicy | None) -> tuple[float, list[dict]]:
    from repro.data.synthetic import TokenStream
    from repro.models import ModelConfig, init_params
    from repro.optim import adamw, constant
    from repro.train import Trainer, TrainerConfig

    cfg = ModelConfig(
        name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=128, logit_chunk=16,
    )
    ds = TokenStream(n_docs=n_docs, seq_len=24, vocab_size=128, n_topics=8)
    tcfg = TrainerConfig(
        batch_size=8, select_every_epochs=1, use_craig=True,
        refresh_mode="async", craig=CraigConfig(fraction=0.5, per_class=False),
        proxy_pool_batches=pool_batches, refresh_failure_policy=policy,
    )
    t = Trainer(cfg, tcfg, ds, adamw(constant(2e-3)),
                lambda: init_params(jax.random.PRNGKey(0), cfg))
    t.run(2)  # compile train_step + select_step
    t.refresher.wait()
    base = len(t.metrics_log)
    t0 = time.perf_counter()
    log = t.run(n_steps)[base:]
    wall = time.perf_counter() - t0
    t.refresher.wait()  # drain the worker before tearing the trainer down
    return n_steps / wall, log


def _retry_section(n_docs: int, pool_batches: int, n_steps: int) -> None:
    clean_sps, _ = _steps_per_s(n_docs, pool_batches, n_steps, None)
    # every job's first attempt fails (calls 1, 3, 5, … with one retry per
    # job), so each refresh succeeds exactly on its retry
    plan = FaultPlan(
        [FaultSpec(site="refresh.worker", kind="raise", every=2)], seed=0
    )
    policy = FailurePolicy(max_retries=1, backoff_base_s=0.01)
    with injected(plan):
        fault_sps, log = _steps_per_s(n_docs, pool_batches, n_steps, policy)
    refreshes = [m for m in log if m["event"] == "craig_refresh"]
    failures = [m for m in log if m["event"] == "craig_refresh_failed"]
    ratio = fault_sps / clean_sps
    ok = ratio >= RETRY_GATE and refreshes and not failures
    _emit(
        f"faults/refresh_retry/n{n_docs}",
        1e6 / fault_sps,
        f"injected/clean={ratio:.3f} gate={RETRY_GATE} "
        f"refreshes={len(refreshes)} failed={len(failures)} "
        f"{'ok' if ok else 'FAIL'}",
        n_docs=n_docs, n_steps=n_steps, clean_steps_per_s=clean_sps,
        injected_steps_per_s=fault_sps, ratio=ratio, gate=RETRY_GATE,
        n_refreshes=len(refreshes), n_failed=len(failures),
    )
    if not ok:
        raise AssertionError(
            f"refresh retry bench failed: ratio={ratio:.3f} (gate "
            f"{RETRY_GATE}), refreshes={len(refreshes)}, "
            f"unrecovered failures={len(failures)}"
        )


def _clustered_pool(n: int, d: int, seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    centers = rng.randn(8, d).astype(np.float32) * 4.0
    return (
        centers[rng.randint(0, 8, n)]
        + 0.5 * rng.randn(n, d).astype(np.float32)
    ).astype(np.float32)


def _objective_on(sim: np.ndarray, idx: np.ndarray) -> float:
    mask = np.zeros(sim.shape[0], bool)
    mask[np.asarray(idx)] = True
    return float(
        fl.facility_location_value(jnp.asarray(sim), jnp.asarray(mask))
    )


def _degraded_section(n: int, d: int, r_local: int, r_final: int) -> None:
    feats = _clustered_pool(n, d)
    # shard in pid order like the process driver: losing leaf 3 of 4
    # leaves the first 3 quarters of the pool (quorum 3/4)
    n_alive = 3 * (n // 4)
    full = tree_select_host(
        jnp.asarray(feats), TreeTopology((4,)), r_local, r_final
    )
    degraded = tree_select_host(
        jnp.asarray(feats[:n_alive]), TreeTopology((3,)), r_local, r_final
    )
    dist = np.asarray(pairwise_distances(jnp.asarray(feats)))
    sim = dist.max() + 1e-6 - dist  # one similarity matrix: the FULL pool
    f_full = _objective_on(sim, np.asarray(full.indices))
    f_degraded = _objective_on(sim, np.asarray(degraded.indices))
    ratio = f_degraded / max(f_full, 1e-9)
    ok = ratio >= DEGRADED_GATE
    _emit(
        f"faults/degraded_objective/n{n}_k{r_final}",
        0.0,
        f"degraded/full={ratio:.4f} gate={DEGRADED_GATE} quorum=3/4 "
        f"{'ok' if ok else 'FAIL'}",
        n=n, d=d, n_alive=n_alive, r_local=r_local, r_final=r_final,
        f_full=f_full, f_degraded=f_degraded, ratio=ratio,
        gate=DEGRADED_GATE, quorum=0.75,
    )
    if not ok:
        raise AssertionError(
            f"degraded-tree objective ratio {ratio:.4f} below the "
            f"{DEGRADED_GATE} gate at quorum 3/4"
        )


def _write_json(smoke: bool) -> None:
    with open("BENCH_faults.json", "w") as f:
        json.dump(
            {
                "schema": 1,
                "smoke": smoke,
                "backend": jax.default_backend(),
                "gates": {
                    "refresh_retry_ratio": RETRY_GATE,
                    "degraded_objective_ratio": DEGRADED_GATE,
                },
                "records": _RECORDS,
            },
            f, indent=1,
        )


def run(smoke: bool = False) -> None:
    try:
        if smoke:
            _retry_section(n_docs=96, pool_batches=12, n_steps=48)
            _degraded_section(n=512, d=32, r_local=16, r_final=24)
        else:
            _retry_section(n_docs=256, pool_batches=32, n_steps=96)
            _degraded_section(n=2048, d=32, r_local=32, r_final=48)
    finally:
        _write_json(smoke)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    print("name,us_per_call,derived")
    run(smoke=ap.parse_args().smoke)
