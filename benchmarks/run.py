"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  ``--smoke`` forwards to
every module whose ``run()`` accepts a ``smoke`` parameter (CI-on-CPU
scale); the rest run at their single scale.  The module → paper
figure/table mapping is documented in EXPERIMENTS.md §Benchmark-map;
roofline numbers come from ``python -m repro.roofline`` over the dry-run
artifacts (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import argparse
import inspect
import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_convex,
        bench_data_efficiency,
        bench_extract,
        bench_faults,
        bench_grad_error,
        bench_greedy_order,
        bench_kernels,
        bench_lm_pipeline,
        bench_mlp,
        bench_refresh,
        bench_selection,
        bench_streaming,
        bench_subset_size,
        bench_tree_select,
    )

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-on-CPU scale for the modules that support it")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    modules = [
        bench_convex,       # Fig 1
        bench_grad_error,   # Fig 2
        bench_subset_size,  # Fig 3
        bench_mlp,          # Fig 4
        bench_data_efficiency,  # Fig 5
        bench_greedy_order, # §3.2/Eq. 13 ordering property
        bench_selection,    # §3.2 complexity ladder + sparse top-k engine
        bench_kernels,      # Pallas hot-spots
        bench_lm_pipeline,  # §3.4 non-convex pipeline
        bench_extract,      # §3.4 proxy-extraction pipeline (DESIGN.md §9)
        bench_refresh,      # §3.4 refresh cadence off the critical path
        bench_streaming,    # §10 sieve-streaming ingest + objective gate
        bench_tree_select,  # §6 hierarchical tree: wire bytes + parity gates
        bench_faults,       # §12 fault model: retry overhead + degraded objective
    ]
    failed = 0
    for mod in modules:
        kw = {}
        if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
            kw["smoke"] = True
        try:
            mod.run(**kw)
        except Exception:  # noqa: BLE001 — report all benches even if one breaks
            failed += 1
            print(f"{mod.__name__},nan,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
