"""LM-path benchmark: CRAIG select→train pipeline on a tiny transformer —
the non-convex extension (§3.4/§5.2) exercising the production code path
(proxy_features → CraigSelector → weighted train_step).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.craig import CraigConfig
from repro.data.synthetic import TokenStream
from repro.models import ModelConfig, init_params, loss_fn
from repro.optim import adamw, constant
from repro.train import Trainer, TrainerConfig

CFG = ModelConfig(
    name="bench-lm", family="dense", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab_size=256, logit_chunk=16,
)


def run() -> None:
    ds = TokenStream(n_docs=64, seq_len=32, vocab_size=256, n_topics=8)

    def pool_loss(params):
        tot = 0.0
        for lo in range(0, 64, 16):
            _, m = loss_fn(params, CFG, ds.batch(np.arange(lo, lo + 16)))
            tot += float(m["loss"])
        return tot / 4

    results = {}
    for use_craig in (True, False):
        tcfg = TrainerConfig(
            batch_size=8,
            select_every_epochs=2 if use_craig else 0,
            use_craig=use_craig,
            craig=CraigConfig(fraction=0.5, per_class=False),
        )
        t = Trainer(CFG, tcfg, ds, adamw(constant(3e-3)),
                    lambda: init_params(jax.random.PRNGKey(0), CFG))
        t0 = time.perf_counter()
        t.run(16)
        dt = time.perf_counter() - t0
        results[use_craig] = (pool_loss(t.params), dt)
        sel = [m for m in t.metrics_log if m["event"] == "craig_refresh"]
        if use_craig:
            sel_s = sum(m["select_time_s"] for m in sel)
    (lc, tc), (lf, tf) = results[True], results[False]
    emit(
        "lm_pipeline_craig",
        tc / 16 * 1e6,
        f"loss_craig={lc:.4f};loss_full={lf:.4f};"
        f"select_overhead={sel_s/tc*100:.1f}%;distinct_data_used=50%",
    )


if __name__ == "__main__":
    run()
