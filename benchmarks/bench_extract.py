"""Extraction-pipeline benchmark (DESIGN.md §9; CREST's observation that at
scale the pool sweep, not the greedy, dominates coreset cost).

Sections
--------
1. Dispatch-bound ladder: proxy extraction over a pretokenized in-memory
   corpus (host batch assembly = an array gather, the memmapped-corpus
   regime) — per-batch baseline (one jitted dispatch + blocking host copy
   per pool batch, the pre-§9 ``Trainer._extract_pool`` loop) vs megabatch
   (``lax.scan``, O(1) programs) vs megabatch+prefetch.  The acceptance
   gate lives here: ≥2× pool-scan throughput at n_pool ≥ 4096 on CI CPU.
2. Host-bound overlap: the same ladder over a dataset with expensive host
   assembly (``TokenStream`` regenerates every example from its RNG) —
   the regime double-buffered prefetch targets; reported, not gated (on
   CPU the "device" computes on the same cores the assembly thread uses,
   so the overlap ceiling is machine-dependent).
3. Refresh-path parity: selections produced through the ProxyExtractor
   refresh path are bit-identical to the per-batch baseline's for fixed
   params, across ``refresh_mode='sync'`` and ``'async'`` — hard gate.

Every run writes ``BENCH_extract.json`` next to the CSV stdout (CI uploads
it alongside ``BENCH_selection.json``); ``--smoke`` keeps CI-on-CPU scale
while still covering the n_pool=4096 acceptance point.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.core.extract import ProxyExtractor
from repro.data.synthetic import TokenStream
from repro.models import ModelConfig, init_params
from repro.train import make_select_step

_RECORDS: list[dict] = []

# Deliberately small forward: the ladder measures the *pipeline* (dispatch
# count, host blocking, overlap), so per-dispatch compute must not drown it
# on CPU the way a TPU's fast device wouldn't be drowned by a real model.
_CFG = ModelConfig(
    name="tiny", family="dense", n_layers=1, d_model=32, n_heads=2,
    n_kv_heads=2, d_ff=64, vocab_size=64, logit_chunk=8,
)
_SEQ = 8


def _emit(name: str, us_per_call: float, derived: str, **extra) -> None:
    emit(name, us_per_call, derived)
    _RECORDS.append(
        {"name": name, "us_per_call": us_per_call, "derived": derived, **extra}
    )


class _TokenArray:
    """Pretokenized in-memory corpus: ``batch`` is a pure array gather —
    the cheap-host-assembly regime (production: memmapped token shards)."""

    def __init__(self, n: int, seq_len: int, vocab: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        toks = rng.integers(0, vocab, (n, seq_len + 1), dtype=np.int32)
        self.x, self.y = toks[:, :-1], toks[:, 1:]
        self.n_docs = n

    def batch(self, idx):
        idx = np.asarray(idx)
        return {"tokens": self.x[idx], "labels": self.y[idx]}


def _per_batch_baseline(step, ds, params, pool, bs):
    """The pre-§9 extraction loop: one jitted dispatch per pool batch,
    blocking ``np.asarray`` per batch, pad-then-drop on the tail."""
    jstep = jax.jit(step)
    feats = []
    for lo in range(0, len(pool), bs):
        chunk = pool[lo : lo + bs]
        if len(chunk) < bs:
            chunk = np.concatenate([chunk, pool[: bs - len(chunk)]])
        feats.append(np.asarray(jstep(params, ds.batch(chunk))))
    return np.concatenate(feats)[: len(pool)]


def _timed(fn, iters: int) -> float:
    """Best-of-iters wall time — min, not median: the ladder compares
    pipeline shapes on a shared CI box, and min is the standard
    noise-robust estimator for that."""
    fn()  # warmup/compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    return float(min(ts))


def _ladder(
    ds, tag: str, n_pool: int, bs: int, iters: int, gate: bool
) -> None:
    params = init_params(jax.random.PRNGKey(0), _CFG)
    step = make_select_step(_CFG)
    pool = np.arange(n_pool)
    t_base = _timed(lambda: _per_batch_baseline(step, ds, params, pool, bs), iters)
    _emit(
        f"extract/{tag}/per_batch/n{n_pool}", t_base / n_pool * 1e6,
        f"examples_per_s={n_pool / t_base:.0f} dispatches={-(-n_pool // bs)}",
        n_pool=n_pool, variant="per_batch", seconds=t_base,
    )
    speedups = {}
    for mb, pf, variant in (
        (64, False, "megabatch"),
        (64, True, "megabatch_prefetch"),
    ):
        ex = ProxyExtractor(step, ds, bs, megabatch=mb, prefetch=pf)
        t = _timed(lambda: ex.extract(params, pool), iters)
        if gate and t_base / t < 2.0:
            # one re-measure before failing: on a shared CI CPU the
            # prefetch thread competes with XLA compute for cores, so a
            # single window can dip below the bar on scheduler noise
            # alone — a *persistent* regression fails both passes
            t = min(t, _timed(lambda: ex.extract(params, pool), iters))
        speedups[variant] = t_base / t
        _emit(
            f"extract/{tag}/{variant}/n{n_pool}", t / n_pool * 1e6,
            f"examples_per_s={n_pool / t:.0f} speedup={t_base / t:.2f}x",
            n_pool=n_pool, variant=variant, seconds=t,
            speedup_vs_per_batch=t_base / t,
        )
    # the documented acceptance bar is megabatch+prefetch vs per-batch —
    # gating each variant specifically also catches a prefetch-path
    # regression that plain megabatch would mask
    if gate and min(speedups.values()) < 2.0:
        raise AssertionError(
            f"extraction ladder below the 2x acceptance bar at "
            f"n_pool={n_pool}: {speedups}"
        )


def _parity(n_docs: int = 96, pool_batches: int = 12) -> None:
    """Selections through the ProxyExtractor refresh path == the per-batch
    baseline's, bit for bit, in both refresh modes."""
    from repro.core.craig import CraigConfig, CraigSelector
    from repro.optim import adamw, constant
    from repro.train import Trainer, TrainerConfig

    cfg = ModelConfig(
        name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=128, logit_chunk=16,
    )
    craig = CraigConfig(fraction=0.5, per_class=False)
    ds = TokenStream(n_docs=n_docs, seq_len=24, vocab_size=128, n_topics=8)

    def trainer(mode):
        tcfg = TrainerConfig(
            batch_size=8, select_every_epochs=1, refresh_mode=mode,
            craig=craig, proxy_pool_batches=pool_batches,
        )
        return Trainer(
            cfg, tcfg, ds, adamw(constant(2e-3)),
            lambda: init_params(jax.random.PRNGKey(0), cfg),
        )

    t0 = trainer("sync")
    pool = t0._pool_indices()
    base_feats = _per_batch_baseline(
        make_select_step(cfg), ds, t0.params, pool, bs=8
    )
    want = CraigSelector(craig).select(base_feats)
    want_idx = np.sort(np.asarray(pool)[np.asarray(want.indices)])
    for mode in ("sync", "async"):
        t = trainer(mode)  # same seed → identical params in both modes
        t.refresher.submit(t.params)
        t.refresher.wait()
        installed = t.sampler.install_pending()
        got_idx = np.sort(np.asarray(installed["indices"]))
        ok = bool(np.array_equal(got_idx, want_idx))
        _emit(
            f"extract/refresh_parity/{mode}", 0.0,
            f"bit_identical={'ok' if ok else 'FAIL'} "
            f"coreset_size={len(got_idx)}",
            mode=mode, parity=ok,
        )
        if not ok:
            raise AssertionError(
                f"ProxyExtractor refresh selection diverged from the "
                f"per-batch baseline in mode={mode}"
            )


def _write_json(smoke: bool) -> None:
    with open("BENCH_extract.json", "w") as f:
        json.dump(
            {
                "schema": 1,
                "smoke": smoke,
                "backend": jax.default_backend(),
                "config": {
                    "n_layers": _CFG.n_layers, "d_model": _CFG.d_model,
                    "vocab_size": _CFG.vocab_size, "seq_len": _SEQ,
                },
                "records": _RECORDS,
            },
            f, indent=1,
        )


def run(smoke: bool = False) -> None:
    try:
        sizes = [4096] if smoke else [1024, 4096, 16384]
        iters = 3 if smoke else 5
        for n_pool in sizes:
            ds = _TokenArray(n_pool, _SEQ, _CFG.vocab_size)
            # the acceptance bar speaks at n_pool ≥ 4k: the dispatch-bound
            # ladder must clear 2x there
            _ladder(ds, "dispatch_bound", n_pool, bs=8, iters=iters,
                    gate=n_pool >= 4096)
        n_host = 1024 if smoke else 4096
        _ladder(
            TokenStream(n_docs=n_host, seq_len=_SEQ, vocab_size=_CFG.vocab_size),
            "host_bound", n_host, bs=8, iters=iters, gate=False,
        )
        _parity()
    finally:
        _write_json(smoke)


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--smoke", action="store_true", help="CI-sized run (CPU, seconds)"
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(smoke=args.smoke)
