"""Gradient compression: quantization error bounds + error-feedback SGD.
Plus the 2-D per-row feature-payload path (tree-selection candidate wire)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.compression import (
    dequantize_int8,
    dequantize_rows_int8,
    make_error_feedback,
    quantize_int8,
    quantize_rows_int8,
)


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s, x.shape)
    # per-block absmax scaling: |err| ≤ scale/2 = absmax/254 per block
    err = np.abs(np.asarray(x - y))
    bound = np.repeat(np.asarray(s) / 2 + 1e-9, 256)[:1000]
    assert (err <= bound + 1e-7).all()


def test_quantize_shapes_and_dtype():
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 33))
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    y = dequantize_int8(q, s, x.shape)
    assert y.shape == x.shape


def test_quantize_rows_roundtrip_error_bound():
    """Per-row absmax scaling: |err| ≤ scale_i/2 within each row — a row
    with a large-magnitude outlier must not degrade other rows."""
    x = jax.random.normal(jax.random.PRNGKey(3), (33, 48)) * 2.0
    x = x.at[5].multiply(100.0)  # outlier row: only its own bound widens
    q, s = quantize_rows_int8(x)
    assert q.dtype == jnp.int8 and q.shape == x.shape
    assert s.shape == (33,) and s.dtype == jnp.float32
    y = dequantize_rows_int8(q, s)
    assert y.dtype == jnp.float32
    err = np.abs(np.asarray(x - y))
    bound = np.asarray(s)[:, None] / 2 + 1e-6
    assert (err <= bound).all()
    # the outlier row's scale did not leak into its neighbors
    assert np.asarray(s)[4] < np.asarray(s)[5] / 10


def test_quantize_rows_bf16_input():
    """bf16 feature payloads quantize through fp32: the round trip is
    bounded by the bf16 row's absmax scale and returns fp32."""
    x32 = jax.random.normal(jax.random.PRNGKey(4), (17, 64))
    x = x32.astype(jnp.bfloat16)
    q, s = quantize_rows_int8(x)
    y = dequantize_rows_int8(q, s)
    assert y.dtype == jnp.float32
    err = np.abs(np.asarray(x.astype(jnp.float32) - y))
    assert (err <= np.asarray(s)[:, None] / 2 + 1e-6).all()


def test_quantize_rows_rejects_non_2d():
    with pytest.raises(ValueError, match="2-D"):
        quantize_rows_int8(jnp.zeros((8,)))
    with pytest.raises(ValueError, match="2-D"):
        quantize_rows_int8(jnp.zeros((2, 3, 4)))


def test_quantize_rows_jit_safe():
    """The row codec runs under jit (it rides inside shard_map gathers)."""
    x = jax.random.normal(jax.random.PRNGKey(5), (9, 16))
    y = jax.jit(lambda v: dequantize_rows_int8(*quantize_rows_int8(v)))(x)
    yr = dequantize_rows_int8(*quantize_rows_int8(x))
    np.testing.assert_array_equal(np.asarray(y), np.asarray(yr))


def test_gradient_path_bit_identical():
    """The 1-D gradient codec is untouched by the 2-D generalization:
    block layout, scales, and payload bytes are exactly the legacy ones."""
    x = jax.random.normal(jax.random.PRNGKey(6), (777,)) * 0.3
    q, s = quantize_int8(x)
    # legacy reference, computed inline: pad to 256, per-block absmax
    flat = np.zeros(1024, np.float32)
    flat[:777] = np.asarray(x, np.float32)
    blocks = flat.reshape(-1, 256)
    ref_s = np.abs(blocks).max(axis=1) / 127.0 + 1e-12
    ref_q = np.clip(np.round(blocks / ref_s[:, None]), -127, 127).astype(np.int8)
    np.testing.assert_array_equal(np.asarray(q), ref_q)
    np.testing.assert_array_equal(np.asarray(s), ref_s.astype(np.float32))


def test_error_feedback_unbiased_over_time():
    """EF compensates quantization: the running delivered sum tracks the true
    gradient sum much better than naive quantization."""
    grads = {"w": jax.random.normal(jax.random.PRNGKey(2), (512,)) * 0.01}
    init_res, apply = make_error_feedback(grads)
    res = init_res()
    total_delivered = jnp.zeros(512)
    total_true = jnp.zeros(512)
    for i in range(20):
        g = {"w": jax.random.normal(jax.random.PRNGKey(i), (512,)) * 0.01}
        delivered, res = apply(g, res)
        total_delivered += delivered["w"]
        total_true += g["w"]
    # residual carries the outstanding error: delivered + residual == true sum
    np.testing.assert_allclose(
        np.asarray(total_delivered + res["w"]),
        np.asarray(total_true),
        rtol=1e-4,
        atol=1e-6,
    )


def test_compressed_sgd_converges():
    """SGD with EF-compressed gradients still reaches the optimum."""
    A = jnp.diag(jnp.array([1.0, 4.0, 9.0]))
    b = jnp.array([1.0, 2.0, 3.0])
    w_star = jnp.linalg.solve(A, b)
    w = {"w": jnp.zeros(3)}
    init_res, apply = make_error_feedback(w)
    res = init_res()
    for _ in range(300):
        g = {"w": A @ w["w"] - b}
        delivered, res = apply(g, res)
        w = {"w": w["w"] - 0.05 * delivered["w"]}
    assert float(jnp.linalg.norm(w["w"] - w_star)) < 1e-2


def test_compressed_psum_multidevice_subprocess():
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum

        from repro.core.distributed import compat_shard_map
        from repro.launch.mesh import compat_mesh

        mesh = compat_mesh((4,), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 1024))

        f = compat_shard_map(
            lambda v: compressed_psum(v[0], "pod")[None],
            mesh=mesh, in_specs=(P("pod", None),),
            out_specs=P("pod", None))
        got = f(x)  # every shard returns the mean
        want = jnp.mean(x, axis=0)
        err = float(jnp.max(jnp.abs(got[0] - want)))
        scale = float(jnp.max(jnp.abs(want)))
        assert err / scale < 0.02, (err, scale)
        print("PSUM_OK", err / scale)
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", script],
        env=env, capture_output=True, text=True, timeout=480,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PSUM_OK" in out.stdout
