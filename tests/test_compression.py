"""Gradient compression: quantization error bounds + error-feedback SGD."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.compression import (
    dequantize_int8,
    make_error_feedback,
    quantize_int8,
)


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q, s = quantize_int8(x)
    y = dequantize_int8(q, s, x.shape)
    # per-block absmax scaling: |err| ≤ scale/2 = absmax/254 per block
    err = np.abs(np.asarray(x - y))
    bound = np.repeat(np.asarray(s) / 2 + 1e-9, 256)[:1000]
    assert (err <= bound + 1e-7).all()


def test_quantize_shapes_and_dtype():
    x = jax.random.normal(jax.random.PRNGKey(1), (7, 33))
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8
    y = dequantize_int8(q, s, x.shape)
    assert y.shape == x.shape


def test_error_feedback_unbiased_over_time():
    """EF compensates quantization: the running delivered sum tracks the true
    gradient sum much better than naive quantization."""
    grads = {"w": jax.random.normal(jax.random.PRNGKey(2), (512,)) * 0.01}
    init_res, apply = make_error_feedback(grads)
    res = init_res()
    total_delivered = jnp.zeros(512)
    total_true = jnp.zeros(512)
    for i in range(20):
        g = {"w": jax.random.normal(jax.random.PRNGKey(i), (512,)) * 0.01}
        delivered, res = apply(g, res)
        total_delivered += delivered["w"]
        total_true += g["w"]
    # residual carries the outstanding error: delivered + residual == true sum
    np.testing.assert_allclose(
        np.asarray(total_delivered + res["w"]),
        np.asarray(total_true),
        rtol=1e-4,
        atol=1e-6,
    )


def test_compressed_sgd_converges():
    """SGD with EF-compressed gradients still reaches the optimum."""
    A = jnp.diag(jnp.array([1.0, 4.0, 9.0]))
    b = jnp.array([1.0, 2.0, 3.0])
    w_star = jnp.linalg.solve(A, b)
    w = {"w": jnp.zeros(3)}
    init_res, apply = make_error_feedback(w)
    res = init_res()
    for _ in range(300):
        g = {"w": A @ w["w"] - b}
        delivered, res = apply(g, res)
        w = {"w": w["w"] - 0.05 * delivered["w"]}
    assert float(jnp.linalg.norm(w["w"] - w_star)) < 1e-2


def test_compressed_psum_multidevice_subprocess():
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum

        from repro.core.distributed import compat_shard_map
        from repro.launch.mesh import compat_mesh

        mesh = compat_mesh((4,), ("pod",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 1024))

        f = compat_shard_map(
            lambda v: compressed_psum(v[0], "pod")[None],
            mesh=mesh, in_specs=(P("pod", None),),
            out_specs=P("pod", None))
        got = f(x)  # every shard returns the mean
        want = jnp.mean(x, axis=0)
        err = float(jnp.max(jnp.abs(got[0] - want)))
        scale = float(jnp.max(jnp.abs(want)))
        assert err / scale < 0.02, (err, scale)
        print("PSUM_OK", err / scale)
        """
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", script],
        env=env, capture_output=True, text=True, timeout=480,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "PSUM_OK" in out.stdout
