"""ProxyExtractor (DESIGN.md §9): megabatch scan, prefetch, shard_map,
device-resident handoff with zero host transfers of the feature matrix."""
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.extract import ProxyExtractor
from repro.data.synthetic import TokenStream
from repro.models import ModelConfig, init_params
from repro.train import make_select_step

CFG = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv_heads=2, d_ff=64, vocab_size=128, logit_chunk=16,
)
BS = 8


@pytest.fixture(scope="module")
def setup():
    ds = TokenStream(n_docs=100, seq_len=24, vocab_size=128, n_topics=8)
    params = init_params(jax.random.PRNGKey(0), CFG)
    step = make_select_step(CFG)
    return ds, params, step


def _per_batch_baseline(step, ds, params, pool, bs=BS):
    """The pre-pipeline extraction loop: one jitted dispatch per batch,
    blocking host copy each time, pad-then-drop on the tail."""
    jstep = jax.jit(step)
    feats = []
    for lo in range(0, len(pool), bs):
        chunk = pool[lo : lo + bs]
        if len(chunk) < bs:
            chunk = np.concatenate([chunk, pool[: bs - len(chunk)]])
        feats.append(np.asarray(jstep(params, ds.batch(chunk))))
    return np.concatenate(feats)[: len(pool)]


def test_megabatch_bit_identical_to_per_batch_baseline(setup):
    """The scan path's batch contents equal the baseline's (tail wraps the
    pool), so features are bit-identical — the refresh-parity invariant
    bench_extract gates."""
    ds, params, step = setup
    pool = np.arange(100)[:52]  # 6 full batches + a 4-row tail
    base = _per_batch_baseline(step, ds, params, pool)
    for mb, pf in [(1, False), (3, False), (8, True), (64, True)]:
        ex = ProxyExtractor(step, ds, BS, megabatch=mb, prefetch=pf)
        got = ex.extract(params, pool)
        assert isinstance(got, jax.Array)
        np.testing.assert_array_equal(np.asarray(got), base)


def test_whole_pool_is_one_dispatch(setup):
    """megabatch ≥ n_batches folds the sweep into O(1) programs."""
    ds, _, step = setup
    ex = ProxyExtractor(step, ds, BS, megabatch=64)
    assert ex._plan(52) == [(0, 7)]  # one program, 7 batches (tail padded)


def test_plan_invariants():
    ds = TokenStream(n_docs=100, seq_len=8, vocab_size=32)
    ex = ProxyExtractor(lambda p, b: None, ds, BS, megabatch=3)
    for n_pool in (1, 7, 8, 52, 100):
        plan = ex._plan(n_pool)
        m_total = -(-n_pool // BS)
        assert sum(m for _, m in plan) >= m_total  # covers the pool
        assert [lo for lo, _ in plan] == list(
            np.cumsum([0] + [m for _, m in plan])[:-1]
        )  # contiguous
        assert len({m for _, m in plan}) <= 2  # at most 2 compiled shapes


def test_device_resident_flag(setup):
    ds, params, step = setup
    ex = ProxyExtractor(step, ds, BS, megabatch=4)
    pool = np.arange(24)
    dev = ex.extract(params, pool)
    host = ex.extract(params, pool, device_resident=False)
    assert isinstance(dev, jax.Array) and isinstance(host, np.ndarray)
    np.testing.assert_array_equal(np.asarray(dev), host)


def test_prefetch_assembly_error_propagates(setup):
    """A dataset failure on the prefetch thread must raise on the caller,
    not leave the queue blocking forever."""
    _, params, step = setup

    class Exploding:
        n_docs = 100

        def __init__(self):
            self.calls = 0
            self._inner = TokenStream(n_docs=100, seq_len=24, vocab_size=128)

        def batch(self, idx):
            self.calls += 1
            if self.calls > 1:
                raise RuntimeError("disk on fire")
            return self._inner.batch(idx)

    ex = ProxyExtractor(step, Exploding(), BS, megabatch=1, prefetch=True)
    with pytest.raises(RuntimeError, match="disk on fire"):
        ex.extract(params, np.arange(40))


def test_pallas_select_step_close_to_einsum(setup):
    """The fused ce_proxy select path (interpret mode on CPU) agrees with
    the chunked einsum path within bf16 tolerance."""
    ds, params, _ = setup
    batch = ds.batch(np.arange(BS))
    f_e = np.asarray(jax.jit(make_select_step(CFG, proxy_impl="einsum"))(params, batch))
    f_p = np.asarray(jax.jit(make_select_step(CFG, proxy_impl="pallas"))(params, batch))
    np.testing.assert_allclose(f_p, f_e, rtol=0.05, atol=3e-3)
    with pytest.raises(ValueError, match="proxy_impl"):
        make_select_step(CFG, proxy_impl="nope")


# ---------------------------------------------------------------------------
# Device-resident handoff: zero host transfers of the feature matrix
# ---------------------------------------------------------------------------


@pytest.fixture
def transfer_guard(monkeypatch):
    """Counts host materializations (np.asarray / jax.device_get) of any
    jax.Array whose shape is being watched — the feature matrix, here."""
    watched: set[tuple] = set()
    hits: list[tuple] = []
    real_asarray, real_get = np.asarray, jax.device_get

    def _check(kind, x):
        for leaf in jax.tree_util.tree_leaves(x):
            if isinstance(leaf, jax.Array) and tuple(leaf.shape) in watched:
                hits.append((kind, tuple(leaf.shape)))

    def guard_asarray(a, *args, **kw):
        _check("np.asarray", a)
        return real_asarray(a, *args, **kw)

    def guard_get(x):
        _check("jax.device_get", x)
        return real_get(x)

    monkeypatch.setattr(np, "asarray", guard_asarray)
    monkeypatch.setattr(jax, "device_get", guard_get)

    class Guard:
        def watch(self, *shape):
            watched.add(tuple(shape))

        @property
        def hits(self):
            return list(hits)

    return Guard()


def _refresh_trainer(engine):
    from repro.core.craig import CraigConfig
    from repro.optim import adamw, constant
    from repro.train import Trainer, TrainerConfig

    ds = TokenStream(n_docs=48, seq_len=24, vocab_size=128, n_topics=6)
    tcfg = TrainerConfig(
        batch_size=BS,
        select_every_epochs=1,
        refresh_mode="sync",
        craig=CraigConfig(fraction=0.5, per_class=False, engine=engine),
    )
    return Trainer(
        CFG, tcfg, ds, adamw(constant(2e-3)),
        lambda: init_params(jax.random.PRNGKey(0), CFG),
    )


def test_jit_safe_refresh_never_lands_features_on_host(transfer_guard):
    """On the jit-safe engine path the (n_pool, D) feature matrix stays a
    jax.Array end to end through extract → CraigSelector.select — zero
    np.asarray / device_get calls see it."""
    from repro.core.engines import FeaturesConfig

    t = _refresh_trainer(FeaturesConfig())
    n_pool = len(t._pool_indices())
    transfer_guard.watch(n_pool, CFG.d_model)
    t.run(8)  # ≥1 full refresh lifecycle
    refreshes = [m for m in t.metrics_log if m["event"] == "craig_refresh"]
    assert refreshes, "refresh never ran — guard proved nothing"
    assert transfer_guard.hits == []


def test_host_engine_refresh_guard_control(transfer_guard):
    """Control proving the guard catches real transfers: the host-side lazy
    engine materializes its (n, n) similarity matrix (never the raw
    (n, D) feature matrix — features hand off device-resident to every
    engine) once per submitted refresh."""
    from repro.core.engines import LazyConfig

    t = _refresh_trainer(LazyConfig())
    n_pool = len(t._pool_indices())
    transfer_guard.watch(n_pool, CFG.d_model)  # the feature matrix...
    transfer_guard.watch(n_pool, n_pool)  # ...and the lazy host similarity
    t.run(8)
    n_submitted = t.refresher.version  # one selection per submitted refresh
    assert n_submitted >= 1
    feat_hits = [h for h in transfer_guard.hits if h[1] == (n_pool, CFG.d_model)]
    sim_hits = [
        h for h in transfer_guard.hits
        if h[0] == "np.asarray" and h[1] == (n_pool, n_pool)
    ]
    assert feat_hits == [], feat_hits  # feature matrix never crosses
    assert len(sim_hits) == n_submitted, transfer_guard.hits


def test_trainer_refresh_selection_matches_manual_baseline():
    """Selections from the ProxyExtractor refresh path are bit-identical to
    a manual per-batch extraction + selection on the same params."""
    from repro.core.craig import CraigConfig, CraigSelector

    t = _refresh_trainer("auto")
    pool = t._pool_indices()
    base_feats = _per_batch_baseline(
        make_select_step(CFG), t.dataset, t.params, pool
    )
    want = CraigSelector(CraigConfig(fraction=0.5, per_class=False)).select(
        base_feats
    )
    sel, got_pool = t._refresh_work(t.params)
    np.testing.assert_array_equal(got_pool, pool)
    np.testing.assert_array_equal(sel.indices, want.indices)
    np.testing.assert_allclose(sel.weights, want.weights, rtol=1e-6)


# ---------------------------------------------------------------------------
# shard_map data-parallel extraction (simulated devices, subprocess)
# ---------------------------------------------------------------------------

SHARD_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, numpy as np
    from repro.core.extract import ProxyExtractor
    from repro.data.synthetic import TokenStream
    from repro.models import ModelConfig, init_params
    from repro.train import make_select_step
    from repro.launch.mesh import compat_mesh

    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=128,
                      logit_chunk=16)
    ds = TokenStream(n_docs=100, seq_len=24, vocab_size=128, n_topics=8)
    params = init_params(jax.random.PRNGKey(0), cfg)
    step = make_select_step(cfg)
    pool = np.arange(100)[:52]

    base = np.asarray(
        ProxyExtractor(step, ds, 8, megabatch=8, prefetch=False)
        .extract(params, pool)
    )
    mesh = compat_mesh((4,), ("data",))
    for mb in (1, 8):  # plan rounds batch counts up to shard multiples
        ex = ProxyExtractor(step, ds, 8, megabatch=mb, prefetch=True,
                            mesh=mesh)
        got = ex.extract(params, pool)
        assert got.shape == (52, 32), got.shape
        np.testing.assert_allclose(np.asarray(got), base,
                                   rtol=1e-6, atol=1e-7)
    print("OK")
    """
)


@pytest.mark.tier2
def test_sharded_extract_matches_single_device():
    r = subprocess.run(
        [sys.executable, "-c", SHARD_SCRIPT],
        capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout
