"""CraigSelector behaviour + the paper's gradient-approximation claims."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import facility_location as fl
from repro.core.craig import (
    CraigConfig,
    CraigSelector,
    _apportion_budgets,
    pairwise_distances,
)
from repro.core.proxy import exact_per_example_grads
from repro.data.synthetic import make_classification


def test_budget_mode_size_and_weights():
    feats = jax.random.normal(jax.random.PRNGKey(0), (100, 8))
    sel = CraigSelector(CraigConfig(fraction=0.2, per_class=False))
    cs = sel.select(feats)
    assert cs.size == 20
    assert cs.weights.sum() == pytest.approx(100.0)
    assert len(set(cs.indices.tolist())) == 20


def test_per_class_budget_apportionment():
    feats = jax.random.normal(jax.random.PRNGKey(0), (120, 8))
    labels = np.array([0] * 60 + [1] * 40 + [2] * 20)
    sel = CraigSelector(CraigConfig(fraction=0.1, per_class=True))
    cs = sel.select(feats, labels)
    assert cs.size == 12
    assert cs.per_class_sizes == {0: 6, 1: 4, 2: 2}
    assert cs.weights.sum() == pytest.approx(120.0)


def test_per_class_many_rare_classes_no_overshoot():
    """Regression: the ≥1-per-class floor used to push Σbudgets far past the
    requested total (never reclaimed).  With 30 singleton classes and a
    budget of 8, the union must have exactly 8 elements."""
    labels = np.concatenate([np.zeros(50, np.int64), np.arange(1, 31)])
    feats = jax.random.normal(jax.random.PRNGKey(1), (80, 8))
    cs = CraigSelector(CraigConfig(fraction=0.1, per_class=True)).select(
        feats, labels
    )
    assert cs.size == 8
    assert len(set(cs.indices.tolist())) == 8
    assert sum(cs.per_class_sizes.values()) == 8
    assert cs.weights.sum() == pytest.approx(80.0)


def test_apportion_budgets_invariants():
    rng = np.random.RandomState(0)
    for _ in range(50):
        k = rng.randint(1, 12)
        counts = rng.randint(1, 40, size=k).astype(np.int64)
        total = rng.randint(1, counts.sum() + 5)
        b = _apportion_budgets(counts, total)
        assert b.sum() == min(total, counts.sum()), (counts, total, b)
        assert (b <= counts).all(), (counts, total, b)
        if total >= k:
            assert (b >= 1).all(), (counts, total, b)


def test_apportion_reclaims_from_largest_classes():
    # floors force overshoot (5 + 9·1 = 14 > 11); reclaimed from the big class
    counts = np.array([20, 2, 2, 2, 2, 2, 2, 2, 2, 2])
    b = _apportion_budgets(counts, 11)
    assert b.sum() == 11 and (b >= 1).all() and (b <= counts).all()
    assert b[0] == b.max()  # reclaim never inverts the ordering


def test_per_class_budget_never_exceeds_class_size():
    feats = jax.random.normal(jax.random.PRNGKey(2), (24, 4))
    labels = np.array([0] * 18 + [1] * 6)
    cs = CraigSelector(CraigConfig(fraction=0.9, per_class=True)).select(
        feats, labels
    )
    assert cs.size == 22  # round(0.9·24), not clamped away silently
    assert cs.per_class_sizes[1] <= 6


def test_per_class_without_labels_warns_and_falls_back():
    feats = jax.random.normal(jax.random.PRNGKey(0), (60, 8))
    sel = CraigSelector(CraigConfig(fraction=0.1, per_class=True))
    with pytest.warns(UserWarning, match="per_class"):
        cs = sel.select(feats)
    assert cs.size == 6
    assert cs.per_class_sizes is None


def test_selector_warm_start_parity_and_dedup():
    feats = jax.random.normal(jax.random.PRNGKey(3), (100, 8))
    sel = CraigSelector(CraigConfig(fraction=0.2, per_class=False))
    cold = sel.select(feats)
    # duplicate entries in the warm prefix are deduped, order preserved
    init = np.repeat(cold.indices[:10], 2)
    warm = sel.select(feats, init_selected=init)
    np.testing.assert_array_equal(cold.indices, warm.indices)
    np.testing.assert_allclose(cold.weights, warm.weights)


def test_selector_warm_start_per_class_parity():
    feats = jax.random.normal(jax.random.PRNGKey(4), (120, 8))
    labels = np.array([0] * 60 + [1] * 40 + [2] * 20)
    sel = CraigSelector(CraigConfig(fraction=0.2, per_class=True))
    cold = sel.select(feats, labels)
    warm = sel.select(feats, labels, init_selected=cold.indices[:12])
    np.testing.assert_array_equal(cold.indices, warm.indices)


@pytest.mark.tier2
def test_cover_mode_per_class_unconstrained_by_budget():
    """cover + per_class: every class grows until its ε target — sizes are
    ε-driven (no apportionment assert, no class skipped)."""
    feats = jax.random.normal(jax.random.PRNGKey(5), (60, 6))
    labels = np.array([0] * 40 + [1] * 20)
    sel = CraigSelector(CraigConfig(mode="cover", epsilon=30.0, per_class=True))
    cs = sel.select(feats, labels)
    assert set(cs.per_class_sizes) == {0, 1}
    assert all(v >= 1 for v in cs.per_class_sizes.values())
    assert cs.size == sum(cs.per_class_sizes.values())
    assert cs.weights.sum() == pytest.approx(60.0)


def test_cover_mode_meets_epsilon():
    feats = jax.random.normal(jax.random.PRNGKey(1), (80, 8))
    dist = pairwise_distances(feats)
    # epsilon achievable with ~15 medoids
    ref = fl.greedy_fl_matrix(jnp.max(dist) + 1e-6 - dist, 15)
    eps = float(fl.coverage_l(dist, ref.indices))
    sel = CraigSelector(CraigConfig(mode="cover", epsilon=eps, per_class=False))
    cs = sel.select(feats)
    assert cs.coverage <= eps + 1e-4
    assert cs.size <= 16


def test_engines_agree_on_clustered_data():
    x, y = make_classification(200, 10, 2, seed=3)
    for engine in ("matrix", "lazy", "features"):
        sel = CraigSelector(
            CraigConfig(fraction=0.1, engine=engine, per_class=False)
        )
        cs = sel.select(x)
        assert cs.size == 20
        assert cs.weights.sum() == pytest.approx(200.0)


# ---------------------------------------------------------------------------
# Paper claims (Fig 2 / Eq 5–8 / §3.2 ordering)
# ---------------------------------------------------------------------------


def _logreg_setup(n=96, d=6, seed=0):
    x, y = make_classification(n, d, 2, seed=seed)
    ybin = y * 2.0 - 1.0  # ±1
    lam = 1e-5

    def loss_one(w, xi, yi):
        return jnp.log1p(jnp.exp(-yi * (xi @ w))) + 0.5 * lam * w @ w

    return x, ybin, loss_one


def test_craig_gradient_error_beats_random():
    """Fig 2: ‖Σ∇f − Σγ∇f_S‖ smaller for CRAIG than random (same size)."""
    x, y, loss_one = _logreg_setup()
    n = x.shape[0]
    sel = CraigSelector(CraigConfig(fraction=0.15, per_class=True))
    cs = sel.select(x, (y > 0).astype(np.int32))

    rng = np.random.RandomState(0)
    errs_craig, errs_rand = [], []
    for seed in range(5):
        w = jax.random.normal(jax.random.PRNGKey(seed), (x.shape[1],)) * 0.5
        grads = exact_per_example_grads(loss_one, w, jnp.asarray(x), jnp.asarray(y))
        full = jnp.sum(grads, axis=0)
        g_craig = jnp.sum(
            grads[jnp.asarray(cs.indices)] * jnp.asarray(cs.weights)[:, None], 0
        )
        errs_craig.append(float(jnp.linalg.norm(full - g_craig)))
        ridx = rng.choice(n, cs.size, replace=False)
        g_rand = jnp.sum(grads[ridx], axis=0) * (n / cs.size)
        errs_rand.append(float(jnp.linalg.norm(full - g_rand)))
    assert np.mean(errs_craig) < np.mean(errs_rand)


def test_epsilon_hat_bounds_weighted_gradient_error_direction():
    """ε̂ from Eq. 15 scales with the actual gradient estimation error:
    larger coresets → smaller ε̂ AND smaller true error."""
    x, y, loss_one = _logreg_setup()
    errs, epss = [], []
    for frac in (0.05, 0.2, 0.5):
        sel = CraigSelector(CraigConfig(fraction=frac, per_class=False))
        cs = sel.select(x)
        w = jax.random.normal(jax.random.PRNGKey(7), (x.shape[1],)) * 0.5
        grads = exact_per_example_grads(loss_one, w, jnp.asarray(x), jnp.asarray(y))
        full = jnp.sum(grads, axis=0)
        g_hat = jnp.sum(
            grads[jnp.asarray(cs.indices)] * jnp.asarray(cs.weights)[:, None], 0
        )
        errs.append(float(jnp.linalg.norm(full - g_hat)))
        epss.append(cs.epsilon_hat)
    assert epss == sorted(epss, reverse=True)
    assert errs[0] >= errs[-1]  # more budget → tighter gradient estimate


def test_greedy_order_prefix_quality():
    """§3.2: greedy order is nested — every prefix of a big selection matches
    the selection at that budget (so early elements carry the approximation)."""
    feats = jax.random.normal(jax.random.PRNGKey(2), (90, 8))
    dist = pairwise_distances(feats)
    sim = jnp.max(dist) + 1e-6 - dist
    big = fl.greedy_fl_matrix(sim, 30)
    small = fl.greedy_fl_matrix(sim, 10)
    np.testing.assert_array_equal(
        np.asarray(big.indices)[:10], np.asarray(small.indices)
    )
