"""Runtime sanitizers for the test suite (DESIGN.md §11).

Tier-1 runs with ``jax_numpy_rank_promotion='raise'`` by default: implicit
rank promotion is how a ``(B, T, H)`` gate silently broadcasts against a
``(H,)`` bias into the wrong axis and produces plausible-but-wrong numbers.
The remaining sanitizers are opt-in because they change performance or are
too strict for host-side staging code:

  --jax-sanitizers=off     escape hatch: run with stock JAX semantics
  --jax-debug-nans         re-run under jax_debug_nans (every NaN traps)
  --jax-transfer-guard=X   set jax_transfer_guard (e.g. 'disallow' to trap
                           implicit device<->host transfers)
"""
import jax


def pytest_addoption(parser):
    group = parser.getgroup("jax-sanitizers")
    group.addoption(
        "--jax-sanitizers",
        choices=("strict", "off"),
        default="strict",
        help="'strict' (default) sets jax_numpy_rank_promotion='raise'; "
        "'off' keeps stock JAX semantics",
    )
    group.addoption(
        "--jax-debug-nans",
        action="store_true",
        default=False,
        help="enable jax_debug_nans (trap on any NaN; slow, opt-in)",
    )
    group.addoption(
        "--jax-transfer-guard",
        choices=("allow", "log", "disallow", "log_explicit", "disallow_explicit"),
        default=None,
        help="set jax_transfer_guard to trap implicit device<->host copies",
    )


def pytest_configure(config):
    if config.getoption("--jax-sanitizers") == "strict":
        jax.config.update("jax_numpy_rank_promotion", "raise")
    if config.getoption("--jax-debug-nans"):
        jax.config.update("jax_debug_nans", True)
    guard = config.getoption("--jax-transfer-guard")
    if guard is not None:
        jax.config.update("jax_transfer_guard", guard)
