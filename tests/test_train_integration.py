"""Paper Fig 1 reproduction direction (CPU-scale): L2-regularized logistic
regression — SGD on a 10–20% CRAIG coreset must (a) approach the full-data
loss, and (b) beat a random subset of the same size.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.craig import CraigConfig, CraigSelector
from repro.data.synthetic import make_classification
from repro.optim import ig_run

LAM = 1e-5
N, D = 400, 10


def _data():
    x, y = make_classification(N, D, 2, seed=0)
    x = x / np.abs(x).max()
    ybin = jnp.asarray(y * 2.0 - 1.0)
    return jnp.asarray(x), ybin, y


def _grad_fn(X, y):
    def grad(w, i):
        xi, yi = X[i], y[i]
        s = jax.nn.sigmoid(-yi * (xi @ w))
        return -s * yi * xi + LAM * w

    return grad


def _full_loss(X, y, w):
    z = -y * (X @ w)
    return float(jnp.mean(jnp.log1p(jnp.exp(z))) + 0.5 * LAM * w @ w)


def _run(X, y, idx, weights, epochs=40):
    grad = _grad_fn(X, y)
    w, _ = ig_run(
        grad,
        jnp.zeros(D),
        jnp.asarray(idx, jnp.int32),
        jnp.asarray(weights, jnp.float32),
        lambda k: 0.5 / (N * (1 + 0.2 * k)),
        epochs,
    )
    return w


def test_craig_matches_full_and_beats_random():
    X, ybin, y = _data()

    # full data baseline
    w_full = _run(X, ybin, np.arange(N), np.ones(N))
    loss_full = _full_loss(X, ybin, w_full)

    # CRAIG 15% (per-class, Eq. 9 feature proxies)
    sel = CraigSelector(CraigConfig(fraction=0.15, per_class=True))
    cs = sel.select(X, y)
    w_craig = _run(X, ybin, cs.indices, cs.weights)
    loss_craig = _full_loss(X, ybin, w_craig)

    # random 15%, reweighted n/r (what SGD's unbiased estimate would use)
    rng = np.random.RandomState(0)
    losses_rand = []
    for s in range(3):
        ridx = rng.choice(N, cs.size, replace=False)
        w_rand = _run(X, ybin, ridx, np.full(cs.size, N / cs.size))
        losses_rand.append(_full_loss(X, ybin, w_rand))
    loss_rand = float(np.mean(losses_rand))

    # (a) CRAIG ends close to the full-data loss
    assert loss_craig < loss_full * 1.25 + 0.02, (loss_craig, loss_full)
    # (b) and beats the average random subset
    assert loss_craig < loss_rand, (loss_craig, loss_rand)


def test_craig_speedup_epochs_to_target():
    """|V|/|S| speedup mechanism: per-epoch gradient work is r vs n, while
    epochs-to-target stay comparable (paper's central speedup argument)."""
    X, ybin, y = _data()
    grad = _grad_fn(X, ybin)

    # target: loss reached by full-data IG after 15 epochs
    w15, _ = ig_run(
        grad, jnp.zeros(D), jnp.arange(N), jnp.ones(N),
        lambda k: 0.5 / (N * (1 + 0.2 * k)), 15,
    )
    target = _full_loss(X, ybin, w15)

    sel = CraigSelector(CraigConfig(fraction=0.2, per_class=True))
    cs = sel.select(X, y)
    # CRAIG epochs to reach the same target
    _, trace = ig_run(
        grad, jnp.zeros(D), jnp.asarray(cs.indices, jnp.int32),
        jnp.asarray(cs.weights), lambda k: 0.5 / (N * (1 + 0.2 * k)), 45,
    )
    epochs_needed = next(
        (k + 1 for k, w in enumerate(trace) if _full_loss(X, ybin, w) <= target * 1.02),
        None,
    )
    assert epochs_needed is not None, "CRAIG never reached the full-data target"
    # gradient evaluations: full = 15·N; CRAIG = epochs·r
    speedup = (15 * N) / (epochs_needed * cs.size)
    assert speedup > 1.5, f"speedup only {speedup:.2f}x"
