"""Per-architecture smoke tests: reduced same-family configs, one forward +
train step + decode step + CRAIG proxy on CPU; asserts shapes and no NaNs.

Full-scale configs are exercised only via the dry-run (launch/dryrun.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config, smoke_config
from repro.models import (
    decode_step,
    init_params,
    init_serve_state,
    loss_fn,
    proxy_features,
)
from repro.optim import adamw, constant
from repro.train import make_train_step

pytestmark = pytest.mark.tier2  # all-arch sweep, 5–50 s per family


def _batch(cfg, B=2, T=16, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {}
    if cfg.frontend == "tokens":
        batch["tokens"] = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    else:
        batch["embeddings"] = (
            jax.random.normal(key, (B, T, cfg.d_model)) * 0.5
        ).astype(jnp.bfloat16)
    if cfg.n_codebooks > 1:
        batch["labels"] = jax.random.randint(
            key, (B, T, cfg.n_codebooks), 0, cfg.vocab_size
        )
    else:
        batch["labels"] = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    if cfg.mrope_sections is not None:
        pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
        batch["positions"] = jnp.broadcast_to(pos[:, None], (B, 3, T))
    batch["weights"] = jnp.ones((B,), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step(arch):
    cfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    opt = adamw(constant(1e-3))
    step = jax.jit(make_train_step(cfg, opt))
    new_params, opt_state, metrics = step(params, opt.init(params), batch)
    assert jnp.isfinite(metrics["loss"]), arch
    # a parameter actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert moved, arch
    # loss magnitude sane for untrained model: ~ln(vocab)
    assert 0.0 < float(metrics["loss"]) < 3 * np.log(cfg.vocab_size) + 5


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_decode_step(arch):
    cfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    B = 2
    state = init_serve_state(cfg, B, 32)
    if cfg.frontend == "tokens":
        b1 = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    else:
        b1 = {"embeddings": jnp.zeros((B, 1, cfg.d_model), jnp.bfloat16)}
    logits, state = decode_step(params, cfg, state, b1)
    if cfg.n_codebooks > 1:
        assert logits.shape == (B, cfg.n_codebooks, cfg.padded_vocab)
    else:
        assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert int(state["pos"]) == 1


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_craig_proxy(arch):
    """The paper's technique applies to every assigned arch (DESIGN.md §5)."""
    cfg = smoke_config(arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, B=4)
    feats = proxy_features(params, cfg, batch)
    assert feats.shape == (4, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(feats)))
    # proxies must differ across examples (selection signal exists)
    assert float(jnp.std(feats, axis=0).mean()) > 0


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_full_config_abstract_init(arch):
    """Full published config initializes abstractly (no allocation) with the
    exact assigned dimensions."""
    cfg = get_config(arch)
    tree = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))
    # within 2% of the analytic count (padding of vocab accounts for the gap)
    assert abs(n - cfg.param_count()) / cfg.param_count() < 0.02, (
        arch, n, cfg.param_count()
    )
