"""Serving: greedy generation shapes, determinism, prefill logits parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ModelConfig, init_params, prefill
from repro.serve import greedy_generate

CFG = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv_heads=1, d_ff=64, vocab_size=64, logit_chunk=8,
)


def test_greedy_generate_shapes_and_determinism():
    params = init_params(jax.random.PRNGKey(0), CFG)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, 64)
    out1 = greedy_generate(params, CFG, prompt, max_new=6)
    out2 = greedy_generate(params, CFG, prompt, max_new=6)
    assert out1.shape == (2, 11)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(out1[:, :5]), np.asarray(prompt))


def test_prefill_last_logits_match_decode_path():
    params = init_params(jax.random.PRNGKey(0), CFG)
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 7), 0, 64)
    _, logits_prefill = prefill(params, CFG, {"tokens": prompt})
    # decode path's logits after teacher-forcing the same prompt
    from repro.models import decode_step, init_serve_state

    state = init_serve_state(CFG, 2, 16)
    logits = None
    for t in range(7):
        logits, state = decode_step(
            params, CFG, state, {"tokens": prompt[:, t : t + 1]}
        )
    err = float(jnp.max(jnp.abs(logits - logits_prefill)))
    scale = float(jnp.max(jnp.abs(logits_prefill)))
    assert err / scale < 2e-2
