"""Recurrent mixers: parallel (train) forms == sequential (decode) forms.

These are fp32 equivalence tests on the raw cells — tighter than the
model-level bf16 parity test.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import recurrent as rec


def test_rglru_scan_equals_decode():
    cfg = rec.RGLRUConfig(d_model=16, d_rnn=24)
    params = rec.init_griffin_block(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 20, 16))
    out_par = rec.griffin_block(params, cfg, x)
    state = rec.init_griffin_state(cfg, 2)
    outs = []
    for t in range(20):
        o, state = rec.griffin_decode(params, cfg, x[:, t : t + 1], state)
        outs.append(o)
    out_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(out_par), np.asarray(out_seq), rtol=1e-4, atol=1e-5
    )


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_mlstm_chunk_equals_decode(chunk):
    cfg = rec.MLSTMConfig(d_model=16, n_heads=2, d_head=8, chunk=chunk)
    params = rec.init_mlstm(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    out_par = rec.mlstm(params, cfg, x)
    state = rec.init_mlstm_state(cfg, 2)
    outs = []
    for t in range(16):
        o, state = rec.mlstm_decode(params, cfg, x[:, t : t + 1], state)
        outs.append(o)
    out_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(out_par), np.asarray(out_seq), rtol=2e-3, atol=2e-3
    )


def test_mlstm_chunk_size_invariance():
    """Chunkwise reassociation is exact: different chunk sizes agree."""
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 16))
    outs = []
    for chunk in (4, 8, 24):
        cfg = rec.MLSTMConfig(d_model=16, n_heads=2, d_head=8, chunk=chunk)
        params = rec.init_mlstm(jax.random.PRNGKey(0), cfg)
        outs.append(np.asarray(rec.mlstm(params, cfg, x)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(outs[0], outs[2], rtol=2e-4, atol=2e-5)


def test_slstm_scan_equals_decode():
    cfg = rec.SLSTMConfig(d_model=16, n_heads=2, d_head=8)
    params = rec.init_slstm(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16))
    out_par = rec.slstm(params, cfg, x)
    state = rec.init_slstm_state(cfg, 2)
    outs = []
    for t in range(12):
        o, state = rec.slstm_decode(params, cfg, x[:, t : t + 1], state)
        outs.append(o)
    out_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(out_par), np.asarray(out_seq), rtol=1e-4, atol=1e-5
    )


def test_rglru_forgetting():
    """RG-LRU decays: with inputs gated off after t0, the state shrinks."""
    cfg = rec.RGLRUConfig(d_model=8, d_rnn=8)
    params = rec.init_griffin_block(jax.random.PRNGKey(0), cfg)
    x = jnp.zeros((1, 50, 8)).at[:, 0].set(5.0)
    state = rec.init_griffin_state(cfg, 1)
    norms = []
    for t in range(50):
        _, state = rec.griffin_decode(params, cfg, x[:, t : t + 1], state)
        norms.append(float(jnp.linalg.norm(state["h"])))
    assert norms[-1] < norms[2]


@pytest.mark.tier2
def test_gradients_flow():
    """All three cells backprop without NaNs."""
    for make in (
        lambda: (
            rec.RGLRUConfig(d_model=8, d_rnn=8),
            rec.init_griffin_block,
            rec.griffin_block,
        ),
        lambda: (
            rec.MLSTMConfig(d_model=8, n_heads=2, d_head=4, chunk=4),
            rec.init_mlstm,
            rec.mlstm,
        ),
        lambda: (
            rec.SLSTMConfig(d_model=8, n_heads=2, d_head=4),
            rec.init_slstm,
            rec.slstm,
        ),
    ):
        cfg, init, fwd = make()
        params = init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8))
        g = jax.grad(lambda p: jnp.sum(fwd(p, cfg, x) ** 2))(params)
        assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))
