"""Two-round distributed CRAIG selection (8 simulated devices, subprocess).

The collective run lives in a subprocess because the device-count flag
must be set before jax initializes and the main test process must keep
seeing 1 device.  Covers both round-1 engines: dense ``matrix`` and the
O(n_local·k) ``sparse`` top-k path.  The candidate-count/ragged-shard
audits (``check_candidate_counts``/``check_even_shards``) are pure-Python
trace-time checks and run in tier 1 directly.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os, warnings
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.distributed import distributed_select
    from repro.core.craig import CraigConfig, CraigSelector
    from repro.core.engines import DeviceConfig, MatrixConfig, SparseConfig

    from repro.launch.mesh import compat_mesh

    mesh = compat_mesh((8,), ("data",))
    k = jax.random.PRNGKey(0)
    centers = jax.random.normal(k, (32, 16)) * 5
    assign = jax.random.randint(jax.random.PRNGKey(1), (1024,), 0, 32)
    feats = centers[assign] + 0.1 * jax.random.normal(
        jax.random.PRNGKey(2), (1024, 16))

    # default local_engine='auto': n_local=128 resolves to the dense exact
    # matrix round 1 via the documented policy
    res = distributed_select(feats, mesh, r_local=16, r_final=32)
    w = np.asarray(res.weights)
    assert w.sum() == 1024.0, w.sum()
    assert res.indices.shape == (32,)

    # recovers (nearly) all clusters
    sel_clusters = set(np.asarray(assign)[np.asarray(res.indices)].tolist())
    assert len(sel_clusters) >= 30, len(sel_clusters)

    # quality parity vs centralized selection: coverage within 1.5x
    cen = CraigSelector(CraigConfig(fraction=32 / 1024, per_class=False,
                                    engine="matrix")).select(feats)
    ratio = float(res.coverage) / max(cen.coverage, 1e-9)
    assert ratio < 1.5, ratio

    # determinism: same result twice; explicit typed config == 'auto' pick
    res2 = distributed_select(feats, mesh, r_local=16, r_final=32)
    assert np.array_equal(np.asarray(res.indices), np.asarray(res2.indices))
    resm = distributed_select(feats, mesh, r_local=16, r_final=32,
                              local_engine=MatrixConfig())
    assert np.array_equal(np.asarray(res.indices), np.asarray(resm.indices))

    # sparse round-1: same contract, O(n_local·k) memory, near-dense
    # quality; the legacy flat-kwarg surface must warn and match the typed
    # SparseConfig surface bit for bit
    with warnings.catch_warnings(record=True) as wrec:
        warnings.simplefilter("always")
        sp = distributed_select(feats, mesh, r_local=16, r_final=32,
                                local_engine="sparse", topk_k=32)
    assert any(issubclass(x.category, DeprecationWarning) for x in wrec), (
        "legacy flat kwargs must emit a DeprecationWarning")
    spt = distributed_select(feats, mesh, r_local=16, r_final=32,
                             local_engine=SparseConfig(k=32))
    assert np.array_equal(np.asarray(sp.indices), np.asarray(spt.indices))
    wsp = np.asarray(sp.weights)
    assert wsp.sum() == 1024.0, wsp.sum()
    sp_clusters = set(np.asarray(assign)[np.asarray(sp.indices)].tolist())
    assert len(sp_clusters) >= 30, len(sp_clusters)
    sp_ratio = float(sp.coverage) / max(cen.coverage, 1e-9)
    assert sp_ratio < 1.5, sp_ratio
    sp2 = distributed_select(feats, mesh, r_local=16, r_final=32,
                             local_engine="sparse", topk_k=32)
    assert np.array_equal(np.asarray(sp.indices), np.asarray(sp2.indices))

    # selector-level wiring: engine='sparse' flips round 1 to the graph path
    sel = CraigSelector(CraigConfig(fraction=32 / 1024, engine="sparse",
                                    topk_k=32, per_class=False))
    cs = sel.select_distributed(feats, mesh)
    assert cs.weights.sum() == 1024.0, cs.weights.sum()

    # device round-1: matrix-free AND exact — identical selections to the
    # dense matrix round-1 (both are exact greedy on each shard)
    dv = distributed_select(feats, mesh, r_local=16, r_final=32,
                            local_engine="device")
    assert np.array_equal(np.asarray(dv.indices), np.asarray(res.indices))
    assert np.asarray(dv.weights).sum() == 1024.0
    # block greedy (q=4) keeps round-1 quality: same contract at the
    # same r_local as the dense run, coverage parity with it; legacy
    # flat kwargs == typed DeviceConfig bit for bit
    dv4 = distributed_select(feats, mesh, r_local=16, r_final=32,
                             local_engine="device", device_q=4)
    assert np.asarray(dv4.weights).sum() == 1024.0
    dv_ratio = float(dv4.coverage) / max(cen.coverage, 1e-9)
    assert dv_ratio < 1.5, dv_ratio
    dv4t = distributed_select(
        feats, mesh, r_local=16, r_final=32,
        local_engine=DeviceConfig(q=4, gains_impl="jax"))
    assert np.array_equal(np.asarray(dv4.indices), np.asarray(dv4t.indices))
    # selector-level wiring for the device engine (same r_local heuristic
    # as the sparse selector path; contract checks only)
    sel_dv = CraigSelector(CraigConfig(fraction=32 / 1024, per_class=False,
                                       engine=DeviceConfig(q=4)))
    cs_dv = sel_dv.select_distributed(feats, mesh)
    assert cs_dv.weights.sum() == 1024.0, cs_dv.weights.sum()
    assert cs_dv.engine["name"] == "device", cs_dv.engine
    # selector engine='auto' (the default): round 1 resolved per shard
    # pool size — dense matrix at n_local=128, identical to the dense run
    cs_auto = CraigSelector(CraigConfig(fraction=32 / 1024,
                                        per_class=False)).select_distributed(
        feats, mesh)
    assert cs_auto.engine["name"] == "matrix", cs_auto.engine
    cs_mat = CraigSelector(
        CraigConfig(fraction=32 / 1024, per_class=False,
                    engine=MatrixConfig())).select_distributed(feats, mesh)
    assert np.array_equal(np.asarray(cs_auto.indices),
                          np.asarray(cs_mat.indices))
    # ragged pool on a real 8-shard mesh: loud audit error, no silent pad
    try:
        distributed_select(feats[:1021], mesh, r_local=16, r_final=32)
        raise SystemExit("expected ValueError for ragged pool")
    except ValueError as e:
        assert "not divisible" in str(e), e
    # shard smaller than r_local on a real mesh (n_local=128 < 200)
    try:
        distributed_select(feats, mesh, r_local=200, r_final=32)
        raise SystemExit("expected ValueError for r_local > n_local")
    except ValueError as e:
        assert "exceeds the shard pool size" in str(e), e
    print("DISTRIBUTED_OK", ratio, sp_ratio, dv_ratio)
    """
)


# -- candidate-count / ragged-shard audits (tier 1: trace-time checks) --------


def test_candidate_count_invariants():
    """The silent failure modes these guard: a greedy run past its pool
    size selects duplicates, and a merge with fewer candidates than
    r_final degenerates — both must be loud ValueErrors with the remedy
    in the message."""
    from repro.core.distributed import check_candidate_counts

    check_candidate_counts(128, 8, 16, 32)  # the happy path is silent
    check_candidate_counts(16, 8, 16, 128)  # boundary: exactly enough
    with pytest.raises(ValueError, match="budgets must be"):
        check_candidate_counts(128, 8, 0, 32)
    with pytest.raises(ValueError, match="budgets must be"):
        check_candidate_counts(128, 8, 16, 0)
    with pytest.raises(ValueError, match="exceeds the shard pool size"):
        check_candidate_counts(10, 8, 16, 32)
    with pytest.raises(ValueError, match=r"8×2=16 candidates, fewer"):
        check_candidate_counts(128, 8, 2, 32)
    # the message names the fix: the minimal sufficient r_local
    with pytest.raises(ValueError, match="raise r_local to ≥ 4"):
        check_candidate_counts(128, 8, 2, 32)


def test_even_shard_audit():
    from repro.core.distributed import check_even_shards

    check_even_shards(1024, 8, where="t")
    with pytest.raises(ValueError, match="not divisible"):
        check_even_shards(1023, 8, where="t")
    with pytest.raises(ValueError, match="tree_select_host"):
        # the remedy names the ragged-capable driver
        check_even_shards(1023, 8, where="t")


def test_distributed_select_rejects_bad_counts_before_tracing():
    """distributed_select raises the informative audit errors even on a
    1-device mesh — they fire before shard_map ever traces."""
    import jax.numpy as jnp

    from repro.core.distributed import distributed_select
    from repro.launch.mesh import compat_mesh

    mesh = compat_mesh((1,), ("data",))
    feats = jnp.zeros((64, 4))
    with pytest.raises(ValueError, match="exceeds the shard pool size"):
        distributed_select(feats, mesh, r_local=65, r_final=8)
    with pytest.raises(ValueError, match="fewer than r_final"):
        distributed_select(feats, mesh, r_local=4, r_final=8)
    with pytest.raises(ValueError, match="budgets must be"):
        distributed_select(feats, mesh, r_local=4, r_final=0)


@pytest.mark.tier2  # 8-device subprocess run, >60 s
def test_distributed_select_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env=env,
        capture_output=True,
        text=True,
        timeout=480,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DISTRIBUTED_OK" in out.stdout
