"""Sparse top-k selection engine: builder correctness, greedy parity, γ sums.

Covers the acceptance contract of the sparse engine (DESIGN.md §3.5):
  * the blocked top-k builders (pure-jnp scan and Pallas kernel) reproduce a
    dense argsort reference,
  * sparse lazy greedy == pure-JAX top-k greedy == dense exact greedy when
    the graph is complete (k == n), and matches exact selections on
    clustered data for sufficiently large k,
  * γ weights stay a partition of the pool (Σγ == n) at every layer.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import facility_location as fl
from repro.core.craig import CraigConfig, CraigSelector, pairwise_distances
from repro.kernels import ops, ref


def _feats(n=150, d=9, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d))


def _clustered(n=200, d=8, n_clusters=8, seed=1, spread=10.0, sigma=0.3):
    kc, kn = jax.random.split(jax.random.PRNGKey(seed))
    centers = jax.random.normal(kc, (n_clusters, d)) * spread
    assign = jnp.arange(n) % n_clusters
    feats = centers[assign] + sigma * jax.random.normal(kn, (n, d))
    return feats, np.asarray(assign)


# -- top-k builder correctness ------------------------------------------------


@pytest.mark.parametrize(
    "n,d,k",
    [
        (64, 8, 4),
        (150, 9, 17),
        pytest.param(300, 33, 64, marks=pytest.mark.tier2),
    ],
)
def test_topk_kernel_vs_dense_ref(n, d, k):
    x = _feats(n, d, seed=n + k)
    d_max = 2.0 * jnp.sqrt(jnp.max(jnp.sum(x * x, 1))) + 1e-6
    gv, gi = ops.topk_sim(x, k, d_max)
    wv, wi = ref.topk_sim_ref(x, k, d_max)
    np.testing.assert_allclose(
        np.asarray(gv), np.asarray(wv), rtol=2e-4, atol=2e-3
    )
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))
    # every row's best neighbor is itself (self-similarity = d_max)
    np.testing.assert_array_equal(np.asarray(gi)[:, 0], np.arange(n))


@pytest.mark.parametrize("block_m", [37, 128, 1024])
def test_topk_graph_jax_vs_dense_ref(block_m):
    x = _feats(130, 12, seed=3)
    d_max = 2.0 * jnp.sqrt(jnp.max(jnp.sum(x * x, 1))) + 1e-6
    gv, gi = fl.topk_graph(x, 23, d_max=d_max, block_m=block_m, impl="jax")
    wv, wi = ref.topk_sim_ref(x, 23, d_max)
    np.testing.assert_allclose(
        np.asarray(gv), np.asarray(wv), rtol=1e-5, atol=1e-4
    )
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_topk_graph_pallas_impl_matches_jax_impl():
    x = _feats(96, 16, seed=7)
    d_max = jnp.float32(20.0)
    jv, ji = fl.topk_graph(x, 12, d_max=d_max, impl="jax")
    pv, pi = fl.topk_graph(x, 12, d_max=d_max, impl="pallas")
    np.testing.assert_allclose(
        np.asarray(jv), np.asarray(pv), rtol=2e-4, atol=2e-3
    )
    np.testing.assert_array_equal(np.asarray(ji), np.asarray(pi))


# -- greedy parity ------------------------------------------------------------


def test_full_k_sparse_equals_exact_greedy():
    """With a complete graph (k == n) the sparse objective IS the dense one:
    selections, gains, and coverage must match the matrix engine exactly."""
    feats = _feats(120, 8)
    dist = pairwise_distances(feats)
    d_max = jnp.max(dist) + 1e-6
    exact = fl.greedy_fl_matrix(d_max - dist, 15)

    vals, idx = fl.topk_graph(feats, 120, d_max=d_max)
    host = fl.sparse_greedy_fl(
        np.asarray(vals), np.asarray(idx), 15, feats=np.asarray(feats)
    )
    jaxres = fl.greedy_fl_topk(vals, idx, 15)

    np.testing.assert_array_equal(
        np.asarray(exact.indices), np.asarray(host.indices)
    )
    np.testing.assert_array_equal(
        np.asarray(exact.indices), np.asarray(jaxres.indices)
    )
    np.testing.assert_allclose(
        np.asarray(exact.gains), np.asarray(host.gains), rtol=1e-3
    )
    # host engine reports true L(S) (exact assignment from features)
    cov = float(fl.coverage_l(dist, exact.indices))
    assert float(host.coverage) == pytest.approx(cov, rel=1e-3)


def test_clustered_parity_large_k():
    """Clustered pools: k = 128 ≥ inter-cluster reach → identical selections;
    k = 64 still covers exactly the same clusters (one medoid each)."""
    feats, assign = _clustered()
    dist = pairwise_distances(feats)
    d_max = jnp.max(dist) + 1e-6
    exact = fl.greedy_fl_matrix(d_max - dist, 8)

    same = fl.sparse_greedy_fl_features(feats, 8, k=128, d_max=d_max)
    np.testing.assert_array_equal(
        np.sort(np.asarray(exact.indices)), np.sort(np.asarray(same.indices))
    )

    approx = fl.sparse_greedy_fl_features(feats, 8, k=64, d_max=d_max)
    assert sorted(assign[np.asarray(exact.indices)].tolist()) == sorted(
        assign[np.asarray(approx.indices)].tolist()
    )
    cov_ratio = float(approx.coverage) / float(
        fl.coverage_l(dist, exact.indices)
    )
    assert cov_ratio < 1.1


def test_host_and_jax_sparse_agree_on_sparse_graph():
    """Both engines maximize the same sparsified objective — identical
    selections even when the graph is far from complete."""
    feats = _feats(180, 10, seed=11)
    vals, idx = fl.topk_graph(feats, 24)
    host = fl.sparse_greedy_fl(np.asarray(vals), np.asarray(idx), 20)
    jaxres = fl.greedy_fl_topk(vals, idx, 20)
    np.testing.assert_array_equal(
        np.asarray(host.indices), np.asarray(jaxres.indices)
    )
    np.testing.assert_allclose(
        np.asarray(host.gains), np.asarray(jaxres.gains), rtol=1e-3, atol=1e-3
    )


# -- γ-weight invariants and selector wiring ---------------------------------


@pytest.mark.parametrize("k", [8, 32, 150])
def test_gamma_partition_invariant(k):
    """Σγ == n at every k, both with and without features for assignment."""
    feats = _feats(150, 8, seed=k)
    vals, idx = fl.topk_graph(feats, k)
    with_feats = fl.sparse_greedy_fl(
        np.asarray(vals), np.asarray(idx), 12, feats=np.asarray(feats)
    )
    graph_only = fl.sparse_greedy_fl(np.asarray(vals), np.asarray(idx), 12)
    jaxres = fl.greedy_fl_topk(vals, idx, 12)
    for res in (with_feats, graph_only, jaxres):
        w = np.asarray(res.weights)
        assert w.sum() == pytest.approx(150.0)
        assert (w >= 0).all()


@pytest.mark.parametrize("per_class", [False, True])
def test_selector_sparse_engine(per_class):
    feats, assign = _clustered(n=240, n_clusters=4)
    sel = CraigSelector(
        CraigConfig(
            fraction=0.1, engine="sparse", topk_k=48, per_class=per_class
        )
    )
    cs = sel.select(np.asarray(feats), labels=assign if per_class else None)
    assert cs.weights.sum() == pytest.approx(240.0)
    assert cs.size == 24
    assert len(set(cs.indices.tolist())) == cs.size
    if per_class:
        assert set(cs.per_class_sizes) == set(range(4))


def test_selector_sparse_matches_matrix_engine_with_full_k():
    feats = _feats(100, 6, seed=21)
    # identical d_max convention: topk_k == n makes the graph complete and
    # step-1 gains are offset-invariant, so selections coincide
    m = CraigSelector(
        CraigConfig(fraction=0.1, engine="matrix", per_class=False)
    ).select(np.asarray(feats))
    s = CraigSelector(
        CraigConfig(fraction=0.1, engine="sparse", topk_k=100, per_class=False)
    ).select(np.asarray(feats))
    np.testing.assert_array_equal(np.sort(m.indices), np.sort(s.indices))
    np.testing.assert_allclose(m.coverage, s.coverage, rtol=0.05)


def test_sparse_engine_cosine_via_normalized_l2():
    """metric='cosine' routes through l2 on unit-normalized features
    (monotone-equivalent ordering — Capabilities.supports_metrics).  With a
    complete graph (k == n) that is exact greedy on the normalized pool, so
    it must match the matrix engine run on pre-normalized features."""
    from repro.core.engines import MatrixConfig, SparseConfig
    from repro.core.engines.base import normalize_for_metric

    feats = np.asarray(_feats(80, 6, seed=23))
    cos = CraigSelector(
        CraigConfig(
            fraction=0.1, engine=SparseConfig(k=80), metric="cosine",
            per_class=False,
        )
    ).select(feats)
    ref = CraigSelector(
        CraigConfig(fraction=0.1, engine=MatrixConfig(), per_class=False)
    ).select(np.asarray(normalize_for_metric(jnp.asarray(feats), "cosine")))
    np.testing.assert_array_equal(np.sort(cos.indices), np.sort(ref.indices))
    assert cos.weights.sum() == pytest.approx(80.0)


def test_midsize_pool_no_dense_smoke():
    """5k-point pool runs the sparse engine comfortably (O(n·k) memory);
    a quick functional stand-in for the 200k bench run (EXPERIMENTS.md)."""
    feats = np.asarray(_feats(5000, 8, seed=5))
    cs = CraigSelector(
        CraigConfig(fraction=0.004, engine="sparse", topk_k=16, per_class=False)
    ).select(feats)
    assert cs.size == 20
    assert cs.weights.sum() == pytest.approx(5000.0)
