"""Tier-1 guard: the legacy flat engine knobs live ONLY in the shim module.

The check itself is now the ``flat-engine-knob`` row of the api-hygiene
rule table in :mod:`repro.analysis.rules.api_hygiene` — AST-based, so
docstring prose no longer trips it but re-threaded kwargs and attribute
names do.  This test is a thin invocation of the linter restricted to
that one rule; the full gate (all rules) is ``tests/test_lint_clean.py``.
"""
from pathlib import Path

from repro.analysis.engine import run_analysis

SRC = Path(__file__).resolve().parent.parent / "src"
SHIM = SRC / "repro" / "core" / "engines" / "legacy.py"


def test_no_flat_engine_knobs_outside_shim():
    assert SHIM.exists(), "legacy shim module moved? update the rule table"
    result = run_analysis([SRC], rule_filter=frozenset({"flat-engine-knob"}))
    offenders = [f.format() for f in result.active]
    assert not offenders, (
        "flat engine knobs referenced outside the legacy shim "
        "(use typed EngineConfigs from repro.core.engines):\n"
        + "\n".join(offenders)
    )


def test_rule_catches_a_reintroduced_knob(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("def f(device_q):\n    return device_q + 1\n")
    result = run_analysis(
        [bad], rule_filter=frozenset({"flat-engine-knob"})
    )
    assert result.active, "linter failed to flag a reintroduced flat knob"
    assert all(f.rule_id == "flat-engine-knob" for f in result.active)
