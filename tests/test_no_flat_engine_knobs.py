"""Tier-1 guard: the legacy flat engine knobs live ONLY in the shim module.

The SelectionEngine redesign (repro.core.engines) replaced the flat
engine-prefixed CraigConfig knobs with typed per-engine configs; the old
names survive solely inside ``repro/core/engines/legacy.py`` (declaration
+ mapping).  Any other reference under ``src/`` means engine-specific
state is being re-threaded around the registry again — the exact
duplication this refactor removed.
"""
import re
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"
SHIM = SRC / "repro" / "core" / "engines" / "legacy.py"
FLAT_KNOBS = re.compile(r"\b(device_q|topk_k|device_stale_tol)\b")


def test_no_flat_engine_knobs_outside_shim():
    assert SHIM.exists(), "legacy shim module moved? update this guard"
    offenders = []
    for path in sorted(SRC.rglob("*.py")):
        if path == SHIM:
            continue
        for lineno, line in enumerate(
            path.read_text(encoding="utf-8").splitlines(), start=1
        ):
            if FLAT_KNOBS.search(line):
                offenders.append(f"{path.relative_to(SRC)}:{lineno}: {line.strip()}")
    assert not offenders, (
        "flat engine knobs referenced outside the legacy shim "
        "(use typed EngineConfigs from repro.core.engines):\n"
        + "\n".join(offenders)
    )
