"""Greedy facility-location engines: exactness, parity, invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import facility_location as fl
from repro.core.craig import pairwise_distances


def _sim(n=120, d=8, seed=0):
    feats = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    dist = pairwise_distances(feats)
    d_max = jnp.max(dist) + 1e-6
    return feats, dist, d_max - dist


def test_matrix_equals_lazy():
    _, _, sim = _sim()
    r1 = fl.greedy_fl_matrix(sim, 15)
    r2 = fl.lazy_greedy_fl(np.asarray(sim), 15)
    np.testing.assert_array_equal(np.asarray(r1.indices), np.asarray(r2.indices))
    np.testing.assert_allclose(
        np.asarray(r1.gains), np.asarray(r2.gains), rtol=1e-4
    )


def test_features_engine_equals_matrix():
    feats, _, sim = _sim()
    r1 = fl.greedy_fl_matrix(sim, 12)
    r2 = fl.greedy_fl_features(feats, 12, gains_impl="jax")
    np.testing.assert_array_equal(np.asarray(r1.indices), np.asarray(r2.indices))


def test_features_pallas_equals_jax():
    feats, _, _ = _sim(n=96, d=16)
    r1 = fl.greedy_fl_features(feats, 10, gains_impl="jax")
    r2 = fl.greedy_fl_features(feats, 10, gains_impl="pallas")
    np.testing.assert_array_equal(np.asarray(r1.indices), np.asarray(r2.indices))


def test_weights_sum_to_n():
    """γ weights are cluster sizes: Σγ = |V| (paper Alg. 1)."""
    _, _, sim = _sim(n=200)
    for r in (1, 7, 50):
        res = fl.greedy_fl_matrix(sim, r)
        assert float(res.weights.sum()) == pytest.approx(200.0)


def test_gains_non_increasing():
    """Exact greedy marginal gains are non-increasing (submodularity)."""
    _, _, sim = _sim()
    res = fl.greedy_fl_matrix(sim, 30)
    g = np.asarray(res.gains)
    assert np.all(g[:-1] >= g[1:] - 1e-4)


def test_coverage_decreases_with_budget():
    """L(S) = Σ_i min_{j∈S} d_ij shrinks as the subset grows (paper Eq. 8)."""
    _, dist, sim = _sim()
    covs = []
    for r in (2, 5, 10, 40):
        res = fl.greedy_fl_matrix(sim, r)
        covs.append(float(fl.coverage_l(dist, res.indices)))
    assert covs == sorted(covs, reverse=True)


def test_stochastic_greedy_quality():
    """Stochastic greedy's coverage stays close to exact greedy's."""
    _, dist, sim = _sim(n=256)
    exact = fl.greedy_fl_matrix(sim, 20)
    stoch = fl.stochastic_greedy_fl(sim, 20, jax.random.PRNGKey(1), 64)
    c_e = float(fl.coverage_l(dist, exact.indices))
    c_s = float(fl.coverage_l(dist, stoch.indices))
    assert c_s <= 1.35 * c_e  # within 35% of exact coverage


def test_stochastic_greedy_no_duplicates_small_pool():
    """Regression: with a tiny pool and a tiny candidate sample, every
    sampled candidate is eventually already chosen; the old code re-selected
    cand[0] forever.  The fallback must keep selections unique."""
    _, _, sim = _sim(n=8)
    for seed in range(8):
        res = fl.stochastic_greedy_fl(sim, 8, jax.random.PRNGKey(seed), 2)
        idx = np.asarray(res.indices).tolist()
        assert sorted(idx) == list(range(8)), idx
        assert float(res.weights.sum()) == pytest.approx(8.0)


def test_stochastic_greedy_budget_clamped():
    _, _, sim = _sim(n=6)
    res = fl.stochastic_greedy_fl(sim, 10, jax.random.PRNGKey(0), 3)
    assert len(np.asarray(res.indices)) == 6


@pytest.mark.parametrize("prefix", [1, 5, 11])
def test_warm_start_matches_cold_matrix(prefix):
    """Prefix consistency: resuming exact greedy from a prefix of the cold
    selection reproduces the cold selection (indices, gains, weights)."""
    _, _, sim = _sim()
    cold = fl.greedy_fl_matrix(sim, 12)
    warm = fl.greedy_fl_matrix(sim, 12, init_selected=cold.indices[:prefix])
    np.testing.assert_array_equal(np.asarray(cold.indices), np.asarray(warm.indices))
    np.testing.assert_allclose(
        np.asarray(cold.gains), np.asarray(warm.gains), rtol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(cold.weights), np.asarray(warm.weights)
    )


def test_warm_start_matches_cold_lazy():
    _, _, sim = _sim()
    cold = fl.lazy_greedy_fl(np.asarray(sim), 14)
    warm = fl.lazy_greedy_fl(
        np.asarray(sim), 14, init_selected=np.asarray(cold.indices)[:7]
    )
    np.testing.assert_array_equal(np.asarray(cold.indices), np.asarray(warm.indices))
    np.testing.assert_allclose(
        np.asarray(cold.gains), np.asarray(warm.gains), rtol=1e-6
    )


def test_warm_start_matches_cold_features():
    feats = _sim()[0]
    cold = fl.greedy_fl_features(feats, 10, gains_impl="jax")
    warm = fl.greedy_fl_features(
        feats, 10, gains_impl="jax", init_selected=cold.indices[:4]
    )
    np.testing.assert_array_equal(np.asarray(cold.indices), np.asarray(warm.indices))


def test_warm_start_matches_cold_sparse():
    feats, _, _ = _sim(n=90)
    vals, idx = fl.topk_graph(feats, 32)
    cold = fl.sparse_greedy_fl(
        np.asarray(vals), np.asarray(idx), 10, feats=np.asarray(feats)
    )
    warm = fl.sparse_greedy_fl(
        np.asarray(vals), np.asarray(idx), 10, feats=np.asarray(feats),
        init_selected=np.asarray(cold.indices)[:5],
    )
    np.testing.assert_array_equal(np.asarray(cold.indices), np.asarray(warm.indices))
    np.testing.assert_allclose(
        np.asarray(cold.weights), np.asarray(warm.weights)
    )


def test_warm_start_full_budget_is_identity():
    """init_selected of size == budget: the engines replay the prefix and
    select nothing new (γ/coverage still recomputed on current features)."""
    _, _, sim = _sim(n=40)
    cold = fl.greedy_fl_matrix(sim, 6)
    warm = fl.greedy_fl_matrix(sim, 6, init_selected=cold.indices)
    np.testing.assert_array_equal(np.asarray(cold.indices), np.asarray(warm.indices))
    np.testing.assert_allclose(np.asarray(cold.weights), np.asarray(warm.weights))


def test_warm_start_longer_than_budget_raises():
    _, _, sim = _sim(n=20)
    with pytest.raises(ValueError, match="budget"):
        fl.greedy_fl_matrix(sim, 3, init_selected=jnp.arange(5))


def test_weighted_point_greedy():
    """Point weights act as multiplicities: duplicating a point == weighting."""
    feats = jax.random.normal(jax.random.PRNGKey(3), (40, 4))
    dup = jnp.concatenate([feats, feats[:10]])  # points 0..9 twice
    dist_d = pairwise_distances(dup)
    sim_d = jnp.max(dist_d) + 1e-6 - dist_d

    dist_w = pairwise_distances(feats)
    # same d_max so similarity scales match
    sim_w = jnp.max(dist_d) + 1e-6 - dist_w
    pw = jnp.ones((40,)).at[:10].set(2.0)
    r_dup = fl.greedy_fl_matrix(sim_d, 5)
    r_w = fl.greedy_fl_matrix(sim_w, 5, point_weights=pw)
    # selections map to the same base points (dup indices mod 40)
    assert set(int(i) % 40 for i in np.asarray(r_dup.indices)) == set(
        int(i) for i in np.asarray(r_w.indices)
    )


def test_facility_location_value_monotone():
    _, _, sim = _sim(n=60)
    mask = jnp.zeros((60,), bool)
    prev = 0.0
    order = np.random.RandomState(0).permutation(60)[:20]
    for e in order:
        mask = mask.at[int(e)].set(True)
        val = float(fl.facility_location_value(sim, mask))
        assert val >= prev - 1e-4
        prev = val
