"""Selection-quality property harness over EVERY registered engine.

Drives each engine through the registry surface (``list_engines``) so a
new engine plugin is automatically held to the selection contract without
edits here:

* **objective gate** vs host lazy greedy on the same pool:
  ``F(S_engine) ≥ factor · F(S_lazy)`` with ``factor = 1/2 − ε`` for the
  sieve-streaming engine (its one-pass guarantee, Badanidiyuru et al.) and
  ``(1 − 1/e) − ε`` for every other engine (the Nemhauser tier — exact and
  near-exact engines clear it with huge margin at these sizes);
* **γ is a partition histogram**: Σγ == n, γ ≥ 0 (paper Alg. 1 line 8);
* **indices are unique and in-pool**;
* **a warm-start prefix survives verbatim** at the front of the selection.

The grid of seeds × shapes runs deterministically in tier 1; when
``hypothesis`` is installed the same contract is additionally fuzzed over
random pools.  Larger shapes ride the tier-2 lane.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engines as E
from repro.core import facility_location as fl
from repro.core.craig import pairwise_distances

try:  # fuzz lane is optional — the deterministic grid always runs
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - env without requirements-dev
    HAVE_HYPOTHESIS = False

EPS_SLACK = 0.10  # tolerance eaten out of each theoretical factor


def _gate(name: str) -> float:
    """Quality floor for ``name``, derived from its advertised guarantee."""
    if name == "streaming":
        return 0.5 - EPS_SLACK  # sieve-streaming: (1/2 − O(ε))·OPT
    return (1.0 - 1.0 / np.e) - 0.05  # Nemhauser tier


def _config_for(name: str, n: int) -> E.EngineConfig:
    cls = E.get_engine(name).config_cls
    if name == "sparse":
        return cls(k=n)  # complete graph → exact greedy at these sizes
    if name == "stochastic":
        return cls(delta=0.01)  # δ→0 limit: effectively the full ground set
    return cls()


def _make_feats(n: int, d: int, kind: str, seed: int) -> np.ndarray:
    rng = np.random.RandomState(seed)
    if kind == "clustered":
        c = rng.randn(max(4, n // 12), d).astype(np.float32) * 4.0
        feats = c[rng.randint(0, len(c), n)] + 0.3 * rng.randn(n, d)
    else:
        feats = rng.randn(n, d)
    return feats.astype(np.float32)


def _sim(feats: np.ndarray) -> np.ndarray:
    d = np.asarray(pairwise_distances(jnp.asarray(feats)))
    return d.max() + 1e-6 - d


def _objective(sim: np.ndarray, idx) -> float:
    mask = np.zeros(sim.shape[0], bool)
    mask[np.asarray(idx)] = True
    return float(fl.facility_location_value(jnp.asarray(sim), jnp.asarray(mask)))


def _check_contract(name: str, feats: np.ndarray, budget: int) -> None:
    """The full property set for one engine on one pool."""
    n = feats.shape[0]
    eng = E.make_engine(_config_for(name, n))
    res = eng.select(jnp.asarray(feats), budget, rng=0)
    idx = np.asarray(res.indices)
    assert idx.shape == (budget,), name
    assert len(np.unique(idx)) == budget, name  # unique …
    assert idx.min() >= 0 and idx.max() < n, name  # … and in-pool
    w = np.asarray(res.weights)
    assert w.sum() == pytest.approx(float(n)), name  # Σγ == n
    assert (w >= 0).all(), name
    sim = _sim(feats)
    f_eng = _objective(sim, idx)
    f_ref = _objective(sim, fl.lazy_greedy_fl(sim, budget).indices)
    assert f_eng >= _gate(name) * f_ref - 1e-4, (name, f_eng, f_ref)


# -- deterministic grid (tier 1) ----------------------------------------------

SHAPES = [
    (48, 6, 8, "random", 0),
    (64, 4, 10, "clustered", 1),
    (40, 8, 6, "random", 2),
]


@pytest.mark.parametrize("n,d,budget,kind,seed", SHAPES)
@pytest.mark.parametrize("name", E.list_engines())
def test_objective_gate_and_partition(name, n, d, budget, kind, seed):
    _check_contract(name, _make_feats(n, d, kind, seed), budget)


@pytest.mark.parametrize("name", E.list_engines())
def test_warm_start_prefix_preserved(name):
    """init_selected is installed verbatim at the front before greedy (or
    the sieve finalize) resumes — the refresh warm-start contract."""
    n, prefix, budget = 56, [7, 23], 8
    feats = _make_feats(n, 5, "clustered", 3)
    eng = E.make_engine(_config_for(name, n))
    res = eng.select(jnp.asarray(feats), budget, init_selected=prefix, rng=0)
    idx = np.asarray(res.indices)
    np.testing.assert_array_equal(idx[:2], prefix, err_msg=name)
    assert len(np.unique(idx)) == budget, name
    assert np.asarray(res.weights).sum() == pytest.approx(float(n)), name


@pytest.mark.parametrize("name", E.list_engines())
def test_oversized_warm_prefix_raises(name):
    """Regression: the streaming engine used to silently truncate an
    oversized ``init_selected`` to the budget — a warm start that quietly
    drops its tail trains on a different coreset than the caller staged.
    Every engine must reject prefix > budget loudly."""
    n, budget = 40, 4
    feats = _make_feats(n, 5, "random", 6)
    eng = E.make_engine(_config_for(name, n))
    with pytest.raises(ValueError):
        eng.select(
            jnp.asarray(feats), budget, init_selected=list(range(6)), rng=0
        )


# -- hierarchical tree selection gate -----------------------------------------

# depth/fan-out grid: depth-1 (the two-round shape), branching depth-2,
# binary depth-3 — every tree must clear the same Nemhauser-tier gate vs
# host lazy greedy that the flat engines do, on BOTH wire modes.  The
# worst-case GreeDi composition factor decays with depth, but on pools
# like these (and empirically, §GreeDi) the loss is far smaller than
# EPS_SLACK — a depth regression (bad merge budgets, wire corruption)
# shows up here immediately.
TREE_GRID = [
    ((4,), "none"),
    ((4,), "int8"),
    ((4, 2), "int8"),
    ((2, 2, 2), "int8"),
    ((2, 4), "none"),
]


@pytest.mark.parametrize("fanouts,compress", TREE_GRID)
def test_tree_objective_gate_and_partition(fanouts, compress):
    from repro.distributed.tree_select import TreeTopology, tree_select_host

    n, d, budget = 96, 6, 10
    feats = _make_feats(n, d, "clustered", 7)
    sel = tree_select_host(
        jnp.asarray(feats), TreeTopology(fanouts), r_local=8, r_final=budget,
        compress=compress,
    )
    idx = np.asarray(sel.indices)
    assert idx.shape == (budget,)
    assert len(np.unique(idx)) == budget and idx.min() >= 0 and idx.max() < n
    w = np.asarray(sel.weights)
    assert w.sum() == pytest.approx(float(n)) and (w >= 0).all()
    sim = _sim(feats)
    f_tree = _objective(sim, idx)
    f_ref = _objective(sim, fl.lazy_greedy_fl(sim, budget).indices)
    assert f_tree >= _gate("matrix") * f_ref - 1e-4, (
        fanouts, compress, f_tree, f_ref)


# -- slow shapes (tier 2) -----------------------------------------------------

SLOW_SHAPES = [
    (400, 16, 40, "clustered", 4),
    (512, 8, 32, "random", 5),
]


@pytest.mark.tier2
@pytest.mark.parametrize("n,d,budget,kind,seed", SLOW_SHAPES)
@pytest.mark.parametrize("name", E.list_engines())
def test_objective_gate_slow_shapes(name, n, d, budget, kind, seed):
    _check_contract(name, _make_feats(n, d, kind, seed), budget)


# -- hypothesis fuzz lane (optional) ------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(
        n=st.integers(16, 48),
        d=st.integers(2, 8),
        seed=st.integers(0, 50),
        kind=st.sampled_from(["random", "clustered"]),
        data=st.data(),
    )
    def test_fuzz_contract_all_engines(n, d, seed, kind, data):
        budget = data.draw(st.integers(2, max(2, n // 4)))
        feats = _make_feats(n, d, kind, seed)
        for name in E.list_engines():
            _check_contract(name, feats, budget)

else:  # keep the lane visible in reports instead of silently absent

    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
    def test_fuzz_contract_all_engines():
        pass
