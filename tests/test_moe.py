"""MoE dispatch/combine: capacity semantics, weighting, shared experts."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.moe import MoEConfig, init_moe, moe_ffn


def test_single_expert_equals_dense():
    """E=1, k=1 with ample capacity reduces to an ordinary gated FFN."""
    cfg = MoEConfig(d_model=16, d_ff_expert=32, n_experts=1, top_k=1,
                    capacity_factor=4.0)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, aux = moe_ffn(params, cfg, x)
    w_in = params["experts_in"][0]
    w_out = params["experts_out"][0]
    h = x @ w_in
    g, u = jnp.split(h, 2, axis=-1)
    want = (jax.nn.silu(g) * u) @ w_out
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-4, atol=2e-5)
    assert float(aux) == 1.0  # perfectly "balanced" single expert


def test_no_capacity_drop_with_large_factor():
    """With capacity ≥ tokens·k/E·E every token is routed: output nonzero."""
    cfg = MoEConfig(d_model=8, d_ff_expert=16, n_experts=4, top_k=2,
                    capacity_factor=8.0)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 8))
    y, _ = moe_ffn(params, cfg, x)
    norms = jnp.linalg.norm(y[0], axis=-1)
    assert float(jnp.min(norms)) > 0.0


def test_capacity_drops_tokens():
    """Tiny capacity forces drops: some tokens get zero expert output."""
    cfg = MoEConfig(d_model=8, d_ff_expert=16, n_experts=2, top_k=1,
                    capacity_factor=0.12)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 8))
    y, _ = moe_ffn(params, cfg, x)
    norms = np.asarray(jnp.linalg.norm(y[0], axis=-1))
    assert (norms < 1e-6).sum() > 0  # dropped tokens exist
    assert (norms > 1e-6).sum() > 0  # routed tokens exist


def test_shared_experts_always_on():
    cfg = MoEConfig(d_model=8, d_ff_expert=16, n_experts=2, top_k=1,
                    capacity_factor=0.01, n_shared_experts=1)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 8))
    y, _ = moe_ffn(params, cfg, x)
    # with ~all tokens dropped by routed experts, shared path still fires
    norms = np.asarray(jnp.linalg.norm(y[0], axis=-1))
    assert (norms > 1e-6).all()


def test_group_independence():
    """Groups dispatch independently: permuting group order permutes output."""
    cfg = MoEConfig(d_model=8, d_ff_expert=16, n_experts=4, top_k=2,
                    capacity_factor=2.0)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 16, 8))
    y, _ = moe_ffn(params, cfg, x)
    y_perm, _ = moe_ffn(params, cfg, x[::-1])
    np.testing.assert_allclose(
        np.asarray(y[::-1]), np.asarray(y_perm), rtol=1e-5, atol=1e-6
    )


def test_aux_loss_favors_balance():
    """Aux loss is ≥ 1 and equals ~1 under a uniform router."""
    cfg = MoEConfig(d_model=8, d_ff_expert=16, n_experts=4, top_k=1,
                    capacity_factor=2.0)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    params = dict(params)
    params["router"] = jnp.zeros_like(params["router"])  # uniform logits
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 256, 8))
    _, aux = moe_ffn(params, cfg, x)
    assert 0.9 <= float(aux) <= 1.6


def test_grad_flows_through_router():
    cfg = MoEConfig(d_model=8, d_ff_expert=16, n_experts=4, top_k=2,
                    capacity_factor=2.0)
    params = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 8))

    def loss(p):
        y, aux = moe_ffn(p, cfg, x)
        return jnp.sum(y**2) + 0.01 * aux

    g = jax.grad(loss)(params)
    assert float(jnp.linalg.norm(g["router"])) > 0
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))
