"""Gradient-proxy extraction vs exact-gradient oracles (paper Eq. 9/16)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.proxy import (
    classifier_last_layer_proxy,
    convex_feature_proxy,
    exact_per_example_grads,
    lm_unembed_input_proxy,
)
from repro.data.synthetic import make_classification


def test_classifier_proxy_is_exact_last_layer_gradient():
    """For a linear softmax classifier, ∇_W f_i = (p−y) xᵀ, so the proxy
    (p−y) captures the full gradient up to the shared xᵀ factor."""
    n, d, c = 20, 5, 4
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (d, c)) * 0.3
    x = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    y = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, c)

    logits = x @ W
    proxy = classifier_last_layer_proxy(logits, y)

    def loss_one(w, xi, yi):
        lg = xi @ w
        return -jax.nn.log_softmax(lg)[yi]

    grads = exact_per_example_grads(loss_one, W, x, y)  # (n, d·c)
    # ∇_W f_i flattened = outer(x_i, p_i − y_i) → reconstruct & compare
    recon = jax.vmap(jnp.outer)(x, proxy).reshape(n, -1)
    np.testing.assert_allclose(np.asarray(grads), np.asarray(recon), rtol=1e-4, atol=1e-5)


def test_convex_proxy_bound_eq9():
    """Eq. 9: ‖∇f_i(w) − ∇f_j(w)‖ ≤ O(‖w‖)·‖x_i − x_j‖ for same-label pairs
    (logistic regression, ‖x‖≤1)."""
    x, y = make_classification(40, 6, 2, seed=1)
    x = x / np.linalg.norm(x, axis=1, keepdims=True)  # ‖x_i‖ ≤ 1
    ybin = jnp.asarray(y * 2.0 - 1.0)
    xj = jnp.asarray(x)

    def loss_one(w, xi, yi):
        return jnp.log1p(jnp.exp(-yi * (xi @ w)))

    for seed in range(3):
        w = jax.random.normal(jax.random.PRNGKey(seed), (6,))
        grads = exact_per_example_grads(loss_one, w, xj, ybin)
        feats = convex_feature_proxy(xj)
        same = y[:, None] == y[None, :]
        gd = np.linalg.norm(
            np.asarray(grads)[:, None] - np.asarray(grads)[None], axis=-1
        )
        xd = np.linalg.norm(
            np.asarray(feats)[:, None] - np.asarray(feats)[None], axis=-1
        )
        # constant: sup sigmoid' · ‖x_j‖ ≤ 1; allow slack 1.0 + eps
        mask = same & ~np.eye(40, dtype=bool)
        assert (gd[mask] <= 1.0 * xd[mask] + 1e-5).all()


def test_lm_proxy_equals_autodiff_hidden_gradient():
    """lm_unembed_input_proxy == d(mean-token CE)/d hidden, pooled — the
    exact §3.4 quantity, validated against jax.grad."""
    B, T, D, V = 3, 10, 8, 32
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    hidden = jax.random.normal(keys[0], (B, T, D)) * 0.5
    W = jax.random.normal(keys[1], (D, V)) * 0.2
    labels = jax.random.randint(keys[2], (B, T), 0, V)

    got = lm_unembed_input_proxy(hidden, W, labels, chunk=4)

    def seq_loss(h_b, y_b):
        logits = h_b @ W
        return -jnp.mean(
            jnp.take_along_axis(jax.nn.log_softmax(logits), y_b[:, None], 1)
        )

    # d/dh of the mean-token loss, pooled (mean over tokens = sum of per-token
    # grads / T, and proxy pools with mean → same thing)
    g = jax.vmap(jax.grad(seq_loss))(hidden, labels)  # (B, T, D)
    want = jnp.sum(g, axis=1) / 1.0  # grad already includes 1/T
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_lm_proxy_mask():
    B, T, D, V = 2, 8, 4, 16
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    hidden = jax.random.normal(keys[0], (B, T, D))
    W = jax.random.normal(keys[1], (D, V)) * 0.3
    labels = jax.random.randint(keys[2], (B, T), 0, V)
    mask = jnp.ones((B, T)).at[:, 5:].set(0.0)
    got = lm_unembed_input_proxy(hidden, W, labels, mask=mask, chunk=4)
    want = lm_unembed_input_proxy(hidden[:, :5], W, labels[:, :5], chunk=5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_lm_proxy_valid_v_masks_padded_vocab():
    B, T, D, V, Vp = 2, 6, 4, 20, 32
    keys = jax.random.split(jax.random.PRNGKey(2), 3)
    hidden = jax.random.normal(keys[0], (B, T, D))
    W = jax.random.normal(keys[1], (D, Vp)) * 0.3
    labels = jax.random.randint(keys[2], (B, T), 0, V)
    got = lm_unembed_input_proxy(hidden, W, labels, chunk=3, valid_v=V)
    want = lm_unembed_input_proxy(hidden, W[:, :V], labels, chunk=3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


def test_lm_proxy_bf16_compute_close_to_fp32():
    """The production bf16 proxy path ranks/clusters like the fp32 oracle."""
    import numpy as np

    B, T, D, V = 8, 16, 32, 512
    keys = jax.random.split(jax.random.PRNGKey(5), 3)
    hidden = jax.random.normal(keys[0], (B, T, D)) * 0.5
    W = jax.random.normal(keys[1], (D, V)) * 0.1
    labels = jax.random.randint(keys[2], (B, T), 0, V)
    f32 = lm_unembed_input_proxy(hidden, W, labels, chunk=8)
    bf16 = lm_unembed_input_proxy(
        hidden, W, labels, chunk=8, compute_dtype=jnp.bfloat16
    )
    # elementwise closeness
    np.testing.assert_allclose(
        np.asarray(bf16), np.asarray(f32), rtol=0.1, atol=5e-3
    )
    # pairwise-distance structure (what selection consumes) is preserved
    def pdist(f):
        d = np.asarray(f)
        return np.linalg.norm(d[:, None] - d[None], axis=-1)
    corr = np.corrcoef(pdist(f32).ravel(), pdist(bf16).ravel())[0, 1]
    assert corr > 0.99, corr


# ---------------------------------------------------------------------------
# Fused ce_proxy kernel ↔ lm_unembed_input_proxy parity (interpret mode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "T,D,V",
    [
        (8, 8, 16),     # everything block-aligned
        (10, 12, 20),   # non-multiple T, D, V tails
        (33, 16, 100),  # T and V straddle several blocks
        (16, 8, 129),   # V one past a block boundary
    ],
)
def test_ce_proxy_kernel_matches_lm_proxy(T, D, V):
    """The two proxy paths — fused Pallas kernel (per-token, pooled here)
    and chunked einsum scan — compute the same §3.4 quantity, including on
    vocab-padded configs (the kernel's valid_v bias == lm's pad_bias)."""
    from repro.kernels import ops

    Vp = V + 12  # tile-padded unembedding, real vocab = V
    keys = jax.random.split(jax.random.PRNGKey(T * 1000 + V), 3)
    hidden = jax.random.normal(keys[0], (T, D)) * 0.5
    W = jax.random.normal(keys[1], (D, Vp)) * 0.2
    labels = jax.random.randint(keys[2], (T,), 0, V)

    got = ops.ce_proxy(
        hidden, W, labels, block_t=8, block_v=16, valid_v=V, interpret=True
    )  # (T, D) per-token
    want = lm_unembed_input_proxy(
        hidden[None], W, labels[None], chunk=5, valid_v=V
    )  # (1, D) token mean
    np.testing.assert_allclose(
        np.asarray(got).mean(0), np.asarray(want)[0], rtol=1e-4, atol=1e-5
    )


def test_ce_proxy_kernel_bf16_compute_close_to_fp32():
    """bf16 compute_dtype (MXU matmuls only; fp32 softmax/accumulators)
    stays tolerance-close to the fp32 kernel — mirroring the
    lm_unembed_input_proxy bf16 contract."""
    from repro.kernels import ops

    T, D, V = 32, 16, 64
    keys = jax.random.split(jax.random.PRNGKey(7), 3)
    hidden = jax.random.normal(keys[0], (T, D)) * 0.5
    W = jax.random.normal(keys[1], (D, V)) * 0.1
    labels = jax.random.randint(keys[2], (T,), 0, V)
    f32 = ops.ce_proxy(hidden, W, labels, block_t=8, block_v=16, interpret=True)
    bf16 = ops.ce_proxy(
        hidden, W, labels, block_t=8, block_v=16, interpret=True,
        compute_dtype=jnp.bfloat16,
    )
    np.testing.assert_allclose(
        np.asarray(bf16), np.asarray(f32), rtol=0.1, atol=5e-3
    )
    # and the bf16 kernel still agrees with the bf16 einsum path
    lm_bf16 = lm_unembed_input_proxy(
        hidden[None], W, labels[None], chunk=8, compute_dtype=jnp.bfloat16
    )
    np.testing.assert_allclose(
        np.asarray(bf16).mean(0), np.asarray(lm_bf16)[0], rtol=0.1, atol=5e-3
    )
