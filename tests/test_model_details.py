"""Model-level details: padded-vocab exactness, remat invariance, weights."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, init_params, loss_fn
from repro.models.model import _chunked_ce


def test_padded_vocab_ce_is_exact():
    """CE with padded logit columns masked == CE over the true vocab."""
    B, T, D, V = 2, 12, 16, 100  # padded to 128
    key = jax.random.PRNGKey(0)
    hidden = jax.random.normal(key, (B, T, D))
    unembed = jax.random.normal(jax.random.PRNGKey(1), (D, 128)) * 0.3
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, V)
    padded = _chunked_ce(hidden, unembed, labels, chunk=4, valid_v=V)
    exact = _chunked_ce(hidden, unembed[:, :V], labels, chunk=4)
    np.testing.assert_allclose(np.asarray(padded), np.asarray(exact), rtol=2e-3, atol=1e-3)


def test_chunk_size_invariance():
    B, T, D, V = 2, 24, 8, 64
    hidden = jax.random.normal(jax.random.PRNGKey(0), (B, T, D))
    unembed = jax.random.normal(jax.random.PRNGKey(1), (D, V)) * 0.3
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, V)
    a = _chunked_ce(hidden, unembed, labels, chunk=4)
    b = _chunked_ce(hidden, unembed, labels, chunk=24)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=1e-3)


@pytest.mark.tier2
@pytest.mark.parametrize("policy", ["nothing", "dots", "full"])
def test_remat_policy_value_invariance(policy):
    """Remat changes memory/recompute, never the loss value or gradients."""
    cfg = ModelConfig(
        name="t", family="dense", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=128, logit_chunk=8,
        remat_policy=policy,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 128),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 128),
    }
    loss, _ = loss_fn(params, cfg, batch)
    g = jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)

    cfg0 = dataclasses.replace(cfg, remat_policy="nothing")
    loss0, _ = loss_fn(params, cfg0, batch)
    g0 = jax.grad(lambda p: loss_fn(p, cfg0, batch)[0])(params)
    # bf16 compute: different fusion/recompute orders reassociate sums
    assert float(loss) == pytest.approx(float(loss0), rel=2e-3)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g0)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-2, atol=2e-3)


@pytest.mark.tier2
def test_craig_weights_scale_gradients():
    """γ-weighted loss == reweighting per-example gradient contributions
    (the paper's per-element stepsize semantics under linear scaling)."""
    cfg = ModelConfig(
        name="t", family="dense", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=2, d_ff=32, vocab_size=64, logit_chunk=8,
    )
    params = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, 64)

    def grad_for(w):
        batch = {"tokens": toks, "labels": labels, "weights": jnp.asarray(w)}
        return jax.grad(lambda p: loss_fn(p, cfg, batch)[0])(params)

    # weights (2, 0): loss == example-0-only loss
    g_w = grad_for([2.0, 0.0])
    batch0 = {"tokens": toks[:1], "labels": labels[:1]}
    g_0 = jax.grad(lambda p: loss_fn(p, cfg, batch0)[0])(params)
    for a, b in zip(jax.tree.leaves(g_w), jax.tree.leaves(g_0)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-2, atol=2e-4)


@pytest.mark.tier2
def test_scan_vs_unrolled_stack_equivalence():
    """scan_layers=False (roofline probes) computes the identical function."""
    base = dict(
        name="t", family="dense", n_layers=4, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=64, logit_chunk=8,
    )
    cfg_s = ModelConfig(**base, scan_layers=True)
    cfg_u = ModelConfig(**base, scan_layers=False)
    params_s = init_params(jax.random.PRNGKey(0), cfg_s)
    # map scanned params → unrolled params (period = 1 layer)
    scanned = params_s["stack"]["scanned"]
    remainder = [
        jax.tree.map(lambda l: l[i], scanned[0]) for i in range(4)
    ]
    params_u = dict(params_s)
    params_u["stack"] = {"scanned": None, "remainder": remainder}
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 64),
    }
    l_s, _ = loss_fn(params_s, cfg_s, batch)
    l_u, _ = loss_fn(params_u, cfg_u, batch)
    # identical math; bf16 fusion order differs between scan and unrolled
    assert float(l_s) == pytest.approx(float(l_u), rel=2e-3)
