"""Sharding rules: every leaf of every arch gets a valid, divisible spec."""
import os
import subprocess
import sys
import textwrap

import numpy as np

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
    import jax, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs.registry import ARCHS
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_production_mesh
    from repro.models import init_params, init_serve_state

    for multi in (False, True):
        mesh = make_production_mesh(multi_pod=multi)
        sizes = dict(mesh.shape)
        for arch, cfg in ARCHS.items():
            tree = jax.eval_shape(lambda c=cfg: init_params(
                jax.random.PRNGKey(0), c))
            specs = shd.param_specs(tree, mesh)
            leaves = jax.tree.leaves(tree)
            spec_leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
            assert len(leaves) == len(spec_leaves)
            n_sharded = 0
            for leaf, spec in zip(leaves, spec_leaves):
                for i, ax in enumerate(spec):
                    if ax is None:
                        continue
                    group = ax if isinstance(ax, tuple) else (ax,)
                    k = int(np.prod([sizes[g] for g in group]))
                    assert leaf.shape[i] % k == 0, (arch, leaf.shape, spec)
                    n_sharded += 1
            # the bulk of parameters must actually be sharded
            big = [
                (l, s) for l, s in zip(leaves, spec_leaves)
                if int(np.prod(l.shape)) > 1_000_000
            ]
            for l, s in big:
                assert any(a is not None for a in s), (arch, l.shape, "replicated big leaf")
        print("MESH_OK", multi)
    print("SHARDING_OK")
    """
)


def test_param_specs_all_archs_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env=env, capture_output=True, text=True, timeout=480,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARDING_OK" in out.stdout


def test_batch_specs_single_device():
    """batch_specs degrade gracefully on a 1-device mesh (CPU tests)."""
    import jax
    from repro.distributed.sharding import batch_specs
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()
    batch = {
        "tokens": jax.ShapeDtypeStruct((8, 16), jax.numpy.int32),
        "weights": jax.ShapeDtypeStruct((8,), jax.numpy.float32),
    }
    specs = batch_specs(mesh, batch)
    assert set(specs) == {"tokens", "weights"}


def test_serve_state_heuristics():
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import serve_state_specs
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh()  # sizes 1 → everything replicated but valid
    state = {
        "k": jax.ShapeDtypeStruct((128, 32768, 8, 128), jnp.bfloat16),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    sh = serve_state_specs(state, mesh, batch=128)
    assert sh["k"].mesh == mesh
