"""Fault injection + supervised failure handling (DESIGN.md §12).

Covers the whole robustness seam without real chaos: the deterministic
:class:`FaultPlan` registry (firing rules, serialization, env arming),
:class:`FailurePolicy` (retry/backoff/exhaustion), every exhaustion route
through ``AsyncRefresher``, the NaN/Inf feature guard on the selector
path, the coreset service's transactional ingest, and the trainer-level
guarantee that a *transient* refresh failure (failed once, retried,
recovered) trains bit-identically to a clean run.  The process-killing
faults run in the tier-2 chaos lane (tests/test_multiprocess_tree.py).
"""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.craig import CraigConfig, CraigSelector
from repro.core.refresh import AsyncRefresher
from repro.faults import (
    ENV_VAR,
    FailurePolicy,
    FaultInjected,
    FaultPlan,
    FaultSpec,
    active_plan,
    clear,
    fault_point,
    fault_value,
    injected,
    install_from_env,
)


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    yield
    clear()


# ---------------------------------------------------------------------------
# FaultPlan / FaultSpec
# ---------------------------------------------------------------------------


def test_fault_spec_validates_fields():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec(site="x", kind="explode")
    with pytest.raises(ValueError, match="1-based"):
        FaultSpec(site="x", kind="raise", on_calls=(0,))
    with pytest.raises(ValueError, match="every"):
        FaultSpec(site="x", kind="raise", every=0)
    with pytest.raises(ValueError, match="p="):
        FaultSpec(site="x", kind="raise", p=1.5)


def test_on_calls_fires_on_exact_call_numbers():
    plan = FaultPlan([FaultSpec(site="s", kind="raise", on_calls=(2,))])
    with injected(plan):
        fault_point("s")  # call 1: quiet
        with pytest.raises(FaultInjected, match="call 2"):
            fault_point("s")
        fault_point("s")  # call 3: quiet
    assert plan.calls("s") == 3


def test_every_pattern_fires_on_first_of_each_period():
    plan = FaultPlan([FaultSpec(site="s", kind="raise", every=2)])
    fired = []
    with injected(plan):
        for i in range(1, 5):
            try:
                fault_point("s")
                fired.append(False)
            except FaultInjected:
                fired.append(True)
    assert fired == [True, False, True, False]


def test_probabilistic_firing_is_seed_deterministic():
    def sequence(seed):
        plan = FaultPlan([FaultSpec(site="s", kind="raise", p=0.5)], seed=seed)
        out = []
        with injected(plan):
            for _ in range(40):
                try:
                    fault_point("s")
                    out.append(0)
                except FaultInjected:
                    out.append(1)
        return out

    assert sequence(7) == sequence(7)
    assert 0 < sum(sequence(7)) < 40  # actually probabilistic, not constant


def test_plan_json_roundtrip_and_env_install(monkeypatch):
    plan = FaultPlan(
        [FaultSpec(site="kv.get", kind="drop_key", key_pattern="sizes")],
        seed=3,
    )
    monkeypatch.setenv(ENV_VAR, plan.to_json())
    installed = install_from_env()
    assert installed is active_plan()
    assert installed.seed == 3
    assert installed.specs == plan.specs
    monkeypatch.delenv(ENV_VAR)
    assert install_from_env() is None  # unset env: no-op, plan untouched
    assert active_plan() is installed


def test_drop_key_respects_key_pattern():
    plan = FaultPlan(
        [FaultSpec(site="kv.get", kind="drop_key", key_pattern="sizes")]
    )
    with injected(plan):
        fault_point("kv.get", key="tree/0/n/1")  # no match: quiet
        with pytest.raises(FaultInjected, match="tree/0/sizes"):
            fault_point("kv.get", key="tree/0/sizes")


def test_latency_fault_sleeps():
    plan = FaultPlan([FaultSpec(site="s", kind="latency", latency_s=0.05)])
    with injected(plan):
        t0 = time.monotonic()
        fault_point("s")
        assert time.monotonic() - t0 >= 0.04


def test_nan_fault_corrupts_leading_rows_preserving_array_family():
    plan = FaultPlan([FaultSpec(site="v", kind="nan", rows=2)])
    feats = np.ones((4, 3), np.float32)
    with injected(plan):
        out = plan.apply("v", feats)
        assert isinstance(out, np.ndarray)
        assert np.isnan(out[:2]).all() and np.isfinite(out[2:]).all()
        jout = fault_value("v", jnp.ones((4, 3)))
        assert isinstance(jout, jnp.ndarray)
        assert bool(jnp.isnan(jout[0]).all())
    # no plan installed → identity
    same = fault_value("v", feats)
    assert same is feats


# ---------------------------------------------------------------------------
# FailurePolicy
# ---------------------------------------------------------------------------


def test_failure_policy_validates():
    with pytest.raises(ValueError, match="max_retries"):
        FailurePolicy(max_retries=-1)
    with pytest.raises(ValueError, match="backoff"):
        FailurePolicy(backoff_base_s=-0.1)
    with pytest.raises(ValueError, match="on_exhaustion"):
        FailurePolicy(on_exhaustion="shrug")


def test_backoff_doubles_and_caps():
    p = FailurePolicy(max_retries=4, backoff_base_s=0.05, backoff_cap_s=0.15)
    assert p.backoff_s(0) == pytest.approx(0.05)
    assert p.backoff_s(1) == pytest.approx(0.10)
    assert p.backoff_s(2) == pytest.approx(0.15)  # capped
    assert p.backoff_s(3) == pytest.approx(0.15)


# ---------------------------------------------------------------------------
# AsyncRefresher supervision: every exhaustion route
# ---------------------------------------------------------------------------


def _flaky(fail_first_n):
    """Work fn failing its first ``fail_first_n`` calls, succeeding after."""
    calls = {"n": 0}

    def work(_params):
        calls["n"] += 1
        if calls["n"] <= fail_first_n:
            raise RuntimeError(f"transient #{calls['n']}")
        return f"ok@{calls['n']}"

    return work, calls


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_retry_recovers_and_records_attempts(mode):
    work, calls = _flaky(1)
    r = AsyncRefresher(
        work, mode=mode,
        failure_policy=FailurePolicy(max_retries=1, backoff_base_s=0.0),
    )
    r.submit(None)
    res = r.collect(block=True)
    assert res.attempts == 2 and not res.fell_back
    assert res.value == "ok@2" and res.error is None
    assert calls["n"] == 2


def test_exhaustion_raise_surfaces_once_and_does_not_poison():
    work, calls = _flaky(2)
    r = AsyncRefresher(
        work, mode="async",
        failure_policy=FailurePolicy(max_retries=1, backoff_base_s=0.0),
    )
    r.submit(None)
    with pytest.raises(RuntimeError, match=r"v1 failed after 2 attempt"):
        r.wait()
    r.wait()  # the failure was consumed: exactly-once surfacing
    # failure is per JOB, not per refresher: the next submit runs clean
    r.submit(None)
    res = r.collect(block=True)
    assert res.value == "ok@3" and res.attempts == 1


def test_keep_stale_abandons_logs_once_and_stays_usable():
    work, calls = _flaky(1)
    failures = []
    r = AsyncRefresher(
        work, mode="async",
        failure_policy=FailurePolicy(on_exhaustion="keep_stale"),
        on_failure=failures.append,
    )
    r.submit(None)
    r.wait()  # does NOT raise: the job was abandoned, not surfaced
    assert len(failures) == 1
    assert failures[0].version == 1 and failures[0].attempts == 1
    assert "transient" in str(failures[0].error)
    assert r.last_failure is failures[0]
    assert r.collect() is None  # nothing published
    r.submit(None)  # refresher fully usable after abandonment
    res = r.collect(block=True)
    assert res.value == "ok@2"
    assert len(failures) == 1  # no spurious second report


def test_sync_fallback_reruns_inline_at_next_touch_point():
    work, calls = _flaky(2)  # both worker attempts fail, inline rerun works
    r = AsyncRefresher(
        work, mode="async",
        failure_policy=FailurePolicy(
            max_retries=1, backoff_base_s=0.0, on_exhaustion="sync_fallback"
        ),
    )
    r.submit(None)
    res = r.collect(block=True)  # wait() runs the fallback on THIS thread
    assert res.fell_back and res.attempts == 3
    assert res.value == "ok@3" and res.error is None


def test_sync_fallback_second_failure_raises():
    work, calls = _flaky(10)
    r = AsyncRefresher(
        work, mode="async",
        failure_policy=FailurePolicy(
            max_retries=0, backoff_base_s=0.0, on_exhaustion="sync_fallback"
        ),
    )
    r.submit(None)
    with pytest.raises(RuntimeError, match=r"v1 failed after 2 attempt"):
        r.wait()
    r.wait()  # consumed exactly once; refresher stays usable


def test_publish_failure_is_never_retried():
    work, calls = _flaky(0)

    def bad_publish(_res):
        raise RuntimeError("stage exploded")

    r = AsyncRefresher(
        work, mode="async", on_complete=bad_publish,
        failure_policy=FailurePolicy(
            max_retries=3, backoff_base_s=0.0, on_exhaustion="sync_fallback"
        ),
    )
    r.submit(None)
    with pytest.raises(RuntimeError, match="failed after 1 attempt"):
        r.wait()
    # the WORK succeeded on call 1 and must not be re-run: a publish
    # failure re-running the work could stage the same version twice
    assert calls["n"] == 1


def test_injected_refresh_fault_rides_the_policy():
    """The refresh.worker hook sits inside the retry loop: a plan that
    fails every first attempt is healed by max_retries=1."""
    plan = FaultPlan([FaultSpec(site="refresh.worker", kind="raise", every=2)])
    r = AsyncRefresher(
        lambda p: "selected", mode="sync",
        failure_policy=FailurePolicy(max_retries=1, backoff_base_s=0.0),
    )
    with injected(plan):
        r.submit(None)
        res = r.collect()
        assert res.attempts == 2 and res.value == "selected"


# ---------------------------------------------------------------------------
# validate_features guard (selector path)
# ---------------------------------------------------------------------------


def _pool_with_bad_rows(n=64, d=8, bad=(3, 7)):
    rng = np.random.RandomState(0)
    feats = rng.randn(n, d).astype(np.float32)
    feats[bad[0], 0] = np.nan
    feats[bad[1], 1] = np.inf
    return feats


def test_validate_features_raise_names_rows():
    sel = CraigSelector(CraigConfig(fraction=0.25, per_class=False))
    with pytest.raises(ValueError, match=r"2 of 64 .* \[3, 7\]"):
        sel.select(_pool_with_bad_rows())


def test_validate_features_drop_warns_remaps_and_counts():
    sel = CraigSelector(
        CraigConfig(fraction=0.25, per_class=False, validate_features="drop")
    )
    with pytest.warns(UserWarning, match="dropping 2"):
        cs = sel.select(_pool_with_bad_rows())
    assert cs.n_dropped == 2
    idx = np.asarray(cs.indices)
    assert 3 not in idx and 7 not in idx  # corrupted rows can't be medoids
    assert idx.max() < 64  # indices are into the ORIGINAL pool
    assert float(np.sum(cs.weights)) == pytest.approx(62.0)  # Σγ == n − dropped


def test_validate_features_off_passes_through():
    sel = CraigSelector(
        CraigConfig(fraction=0.25, per_class=False, validate_features="off")
    )
    cs = sel.select(_pool_with_bad_rows())  # caller opted out of the guard
    assert cs.n_dropped == 0 and len(np.asarray(cs.indices)) == 16


def test_extract_nan_injection_is_caught_by_the_guard():
    """End-to-end seam: a nan fault at extract.features produces exactly
    the corruption validate_features exists to catch."""
    plan = FaultPlan([FaultSpec(site="extract.features", kind="nan", rows=4)])
    feats = np.abs(np.random.RandomState(1).randn(32, 8)).astype(np.float32)
    with injected(plan):
        corrupted = fault_value("extract.features", feats)
    assert np.isnan(corrupted[:4]).all()
    sel = CraigSelector(CraigConfig(fraction=0.25, per_class=False))
    with pytest.raises(ValueError, match="4 of 32"):
        sel.select(corrupted)


# ---------------------------------------------------------------------------
# CoresetService: transactional ingest + keep_stale replies
# ---------------------------------------------------------------------------


def _delta(seed, n=16, d=4):
    return np.random.RandomState(seed).randn(n, d).astype(np.float32)


def test_service_ingest_failure_is_atomic_and_recoverable():
    from repro.serve import CoresetService

    svc = CoresetService(8, 4, mode="sync")
    plan = FaultPlan([FaultSpec(site="service.ingest", kind="raise", on_calls=(2,))])
    with injected(plan):
        svc.submit_delta(_delta(0))
        assert svc.n_seen == 16
        with pytest.raises(RuntimeError, match="failed after 1 attempt"):
            svc.submit_delta(_delta(1))
        # transactional: the poisoned drain rolled back wholesale
        assert svc.n_seen == 16
        svc.submit_delta(_delta(2))  # call 3: loop survives the failure
    assert svc.n_seen == 32
    u = svc.coreset()
    assert u is not None and u.n_seen == 32
    assert len(u.indices) == 8


def test_service_keep_stale_records_failure_and_serves_stale():
    from repro.serve import CoresetService

    svc = CoresetService(
        8, 4, mode="sync",
        failure_policy=FailurePolicy(on_exhaustion="keep_stale"),
    )
    plan = FaultPlan([FaultSpec(site="service.ingest", kind="raise", on_calls=(2,))])
    with injected(plan):
        v1 = svc.submit_delta(_delta(0))
        assert svc.pop_failure() is None
        u1 = svc.coreset()
        svc.submit_delta(_delta(1))  # abandoned, no raise
        failure = svc.pop_failure()
        assert failure is not None
        assert failure["event"] == "craig_refresh_failed"
        assert failure["attempts"] == 1 and "injected" in failure["error"]
        assert svc.pop_failure() is None  # popped exactly once
        # stale selection still served, state unpoisoned
        assert svc.n_seen == 16
        assert svc.coreset().version == u1.version == v1
        svc.submit_delta(_delta(2))
    assert svc.n_seen == 32 and svc.coreset().n_seen == 32


def test_serve_loop_replies_error_event_and_survives(monkeypatch):
    """The stdio protocol surfaces a keep_stale abandonment as an explicit
    ok=false reply with the craig_refresh_failed event, then keeps serving."""
    import io
    import json as _json

    from repro.launch.serve import _serve_coreset

    plan = FaultPlan([FaultSpec(site="service.ingest", kind="raise", on_calls=(2,))])
    monkeypatch.setenv(ENV_VAR, plan.to_json())

    class Args:
        budget, dim, metric, per_class = 8, 4, "l2", False
        eps, levels, evict = 0.15, 0, False
        ingest_retries, ingest_backoff_s = 0, 0.0
        on_exhaustion = "keep_stale"

    reqs = [
        {"op": "delta", "feats": _delta(0).tolist()},
        {"op": "delta", "feats": _delta(1).tolist()},
        {"op": "coreset"},
        {"op": "quit"},
    ]
    stdin = io.StringIO("\n".join(_json.dumps(r) for r in reqs) + "\n")
    stdout = io.StringIO()
    _serve_coreset(Args(), stdin=stdin, stdout=stdout)
    r1, r2, r3, r4 = [
        _json.loads(line) for line in stdout.getvalue().splitlines()
    ]
    assert r1["ok"] is True and r1["version"] == 1
    assert r2["ok"] is False and r2["event"] == "craig_refresh_failed"
    assert r2["n_seen"] == 16  # the failed delta rolled back
    assert r3["ok"] is True and r3["version"] == 1  # stale but served
    assert r4 == {"ok": True, "bye": True}


# ---------------------------------------------------------------------------
# Trainer: transient failure heals bit-identically; keep_stale degrades
# ---------------------------------------------------------------------------


def _train(n_steps=14, policy=None):
    import jax

    from repro.data.synthetic import TokenStream
    from repro.models import ModelConfig, init_params
    from repro.optim import adamw, constant
    from repro.train import Trainer, TrainerConfig

    cfg = ModelConfig(
        name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=128, logit_chunk=16,
    )
    ds = TokenStream(n_docs=48, seq_len=24, vocab_size=128, n_topics=6)
    tcfg = TrainerConfig(
        batch_size=8, select_every_epochs=2, refresh_mode="sync",
        craig=CraigConfig(fraction=0.5, per_class=False),
        refresh_failure_policy=policy,
    )
    t = Trainer(
        cfg, tcfg, ds, adamw(constant(2e-3)),
        lambda: init_params(jax.random.PRNGKey(0), cfg),
    )
    return t.run(n_steps)


def test_trainer_transient_refresh_failure_trains_bit_identically():
    clean = _train()
    plan = FaultPlan([FaultSpec(site="refresh.worker", kind="raise", every=2)])
    with injected(plan):
        healed = _train(
            policy=FailurePolicy(
                max_retries=1, backoff_base_s=0.0, on_exhaustion="keep_stale"
            )
        )
    clean_losses = [m["loss"] for m in clean if m["event"] == "step"]
    healed_losses = [m["loss"] for m in healed if m["event"] == "step"]
    assert clean_losses == healed_losses  # bit-identical, not approx
    refreshes = [m for m in healed if m["event"] == "craig_refresh"]
    assert refreshes, "the retried refreshes must still install"
    assert not [m for m in healed if m["event"] == "craig_refresh_failed"]


def test_trainer_keep_stale_logs_failures_and_completes():
    plan = FaultPlan([FaultSpec(site="refresh.worker", kind="raise")])
    with injected(plan):
        log = _train(
            policy=FailurePolicy(on_exhaustion="keep_stale")
        )
    steps = [m for m in log if m["event"] == "step"]
    assert len(steps) == 14  # training survived every refresh failing
    failed = [m for m in log if m["event"] == "craig_refresh_failed"]
    assert failed and failed[0]["attempts"] == 1
    assert "FaultInjected" in failed[0]["error"]
    assert not [m for m in log if m["event"] == "craig_refresh"]
