"""Fixture tests for the repro-lint passes (DESIGN.md §11).

Each pass gets three proofs: a known-bad snippet is flagged with the right
rule_id on the right line, a known-good snippet stays clean, and an inline
suppression (with its mandatory reason) silences — but still reports — the
finding.  ``tests/test_lint_clean.py`` is the complementary gate that the
real ``src/`` tree stays clean end to end.
"""
import textwrap

import pytest

from repro.analysis.engine import run_analysis


def _lint(tmp_path, source, name="snippet.py", rules=None):
    f = tmp_path / name
    f.write_text(textwrap.dedent(source))
    return run_analysis([f], rule_filter=rules)


def _by_rule(result, rule_id):
    return [f for f in result.active if f.rule_id == rule_id]


# ---------------------------------------------------------------------------
# pass 1: jit-safety
# ---------------------------------------------------------------------------


def test_jit_safety_flags_host_sync_in_jitted_fn(tmp_path):
    result = _lint(
        tmp_path,
        '''
        import jax
        import jax.numpy as jnp

        @jax.jit
        def bad(x):
            if jnp.sum(x) > 0:
                return float(jnp.max(x))
            return x.item()
        ''',
    )
    findings = _by_rule(result, "jit-host-sync")
    lines = {f.line for f in findings}
    assert 7 in lines, "branch on traced value not flagged"
    assert 8 in lines, "float() concretization not flagged"
    assert 9 in lines, ".item() host sync not flagged"


def test_jit_safety_follows_scan_callee_through_call_graph(tmp_path):
    result = _lint(
        tmp_path,
        '''
        import jax
        import jax.numpy as jnp

        def helper(x):
            return jax.device_get(x)

        def body(carry, x):
            return carry + helper(x), None

        def run(xs):
            return jax.lax.scan(body, jnp.zeros(()), xs)
        ''',
    )
    findings = _by_rule(result, "jit-host-sync")
    assert any(f.line == 6 for f in findings), (
        "device_get in a scan-body callee not flagged"
    )


def test_jit_safety_quiet_on_host_side_code(tmp_path):
    result = _lint(
        tmp_path,
        '''
        import jax.numpy as jnp

        def host_summary(x):
            # not reachable from any jit/scan root: host sync is fine here
            return float(jnp.max(x))
        ''',
    )
    assert not _by_rule(result, "jit-host-sync")


def test_jit_safety_roots_jit_safe_engine_select(tmp_path):
    result = _lint(
        tmp_path,
        '''
        import jax.numpy as jnp
        from repro.core.engines.base import Capabilities, SelectionEngine

        class FakeEngine(SelectionEngine):
            capabilities = Capabilities(jit_safe=True)

            def select(self, gains):
                return int(jnp.argmax(gains))
        ''',
    )
    findings = _by_rule(result, "jit-host-sync")
    assert any(f.line == 9 for f in findings), (
        "host sync inside a jit_safe=True engine's select not flagged"
    )


# ---------------------------------------------------------------------------
# pass 2: pallas contract
# ---------------------------------------------------------------------------

_PALLAS_PRELUDE = (
    "import jax\n"
    "import jax.numpy as jnp\n"
    "from jax.experimental import pallas as pl\n"
)


def test_pallas_index_map_arity_mismatch(tmp_path):
    result = _lint(
        tmp_path,
        _PALLAS_PRELUDE
        + textwrap.dedent('''
        def kernel(a_ref, o_ref):
            o_ref[...] = a_ref[...]

        def run(x):
            return pl.pallas_call(
                kernel,
                grid=(4, 4),
                in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
                out_shape=jax.ShapeDtypeStruct((32, 32), jnp.float32),
            )(x)
        '''),
    )
    findings = _by_rule(result, "pallas-index-map-arity")
    assert len(findings) == 1, findings
    assert "1 argument(s)" in findings[0].message


def test_pallas_kernel_arity_mismatch(tmp_path):
    result = _lint(
        tmp_path,
        _PALLAS_PRELUDE
        + textwrap.dedent('''
        def kernel(a_ref, b_ref, o_ref, scratch):
            o_ref[...] = a_ref[...]

        def run(x):
            return pl.pallas_call(
                kernel,
                grid=(4,),
                in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
                out_specs=pl.BlockSpec((8,), lambda i: (i,)),
                out_shape=jax.ShapeDtypeStruct((32,), jnp.float32),
            )(x)
        '''),
    )
    findings = _by_rule(result, "pallas-kernel-arity")
    assert len(findings) == 1, findings
    assert "takes 4" in findings[0].message


def test_pallas_low_precision_accumulator_flagged(tmp_path):
    result = _lint(
        tmp_path,
        _PALLAS_PRELUDE
        + textwrap.dedent('''
        def kernel(a_ref, o_ref):
            o_ref[...] = jnp.dot(a_ref[...], a_ref[...])

        def run(x):
            return pl.pallas_call(
                kernel,
                grid=(4,),
                in_specs=[pl.BlockSpec((8,), lambda i: (i,))],
                out_specs=pl.BlockSpec((8,), lambda i: (i,)),
                out_shape=jax.ShapeDtypeStruct((32, 32), jnp.bfloat16),
            )(x)
        '''),
    )
    assert _by_rule(result, "pallas-accumulator-dtype"), (
        "bf16 out_shape accumulator not flagged"
    )
    assert _by_rule(result, "pallas-dot-preferred-type"), (
        "dot without preferred_element_type not flagged"
    )


def test_pallas_clean_site_stays_quiet(tmp_path):
    result = _lint(
        tmp_path,
        _PALLAS_PRELUDE
        + textwrap.dedent('''
        def kernel(a_ref, o_ref):
            o_ref[...] = jnp.dot(
                a_ref[...], a_ref[...],
                preferred_element_type=jnp.float32,
            )

        def run(x):
            grid = (4, 4)
            return pl.pallas_call(
                kernel,
                grid=grid,
                in_specs=[pl.BlockSpec((8, 8), lambda i, j: (i, j))],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
                out_shape=jax.ShapeDtypeStruct((32, 32), jnp.float32),
            )(x)
        '''),
    )
    pallas = [f for f in result.active if f.rule_id.startswith("pallas-")]
    assert not pallas, pallas


# ---------------------------------------------------------------------------
# pass 3: concurrency
# ---------------------------------------------------------------------------


def test_concurrency_write_outside_lock_flagged(tmp_path):
    result = _lint(
        tmp_path,
        '''
        import threading

        class Box:
            def __init__(self):
                self._lock = threading.Lock()
                self._val = 0

            def set_locked(self, v):
                with self._lock:
                    self._val = v

            def set_racy(self, v):
                self._val = v
        ''',
    )
    findings = _by_rule(result, "lock-discipline")
    assert len(findings) == 1, findings
    assert findings[0].line == 14


def test_concurrency_thread_without_join_or_capture(tmp_path):
    result = _lint(
        tmp_path,
        '''
        import threading

        def work():
            raise RuntimeError("dies silently")

        def spawn():
            t = threading.Thread(target=work)
            t.start()
        ''',
    )
    assert _by_rule(result, "thread-join"), "missing join path not flagged"
    assert _by_rule(result, "thread-failure-propagation"), (
        "uncaptured worker failure not flagged"
    )


def test_concurrency_clean_worker_stays_quiet(tmp_path):
    result = _lint(
        tmp_path,
        '''
        import threading

        class Runner:
            def __init__(self):
                self._lock = threading.Lock()
                self._result = None
                self._t = None

            def start(self):
                def work():
                    try:
                        out = 42
                        with self._lock:
                            self._result = out
                    except BaseException as e:
                        with self._lock:
                            self._result = e

                self._t = threading.Thread(target=work)
                self._t.start()

            def wait(self):
                self._t.join()
                with self._lock:
                    return self._result
        ''',
    )
    conc = [
        f
        for f in result.active
        if f.rule_id
        in ("lock-discipline", "thread-join", "thread-failure-propagation")
    ]
    assert not conc, conc


def test_concurrency_bare_blocking_kv_get_flagged(tmp_path):
    result = _lint(
        tmp_path,
        '''
        def fetch(client, key):
            return client.blocking_key_value_get_bytes(key, 300_000)
        ''',
    )
    findings = _by_rule(result, "kv-deadline")
    assert len(findings) == 1, findings
    assert findings[0].line == 3
    assert "_kv_get" in findings[0].message


def test_concurrency_kv_get_inside_wrapper_stays_quiet(tmp_path):
    result = _lint(
        tmp_path,
        '''
        def _raw_get_bytes(client, key, timeout_ms):
            return client.blocking_key_value_get_bytes(key, int(timeout_ms))
        ''',
    )
    assert not _by_rule(result, "kv-deadline")


def test_concurrency_kv_get_suppressible_with_reason(tmp_path):
    result = _lint(
        tmp_path,
        '''
        def probe(client, key):
            return client.blocking_key_value_get(key, 5)  # repro-lint: disable=kv-deadline  # fixture
        ''',
    )
    assert not _by_rule(result, "kv-deadline")
    assert any(
        f.rule_id == "kv-deadline" for f in result.suppressed
    ), "suppression should still be reported"


# ---------------------------------------------------------------------------
# pass 4: api hygiene
# ---------------------------------------------------------------------------


def test_api_hygiene_forbidden_pallas_import(tmp_path):
    result = _lint(
        tmp_path,
        '''
        from jax.experimental import pallas as pl

        def run(x):
            return x
        ''',
    )
    findings = _by_rule(result, "forbidden-import")
    assert findings and findings[0].line == 2


def test_api_hygiene_engine_registration_contract(tmp_path):
    engines = tmp_path / "repro" / "core" / "engines"
    engines.mkdir(parents=True)
    (engines / "rogue.py").write_text(
        textwrap.dedent(
            '''
            from repro.core.engines.base import SelectionEngine

            class RogueEngine(SelectionEngine):
                def select(self, gains):
                    return gains
            '''
        )
    )
    result = run_analysis([engines])
    findings = _by_rule(result, "engine-capabilities")
    msgs = " ".join(f.message for f in findings)
    assert "capabilities" in msgs and "register_engine" in msgs


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------


def test_suppression_with_reason_is_honored_but_reported(tmp_path):
    result = _lint(
        tmp_path,
        '''
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return x.item()  # repro-lint: disable=jit-host-sync  # fixture
        ''',
    )
    assert not _by_rule(result, "jit-host-sync")
    assert any(
        f.rule_id == "jit-host-sync" and f.suppressed
        for f in result.suppressed
    )
    assert result.exit_code == 0


def test_suppression_without_reason_is_a_finding(tmp_path):
    result = _lint(
        tmp_path,
        '''
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            return x.item()  # repro-lint: disable=jit-host-sync
        ''',
    )
    assert _by_rule(result, "suppression-missing-reason")
    assert result.exit_code == 1


def test_suppression_covers_only_its_own_line(tmp_path):
    result = _lint(
        tmp_path,
        '''
        import jax
        import jax.numpy as jnp

        @jax.jit
        def f(x):
            y = x.item()  # repro-lint: disable=jit-host-sync  # fixture
            return x.tolist()
        ''',
    )
    assert [f.line for f in _by_rule(result, "jit-host-sync")] == [8]


# ---------------------------------------------------------------------------
# framework plumbing
# ---------------------------------------------------------------------------


def test_parse_error_is_a_finding(tmp_path):
    result = _lint(tmp_path, "def broken(:\n")
    assert _by_rule(result, "parse-error")
    assert result.exit_code == 1


def test_rule_filter_restricts_output(tmp_path):
    result = _lint(
        tmp_path,
        '''
        import jax

        @jax.jit
        def f(x, device_q):
            return x.item()
        ''',
        rules=frozenset({"flat-engine-knob"}),
    )
    assert {f.rule_id for f in result.active} == {"flat-engine-knob"}


def test_findings_sorted_and_serializable(tmp_path):
    result = _lint(
        tmp_path,
        '''
        import jax

        @jax.jit
        def f(x):
            a = x.item()
            b = x.tolist()
            return a, b
        ''',
    )
    lines = [f.line for f in result.active]
    assert lines == sorted(lines)
    for f in result.active:
        d = f.to_dict()
        assert d["rule_id"] and d["path"] and d["line"] > 0
