"""Launch CLIs run end-to-end in smoke mode (subprocess)."""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.tier2  # subprocess CLI round-trips, >10 s

ENV = dict(os.environ)
ENV["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(args, timeout=480):
    return subprocess.run(
        [sys.executable, "-m", *args], env=ENV, capture_output=True,
        text=True, timeout=timeout,
    )


def test_train_cli_smoke():
    out = _run([
        "repro.launch.train", "--arch", "qwen3-1.7b", "--smoke",
        "--steps", "4", "--batch", "4", "--seq", "32", "--docs", "16",
    ])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "loss" in out.stdout


def test_serve_cli_smoke():
    out = _run([
        "repro.launch.serve", "--arch", "granite-3-8b", "--smoke",
        "--batch", "2", "--prompt-len", "4", "--new", "4",
    ])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "tok/s" in out.stdout
