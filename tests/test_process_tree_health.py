"""Tier-1 unit tests for the process-tree fault model (DESIGN.md §12).

The real multi-process lanes (bit-identity and the SIGKILL chaos case)
live in tests/test_multiprocess_tree.py behind the tier2 marker; these
tests exercise the health plumbing — the KV wire primitives, the env
deadline knob, heartbeat monitoring, degraded candidate-count algebra and
quorum math — against a dict-backed fake KV client, so the failure paths
run on every PR without spawning processes.
"""
import time

import numpy as np
import pytest

from repro.distributed.process_tree import (
    KV_TIMEOUT_ENV,
    HealthConfig,
    KVStoreError,
    QuorumError,
    _await_key,
    _decode_mask,
    _encode_mask,
    _HeartbeatMonitor,
    _kv_get,
    _node_r,
    _nominal_r,
    _poll_str,
    _put_cell,
    _require_quorum,
    kv_timeout_ms,
)
from repro.distributed.tree_select import TreeTopology
from repro.faults import FaultPlan, FaultSpec, clear, injected


class FakeKV:
    """Dict-backed stand-in for the jax.distributed coordination client,
    implementing the four methods the wire layer uses."""

    def __init__(self):
        self.strings = {}
        self.blobs = {}

    def key_value_set(self, key, value):
        self.strings[key] = value

    def key_value_set_bytes(self, key, value):
        self.blobs[key] = bytes(value)

    def key_value_dir_get(self, key):
        prefix = key + "/"
        return [
            (k, v) for k, v in sorted(self.strings.items())
            if k.startswith(prefix)
        ]

    def blocking_key_value_get_bytes(self, key, timeout_ms):
        if key in self.blobs:
            return self.blobs[key]
        raise RuntimeError(f"Deadline Exceeded waiting for {key}")


@pytest.fixture(autouse=True)
def _no_leaked_plan():
    yield
    clear()


# ---------------------------------------------------------------------------
# env deadline knob
# ---------------------------------------------------------------------------


def test_kv_timeout_defaults_to_300s(monkeypatch):
    monkeypatch.delenv(KV_TIMEOUT_ENV, raising=False)
    assert kv_timeout_ms() == 300_000


def test_kv_timeout_env_override(monkeypatch):
    monkeypatch.setenv(KV_TIMEOUT_ENV, "1500")
    assert kv_timeout_ms() == 1500


@pytest.mark.parametrize("bad", ["soon", "1.5", "0", "-10"])
def test_kv_timeout_rejects_bad_values(monkeypatch, bad):
    monkeypatch.setenv(KV_TIMEOUT_ENV, bad)
    with pytest.raises(ValueError, match=KV_TIMEOUT_ENV):
        kv_timeout_ms()


# ---------------------------------------------------------------------------
# HealthConfig validation
# ---------------------------------------------------------------------------


def test_health_config_validates():
    with pytest.raises(ValueError, match="level_deadline_s"):
        HealthConfig(level_deadline_s=0)
    with pytest.raises(ValueError, match="heartbeat_interval_s"):
        HealthConfig(heartbeat_interval_s=0)
    with pytest.raises(ValueError, match="2×"):
        HealthConfig(heartbeat_interval_s=1.0, heartbeat_grace_s=1.5)
    with pytest.raises(ValueError, match="poll_ms"):
        HealthConfig(poll_ms=0)
    with pytest.raises(ValueError, match="min_quorum"):
        HealthConfig(min_quorum=0.0)
    with pytest.raises(ValueError, match="min_quorum"):
        HealthConfig(min_quorum=1.1)


def test_health_config_deadline_falls_back_to_env(monkeypatch):
    monkeypatch.setenv(KV_TIMEOUT_ENV, "2000")
    assert HealthConfig().deadline_s() == pytest.approx(2.0)
    assert HealthConfig(level_deadline_s=7.5).deadline_s() == 7.5


# ---------------------------------------------------------------------------
# wire primitives
# ---------------------------------------------------------------------------


def test_put_cell_poll_str_roundtrip():
    kv = FakeKV()
    assert _poll_str(kv, "t/sizes") is None
    _put_cell(kv, "t/sizes", "64,64,-1,64")
    assert _poll_str(kv, "t/sizes") == "64,64,-1,64"
    # directory semantics: the value lives at {key}/v, never at {key}
    assert "t/sizes/v" in kv.strings and "t/sizes" not in kv.strings
    # sibling cells don't bleed into each other
    _put_cell(kv, "t/sizes2", "1")
    assert _poll_str(kv, "t/sizes") == "64,64,-1,64"


def test_mask_roundtrip():
    mask = np.array([0, 1, 1, 0], np.int8)
    s = _encode_mask(mask)
    assert s == "0110"
    np.testing.assert_array_equal(_decode_mask(s), mask)


def test_kv_get_error_names_key_pid_level_and_timeout():
    kv = FakeKV()
    with pytest.raises(KVStoreError) as ei:
        _kv_get(kv, "t/0/f", (4, 2), np.float32,
                pid=3, level=1, what="child features", timeout_ms=50)
    msg = str(ei.value)
    assert "'t/0/f'" in msg and "pid 3" in msg
    assert "level 1" in msg and "50 ms" in msg and "child features" in msg


def test_kv_get_roundtrips_bytes():
    kv = FakeKV()
    arr = np.arange(8, dtype=np.float32).reshape(4, 2)
    kv.key_value_set_bytes("t/0/f", arr.tobytes())
    out = _kv_get(kv, "t/0/f", (4, 2), np.float32,
                  pid=0, level=1, what="child features", timeout_ms=50)
    np.testing.assert_array_equal(out, arr)


def test_drop_key_fault_surfaces_as_kv_store_error():
    kv = FakeKV()
    kv.key_value_set_bytes("t/0/f", b"\x00" * 4)
    plan = FaultPlan(
        [FaultSpec(site="kv.get", kind="drop_key", key_pattern="t/0/f")]
    )
    with injected(plan):
        with pytest.raises(KVStoreError, match="FaultInjected"):
            _kv_get(kv, "t/0/f", (1,), np.float32,
                    pid=0, level=1, what="child features", timeout_ms=50)


# ---------------------------------------------------------------------------
# heartbeat monitor + deadline waits
# ---------------------------------------------------------------------------


def test_monitor_alive_while_beats_arrive_dead_after_silence():
    kv = FakeKV()
    mon = _HeartbeatMonitor(kv, "t", 1, grace_s=0.15)
    assert mon.alive()  # creation counts as a beat
    kv.key_value_set("t/hb/1/0", "1")
    assert mon.alive()
    time.sleep(0.1)
    kv.key_value_set("t/hb/1/1", "1")  # fresh beat resets the clock
    assert mon.alive()
    time.sleep(0.2)  # silence past the grace window
    assert not mon.alive()


def test_await_key_returns_value_published_late():
    kv = FakeKV()
    _put_cell(kv, "t/k", "ready")
    assert _await_key(kv, "t/k", deadline_s=0.5, poll_ms=10) == "ready"


def test_await_key_deadline_expiry_returns_none():
    kv = FakeKV()
    t0 = time.monotonic()
    assert _await_key(kv, "t/k", deadline_s=0.2, poll_ms=10) is None
    assert 0.15 <= time.monotonic() - t0 < 2.0


def test_await_key_dead_publisher_short_circuits_with_final_probe():
    kv = FakeKV()
    mon = _HeartbeatMonitor(kv, "t", 1, grace_s=0.05)
    time.sleep(0.1)  # publisher already silent past grace
    t0 = time.monotonic()
    assert _await_key(kv, "t/k", deadline_s=30.0, poll_ms=10,
                      monitor=mon) is None
    assert time.monotonic() - t0 < 5.0  # nowhere near the 30 s deadline
    # publish-then-die: a committed publish is honored by the final probe
    _put_cell(kv, "t/k2", "committed")
    mon2 = _HeartbeatMonitor(kv, "t", 2, grace_s=0.05)
    time.sleep(0.1)
    assert _await_key(kv, "t/k2", deadline_s=30.0, poll_ms=10,
                      monitor=mon2) == "committed"


# ---------------------------------------------------------------------------
# degraded candidate-count algebra + quorum
# ---------------------------------------------------------------------------


def test_node_r_matches_nominal_when_clean():
    topo = TreeTopology((4, 2))
    dead = np.zeros(8, np.int8)
    for level in range(topo.depth + 1):
        nodes = int(np.prod(topo.fanouts[level:])) if level < topo.depth else 1
        for node in range(nodes):
            assert _node_r(level, node, dead, topo, 8, 16, 10) == _nominal_r(
                level, topo, 8, 16, 10
            )


def test_node_r_degrades_to_surviving_union():
    topo = TreeTopology((4,))
    dead = np.array([0, 0, 0, 1], np.int8)
    # 3 surviving leaves × r_local=8 = 24 ≥ r_final=10 → budget holds
    assert _node_r(1, 0, dead, topo, 8, 16, 10) == 10
    # 1 survivor: union 8 < r_final 10 → shrink to what exists
    dead3 = np.array([1, 1, 1, 0], np.int8)
    assert _node_r(1, 0, dead3, topo, 8, 16, 10) == 8
    # whole subtree dead → 0
    assert _node_r(1, 0, np.ones(4, np.int8), topo, 8, 16, 10) == 0
    # dead leaf level-0 base case
    assert _node_r(0, 3, dead, topo, 8, 16, 10) == 0
    assert _node_r(0, 0, dead, topo, 8, 16, 10) == 8


def test_node_r_composes_up_a_two_level_tree():
    topo = TreeTopology((2, 2))
    dead = np.array([1, 0, 0, 0], np.int8)  # leaf 0 of 4 dead
    # node 0 at level 1 keeps only leaf 1's candidates: min(r_node, 8) = 8
    assert _node_r(1, 0, dead, topo, 8, 12, 10) == 8
    assert _node_r(1, 1, dead, topo, 8, 12, 10) == 12  # clean: min(12, 16)
    # root sees union 8 + 12 = 20 ≥ r_final
    assert _node_r(2, 0, dead, topo, 8, 12, 10) == 10


def test_require_quorum_boundary_and_failure():
    _require_quorum(3, 4, 0.75, level=1, node=0, missing=[3])  # exactly at
    with pytest.raises(QuorumError) as ei:
        _require_quorum(2, 4, 0.75, level=1, node=0, missing=[3, 1])
    msg = str(ei.value)
    assert "2/4" in msg and "min_quorum=0.75" in msg and "[1, 3]" in msg
