"""Async refresh subsystem: AsyncRefresher lifecycle, sampler double buffer,
async-vs-sync trainer determinism, checkpoint semantics of a pending refresh.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.craig import CraigConfig
from repro.core.refresh import AsyncRefresher
from repro.data.pipeline import CoresetSampler
from repro.data.synthetic import TokenStream
from repro.models import ModelConfig, init_params
from repro.optim import adamw, constant
from repro.train import Trainer, TrainerConfig

CFG = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv_heads=2, d_ff=64, vocab_size=128, logit_chunk=16,
)


def _trainer(tmp, mode="async", seed=0, **kw):
    ds = TokenStream(n_docs=48, seq_len=24, vocab_size=128, n_topics=6)
    tcfg = TrainerConfig(
        batch_size=8,
        select_every_epochs=kw.pop("select_every_epochs", 1),
        refresh_mode=mode,
        checkpoint_dir=str(tmp) if tmp else None,
        checkpoint_every=kw.pop("checkpoint_every", 100),
        craig=kw.pop("craig", CraigConfig(fraction=0.5, per_class=False)),
        **kw,
    )
    return Trainer(
        CFG, tcfg, ds, adamw(constant(2e-3)),
        lambda: init_params(jax.random.PRNGKey(seed), CFG),
    )


# ---------------------------------------------------------------------------
# AsyncRefresher unit behaviour
# ---------------------------------------------------------------------------


def test_refresher_async_publishes_result():
    done = threading.Event()
    seen = []

    def work(params):
        return int(np.asarray(params["x"]).sum()) * 2

    r = AsyncRefresher(work, mode="async",
                       on_complete=lambda res: (seen.append(res.version),
                                                done.set()))
    v = r.submit({"x": np.arange(5)})
    assert v == 1
    res = r.collect(block=True)
    assert res.version == 1 and res.value == 20
    assert res.wall_time_s >= 0
    assert done.wait(1.0) and seen == [1]
    assert r.collect() is None  # single publish slot, popped once


def test_refresher_sync_mode_runs_inline():
    order = []
    r = AsyncRefresher(lambda p: order.append("work"), mode="sync")
    r.submit({}, snapshot=False)
    order.append("after")
    assert order == ["work", "after"]
    assert not r.busy


def test_refresher_rejects_double_submit():
    release = threading.Event()
    r = AsyncRefresher(lambda p: release.wait(5.0), mode="async")
    r.submit({}, snapshot=False)
    with pytest.raises(RuntimeError, match="in flight"):
        r.submit({}, snapshot=False)
    release.set()
    r.wait()
    r.submit({}, snapshot=False)  # fine once drained
    r.wait()


def test_refresher_propagates_worker_error():
    def boom(params):
        raise ValueError("proxy extraction exploded")

    r = AsyncRefresher(boom, mode="async")
    r.submit({}, snapshot=False)
    with pytest.raises(RuntimeError, match="refresh v1 failed"):
        r.wait()
    # error is consumed: the refresher is reusable
    r2 = AsyncRefresher(boom, mode="sync")
    with pytest.raises(RuntimeError, match="failed"):
        r2.submit({}, snapshot=False)


def test_refresher_wait_timeout_is_a_total_deadline():
    """Regression: wait(timeout=) used to pass the timeout to EVERY
    internal join, so a worker sleeping past it in short naps could keep
    wait() blocked for many multiples of the requested deadline.  It must
    be a single total deadline, raise TimeoutError, and leave the
    refresher fully usable (the job keeps running; a later untimed wait
    collects it)."""
    release = threading.Event()

    def slow(params):
        release.wait(5.0)
        return "done"

    r = AsyncRefresher(slow, mode="async")
    r.submit({}, snapshot=False)
    t0 = time.monotonic()
    with pytest.raises(TimeoutError, match="still running after 0.05"):
        r.wait(timeout=0.05)
    assert time.monotonic() - t0 < 1.0  # honored the deadline, not 5 s
    assert r.busy  # the job was NOT cancelled or abandoned
    release.set()
    r.wait()  # untimed wait after a timed-out one still drains
    res = r.collect()
    assert res is not None and res.value == "done"


def test_refresher_wait_timeout_then_failure_surfaces_once():
    release = threading.Event()

    def slow_boom(params):
        release.wait(5.0)
        raise ValueError("late failure")

    r = AsyncRefresher(slow_boom, mode="async")
    r.submit({}, snapshot=False)
    with pytest.raises(TimeoutError):
        r.wait(timeout=0.05)
    release.set()
    with pytest.raises(RuntimeError, match="refresh v1 failed"):
        r.wait()
    r.wait()  # consumed exactly once
    r.submit({}, snapshot=False)  # and the refresher stays usable
    with pytest.raises(RuntimeError, match="refresh v2 failed"):
        r.wait()


def test_refresher_captures_on_complete_failure():
    """A publish (on_complete) failure must surface at wait() in async mode
    just like it raises at submit() in sync mode — never vanish on the
    worker thread while training continues on stale data."""

    def bad_publish(res):
        raise ValueError("stage rejected the selection")

    r = AsyncRefresher(lambda p: 1, mode="async", on_complete=bad_publish)
    r.submit({}, snapshot=False)
    with pytest.raises(RuntimeError, match="failed"):
        r.wait()
    rs = AsyncRefresher(lambda p: 1, mode="sync", on_complete=bad_publish)
    with pytest.raises(RuntimeError, match="failed"):
        rs.submit({}, snapshot=False)


def test_refresher_snapshot_isolates_params():
    """The worker sees the params at submit time, not later mutations."""
    got = []
    hold = threading.Event()

    def work(params):
        hold.wait(5.0)
        got.append(np.asarray(params["w"]).copy())

    r = AsyncRefresher(work, mode="async")
    params = {"w": np.zeros(3)}
    r.submit(params)
    params["w"] += 100.0  # trainer keeps updating the live params
    hold.set()
    r.wait()
    np.testing.assert_array_equal(got[0], np.zeros(3))


# ---------------------------------------------------------------------------
# Streaming ingest path (coalescing) + submit failure precedence
# ---------------------------------------------------------------------------


def test_refresher_ingest_coalesces_behind_busy_job():
    """Deltas queued while a job is in flight drain as ONE coalesced job —
    one version per drain, not per delta."""
    release = threading.Event()
    batches = []

    def ingest(deltas):
        batches.append(list(deltas))
        release.wait(5.0)
        return len(deltas)

    r = AsyncRefresher(lambda p: None, mode="async", ingest_fn=ingest)
    assert r.ingest("a") == 1  # idle → drains immediately
    assert r.ingest("b") is None  # busy → queued
    assert r.ingest("c") is None
    assert r.pending_deltas == 2
    release.set()
    r.wait()  # joins v1, then drains the queue as v2
    assert r.pending_deltas == 0
    assert r.version == 2
    assert batches == [["a"], ["b", "c"]]
    res = r.collect()
    assert res.version == 2 and res.value == 2


def test_refresher_ingest_sync_one_version_per_call():
    seen = []
    r = AsyncRefresher(lambda p: None, mode="sync",
                       ingest_fn=lambda ds: seen.append(list(ds)))
    assert r.ingest("a") == 1
    assert r.ingest("b", "c") == 2  # multi-delta call still one drain
    assert seen == [["a"], ["b", "c"]]


def test_refresher_ingest_requires_ingest_fn_and_deltas():
    r = AsyncRefresher(lambda p: None, mode="sync")
    with pytest.raises(RuntimeError, match="ingest_fn"):
        r.ingest("a")
    r2 = AsyncRefresher(lambda p: None, mode="sync", ingest_fn=lambda ds: None)
    with pytest.raises(ValueError, match="at least one"):
        r2.ingest()


def test_refresher_busy_error_names_version_and_hints_ingest():
    """Regression: submit-while-busy must name the in-flight version and
    point at the coalescing alternative, not just say 'in flight'."""
    release = threading.Event()
    r = AsyncRefresher(lambda p: release.wait(5.0), mode="async")
    r.submit({}, snapshot=False)
    with pytest.raises(RuntimeError, match=r"v1.*in flight.*ingest"):
        r.submit({}, snapshot=False)
    release.set()
    r.wait()


def test_refresher_submit_raises_pending_failure_first():
    """Regression: submitting on top of an uncollected worker failure must
    re-raise the failure, never silently start new work over it."""

    def boom(params):
        raise ValueError("proxy extraction exploded")

    r = AsyncRefresher(boom, mode="async")
    r.submit({}, snapshot=False)
    deadline = time.time() + 5.0
    while r.busy and time.time() < deadline:
        time.sleep(0.01)
    with pytest.raises(RuntimeError, match="refresh v1 failed"):
        r.submit({}, snapshot=False)
    r.submit({}, snapshot=False)  # failure consumed → reusable
    with pytest.raises(RuntimeError, match="failed"):
        r.wait()


def test_refresher_ingest_failure_surfaces_at_collect_block():
    def bad_ingest(deltas):
        raise ValueError("sieve exploded")

    r = AsyncRefresher(lambda p: None, mode="async", ingest_fn=bad_ingest)
    r.ingest("a")
    with pytest.raises(RuntimeError, match="refresh v1 failed"):
        r.collect(block=True)
    assert r.ingest("b") == 2  # failure consumed → path reusable
    with pytest.raises(RuntimeError, match="v2 failed"):
        r.wait()


# ---------------------------------------------------------------------------
# Sampler versioned double buffer
# ---------------------------------------------------------------------------


def test_sampler_stage_does_not_disturb_iteration():
    s = CoresetSampler(n=32, batch=4, seed=0)
    before = [s.next_batch()[0].tolist() for _ in range(2)]
    s2 = CoresetSampler(n=32, batch=4, seed=0)
    s2.stage(np.arange(0, 32, 2), np.ones(16, np.float32))
    after = [s2.next_batch()[0].tolist() for _ in range(2)]
    assert before == after  # staged back buffer is invisible until install
    assert s2.version == 0 and s2.pending_version == 1
    p = s2.install_pending()
    assert p["version"] == 1 and s2.version == 1
    assert s2.active_size == 16
    assert s2.install_pending() is None


def test_sampler_pending_roundtrips_through_state_dict():
    s1 = CoresetSampler(n=40, batch=5, seed=3)
    s1.set_coreset(np.arange(0, 40, 2), np.ones(20, np.float32))
    s1.stage(np.arange(0, 40, 4), 2 * np.ones(10, np.float32),
             meta={"epsilon_hat": 0.25})
    s1.next_batch()
    s2 = CoresetSampler(n=40, batch=5, seed=3)
    s2.load_state_dict(s1.state_dict())
    assert s2.version == s1.version and s2.pending_version == s1.pending_version
    p1, p2 = s1.install_pending(), s2.install_pending()
    assert p1["version"] == p2["version"]
    assert p2["meta"] == {"epsilon_hat": 0.25}
    for _ in range(6):
        i1, w1 = s1.next_batch()
        i2, w2 = s2.next_batch()
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_array_equal(w1, w2)


# ---------------------------------------------------------------------------
# Trainer lifecycle
# ---------------------------------------------------------------------------


@pytest.mark.tier2
def test_async_matches_sync_step_for_step():
    """Same install boundaries in both modes → identical training streams
    (the selection runs from the same params snapshot either way)."""
    log_a = _trainer(None, mode="async").run(16)
    log_s = _trainer(None, mode="sync").run(16)
    steps_a = [m["loss"] for m in log_a if m["event"] == "step"]
    steps_s = [m["loss"] for m in log_s if m["event"] == "step"]
    np.testing.assert_allclose(steps_a, steps_s, rtol=1e-6, atol=1e-7)
    inst_a = [(m["step"], m["version"], m["coreset_size"])
              for m in log_a if m["event"] == "craig_refresh"]
    inst_s = [(m["step"], m["version"], m["coreset_size"])
              for m in log_s if m["event"] == "craig_refresh"]
    assert inst_a == inst_s and len(inst_a) >= 2


def test_async_refresh_stays_off_critical_path():
    """The first selection overlaps epoch 0: by the install boundary it is
    already published, so the install stall is (near) zero."""
    t = _trainer(None, mode="async")
    log = t.run(8)  # epoch 0 is 6 full-data steps; install lands at step 6
    refreshes = [m for m in log if m["event"] == "craig_refresh"]
    assert len(refreshes) == 1
    assert refreshes[0]["step"] == 6
    assert refreshes[0]["coreset_size"] == 24
    assert refreshes[0]["select_time_s"] > 0
    # the worker had a full epoch of head start; any residual stall is the
    # thread-join overhead, not the selection itself
    assert refreshes[0]["install_stall_s"] < refreshes[0]["select_time_s"]


def test_checkpoint_between_publish_and_install(tmp_path):
    """A staged-but-not-installed refresh survives checkpoint-restart."""
    t1 = _trainer(tmp_path, mode="async", checkpoint_every=4)
    t1.run(4)  # refresh v1 triggered at step 0; install boundary is step 6
    t1.ckpt.wait()
    assert t1.sampler.has_pending  # _save drained the refresher first

    t2 = _trainer(tmp_path, mode="async", seed=9)
    assert t2.restore_or_init()
    assert t2.sampler.has_pending
    assert t2.sampler.pending_version == t1.sampler.pending_version
    log1 = t1.run(4)  # cumulative log: keep only post-restore steps
    log2 = t2.run(4)
    steps1 = [m["loss"] for m in log1 if m["event"] == "step" and m["step"] > 4]
    steps2 = [m["loss"] for m in log2 if m["event"] == "step"]
    np.testing.assert_allclose(steps1, steps2, rtol=2e-3, atol=2e-4)
    refr1 = [(m["step"], m["version"]) for m in log1
             if m["event"] == "craig_refresh" and m["step"] > 4]
    refr2 = [(m["step"], m["version"]) for m in log2
             if m["event"] == "craig_refresh"]
    assert refr1 == refr2 == [(6, 1)]  # v1 installed at the epoch boundary
    i1, _ = t1.sampler.next_batch()
    i2, _ = t2.sampler.next_batch()
    np.testing.assert_array_equal(i1, i2)


def test_restore_keeps_versions_monotone_and_warm_seed(tmp_path):
    """A restored trainer must not re-issue already-used refresh versions,
    and must keep the previous selection as its warm-start seed."""
    t1 = _trainer(tmp_path, mode="async", checkpoint_every=4)
    t1.run(4)
    t1.ckpt.wait()
    assert t1.refresher.version == 1

    t2 = _trainer(tmp_path, mode="async", seed=5)
    assert t2.restore_or_init()
    assert t2.refresher.version == 1  # fast-forwarded past the staged v1
    assert t2._prev_selection is not None
    np.testing.assert_array_equal(
        t2._prev_selection.indices, t1._prev_selection.indices
    )
    log = t2.run(6)  # install v1 at step 6, trigger+install v2 after
    versions = [m["version"] for m in log if m["event"] == "craig_refresh"]
    assert versions == sorted(set(versions))  # strictly increasing
    assert versions[0] == 1 and versions[-1] >= 2
    t2.refresher.wait()


def test_warm_start_refresh_matches_cold_refresh():
    """warm_start_fraction only amortizes work — on this tiny problem the
    proxies barely drift between refreshes, and in all cases the training
    stream must remain valid: unique indices, Σγ == pool size."""
    t_warm = _trainer(None, mode="sync", warm_start_fraction=0.5)
    t_cold = _trainer(None, mode="sync", warm_start_fraction=0.0)
    t_warm.run(14)
    t_cold.run(14)
    for t in (t_warm, t_cold):
        assert t._prev_selection is not None
        idx = t._prev_selection.indices
        assert len(np.unique(idx)) == len(idx)
        assert t._prev_selection.weights.sum() == pytest.approx(48.0)
    # first refresh has no previous selection → identical cold start
    first_warm = [m for m in t_warm.metrics_log
                  if m["event"] == "craig_refresh"][0]
    first_cold = [m for m in t_cold.metrics_log
                  if m["event"] == "craig_refresh"][0]
    assert first_warm["coreset_size"] == first_cold["coreset_size"] == 24
