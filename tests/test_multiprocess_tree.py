"""Multi-process tree selection over the jax.distributed KV store.

Launches REAL processes (``python -m repro.launch.tree``, one per leaf)
against a coordinator on a free local port — the same launch line a
multi-host run uses — and checks that every process returns the same
selection, that γ conservation holds, and that the result is
bit-identical to the single-process host driver on the concatenated
pool.  This is the tier-2 multi-process CI lane (XLA CPU has no
cross-process collectives, so the KV-store driver is the only
process-spanning path off-TPU/GPU).
"""
import json
import os
import socket
import subprocess
import sys

import numpy as np
import pytest

pytestmark = pytest.mark.tier2  # spawns real coordinated processes, >60 s

_TIMEOUT = 420


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _launch(nproc: int, fanouts: str, n: int, d: int, r_local: int,
            r_final: int, compress: str) -> list[dict]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    common = [
        "--coordinator", f"127.0.0.1:{_free_port()}",
        "--num-processes", str(nproc), "--fanouts", fanouts,
        "--n", str(n), "--d", str(d), "--r-local", str(r_local),
        "--r-final", str(r_final), "--compress", compress,
    ]
    procs = [
        subprocess.Popen(
            [sys.executable, "-m", "repro.launch.tree",
             "--process-id", str(i)] + common,
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True,
        )
        for i in range(nproc)
    ]
    outs = [p.communicate(timeout=_TIMEOUT) for p in procs]
    for p, (out, err) in zip(procs, outs):
        assert p.returncode == 0, err[-3000:]
    records = []
    for out, _ in outs:
        lines = [l for l in out.splitlines()
                 if l.startswith("TREE_SELECT_RESULT ")]
        assert lines, out
        records.append(json.loads(lines[0].split(" ", 1)[1]))
    return records


def _host_reference(fanouts: tuple[int, ...], n: int, d: int, r_local: int,
                    r_final: int, compress: str):
    from repro.distributed.tree_select import TreeTopology, tree_select_host
    from repro.launch.tree import _synthetic_pool

    return tree_select_host(
        _synthetic_pool(n, d, 0), TreeTopology(fanouts), r_local, r_final,
        compress=compress,
    )


def test_two_process_tree_select():
    """2 processes, depth-1, int8 wire, ragged pool (255 points)."""
    recs = _launch(2, "2", n=255, d=32, r_local=8, r_final=10,
                   compress="int8")
    assert recs[0]["indices"] == recs[1]["indices"], "processes disagree"
    assert recs[0]["weight_sum"] == 255.0
    assert len(set(recs[0]["indices"])) == 10
    # ~3.56x fewer candidate-feature bytes on the wire at d=32
    assert recs[0]["wire_reduction"] >= 3.5, recs[0]
    ref = _host_reference((2,), 255, 32, 8, 10, "int8")
    assert np.asarray(ref.indices).tolist() == recs[0]["indices"]
    np.testing.assert_allclose(float(ref.coverage), recs[0]["coverage"],
                               rtol=1e-5)


def test_four_process_depth_two_tree_select():
    """4 processes, fanouts 2,2 — exercises intermediate-level ownership
    (the stride logic): pids 0/2 own level-1 nodes, pid 0 owns the root."""
    recs = _launch(4, "2,2", n=256, d=32, r_local=8, r_final=10,
                   compress="int8")
    assert all(r["indices"] == recs[0]["indices"] for r in recs)
    assert recs[0]["weight_sum"] == 256.0
    ref = _host_reference((2, 2), 256, 32, 8, 10, "int8")
    assert np.asarray(ref.indices).tolist() == recs[0]["indices"]
