"""Multi-process tree selection over the jax.distributed KV store.

Launches REAL processes (``python -m repro.launch.tree``, one per leaf)
against a coordinator on a free local port — the same launch line a
multi-host run uses — and checks that every process returns the same
selection, that γ conservation holds, and that the result is
bit-identical to the single-process host driver on the concatenated
pool.  This is the tier-2 multi-process CI lane (XLA CPU has no
cross-process collectives, so the KV-store driver is the only
process-spanning path off-TPU/GPU).
"""
import json
import os
import socket
import subprocess
import sys
import time

import numpy as np
import pytest

pytestmark = pytest.mark.tier2  # spawns real coordinated processes, >60 s

_TIMEOUT = 420


def _free_port() -> int:
    s = socket.socket()
    s.bind(("127.0.0.1", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _launch(nproc: int, fanouts: str, n: int, d: int, r_local: int,
            r_final: int, compress: str) -> list[dict]:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    common = [
        "--coordinator", f"127.0.0.1:{_free_port()}",
        "--num-processes", str(nproc), "--fanouts", fanouts,
        "--n", str(n), "--d", str(d), "--r-local", str(r_local),
        "--r-final", str(r_final), "--compress", compress,
    ]
    procs = [
        subprocess.Popen(
            [sys.executable, "-m", "repro.launch.tree",
             "--process-id", str(i)] + common,
            env=env, stdout=subprocess.PIPE, stderr=subprocess.PIPE,
            text=True,
        )
        for i in range(nproc)
    ]
    outs = [p.communicate(timeout=_TIMEOUT) for p in procs]
    for p, (out, err) in zip(procs, outs):
        assert p.returncode == 0, err[-3000:]
    records = []
    for out, _ in outs:
        lines = [l for l in out.splitlines()
                 if l.startswith("TREE_SELECT_RESULT ")]
        assert lines, out
        records.append(json.loads(lines[0].split(" ", 1)[1]))
    return records


def _host_reference(fanouts: tuple[int, ...], n: int, d: int, r_local: int,
                    r_final: int, compress: str):
    from repro.distributed.tree_select import TreeTopology, tree_select_host
    from repro.launch.tree import _synthetic_pool

    return tree_select_host(
        _synthetic_pool(n, d, 0), TreeTopology(fanouts), r_local, r_final,
        compress=compress,
    )


def test_two_process_tree_select():
    """2 processes, depth-1, int8 wire, ragged pool (255 points)."""
    recs = _launch(2, "2", n=255, d=32, r_local=8, r_final=10,
                   compress="int8")
    assert recs[0]["indices"] == recs[1]["indices"], "processes disagree"
    assert recs[0]["weight_sum"] == 255.0
    assert len(set(recs[0]["indices"])) == 10
    # ~3.56x fewer candidate-feature bytes on the wire at d=32
    assert recs[0]["wire_reduction"] >= 3.5, recs[0]
    ref = _host_reference((2,), 255, 32, 8, 10, "int8")
    assert np.asarray(ref.indices).tolist() == recs[0]["indices"]
    np.testing.assert_allclose(float(ref.coverage), recs[0]["coverage"],
                               rtol=1e-5)


def test_four_process_depth_two_tree_select():
    """4 processes, fanouts 2,2 — exercises intermediate-level ownership
    (the stride logic): pids 0/2 own level-1 nodes, pid 0 owns the root."""
    recs = _launch(4, "2,2", n=256, d=32, r_local=8, r_final=10,
                   compress="int8")
    assert all(r["indices"] == recs[0]["indices"] for r in recs)
    assert recs[0]["weight_sum"] == 256.0
    ref = _host_reference((2, 2), 256, 32, 8, 10, "int8")
    assert np.asarray(ref.indices).tolist() == recs[0]["indices"]


def test_chaos_leaf_killed_mid_round_degrades_to_quorum():
    """The chaos lane (DESIGN.md §12): 4 leaves, pid 3 SIGKILLed by an
    injected fault right before publishing its candidates.  The three
    survivors must finish within the configured deadline envelope (NOT
    the legacy 300 s KV timeout), agree on one degraded selection with
    correct provenance, and conserve Σγ over the surviving shards."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    victim_env = dict(env)
    victim_env["REPRO_FAULT_PLAN"] = json.dumps(
        {"seed": 0, "specs": [{"site": "tree.publish", "kind": "kill"}]}
    )
    common = [
        "--coordinator", f"127.0.0.1:{_free_port()}",
        "--num-processes", "4", "--fanouts", "4",
        "--n", "256", "--d", "16", "--r-local", "8", "--r-final", "10",
        "--compress", "int8",
        "--level-deadline-s", "20", "--min-quorum", "0.75",
        "--heartbeat-interval-s", "0.2", "--heartbeat-grace-s", "2.0",
    ]
    t0 = time.monotonic()
    procs = [
        subprocess.Popen(
            [sys.executable, "-m", "repro.launch.tree",
             "--process-id", str(i)] + common,
            env=victim_env if i == 3 else env,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        )
        for i in range(4)
    ]
    outs = [p.communicate(timeout=_TIMEOUT) for p in procs]
    elapsed = time.monotonic() - t0
    # the victim died by its own injected SIGKILL
    assert procs[3].returncode == -9, outs[3][1][-2000:]
    records = []
    for p, (out, err) in zip(procs[:3], outs[:3]):
        assert p.returncode == 0, err[-3000:]
        lines = [l for l in out.splitlines()
                 if l.startswith("TREE_SELECT_RESULT ")]
        assert lines, out
        records.append(json.loads(lines[0].split(" ", 1)[1]))
    # survivors finished inside the configured envelope, not 300 s
    assert elapsed < 120, f"degraded run took {elapsed:.0f}s"
    assert all(r["indices"] == records[0]["indices"] for r in records)
    health = records[0]["health"]
    assert health["degraded"] is True
    assert health["missing_pids"] == [3]
    assert health["quorum"] == pytest.approx(0.75)
    # Σγ covers exactly the surviving shards (3 × 64 points) and no
    # point of the dead shard (global ids 192..255) can be selected
    assert records[0]["weight_sum"] == 192.0
    assert max(records[0]["indices"]) < 192
    assert len(set(records[0]["indices"])) == 10
