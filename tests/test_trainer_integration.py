"""Trainer loop: CRAIG refresh schedule, preemption, restart equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.craig import CraigConfig
from repro.data.synthetic import TokenStream
from repro.models import ModelConfig, init_params
from repro.optim import adamw, constant
from repro.train import Trainer, TrainerConfig

CFG = ModelConfig(
    name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
    n_kv_heads=2, d_ff=64, vocab_size=128, logit_chunk=16,
)


def _trainer(tmp, seed=0, **kw):
    ds = TokenStream(n_docs=48, seq_len=24, vocab_size=128, n_topics=6)
    tcfg = TrainerConfig(
        batch_size=8,
        select_every_epochs=kw.pop("select_every_epochs", 2),
        checkpoint_dir=str(tmp) if tmp else None,
        checkpoint_every=kw.pop("checkpoint_every", 4),
        craig=kw.pop("craig", CraigConfig(fraction=0.5, per_class=False)),
        **kw,
    )
    return Trainer(
        CFG, tcfg, ds, adamw(constant(2e-3)),
        lambda: init_params(jax.random.PRNGKey(seed), CFG),
    )


def test_loss_decreases_with_craig(tmp_path):
    t = _trainer(None)
    log = t.run(14)
    steps = [m["loss"] for m in log if m["event"] == "step"]
    refreshes = [m for m in log if m["event"] == "craig_refresh"]
    assert len(refreshes) >= 1
    assert refreshes[0]["coreset_size"] == 24  # 50% of 48
    assert np.mean(steps[-4:]) < np.mean(steps[:4])


def test_device_engine_refresh_during_training():
    """engine='device' rides the async refresh path end to end: the fused
    device greedy runs on the worker thread, selections install at epoch
    boundaries, and the warm-start prefix threads through (DESIGN.md §3.6)."""
    t = _trainer(
        None,
        craig=CraigConfig(
            fraction=0.5, per_class=False, engine="device", device_q=4
        ),
        refresh_mode="async",
        warm_start_fraction=0.5,
    )
    log = t.run(14)
    refreshes = [m for m in log if m["event"] == "craig_refresh"]
    assert len(refreshes) >= 1
    assert refreshes[0]["coreset_size"] == 24
    # the warm-start seed was recorded for the next refresh
    assert t._prev_selection is not None
    assert len(np.unique(t._prev_selection.indices)) == 24


def test_auto_engine_refresh_records_resolved_engine():
    """engine='auto' (the CraigConfig default) resolves per refresh-pool
    size — the dense matrix engine at this scale — and the resolved
    EngineConfig dict is stamped into the refresh event and sampler meta,
    surviving the sampler's state_dict round trip."""
    from repro.core.engines import EngineConfig

    t = _trainer(
        None, craig=CraigConfig(fraction=0.5, per_class=False, engine="auto")
    )
    log = t.run(14)
    refreshes = [m for m in log if m["event"] == "craig_refresh"]
    assert refreshes and refreshes[0]["coreset_size"] == 24
    assert refreshes[0]["engine"]["name"] == "matrix"
    # the provenance dict restores to a typed config
    assert EngineConfig.from_dict(refreshes[0]["engine"]).name == "matrix"
    # and a staged-but-not-installed refresh keeps it through state_dict
    import json

    json.dumps(t.sampler.state_dict())  # meta (incl. engine) is JSON-able


def test_typed_engine_config_in_trainer():
    """A typed EngineConfig threads end to end through TrainerConfig."""
    from repro.core.engines import DeviceConfig

    t = _trainer(
        None,
        craig=CraigConfig(
            fraction=0.5, per_class=False, engine=DeviceConfig(q=4)
        ),
    )
    log = t.run(14)
    refreshes = [m for m in log if m["event"] == "craig_refresh"]
    assert refreshes and refreshes[0]["coreset_size"] == 24
    assert refreshes[0]["engine"]["name"] == "device"
    assert refreshes[0]["engine"]["q"] == 4


def test_device_engine_sync_equals_async_refresh():
    """refresh_mode sync/async remain step-for-step replicas with the
    device engine doing the selection."""
    logs = {}
    for mode in ("sync", "async"):
        t = _trainer(
            None,
            craig=CraigConfig(fraction=0.5, per_class=False, engine="device"),
            refresh_mode=mode,
        )
        logs[mode] = [
            m["loss"] for m in t.run(10) if m["event"] == "step"
        ]
    np.testing.assert_allclose(logs["sync"], logs["async"], rtol=1e-6)


def test_preemption_saves_and_restart_resumes(tmp_path):
    t1 = _trainer(tmp_path)
    t1.run(6)
    t1.request_preempt()
    t1.run(1)  # triggers emergency save and stops
    saved_step = t1.step

    t2 = _trainer(tmp_path, seed=99)  # different init — must be overwritten
    assert t2.restore_or_init()
    assert t2.step == saved_step
    # params identical to the preempted trainer's
    for a, b in zip(jax.tree.leaves(t1.params), jax.tree.leaves(t2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    # the data stream continues exactly where it stopped
    i1, _ = t1.sampler.next_batch()
    i2, _ = t2.sampler.next_batch()
    np.testing.assert_array_equal(i1, i2)


def test_restart_training_continuation_matches(tmp_path):
    """Uninterrupted run == run that checkpoints, dies, and restores."""
    t_full = _trainer(tmp_path / "a", checkpoint_every=100)
    log_full = t_full.run(10)

    t_a = _trainer(tmp_path / "b", checkpoint_every=5)
    t_a.run(5)
    t_a.ckpt.wait()
    t_b = _trainer(tmp_path / "b", seed=7)
    assert t_b.restore_or_init()
    log_b = t_b.run(5)

    full_losses = [m["loss"] for m in log_full if m["event"] == "step"][5:]
    resumed = [m["loss"] for m in log_b if m["event"] == "step"]
    np.testing.assert_allclose(full_losses, resumed, rtol=2e-3, atol=2e-4)


def test_straggler_watchdog_records():
    t = _trainer(None, step_timeout_s=0.0)  # everything is a "straggler"
    t.run(3)
    assert len(t.straggler_events) == 3


def test_no_craig_mode_plain_training():
    t = _trainer(None, use_craig=False)
    log = t.run(6)
    assert not [m for m in log if m["event"] == "craig_refresh"]
    assert t.sampler.active_size == 48


def test_refresh_passes_labels_for_per_class_selection():
    """Regression: the refresh path used to drop labels, silently disabling
    the paper-§5 per-class mode during training.  With a labeled dataset the
    installed coreset must be stratified across every topic."""
    ds = TokenStream(n_docs=48, seq_len=24, vocab_size=128, n_topics=6)
    tcfg = TrainerConfig(
        batch_size=8,
        select_every_epochs=1,
        craig=CraigConfig(fraction=0.5, per_class=True),
    )
    t = Trainer(CFG, tcfg, ds, adamw(constant(2e-3)),
                lambda: init_params(jax.random.PRNGKey(0), CFG))
    t.run(8)  # epoch 0 full data, install at step 6
    refreshes = [m for m in t.metrics_log if m["event"] == "craig_refresh"]
    assert refreshes and refreshes[0]["coreset_size"] == 24
    sel = t._prev_selection
    assert sel is not None and sel.per_class_sizes is not None
    assert sum(sel.per_class_sizes.values()) == 24
    # budgets ∝ topic frequency: every topic (8 docs each) is represented
    assert set(sel.per_class_sizes) == set(range(6))
    assert all(v == 4 for v in sel.per_class_sizes.values())


def test_refresh_warns_when_labels_unavailable():
    class NoLabelStream:
        """Index-addressable dataset without a class_labels() accessor."""

        def __init__(self, inner):
            self._inner = inner
            self.n_docs = inner.n_docs

        def batch(self, idx):
            return self._inner.batch(idx)

    ds = NoLabelStream(TokenStream(n_docs=48, seq_len=24, vocab_size=128))
    tcfg = TrainerConfig(
        batch_size=8,
        select_every_epochs=1,
        craig=CraigConfig(fraction=0.5, per_class=True),
    )
    with pytest.warns(UserWarning, match="class_labels"):
        t = Trainer(CFG, tcfg, ds, adamw(constant(2e-3)),
                    lambda: init_params(jax.random.PRNGKey(0), CFG))
    t.run(8)  # still trains; selection falls back to flat mode
    refreshes = [m for m in t.metrics_log if m["event"] == "craig_refresh"]
    assert refreshes and refreshes[0]["coreset_size"] == 24
    assert t._prev_selection.per_class_sizes is None


class GrowingStream:
    """A corpus that grows between epochs: ``n_docs`` exposes a prefix of
    the inner stream, extended by :meth:`grow` — the streaming-ingest
    trainer must pick up exactly the appended suffix at each boundary."""

    def __init__(self, inner, visible):
        self._inner = inner
        self.n_docs = int(visible)

    def batch(self, idx):
        return self._inner.batch(idx)

    def class_labels(self, idx):
        return self._inner.class_labels(idx)

    def grow(self, n):
        self.n_docs = min(self._inner.n_docs, self.n_docs + int(n))


def test_streaming_ingest_growing_corpus():
    """streaming_ingest=True: refreshes ride AsyncRefresher.ingest — only
    docs appended since the last boundary are extracted (O(Δn), not the
    full pool), the sieve pool buffer stays compacted in lockstep with
    eviction, and installed coresets index the whole grown corpus."""
    inner = TokenStream(n_docs=48, seq_len=24, vocab_size=128, n_topics=6)
    ds = GrowingStream(inner, visible=24)
    tcfg = TrainerConfig(
        batch_size=8,
        select_every_epochs=1,
        refresh_mode="sync",
        streaming_ingest=True,
        craig=CraigConfig(fraction=0.5, per_class=False),
    )
    t = Trainer(CFG, tcfg, ds, adamw(constant(2e-3)),
                lambda: init_params(jax.random.PRNGKey(0), CFG))
    t.run(4)  # boundary 0 ingests docs [0, 24); install at epoch 1
    assert t._stream_cursor == 24
    assert t._stream_sel is not None and t._stream_sel.n_seen == 24
    # budget fixed at fraction × first delta
    assert t._stream_sel.budget == 12

    ds.grow(24)
    t.run(8)  # next boundary ingests exactly the appended [24, 48)
    assert t._stream_cursor == 48
    assert t._stream_sel.n_seen == 48
    refreshes = [m for m in t.metrics_log if m["event"] == "craig_refresh"]
    assert len(refreshes) >= 2
    assert all(r["coreset_size"] == 12 for r in refreshes)
    # pool buffer and doc-id map stay in lockstep with eviction
    n_rows = t._stream_sel.n_rows
    assert t._stream_pool.shape[0] == n_rows
    assert t._stream_doc_ids.shape[0] == n_rows
    assert n_rows <= t._stream_sel.n_seen
    # the installed coreset indexes the corpus directly (doc ids, unique)
    idx = t.sampler._indices
    assert idx is not None and len(idx) == 12
    assert len(np.unique(idx)) == 12 and idx.min() >= 0 and idx.max() < 48
    # γ covers the live pool
    np.testing.assert_allclose(np.sum(t.sampler._weights), n_rows)


def test_streaming_ingest_restart_resumes(tmp_path):
    """Streaming state (cursor, sieve states, compacted pool + doc ids)
    round-trips through the checkpoint — a restarted trainer continues the
    stream without re-ingesting or double-counting docs."""
    inner = TokenStream(n_docs=48, seq_len=24, vocab_size=128, n_topics=6)

    def make(seed=0):
        ds = GrowingStream(inner, visible=24)
        tcfg = TrainerConfig(
            batch_size=8,
            select_every_epochs=1,
            refresh_mode="sync",
            streaming_ingest=True,
            checkpoint_dir=str(tmp_path),
            craig=CraigConfig(fraction=0.5, per_class=False),
        )
        return ds, Trainer(CFG, tcfg, ds, adamw(constant(2e-3)),
                           lambda: init_params(jax.random.PRNGKey(seed), CFG))

    _, t1 = make()
    t1.run(4)
    t1._save(blocking=True)

    ds2, t2 = make(seed=9)
    assert t2.restore_or_init()
    assert t2._stream_cursor == t1._stream_cursor == 24
    assert t2._stream_sel.n_seen == t1._stream_sel.n_seen
    np.testing.assert_array_equal(t2._stream_doc_ids, t1._stream_doc_ids)
    np.testing.assert_allclose(t2._stream_pool, t1._stream_pool)
    # and the resumed stream keeps growing without double-ingesting
    ds2.grow(24)
    t2.run(6)
    assert t2._stream_cursor == 48
    assert t2._stream_sel.n_seen == 48


@pytest.mark.tier2
def test_eval_harness_tracks_heldout_loss():
    ds_train = TokenStream(n_docs=48, seq_len=24, vocab_size=128, n_topics=6)
    ds_eval = TokenStream(n_docs=16, seq_len=24, vocab_size=128, n_topics=6,
                          seed=99)
    tcfg = TrainerConfig(batch_size=8, eval_every=4, eval_batches=2,
                         select_every_epochs=0, use_craig=False)
    t = Trainer(CFG, tcfg, ds_train, adamw(constant(2e-3)),
                lambda: init_params(jax.random.PRNGKey(0), CFG),
                eval_dataset=ds_eval)
    log = t.run(9)
    evals = [m for m in log if m["event"] == "eval"]
    assert len(evals) == 2  # steps 4 and 8
    assert all(np.isfinite(e["eval_loss"]) for e in evals)
    # eval loss should improve as training progresses
    assert evals[-1]["eval_loss"] <= evals[0]["eval_loss"] + 0.05


def test_restore_keeps_selection_provenance(tmp_path):
    """Regression: restore_or_init used to drop ``engine`` and
    ``per_class_sizes`` when rebuilding the warm-start CoresetSelection
    from checkpoint extras — a restarted trainer lost the provenance of
    the selection it warm-starts from."""
    craig = CraigConfig(fraction=0.5, per_class=True)
    t1 = _trainer(tmp_path, craig=craig, select_every_epochs=1)
    t1.run(8)  # ≥1 refresh; prev_selection carries engine + class sizes
    t1._save(blocking=True)
    prev1 = t1._prev_selection
    assert prev1 is not None
    assert prev1.engine is not None and prev1.per_class_sizes is not None

    t2 = _trainer(tmp_path, seed=9, craig=craig, select_every_epochs=1)
    assert t2.restore_or_init()
    prev2 = t2._prev_selection
    assert prev2.engine == prev1.engine
    # JSON stringifies int keys; restore must re-int them
    assert prev2.per_class_sizes == prev1.per_class_sizes
    np.testing.assert_array_equal(prev2.indices, prev1.indices)
