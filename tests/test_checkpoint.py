"""Checkpoint manager: atomicity, keep-k, async, extras, elastic restore."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros(4)},
        "opt": (jnp.zeros((), jnp.int32), [jnp.ones(3)]),
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    mgr.save(5, tree, extras={"cursor": 42})
    got, extras = mgr.restore(jax.tree.map(jnp.zeros_like, tree))
    assert extras == {"cursor": 42}
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_latest_pointer_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s))
    assert mgr.latest_step() == 4
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(1, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_atomicity_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(7, _tree())
    # no .tmp leftovers
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]
    # manifest parses
    with open(tmp_path / "step_00000007" / "manifest.json") as f:
        m = json.load(f)
    assert m["step"] == 7 and len(m["leaves"]) == 4


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    got, _ = mgr.restore(jax.tree.map(jnp.zeros_like, _tree()), step=1)
    want = _tree(1)
    np.testing.assert_allclose(
        np.asarray(got["params"]["w"]), np.asarray(want["params"]["w"])
    )


def test_elastic_restore_with_shardings(tmp_path):
    """Restore places arrays with the provided (new-mesh) shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import compat_mesh

    mesh = compat_mesh((1,), ("data",))
    mgr = CheckpointManager(str(tmp_path), keep=1)
    tree = _tree()
    mgr.save(3, tree)
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    got, _ = mgr.restore(jax.tree.map(jnp.zeros_like, tree), shardings=shardings)
    assert got["params"]["w"].sharding == NamedSharding(mesh, P())


def test_missing_checkpoint_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore({"x": jnp.zeros(1)})


def test_streaming_selector_rides_extras_kill_and_resume(tmp_path):
    """Mid-stream sieve state checkpoints through the extras channel and a
    'killed' service resumes bit-identically against the uninterrupted run
    (engines.streaming state is JSON-able by construction)."""
    from repro.core.engines.streaming import StreamingSelector

    rng = np.random.RandomState(0)
    deltas = [rng.randn(30, 5).astype(np.float32) for _ in range(4)]
    pool = np.concatenate(deltas)

    straight = StreamingSelector(12, 5)
    for d in deltas:
        straight.ingest(d)

    sel = StreamingSelector(12, 5)
    sel.ingest(deltas[0])
    sel.ingest(deltas[1])
    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(2, _tree(), extras={"streaming": sel.state_dict()})
    del sel  # the "kill"

    _, extras = CheckpointManager(str(tmp_path)).restore(
        jax.tree.map(jnp.zeros_like, _tree())
    )
    resumed = StreamingSelector(12, 5)
    resumed.load_state_dict(extras["streaming"])
    assert resumed.n_seen == 60
    resumed.ingest(deltas[2])
    resumed.ingest(deltas[3])

    ra, rb = straight.result(pool), resumed.result(pool)
    np.testing.assert_array_equal(np.asarray(ra.indices), np.asarray(rb.indices))
    np.testing.assert_array_equal(np.asarray(ra.weights), np.asarray(rb.weights))
    assert float(np.asarray(rb.weights).sum()) == pytest.approx(120.0)
