"""Teacher-forced forward vs token-by-token decode parity, all families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import (
    ModelConfig,
    decode_step,
    forward,
    init_params,
    init_serve_state,
)
from repro.models.model import COMPUTE_DTYPE, _unembed_matrix

pytestmark = pytest.mark.tier2  # per-family decode sweeps, 18–45 s each

CFGS = {
    "dense": ModelConfig(
        name="dense", family="dense", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, qkv_bias=True, qk_norm=True,
    ),
    "griffin": ModelConfig(
        name="griffin", family="hybrid", n_layers=5, d_model=64, n_heads=4,
        n_kv_heads=1, d_ff=128, vocab_size=128,
        block_pattern=("rglru", "rglru", "local_attn"), window=8, d_rnn=64,
        activation="gelu",
    ),
    "xlstm": ModelConfig(
        name="xlstm", family="ssm", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=0, vocab_size=128,
        block_pattern=("mlstm", "mlstm", "mlstm", "slstm"), mlstm_chunk=8,
    ),
    "moe": ModelConfig(
        name="moe", family="moe", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=64, vocab_size=128, n_experts=4, top_k=2,
        capacity_factor=2.0,
    ),
    "vlm": ModelConfig(
        name="vlm", family="vlm", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=128, mrope_sections=(4, 2, 2),
        frontend="embeddings",
    ),
    "musicgen": ModelConfig(
        name="musicgen", family="audio", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=64, frontend="embeddings",
        n_codebooks=4, activation="gelu", gated_ffn=False, norm="layernorm",
    ),
}


@pytest.mark.parametrize("name", sorted(CFGS))
def test_forward_decode_parity(name):
    cfg = CFGS[name]
    T, B = 24, 2
    # recurrent cells reassociate (associative scan / chunked vs sequential):
    # bf16 noise compounds over T — allow 4% for those families
    tol = 4e-2 if name in ("griffin", "xlstm") else 2e-2
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    if cfg.frontend == "tokens":
        toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
        batch = {"tokens": toks}
    else:
        emb = jax.random.normal(key, (B, T, cfg.d_model), jnp.float32) * 0.5
        batch = {"embeddings": emb}
    hidden, _ = forward(params, cfg, batch)
    state = init_serve_state(cfg, B, T)
    outs = []
    for t in range(T):
        b1 = (
            {"tokens": toks[:, t : t + 1]}
            if cfg.frontend == "tokens"
            else {"embeddings": emb[:, t : t + 1]}
        )
        logits, state = decode_step(params, cfg, state, b1)
        outs.append(logits)
    un = _unembed_matrix(params, cfg)
    if cfg.n_codebooks > 1:
        ref = jnp.einsum(
            "btd,cdv->btcv", hidden.astype(COMPUTE_DTYPE), un.astype(COMPUTE_DTYPE)
        ).astype(jnp.float32)
    else:
        ref = (hidden.astype(COMPUTE_DTYPE) @ un.astype(COMPUTE_DTYPE)).astype(
            jnp.float32
        )
    got = jnp.stack(outs, 1)
    err = float(jnp.max(jnp.abs(ref - got)))
    scale = float(jnp.max(jnp.abs(ref)) + 1e-9)
    assert err / scale < tol, f"{name}: rel err {err/scale:.3e}"
    assert int(state["pos"]) == T
