"""Hierarchical tree selection (distributed/tree_select, DESIGN.md §6).

Tier 1 exercises the host driver (single-process, ragged-capable) plus
topology/config/wire units — no mesh needed.  The tier-2 subprocess runs
the N-axis mesh driver on 8 simulated devices and pins the load-bearing
identities: depth-1 fp32 tree ≡ ``local_then_merge`` bit for bit, and
mesh ≡ host at every depth/wire mode.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.craig import CraigConfig, CraigSelector
from repro.core.engines import engine_config_from_dict
from repro.distributed.tree_select import (
    TreeSelectConfig,
    TreeTopology,
    default_r_node,
    tree_select_host,
    wire_bytes_plan,
)


def _clustered(n, d, seed=0):
    rng = np.random.RandomState(seed)
    c = rng.randn(8, d).astype(np.float32) * 5.0
    assign = rng.randint(0, 8, n)
    return (c[assign] + 0.3 * rng.randn(n, d)).astype(np.float32), assign


# ---------------------------------------------------------------------------
# topology + config + wire units
# ---------------------------------------------------------------------------


def test_topology_shape():
    t = TreeTopology((4, 2))
    assert t.depth == 2 and t.n_leaves == 8
    assert t.nodes_at(0) == 8 and t.nodes_at(1) == 2 and t.nodes_at(2) == 1
    assert t.axis_names == ("lvl0", "lvl1")
    assert TreeTopology.from_dict(t.to_dict()) == t


def test_topology_rejects_degenerate():
    with pytest.raises(ValueError, match="at least one fan-out"):
        TreeTopology(())
    with pytest.raises(ValueError, match="≥ 1"):
        TreeTopology((4, 0))
    with pytest.raises(ValueError, match="degenerate"):
        TreeTopology((1, 1, 1))
    # a 1-fan-out level inside a non-degenerate tree is fine (pass-through)
    assert TreeTopology((1, 4)).n_leaves == 4


def test_tree_config_provenance_roundtrip():
    cfg = TreeSelectConfig(fanouts=(4, 2), compress="int8",
                           local={"name": "matrix"})
    d = cfg.to_dict()
    assert d["name"] == "tree"
    restored = engine_config_from_dict(d)
    assert restored == cfg and restored.topology.n_leaves == 8
    # JSON round trip turns the fanouts tuple into a list; the config
    # normalizes it back
    import json

    rejson = engine_config_from_dict(json.loads(json.dumps(d)))
    assert rejson == cfg
    with pytest.raises(ValueError, match="wire mode"):
        TreeSelectConfig(fanouts=(2,), compress="fp8")


def test_wire_bytes_plan_math():
    # depth-2, r uniform: every child ships once per level; int8 payload is
    # r·d + 4r (scales) vs 4·r·d fp32 → reduction 4d/(d+4)
    t = TreeTopology((4, 2))
    plan = wire_bytes_plan(t, r_local=8, r_node=8, d=64, compress="int8")
    per_payload = 8 * 64 + 4 * 8
    assert plan["per_level"][0]["bytes"] == 8 * per_payload
    assert plan["per_level"][1]["bytes"] == 2 * per_payload
    assert plan["fp32_feature_bytes"] == (8 + 2) * 4 * 8 * 64
    np.testing.assert_allclose(plan["reduction"], 4 * 64 / (64 + 4))
    # forwarded size is min(r_node, fanout·r), not r_node blindly
    shrunk = wire_bytes_plan(t, r_local=2, r_node=100, d=16, compress="none")
    assert shrunk["per_level"][1]["r_child"] == 8  # 4·2, not 100
    assert default_r_node(8, 32) == 32 and default_r_node(64, 32) == 64


# ---------------------------------------------------------------------------
# host driver
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fanouts", [(4,), (4, 2), (2, 2, 2)])
@pytest.mark.parametrize("compress", ["int8", "none"])
def test_host_driver_contract(fanouts, compress):
    feats, assign = _clustered(256, 16)
    sel = tree_select_host(
        jnp.asarray(feats), TreeTopology(fanouts), r_local=6, r_final=8,
        compress=compress,
    )
    idx = np.asarray(sel.indices)
    assert idx.shape == (8,) and len(set(idx.tolist())) == 8
    assert (0 <= idx).all() and (idx < 256).all()
    w = np.asarray(sel.weights)
    assert w.sum() == 256.0 and (w >= 0).all()  # exact γ partition
    # well-separated clusters: the selection spans most of them
    assert len(set(assign[idx].tolist())) >= 7


def test_host_driver_ragged_leaves():
    """n not divisible by n_leaves: array_split semantics, no phantom or
    dropped points — Σγ still equals the exact pool size."""
    feats, _ = _clustered(251, 12, seed=3)
    sel = tree_select_host(
        jnp.asarray(feats), TreeTopology((4, 2)), r_local=5, r_final=8
    )
    assert float(np.asarray(sel.weights).sum()) == 251.0
    assert len(set(np.asarray(sel.indices).tolist())) == 8


def test_host_driver_int8_matches_fp32_on_separated_clusters():
    """The int8 wire perturbs candidate features by ≤ scale/2 per row —
    on well-separated clusters the selected medoid set is unchanged."""
    feats, _ = _clustered(256, 32, seed=1)
    t = TreeTopology((4, 2))
    a = tree_select_host(jnp.asarray(feats), t, r_local=6, r_final=8,
                         compress="int8")
    b = tree_select_host(jnp.asarray(feats), t, r_local=6, r_final=8,
                         compress="none")
    assert set(np.asarray(a.indices).tolist()) == set(
        np.asarray(b.indices).tolist())


def test_host_driver_deeper_tree_stays_close():
    """Depth-2/3 coverage stays within a small factor of the depth-1 tree
    (the GreeDi-composition loss is empirically tiny)."""
    feats, _ = _clustered(512, 16, seed=2)
    covs = {}
    for fo in [(8,), (4, 2), (2, 2, 2)]:
        covs[fo] = float(
            tree_select_host(jnp.asarray(feats), TreeTopology(fo),
                             r_local=8, r_final=10).coverage
        )
    assert covs[(4, 2)] <= 1.3 * covs[(8,)], covs
    assert covs[(2, 2, 2)] <= 1.3 * covs[(8,)], covs


def test_host_driver_error_paths():
    feats, _ = _clustered(64, 8)
    t = TreeTopology((4,))
    with pytest.raises(ValueError, match="wire mode"):
        tree_select_host(jnp.asarray(feats), t, 4, 8, compress="fp16")
    with pytest.raises(ValueError, match="exceeds the shard pool"):
        tree_select_host(jnp.asarray(feats), t, 40, 8)
    with pytest.raises(ValueError, match="fewer than"):
        tree_select_host(jnp.asarray(feats), t, 1, 8)
    with pytest.raises(ValueError, match="r_node"):
        tree_select_host(jnp.asarray(feats), TreeTopology((2, 2)), 4, 4,
                         r_node=0)
    with pytest.raises(ValueError, match="leaves"):
        tree_select_host(jnp.asarray(feats), TreeTopology((65,)), 1, 8)
    with pytest.raises(ValueError, match="budgets must be"):
        tree_select_host(jnp.asarray(feats), t, 4, 0)


def test_selector_select_tree_contract_and_provenance():
    feats, _ = _clustered(300, 24)
    sel = CraigSelector(CraigConfig(fraction=0.05, per_class=False))
    cs = sel.select_tree(jnp.asarray(feats), (4, 2))
    assert cs.size == 15
    np.testing.assert_allclose(cs.weights.sum(), 300.0)
    assert cs.engine["name"] == "tree"
    assert tuple(cs.engine["fanouts"]) == (4, 2)
    assert cs.engine["local"]["name"] == "matrix"  # auto at n_local=75
    restored = engine_config_from_dict(cs.engine)
    assert isinstance(restored, TreeSelectConfig)
    # cover mode has no tree path (needs exact prefix coverages)
    with pytest.raises(ValueError, match="budget"):
        CraigSelector(
            CraigConfig(mode="cover", epsilon=1.0, per_class=False)
        ).select_tree(jnp.asarray(feats), (2,))


def test_selector_select_tree_cosine_units():
    """metric='cosine' reports coverage in 1−cosθ units (same invariant
    as select/select_distributed): bounded by n·max(1−cosθ) ≤ 2n."""
    feats, _ = _clustered(200, 16, seed=5)
    cs = CraigSelector(
        CraigConfig(fraction=0.05, per_class=False, metric="cosine")
    ).select_tree(jnp.asarray(feats), (2, 2))
    assert 0.0 <= cs.coverage <= 2.0 * 200


# ---------------------------------------------------------------------------
# tier 2: mesh driver on 8 simulated devices (subprocess — XLA_FLAGS must
# be set before jax initializes; the main process keeps seeing 1 device)
# ---------------------------------------------------------------------------

MESH_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.distributed import distributed_select
    from repro.distributed.tree_select import (
        TreeTopology, tree_mesh, tree_select_host, tree_select_mesh)
    from repro.launch.mesh import compat_mesh

    k = jax.random.PRNGKey(0)
    centers = jax.random.normal(k, (8, 16)) * 5.0
    assign = jax.random.randint(jax.random.PRNGKey(1), (512,), 0, 8)
    feats = centers[assign] + 0.3 * jax.random.normal(
        jax.random.PRNGKey(2), (512, 16))

    # depth-1 fp32 tree ≡ the existing two-round path, bit for bit
    topo1 = TreeTopology((8,))
    ds = distributed_select(feats, compat_mesh((8,), ("data",)),
                            r_local=6, r_final=10)
    th = tree_select_host(feats, topo1, 6, 10, compress="none")
    tm = tree_select_mesh(feats, tree_mesh(topo1), topo1, 6, 10,
                          compress="none")
    for t in (th, tm):
        assert np.array_equal(np.asarray(t.indices), np.asarray(ds.indices))
        assert np.array_equal(np.asarray(t.weights), np.asarray(ds.weights))
        np.testing.assert_allclose(float(t.coverage), float(ds.coverage),
                                   rtol=1e-5)

    # mesh ≡ host at depth 2 and 3, int8 wire (same leaf order, same
    # wire codec, same merge budgets → identical selections)
    for fo in [(4, 2), (2, 2, 2), (2, 4)]:
        topo = TreeTopology(fo)
        m = tree_select_mesh(feats, tree_mesh(topo), topo, 6, 10,
                             compress="int8")
        h = tree_select_host(feats, topo, 6, 10, compress="int8")
        assert np.array_equal(np.asarray(m.indices), np.asarray(h.indices)), fo
        assert np.array_equal(np.asarray(m.weights), np.asarray(h.weights)), fo
        assert np.asarray(m.weights).sum() == 512.0
        np.testing.assert_allclose(float(m.coverage), float(h.coverage),
                                   rtol=1e-5)

    # determinism of the mesh program
    topo = TreeTopology((4, 2))
    a = tree_select_mesh(feats, tree_mesh(topo), topo, 6, 10)
    b = tree_select_mesh(feats, tree_mesh(topo), topo, 6, 10)
    assert np.array_equal(np.asarray(a.indices), np.asarray(b.indices))

    # ragged pool is rejected with the informative error (no silent pad)
    try:
        tree_select_mesh(feats[:509], tree_mesh(topo1), topo1, 6, 10)
        raise SystemExit("expected ValueError for ragged mesh pool")
    except ValueError as e:
        assert "not divisible" in str(e), e
    # mesh without the level axes is rejected
    try:
        tree_select_mesh(feats, compat_mesh((8,), ("data",)), topo1, 6, 10)
        raise SystemExit("expected ValueError for missing level axis")
    except ValueError as e:
        assert "missing level axis" in str(e), e
    print("TREE_MESH_OK")
    """
)


@pytest.mark.tier2
def test_tree_mesh_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-c", MESH_SCRIPT],
        env=env, capture_output=True, text=True, timeout=480,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "TREE_MESH_OK" in out.stdout
