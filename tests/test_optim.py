"""Optimizers + schedules: convergence on quadratics, clipping, state."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adamw,
    clip_by_global_norm,
    constant,
    exponential_decay,
    global_norm,
    k_inverse,
    momentum,
    sgd,
    warmup_cosine,
)


def _quadratic_target():
    A = jnp.diag(jnp.array([1.0, 5.0, 10.0]))
    b = jnp.array([1.0, -2.0, 3.0])
    w_star = jnp.linalg.solve(A, b)

    def grad(w):
        return A @ w - b

    return grad, w_star


@pytest.mark.parametrize(
    "make_opt,steps",
    [
        (lambda: sgd(constant(0.05)), 400),
        (lambda: momentum(constant(0.02), 0.9), 400),
        (lambda: adamw(constant(0.1)), 600),
    ],
)
def test_converges_on_quadratic(make_opt, steps):
    grad, w_star = _quadratic_target()
    opt = make_opt()
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        g = {"w": grad(p["w"])}
        return opt.update(g, s, p)

    for _ in range(steps):
        params, state = step(params, state)
    assert float(jnp.linalg.norm(params["w"] - w_star)) < 1e-2


def test_schedules_shapes_and_monotonicity():
    s1 = exponential_decay(0.1, 0.9)
    s2 = k_inverse(0.1, 0.5, tau=1.0)
    s3 = warmup_cosine(0.1, 10, 100)
    ks = jnp.arange(0, 100)
    v1 = jax.vmap(s1)(ks)
    v2 = jax.vmap(s2)(ks)
    v3 = jax.vmap(s3)(ks)
    assert np.all(np.diff(np.asarray(v1)) <= 0)
    assert np.all(np.diff(np.asarray(v2)) <= 0)
    assert float(v3[0]) == 0.0 and float(v3[10]) == pytest.approx(0.1, rel=1e-3)
    assert float(v3[99]) < 0.01


def test_clip_by_global_norm():
    tree = {"a": jnp.ones(4) * 3.0, "b": jnp.ones(9) * 4.0}
    norm = float(global_norm(tree))
    clipped, reported = clip_by_global_norm(tree, 1.0)
    assert reported == pytest.approx(norm)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)
    # below threshold: untouched
    same, _ = clip_by_global_norm(tree, norm * 2)
    np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(tree["a"]))


def test_adamw_weight_decay_shrinks():
    opt = adamw(constant(0.1), weight_decay=0.5)
    params = {"w": jnp.ones(3) * 10.0}
    state = opt.init(params)
    zero_g = {"w": jnp.zeros(3)}
    for _ in range(50):
        params, state = opt.update(zero_g, state, params)
    assert float(jnp.abs(params["w"]).max()) < 10.0


def test_opt_state_is_pytree_like_params():
    opt = adamw(constant(1e-3))
    params = {"x": jnp.zeros((4, 4)), "nested": {"y": jnp.zeros(7)}}
    st = opt.init(params)
    assert st.inner["m"]["x"].shape == (4, 4)
    assert st.inner["v"]["nested"]["y"].shape == (7,)
