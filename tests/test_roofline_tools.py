"""Roofline machinery: collective census parser + term assembly."""
import json
import os

import pytest

from repro.launch.dryrun import collective_census, model_flops
from repro.configs.registry import get_config
from repro.configs.shapes import SHAPES


def test_collective_census_parses_hlo_text():
    hlo = """
  %ag = bf16[16,4096,2048]{2,1,0} all-gather(%x), replica_groups=...
  %ar.1 = f32[256,1024]{1,0} all-reduce-start(%y), to_apply=%sum
  %rs = f32[128]{0} reduce-scatter(%z), dimensions={0}
  %cp = bf16[8,8]{1,0} collective-permute(%w), source_target_pairs=...
  %a2a = s32[64]{0} all-to-all(%v), dimensions={0}
  %not_a_collective = f32[10]{0} add(%a, %b)
"""
    c = collective_census(hlo)
    assert c["all-gather"]["bytes"] == 16 * 4096 * 2048 * 2
    assert c["all-reduce"]["bytes"] == 256 * 1024 * 4
    assert c["reduce-scatter"]["bytes"] == 128 * 4
    assert c["collective-permute"]["bytes"] == 64 * 2
    assert c["all-to-all"]["bytes"] == 64 * 4
    assert sum(v["count"] for v in c.values()) == 5


def test_model_flops_train_vs_decode():
    cfg = get_config("qwen3-1.7b")
    f_train = model_flops(cfg, SHAPES["train_4k"])
    f_dec = model_flops(cfg, SHAPES["decode_32k"])
    # train: 6·N·B·T;  decode: 2·N·B (one token per sequence)
    assert f_train == pytest.approx(6 * cfg.param_count() * 256 * 4096, rel=1e-6)
    assert f_dec == pytest.approx(2 * cfg.param_count() * 128, rel=1e-6)


def test_moe_uses_active_params():
    cfg = get_config("dbrx-132b")
    f = model_flops(cfg, SHAPES["train_4k"])
    assert f == pytest.approx(
        6 * cfg.active_param_count() * 256 * 4096, rel=1e-6
    )
    assert cfg.active_param_count() < 0.35 * cfg.param_count()


@pytest.mark.skipif(
    not os.path.exists(
        os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")
    ),
    reason="dry-run artifacts not present",
)
def test_roofline_analyze_artifacts():
    from repro import roofline

    cells = roofline.analyze_all(mesh="16x16")
    if not cells:
        pytest.skip("no artifacts yet")
    for c in cells:
        assert c.t_compute >= 0 and c.t_memory >= 0 and c.t_collective >= 0
        assert c.dominant in ("compute", "memory", "collective")
        assert 0 < c.useful_ratio
        md = roofline.to_markdown(cells[:3])
        assert "dominant" in md
