"""SelectionEngine registry: capabilities match behavior, legacy shims map
with a single DeprecationWarning, EngineConfig dict round-trips, and the
engine='auto' policy table."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engines as E
from repro.core.craig import CraigConfig, CraigSelector
from repro.core.engines.legacy import resolve_engine_config

ALL_ENGINES = (
    "matrix", "lazy", "stochastic", "features", "sparse", "device", "streaming",
)


def _feats(n=96, d=6, seed=0):
    return np.random.RandomState(seed).randn(n, d).astype(np.float32)


# -- registry surface ---------------------------------------------------------


def test_list_engines_complete_and_matrix_first():
    names = E.list_engines()
    assert set(names) == set(ALL_ENGINES)
    assert names[0] == "matrix"  # ladder/parity baseline anchor


def test_get_engine_unknown_names_registered_set():
    with pytest.raises(ValueError, match="matrix"):
        E.get_engine("quantum")


def test_every_engine_selects_via_typed_config():
    """All six engines, typed-config surface only: unique indices, Σγ == n,
    and the exact engines bit-match the matrix baseline."""
    feats = _feats(120, 8)
    base = CraigSelector(
        CraigConfig(fraction=0.1, engine=E.MatrixConfig(), per_class=False)
    ).select(feats)
    configs = {
        "matrix": E.MatrixConfig(),
        "lazy": E.LazyConfig(),
        "stochastic": E.StochasticConfig(delta=0.01),
        "features": E.FeaturesConfig(),
        "sparse": E.SparseConfig(k=120),  # complete graph == exact greedy
        "device": E.DeviceConfig(),
        "streaming": E.StreamingConfig(),  # (1/2 − eps) sieve, not exact
    }
    for name, ec in configs.items():
        cs = CraigSelector(
            CraigConfig(fraction=0.1, engine=ec, per_class=False)
        ).select(feats)
        assert cs.size == 12, name
        assert len(np.unique(cs.indices)) == 12, name
        assert cs.weights.sum() == pytest.approx(120.0), name
        assert cs.engine == ec.to_dict(), name
        if name in ("matrix", "lazy", "features", "device"):
            np.testing.assert_array_equal(base.indices, cs.indices, err_msg=name)
        if name == "sparse":
            np.testing.assert_array_equal(
                np.sort(base.indices), np.sort(cs.indices)
            )


# -- capabilities match behavior ----------------------------------------------


@pytest.mark.parametrize("name", ALL_ENGINES)
def test_cover_capability_matches_behavior(name):
    ec = E.get_engine(name).config_cls()
    sel = CraigSelector(
        CraigConfig(mode="cover", epsilon=1e9, engine=ec, per_class=False)
    )
    feats = _feats(40, 4)
    if E.get_engine(name).capabilities.supports_cover:
        cs = sel.select(feats)  # huge ε: one medoid suffices
        assert cs.size >= 1
    else:
        with pytest.raises(ValueError, match="cover"):
            sel.select(feats)


@pytest.mark.parametrize("name", ALL_ENGINES)
def test_jit_safety_capability_matches_behavior(name):
    """Engines advertising jit_safe must trace end to end under jax.jit."""
    eng = E.make_engine(E.get_engine(name).config_cls())
    feats = jnp.asarray(_feats(48, 5, seed=3))
    if eng.capabilities.jit_safe:
        idx = jax.jit(lambda f: eng.select(f, 6, rng=0).indices)(feats)
        eager = eng.select(feats, 6, rng=0).indices
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(eager))
    else:
        # host-side engines still satisfy the protocol eagerly
        res = eng.select(feats, 6)
        assert len(np.unique(np.asarray(res.indices))) == 6


@pytest.mark.parametrize("name", ALL_ENGINES)
def test_metric_capability_accepts_cosine(name):
    caps = E.get_engine(name).capabilities
    assert "cosine" in caps.supports_metrics
    assert caps.memory(10_000, 32) > 0


def test_unsupported_metric_rejected_via_capabilities():
    with pytest.raises(ValueError, match="metric|manhattan"):
        CraigSelector(
            CraigConfig(
                engine=E.MatrixConfig(), metric="manhattan", per_class=False
            )
        ).select(_feats(20, 3))


def test_cosine_parity_matrix_vs_matrix_free_engines():
    """Satellite: cosine on the matrix-free engines (l2 on unit-normalized
    features, monotone-equivalent ordering) recovers the same cluster
    structure as the dense matrix engine's native cosine matrix."""
    rng = np.random.RandomState(7)
    centers = rng.randn(6, 8).astype(np.float32)
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    assign = np.arange(120) % 6
    feats = (centers[assign] + 0.02 * rng.randn(120, 8)).astype(np.float32)

    def clusters(cs):
        return sorted(assign[np.asarray(cs.indices)].tolist())

    dist_cos = np.asarray(
        E.pairwise_distances(jnp.asarray(feats), "cosine")
    )

    def cosine_l(cs) -> float:
        """L(S) = Σ_i min_{j∈S} (1 − cos θ_ij) for cs's own selection."""
        return float(dist_cos[:, np.asarray(cs.indices)].min(axis=1).sum())

    ref = CraigSelector(
        CraigConfig(
            fraction=6 / 120, engine=E.MatrixConfig(), metric="cosine",
            per_class=False,
        )
    ).select(feats)
    assert len(set(clusters(ref))) == 6
    assert ref.coverage == pytest.approx(cosine_l(ref), rel=1e-3)
    for ec in (E.FeaturesConfig(), E.DeviceConfig(), E.SparseConfig(k=120)):
        cs = CraigSelector(
            CraigConfig(
                fraction=6 / 120, engine=ec, metric="cosine", per_class=False
            )
        ).select(feats)
        assert clusters(cs) == clusters(ref), ec.name
        # coverage is reported in the dense engines' cosine-distance units
        # (Σ min 1−cosθ) regardless of engine — engine='auto' crossing a
        # pool-size threshold must not change ε̂ units
        assert cs.coverage == pytest.approx(cosine_l(cs), rel=1e-3), ec.name


# -- legacy shims -------------------------------------------------------------


@pytest.mark.parametrize(
    "engine,knobs,expected",
    [
        ("matrix", {}, E.MatrixConfig()),
        ("lazy", {}, E.LazyConfig()),
        ("stochastic", {"stochastic_delta": 0.1}, E.StochasticConfig(delta=0.1)),
        ("features", {"gains_impl": "pallas"},
         E.FeaturesConfig(gains_impl="pallas")),
        ("sparse", {"topk_k": 32, "topk_impl": "pallas"},
         E.SparseConfig(k=32, impl="pallas")),
        ("device",
         {"device_q": 8, "device_stale_tol": 0.9,
          "device_tile_dtype": "bfloat16"},
         E.DeviceConfig(q=8, stale_tol=0.9, tile_dtype="bfloat16",
                        gains_impl="jax")),
        ("device", {}, E.DeviceConfig(gains_impl="jax")),
        ("sparse", {}, E.SparseConfig()),
        ("stochastic", {}, E.StochasticConfig()),
        ("features", {}, E.FeaturesConfig()),
    ],
)
def test_legacy_string_maps_with_single_deprecation_warning(
    engine, knobs, expected
):
    cfg = CraigConfig(engine=engine, per_class=False, **knobs)
    with pytest.warns(DeprecationWarning) as record:
        resolved = resolve_engine_config(cfg)
    assert len(record) == 1
    assert "README" in str(record[0].message)
    assert resolved == expected


def test_typed_config_and_auto_resolve_without_warning():
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert resolve_engine_config(
            CraigConfig(engine=E.SparseConfig(k=8))
        ) == E.SparseConfig(k=8)
        assert resolve_engine_config(CraigConfig()) is None  # 'auto'


def test_unknown_engine_string_raises():
    with pytest.raises(ValueError, match="unknown engine"):
        resolve_engine_config(CraigConfig(engine="quantum"))


def test_legacy_and_typed_selections_identical():
    """Acceptance: legacy strings and typed configs drive bit-identical
    selections on fixed seeds."""
    feats = _feats(100, 8, seed=11)
    pairs = [
        ("matrix", {}, E.MatrixConfig()),
        ("lazy", {}, E.LazyConfig()),
        ("stochastic", {"stochastic_delta": 0.05},
         E.StochasticConfig(delta=0.05)),
        ("features", {}, E.FeaturesConfig()),
        ("sparse", {"topk_k": 24}, E.SparseConfig(k=24)),
        ("device", {"device_q": 4}, E.DeviceConfig(q=4, gains_impl="jax")),
    ]
    for engine, knobs, typed in pairs:
        with pytest.warns(DeprecationWarning):
            old = CraigSelector(
                CraigConfig(fraction=0.1, engine=engine, per_class=False,
                            seed=3, **knobs)
            ).select(feats)
        new = CraigSelector(
            CraigConfig(fraction=0.1, engine=typed, per_class=False, seed=3)
        ).select(feats)
        np.testing.assert_array_equal(old.indices, new.indices, err_msg=engine)
        np.testing.assert_allclose(old.weights, new.weights, err_msg=engine)


# -- EngineConfig serialization -----------------------------------------------


@pytest.mark.parametrize(
    "ec",
    [
        E.MatrixConfig(),
        E.LazyConfig(),
        E.StochasticConfig(delta=0.2),
        E.FeaturesConfig(gains_impl="pallas", block_n=256),
        E.SparseConfig(k=17, impl="pallas", block_m=512),
        E.DeviceConfig(q=16, stale_tol=1.0, tile_dtype="bfloat16"),
        E.StreamingConfig(eps=0.1, levels=24),
    ],
)
def test_engine_config_dict_round_trip(ec):
    d = ec.to_dict()
    assert d["name"] == type(ec).name
    import json

    assert json.loads(json.dumps(d)) == d  # JSON-able (checkpoint metadata)
    assert E.EngineConfig.from_dict(d) == ec
    assert E.engine_config_from_dict(d) == ec


def test_parse_engine_spec():
    assert E.parse_engine_spec("matrix") == E.MatrixConfig()
    assert E.parse_engine_spec("device:q=16,stale_tol=0.8") == E.DeviceConfig(
        q=16, stale_tol=0.8
    )
    assert E.parse_engine_spec("sparse:k=8,impl=pallas") == E.SparseConfig(
        k=8, impl="pallas"
    )
    with pytest.raises(ValueError, match="unknown engine"):
        E.parse_engine_spec("warp:q=1")
    with pytest.raises(ValueError, match="key=value"):
        E.parse_engine_spec("device:q")


# -- engine='auto' policy -----------------------------------------------------


@pytest.mark.parametrize(
    "n,backend,mode,expected",
    [
        (100, "cpu", "budget", "matrix"),
        (100, "tpu", "budget", "matrix"),
        (20_000, "cpu", "budget", "matrix"),
        (50_000, "cpu", "budget", "features"),
        (50_000, "gpu", "budget", "features"),
        (50_000, "tpu", "budget", "device"),
        (200_000, "tpu", "budget", "device"),
        (300_000, "cpu", "budget", "sparse"),
        (300_000, "tpu", "budget", "sparse"),
        (50_000, "cpu", "cover", "matrix"),
        (300_000, "tpu", "cover", "matrix"),
    ],
)
def test_auto_policy_table(n, backend, mode, expected):
    ec = E.auto_engine_config(n, backend=backend, mode=mode)
    assert ec.name == expected
    assert ec == E.get_engine(expected).config_cls()  # defaults, no knobs


def test_auto_default_selects_like_matrix_on_small_pools():
    """CraigConfig's default engine='auto' resolves to the dense exact
    greedy for small pools — no warning, bit-identical selections."""
    feats = _feats(90, 6, seed=2)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        auto = CraigSelector(
            CraigConfig(fraction=0.1, per_class=False)
        ).select(feats)
    ref = CraigSelector(
        CraigConfig(fraction=0.1, engine=E.MatrixConfig(), per_class=False)
    ).select(feats)
    np.testing.assert_array_equal(auto.indices, ref.indices)
    assert auto.engine == {"name": "matrix"}


def test_selector_resolve_engine_exposed():
    sel = CraigSelector(CraigConfig(per_class=False))
    assert sel.resolve_engine(500).name == "matrix"
    assert sel.resolve_engine(50_000).name in ("features", "device")
    assert sel.resolve_engine(10**6).name == "sparse"


def test_auto_per_class_keys_on_largest_class():
    """Per-class selection runs one greedy per class, so engine='auto'
    must key on the largest class pool, not the pool union — a pool past
    the dense threshold made of small classes stays on exact greedy."""
    n = 25_000  # > DENSE_MAX_N, but the largest class is only 500 points
    labels = np.arange(n) % 50
    feats = (
        np.random.RandomState(0).randn(50, 6)[labels]
        + 0.1 * np.random.RandomState(1).randn(n, 6)
    ).astype(np.float32)
    cs = CraigSelector(
        CraigConfig(fraction=100 / n, per_class=True)
    ).select(feats, labels=labels)
    assert cs.engine == {"name": "matrix"}
    assert cs.size == 100
    assert cs.weights.sum() == pytest.approx(float(n))


def test_stray_flat_knobs_with_typed_or_auto_warn():
    """Half-migrated configs: flat knobs alongside a typed config or
    'auto' have nothing to attach to — ignored with a loud warning."""
    with pytest.warns(UserWarning, match="ignores the legacy flat"):
        ec = resolve_engine_config(
            CraigConfig(engine=E.SparseConfig(), topk_k=128)
        )
    assert ec == E.SparseConfig()  # the typed config wins unchanged
    with pytest.warns(UserWarning, match="device_q"):
        assert resolve_engine_config(CraigConfig(device_q=16)) is None


def test_craig_config_is_keyword_only():
    """Inheriting the legacy knobs would silently re-order positional
    fields; kw_only makes positional construction a loud error instead."""
    with pytest.raises(TypeError):
        CraigConfig("cover")


def test_round1_config_pins_gains_impl():
    """Distributed round-1 bodies run the jnp sweep: configs are pinned so
    stamped provenance records the real execution path — explicit 'pallas'
    warns, the 'auto' default pins silently, 'jax' passes through."""
    from repro.core.distributed import normalize_round1_config

    with pytest.warns(UserWarning, match="pinned"):
        ec = normalize_round1_config(E.DeviceConfig(q=4, gains_impl="pallas"))
    assert ec.gains_impl == "jax" and ec.q == 4
    with pytest.warns(UserWarning, match="pinned"):
        sp = normalize_round1_config(E.SparseConfig(k=9, impl="pallas"))
    assert sp == E.SparseConfig(k=9, impl="jax")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        dv = normalize_round1_config(
            E.DeviceConfig(tile_dtype="bfloat16")  # 'auto' pinned silently
        )
        assert dv.gains_impl == "jax" and dv.tile_dtype == "bfloat16"
        assert normalize_round1_config(
            E.FeaturesConfig()
        ) == E.FeaturesConfig()
        assert normalize_round1_config(E.MatrixConfig()) == E.MatrixConfig()
        assert normalize_round1_config(E.SparseConfig(k=9)) == E.SparseConfig(k=9)
