"""Activation annotation layer: no-mesh no-op, axis resolution, strictness."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.annotate import constrain, mesh_context, set_mesh
from repro.launch.mesh import make_host_mesh


def test_noop_without_mesh():
    set_mesh(None)
    x = jnp.ones((4, 8))
    y = constrain(x, "batch", "tp")
    assert y is x


def test_mesh_context_restores():
    mesh = make_host_mesh()
    set_mesh(None)
    with mesh_context(mesh):
        x = constrain(jnp.ones((4, 8)), "batch", None)
        assert x.shape == (4, 8)
    # restored
    y = constrain(jnp.ones((2, 2)), "batch", "tp")
    assert y.shape == (2, 2)


def test_strict_vs_padded():
    mesh = make_host_mesh()  # sizes 1 → everything divisible; just smoke
    with mesh_context(mesh):
        x = jnp.ones((3, 5))
        a = constrain(x, "batch", "tp")
        b = constrain(x, "batch", "tp", strict=True)
        assert a.shape == b.shape == (3, 5)


def test_dp_over_model_resolution():
    mesh = make_host_mesh()
    set_mesh(mesh, dp_over_model=True)
    x = constrain(jnp.ones((4, 4)), "batch", "tp")
    assert x.shape == (4, 4)
    set_mesh(None)
