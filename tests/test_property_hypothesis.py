"""Property-based tests (hypothesis) for the system's submodular core."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="pip install -r requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import facility_location as fl

_settings = settings(max_examples=25, deadline=None)


def _random_sim(n, seed):
    rng = np.random.RandomState(seed)
    feats = rng.randn(n, 4).astype(np.float32)
    d = np.sqrt(
        np.maximum(
            (feats**2).sum(1)[:, None]
            + (feats**2).sum(1)[None, :]
            - 2 * feats @ feats.T,
            0,
        )
    )
    return jnp.asarray(d.max() + 1e-6 - d)


def _F(sim, subset):
    mask = jnp.zeros((sim.shape[0],), bool)
    for e in subset:
        mask = mask.at[int(e)].set(True)
    return float(fl.facility_location_value(sim, mask))


@_settings
@given(
    n=st.integers(8, 24),
    seed=st.integers(0, 100),
    data=st.data(),
)
def test_submodularity_diminishing_returns(n, seed, data):
    """F(S∪e) − F(S) ≥ F(T∪e) − F(T) for S ⊆ T, e ∉ T."""
    sim = _random_sim(n, seed)
    t_size = data.draw(st.integers(2, n - 2))
    T = data.draw(
        st.lists(st.integers(0, n - 1), min_size=t_size, max_size=t_size, unique=True)
    )
    s_size = data.draw(st.integers(1, len(T) - 1)) if len(T) > 1 else 1
    S = T[:s_size]
    e = data.draw(st.integers(0, n - 1).filter(lambda x: x not in T))
    gain_S = _F(sim, S + [e]) - _F(sim, S)
    gain_T = _F(sim, T + [e]) - _F(sim, T)
    assert gain_S >= gain_T - 1e-3


@_settings
@given(n=st.integers(8, 24), seed=st.integers(0, 100), data=st.data())
def test_monotonicity(n, seed, data):
    """F(S ∪ e) ≥ F(S)."""
    sim = _random_sim(n, seed)
    size = data.draw(st.integers(1, n - 2))
    S = data.draw(
        st.lists(st.integers(0, n - 1), min_size=size, max_size=size, unique=True)
    )
    e = data.draw(st.integers(0, n - 1).filter(lambda x: x not in S))
    assert _F(sim, S + [e]) >= _F(sim, S) - 1e-4


@_settings
@given(n=st.integers(6, 12), seed=st.integers(0, 50), r=st.integers(1, 3))
def test_greedy_achieves_1_minus_1_over_e(n, seed, r):
    """Nemhauser bound: F(greedy_r) ≥ (1 − 1/e)·F(OPT_r), OPT by brute force."""
    import itertools

    sim = _random_sim(n, seed)
    res = fl.greedy_fl_matrix(sim, r)
    f_greedy = _F(sim, list(np.asarray(res.indices)))
    f_opt = max(_F(sim, list(c)) for c in itertools.combinations(range(n), r))
    assert f_greedy >= (1 - 1 / np.e) * f_opt - 1e-3


@_settings
@given(n=st.integers(8, 40), seed=st.integers(0, 100), r=st.integers(1, 8))
def test_weights_partition_the_pool(n, seed, r):
    """γ is a partition histogram: Σγ = n, γ_j ≥ 0 (paper Alg. 1 line 8)."""
    sim = _random_sim(n, seed)
    res = fl.greedy_fl_matrix(sim, min(r, n))
    w = np.asarray(res.weights)
    assert w.sum() == float(n)
    assert (w >= 0).all()


@settings(max_examples=10, deadline=None, derandomize=True)
@given(n=st.sampled_from([6, 9, 13]), seed=st.integers(0, 100), data=st.data())
def test_all_engines_equivalent_at_full_k(n, seed, data):
    """Engine equivalence (DESIGN.md §3): with the graph at k = n and the
    stochastic sample at its δ→0 limit, every engine — dense matrix, lazy,
    stochastic, features, sparse (host), topk (JAX), device (q=1) — is exact
    greedy: identical selections, unique indices, non-increasing gains, and
    Σγ == n."""
    r = data.draw(st.integers(1, n))
    rng = np.random.RandomState(seed)
    feats = jnp.asarray(rng.randn(n, 4).astype(np.float32))
    from repro.core.craig import pairwise_distances

    dist = pairwise_distances(feats)
    sim = jnp.max(dist) + 1e-6 - dist
    base = fl.greedy_fl_matrix(sim, r)
    vals, idx = fl.topk_graph(feats, n)
    results = {
        "matrix": base,
        "lazy": fl.lazy_greedy_fl(np.asarray(sim), r),
        "stochastic": fl.stochastic_greedy_fl(
            sim, r, jax.random.PRNGKey(0), n
        ),
        "features": fl.greedy_fl_features(feats, r),
        "topk": fl.greedy_fl_topk(vals, idx, r),
        "sparse": fl.sparse_greedy_fl(
            np.asarray(vals), np.asarray(idx), r, feats=np.asarray(feats)
        ),
        "device": fl.greedy_fl_device(feats, r, q=1),
    }
    base_idx = np.asarray(base.indices)
    for name, res in results.items():
        sel = np.asarray(res.indices)
        np.testing.assert_array_equal(base_idx, sel, err_msg=name)
        assert len(np.unique(sel)) == r, name
        g = np.asarray(res.gains)
        assert np.all(g[:-1] >= g[1:] - 1e-3), (name, g)
        assert float(np.asarray(res.weights).sum()) == pytest.approx(
            float(n), rel=1e-5
        ), name


@_settings
@given(n=st.integers(8, 30), seed=st.integers(0, 100))
def test_full_budget_zero_coverage(n, seed):
    """Selecting everything drives L(S) to 0 (every point is its own medoid)."""
    rng = np.random.RandomState(seed)
    feats = jnp.asarray(rng.randn(n, 4).astype(np.float32))
    res = fl.greedy_fl_features(feats, n)
    assert float(res.coverage) <= 1e-3 * n
