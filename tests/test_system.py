"""End-to-end behaviour of the paper's system: select → weighted-train →
evaluate, on both the convex path and the LM path.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.craig import CraigConfig, CraigSelector
from repro.data.synthetic import TokenStream
from repro.models import ModelConfig, init_params, loss_fn
from repro.optim import adamw, constant
from repro.train import Trainer, TrainerConfig

import pytest

pytestmark = pytest.mark.tier2  # end-to-end pipelines, >10 s each


def test_lm_craig_pipeline_beats_random_subset():
    """Same-budget comparison on a tiny LM: training on the CRAIG coreset
    reaches lower full-pool loss than training on a random coreset."""
    cfg = ModelConfig(
        name="tiny", family="dense", n_layers=2, d_model=32, n_heads=2,
        n_kv_heads=2, d_ff=64, vocab_size=128, logit_chunk=16,
    )
    ds = TokenStream(n_docs=64, seq_len=24, vocab_size=128, n_topics=4)

    def full_pool_loss(params):
        tot = 0.0
        for lo in range(0, 64, 16):
            batch = ds.batch(np.arange(lo, lo + 16))
            _, m = loss_fn(params, cfg, batch)
            tot += float(m["loss"])
        return tot / 4

    def run(use_craig, seed):
        tcfg = TrainerConfig(
            batch_size=8,
            select_every_epochs=1 if use_craig else 0,
            use_craig=use_craig,
            craig=CraigConfig(fraction=0.25, per_class=False),
            proxy_pool_batches=8,
        )
        t = Trainer(cfg, tcfg, ds, adamw(constant(3e-3)),
                    lambda: init_params(jax.random.PRNGKey(seed), cfg))
        if not use_craig:
            # random quarter of the corpus, uniform weights
            rng = np.random.RandomState(seed)
            idx = rng.choice(64, 16, replace=False)
            t.sampler.set_coreset(idx, np.ones(16, np.float32))
        t.run(16)
        return full_pool_loss(t.params)

    loss_craig = run(True, 0)
    loss_rand = np.mean([run(False, s) for s in (0, 1)])
    assert loss_craig < loss_rand * 1.05, (loss_craig, loss_rand)


def test_selector_scales_to_pool():
    """Selection on a 2k-example pool completes and keeps invariants."""
    feats = np.random.RandomState(0).randn(2048, 32).astype(np.float32)
    sel = CraigSelector(CraigConfig(fraction=0.05, engine="stochastic",
                                    per_class=False))
    cs = sel.select(feats)
    assert cs.size == 102
    assert cs.weights.sum() == 2048.0
