"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,m,d", [(64, 32, 8), (300, 150, 37), (513, 100, 130), (128, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairwise_l2(n, m, d, dtype):
    kx, ky = jax.random.split(jax.random.PRNGKey(n + m))
    x = jax.random.normal(kx, (n, d), dtype)
    y = jax.random.normal(ky, (m, d), dtype)
    got = ops.pairwise_l2(x, y)
    want = ref.pairwise_l2_ref(x, y)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("n,m,d", [(64, 64, 16), (250, 90, 33), (512, 256, 128), (80, 300, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fl_gains(n, m, d, dtype):
    keys = jax.random.split(jax.random.PRNGKey(n * 3 + m), 3)
    x = jax.random.normal(keys[0], (n, d), dtype)
    e = jax.random.normal(keys[1], (m, d), dtype)
    cur_max = jax.random.uniform(keys[2], (n,), jnp.float32, 0.0, 3.0)
    d_max = jnp.float32(12.0)
    x32, e32 = x.astype(jnp.float32), e.astype(jnp.float32)
    got = ops.fl_gains(
        x32, e32, cur_max, jnp.sum(x32 * x32, 1), jnp.sum(e32 * e32, 1), d_max
    )
    want = ref.fl_gains_ref(x32, e32, cur_max, d_max)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


@pytest.mark.parametrize(
    "t,d,v,bt,bv",
    [
        (32, 16, 64, 16, 16),
        (70, 33, 96, 32, 32),
        (128, 64, 512, 64, 128),
        (16, 8, 1000, 16, 8),  # block_v fallback: 1000 % 8 == 0
    ],
)
def test_ce_proxy(t, d, v, bt, bv):
    keys = jax.random.split(jax.random.PRNGKey(t + v), 3)
    h = jax.random.normal(keys[0], (t, d)) * 0.5
    w = jax.random.normal(keys[1], (d, v)) * 0.1
    y = jax.random.randint(keys[2], (t,), 0, v)
    got = ops.ce_proxy(h, w, y, block_t=bt, block_v=bv)
    want = ref.ce_proxy_ref(h, w, y)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_ce_proxy_bf16_hidden():
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    h = (jax.random.normal(keys[0], (64, 32)) * 0.5).astype(jnp.bfloat16)
    w = jax.random.normal(keys[1], (32, 128)) * 0.1
    y = jax.random.randint(keys[2], (64,), 0, 128)
    got = ops.ce_proxy(h, w, y, block_t=32, block_v=32)
    want = ref.ce_proxy_ref(h, w, y)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-3)


def test_fl_gains_inside_greedy_matches_matrix_engine():
    """End-to-end: the Pallas gains path yields identical greedy selections."""
    from repro.core import facility_location as fl

    feats = jax.random.normal(jax.random.PRNGKey(5), (200, 24))
    r_jax = fl.greedy_fl_features(feats, 16, gains_impl="jax")
    r_pal = fl.greedy_fl_features(feats, 16, gains_impl="pallas")
    np.testing.assert_array_equal(
        np.asarray(r_jax.indices), np.asarray(r_pal.indices)
    )
    np.testing.assert_allclose(
        np.asarray(r_jax.weights), np.asarray(r_pal.weights)
    )
