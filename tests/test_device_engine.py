"""Device-resident fused greedy engine (DESIGN.md §3.6): parity, padding
contract, block-greedy invariants, and selector wiring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import facility_location as fl
from repro.core.craig import CraigConfig, CraigSelector
from repro.kernels import ops, ref

# Pool sizes that are NOT lane/block multiples — the DESIGN.md §2 "padding
# must be inert" rule must hold at every awkward shape.
PADDING_SIZES = (1, 7, 129, 1000)


def _feats(n, d=8, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d))


def _ref_greedy(feats, budget):
    """Reference greedy driven by the pure-jnp kernel oracle (kernels/ref.py)."""
    feats = jnp.asarray(feats, jnp.float32)
    n = feats.shape[0]
    sq = jnp.sum(feats * feats, axis=1)
    d_max = 2.0 * jnp.sqrt(jnp.max(sq)) + 1e-6
    cur_max = jnp.zeros((n,), jnp.float32)
    chosen = np.zeros(n, bool)
    indices = []
    for _ in range(budget):
        gains = np.array(ref.fl_gains_ref(feats, feats, cur_max, d_max))
        gains[chosen] = -np.inf
        e = int(np.argmax(gains))
        indices.append(e)
        chosen[e] = True
        sim_e = d_max - ref.pairwise_l2_ref(feats, feats[e][None])[:, 0]
        cur_max = jnp.maximum(cur_max, sim_e)
    return np.array(indices)


# -- exactness at q=1 ---------------------------------------------------------


@pytest.mark.parametrize("gains_impl", ["jax", "pallas"])
def test_device_q1_equals_matrix_engine(gains_impl):
    feats = _feats(120)
    from repro.core.craig import pairwise_distances

    dist = pairwise_distances(feats)
    sim = jnp.max(dist) + 1e-6 - dist
    r1 = fl.greedy_fl_matrix(sim, 15)
    r2 = fl.greedy_fl_device(feats, 15, q=1, gains_impl=gains_impl)
    np.testing.assert_array_equal(np.asarray(r1.indices), np.asarray(r2.indices))
    np.testing.assert_allclose(
        np.asarray(r1.weights), np.asarray(r2.weights)
    )


def test_device_equals_features_engine():
    feats = _feats(200, d=16, seed=3)
    r1 = fl.greedy_fl_features(feats, 25, gains_impl="jax")
    r2 = fl.greedy_fl_device(feats, 25)
    np.testing.assert_array_equal(np.asarray(r1.indices), np.asarray(r2.indices))
    np.testing.assert_allclose(
        np.asarray(r1.gains), np.asarray(r2.gains), rtol=2e-3, atol=1e-2
    )
    np.testing.assert_allclose(
        np.asarray(r1.coverage), np.asarray(r2.coverage), rtol=1e-4
    )


# -- padding contract (DESIGN.md §2): non-multiple pool sizes -----------------


@pytest.mark.parametrize("n", PADDING_SIZES)
def test_fl_gains_pallas_padding_inert(n):
    """fl_gains at non-block-multiple shapes: bit-identical winner vs the
    kernels/ref.py oracle, gains allclose."""
    feats = _feats(n, d=5, seed=n)
    x = feats.astype(jnp.float32)
    sq = jnp.sum(x * x, axis=1)
    d_max = 2.0 * jnp.sqrt(jnp.max(sq)) + 1e-6
    cur_max = jax.random.uniform(jax.random.PRNGKey(n + 1), (n,), maxval=2.0)
    got = np.asarray(ops.fl_gains(x, x, cur_max, sq, sq, d_max))
    want = np.asarray(ref.fl_gains_ref(x, x, cur_max, d_max))
    assert got.shape == (n,)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)
    assert int(np.argmax(got)) == int(np.argmax(want))


@pytest.mark.parametrize("n", PADDING_SIZES)
def test_fl_gains_argmax_padding_inert(n):
    """The fused argmax partials never let a padded/chosen column win."""
    feats = _feats(n, d=5, seed=n)
    x = feats.astype(jnp.float32)
    sq = jnp.sum(x * x, axis=1)
    d_max = 2.0 * jnp.sqrt(jnp.max(sq)) + 1e-6
    cur_max = jnp.zeros((n,), jnp.float32)
    chosen = jnp.zeros((n,), bool).at[0].set(n > 1)
    g, pg, pi = ops.fl_gains_argmax(x, x, cur_max, sq, sq, d_max, chosen)
    g, pg, pi = np.asarray(g), np.asarray(pg), np.asarray(pi)
    live = pg > -1e29
    assert live.any()
    blk = int(np.argmax(np.where(live, pg, -np.inf)))
    win = int(pi[blk])
    want = np.array(ref.fl_gains_ref(x, x, cur_max, d_max))
    np.testing.assert_allclose(g, want, rtol=2e-4, atol=2e-3)  # full vector
    want[np.asarray(chosen)] = -np.inf
    assert win == int(np.argmax(want))
    assert win < n  # padding can never win
    np.testing.assert_allclose(pg[blk], want[win], rtol=2e-4, atol=2e-3)


@pytest.mark.parametrize("n", PADDING_SIZES)
@pytest.mark.parametrize("gains_impl", ["jax", "pallas"])
def test_device_padding_sizes_match_reference_greedy(n, gains_impl):
    """greedy_fl_device winners at awkward n: bit-identical to the reference
    greedy driven by kernels/ref.py gains."""
    budget = min(n, 5)
    feats = _feats(n, d=5, seed=n)
    want = _ref_greedy(feats, budget)
    res = fl.greedy_fl_device(feats, budget, q=1, gains_impl=gains_impl)
    np.testing.assert_array_equal(np.asarray(res.indices), want)
    assert float(res.weights.sum()) == pytest.approx(float(n))


# -- warm start ---------------------------------------------------------------


@pytest.mark.parametrize("prefix", [1, 4, 9])
def test_warm_start_matches_cold_device(prefix):
    """Prefix consistency, same guarantee the other five engines test."""
    feats = _feats(90, d=6, seed=7)
    cold = fl.greedy_fl_device(feats, 12)
    warm = fl.greedy_fl_device(
        feats, 12, init_selected=cold.indices[:prefix]
    )
    np.testing.assert_array_equal(
        np.asarray(cold.indices), np.asarray(warm.indices)
    )
    np.testing.assert_allclose(
        np.asarray(cold.gains), np.asarray(warm.gains), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(cold.weights), np.asarray(warm.weights)
    )


def test_warm_start_full_budget_device():
    feats = _feats(40, seed=11)
    cold = fl.greedy_fl_device(feats, 6)
    warm = fl.greedy_fl_device(feats, 6, init_selected=cold.indices)
    np.testing.assert_array_equal(
        np.asarray(cold.indices), np.asarray(warm.indices)
    )


# -- block greedy (q > 1) -----------------------------------------------------


@pytest.mark.parametrize("q", [2, 4, 16])
def test_block_greedy_invariants(q):
    """q>1: unique indices, full budget, Σγ == n, near-exact coverage."""
    feats = _feats(256, d=8, seed=5)
    exact = fl.greedy_fl_device(feats, 32, q=1)
    blocked = fl.greedy_fl_device(feats, 32, q=q)
    idx = np.asarray(blocked.indices)
    assert len(np.unique(idx)) == 32
    assert float(blocked.weights.sum()) == pytest.approx(256.0)
    # re-checked winners keep coverage within a few % of exact greedy
    assert float(blocked.coverage) <= 1.1 * float(exact.coverage) + 1e-6


def test_block_greedy_round_gains_non_increasing_q1():
    feats = _feats(150, seed=9)
    res = fl.greedy_fl_device(feats, 20, q=1)
    g = np.asarray(res.gains)
    assert np.all(g[:-1] >= g[1:] - 1e-4)


def test_bf16_tiles_select_reasonably():
    """bf16 similarity tiles + fp32 accumulation: valid selection, coverage
    close to the fp32 run (bit-parity is not promised for bf16)."""
    feats = _feats(200, d=16, seed=13)
    f32 = fl.greedy_fl_device(feats, 20, q=1)
    b16 = fl.greedy_fl_device(feats, 20, q=1, tile_dtype="bfloat16")
    idx = np.asarray(b16.indices)
    assert len(np.unique(idx)) == 20
    assert float(b16.weights.sum()) == pytest.approx(200.0)
    assert float(b16.coverage) <= 1.25 * float(f32.coverage) + 1e-6


# -- selector wiring ----------------------------------------------------------


@pytest.mark.parametrize("per_class", [False, True])
def test_selector_device_engine(per_class):
    rng = np.random.RandomState(0)
    feats = rng.randn(160, 8).astype(np.float32)
    labels = rng.randint(0, 4, 160)
    sel = CraigSelector(
        CraigConfig(fraction=0.1, engine="device", per_class=per_class)
    )
    cs = sel.select(feats, labels=labels if per_class else None)
    assert cs.size == 16
    assert len(np.unique(cs.indices)) == 16
    assert cs.weights.sum() == pytest.approx(160.0)


def test_selector_device_matches_matrix_engine():
    rng = np.random.RandomState(1)
    feats = rng.randn(128, 8).astype(np.float32)
    a = CraigSelector(
        CraigConfig(fraction=0.1, engine="matrix", per_class=False)
    ).select(feats)
    b = CraigSelector(
        CraigConfig(fraction=0.1, engine="device", per_class=False)
    ).select(feats)
    np.testing.assert_array_equal(a.indices, b.indices)


def test_selector_device_warm_start_parity():
    rng = np.random.RandomState(2)
    feats = rng.randn(140, 8).astype(np.float32)
    sel = CraigSelector(
        CraigConfig(fraction=0.1, engine="device", per_class=False)
    )
    cold = sel.select(feats)
    warm = sel.select(feats, init_selected=cold.indices[:7])
    np.testing.assert_array_equal(cold.indices, warm.indices)


def test_device_engine_rejects_cover():
    feats = np.random.RandomState(3).randn(32, 4).astype(np.float32)
    with pytest.raises(ValueError, match="cover"):
        CraigSelector(
            CraigConfig(engine="device", mode="cover", per_class=False)
        ).select(feats)


def test_device_engine_cosine_matches_features_engine():
    """metric='cosine' is served via l2 on unit-normalized features
    (Capabilities.supports_metrics); device and features run the same exact
    greedy on the normalized pool, so selections are bit-identical."""
    from repro.core.engines import DeviceConfig, FeaturesConfig

    feats = np.random.RandomState(5).randn(120, 8).astype(np.float32)
    dev = CraigSelector(
        CraigConfig(
            fraction=0.1, engine=DeviceConfig(), metric="cosine",
            per_class=False,
        )
    ).select(feats)
    fea = CraigSelector(
        CraigConfig(
            fraction=0.1, engine=FeaturesConfig(), metric="cosine",
            per_class=False,
        )
    ).select(feats)
    np.testing.assert_array_equal(dev.indices, fea.indices)
    assert dev.weights.sum() == pytest.approx(120.0)


def test_device_engine_rejects_bad_impl_and_dtype():
    feats = _feats(16)
    with pytest.raises(ValueError, match="gains_impl"):
        fl.greedy_fl_device(feats, 4, gains_impl="cuda")
    with pytest.raises((ValueError, TypeError)):
        fl.greedy_fl_device(feats, 4, tile_dtype="int8")
