"""Tier-1 gate: ``python -m repro.analysis src/`` runs clean end to end.

This exercises the real CLI (exit codes, JSON report) over the real tree —
any unsuppressed finding introduced by a change fails tier-1 locally with
the same output the CI lint job uploads as an artifact.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent


def _run(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=REPO,
        env=env,
        capture_output=True,
        text=True,
        timeout=300,
    )


def test_src_tree_is_lint_clean():
    proc = _run("src", "--format", "json")
    assert proc.returncode == 0, (
        f"repro-lint found unsuppressed findings:\n{proc.stdout}\n{proc.stderr}"
    )
    report = json.loads(proc.stdout)
    assert report["counts"]["active"] == 0
    assert report["exit_code"] == 0


def test_list_rules_covers_all_passes():
    proc = _run("--list-rules")
    assert proc.returncode == 0
    listed = {line.split(":")[0] for line in proc.stdout.splitlines() if line}
    for rid in (
        "jit-host-sync",
        "pallas-index-map-arity",
        "pallas-kernel-arity",
        "pallas-accumulator-dtype",
        "pallas-dot-preferred-type",
        "lock-discipline",
        "thread-join",
        "thread-failure-propagation",
        "flat-engine-knob",
        "forbidden-import",
        "engine-capabilities",
    ):
        assert rid in listed, f"rule {rid} missing from --list-rules"


def test_unknown_rule_is_usage_error():
    proc = _run("src", "--rules", "no-such-rule")
    assert proc.returncode == 2
    assert "unknown rule" in proc.stderr


def test_findings_exit_code_is_one(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text(
        "import jax\n\n@jax.jit\ndef f(x):\n    return x.item()\n"
    )
    proc = _run(str(bad), "--format", "json")
    assert proc.returncode == 1
    report = json.loads(proc.stdout)
    assert report["counts"]["active"] >= 1
