"""Data pipeline: determinism, coreset batches, restart exactness."""
import numpy as np
import pytest

from repro.data import CoresetSampler, GlobalBatcher, Prefetcher, TokenStream
from repro.data.synthetic import make_classification


def test_token_stream_deterministic():
    ds = TokenStream(n_docs=16, seq_len=32, vocab_size=100, seed=7)
    a1, b1 = ds.example(3)
    a2, b2 = ds.example(3)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(b1, b2)
    # labels are next-token shifted
    np.testing.assert_array_equal(a1[1:], b1[:-1])


def test_topic_structure_exists():
    ds = TokenStream(n_docs=32, seq_len=256, vocab_size=64, n_topics=4, seed=0)
    # same-topic docs share token distribution more than cross-topic
    def hist(i):
        t, _ = ds.example(i)
        h = np.bincount(t, minlength=64).astype(float)
        return h / h.sum()

    same = np.abs(hist(0) - hist(4)).sum()  # topics 0,0
    diff = np.abs(hist(0) - hist(1)).sum()  # topics 0,1
    assert same < diff


def test_sampler_epoch_coverage():
    s = CoresetSampler(n=40, batch=8, seed=0)
    seen = []
    for _ in range(s.steps_per_epoch):
        idx, w = s.next_batch()
        seen.extend(idx.tolist())
        assert (w == 1.0).all()
    assert sorted(seen) == list(range(40))
    assert s.epoch == 1


def test_sampler_coreset_weights():
    s = CoresetSampler(n=100, batch=5, seed=0)
    idx = np.array([3, 10, 50, 99, 7])
    w = np.array([30, 20, 25, 15, 10], np.float32)
    s.set_coreset(idx, w)
    got_i, got_w = s.next_batch()
    assert set(got_i).issubset(set(idx.tolist()))
    # weights normalized so an epoch over the coreset has mean weight 1
    scale = len(w) / w.sum()
    assert got_w.min() > 0
    norm_w = {i: ww * scale for i, ww in zip(idx, w)}
    for i, ww in zip(got_i, got_w):
        assert ww == pytest.approx(norm_w[int(i)], rel=1e-5)


def test_sampler_state_roundtrip():
    s1 = CoresetSampler(n=30, batch=4, seed=1)
    s1.set_coreset(np.arange(0, 30, 2), np.ones(15, np.float32) * 2)
    for _ in range(5):
        s1.next_batch()
    state = s1.state_dict()

    s2 = CoresetSampler(n=30, batch=4, seed=1)
    s2.load_state_dict(state)
    for _ in range(4):
        i1, w1 = s1.next_batch()
        i2, w2 = s2.next_batch()
        np.testing.assert_array_equal(i1, i2)
        np.testing.assert_array_equal(w1, w2)


def test_sampler_versions_track_installs():
    s = CoresetSampler(n=20, batch=4, seed=0)
    assert s.version == 0  # full data
    s.set_coreset(np.arange(10), np.ones(10, np.float32))
    assert s.version == 1
    s.stage(np.arange(0, 20, 2), np.ones(10, np.float32))
    s.install_pending()
    assert s.version == 2
    s.clear_coreset()
    assert s.version == 0 and not s.has_pending


def test_batcher_and_prefetcher():
    ds = TokenStream(n_docs=16, seq_len=8, vocab_size=32, seed=0)
    s = CoresetSampler(n=16, batch=4, seed=0)
    gb = GlobalBatcher(ds, s)
    pf = Prefetcher(iter(gb), depth=2)
    b = pf.next()
    assert b["tokens"].shape == (4, 8)
    assert b["labels"].shape == (4, 8)
    assert b["weights"].shape == (4,)
    pf.close()


def test_skip_ahead_restart_equivalence():
    """A worker restarted with skip_to sees the identical stream."""
    s1 = CoresetSampler(n=64, batch=8, seed=5)
    stream1 = [s1.next_batch()[0].tolist() for _ in range(20)]

    s2 = CoresetSampler(n=64, batch=8, seed=5)
    s2.skip_to(epoch=1, step_in_epoch=2)  # = step 10
    stream2 = [s2.next_batch()[0].tolist() for _ in range(10)]
    assert stream1[10:] == stream2


def test_make_classification_balanced_modes():
    x, y = make_classification(400, 8, 4, seed=0)
    assert x.shape == (400, 8)
    assert set(np.unique(y)) == {0, 1, 2, 3}
