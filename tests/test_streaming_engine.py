"""Sieve-streaming engine (engines.streaming) + the coreset service.

Engine-level: single-megabatch selection recovers cluster structure at
parity with the features engine, multi-delta ingestion is order-robust,
per-class budgets stratify by *observed* arrival, and the serializable
``StreamingState``/``StreamingSelector`` round-trip bit-identically
mid-stream.  Service-level: versioned staged→installed publishes, async
coalescing, worker-failure surfacing, and (tier 2) a subprocess JSON-lines
round-trip through ``launch/serve.py --coreset``.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engines as E
from repro.core import facility_location as fl
from repro.core.craig import _apportion_budgets, pairwise_distances
from repro.core.engines.streaming import (
    StreamingSelector,
    ingest_delta,
    init_streaming_state,
    num_sieves,
    streaming_result,
    streaming_result_blocked,
)
from repro.serve import CoresetService


def _clusters(n, d, n_clusters, seed, spread=0.25):
    rng = np.random.RandomState(seed)
    centers = rng.randn(n_clusters, d).astype(np.float32) * 6.0
    labels = np.arange(n) % n_clusters
    feats = centers[labels] + spread * rng.randn(n, d).astype(np.float32)
    return feats.astype(np.float32), labels


def _objective(feats, idx):
    dist = np.asarray(pairwise_distances(jnp.asarray(feats)))
    sim = dist.max() + 1e-6 - dist
    mask = np.zeros(len(feats), bool)
    mask[np.asarray(idx)] = True
    return float(fl.facility_location_value(jnp.asarray(sim), jnp.asarray(mask)))


# -- engine: selection quality ------------------------------------------------


def test_one_shot_cluster_parity_with_features_engine():
    """One megabatch at a fine sieve grid: one medoid per well-separated
    cluster, objective at parity with the exact features engine."""
    feats, labels = _clusters(96, 5, 8, seed=0)
    eng = E.make_engine(E.StreamingConfig(eps=0.05, levels=96))
    res = eng.select(jnp.asarray(feats), 8, rng=0)
    idx = np.asarray(res.indices)
    assert sorted(labels[idx]) == list(range(8))  # one per cluster
    exact = E.make_engine(E.FeaturesConfig()).select(jnp.asarray(feats), 8)
    ratio = _objective(feats, idx) / _objective(feats, np.asarray(exact.indices))
    assert ratio >= 0.9, ratio


def test_num_sieves_auto_cap_and_override():
    assert num_sieves(10, 0.15, 32) == 32  # explicit levels win
    assert num_sieves(4, 0.15, 0) >= 4
    assert num_sieves(100_000, 0.01, 0) == 64  # auto grid caps at 64


def test_multi_delta_order_invariance_bounds():
    """Shuffled delta arrival orders land within a tight objective band of
    each other and all clear the streaming gate vs lazy greedy."""
    feats, _ = _clusters(120, 6, 10, seed=1, spread=0.6)
    budget, chunk = 12, 30
    f_lazy = None
    objectives = []
    for perm_seed in (0, 1, 2):
        order = np.random.RandomState(perm_seed).permutation(len(feats))
        sel = StreamingSelector(budget, feats.shape[1])
        for lo in range(0, len(feats), chunk):
            sel.ingest(feats[order[lo : lo + chunk]])
        res = sel.result(feats[order])
        idx = order[np.asarray(res.indices)]  # back to pool coordinates
        assert np.asarray(res.weights).sum() == pytest.approx(float(len(feats)))
        objectives.append(_objective(feats, idx))
    if f_lazy is None:
        dist = np.asarray(pairwise_distances(jnp.asarray(feats)))
        sim = dist.max() + 1e-6 - dist
        f_lazy = _objective(feats, np.asarray(fl.lazy_greedy_fl(sim, budget).indices))
    objectives = np.asarray(objectives)
    assert (objectives >= 0.4 * f_lazy).all(), objectives / f_lazy
    assert objectives.min() >= 0.8 * objectives.max(), objectives


def test_per_class_budgets_follow_observed_arrival():
    """Stratified budgets apportion to class frequencies as *ingested*
    (paper §5), even when one class arrives mostly late."""
    rng = np.random.RandomState(2)
    feats0 = rng.randn(140, 4).astype(np.float32)  # class 0: 70%
    feats1 = 5.0 + rng.randn(60, 4).astype(np.float32)  # class 1: 30%, late
    sel = StreamingSelector(20, 4, per_class=True)
    sel.ingest(feats0[:100], labels=np.zeros(100, np.int64))
    sel.ingest(
        np.concatenate([feats0[100:], feats1]),
        labels=np.concatenate([np.zeros(40), np.ones(60)]).astype(np.int64),
    )
    pool = np.concatenate([feats0[:100], feats0[100:], feats1])
    pool_labels = np.concatenate([np.zeros(140), np.ones(60)]).astype(np.int64)
    res = sel.result(pool)
    idx = np.asarray(res.indices)
    counts = np.bincount(pool_labels[idx], minlength=2)
    expect = _apportion_budgets(np.asarray([140, 60]), 20)
    np.testing.assert_array_equal(counts, expect)  # 14 / 6
    assert np.asarray(res.weights).sum() == pytest.approx(200.0)
    assert len(np.unique(idx)) == 20


def test_streaming_engine_jit_parity():
    """The whole select() path traces under jax.jit (capability jit_safe)."""
    feats = jnp.asarray(np.random.RandomState(3).randn(64, 5).astype(np.float32))
    eng = E.make_engine(E.StreamingConfig())
    eager = eng.select(feats, 9, rng=0)
    jitted = jax.jit(lambda f: eng.select(f, 9, rng=0).indices)(feats)
    np.testing.assert_array_equal(np.asarray(jitted), np.asarray(eager.indices))


# -- blocked finalize (DESIGN.md §10) ----------------------------------------


def _mk_state(feats, budget, chunk=40, prefix=None, eps=0.15):
    st = init_streaming_state(
        budget, feats.shape[1],
        init_selected=prefix,
        init_feats=None if prefix is None else feats[np.asarray(prefix)],
    )
    for lo in range(0, len(feats), chunk):
        hi = min(lo + chunk, len(feats))
        st = ingest_delta(
            st, jnp.asarray(feats[lo:hi]),
            jnp.arange(lo, hi, dtype=jnp.int32), eps,
        )
    return st


@pytest.mark.parametrize("impl", ["jax", "pallas"])
@pytest.mark.parametrize("prefix", [None, [3, 17]])
def test_blocked_finalize_matches_dense(impl, prefix):
    """The blocked replay finalize: exact index/weight parity with the
    dense per-step sweep (including jnp.argmax's lowest-index tie rule),
    gains and coverage to fp tolerance.  'pallas' runs in interpret mode
    off-TPU, so this is the kernel's CI contract too."""
    rng = np.random.RandomState(11)
    feats = rng.randn(120, 5).astype(np.float32)
    st = _mk_state(feats, budget=14, prefix=prefix)
    jf = jnp.asarray(feats)
    ref = streaming_result(st, jf, 14)
    got = streaming_result_blocked(st, jf, 14, impl=impl, block_m=8)
    np.testing.assert_array_equal(np.asarray(ref.indices), np.asarray(got.indices))
    np.testing.assert_array_equal(np.asarray(ref.weights), np.asarray(got.weights))
    np.testing.assert_allclose(
        np.asarray(ref.gains), np.asarray(got.gains), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        float(ref.coverage), float(got.coverage), rtol=1e-4
    )


@pytest.mark.parametrize("impl", ["jax", "pallas"])
def test_blocked_finalize_backfill_parity(impl):
    """When the best sieve holds fewer picks than the budget (here: finalize
    budget above the sieve capacity), the residual backfill suffix must also
    match the dense scan pick for pick."""
    rng = np.random.RandomState(12)
    feats = rng.randn(60, 4).astype(np.float32)
    st = _mk_state(feats, budget=6)  # sieve capacity 6 < finalize budget 10
    best = int(np.argmax(np.asarray(st.fval)))
    assert int(np.asarray(st.count)[best]) < 10  # backfill actually exercised
    jf = jnp.asarray(feats)
    ref = streaming_result(st, jf, 10)
    got = streaming_result_blocked(st, jf, 10, impl=impl)
    np.testing.assert_array_equal(np.asarray(ref.indices), np.asarray(got.indices))
    np.testing.assert_array_equal(np.asarray(ref.weights), np.asarray(got.weights))
    np.testing.assert_allclose(
        float(ref.coverage), float(got.coverage), rtol=1e-4
    )


def test_per_class_single_class_matches_flat():
    """Regression: per-class finalize used to derive each subpool's own
    d_max offset, so a degenerate single-class stratified run disagreed
    with the flat run on identical data.  With one pool-wide offset the
    two are exactly equal."""
    rng = np.random.RandomState(13)
    feats = rng.randn(80, 4).astype(np.float32)
    flat = StreamingSelector(9, 4)
    strat = StreamingSelector(9, 4, per_class=True)
    for lo in range(0, 80, 32):
        d = feats[lo : lo + 32]
        flat.ingest(d)
        strat.ingest(d, labels=np.zeros(len(d), np.int64))
    rf, rs = flat.result(feats), strat.result(feats)
    np.testing.assert_array_equal(np.asarray(rf.indices), np.asarray(rs.indices))
    np.testing.assert_array_equal(np.asarray(rf.weights), np.asarray(rs.weights))
    np.testing.assert_allclose(
        float(rf.coverage), float(rs.coverage), rtol=1e-6
    )


# -- sieve-pool eviction ------------------------------------------------------


def test_eviction_bounds_pool_and_maps_global_ids():
    """evict=True: after every compact() only sieve-referenced rows stay
    live, γ sums to the live count, and live_ids maps finalize indices
    back to global arrival positions (the rows match bit for bit)."""
    rng = np.random.RandomState(14)
    deltas = [rng.randn(100, 6).astype(np.float32) for _ in range(6)]
    sel = StreamingSelector(12, 6, evict=True)
    pool = np.zeros((0, 6), np.float32)
    for d in deltas:
        sel.ingest(d)
        pool = np.concatenate([pool, d])[sel.compact()]
    assert sel.n_seen == 600
    assert sel.n_rows == len(sel.live_ids) == pool.shape[0] < 600
    res = sel.result(pool)
    assert np.asarray(res.weights).sum() == pytest.approx(float(sel.n_rows))
    gids = sel.live_ids[np.asarray(res.indices, np.int64)]
    full = np.concatenate(deltas)
    np.testing.assert_array_equal(full[gids], pool[np.asarray(res.indices)])


def test_evicted_state_dict_resume_bit_identical():
    """Kill-and-resume mid-stream with eviction on: the compacted remap
    (live ids, remapped sel/pre indices) survives a real JSON round-trip
    and continues to the exact selection of the uninterrupted run."""
    rng = np.random.RandomState(15)
    deltas = [rng.randn(60, 4).astype(np.float32) for _ in range(4)]

    def run(selector, pool, ds):
        for d in ds:
            selector.ingest(d)
            pool = np.concatenate([pool, d])[selector.compact()]
        return pool

    a = StreamingSelector(10, 4, evict=True)
    pa = run(a, np.zeros((0, 4), np.float32), deltas)

    b = StreamingSelector(10, 4, evict=True)
    pb = run(b, np.zeros((0, 4), np.float32), deltas[:2])
    snap = json.loads(json.dumps(b.state_dict()))
    c = StreamingSelector(10, 4, evict=True)
    c.load_state_dict(snap)
    pc = run(c, pb, deltas[2:])

    np.testing.assert_array_equal(a.live_ids, c.live_ids)
    ra, rc = a.result(pa), c.result(pc)
    np.testing.assert_array_equal(np.asarray(ra.indices), np.asarray(rc.indices))
    np.testing.assert_array_equal(np.asarray(ra.weights), np.asarray(rc.weights))


# -- state round-trips --------------------------------------------------------


def test_selector_state_dict_resume_bit_identical():
    """Kill-and-resume mid-stream: restore from a JSON round-trip, ingest the
    remaining deltas, and get the exact selection of the uninterrupted run."""
    rng = np.random.RandomState(4)
    deltas = [rng.randn(40, 6).astype(np.float32) for _ in range(4)]
    pool = np.concatenate(deltas)

    a = StreamingSelector(15, 6)
    for d in deltas:
        a.ingest(d)

    b = StreamingSelector(15, 6)
    b.ingest(deltas[0])
    b.ingest(deltas[1])
    snap = json.loads(json.dumps(b.state_dict()))  # through real JSON
    c = StreamingSelector(15, 6)
    c.load_state_dict(snap)
    c.ingest(deltas[2])
    c.ingest(deltas[3])

    ra, rc = a.result(pool), c.result(pool)
    np.testing.assert_array_equal(np.asarray(ra.indices), np.asarray(rc.indices))
    np.testing.assert_array_equal(np.asarray(ra.weights), np.asarray(rc.weights))


def test_per_class_state_dict_round_trip():
    rng = np.random.RandomState(5)
    sel = StreamingSelector(10, 3, per_class=True)
    sel.ingest(rng.randn(50, 3).astype(np.float32),
               labels=rng.randint(0, 3, 50))
    snap = json.loads(json.dumps(sel.state_dict()))
    back = StreamingSelector(10, 3, per_class=True)
    back.load_state_dict(snap)
    assert back.n_seen == sel.n_seen


def test_result_requires_full_ingested_pool():
    sel = StreamingSelector(5, 2)
    sel.ingest(np.zeros((8, 2), np.float32))
    with pytest.raises(ValueError, match="8"):
        sel.result(np.zeros((6, 2), np.float32))


def test_init_streaming_state_validates_prefix():
    with pytest.raises(ValueError):
        init_streaming_state(2, 3, init_selected=[0, 1, 2])  # prefix > budget


# -- coreset service ----------------------------------------------------------


def test_service_versions_and_double_buffer():
    rng = np.random.RandomState(6)
    svc = CoresetService(10, 4)
    assert svc.coreset() is None and svc.version == 0
    v1 = svc.submit_delta(rng.randn(30, 4))
    assert v1 == 1 and svc.version == 0  # staged, not yet installed
    u1 = svc.coreset()
    assert (u1.version, svc.version, u1.n_seen) == (1, 1, 30)
    assert u1.weights.sum() == pytest.approx(30.0)
    v2 = svc.submit_delta(rng.randn(20, 4))
    u2 = svc.coreset()
    assert (v2, u2.version, u2.n_seen) == (2, 2, 50)
    assert u2.weights.sum() == pytest.approx(50.0)
    assert svc.coreset() is u2  # no new publish → installed unchanged


def test_service_async_coalesces_and_drains():
    rng = np.random.RandomState(7)
    svc = CoresetService(8, 3, mode="async")
    for _ in range(4):
        svc.submit_delta(rng.randn(16, 3))
    u = svc.coreset(block=True)
    assert u is not None and u.n_seen == 64
    assert u.weights.sum() == pytest.approx(64.0)
    assert 1 <= u.version <= 4  # coalesced drains publish ≤ one version each


def test_service_worker_failure_surfaces():
    svc = CoresetService(6, 2, per_class=True)
    with pytest.raises(RuntimeError, match="failed"):
        svc.submit_delta(np.zeros((10, 2), np.float32))  # per_class, no labels


def test_service_state_dict_resume_bit_identical():
    rng = np.random.RandomState(8)
    d1, d2 = rng.randn(25, 3).astype(np.float32), rng.randn(25, 3).astype(np.float32)
    a = CoresetService(7, 3)
    a.submit_delta(d1)
    a.coreset()
    snap = json.loads(json.dumps(a.state_dict()))
    b = CoresetService(7, 3)
    b.load_state_dict(snap)
    assert b.version == a.version
    va, vb = a.submit_delta(d2), b.submit_delta(d2)
    ua, ub = a.coreset(), b.coreset()
    assert va == vb == 2
    np.testing.assert_array_equal(ua.indices, ub.indices)
    np.testing.assert_array_equal(ua.weights, ub.weights)


def test_service_evict_reports_n_live_and_global_indices():
    """evict=True service: published updates carry n_live, γ sums to the
    live count, and indices stay global arrival positions."""
    rng = np.random.RandomState(16)
    svc = CoresetService(8, 3, evict=True)
    for _ in range(5):
        svc.submit_delta(rng.randn(64, 3))
    u = svc.coreset()
    assert u.n_seen == 320 and 8 <= u.n_live < 320
    assert u.weights.sum() == pytest.approx(float(u.n_live))
    assert len(set(u.indices.tolist())) == 8
    assert 0 <= u.indices.min() and u.indices.max() < 320


@pytest.mark.tier2
def test_evicted_service_kill_and_resume_size_bound():
    """Kill-and-resume with eviction: the serialized pool holds ONLY live
    rows (O(L·k·d), a small fraction of the stream), and the resumed
    service continues bit-identically to the uninterrupted one."""
    rng = np.random.RandomState(17)
    deltas = [rng.randn(128, 4).astype(np.float32) for _ in range(8)]

    a = CoresetService(10, 4, evict=True)
    for d in deltas:
        a.submit_delta(d)

    b = CoresetService(10, 4, evict=True)
    for d in deltas[:4]:
        b.submit_delta(d)
    snap = json.loads(json.dumps(b.state_dict()))
    # the size bound eviction buys: live rows only, far below the stream
    n_live = b.selector.n_rows
    assert sum(len(p) for p in snap["pool"]) == n_live
    assert n_live < 512 // 4  # << the 512 rows ingested so far
    c = CoresetService(10, 4, evict=True)
    c.load_state_dict(snap)
    for d in deltas[4:]:
        c.submit_delta(d)

    ua, uc = a.coreset(), c.coreset()
    assert ua.n_seen == uc.n_seen == 1024
    assert ua.n_live == uc.n_live == a.selector.n_rows
    np.testing.assert_array_equal(ua.indices, uc.indices)
    np.testing.assert_array_equal(ua.weights, uc.weights)


def test_service_rejects_bad_delta_shape():
    svc = CoresetService(4, 3)
    with pytest.raises(ValueError, match=r"\(Δn, 3\)"):
        svc.submit_delta(np.zeros((5, 2), np.float32))


# -- subprocess round-trip (tier 2) ------------------------------------------


@pytest.mark.tier2
def test_coreset_service_subprocess_round_trip():
    """launch/serve.py --coreset over real pipes: deltas in, selection out."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    rng = np.random.RandomState(9)
    reqs = [
        {"op": "delta", "feats": rng.randn(24, 4).tolist()},
        {"op": "delta", "feats": rng.randn(16, 4).tolist()},
        {"op": "coreset"},
        {"op": "bogus"},
        {"op": "quit"},
    ]
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--coreset",
         "--budget", "6", "--dim", "4"],
        input="\n".join(json.dumps(r) for r in reqs) + "\n",
        env=env, capture_output=True, text=True, timeout=480,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    resp = [json.loads(ln) for ln in out.stdout.splitlines() if ln.strip()]
    assert len(resp) == 5
    assert resp[0] == {"ok": True, "version": 1, "n_seen": 24}
    assert resp[1] == {"ok": True, "version": 2, "n_seen": 40}
    sel = resp[2]
    assert sel["ok"] and sel["version"] == 2 and sel["n_seen"] == 40
    assert len(sel["indices"]) == 6 == len(set(sel["indices"]))
    assert sum(sel["gamma"]) == pytest.approx(40.0)
    assert resp[3]["ok"] is False and "bogus" in resp[3]["error"]
    assert resp[4] == {"ok": True, "bye": True}
