"""Attention unit tests: blockwise==dense, windowing, M-RoPE properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    AttentionConfig,
    _blockwise_attention,
    _dense_attention,
    attention,
    init_attention,
)
from repro.models.layers import apply_mrope, apply_rope


def _qkv(B=2, T=32, H=4, hd=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, T, H, hd))
    k = jax.random.normal(ks[1], (B, T, H, hd))
    v = jax.random.normal(ks[2], (B, T, H, hd))
    return q, k, v


@pytest.mark.parametrize("window", [None, 8])
@pytest.mark.parametrize("chunks", [(8, 8), (16, 4), (4, 16)])
def test_blockwise_equals_dense(window, chunks):
    q, k, v = _qkv()
    scale = 0.25
    cfg = AttentionConfig(
        d_model=64, n_heads=4, n_kv_heads=4, d_head=16, window=window,
        chunk_q=chunks[0], chunk_kv=chunks[1],
    )
    dense = _dense_attention(q, k, v, scale, 0, window)
    block = _blockwise_attention(q, k, v, scale, cfg)
    np.testing.assert_allclose(
        np.asarray(dense), np.asarray(block), rtol=2e-5, atol=2e-5
    )


def test_window_masks_past():
    """With window w, token t must ignore keys < t − w + 1: perturbing an
    out-of-window key must not change the output."""
    q, k, v = _qkv(T=24)
    cfg = AttentionConfig(d_model=64, n_heads=4, n_kv_heads=4, d_head=16, window=4)
    base = _dense_attention(q, k, v, 0.25, 0, 4)
    k2 = k.at[:, 0].add(100.0)  # way outside the window of t ≥ 5
    v2 = v.at[:, 0].add(100.0)
    pert = _dense_attention(q, k2, v2, 0.25, 0, 4)
    np.testing.assert_allclose(
        np.asarray(base[:, 5:]), np.asarray(pert[:, 5:]), rtol=1e-5, atol=1e-5
    )


def test_causality():
    """Future-token perturbations never leak backwards."""
    cfg = AttentionConfig(d_model=64, n_heads=4, n_kv_heads=2, d_head=16)
    params = init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
    base = attention(params, cfg, x, pos)
    x2 = x.at[:, 10:].add(3.0)
    pert = attention(params, cfg, x2, pos)
    np.testing.assert_allclose(
        np.asarray(base[:, :10]), np.asarray(pert[:, :10]), rtol=1e-4, atol=1e-4
    )


def test_rope_relative_property():
    """RoPE inner products depend only on relative positions."""
    hd = 16
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))

    def score(pq, pk):
        qr = apply_rope(q, jnp.array([[pq]]))
        kr = apply_rope(k, jnp.array([[pk]]))
        return float(jnp.sum(qr * kr))

    assert score(5, 3) == pytest.approx(score(105, 103), rel=1e-4)
    assert score(7, 0) == pytest.approx(score(57, 50), rel=1e-4)


def test_mrope_reduces_to_rope_on_text():
    """With t==h==w position streams, M-RoPE must equal plain RoPE."""
    B, T, H, hd = 2, 8, 2, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, hd))
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    pos3 = jnp.broadcast_to(pos[:, None], (B, 3, T))
    a = apply_rope(x, pos)
    b = apply_mrope(x, pos3, (4, 2, 2))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_mrope_streams_differ():
    """Distinct h/w streams must produce different rotations than text mode."""
    B, T, H, hd = 1, 8, 2, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, hd))
    t = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    pos_text = jnp.stack([t, t, t], axis=1)
    pos_img = jnp.stack([t, t * 0 + 3, t * 0 + 5], axis=1)
    a = apply_mrope(x, pos_text, (4, 2, 2))
    b = apply_mrope(x, pos_img, (4, 2, 2))
    assert float(jnp.max(jnp.abs(a - b))) > 1e-3


def test_gqa_repeat_matches_full_heads():
    """GQA with kv broadcast equals MHA where kv heads are replicated."""
    cfg_gqa = AttentionConfig(d_model=32, n_heads=4, n_kv_heads=2, d_head=8)
    params = init_attention(jax.random.PRNGKey(0), cfg_gqa)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 12, 32))
    pos = jnp.broadcast_to(jnp.arange(12), (1, 12))
    out = attention(params, cfg_gqa, x, pos)

    cfg_mha = AttentionConfig(d_model=32, n_heads=4, n_kv_heads=4, d_head=8)
    params_mha = dict(params)
    params_mha["wk"] = jnp.repeat(params["wk"], 2, axis=1)
    params_mha["wv"] = jnp.repeat(params["wv"], 2, axis=1)
    out2 = attention(params_mha, cfg_mha, x, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-5, atol=1e-5)
