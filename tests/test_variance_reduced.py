"""SAGA / SVRG / weighted IG on a strongly convex problem (paper Thm 1/2).

Includes the Theorem-1 integration check: IG on the CRAIG coreset with
per-element stepsizes converges into a neighborhood of the full-data optimum
whose radius shrinks with the coreset budget (ε).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.craig import CraigConfig, CraigSelector
from repro.data.synthetic import make_classification
from repro.optim import ig_run, saga_run, svrg_run

LAM = 1e-2


def _ridge_problem(n=60, d=5, seed=0):
    """Ridge regression: strongly convex, closed-form optimum."""
    rng = np.random.RandomState(seed)
    X = rng.randn(n, d).astype(np.float32)
    w_true = rng.randn(d).astype(np.float32)
    y = X @ w_true + 0.05 * rng.randn(n).astype(np.float32)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)

    def grad_fn(w, i):
        xi, yi = Xj[i], yj[i]
        return xi * (xi @ w - yi) + LAM * w

    # optimum of (1/1)Σ_i f_i = Σ(.5(x·w−y)² + .5λ‖w‖²)
    A = X.T @ X + n * LAM * np.eye(d)
    w_star = jnp.asarray(np.linalg.solve(A, X.T @ y))
    return grad_fn, w_star, X, y


@pytest.mark.parametrize("runner", [ig_run, saga_run, svrg_run])
def test_full_data_convergence(runner):
    grad_fn, w_star, X, _ = _ridge_problem()
    n, d = X.shape
    order = jnp.arange(n)
    weights = jnp.ones(n)
    w, _ = runner(
        grad_fn, jnp.zeros(d), order, weights,
        lambda k: 0.3 / (n * (1 + 0.3 * k)), epochs=80,
    )
    # IG converges O(1/√k); the VR methods are much tighter but share a bound
    assert float(jnp.linalg.norm(w - w_star)) < 0.12


def test_weighted_ig_on_craig_subset_theorem1():
    """IG on (S, γ) lands near w*; bigger budgets land closer (Thm 1)."""
    grad_fn, w_star, X, y = _ridge_problem(n=120)
    n, d = X.shape
    dists = {}
    for frac in (0.1, 0.5):
        sel = CraigSelector(CraigConfig(fraction=frac, per_class=False))
        cs = sel.select(jnp.asarray(X))  # Eq. 9 proxy: feature space
        w, _ = ig_run(
            grad_fn,
            jnp.zeros(d),
            jnp.asarray(cs.indices, jnp.int32),
            jnp.asarray(cs.weights),
            lambda k: 0.3 / (n * (1 + 0.3 * k)),
            epochs=60,
        )
        dists[frac] = float(jnp.linalg.norm(w - w_star))
    # converges into a neighborhood, radius shrinking with budget
    assert dists[0.5] < 0.25
    assert dists[0.5] <= dists[0.1] + 1e-3


def test_saga_variance_reduction_beats_sgd_late():
    """With constant stepsize, SAGA keeps converging where plain IG stalls."""
    grad_fn, w_star, X, _ = _ridge_problem(n=80, seed=2)
    n, d = X.shape
    order, weights = jnp.arange(n), jnp.ones(n)
    sched = lambda k: 0.02 / n * 8
    w_ig, _ = ig_run(grad_fn, jnp.zeros(d), order, weights, sched, epochs=80)
    w_saga, _ = saga_run(grad_fn, jnp.zeros(d), order, weights, sched, epochs=80)
    d_ig = float(jnp.linalg.norm(w_ig - w_star))
    d_saga = float(jnp.linalg.norm(w_saga - w_star))
    assert d_saga <= d_ig + 1e-4


def test_svrg_matches_gd_fixed_point():
    grad_fn, w_star, X, _ = _ridge_problem(n=50, seed=3)
    n, d = X.shape
    w, _ = svrg_run(
        grad_fn, jnp.zeros(d), jnp.arange(n), jnp.ones(n),
        lambda k: 0.1 / n, epochs=100,
    )
    assert float(jnp.linalg.norm(w - w_star)) < 0.05
