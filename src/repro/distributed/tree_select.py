"""Hierarchical (tree) distributed selection with compressed candidate
collectives (DESIGN.md §6).

Two-round selection (``core/distributed.local_then_merge``) is the depth-1
special case of a leaf→merge→root tree: leaves select ``r_local``
candidates with any round-1 engine, every non-leaf node merges its
children's candidate sets with one bounded weighted re-greedy pass
(``merge_round``), and the root runs the final exact weighted round.  The
tree is what takes selection past one host's mesh: leaf fan-in happens
close to the data (ICI / intra-host), and only ``r_node``-sized candidate
sets cross the slow axes toward the root.

The bandwidth wall is the candidate-feature gather at each non-leaf
level.  Every gather here ships int8 per-row block-quantized payloads
(``distributed.compression.quantize_rows_int8`` — ~4x fewer bytes than
fp32, one-shot so no error feedback), with ``compress='none'`` as the
fp32 escape hatch; ``bench_tree_select`` gates the compressed tree at
≥ 0.95 of the uncompressed tree's objective.

Three drivers share the same level math (``leaf_round``/``merge_round``
from ``core.distributed`` — the N-level generalization of the two-round
refactor), so their selections agree bit for bit on the same pool:

* :func:`tree_select_host` — single-process orchestration over a global
  (n, d) pool.  Supports ragged leaf shards, needs no mesh; the reference
  implementation and the tier-1 test surface.
* :func:`tree_select_mesh` — one ``shard_map`` program over an N-axis
  mesh (one axis per tree level, built by :func:`tree_mesh`); merges run
  replicated within each subtree exactly like the two-round path's
  replicated merge.  Spans processes wherever XLA's cross-process
  collectives exist (TPU/GPU pods via ``jax.distributed``); on CPU it
  runs single-process over simulated devices.
* ``tree_select_processes`` (``repro.distributed.process_tree``) — one
  process per leaf over the ``jax.distributed`` KV store, the
  multi-process CPU path (XLA CPU has no cross-process collectives); the
  tier-2 CI lane drives it end to end with 2 real processes.

Guarantee shape: each merge level is a GreeDi-style composition — greedy
over the union of children's (1−1/e)-approximate candidate sets, weighted
by the γ mass each candidate represents — so the worst-case factor decays
geometrically with depth but the empirical loss is small (the CREST
observation: selection from pool *subsets* loses little), and the final
exact re-weighting pass keeps Σγ = n and coverage exact over the whole
pool regardless of depth.  ``tests/test_selection_properties.py`` gates
the objective ratio vs lazy greedy across depths and fan-outs.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.distributed import (
    check_candidate_counts,
    check_even_shards,
    compat_shard_map,
    leaf_round,
    merge_round,
    resolve_round1_config,
)
from repro.core.engines import EngineConfig
from repro.distributed.compression import (
    dequantize_rows_int8,
    quantize_rows_int8,
)

__all__ = [
    "WIRE_MODES",
    "TreeTopology",
    "TreeSelectConfig",
    "TreeSelection",
    "tree_mesh",
    "tree_select_host",
    "tree_select_mesh",
    "wire_bytes_plan",
    "default_r_node",
]

WIRE_MODES = ("int8", "none")


# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TreeTopology:
    """A leaf→root merge tree described by per-level fan-outs.

    ``fanouts[0]`` leaves merge into each level-1 node, ``fanouts[1]``
    level-1 nodes merge into each level-2 node, …, and the last fan-out
    merges into the single root.  ``n_leaves = Π fanouts`` and
    ``depth = len(fanouts)`` merge levels; ``fanouts=(n_shards,)`` is
    exactly the existing two-round path (one merge at the root).
    """

    fanouts: tuple[int, ...]

    def __post_init__(self):
        fo = tuple(int(f) for f in self.fanouts)
        object.__setattr__(self, "fanouts", fo)
        if not fo:
            raise ValueError("TreeTopology needs at least one fan-out level")
        if any(f < 1 for f in fo):
            raise ValueError(f"fan-outs must be ≥ 1, got {fo}")
        if all(f == 1 for f in fo):
            raise ValueError(
                f"degenerate topology {fo}: at least one fan-out must be "
                "> 1 (a chain of 1-child merges re-greedies the same "
                "candidate set over and over)"
            )

    @property
    def depth(self) -> int:
        """Number of merge levels (leaves excluded)."""
        return len(self.fanouts)

    @property
    def n_leaves(self) -> int:
        n = 1
        for f in self.fanouts:
            n *= f
        return n

    def nodes_at(self, level: int) -> int:
        """Node count after ``level`` merges (level 0 = leaves)."""
        n = self.n_leaves
        for f in self.fanouts[:level]:
            n //= f
        return n

    @property
    def axis_names(self) -> tuple[str, ...]:
        """Mesh axis per merge level, leaf-adjacent first."""
        return tuple(f"lvl{i}" for i in range(self.depth))

    def to_dict(self) -> dict:
        return {"fanouts": list(self.fanouts)}

    @classmethod
    def from_dict(cls, d: dict) -> "TreeTopology":
        return cls(fanouts=tuple(d["fanouts"]))


@dataclasses.dataclass(frozen=True)
class TreeSelectConfig(EngineConfig):
    """Provenance record for tree-orchestrated selections.

    Not a registered ``SelectionEngine`` — the tree is an orchestration
    layer over the round-1 engines, not a greedy maximizer itself — but it
    speaks the ``EngineConfig`` dict protocol so ``CoresetSelection.engine``
    / sampler checkpoints round-trip it like any engine provenance
    (``engine_config_from_dict`` dispatches ``name == 'tree'`` here).

    Attributes:
      fanouts: the merge-tree shape (``TreeTopology.fanouts``).
      compress: candidate wire mode — ``'int8'`` (per-row block-quantized
        gathers) or ``'none'`` (fp32 escape hatch).
      local: the resolved *leaf* engine's ``EngineConfig.to_dict()`` —
        nested verbatim so the full execution path is recorded.
      degraded: True when the process driver completed under quorum
        degradation (DESIGN.md §12) — one or more leaves died and the
        selection covers only the surviving shards.
      missing_pids: the dead leaves' process indices (empty when clean).
      quorum: achieved surviving-leaf fraction (1.0 when clean).
    """

    name: ClassVar[str] = "tree"
    fanouts: tuple[int, ...] = (2,)
    compress: str = "int8"
    local: dict | None = None
    degraded: bool = False
    missing_pids: tuple[int, ...] = ()
    quorum: float = 1.0

    def __post_init__(self):
        object.__setattr__(self, "fanouts", tuple(int(f) for f in self.fanouts))
        object.__setattr__(
            self, "missing_pids", tuple(int(p) for p in self.missing_pids)
        )
        if self.compress not in WIRE_MODES:
            raise ValueError(
                f"compress={self.compress!r} is not a wire mode; "
                f"expected one of {WIRE_MODES}"
            )

    @property
    def topology(self) -> TreeTopology:
        return TreeTopology(self.fanouts)


# ---------------------------------------------------------------------------
# Candidate wire
# ---------------------------------------------------------------------------


def _through_wire(feats: jax.Array, compress: str) -> jax.Array:
    """What the receiving merge node sees of a shipped candidate matrix."""
    if compress == "int8":
        return dequantize_rows_int8(*quantize_rows_int8(feats))
    if compress == "none":
        return feats
    raise ValueError(
        f"compress={compress!r} is not a wire mode; expected one of "
        f"{WIRE_MODES}"
    )


def _payload_bytes(r: int, d: int, compress: str) -> int:
    """Wire bytes for one (r, d) candidate-feature payload."""
    if compress == "int8":
        return r * d + 4 * r  # int8 payload + fp32 per-row scales
    return 4 * r * d


def wire_bytes_plan(
    topology: TreeTopology,
    r_local: int,
    r_node: int,
    d: int,
    compress: str,
) -> dict:
    """Static bytes-on-wire accounting for one tree selection.

    Counts the candidate-FEATURE payloads every non-leaf gather ships
    (γ weights and global ids are identical small fp32/int32 sidecars in
    both modes and are excluded, like the scales' fp32 sidecar is
    *included* — it only exists in int8 mode).  Per level: every child
    node ships its candidate matrix once.
    """
    if compress not in WIRE_MODES:
        raise ValueError(
            f"compress={compress!r} is not a wire mode; expected one of "
            f"{WIRE_MODES}"
        )
    per_level = []
    r = r_local
    for level, fanout in enumerate(topology.fanouts):
        n_children = topology.nodes_at(level)  # shipping nodes at this level
        per_level.append(
            {
                "level": level + 1,
                "children": n_children,
                "r_child": r,
                "bytes": n_children * _payload_bytes(r, d, compress),
                "fp32_bytes": n_children * _payload_bytes(r, d, "none"),
            }
        )
        r = min(r_node, fanout * r)  # what each merged node forwards
    total = sum(lv["bytes"] for lv in per_level)
    fp32_total = sum(lv["fp32_bytes"] for lv in per_level)
    return {
        "compress": compress,
        "per_level": per_level,
        "gathered_feature_bytes": total,
        "fp32_feature_bytes": fp32_total,
        "reduction": fp32_total / max(total, 1),
    }


def default_r_node(r_local: int, r_final: int) -> int:
    """Intermediate merge budget: every non-root node forwards this many.

    ``max(r_local, r_final)`` keeps at least the final budget's worth of
    candidates alive at every level (the GreeDi composition needs ≥
    ``r_final`` distinct survivors per merge to preserve its factor) while
    never *expanding* a level's output past what a bigger leaf round would
    have shipped anyway.
    """
    return max(int(r_local), int(r_final))


class TreeSelection(NamedTuple):
    """Result of a hierarchical selection (same contract at any depth).

    Attributes:
      indices: (r_final,) int32 — global pool indices.
      weights: (r_final,) float32 — exact global γ, Σ == n.
      coverage: () float32 — exact global L(S) over the whole pool.
      wire: static bytes-on-wire accounting (:func:`wire_bytes_plan`).
      health: degradation record from the process driver (DESIGN.md §12):
        ``{'degraded', 'missing_pids', 'quorum', 'min_quorum', 'r_final',
        'level_deadline_s'}``.  None from the host/mesh drivers (no
        process failure domain), and under degradation ``r_final``/Σγ
        cover the *surviving* shards only.
    """

    indices: jax.Array
    weights: jax.Array
    coverage: jax.Array
    wire: dict
    health: dict | None = None


# ---------------------------------------------------------------------------
# Shared validation
# ---------------------------------------------------------------------------


def _check_tree_counts(
    leaf_sizes: list[int],
    topology: TreeTopology,
    r_local: int,
    r_node: int,
    r_final: int,
    *,
    where: str,
) -> None:
    """Candidate-count invariants at every level of the tree (the N-level
    generalization of ``check_candidate_counts``)."""
    if r_node < 1:
        raise ValueError(f"{where}: r_node={r_node} must be ≥ 1")
    depth = topology.depth
    level1_budget = r_final if depth == 1 else min(
        r_node, topology.fanouts[0] * r_local
    )
    check_candidate_counts(
        min(leaf_sizes), topology.fanouts[0], r_local, level1_budget,
        where=f"{where} (level 1)",
    )
    r = r_local
    for level, fanout in enumerate(topology.fanouts):
        budget = r_final if level == depth - 1 else min(r_node, fanout * r)
        if fanout * r < budget:
            raise ValueError(
                f"{where}: level {level + 1} merges only {fanout}×{r}="
                f"{fanout * r} candidates, fewer than its budget "
                f"{budget} — raise r_local/r_node or lower r_final"
            )
        r = budget


# ---------------------------------------------------------------------------
# Host driver
# ---------------------------------------------------------------------------


def tree_select_host(
    feats: jax.Array,
    topology: TreeTopology,
    r_local: int,
    r_final: int,
    *,
    r_node: int | None = None,
    local_engine: str | EngineConfig = "auto",
    compress: str = "int8",
    squared_coverage: bool = False,
) -> TreeSelection:
    """Single-process hierarchical selection over a global (n, d) pool.

    The pool splits into ``topology.n_leaves`` contiguous leaf shards
    (ragged splits supported — ``np.array_split`` semantics, no padding or
    truncation), each leaf runs :func:`leaf_round` with the resolved
    engine, and candidate sets merge up the tree with every non-leaf
    gather passed through the ``compress`` wire.  The final re-weighting
    assigns every pool point to its nearest final medoid, so ``weights``
    and ``coverage`` are exact regardless of depth or compression.

    This is the reference driver: :func:`tree_select_mesh` and the
    process driver produce bit-identical selections on the same pool.
    """
    if compress not in WIRE_MODES:
        raise ValueError(
            f"compress={compress!r} is not a wire mode; expected one of "
            f"{WIRE_MODES}"
        )
    feats = jnp.asarray(feats, jnp.float32)
    n, d = feats.shape
    n_leaves = topology.n_leaves
    if n_leaves > n:
        raise ValueError(
            f"tree_select_host: topology has {n_leaves} leaves but the "
            f"pool only has {n} points"
        )
    r_node = default_r_node(r_local, r_final) if r_node is None else int(r_node)
    leaf_slices = np.array_split(np.arange(n, dtype=np.int64), n_leaves)
    _check_tree_counts(
        [len(s) for s in leaf_slices], topology, r_local, r_node, r_final,
        where="tree_select_host",
    )
    engine_cfg = resolve_round1_config(
        local_engine, {}, min(len(s) for s in leaf_slices)
    )

    # Leaves: local selection, candidates carry exact local features.
    nodes = []  # (cand_feats, cand_w, cand_gidx) per live node, leaf order
    for sl in leaf_slices:
        leaf_feats = feats[jnp.asarray(sl)]
        idx, w = leaf_round(leaf_feats, r_local, engine_cfg)
        nodes.append((leaf_feats[idx], w, jnp.asarray(sl)[idx]))

    # Merge levels: children ship through the wire, parent re-greedies.
    for level, fanout in enumerate(topology.fanouts):
        budget = r_final if level == topology.depth - 1 else min(
            r_node, fanout * nodes[0][0].shape[0]
        )
        merged = []
        for lo in range(0, len(nodes), fanout):
            group = nodes[lo : lo + fanout]
            cand_feats = jnp.concatenate(
                [_through_wire(f, compress) for f, _, _ in group]
            )
            cand_w = jnp.concatenate([w for _, w, _ in group])
            cand_gidx = jnp.concatenate([g for _, _, g in group])
            res = merge_round(cand_feats, cand_w, budget)
            merged.append(
                (cand_feats[res.indices], res.weights, cand_gidx[res.indices])
            )
        nodes = merged
    (root_feats, _, root_gidx), = nodes

    # Exact global re-weighting + coverage, leaf order (matches the mesh
    # driver's psum over shards up to float-sum association).
    sqm = jnp.sum(root_feats * root_feats, axis=-1)
    counts = jnp.zeros((r_final,), jnp.float32)
    coverage = jnp.zeros((), jnp.float32)
    for sl in leaf_slices:
        leaf_feats = feats[jnp.asarray(sl)]
        sqx = jnp.sum(leaf_feats * leaf_feats, axis=-1)
        d2 = sqx[:, None] + sqm[None, :] - 2.0 * leaf_feats @ root_feats.T
        dist = jnp.sqrt(jnp.maximum(d2, 0.0))
        assign = jnp.argmin(dist, axis=1)
        counts = counts.at[assign].add(1.0)
        min_dist = jnp.min(dist, axis=1)
        residual = (
            jnp.square(min_dist) / 2.0 if squared_coverage else min_dist
        )
        coverage = coverage + jnp.sum(residual)
    wire = wire_bytes_plan(topology, r_local, r_node, d, compress)
    return TreeSelection(
        root_gidx.astype(jnp.int32), counts, coverage, wire
    )


# ---------------------------------------------------------------------------
# Mesh driver (one shard_map program, one axis per level)
# ---------------------------------------------------------------------------


def tree_mesh(topology: TreeTopology, devices=None):
    """Mesh with one axis per merge level: shape ``reversed(fanouts)``,
    axes ``('lvl{L-1}', …, 'lvl0')`` — ``lvl0`` minor, so the leaf-adjacent
    gathers group the closest devices.  Needs exactly ``n_leaves`` devices
    (pass ``devices`` to sub-select; defaults to ``jax.devices()``, which
    spans processes under ``jax.distributed``)."""
    from repro.launch.mesh import compat_mesh

    if devices is None:
        devices = jax.devices()
    if len(devices) != topology.n_leaves:
        raise ValueError(
            f"tree_mesh: topology has {topology.n_leaves} leaves but "
            f"{len(devices)} devices are available — fan-outs must "
            "multiply to the device count"
        )
    shape = tuple(reversed(topology.fanouts))
    axes = tuple(reversed(topology.axis_names))
    if hasattr(jax.sharding, "AxisType"):
        return jax.sharding.Mesh(
            np.asarray(devices).reshape(shape), axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    return jax.sharding.Mesh(np.asarray(devices).reshape(shape), axes)


def _tree_body(
    feats_local: jax.Array,
    topology: TreeTopology,
    r_local: int,
    r_node: int,
    r_final: int,
    engine_cfg: EngineConfig,
    compress: str,
    squared_coverage: bool,
):
    """shard_map body: one leaf per device, merges replicated per subtree.

    Gathering over axis ``lvl{l}`` collects exactly the ``fanouts[l]``
    distinct child nodes of this device's level-``l+1`` ancestor (all
    devices below a child carry identical replicated copies of its
    candidate set, so any fixed coordinate on the lower axes picks one
    representative) — the same replicated-merge design as the two-round
    path, generalized level over level.
    """
    n_local, _ = feats_local.shape
    axes = topology.axis_names

    # global leaf id from the axis coordinates, major → minor
    leaf_id = jnp.zeros((), jnp.int32)
    for ax in reversed(axes):
        leaf_id = leaf_id * jnp.int32(
            int(jax.lax.psum(1, ax))
        ) + jax.lax.axis_index(ax)

    local_idx, local_w = leaf_round(feats_local, r_local, engine_cfg)
    cand_feats = feats_local[local_idx]
    cand_w = local_w
    cand_gidx = leaf_id * n_local + local_idx

    for level, ax in enumerate(axes):
        fanout = topology.fanouts[level]
        # candidate features ship through the wire: int8 payload + fp32
        # per-row scales gathered, dequantized on arrival
        if compress == "int8":
            q, scale = quantize_rows_int8(cand_feats)
            q_g = jax.lax.all_gather(q, ax, tiled=True)
            s_g = jax.lax.all_gather(scale, ax, tiled=True)
            gathered_feats = dequantize_rows_int8(q_g, s_g)
        else:
            gathered_feats = jax.lax.all_gather(cand_feats, ax, tiled=True)
        gathered_w = jax.lax.all_gather(cand_w, ax, tiled=True)
        gathered_gidx = jax.lax.all_gather(cand_gidx, ax, tiled=True)

        budget = r_final if level == topology.depth - 1 else min(
            r_node, fanout * cand_feats.shape[0]
        )
        res = merge_round(gathered_feats, gathered_w, budget)
        cand_feats = gathered_feats[res.indices]
        cand_w = res.weights
        cand_gidx = gathered_gidx[res.indices]

    # Exact global re-weighting: assign local points to the final medoids
    # (replicated on every device), psum counts/coverage over every axis.
    sqx = jnp.sum(feats_local * feats_local, axis=-1)
    sqm = jnp.sum(cand_feats * cand_feats, axis=-1)
    d2 = sqx[:, None] + sqm[None, :] - 2.0 * feats_local @ cand_feats.T
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    assign = jnp.argmin(dist, axis=1)
    local_counts = jnp.zeros((r_final,), jnp.float32).at[assign].add(1.0)
    weights = jax.lax.psum(local_counts, axes)
    min_dist = jnp.min(dist, axis=1)
    residual = jnp.square(min_dist) / 2.0 if squared_coverage else min_dist
    coverage = jax.lax.psum(jnp.sum(residual), axes)
    return cand_gidx.astype(jnp.int32), weights, coverage


def tree_select_mesh(
    feats: jax.Array,
    mesh,
    topology: TreeTopology,
    r_local: int,
    r_final: int,
    *,
    r_node: int | None = None,
    local_engine: str | EngineConfig = "auto",
    compress: str = "int8",
    squared_coverage: bool = False,
) -> TreeSelection:
    """Hierarchical selection as ONE shard_map program over ``mesh``.

    ``mesh`` must carry the topology's level axes (build it with
    :func:`tree_mesh`); ``feats`` is the global (n, d) pool, n divisible
    by ``n_leaves``.  Each device is a leaf; outputs are fully replicated.
    Where XLA's collectives span processes (TPU/GPU pods bootstrapped via
    ``launch.tree.initialize_distributed``) this is the multi-host path;
    CPU multi-process runs use ``process_tree.tree_select_processes``.
    """
    if compress not in WIRE_MODES:
        raise ValueError(
            f"compress={compress!r} is not a wire mode; expected one of "
            f"{WIRE_MODES}"
        )
    for ax in topology.axis_names:
        if ax not in mesh.shape:
            raise ValueError(
                f"tree_select_mesh: mesh axes {tuple(mesh.shape)} are "
                f"missing level axis {ax!r} — build the mesh with "
                "tree_mesh(topology)"
            )
    feats = jnp.asarray(feats, jnp.float32)
    n, d = feats.shape
    n_leaves = topology.n_leaves
    check_even_shards(n, n_leaves, where="tree_select_mesh")
    n_local = n // n_leaves
    r_node = default_r_node(r_local, r_final) if r_node is None else int(r_node)
    _check_tree_counts(
        [n_local], topology, r_local, r_node, r_final,
        where="tree_select_mesh",
    )
    engine_cfg = resolve_round1_config(local_engine, {}, n_local)

    def body(x):
        return _tree_body(
            x, topology, r_local, r_node, r_final, engine_cfg, compress,
            squared_coverage,
        )

    # dim 0 sharded over every level axis, major → minor: global index
    # order is (lvl{L-1}, …, lvl0) row-major, matching the body's leaf_id
    flat_axes = tuple(reversed(topology.axis_names))
    fn = compat_shard_map(
        body, mesh=mesh, in_specs=(P(flat_axes, None),),
        out_specs=(P(), P(), P()),
    )
    idx, w, cov = fn(feats)
    wire = wire_bytes_plan(topology, r_local, r_node, d, compress)
    return TreeSelection(idx, w, cov, wire)
