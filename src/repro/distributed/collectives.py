"""Collective helpers: manual reduce-scatter/all-gather gradient sync.

Under plain pjit, gradient synchronization is implicit (GSPMD inserts
all-reduces).  For §Perf iterations we also provide an explicit shard_map
path that replaces `all-reduce` with `reduce-scatter + all-gather` so the
optimizer update runs on 1/|axis| of each gradient (ZeRO-2 style update
sharding) — halving the collective bytes on the critical path and letting
XLA overlap the all-gather of updated params with the next microbatch.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["reduce_scatter_mean", "all_gather_params", "psum_mean"]


def psum_mean(tree: Any, axis_name: str) -> Any:
    n = jax.lax.psum(1, axis_name)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_name) / n, tree)


def reduce_scatter_mean(x: jax.Array, axis_name: str) -> jax.Array:
    """Reduce-scatter over dim 0 (padded to the axis size), mean semantics."""
    # psum of a literal constant-folds to the static axis size at trace
    # time (jax.lax.axis_size only exists on newer jax releases)
    n = int(jax.lax.psum(1, axis_name))
    pad = (-x.shape[0]) % n
    if pad:
        x = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1))
    out = jax.lax.psum_scatter(x, axis_name, scatter_dimension=0, tiled=True)
    return out / n


def all_gather_params(x: jax.Array, axis_name: str, orig_dim0: int) -> jax.Array:
    """Inverse of reduce_scatter_mean's sharding (drops dim-0 padding)."""
    full = jax.lax.all_gather(x, axis_name, tiled=True)
    return full[:orig_dim0]
