"""Payload compression for the cross-pod / cross-process wire.

Two int8 quantization schemes share this module:

**Gradients** (``quantize_int8``/``dequantize_int8``/``compressed_psum``) —
per-block (256) absmax scaling with error feedback (1-bit-Adam-family
technique adapted to jax collectives):

  * quantize: per-block (256) absmax scaling to int8;
  * sync: ``all_gather`` of the int8 payload (+fp32 scales, ~0.4% overhead)
    keeps int8 *on the wire*; the weighted sum is reconstructed locally and
    exactly equals the sum of per-peer dequantized gradients;
  * error feedback: each peer's quantization residual is carried into its
    next step's gradient (preserves convergence — Karimireddy et al. 2019).

DCN bytes per sync drop ~4x vs fp32 ring all-reduce at pod-count 2.
Used via the ``grad_transform`` hook of train_step inside shard_map, or
standalone through ``compressed_psum``.

**Candidate-feature matrices** (``quantize_rows_int8``/
``dequantize_rows_int8``) — per-ROW absmax scaling of a 2-D (r, d) payload,
used by hierarchical tree selection (DESIGN.md §6) to ship candidate
features up the merge tree at ~4x fewer bytes than fp32.  Rows are the
natural block: each row is one candidate's proxy-feature vector, so a
single outlier feature only degrades its own candidate, and the (r,)
scale vector rides the same gather as the payload.  These are ONE-SHOT
payloads — each candidate set is gathered once per selection, so there is
no error-feedback residual to carry (unlike the gradient path, where the
same tensor syncs every step).  bf16 inputs are accepted and quantized
through fp32; both functions are jit/shard_map-safe.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "quantize_rows_int8",
    "dequantize_rows_int8",
    "compressed_psum",
    "make_error_feedback",
]

_BLOCK = 256


def _pad_to_block(x: jax.Array) -> jax.Array:
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % _BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (any shape) → (int8 payload (n_blocks, B), fp32 scales (n_blocks,))."""
    blocks = _pad_to_block(x.astype(jnp.float32)).reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(
    q: jax.Array, scale: jax.Array, shape: tuple[int, ...]
) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def quantize_rows_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(r, d) feature matrix → (int8 payload (r, d), fp32 scales (r,)).

    Per-row absmax scaling: row i is quantized with scale_i = max|x_i|/127,
    so the round-trip error is bounded per row by scale_i/2 (plus fp
    rounding) — one candidate's outlier feature never degrades another
    candidate's row.  fp32 and bf16 inputs are accepted (bf16 is widened
    to fp32 before the scale computation); the dequantized result is
    always fp32, matching what the merge greedy consumes.
    """
    if x.ndim != 2:
        raise ValueError(
            f"quantize_rows_int8 expects a 2-D (r, d) feature matrix, got "
            f"shape {x.shape} — use quantize_int8 for arbitrary-shape "
            "gradient payloads"
        )
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_rows_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_rows_int8` — (r, d) fp32 features."""
    return q.astype(jnp.float32) * scale[:, None]


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean over ``axis_name`` with int8 on-the-wire payload."""
    q, scale = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)  # (P, nb, B) — int8 wire bytes
    ss = jax.lax.all_gather(scale, axis_name)  # (P, nb)
    total = jnp.sum(qs.astype(jnp.float32) * ss[..., None], axis=0)
    n = jax.lax.psum(1, axis_name)
    flat = total.reshape(-1)
    size = 1
    for s in x.shape:
        size *= s
    return flat[:size].reshape(x.shape) / n


def make_error_feedback(grad_like: Any):
    """Returns (init_residual(), apply(grads, residual) → (delivered, res')).

    ``apply`` adds the carried residual, quantize→dequantize (what the wire
    delivers), and stores the new residual = input − delivered.
    """

    def init_residual():
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grad_like)

    def apply(grads, residual):
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_r = jax.tree_util.tree_leaves(residual)
        delivered, new_res = [], []
        for g, r in zip(flat_g, flat_r):
            total = g.astype(jnp.float32) + r
            q, s = quantize_int8(total)
            d = dequantize_int8(q, s, total.shape)
            delivered.append(d)
            new_res.append(total - d)
        return (
            jax.tree_util.tree_unflatten(treedef, delivered),
            jax.tree_util.tree_unflatten(treedef, new_res),
        )

    return init_residual, apply
