"""Activation sharding annotations (logical-axis constraints).

GSPMD propagates shardings from inputs, but on deep programs it can pick
pathological layouts (e.g. replicating full-batch logits when an op it can't
partition — a gather along a sharded dim — appears).  Production frameworks
pin the layout of every major activation; this module is that layer.

``set_mesh(mesh)`` is called by the launcher before tracing;
``constrain(x, *logical_axes)`` then applies
``jax.lax.with_sharding_constraint`` with divisibility-checked specs.
With no mesh set (CPU unit tests) it is a no-op, so model code can annotate
unconditionally.

Logical axis vocabulary:
  "batch"  → (pod, data)     "tp" → model        None → replicated
  "batch_or_none" behaves like "batch" but silently drops when the dim is
  not divisible (long_500k batch=1).
"""
from __future__ import annotations

import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["set_mesh", "get_mesh", "constrain", "mesh_context"]

_STATE = threading.local()


def set_mesh(mesh: Optional[Mesh], dp_over_model: bool = False) -> None:
    """dp_over_model=True: the `model` axis joins data parallelism — used by
    throughput-oriented forward-only programs (CRAIG select_step), where
    ZeRO-3 weight gathers are far cheaper than per-layer TP all-reduces
    (§Perf iteration 3)."""
    _STATE.mesh = mesh
    _STATE.dp_over_model = dp_over_model


def get_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


class mesh_context:
    def __init__(self, mesh: Optional[Mesh]):
        self.mesh = mesh

    def __enter__(self):
        self.prev = get_mesh()
        set_mesh(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        set_mesh(self.prev)
        return False


def _resolve(axis: Optional[str], mesh: Mesh) -> tuple:
    names = set(mesh.axis_names)
    dp_over_model = getattr(_STATE, "dp_over_model", False)
    if axis is None:
        return ()
    if axis == "batch":
        dp = ("pod", "data", "model") if dp_over_model else ("pod", "data")
        return tuple(a for a in dp if a in names)
    if axis == "tp":
        if dp_over_model:
            return ()  # model axis repurposed as DP
        return ("model",) if "model" in names else ()
    if axis in names:
        return (axis,)
    return ()


def constrain(
    x: jax.Array, *logical_axes: Optional[str], strict: bool = False
) -> jax.Array:
    """Pin x's layout: one logical axis name (or None) per dimension.

    strict=True drops axes whose dim is not exactly divisible — use for dims
    that feed broadcast/reshape chains (uneven GSPMD padding through a
    reshape degenerates to full rematerialization).
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    spec = []
    for dim, axis in zip(x.shape, logical_axes):
        group = _resolve(axis, mesh)
        size = int(np.prod([mesh.shape[g] for g in group])) if group else 1
        # GSPMD supports uneven sharding (internal padding), so by default
        # only require the dim to be at least the axis size (e.g. 28 heads
        # over 16-way TP behaves as pad-to-32).
        ok = dim % size == 0 if strict else dim >= size
        if group and ok:
            spec.append(group if len(group) > 1 else group[0])
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec))
    )
