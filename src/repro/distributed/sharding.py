"""Sharding rules: parameter-path patterns → PartitionSpec.

Mesh axes (launch/mesh.py):
  single-pod:  ("data", "model")           = (16, 16)
  multi-pod:   ("pod", "data", "model")    = (2, 16, 16)

Policy (DESIGN.md §4):
  * 2-D "fsdp × tensor" parameter sharding: the d_model-like dimension of
    every large matrix shards over ``data`` (ZeRO-3), the ffn/head/vocab/
    expert dimension over ``model`` (tensor/expert parallelism).
  * ``pod`` is pure data parallelism (DCN): params replicated across pods,
    gradients all-reduced over (pod, data).
  * Activations: batch over (pod, data); sequence-parallel fallback for
    batch < |data| cells (long_500k) is handled by the batch specs below.
  * Optimizer state shards exactly like its parameter.

Rules are (regex, spec-builder) pairs matched against "path/like/this"
parameter paths; first match wins.  ``spec(mesh)`` drops axes the mesh does
not have, so one rule set serves both meshes.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "param_specs",
    "param_shardings",
    "batch_specs",
    "state_shardings",
    "serve_param_specs",
    "serve_state_specs",
    "logical_to_sharding",
]

# dimension-name → mesh-axis mapping
_FSDP = "data"  # ZeRO-3 axis
_TP = "model"  # tensor/expert axis


def _spec(*axes):
    return P(*axes)


# (regex over param path, PartitionSpec with logical names). Paths use '/'.
# Order matters: first full match wins (unembed before embed!).
_RULES: list[tuple[str, P]] = [
    # unembedding: d_model over data, vocab over model (plain matmul)
    (r".*unembed$", P(_FSDP, _TP)),  # (D, V) [(C, D, V) rank-aligns]
    # embedding: vocab replicated — a vocab-sharded gather forces SPMD full
    # rematerialization; d_model over both axes instead
    (r".*embed$", P(None, (_FSDP, _TP))),  # (V, D)
    # attention
    (r".*mixer/wq$", P(_FSDP, _TP, None)),  # (D, H, hd)
    (r".*mixer/wk$", P(_FSDP, _TP, None)),
    (r".*mixer/wv$", P(_FSDP, _TP, None)),
    (r".*mixer/wo$", P(_TP, None, _FSDP)),  # (H, hd, D)
    (r".*mixer/b[qkv]$", P(_TP, None)),  # (H, hd)
    # griffin / rg-lru
    (r".*mixer/w_(x|gate)$", P(_FSDP, _TP)),  # (D, R)
    (r".*mixer/w_out$", P(_TP, _FSDP)),  # (R, D)
    (r".*mixer/w_(a|i)$", P(_TP, None)),  # (R, R) diag-ish gates
    (r".*mixer/conv$", P(None, _TP)),  # (K, R)
    (r".*mixer/(lam|b_a|b_i)$", P(_TP)),  # (R,)
    # mlstm / slstm
    (r".*mixer/w_up$", P(_FSDP, _TP)),
    (r".*mixer/w_down$", P(_TP, _FSDP)),
    (r".*mixer/w(q|k|v)$", P(_TP, None, None)),  # (di, H, hd) — di over model
    (r".*mixer/w_if$", P(_TP, None)),
    (r".*mixer/w_in$", P(_FSDP, _TP)),  # slstm (D, 4di)
    (r".*mixer/r_in$", P(None, None, _TP, None)),  # (4, H, hd, hd) — hd
    # over model (H is tiny for xLSTM's 4-head sLSTM)
    (r".*mixer/(skip_scale|b)$", P(_TP)),
    # MoE: experts over model, fsdp over d_model dim
    (r".*ffn/router$", P(_FSDP, None)),  # (D, E) — small
    (r".*ffn/experts_in$", P(_TP, _FSDP, None)),  # (E, D, F)
    (r".*ffn/experts_out$", P(_TP, None, _FSDP)),  # (E, F, D)
    (r".*ffn/shared_in$", P(_FSDP, _TP)),
    (r".*ffn/shared_out$", P(_TP, _FSDP)),
    # dense FFN
    (r".*ffn/w_in$", P(_FSDP, _TP)),  # (D, 2F)
    (r".*ffn/w_out$", P(_TP, _FSDP)),  # (F, D)
    # norms and anything 1-D: replicate
    (r".*scale$", P()),
    (r".*", P()),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _mesh_axes(mesh: Mesh) -> set[str]:
    return set(mesh.axis_names)


def _filter_spec(spec: P, mesh: Mesh, ndim: int, shape=None) -> P:
    """Drop axes the mesh lacks; align rank; drop non-divisible shardings.

    pjit *argument* shardings require exact divisibility (unlike activation
    constraints, where GSPMD pads unevenly), so non-divisible dims replicate.
    """
    axes = list(spec)
    # rank-align: stacked (scan) params gain a leading layer axis — prepend
    # None.  A rule with MORE axes than the leaf is a mismatch: replicate.
    while len(axes) < ndim:
        axes = [None] + axes
    if len(axes) > ndim:
        return P()
    names = _mesh_axes(mesh)
    out = []
    for i, a in enumerate(axes):
        group = a if isinstance(a, tuple) else (a,) if a is not None else ()
        group = tuple(g for g in group if g in names)
        if group and shape is not None:
            if shape[i] % int(np.prod([mesh.shape[g] for g in group])) != 0:
                group = ()  # non-divisible: replicate this dim
        out.append(group if len(group) > 1 else (group[0] if group else None))
    return P(*out)


def param_specs(params: Any, mesh: Mesh) -> Any:
    """PartitionSpec pytree matching ``params``."""

    def one(path, leaf):
        ps = _path_str(path)
        for pat, spec in _RULES:
            if re.fullmatch(pat, ps):
                return _filter_spec(spec, mesh, leaf.ndim, leaf.shape)
        return P()

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh)
    )


def serve_param_specs(params: Any, mesh: Mesh) -> Any:
    """Inference-time parameter specs: TP/expert sharding only, NO ZeRO-3.

    At serve time there is no optimizer state, so per-layer fsdp weight
    all-gathers are pure overhead on the decode critical path (§Perf
    iteration 1c): drop the `data` axis from every param spec — weights are
    replicated across data-parallel replicas like every serving system does,
    and per-device memory is params_bytes/|model| with no optimizer.
    """

    def strip(spec: P) -> P:
        out = []
        for a in spec:
            group = a if isinstance(a, tuple) else (a,) if a else ()
            group = tuple(g for g in group if g != _FSDP)
            out.append(group if len(group) > 1 else (group[0] if group else None))
        return P(*out)

    return jax.tree.map(
        strip, param_specs(params, mesh), is_leaf=lambda x: isinstance(x, P)
    )


def state_shardings(opt_state: Any, params_specs: Any, mesh: Mesh) -> Any:
    """Optimizer state shards like its parameter; scalars replicate."""

    def one(leaf):
        return NamedSharding(mesh, P())

    # OptState = (step, inner) where inner mirrors params (m/v dicts)
    import jax.tree_util as jtu

    def map_state(state):
        step, inner = state
        step_s = NamedSharding(mesh, P())
        if isinstance(inner, dict):  # adamw {m, v}
            inner_s = {
                k: jax.tree.map(
                    lambda s: NamedSharding(mesh, s), params_specs
                )
                for k in inner
            }
        elif inner == ():
            inner_s = ()
        else:  # momentum: tree like params
            inner_s = jax.tree.map(lambda s: NamedSharding(mesh, s), params_specs)
        return type(state)(step_s, inner_s)

    return map_state(opt_state)


def batch_specs(
    mesh: Mesh,
    batch_shape_tree: dict,
    seq_shard: bool = False,
    dp_over_model: bool = False,
) -> dict:
    """Input batch specs: batch dim over (pod, data) — plus `model` in
    dp_over_model mode (forward-only throughput programs); optionally shard
    the sequence dim over data instead (long-context, batch=1 cells)."""
    names = _mesh_axes(mesh)
    dp_names = ("pod", "data", "model") if dp_over_model else ("pod", "data")
    dp = tuple(a for a in dp_names if a in names)

    def one(name, arr):
        ndim = len(arr.shape)
        b = arr.shape[0]
        dp_size = int(np.prod([mesh.shape[a] for a in dp]))
        if name == "weights":
            return P(dp if b % dp_size == 0 else None)
        if b % dp_size != 0:
            # batch not shardable (e.g. long_500k batch=1): shard sequence
            if seq_shard and ndim >= 2 and arr.shape[1] % mesh.shape.get("data", 1) == 0:
                return P(None, "data") if ndim == 2 else P(None, "data", *(None,) * (ndim - 2))
            # greedy prefix of dp axes whose cumulative product divides b
            dp_fit: list = []
            prod = 1
            for a in dp:
                if b % (prod * mesh.shape[a]) == 0:
                    dp_fit.append(a)
                    prod *= mesh.shape[a]
            dp_fit = tuple(dp_fit)
            return P(dp_fit if dp_fit else None, *(None,) * (ndim - 1))
        return P(dp, *(None,) * (ndim - 1))

    return {k: one(k, v) for k, v in batch_shape_tree.items()}


def serve_state_specs(state_tree: Any, mesh: Mesh, batch: int) -> Any:
    """Sharding for decode caches/recurrent states (heuristic, shape-driven).

    Per leaf:
      * the dim whose size == ``batch`` shards over (pod, data) when
        divisible (synchronized batched decode);
      * the *last* remaining divisible dim shards over ``model`` — head_dim
        for KV caches, value dim for mLSTM memories, recurrence width for
        RG-LRU.  Sharding the *sequence* dim (split-KV) is tempting but
        GSPMD cannot partition the per-step dynamic_update_slice into a
        sharded dim: it all-gathers the cache every layer (measured 135x
        collective blow-up — §Perf iteration 1); contraction-dim sharding
        keeps cache updates local and costs only a small partial-sum
        all-reduce of the scores;
      * if the batch dim could not shard (long_500k batch=1), the largest
        remaining divisible dim additionally takes ``data``.
    """
    names = _mesh_axes(mesh)
    dp = tuple(a for a in ("pod", "data") if a in names)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    tp_size = mesh.shape.get(_TP, 1)
    data_size = mesh.shape.get("data", 1)

    def one(leaf):
        shape = leaf.shape
        ndim = len(shape)
        axes: list = [None] * ndim
        used = set()
        # batch dim
        b_dim = None
        for i, s in enumerate(shape):
            if s == batch and batch % dp_size == 0 and batch >= dp_size:
                axes[i] = dp
                b_dim = i
                used.add(i)
                break
        # model dim: last remaining divisible dim (see docstring)
        cand = [
            i
            for i in range(ndim)
            if i not in used and shape[i] % tp_size == 0 and shape[i] >= tp_size
        ]
        if cand and tp_size > 1:
            mi = cand[-1]
            axes[mi] = _TP
            used.add(mi)
        # orphaned data axis (batch unshardable): next largest divisible dim
        if b_dim is None and data_size > 1:
            cand = [
                (shape[i], i)
                for i in range(ndim)
                if i not in used
                and shape[i] % data_size == 0
                and shape[i] >= data_size
            ]
            if cand:
                _, di = max(cand)
                axes[di] = "data"
        return NamedSharding(mesh, P(*axes))

    return jax.tree.map(one, state_tree)


def logical_to_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)
