"""Distribution substrate: sharding rules, collectives, compression,
hierarchical tree selection (``tree_select`` in-process/mesh drivers,
``process_tree`` KV-store driver for multi-process CPU)."""
from repro.distributed import collectives, compression, sharding, tree_select

__all__ = ["collectives", "compression", "sharding", "tree_select"]
