"""Distribution substrate: sharding rules, collectives, compression."""
from repro.distributed import collectives, compression, sharding

__all__ = ["collectives", "compression", "sharding"]
