"""Process-spanning tree selection over the ``jax.distributed`` KV store.

XLA's CPU backend has no cross-process collectives ("Multiprocess
computations aren't implemented on the CPU backend"), so
``tree_select_mesh`` cannot span processes off-TPU/GPU.  This driver
runs the same tree with one *process* per leaf, using the coordination
service's key-value store — available on every backend the moment
``jax.distributed.initialize`` has run — as the candidate wire:

* every live node at a level serializes its candidate payload
  (int8-quantized features + fp32 per-row scales, or raw fp32 under
  ``compress='none'``) into the KV store;
* each parent *owner* (the lowest-pid process under the parent) blocks
  on its children's keys, dequantizes, and runs the same ``merge_round``;
* the root owner publishes the final medoids (exact fp32 — the
  dequantized values are fp32-representable, so every process re-weights
  against bit-identical medoids);
* re-weighting partials are combined in pid order, matching the host
  driver's leaf-order accumulation.

The selection is bit-identical to ``tree_select_host`` on the
concatenated pool (indices and weights exactly; coverage to float-sum
association), because every payload — including a merge owner's own —
passes through the same wire codec in the same leaf order.  The tier-2
CI lane (``tests/test_multiprocess_tree.py``) runs this end to end with
2 real processes.

Keys are namespaced by a per-call tag; the default tag comes from a
module-level counter, so all processes must make the same sequence of
calls (the usual SPMD contract).  Payload shapes are derived from the
static (r, d) candidate-set sizes, so no shape metadata crosses the
wire.
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import leaf_round, merge_round, resolve_round1_config
from repro.core.engines import EngineConfig
from repro.distributed.compression import (
    dequantize_rows_int8,
    quantize_rows_int8,
)
from repro.distributed.tree_select import (
    WIRE_MODES,
    TreeSelection,
    TreeTopology,
    _check_tree_counts,
    default_r_node,
    wire_bytes_plan,
)

__all__ = ["tree_select_processes", "kv_client"]

_CALLS = itertools.count()
_TIMEOUT_MS = 300_000


def kv_client():
    """The coordination-service KV client (requires
    ``jax.distributed.initialize``).  ``jax.distributed.global_state`` is
    not public API on the pinned jax, so reach through ``jax._src``."""
    from jax._src import distributed

    client = distributed.global_state.client
    if client is None:
        raise RuntimeError(
            "tree_select_processes needs the jax.distributed coordination "
            "service — call repro.launch.tree.initialize_distributed() "
            "(or jax.distributed.initialize) in every process first"
        )
    return client


def _put(client, key: str, arr: np.ndarray) -> None:
    client.key_value_set_bytes(key, np.ascontiguousarray(arr).tobytes())


def _get(client, key: str, shape, dtype) -> np.ndarray:
    raw = client.blocking_key_value_get_bytes(key, _TIMEOUT_MS)
    return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()


def _put_payload(client, key, feats, w, gidx, compress):
    feats = np.asarray(feats, np.float32)
    if compress == "int8":
        q, s = quantize_rows_int8(jnp.asarray(feats))
        _put(client, key + "/q", np.asarray(q))
        _put(client, key + "/s", np.asarray(s))
    else:
        _put(client, key + "/f", feats)
    _put(client, key + "/w", np.asarray(w, np.float32))
    _put(client, key + "/g", np.asarray(gidx, np.int64))


def _get_payload(client, key, r, d, compress):
    if compress == "int8":
        q = _get(client, key + "/q", (r, d), np.int8)
        s = _get(client, key + "/s", (r,), np.float32)
        feats = np.asarray(dequantize_rows_int8(jnp.asarray(q), jnp.asarray(s)))
    else:
        feats = _get(client, key + "/f", (r, d), np.float32)
    w = _get(client, key + "/w", (r,), np.float32)
    gidx = _get(client, key + "/g", (r,), np.int64)
    return feats, w, gidx


def tree_select_processes(
    feats_local: jax.Array,
    topology: TreeTopology,
    r_local: int,
    r_final: int,
    *,
    r_node: int | None = None,
    local_engine: str | EngineConfig = "auto",
    compress: str = "int8",
    squared_coverage: bool = False,
    tag: str | None = None,
) -> TreeSelection:
    """Hierarchical selection with one process per leaf (SPMD: every
    process calls with its own ``(n_pid, d)`` shard; ragged shard sizes
    are fine).  Returns the full replicated :class:`TreeSelection` in
    every process, with global indices into the pid-order concatenated
    pool."""
    if compress not in WIRE_MODES:
        raise ValueError(
            f"compress={compress!r} is not a wire mode; expected one of "
            f"{WIRE_MODES}"
        )
    pid = jax.process_index()
    nproc = jax.process_count()
    if nproc != topology.n_leaves:
        raise ValueError(
            f"tree_select_processes: topology has {topology.n_leaves} "
            f"leaves but {nproc} processes are running — one process per "
            "leaf"
        )
    client = kv_client()
    tag = f"tree/{next(_CALLS)}" if tag is None else f"tree/{tag}"
    feats_local = jnp.asarray(feats_local, jnp.float32)
    n_local, d = feats_local.shape
    r_node = default_r_node(r_local, r_final) if r_node is None else int(r_node)

    # Global index base: publish shard sizes, prefix-sum in pid order.
    client.key_value_set(f"{tag}/n/{pid}", str(n_local))
    sizes = [
        int(client.blocking_key_value_get(f"{tag}/n/{p}", _TIMEOUT_MS))
        for p in range(nproc)
    ]
    _check_tree_counts(
        sizes, topology, r_local, r_node, r_final,
        where="tree_select_processes",
    )
    base = sum(sizes[:pid])
    engine_cfg = resolve_round1_config(local_engine, {}, min(sizes))

    local_idx, local_w = leaf_round(feats_local, r_local, engine_cfg)
    cand_feats = np.asarray(feats_local[local_idx], np.float32)
    cand_w = np.asarray(local_w, np.float32)
    cand_gidx = base + np.asarray(local_idx, np.int64)

    # Merge levels: live node owners publish, parent owners merge.  A
    # process owns its level-l node iff pid % stride == 0.
    stride = 1
    r = r_local
    for level, fanout in enumerate(topology.fanouts):
        if pid % stride == 0:
            node = pid // stride
            _put_payload(
                client, f"{tag}/l{level}/{node}", cand_feats, cand_w,
                cand_gidx, compress,
            )
        parent_stride = stride * fanout
        budget = r_final if level == topology.depth - 1 else min(
            r_node, fanout * r
        )
        if pid % parent_stride == 0:
            first_child = (pid // stride)  # == pid // stride, a multiple of fanout
            feats_l, w_l, gidx_l = [], [], []
            for c in range(first_child, first_child + fanout):
                f, w, g = _get_payload(
                    client, f"{tag}/l{level}/{c}", r, d, compress
                )
                feats_l.append(f)
                w_l.append(w)
                gidx_l.append(g)
            union_feats = jnp.asarray(np.concatenate(feats_l))
            union_w = jnp.asarray(np.concatenate(w_l))
            union_gidx = np.concatenate(gidx_l)
            res = merge_round(union_feats, union_w, budget)
            keep = np.asarray(res.indices)
            cand_feats = np.asarray(union_feats, np.float32)[keep]
            cand_w = np.asarray(res.weights, np.float32)
            cand_gidx = union_gidx[keep]
        stride = parent_stride
        r = budget

    # Root broadcast: exact fp32 medoid features + global ids.
    if pid == 0:
        _put(client, f"{tag}/root/f", cand_feats)
        _put(client, f"{tag}/root/g", cand_gidx)
    root_feats = jnp.asarray(
        _get(client, f"{tag}/root/f", (r_final, d), np.float32)
    )
    root_gidx = _get(client, f"{tag}/root/g", (r_final,), np.int64)

    # Exact global re-weighting: local partials combined in pid order
    # (matches the host driver's leaf-order accumulation).
    sqx = jnp.sum(feats_local * feats_local, axis=-1)
    sqm = jnp.sum(root_feats * root_feats, axis=-1)
    d2 = sqx[:, None] + sqm[None, :] - 2.0 * feats_local @ root_feats.T
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    assign = jnp.argmin(dist, axis=1)
    local_counts = jnp.zeros((r_final,), jnp.float32).at[assign].add(1.0)
    min_dist = jnp.min(dist, axis=1)
    residual = jnp.square(min_dist) / 2.0 if squared_coverage else min_dist
    partial = np.concatenate(
        [np.asarray(local_counts, np.float32),
         np.asarray(jnp.sum(residual), np.float32).reshape(1)]
    )
    _put(client, f"{tag}/rw/{pid}", partial)
    counts = jnp.zeros((r_final,), jnp.float32)
    coverage = jnp.zeros((), jnp.float32)
    for p in range(nproc):
        part = _get(client, f"{tag}/rw/{p}", (r_final + 1,), np.float32)
        counts = counts + jnp.asarray(part[:r_final])
        coverage = coverage + jnp.float32(part[r_final])

    wire = wire_bytes_plan(topology, r_local, r_node, d, compress)
    return TreeSelection(
        jnp.asarray(root_gidx.astype(np.int32)), counts, coverage, wire
    )
