"""Process-spanning tree selection over the ``jax.distributed`` KV store.

XLA's CPU backend has no cross-process collectives ("Multiprocess
computations aren't implemented on the CPU backend"), so
``tree_select_mesh`` cannot span processes off-TPU/GPU.  This driver
runs the same tree with one *process* per leaf, using the coordination
service's key-value store — available on every backend the moment
``jax.distributed.initialize`` has run — as the candidate wire:

* every live node at a level serializes its candidate payload
  (int8-quantized features + fp32 per-row scales, or raw fp32 under
  ``compress='none'``) into the KV store;
* each parent *owner* (the lowest-pid process under the parent) blocks
  on its children's keys, dequantizes, and runs the same ``merge_round``;
* the root owner publishes the final medoids (exact fp32 — the
  dequantized values are fp32-representable, so every process re-weights
  against bit-identical medoids);
* re-weighting partials are combined in pid order, matching the host
  driver's leaf-order accumulation.

With every process alive the selection is bit-identical to
``tree_select_host`` on the concatenated pool (indices and weights
exactly; coverage to float-sum association), because every payload —
including a merge owner's own — passes through the same wire codec in
the same leaf order.  The tier-2 CI lane
(``tests/test_multiprocess_tree.py``) runs this end to end with real
processes, including a chaos case that SIGKILLs a leaf mid-round.

Fault model (DESIGN.md §12).  Every process publishes a heartbeat key on
a background thread; every *wait* on another process's key is bounded by
a per-level deadline (``HealthConfig.level_deadline_s``, defaulting to
the ``REPRO_KV_TIMEOUT_MS`` env knob) and monitored against the
publisher's heartbeat.  When a child subtree misses its deadline or its
owner's heartbeat goes silent, the parent owner *proceeds without it* —
provided the surviving leaves still meet ``HealthConfig.min_quorum`` —
and records the loss in a dead-leaf mask that composes up the tree
(payload published first, mask last, so a mask's arrival guarantees its
payload is readable).  The root's mask is authoritative: every process
learns the final excluded set from it, excluded-but-alive processes
raise :class:`ShardExcludedError` (the straggler-exclusion contract),
and the returned :class:`TreeSelection` carries a ``health`` record
(``degraded``, ``missing_pids``, achieved ``quorum``) with Σγ equal to
the *surviving* shards' pool size.  CREST's observation (selection from
pool subsets still converges, PAPERS.md) is what makes proceeding on a
quorum principled rather than heuristic.

Failure-domain limits, by design: a dead merge *owner* loses its whole
subtree's candidates (non-owner survivors below it are excluded and
raise); the root owner (pid 0) and any process dying *after* the root
broadcast (re-weight partials) are single points of failure — those
deaths surface as :class:`KVStoreError` after the deadline, not as
degradation.

Keys are namespaced by a per-call tag; the default tag comes from a
module-level counter, so all processes must make the same sequence of
calls (the usual SPMD contract).  Payload shapes are derived from the
static (r, d) candidate-set sizes plus the shared dead-leaf masks, so no
shape metadata crosses the wire.
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributed import leaf_round, merge_round, resolve_round1_config
from repro.core.engines import EngineConfig
from repro.distributed.compression import (
    dequantize_rows_int8,
    quantize_rows_int8,
)
from repro.distributed.tree_select import (
    WIRE_MODES,
    TreeSelection,
    TreeTopology,
    _check_tree_counts,
    default_r_node,
    wire_bytes_plan,
)
from repro.faults import fault_point

__all__ = [
    "tree_select_processes",
    "kv_client",
    "kv_timeout_ms",
    "HealthConfig",
    "KVStoreError",
    "QuorumError",
    "ShardExcludedError",
    "KV_TIMEOUT_ENV",
]

_CALLS = itertools.count()

KV_TIMEOUT_ENV = "REPRO_KV_TIMEOUT_MS"
_DEFAULT_TIMEOUT_MS = 300_000


def kv_timeout_ms() -> int:
    """Default KV-store blocking-get timeout in ms.

    Reads the ``REPRO_KV_TIMEOUT_MS`` env knob (replacing the old
    hardcoded 300 s constant); also the default per-level deadline when
    :class:`HealthConfig` does not set one explicitly.
    """
    raw = os.environ.get(KV_TIMEOUT_ENV)
    if raw is None:
        return _DEFAULT_TIMEOUT_MS
    try:
        ms = int(raw)
    except ValueError as e:
        raise ValueError(
            f"${KV_TIMEOUT_ENV}={raw!r} is not an integer millisecond count"
        ) from e
    if ms <= 0:
        raise ValueError(f"${KV_TIMEOUT_ENV}={ms} must be > 0")
    return ms


class KVStoreError(RuntimeError):
    """A KV-store get failed terminally (missing key / dead peer past the
    point of graceful degradation); names the key, pid and tree level."""


class QuorumError(RuntimeError):
    """Too few surviving leaves to proceed (below ``min_quorum``)."""


class ShardExcludedError(RuntimeError):
    """This process was excluded from the selection (its subtree's owner
    died before publishing) — its shard is not represented in the result
    the survivors agreed on, so it must not use that result as its own."""


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Liveness/degradation knobs for :func:`tree_select_processes`.

    Attributes:
      level_deadline_s: how long a parent owner waits for one child
        subtree's payload before declaring it dead (None → the
        ``REPRO_KV_TIMEOUT_MS`` env knob, itself defaulting to 300 s —
        the legacy behavior).
      heartbeat_interval_s: liveness-key publish period.
      heartbeat_grace_s: silence longer than this marks a peer dead
        (must cover GC/compile pauses; ≥ 2× the interval).
      poll_ms: KV poll slice while waiting under a deadline.
      min_quorum: minimum surviving-leaf fraction per merge group; below
        it the selection fails with :class:`QuorumError` instead of
        degrading (1.0 = any death is fatal, the pre-fault-model
        behavior except it fails within the deadline, not 300 s).
    """

    level_deadline_s: float | None = None
    heartbeat_interval_s: float = 0.5
    heartbeat_grace_s: float = 5.0
    poll_ms: int = 100
    min_quorum: float = 1.0

    def __post_init__(self):
        if self.level_deadline_s is not None and self.level_deadline_s <= 0:
            raise ValueError(
                f"level_deadline_s={self.level_deadline_s} must be > 0"
            )
        if self.heartbeat_interval_s <= 0:
            raise ValueError(
                f"heartbeat_interval_s={self.heartbeat_interval_s} must be > 0"
            )
        if self.heartbeat_grace_s < 2 * self.heartbeat_interval_s:
            raise ValueError(
                f"heartbeat_grace_s={self.heartbeat_grace_s} must be ≥ 2× "
                f"heartbeat_interval_s={self.heartbeat_interval_s} or every "
                "scheduling hiccup reads as a death"
            )
        if int(self.poll_ms) < 1:
            raise ValueError(f"poll_ms={self.poll_ms} must be ≥ 1")
        if not 0.0 < self.min_quorum <= 1.0:
            raise ValueError(
                f"min_quorum={self.min_quorum} must be in (0, 1]"
            )

    def deadline_s(self) -> float:
        return (
            kv_timeout_ms() / 1000.0
            if self.level_deadline_s is None
            else float(self.level_deadline_s)
        )


def kv_client():
    """The coordination-service KV client (requires
    ``jax.distributed.initialize``).  ``jax.distributed.global_state`` is
    not public API on the pinned jax, so reach through ``jax._src``."""
    from jax._src import distributed

    client = distributed.global_state.client
    if client is None:
        raise RuntimeError(
            "tree_select_processes needs the jax.distributed coordination "
            "service — call repro.launch.tree.initialize_distributed() "
            "(or jax.distributed.initialize) in every process first"
        )
    return client


# ---------------------------------------------------------------------------
# KV wire primitives.  _raw_get_bytes is the ONLY call site of the raw
# blocking getters (repro-lint's kv-deadline rule enforces this); polling
# uses the NON-blocking directory listing instead — repeated short-timeout
# blocking gets race the coordination client's RPC teardown and segfault
# (observed on the pinned jaxlib), so every key a process may *poll*
# (heartbeats, dead masks, canonical sizes) carries a STRING value readable
# via key_value_dir_get, and bulk binary payloads are only ever read with a
# full-deadline blocking get after their commit record has arrived.
# ---------------------------------------------------------------------------


def _raw_get_bytes(client, key: str, timeout_ms: int) -> bytes:
    return client.blocking_key_value_get_bytes(key, int(timeout_ms))


def _put_cell(client, key: str, value: str) -> None:
    """Publish a *polled cell*: a UTF-8 string value at ``{key}/v`` (the
    directory listing has directory semantics — it matches ``{key}/…``,
    never ``{key}`` itself — so pollable values live one level down)."""
    client.key_value_set(f"{key}/v", str(value))


def _poll_str(client, key: str) -> str | None:
    """Non-blocking read of the polled cell at ``key``: its string value,
    or None if absent (any transport error reads as absent — the
    *deadline* decides when absence becomes an error)."""
    try:
        fault_point("kv.get", key=key)
        entries = client.key_value_dir_get(key)
    except Exception:  # noqa: BLE001 — absence, by contract
        return None
    for k, v in entries:
        if k == f"{key}/v":
            return v
    return None


def _encode_mask(mask: np.ndarray) -> str:
    return "".join("1" if x else "0" for x in mask)


def _decode_mask(s: str) -> np.ndarray:
    return np.array([c == "1" for c in s], np.int8)


def _kv_get(
    client,
    key: str,
    shape,
    dtype,
    *,
    pid: int,
    level,
    what: str,
    timeout_ms: int | None = None,
) -> np.ndarray:
    """Blocking KV get with a deadline and a contextual error: any failure
    (timeout, dropped key, transport) surfaces as a :class:`KVStoreError`
    naming the key, the waiting pid and the tree level — never the raw
    XLA/coordination-service exception."""
    timeout_ms = kv_timeout_ms() if timeout_ms is None else int(timeout_ms)
    try:
        fault_point("kv.get", key=key, pid=pid, level=level)
        raw = _raw_get_bytes(client, key, timeout_ms)
    except Exception as e:  # noqa: BLE001 — re-raised with full context
        raise KVStoreError(
            f"KV get of key {key!r} ({what}) failed in pid {pid} at tree "
            f"level {level} after {timeout_ms} ms: {type(e).__name__}: {e}"
        ) from e
    return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()


class _Heartbeat:
    """Publishes ``{tag}/hb/{pid}/{seq}`` every interval on a daemon
    thread (the KV store has no TTL or delete, so liveness is a growing
    sequence of per-beat keys, consumed in order by monitors)."""

    def __init__(self, client, tag: str, pid: int, interval_s: float):
        self._client = client
        self._key = f"{tag}/hb/{pid}"
        self._interval_s = float(interval_s)
        self._stop = threading.Event()
        self.error: BaseException | None = None
        self._thread = threading.Thread(
            target=self._run, name=f"tree-heartbeat-{pid}", daemon=True
        )
        self._thread.start()

    def _run(self) -> None:
        seq = 0
        try:
            while not self._stop.is_set():
                self._client.key_value_set(f"{self._key}/{seq}", "1")
                seq += 1
                self._stop.wait(self._interval_s)
        except BaseException as e:  # noqa: BLE001 — surfaced via .error
            self.error = e

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=5.0)


class _HeartbeatMonitor:
    """Watches one peer's heartbeat directory; ``alive()`` is False once
    the peer has been silent longer than the grace window.  A growing
    beat count (one listing per check — O(beats), fine at selection
    timescales) refreshes the last-seen clock."""

    def __init__(self, client, tag: str, pid: int, grace_s: float):
        self._client = client
        self._key = f"{tag}/hb/{pid}"
        self._grace_s = float(grace_s)
        self._n_beats = 0
        self._last_seen = time.monotonic()  # creation counts as a beat

    def alive(self) -> bool:
        try:
            n = len(self._client.key_value_dir_get(self._key))
        except Exception:  # noqa: BLE001 — transient listing failure
            n = self._n_beats
        if n > self._n_beats:
            self._n_beats = n
            self._last_seen = time.monotonic()
        return time.monotonic() - self._last_seen < self._grace_s


def _await_key(
    client,
    key: str,
    *,
    deadline_s: float,
    poll_ms: int,
    monitor: _HeartbeatMonitor | None = None,
) -> str | None:
    """Wait for the polled cell at ``key`` under a deadline, optionally
    monitoring its publisher's heartbeat.  Returns the string value, or
    None when the deadline expires or the publisher dies first.  A dead
    publisher gets ONE final probe — publish-then-die is a committed
    publish and must be honored (the payload-before-mask ordering relies
    on exactly this)."""
    deadline = time.monotonic() + float(deadline_s)
    poll_s = max(1, int(poll_ms)) / 1000.0
    while True:
        val = _poll_str(client, key)
        if val is not None:
            return val
        now = time.monotonic()
        if now >= deadline:
            return None
        if monitor is not None and not monitor.alive():
            return _poll_str(client, key)
        time.sleep(min(poll_s, deadline - now))


# ---------------------------------------------------------------------------
# Degraded candidate counts
# ---------------------------------------------------------------------------


def _nominal_r(
    level: int, topology: TreeTopology, r_local: int, r_node: int, r_final: int
) -> int:
    """Candidate count a node holds after ``level`` merges, clean tree."""
    if level == 0:
        return int(r_local)
    fanout = topology.fanouts[level - 1]
    below = _nominal_r(level - 1, topology, r_local, r_node, r_final)
    if level == topology.depth:
        return int(r_final)
    return min(int(r_node), fanout * below)


def _node_r(
    level: int,
    node: int,
    dead: np.ndarray,
    topology: TreeTopology,
    r_local: int,
    r_node: int,
    r_final: int,
) -> int:
    """Candidate count node ``node`` holds after ``level`` merges given the
    dead-leaf mask — exactly :func:`_nominal_r` when its subtree is clean,
    ``min(declared budget, surviving union)`` otherwise, 0 when the whole
    subtree is dead.  Both sides of every wire derive payload shapes from
    this, so a parent always reads exactly what a degraded child wrote."""
    if level == 0:
        return 0 if dead[node] else int(r_local)
    fanout = topology.fanouts[level - 1]
    union = sum(
        _node_r(
            level - 1, node * fanout + c, dead, topology,
            r_local, r_node, r_final,
        )
        for c in range(fanout)
    )
    if union == 0:
        return 0
    return min(
        _nominal_r(level, topology, r_local, r_node, r_final), union
    )


def _require_quorum(
    alive_leaves: int,
    total_leaves: int,
    min_quorum: float,
    *,
    level,
    node: int,
    missing: list[int],
) -> None:
    if alive_leaves / max(total_leaves, 1) < min_quorum - 1e-9:
        raise QuorumError(
            f"tree_select_processes: merge level {level} node {node} has "
            f"only {alive_leaves}/{total_leaves} surviving leaves, below "
            f"min_quorum={min_quorum} (dead pids: {sorted(missing)})"
        )


# ---------------------------------------------------------------------------
# Wire payloads
# ---------------------------------------------------------------------------


def _put(client, key: str, arr: np.ndarray) -> None:
    client.key_value_set_bytes(key, np.ascontiguousarray(arr).tobytes())


def _put_payload(client, key, feats, w, gidx, compress):
    feats = np.asarray(feats, np.float32)
    if compress == "int8":
        q, s = quantize_rows_int8(jnp.asarray(feats))
        _put(client, key + "/q", np.asarray(q))
        _put(client, key + "/s", np.asarray(s))
    else:
        _put(client, key + "/f", feats)
    _put(client, key + "/w", np.asarray(w, np.float32))
    _put(client, key + "/g", np.asarray(gidx, np.int64))


def _get_payload(client, key, r, d, compress, *, pid, level, timeout_ms=None):
    kw = dict(pid=pid, level=level, timeout_ms=timeout_ms)
    if compress == "int8":
        q = _kv_get(client, key + "/q", (r, d), np.int8,
                    what="candidate int8 payload", **kw)
        s = _kv_get(client, key + "/s", (r,), np.float32,
                    what="candidate scales", **kw)
        feats = np.asarray(dequantize_rows_int8(jnp.asarray(q), jnp.asarray(s)))
    else:
        feats = _kv_get(client, key + "/f", (r, d), np.float32,
                        what="candidate fp32 payload", **kw)
    w = _kv_get(client, key + "/w", (r,), np.float32,
                what="candidate weights", **kw)
    gidx = _kv_get(client, key + "/g", (r,), np.int64,
                   what="candidate global ids", **kw)
    return feats, w, gidx


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def tree_select_processes(
    feats_local: jax.Array,
    topology: TreeTopology,
    r_local: int,
    r_final: int,
    *,
    r_node: int | None = None,
    local_engine: str | EngineConfig = "auto",
    compress: str = "int8",
    squared_coverage: bool = False,
    tag: str | None = None,
    health: HealthConfig | None = None,
) -> TreeSelection:
    """Hierarchical selection with one process per leaf (SPMD: every
    process calls with its own ``(n_pid, d)`` shard; ragged shard sizes
    are fine).  Returns the full replicated :class:`TreeSelection` in
    every surviving process, with global indices into the pid-order
    concatenation of the *surviving* shards; its ``health`` field records
    any quorum degradation (module docstring)."""
    if compress not in WIRE_MODES:
        raise ValueError(
            f"compress={compress!r} is not a wire mode; expected one of "
            f"{WIRE_MODES}"
        )
    health = HealthConfig() if health is None else health
    pid = jax.process_index()
    nproc = jax.process_count()
    if nproc != topology.n_leaves:
        raise ValueError(
            f"tree_select_processes: topology has {topology.n_leaves} "
            f"leaves but {nproc} processes are running — one process per "
            "leaf"
        )
    client = kv_client()
    tag = f"tree/{next(_CALLS)}" if tag is None else f"tree/{tag}"
    feats_local = jnp.asarray(feats_local, jnp.float32)
    n_local, d = feats_local.shape
    r_node = default_r_node(r_local, r_final) if r_node is None else int(r_node)
    deadline_s = health.deadline_s()
    poll_ms = int(health.poll_ms)
    deadline_ms = int(deadline_s * 1000)

    hb = _Heartbeat(client, tag, pid, health.heartbeat_interval_s)
    try:
        monitors = {
            p: _HeartbeatMonitor(client, tag, p, health.heartbeat_grace_s)
            for p in range(nproc)
            if p != pid
        }

        # -- size exchange, root-arbitrated -------------------------------
        # pid 0 gathers every shard size (a leaf missing its deadline is
        # declared dead up front) and publishes ONE canonical size/death
        # vector, so every survivor agrees on the leaf-level dead set and
        # on the global index bases — no per-process divergence.
        _put_cell(client, f"{tag}/n/{pid}", str(n_local))
        if pid == 0:
            sizes = np.empty((nproc,), np.int64)
            sizes[0] = n_local
            for p in range(1, nproc):
                raw = _await_key(
                    client, f"{tag}/n/{p}",
                    deadline_s=deadline_s, poll_ms=poll_ms,
                    monitor=monitors[p],
                )
                sizes[p] = -1 if raw is None else int(raw)
            _put_cell(client, f"{tag}/sizes", ",".join(str(int(s)) for s in sizes))
        else:
            # 2× the level deadline per peer: covers pid 0's full gather
            raw = _await_key(
                client, f"{tag}/sizes",
                deadline_s=2 * deadline_s * max(1, nproc - 1),
                poll_ms=poll_ms, monitor=monitors[0],
            )
            if raw is None:
                raise KVStoreError(
                    f"KV get of key {tag + '/sizes'!r} (canonical shard "
                    f"sizes) failed in pid {pid} at tree level 0: the root "
                    "arbiter (pid 0) never published — pid 0 death is "
                    "fatal by design"
                )
            sizes = np.array([int(x) for x in raw.split(",")], np.int64)
        dead = np.zeros((nproc,), np.int8)
        dead[sizes < 0] = 1
        missing = [int(p) for p in np.nonzero(dead)[0]]
        if dead[pid]:  # we were declared dead but are alive: a straggler
            raise ShardExcludedError(
                f"pid {pid} missed the size-exchange deadline "
                f"({deadline_s:.1f} s) and was excluded from the selection"
            )
        _require_quorum(
            nproc - len(missing), nproc, health.min_quorum,
            level=0, node=0, missing=missing,
        )
        alive_sizes = [int(s) for s in sizes if s >= 0]
        _check_tree_counts(
            alive_sizes, topology, r_local, r_node, r_final,
            where="tree_select_processes",
        )
        # global index base over SURVIVING shards in pid order (a dead
        # shard's points are simply absent from the degraded pool)
        base = int(sum(s for s in sizes[:pid] if s >= 0))
        engine_cfg = resolve_round1_config(local_engine, {}, min(alive_sizes))

        local_idx, local_w = leaf_round(feats_local, r_local, engine_cfg)
        cand_feats = np.asarray(feats_local[local_idx], np.float32)
        cand_w = np.asarray(local_w, np.float32)
        cand_gidx = base + np.asarray(local_idx, np.int64)

        nr = dict(
            topology=topology, r_local=r_local, r_node=r_node, r_final=r_final
        )

        # -- merge levels -------------------------------------------------
        # Live node owners publish payload THEN their dead mask: the mask
        # is the commit record, so a mask's arrival guarantees the payload
        # is readable even if the publisher dies in between.
        stride = 1
        for level, fanout in enumerate(topology.fanouts):
            if pid % stride == 0 and not dead[pid]:
                node = pid // stride
                key = f"{tag}/l{level}/{node}"
                fault_point("tree.publish", pid=pid, level=level)
                _put_payload(
                    client, key, cand_feats, cand_w, cand_gidx, compress
                )
                _put_cell(client, key + "/dead", _encode_mask(dead))
            parent_stride = stride * fanout
            if pid % parent_stride == 0:
                first_child = pid // stride
                feats_l, w_l, gidx_l = [], [], []
                for c in range(first_child, first_child + fanout):
                    child_owner = c * stride
                    sub = slice(child_owner, child_owner + stride)
                    if c == first_child:
                        child_mask = dead.copy()  # our own subtree: local view
                    elif dead[sub].all():
                        continue  # known-dead since the size exchange
                    else:
                        raw = _await_key(
                            client, f"{tag}/l{level}/{c}/dead",
                            deadline_s=deadline_s, poll_ms=poll_ms,
                            monitor=monitors.get(child_owner),
                        )
                        if raw is None:
                            # a dead owner loses its whole subtree (module
                            # docstring): survivors below it get excluded
                            dead[sub] = 1
                            continue
                        child_mask = _decode_mask(raw)
                        dead = np.maximum(dead, child_mask)
                    child_r = _node_r(level, c, child_mask, **nr)
                    if child_r == 0:
                        continue
                    f, w, g = _get_payload(
                        client, f"{tag}/l{level}/{c}", child_r, d, compress,
                        pid=pid, level=level + 1, timeout_ms=deadline_ms,
                    )
                    feats_l.append(f)
                    w_l.append(w)
                    gidx_l.append(g)
                missing = [int(p) for p in np.nonzero(dead)[0]]
                group = slice(first_child * stride, (first_child + fanout) * stride)
                group_leaves = (group.stop - group.start)
                _require_quorum(
                    group_leaves - int(dead[group].sum()), group_leaves,
                    health.min_quorum,
                    level=level + 1, node=pid // parent_stride,
                    missing=missing,
                )
                union_feats = jnp.asarray(np.concatenate(feats_l))
                union_w = jnp.asarray(np.concatenate(w_l))
                union_gidx = np.concatenate(gidx_l)
                nominal = _nominal_r(level + 1, topology, r_local, r_node, r_final)
                budget = min(nominal, int(union_feats.shape[0]))
                res = merge_round(union_feats, union_w, budget)
                keep = np.asarray(res.indices)
                cand_feats = np.asarray(union_feats, np.float32)[keep]
                cand_w = np.asarray(res.weights, np.float32)
                cand_gidx = union_gidx[keep]
            stride = parent_stride

        # -- root broadcast ----------------------------------------------
        # Same commit ordering: medoids first, the authoritative final
        # dead mask last.  Everyone keys every remaining shape off that
        # mask, so survivors agree on r_root and on who re-weights.
        if pid == 0:
            fault_point("tree.publish", pid=pid, level=topology.depth)
            _put(client, f"{tag}/root/f", cand_feats)
            _put(client, f"{tag}/root/g", cand_gidx)
            _put_cell(client, f"{tag}/root/dead", _encode_mask(dead))
            root_mask = dead
        else:
            raw = _await_key(
                client, f"{tag}/root/dead",
                # pid 0 must finish every merge level first
                deadline_s=deadline_s * (topology.depth + 1),
                poll_ms=poll_ms, monitor=monitors[0],
            )
            if raw is None:
                raise KVStoreError(
                    f"KV get of key {tag + '/root/dead'!r} (final dead "
                    f"mask) failed in pid {pid} at tree level "
                    f"{topology.depth}: the root owner (pid 0) never "
                    "published — pid 0 death is fatal by design"
                )
            root_mask = _decode_mask(raw)
        if root_mask[pid]:
            raise ShardExcludedError(
                f"pid {pid} was excluded from the selection (its subtree's "
                "owner died before publishing its candidates); this "
                "shard's points are not represented in the survivors' "
                "result"
            )
        missing = [int(p) for p in np.nonzero(root_mask)[0]]
        r_root = _node_r(topology.depth, 0, root_mask, **nr)
        root_feats = jnp.asarray(
            _kv_get(
                client, f"{tag}/root/f", (r_root, d), np.float32,
                pid=pid, level=topology.depth, what="root medoid features",
                timeout_ms=deadline_ms,
            )
        )
        root_gidx = _kv_get(
            client, f"{tag}/root/g", (r_root,), np.int64,
            pid=pid, level=topology.depth, what="root medoid global ids",
            timeout_ms=deadline_ms,
        )

        # -- exact re-weighting over surviving shards ---------------------
        # Partials combined in pid order over the NON-excluded pids
        # (matches the host driver's leaf-order accumulation); a survivor
        # dying here is past the degradation point — fatal after the
        # deadline, by design.
        sqx = jnp.sum(feats_local * feats_local, axis=-1)
        sqm = jnp.sum(root_feats * root_feats, axis=-1)
        d2 = sqx[:, None] + sqm[None, :] - 2.0 * feats_local @ root_feats.T
        dist = jnp.sqrt(jnp.maximum(d2, 0.0))
        assign = jnp.argmin(dist, axis=1)
        local_counts = jnp.zeros((r_root,), jnp.float32).at[assign].add(1.0)
        min_dist = jnp.min(dist, axis=1)
        residual = jnp.square(min_dist) / 2.0 if squared_coverage else min_dist
        partial = np.concatenate(
            [np.asarray(local_counts, np.float32),
             np.asarray(jnp.sum(residual), np.float32).reshape(1)]
        )
        _put(client, f"{tag}/rw/{pid}", partial)
        counts = jnp.zeros((r_root,), jnp.float32)
        coverage = jnp.zeros((), jnp.float32)
        for p in range(nproc):
            if root_mask[p]:
                continue
            part = _kv_get(
                client, f"{tag}/rw/{p}", (r_root + 1,), np.float32,
                pid=pid, level=topology.depth, what="re-weight partial",
                timeout_ms=deadline_ms,
            )
            counts = counts + jnp.asarray(part[:r_root])
            coverage = coverage + jnp.float32(part[r_root])
    finally:
        hb.close()

    wire = wire_bytes_plan(topology, r_local, r_node, d, compress)
    health_rec = {
        "degraded": bool(missing),
        "missing_pids": missing,
        "quorum": (nproc - len(missing)) / nproc,
        "min_quorum": float(health.min_quorum),
        "r_final": int(r_root),
        "level_deadline_s": deadline_s,
    }
    return TreeSelection(
        jnp.asarray(root_gidx.astype(np.int32)), counts, coverage, wire,
        health_rec,
    )
