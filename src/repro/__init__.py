"""repro: CRAIG coreset-accelerated training framework (JAX, multi-pod)."""
__version__ = "1.0.0"
