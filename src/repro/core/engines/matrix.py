"""Dense exact greedy engine (DESIGN.md §3.1) — and the only cover engine.

``greedy_fl_matrix`` maximizes F over a precomputed (n, n) similarity
matrix in pure JAX (``lax.scan``), O(r·n²) — matmul-shaped and MXU/VPU
friendly on TPU.  The production path for per-shard selection and the
reference every other engine's parity tests anchor to.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.core.engines.base import (
    Capabilities,
    EngineConfig,
    FLResult,
    SelectionEngine,
    _cluster_weights,
    _replay_prefix,
    assign_and_weights,
    coverage_l,
    pairwise_distances,
)
from repro.core.engines.registry import register_engine

__all__ = ["MatrixConfig", "MatrixEngine", "greedy_fl_matrix"]


@partial(jax.jit, static_argnames=("budget",))
def greedy_fl_matrix(
    sim: jax.Array,
    budget: int,
    point_weights: jax.Array | None = None,
    init_selected: jax.Array | None = None,
) -> FLResult:
    """Exact greedy maximization of F over a dense (n, n) similarity matrix.

    Maintains cur_max_i = max_{j∈S} s_ij (0 for the auxiliary element), so the
    marginal gain of candidate e is Σ_i w_i·relu(s_ie − cur_max_i).  One
    ``scan`` step does an O(n²) relu-reduce; total O(r·n²) — matmul-shaped
    and MXU/VPU friendly on TPU.

    Args:
      sim: (n, n) float similarities, s_ij ≥ 0. sim[i, e] = benefit of e for i.
      budget: r, number of elements to select (static).
      point_weights: optional (n,) per-point multiplicities (weighted FL, used
        by the distributed two-round merge where each candidate represents a
        cluster of γ points).  Defaults to 1.
      init_selected: optional (r₀ ≤ r,) warm-start prefix.  Its elements are
        installed first (marginal gains replayed in order, O(r₀·n)), then
        greedy selects the remaining r − r₀.
    """
    n = sim.shape[0]
    sim = sim.astype(jnp.float32)
    pw = (
        jnp.ones((n,), jnp.float32)
        if point_weights is None
        else point_weights.astype(jnp.float32)
    )

    init_idx, init_gains, cur_max0, chosen0 = _replay_prefix(
        init_selected, budget, n, lambda e: sim[:, e], pw=pw
    )

    def step(state, _):
        cur_max, chosen_mask = state
        # gains[e] = sum_i w_i · relu(sim[i, e] - cur_max[i])
        gains = pw @ jnp.maximum(sim - cur_max[:, None], 0.0)
        gains = jnp.where(chosen_mask, -jnp.inf, gains)
        e = jnp.argmax(gains)
        new_max = jnp.maximum(cur_max, sim[:, e])
        return (new_max, chosen_mask.at[e].set(True)), (e.astype(jnp.int32), gains[e])

    (cur_max, _), (new_idx, new_gains) = jax.lax.scan(
        step, (cur_max0, chosen0), None, length=budget - init_idx.shape[0]
    )
    indices = jnp.concatenate([init_idx, new_idx])
    gains = jnp.concatenate([init_gains, new_gains])

    weights = _cluster_weights(sim, indices, pw)
    # L(S) in similarity space: Σ_i (s_max_i_possible − cur_max) is not
    # recoverable without d; callers with distances use coverage_l. Report the
    # residual un-covered mass Σ_i (max_col_i − cur_max_i) as coverage proxy.
    coverage = jnp.sum(jnp.max(sim, axis=1) - cur_max)
    return FLResult(indices, gains.astype(jnp.float32), weights, coverage)


@dataclasses.dataclass(frozen=True)
class MatrixConfig(EngineConfig):
    """Dense exact greedy — no knobs; the whole surface is the metric."""

    name: ClassVar[str] = "matrix"


@register_engine
class MatrixEngine(SelectionEngine):
    name = "matrix"
    config_cls = MatrixConfig
    capabilities = Capabilities(
        exact=True,
        matrix_free=False,
        jit_safe=True,
        supports_cover=True,
        supports_metrics=("l2", "cosine"),
        memory=lambda n, d: 8 * n * n,  # dist + sim, fp32 each
    )

    def select(
        self, feats, budget, *, metric="l2", init_selected=None, rng=None
    ) -> FLResult:
        feats = jnp.asarray(feats)
        dist = pairwise_distances(feats, metric)
        d_max = jnp.max(dist) + 1e-6
        res = greedy_fl_matrix(
            d_max - dist, budget, init_selected=init_selected
        )
        return res._replace(coverage=coverage_l(dist, res.indices))

    def select_cover(self, feats, epsilon, *, metric="l2") -> FLResult:
        """Submodular cover (paper Eq. 12): grow until L(S) ≤ epsilon.

        Runs greedy with the full budget, then cuts at the first prefix
        whose coverage meets ε (greedy order is nested, so prefixes are
        valid selections).  ε unreachable keeps everything.
        """
        feats = jnp.asarray(feats)
        dist = pairwise_distances(feats, metric)
        d_max = jnp.max(dist) + 1e-6
        sim = d_max - dist
        n = dist.shape[0]
        res = greedy_fl_matrix(sim, n)
        dist_sel = dist[:, res.indices]  # (n, n) in greedy order
        run_min = jax.lax.associative_scan(jnp.minimum, dist_sel, axis=1)
        cov_prefix = jnp.sum(run_min, axis=0)  # (n,) L(S_k) for k=1..n
        k = int(jnp.argmax(cov_prefix <= epsilon)) + 1
        if not bool(cov_prefix[k - 1] <= epsilon):
            k = n  # ε unreachable: keep everything
        idx = res.indices[:k]
        _, w = assign_and_weights(dist[:, idx])
        return FLResult(idx, res.gains[:k], w, cov_prefix[k - 1])
