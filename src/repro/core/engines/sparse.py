"""Sparse top-k engine (DESIGN.md §3.5) — O(n·k) memory, million-point pools.

``topk_graph`` builds the (n, k) neighbor structure blockwise — pure-jnp
scan or the Pallas ``topk_sim`` kernel — without materializing (n, n).
Greedy then maximizes the *sparsified* objective two ways with identical
selections: ``sparse_greedy_fl`` (host CSC lazy greedy, the engine's
``select`` path) and ``greedy_fl_topk`` (pure JAX scatter-add, jit- and
shard_map-safe — the distributed round-1 path).
"""
from __future__ import annotations

import dataclasses
import heapq
from functools import partial
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines.base import (
    Capabilities,
    EngineConfig,
    FLResult,
    SelectionEngine,
    normalize_for_metric,
)
from repro.core.engines.registry import register_engine

__all__ = [
    "SparseConfig",
    "SparseEngine",
    "topk_graph",
    "greedy_fl_topk",
    "sparse_greedy_fl",
    "sparse_greedy_fl_features",
]


def topk_graph(
    feats: jax.Array,
    k: int,
    *,
    d_max: jax.Array | None = None,
    block_m: int = 2048,
    impl: str = "jax",
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Blockwise top-k similarity graph: (vals (n, k) desc, idx (n, k) int32).

    Streams (n × block_m) similarity tiles and folds each into a running
    per-row top-k, so peak memory is O(n·(k + block_m)) — the dense (n, n)
    matrix never exists.  ``impl='pallas'`` routes to the fused
    ``repro.kernels.ops.topk_sim`` kernel (tile compute + merge in VMEM);
    ``'jax'`` is the pure-jnp scan (identical output, lax.top_k merge) and
    is shard_map-safe for the distributed round-1 path.

    Args:
      feats: (n, d) proxy features.
      k: neighbors per row (clamped to n); every row's list includes itself.
      d_max: similarity offset s = d_max − dist.  Defaults to the
        2·max‖x‖ + ε distance upper bound (same as ``greedy_fl_features``).
      block_m: column tile width for the jnp path.
    """
    n, _ = feats.shape
    k = int(min(k, n))
    feats = feats.astype(jnp.float32)
    if impl == "pallas":
        from repro.kernels import ops as kops  # local import; kernels optional

        return kops.topk_sim(feats, k, d_max, interpret=interpret)
    if impl != "jax":
        raise ValueError(f"unknown topk impl {impl!r}")

    sq = jnp.sum(feats * feats, axis=-1)
    if d_max is None:
        d_max = 2.0 * jnp.sqrt(jnp.max(sq)) + 1e-6
    block_m = min(block_m, n)
    n_blocks = (n + block_m - 1) // block_m
    pad = n_blocks * block_m - n
    featp = jnp.pad(feats, ((0, pad), (0, 0)))
    sqp = jnp.pad(sq, (0, pad), constant_values=1e30)  # padded cols → sim ≪ 0

    def blk(carry, b):
        vals, idx = carry
        cf = jax.lax.dynamic_slice_in_dim(featp, b * block_m, block_m)
        csq = jax.lax.dynamic_slice_in_dim(sqp, b * block_m, block_m)
        d2 = sq[:, None] + csq[None, :] - 2.0 * feats @ cf.T
        sim = d_max - jnp.sqrt(jnp.maximum(d2, 0.0))  # (n, bm)
        cols = b * block_m + jnp.arange(block_m, dtype=jnp.int32)
        cat_v = jnp.concatenate([vals, sim], axis=1)
        cat_i = jnp.concatenate(
            [idx, jnp.broadcast_to(cols[None, :], sim.shape)], axis=1
        )
        new_v, pos = jax.lax.top_k(cat_v, k)
        new_i = jnp.take_along_axis(cat_i, pos, axis=1)
        return (new_v, new_i), None

    init = (
        jnp.full((n, k), -1e30, jnp.float32),
        jnp.zeros((n, k), jnp.int32),
    )
    (vals, idx), _ = jax.lax.scan(blk, init, jnp.arange(n_blocks))
    return vals, idx


@partial(jax.jit, static_argnames=("budget",))
def greedy_fl_topk(vals: jax.Array, idx: jax.Array, budget: int) -> FLResult:
    """Exact greedy over the *sparsified* FL objective, pure JAX.

    Maximizes F̂(S) = Σ_i max(max_{j∈S∩nbr(i)} ŝ_ij, 0) where ŝ is the top-k
    graph.  Per step, every entry (i, j) contributes relu(ŝ_ij − cur_max_i)
    to candidate j's gain via one (n, k) scatter-add — O(n·k) per step,
    O(r·n·k) total, no dense structure.  jit- and shard_map-compatible
    (used by the sparse round-1 of ``core.distributed``).

    Weights are graph-assigned (each point to its best selected neighbor;
    points whose neighbor list contains no selected element fall back to the
    first medoid).  Callers holding features can recompute exact γ with
    ``assign_and_weights``; Σγ == n either way.
    """
    n, k = vals.shape
    vals = vals.astype(jnp.float32)
    budget = int(min(budget, n))

    def step(state, _):
        cur_max, chosen = state
        contrib = jnp.maximum(vals - cur_max[:, None], 0.0)  # (n, k)
        gains = jnp.zeros((n,), jnp.float32).at[idx].add(contrib)
        gains = jnp.where(chosen, -jnp.inf, gains)
        e = jnp.argmax(gains)
        # cover update: rows that list e as a neighbor take max(cur, ŝ_ie)
        cov = jnp.max(jnp.where(idx == e, vals, -jnp.inf), axis=1)
        return (jnp.maximum(cur_max, cov), chosen.at[e].set(True)), (
            e.astype(jnp.int32),
            gains[e],
        )

    init = (jnp.zeros((n,), jnp.float32), jnp.zeros((n,), bool))
    (cur_max, chosen), (indices, gains) = jax.lax.scan(
        step, init, None, length=budget
    )

    # Graph-based γ: best selected neighbor per row.
    ent_sel = chosen[idx]  # (n, k)
    best = jnp.where(ent_sel, vals, -jnp.inf)
    bpos = jnp.argmax(best, axis=1)
    assigned = jnp.take_along_axis(idx, bpos[:, None], axis=1)[:, 0]
    orphan = ~jnp.isfinite(jnp.max(best, axis=1))
    assigned = jnp.where(orphan, indices[0], assigned)
    slot = jnp.zeros((n,), jnp.int32).at[indices].set(
        jnp.arange(budget, dtype=jnp.int32)
    )[assigned]
    weights = jnp.zeros((budget,), jnp.float32).at[slot].add(1.0)
    # Residual un-covered similarity mass, same convention as the dense
    # engines (callers with features recompute true L(S) via distances).
    coverage = jnp.sum(jnp.maximum(vals[:, 0] - cur_max, 0.0))
    return FLResult(indices, gains.astype(jnp.float32), weights, coverage)


def sparse_greedy_fl(
    vals: np.ndarray,
    idx: np.ndarray,
    budget: int,
    feats: np.ndarray | None = None,
    init_selected: np.ndarray | None = None,
    squared_coverage: bool = False,
) -> FLResult:
    """Host lazy greedy (Minoux) over the top-k graph, walking CSR columns.

    The (n, k) row structure is transposed once into a CSC layout — for each
    candidate c, the rows that list c as a neighbor — so a gain evaluation
    touches only that candidate's column (apricot's ``select_next_sparse``,
    vectorized over the column instead of a numba scalar loop).  With the
    Minoux priority queue most candidates are never re-evaluated; per-step
    cost is O(nnz/n · re-evals) instead of O(n²).

    Selections are identical to ``greedy_fl_topk`` (same objective, ties to
    the lowest index).  If ``feats`` is given, γ weights and coverage are
    computed by *exact* blocked assignment of every point to its nearest
    selected medoid (O(n·r), no (n, n)); otherwise graph assignment is used
    and coverage is the residual similarity mass.  ``init_selected``
    warm-starts from a previous selection's prefix — each prefix element
    costs one CSR-column walk, and the heap is initialized against the
    warmed cover state.  ``squared_coverage`` (requires ``feats``) reports
    Σ min ‖x−m‖²/2 instead of Σ min ‖x−m‖ — on unit-normalized features
    that is Σ min (1 − cos θ), the cosine-metric units — reusing the one
    blocked-assignment pass.
    """
    if squared_coverage and feats is None:
        raise ValueError("squared_coverage needs feats for exact assignment")
    vals = np.asarray(vals, np.float64)
    idx = np.asarray(idx, np.int64)
    n, k = vals.shape
    budget = int(min(budget, n))

    # CSC transpose: entries sorted by candidate column.
    flat_v = vals.ravel()
    flat_c = idx.ravel()
    flat_r = np.repeat(np.arange(n, dtype=np.int64), k)
    valid = flat_v > -1e29  # drop builder padding
    flat_v, flat_c, flat_r = flat_v[valid], flat_c[valid], flat_r[valid]
    order = np.argsort(flat_c, kind="stable")
    col_vals = flat_v[order]
    col_rows = flat_r[order]
    sorted_c = flat_c[order]
    indptr = np.searchsorted(sorted_c, np.arange(n + 1))

    cur_max = np.zeros(n)
    indices: list[int] = []
    gains: list[float] = []
    if init_selected is not None:
        init = np.asarray(init_selected, np.int64)
        if init.shape[0] > budget:
            raise ValueError(
                f"init_selected has {init.shape[0]} elements > budget {budget}"
            )
        for c in init:
            c = int(c)
            lo, hi = indptr[c], indptr[c + 1]
            indices.append(c)
            gains.append(
                float(
                    np.maximum(
                        col_vals[lo:hi] - cur_max[col_rows[lo:hi]], 0.0
                    ).sum()
                )
            )
            np.maximum.at(cur_max, col_rows[lo:hi], col_vals[lo:hi])
    r0 = len(indices)
    in_init = set(indices)
    init_gain = np.zeros(n)
    np.add.at(
        init_gain, sorted_c, np.maximum(col_vals - cur_max[col_rows], 0.0)
    )
    heap = [(-g, c, r0) for c, g in enumerate(init_gain) if c not in in_init]
    heapq.heapify(heap)
    for t in range(r0, budget):
        while True:
            neg_g, c, stamp = heapq.heappop(heap)
            if stamp == t:
                break
            lo, hi = indptr[c], indptr[c + 1]
            g = float(
                np.maximum(col_vals[lo:hi] - cur_max[col_rows[lo:hi]], 0.0).sum()
            )
            heapq.heappush(heap, (-g, c, t))
        indices.append(c)
        gains.append(-neg_g)
        lo, hi = indptr[c], indptr[c + 1]
        np.maximum.at(cur_max, col_rows[lo:hi], col_vals[lo:hi])

    sel = np.array(indices, np.int64)
    if feats is not None:
        assign, mind = _blocked_assignment(np.asarray(feats), sel)
        weights = np.bincount(assign, minlength=budget).astype(np.float32)
        # true L(S): Σ min d (l2 units) or Σ min d²/2 (cosine units on a
        # unit-normalized pool), from the same assignment pass
        coverage = float(
            np.sum(mind**2) / 2.0 if squared_coverage else mind.sum()
        )
    else:
        in_sel = np.zeros(n, bool)
        in_sel[sel] = True
        slot_of = np.zeros(n, np.int64)
        slot_of[sel] = np.arange(budget)
        masked = np.where(in_sel[idx] & (vals > -1e29), vals, -np.inf)
        rows_hit = masked.max(axis=1) > -np.inf
        best_c = np.full(n, sel[0], np.int64)  # orphans → first medoid
        best_c[rows_hit] = idx[np.arange(n), masked.argmax(axis=1)][rows_hit]
        weights = np.bincount(slot_of[best_c], minlength=budget).astype(
            np.float32
        )
        coverage = float(np.maximum(vals[:, 0] - cur_max, 0.0).sum())
    return FLResult(
        jnp.asarray(sel.astype(np.int32)),
        jnp.asarray(np.array(gains, np.float32)),
        jnp.asarray(weights),
        jnp.asarray(coverage, jnp.float32),
    )


def _blocked_assignment(
    feats: np.ndarray, sel: np.ndarray, block: int = 65536
) -> tuple[np.ndarray, np.ndarray]:
    """Exact nearest-selected-medoid assignment, O(block·r) peak memory.

    Returns (assign (n,) positions into sel, min_dist (n,)).
    """
    feats = np.asarray(feats, np.float32)
    sf = feats[sel]  # (r, d)
    sq_s = (sf * sf).sum(axis=1)
    assign = np.empty(len(feats), np.int64)
    mind = np.empty(len(feats), np.float64)
    for lo in range(0, len(feats), block):
        chunk = feats[lo : lo + block]
        d2 = (
            (chunk * chunk).sum(axis=1)[:, None]
            + sq_s[None, :]
            - 2.0 * chunk @ sf.T
        )
        d2 = np.maximum(d2, 0.0)
        assign[lo : lo + block] = d2.argmin(axis=1)
        mind[lo : lo + block] = np.sqrt(d2.min(axis=1))
    return assign, mind


def sparse_greedy_fl_features(
    feats: jax.Array,
    budget: int,
    *,
    k: int = 64,
    d_max: jax.Array | None = None,
    topk_impl: str = "jax",
    block_m: int = 2048,
    init_selected: np.ndarray | None = None,
    squared_coverage: bool = False,
) -> FLResult:
    """End-to-end sparse engine: top-k graph build + host lazy greedy.

    O(n·k + n·block_m) peak memory — the production path for pools past the
    dense engines' ~10⁵-point ceiling.  Exact γ/coverage via blocked
    assignment (the ``feats`` are already in hand).
    """
    vals, idx = topk_graph(
        feats, k, d_max=d_max, block_m=block_m, impl=topk_impl
    )
    return sparse_greedy_fl(
        np.asarray(vals),
        np.asarray(idx),
        budget,
        feats=np.asarray(feats),
        init_selected=init_selected,
        squared_coverage=squared_coverage,
    )


@dataclasses.dataclass(frozen=True)
class SparseConfig(EngineConfig):
    """Sparse top-k graph greedy.

    Attributes:
      k: neighbors kept per point (clamped to n).  Larger k → closer to
        exact greedy (k == n is exact); memory scales as n·k.
      impl: 'jax' | 'pallas' graph builder (the ``topk_sim`` kernel).
      block_m: column tile width for the graph build.
    """

    name: ClassVar[str] = "sparse"
    k: int = 64
    impl: str = "jax"
    block_m: int = 2048


@register_engine
class SparseEngine(SelectionEngine):
    name = "sparse"
    config_cls = SparseConfig
    capabilities = Capabilities(
        exact=False,  # exact on the k-NN graph; == exact greedy at k = n
        matrix_free=True,
        jit_safe=False,  # host CSC lazy greedy (greedy_fl_topk is the
        # jit-safe sibling, used by distributed round 1)
        supports_cover=False,
        supports_metrics=("l2", "cosine"),  # cosine via normalized l2
        memory=lambda n, d: 8 * n * 64 + 4 * n * 2048,
    )

    def select(
        self, feats, budget, *, metric="l2", init_selected=None, rng=None
    ) -> FLResult:
        cfg = self.config
        feats = normalize_for_metric(jnp.asarray(feats), metric)
        # cosine pools are unit-normalized, so Σ min ‖x−m‖²/2 from the
        # engine's own blocked-assignment pass is Σ min (1 − cos θ) — the
        # dense engines' cosine coverage units, with no extra O(n·r) pass
        # and no unblocked (n, r) (the O(n·k) contract holds)
        return sparse_greedy_fl_features(
            feats,
            budget,
            k=cfg.k,
            topk_impl=cfg.impl,
            block_m=cfg.block_m,
            init_selected=init_selected,
            squared_coverage=metric == "cosine",
        )
