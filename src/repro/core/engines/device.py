"""Device-resident fused greedy engine (DESIGN.md §3.6).

The whole selection loop lives in one jitted ``lax.while_loop``; a sweep
round is a single fused gains-sweep + per-block argmax kernel launch
(``fl_gains_argmax`` on TPU, a blockwise jnp scan elsewhere), streaming
feature tiles so the (n, n) similarity never exists.  ``q > 1`` amortizes
each sweep over up to q commits via device-resident Minoux bounds.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.core.engines.base import (
    Capabilities,
    EngineConfig,
    FLResult,
    SelectionEngine,
    _replay_prefix,
    cosine_residual_coverage,
    normalize_for_metric,
)
from repro.core.engines.registry import register_engine

__all__ = ["DeviceConfig", "DeviceEngine", "greedy_fl_device"]


@partial(
    jax.jit,
    static_argnames=(
        "budget", "q", "gains_impl", "block_n", "block_m", "tile_dtype",
        "stale_tol",
    ),
)
def greedy_fl_device(
    feats: jax.Array,
    budget: int,
    *,
    q: int = 1,
    gains_impl: str = "auto",
    block_n: int = 512,
    block_m: int = 2048,
    tile_dtype: str = "float32",
    stale_tol: float = 0.7,
    init_selected: jax.Array | None = None,
) -> FLResult:
    """Fully jitted device-resident greedy FL from features (DESIGN.md §3.6).

    The entire selection loop is one ``lax.while_loop`` on device — no
    per-round host round-trip, no (n, n) similarity, no host-visible gains
    vector on the Pallas path.  A *sweep* round runs one fused
    gains + argmax pass over every candidate — on TPU a single
    ``fl_gains_argmax`` kernel launch (gains accumulate tile-by-tile in
    VMEM, the argmax epilogue is fused, chosen candidates are penalized
    in-kernel), elsewhere an equivalent blockwise jnp scan with identical
    tie semantics (lowest index within a block, lowest block across blocks
    — i.e. ``jnp.argmax`` order) — and commits the winner.

    Block-greedy mode (``q > 1``) amortizes that O(n²·d) sweep over up to
    ``q`` commits: the sweep's full gains vector stays resident as Minoux
    upper bounds.  Between sweeps the loop refreshes the top-P bounds
    against the *updated* cover state in one (n, d)×(d, P) matmul and
    commits the best refreshed winner iff its fresh gain retains at least
    ``stale_tol`` of the best outstanding bound (bounds only overestimate,
    so ``stale_tol=1.0`` is the exact Minoux acceptance rule — the winner
    is the true argmax; the 0.7 default admits near-argmax winners, which
    in practice keeps coverage within ~1% of exact while committing far
    more often).  A failed re-check writes the fresh gains back as new
    (tighter) bounds; once the refresh budget is spent — the bounds have
    gone uniformly stale under heavy cover overlap — the engine falls back
    to a fresh q=1-style sweep.

    ``q=1`` sweeps before every commit and is bit-faithful to
    ``greedy_fl_matrix``/``greedy_fl_features`` (same objective, same
    tie-breaking) regardless of ``stale_tol``.

    Args:
      feats: (n, d) proxy features.
      budget: r (static); clamped to n.
      q: max winners committed per sweep (static).  1 = sweep every round;
        larger values amortize sweeps at large budgets via the lazy bounds.
      gains_impl: 'auto' (pallas on TPU, jax elsewhere) | 'pallas' | 'jax'.
      block_n / block_m: pool/candidate tile sizes for the sweep.
      tile_dtype: 'float32' | 'bfloat16' feature tiles; gains always
        accumulate fp32.
      stale_tol: lazy-commit floor in (0, 1]; 1.0 = exact greedy at any q.
      init_selected: optional warm-start prefix (see ``greedy_fl_matrix``).
    """
    n, d = feats.shape
    feats = feats.astype(jnp.float32)
    budget = int(min(budget, n))
    if gains_impl == "auto":
        gains_impl = "pallas" if jax.default_backend() == "tpu" else "jax"
    if gains_impl not in ("pallas", "jax"):
        raise ValueError(f"unknown gains_impl {gains_impl!r}")
    if tile_dtype not in ("float32", "bfloat16"):
        raise ValueError(f"unsupported tile_dtype {tile_dtype!r}")
    td = jnp.dtype(tile_dtype)

    sq = jnp.sum(feats * feats, axis=-1)  # (n,)
    d_max = 2.0 * jnp.sqrt(jnp.max(sq)) + 1e-6

    def sim_cols(idx: jax.Array) -> jax.Array:
        """(n, m) similarity of every point to elements ``idx`` ((m,))."""
        cf = feats[idx]
        d2 = sq[:, None] + sq[idx][None, :] - 2.0 * (feats @ cf.T)
        return d_max - jnp.sqrt(jnp.maximum(d2, 0.0))

    def sim_col(e: jax.Array) -> jax.Array:
        """(n,) similarity of every point to element e."""
        return sim_cols(jnp.asarray(e)[None])[:, 0]

    bm = min(block_m, n)
    n_blocks = (n + bm - 1) // bm
    pad_m = n_blocks * bm
    if gains_impl == "jax":
        featp = jnp.pad(feats, ((0, pad_m - n), (0, 0)))
        sqp = jnp.pad(sq, (0, pad_m - n))
        featp_t = featp.astype(td)
        feats_t = feats.astype(td)

    def sweep(cur_max, chosen):
        """One fused pass: full gains vector + per-block (best_gain,
        best_idx) partials.  Blocks whose every candidate is chosen/padded
        report best_gain ≤ −1e29 (real gains are ≥ 0)."""
        if gains_impl == "pallas":
            from repro.kernels import ops as kops  # local; kernels optional

            return kops.fl_gains_argmax(
                feats, feats, cur_max, sq, sq, d_max, chosen,
                block_n=block_n, block_m=bm, tile_dtype=tile_dtype,
            )
        penp = jnp.where(
            jnp.pad(chosen, (0, pad_m - n), constant_values=True), -1e30, 0.0
        )

        def blk(carry, b):
            lo = b * bm
            cf = jax.lax.dynamic_slice_in_dim(featp_t, lo, bm)
            csq = jax.lax.dynamic_slice_in_dim(sqp, lo, bm)
            cpen = jax.lax.dynamic_slice_in_dim(penp, lo, bm)
            dots = jax.lax.dot_general(
                feats_t, cf, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # (n, bm)
            d2 = sq[:, None] + csq[None, :] - 2.0 * dots
            s = d_max - jnp.sqrt(jnp.maximum(d2, 0.0))
            g = jnp.sum(jnp.maximum(s - cur_max[:, None], 0.0), axis=0)
            gp = g + cpen
            p = jnp.argmax(gp)
            return carry, (g, gp[p], (lo + p).astype(jnp.int32))

        _, (g, pg, pi) = jax.lax.scan(blk, None, jnp.arange(n_blocks))
        return g.reshape(pad_m)[:n], pg, pi

    init_idx, init_gains, cur_max0, chosen0 = _replay_prefix(
        init_selected, budget, n, sim_col
    )
    r0 = init_idx.shape[0]
    q = max(1, int(q))
    # Between sweeps, stale bounds are refreshed P at a time (one
    # (n, d) × (d, P) matmul — ~P/n of a sweep, and one loop dispatch
    # instead of P).  The refresh budget caps the worst-case chew at ~1/4
    # sweep before falling back to a fresh full sweep.  Between two commits
    # each candidate can go stale at most once (a refreshed bound is exact),
    # so the loop terminates even without the fallback.
    refresh_p = min(128, n)
    max_fails = max(1, n // (4 * refresh_p))

    out_idx0 = jnp.zeros((budget,), jnp.int32).at[:r0].set(init_idx)
    out_g0 = jnp.zeros((budget,), jnp.float32).at[:r0].set(init_gains)
    neg = jnp.float32(-jnp.inf)

    # Carry: cover state, chosen mask, Minoux upper bounds (−inf = invalid /
    # chosen), commits since the last sweep, consecutive stale re-checks,
    # output buffers, count.  commits0 = q forces a sweep on entry.
    state0 = (
        cur_max0, chosen0, jnp.full((n,), neg), jnp.int32(q), jnp.int32(0),
        out_idx0, out_g0, jnp.int32(r0),
    )

    def cond(state):
        return state[7] < budget

    def body(state):
        cur_max, chosen, ub, commits, fails, out_idx, out_g, count = state
        need_sweep = (commits >= q) | (fails >= max_fails)

        def sweep_round(_):
            g, pg, pi = sweep(cur_max, chosen)
            e = pi[jnp.argmax(pg)]  # exact winner (jnp.argmax tie order)
            col = sim_col(e)
            fresh = jnp.sum(jnp.maximum(col - cur_max, 0.0))
            new_ub = jnp.where(chosen, neg, g).at[e].set(neg)
            return (
                jnp.maximum(cur_max, col),
                chosen.at[e].set(True),
                new_ub,
                jnp.int32(1),
                jnp.int32(0),
                out_idx.at[count].set(e),
                out_g.at[count].set(fresh),
                count + 1,
            )

        def lazy_round(_):
            # Refresh the top-P bounds in one matmul, then the tolerance-
            # scaled Minoux rule: the best refreshed (exact) gain commits
            # iff it retains ≥ stale_tol of the best bound outside the
            # batch; at stale_tol=1.0 the winner is the true argmax
            # (bounds only overestimate).
            tg, tp = jax.lax.top_k(ub, refresh_p)
            cols = sim_cols(tp)  # (n, P)
            fresh_p = jnp.sum(
                jnp.maximum(cols - cur_max[:, None], 0.0), axis=0
            )
            fresh_p = jnp.where(jnp.isfinite(tg), fresh_p, neg)  # chosen
            j = jnp.argmax(fresh_p)
            e = tp[j]
            fresh = fresh_p[j]
            col = cols[:, j]
            rest = jnp.max(ub.at[tp].set(neg))
            # Small slack absorbs the sweep-vs-column summation-order
            # difference.
            commit = fresh * (1.0 + 1e-5) + 1e-6 >= stale_tol * rest
            new_ub = ub.at[tp].set(fresh_p).at[e].set(
                jnp.where(commit, neg, fresh)
            )
            return (
                jnp.where(commit, jnp.maximum(cur_max, col), cur_max),
                chosen.at[e].set(chosen[e] | commit),
                new_ub,
                commits + commit.astype(jnp.int32),
                jnp.where(commit, 0, fails + 1).astype(jnp.int32),
                out_idx.at[count].set(jnp.where(commit, e, out_idx[count])),
                out_g.at[count].set(jnp.where(commit, fresh, out_g[count])),
                count + commit.astype(jnp.int32),
            )

        return jax.lax.cond(need_sweep, sweep_round, lazy_round, None)

    cur_max, _, _, _, _, indices, gains, _ = jax.lax.while_loop(
        cond, body, state0
    )

    # γ / coverage: exact assignment of every point to its nearest medoid.
    sel_sim = sim_cols(indices)  # (n, r)
    assign = jnp.argmax(sel_sim, axis=1)
    weights = jnp.zeros((budget,), jnp.float32).at[assign].add(1.0)
    coverage = jnp.sum(d_max - jnp.max(sel_sim, axis=1))
    return FLResult(indices, gains, weights, coverage)


@dataclasses.dataclass(frozen=True)
class DeviceConfig(EngineConfig):
    """Device-resident fused greedy.

    Attributes:
      q: winners committed per fused sweep (block greedy).  1 = exact
        greedy; larger amortizes the O(n²·d) sweep at large budgets.
      stale_tol: lazy-commit floor in (0, 1]; 1.0 = exact Minoux rule
        (exact greedy at any q), the 0.7 default is near-exact.
      tile_dtype: 'float32' | 'bfloat16' feature tiles (gains always
        accumulate fp32).
      gains_impl: 'auto' (pallas on TPU, jax elsewhere) | 'pallas' | 'jax'.
      block_n / block_m: pool/candidate tile sizes for the sweep.
    """

    name: ClassVar[str] = "device"
    q: int = 1
    stale_tol: float = 0.7
    tile_dtype: str = "float32"
    gains_impl: str = "auto"
    block_n: int = 512
    block_m: int = 2048


@register_engine
class DeviceEngine(SelectionEngine):
    name = "device"
    config_cls = DeviceConfig
    capabilities = Capabilities(
        exact=True,  # at the q=1 default (or stale_tol=1.0); near-exact past
        matrix_free=True,
        jit_safe=True,
        supports_cover=False,
        supports_metrics=("l2", "cosine"),  # cosine via normalized l2
        memory=lambda n, d: 4 * n * (d + 2048),
    )

    def select(
        self, feats, budget, *, metric="l2", init_selected=None, rng=None
    ) -> FLResult:
        cfg = self.config
        feats = normalize_for_metric(jnp.asarray(feats), metric)
        init = None if init_selected is None else jnp.asarray(init_selected)
        res = greedy_fl_device(
            feats,
            budget,
            q=cfg.q,
            gains_impl=cfg.gains_impl,
            block_n=cfg.block_n,
            block_m=cfg.block_m,
            tile_dtype=cfg.tile_dtype,
            stale_tol=cfg.stale_tol,
            init_selected=init,
        )
        if metric == "cosine":  # report L(S) in cosine-distance units
            res = res._replace(
                coverage=cosine_residual_coverage(feats, res.indices)
            )
        return res
