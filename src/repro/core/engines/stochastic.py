"""Stochastic greedy engine (Mirzasoleiman et al. 2015a; DESIGN.md §3.3).

The paper's O(|V|) fast path (§3.2, §3.4): each step evaluates gains on a
random candidate sample of size (n/r)·ln(1/δ), a (1−1/e−δ) approximation
in expectation.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import ClassVar

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines.base import (
    Capabilities,
    EngineConfig,
    FLResult,
    SelectionEngine,
    _cluster_weights,
    _replay_prefix,
    coverage_l,
    pairwise_distances,
)
from repro.core.engines.registry import register_engine

__all__ = ["StochasticConfig", "StochasticEngine", "stochastic_greedy_fl"]


@partial(jax.jit, static_argnames=("budget", "sample_size"))
def stochastic_greedy_fl(
    sim: jax.Array,
    budget: int,
    key: jax.Array,
    sample_size: int,
    init_selected: jax.Array | None = None,
) -> FLResult:
    """Stochastic greedy: each step evaluates gains on a random candidate set.

    With sample_size = (n/r)·log(1/δ) the result is a (1−1/e−δ) approximation
    in expectation (Mirzasoleiman et al., AAAI'15), with O(n·log 1/δ) total
    gain evaluations.

    When every sampled candidate is already selected (small pools, large
    budgets), the step falls back to the first unchosen element instead of
    re-selecting a masked candidate — selections are always unique.

    ``sample_size >= n`` is the δ→0 limit: the step sweeps every candidate
    deterministically (sampling n-of-n with replacement would still miss the
    argmax with probability ≈ 1/e) and the engine reduces to exact greedy.

    Args:
      sim: (n, n) similarities.
      budget: r (static); clamped to n.
      key: PRNG key for candidate sampling.
      sample_size: candidates per step (static).
      init_selected: optional warm-start prefix (see ``greedy_fl_matrix``).
    """
    n = sim.shape[0]
    budget = int(min(budget, n))
    sim = sim.astype(jnp.float32)

    init_idx, init_gains, cur_max0, chosen0 = _replay_prefix(
        init_selected, budget, n, lambda e: sim[:, e]
    )

    full_sweep = sample_size >= n  # δ→0: evaluate everything, exact greedy

    def step(state, key_t):
        cur_max, chosen_mask = state
        # Sample candidates (with replacement; collisions harmless), or the
        # whole ground set once the requested sample covers it.
        if full_sweep:
            cand = jnp.arange(n)
        else:
            cand = jax.random.randint(key_t, (sample_size,), 0, n)
        cand_sim = sim[:, cand]  # (n, m)
        gains = jnp.sum(jnp.maximum(cand_sim - cur_max[:, None], 0.0), axis=0)
        gains = jnp.where(chosen_mask[cand], -jnp.inf, gains)
        best = jnp.argmax(gains)
        # All candidates already chosen → every gain is −inf and argmax
        # would re-select cand[0]; take the first unchosen element instead
        # (one always exists while |S| < n).
        all_dup = ~jnp.isfinite(gains[best])
        fallback = jnp.argmin(chosen_mask)  # first False
        e = jnp.where(all_dup, fallback, cand[best])
        g = jnp.where(
            all_dup,
            jnp.sum(jnp.maximum(sim[:, fallback] - cur_max, 0.0)),
            gains[best],
        )
        new_max = jnp.maximum(cur_max, sim[:, e])
        return (new_max, chosen_mask.at[e].set(True)), (e.astype(jnp.int32), g)

    keys = jax.random.split(key, budget - init_idx.shape[0])
    (cur_max, _), (new_idx, new_gains) = jax.lax.scan(
        step, (cur_max0, chosen0), keys
    )
    indices = jnp.concatenate([init_idx, new_idx])
    gains = jnp.concatenate([init_gains, new_gains])
    weights = _cluster_weights(sim, indices)
    coverage = jnp.sum(jnp.max(sim, axis=1) - cur_max)
    return FLResult(indices, gains.astype(jnp.float32), weights, coverage)


@dataclasses.dataclass(frozen=True)
class StochasticConfig(EngineConfig):
    """Stochastic greedy.

    Attributes:
      delta: failure probability δ of the per-step sample; the sample size
        is (n/r)·ln(1/δ), clamped to n (δ→0 reduces to exact greedy).
    """

    name: ClassVar[str] = "stochastic"
    delta: float = 0.01


@register_engine
class StochasticEngine(SelectionEngine):
    name = "stochastic"
    config_cls = StochasticConfig
    capabilities = Capabilities(
        exact=False,  # (1−1/e−δ) in expectation
        matrix_free=False,
        jit_safe=True,
        supports_cover=False,
        supports_metrics=("l2", "cosine"),
        memory=lambda n, d: 4 * n * n,
    )

    def select(
        self, feats, budget, *, metric="l2", init_selected=None, rng=None
    ) -> FLResult:
        feats = jnp.asarray(feats)
        n = feats.shape[0]
        budget = int(min(budget, n))
        dist = pairwise_distances(feats, metric)
        d_max = jnp.max(dist) + 1e-6
        m = max(
            1, int(np.ceil(n / budget * np.log(1.0 / self.config.delta)))
        )
        m = min(m, n)
        if rng is None or isinstance(rng, int):
            rng = jax.random.PRNGKey(0 if rng is None else rng)
        res = stochastic_greedy_fl(
            d_max - dist, budget, rng, m, init_selected=init_selected
        )
        return res._replace(coverage=coverage_l(dist, res.indices))
