"""Greedy facility-location engines behind the SelectionEngine registry.

The package splits the former monolithic ``core/facility_location.py``
into one module per engine (DESIGN.md §3.1–§3.6), a shared protocol
(``base``), a capability-driven registry with the ``engine='auto'``
policy (``registry``), and the flat-knob deprecation shims (``legacy``).

Adding an engine is a one-file plugin::

    # repro/core/engines/my_engine.py
    @dataclasses.dataclass(frozen=True)
    class MyConfig(EngineConfig):
        name: ClassVar[str] = "my_engine"
        knob: int = 3

    @register_engine
    class MyEngine(SelectionEngine):
        name, config_cls = "my_engine", MyConfig
        capabilities = Capabilities(...)
        def select(self, feats, budget, *, metric="l2",
                   init_selected=None, rng=None): ...

then import it here; ``CraigSelector``, ``distributed_select``, the
benchmarks, and the trainer pick it up through the registry.
"""
from repro.core.engines.base import (
    Capabilities,
    EngineConfig,
    FLResult,
    SelectionEngine,
    assign_and_weights,
    cosine_residual_coverage,
    coverage_l,
    facility_location_value,
    normalize_for_metric,
    pairwise_distances,
)
from repro.core.engines.registry import (
    auto_engine_config,
    engine_config_from_dict,
    get_engine,
    list_engines,
    make_engine,
    parse_engine_spec,
    register_engine,
)

# Engine modules self-register on import; matrix first (ladder baseline).
from repro.core.engines.matrix import MatrixConfig, MatrixEngine, greedy_fl_matrix
from repro.core.engines.lazy import LazyConfig, LazyEngine, lazy_greedy_fl
from repro.core.engines.stochastic import (
    StochasticConfig,
    StochasticEngine,
    stochastic_greedy_fl,
)
from repro.core.engines.features import (
    FeaturesConfig,
    FeaturesEngine,
    greedy_fl_features,
)
from repro.core.engines.sparse import (
    SparseConfig,
    SparseEngine,
    greedy_fl_topk,
    sparse_greedy_fl,
    sparse_greedy_fl_features,
    topk_graph,
)
from repro.core.engines.device import DeviceConfig, DeviceEngine, greedy_fl_device
from repro.core.engines.streaming import (
    StreamingConfig,
    StreamingEngine,
    StreamingSelector,
    StreamingState,
    init_streaming_state,
    ingest_delta,
    streaming_result,
)

__all__ = [
    # protocol
    "Capabilities",
    "EngineConfig",
    "FLResult",
    "SelectionEngine",
    # registry / policy
    "register_engine",
    "get_engine",
    "list_engines",
    "make_engine",
    "engine_config_from_dict",
    "parse_engine_spec",
    "auto_engine_config",
    # typed configs + engines
    "MatrixConfig", "MatrixEngine",
    "LazyConfig", "LazyEngine",
    "StochasticConfig", "StochasticEngine",
    "FeaturesConfig", "FeaturesEngine",
    "SparseConfig", "SparseEngine",
    "DeviceConfig", "DeviceEngine",
    "StreamingConfig", "StreamingEngine",
    # streaming state machine (sieve-streaming, DESIGN.md §10)
    "StreamingSelector", "StreamingState",
    "init_streaming_state", "ingest_delta", "streaming_result",
    # functional API (shared with core.facility_location)
    "pairwise_distances",
    "normalize_for_metric",
    "cosine_residual_coverage",
    "facility_location_value",
    "coverage_l",
    "assign_and_weights",
    "greedy_fl_matrix",
    "lazy_greedy_fl",
    "stochastic_greedy_fl",
    "greedy_fl_features",
    "greedy_fl_device",
    "topk_graph",
    "greedy_fl_topk",
    "sparse_greedy_fl",
    "sparse_greedy_fl_features",
]
