"""Host-side lazy greedy engine (Minoux 1978; DESIGN.md §3.2).

Exact greedy with a max-heap of stale upper bounds: submodularity
guarantees a popped entry whose bound was recomputed this round is the
true argmax, so most candidates are never re-evaluated.  The oracle and
large-n CPU path; selections are identical to the matrix engine.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import ClassVar

import jax.numpy as jnp
import numpy as np

from repro.core.engines.base import (
    Capabilities,
    EngineConfig,
    FLResult,
    SelectionEngine,
    coverage_l,
    pairwise_distances,
)
from repro.core.engines.registry import register_engine

__all__ = ["LazyConfig", "LazyEngine", "lazy_greedy_fl"]


def lazy_greedy_fl(
    sim: np.ndarray, budget: int, init_selected: np.ndarray | None = None
) -> FLResult:
    """Exact lazy greedy with a max-heap of stale upper bounds.

    Numerically identical selections to ``greedy_fl_matrix`` (ties broken by
    lowest index) but typically evaluates far fewer gains.  ``init_selected``
    warm-starts: the prefix is installed first (gains replayed in order) and
    the heap is built against the warmed cover state, so the O(n²) heap
    initialization prices in the prefix for free.
    """
    sim = np.asarray(sim, np.float64)
    n = sim.shape[0]
    budget = min(budget, n)
    cur_max = np.zeros(n)
    indices, gains = [], []
    if init_selected is not None:
        init = np.asarray(init_selected, np.int64)
        if init.shape[0] > budget:
            raise ValueError(
                f"init_selected has {init.shape[0]} elements > budget {budget}"
            )
        for e in init:
            e = int(e)
            indices.append(e)
            gains.append(float(np.maximum(sim[:, e] - cur_max, 0.0).sum()))
            cur_max = np.maximum(cur_max, sim[:, e])
    r0 = len(indices)
    in_init = set(indices)
    # heap of (-gain, index, stamp); stamp = |S| when the gain was computed
    heap = [
        (-float(np.maximum(sim[:, e] - cur_max, 0.0).sum()), e, r0)
        for e in range(n)
        if e not in in_init
    ]
    heapq.heapify(heap)
    for t in range(r0, budget):
        while True:
            neg_g, e, stamp = heapq.heappop(heap)
            if stamp == t:
                break
            g = float(np.maximum(sim[:, e] - cur_max, 0.0).sum())
            heapq.heappush(heap, (-g, e, t))
        indices.append(e)
        gains.append(-neg_g)
        cur_max = np.maximum(cur_max, sim[:, e])
    idx = jnp.asarray(np.array(indices, np.int32))
    sub = sim[:, np.array(indices)]
    assign = np.argmax(sub, axis=1)
    weights = np.bincount(assign, minlength=budget).astype(np.float32)
    coverage = float(np.sum(sim.max(axis=1) - cur_max))
    return FLResult(idx, jnp.asarray(np.array(gains, np.float32)),
                    jnp.asarray(weights), jnp.asarray(coverage, jnp.float32))


@dataclasses.dataclass(frozen=True)
class LazyConfig(EngineConfig):
    """Host lazy greedy — no knobs (the heap is self-tuning)."""

    name: ClassVar[str] = "lazy"


@register_engine
class LazyEngine(SelectionEngine):
    name = "lazy"
    config_cls = LazyConfig
    capabilities = Capabilities(
        exact=True,
        matrix_free=False,
        jit_safe=False,  # host heapq loop
        supports_cover=False,
        supports_metrics=("l2", "cosine"),
        memory=lambda n, d: 8 * n * n,  # float64 similarity on host
    )

    def select(
        self, feats, budget, *, metric="l2", init_selected=None, rng=None
    ) -> FLResult:
        feats = jnp.asarray(feats)
        dist = pairwise_distances(feats, metric)
        d_max = jnp.max(dist) + 1e-6
        res = lazy_greedy_fl(
            np.asarray(d_max - dist), budget, init_selected=init_selected
        )
        return res._replace(coverage=coverage_l(dist, res.indices))
