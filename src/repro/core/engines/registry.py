"""Engine registry and the ``engine='auto'`` policy.

``register_engine`` is how an engine module publishes itself; everything
else in the codebase goes through ``get_engine``/``list_engines``/
``make_engine`` so a new engine is a one-file plugin — no selector,
distributed, refresh, or benchmark edits required.

``auto_engine_config`` is the documented ``engine='auto'`` policy: pick
the engine from capabilities + pool size + backend instead of making the
caller name an implementation.  ``CraigSelector`` (flat and per-class),
``AsyncRefresher``-driven trainer refreshes, and ``distributed_select``
round 1 all default to it.
"""
from __future__ import annotations

import jax

from repro.core.engines.base import EngineConfig, SelectionEngine

__all__ = [
    "register_engine",
    "get_engine",
    "list_engines",
    "make_engine",
    "engine_config_from_dict",
    "parse_engine_spec",
    "auto_engine_config",
    "DENSE_MAX_N",
    "SPARSE_MIN_N",
]

_REGISTRY: dict[str, type[SelectionEngine]] = {}


def register_engine(cls: type[SelectionEngine]) -> type[SelectionEngine]:
    """Class decorator: publish a SelectionEngine under ``cls.name``."""
    for attr in ("name", "config_cls", "capabilities"):
        if not hasattr(cls, attr):
            raise TypeError(f"engine {cls.__name__} is missing {attr!r}")
    if cls.name in _REGISTRY:
        raise ValueError(f"engine {cls.name!r} already registered")
    if cls.config_cls.name != cls.name:
        raise ValueError(
            f"engine {cls.name!r} has a config named {cls.config_cls.name!r}"
        )
    _REGISTRY[cls.name] = cls
    return cls


def get_engine(name: str) -> type[SelectionEngine]:
    """Engine class for ``name``; raises with the registered set."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered engines: "
            f"{', '.join(_REGISTRY)}"
        ) from None


def list_engines() -> tuple[str, ...]:
    """Registered engine names, in registration order (matrix first)."""
    return tuple(_REGISTRY)


def make_engine(config: EngineConfig) -> SelectionEngine:
    """Instantiate the engine a typed config names."""
    return get_engine(config.name)(config)


def engine_config_from_dict(d: dict) -> EngineConfig:
    """Inverse of ``EngineConfig.to_dict`` — restores the typed config.

    ``name == 'tree'`` dispatches to ``TreeSelectConfig``: tree selection
    is an orchestration layer over the round-1 engines, not a registered
    ``SelectionEngine``, but its provenance dicts ride the same
    checkpoint/metadata paths (lazy import — the tree module imports
    ``core.distributed``, which imports the engines)."""
    d = dict(d)
    try:
        name = d.pop("name")
    except KeyError:
        raise ValueError(f"engine config dict has no 'name': {d!r}") from None
    if name == "tree":
        from repro.distributed.tree_select import TreeSelectConfig

        return TreeSelectConfig(**{**d, "fanouts": tuple(d["fanouts"])})
    return get_engine(name).config_cls(**d)


def parse_engine_spec(spec: str) -> EngineConfig:
    """CLI-style engine spec → typed config.

    ``'matrix'`` → ``MatrixConfig()``; ``'device:q=16,stale_tol=0.8'`` →
    ``DeviceConfig(q=16, stale_tol=0.8)``.  Values are coerced int → float
    → str.  Used by the benchmarks' ``--engine`` flags.
    """
    name, _, args = spec.partition(":")
    cfg_cls = get_engine(name.strip()).config_cls
    kw = {}
    for item in filter(None, (s.strip() for s in args.split(","))):
        key, sep, val = item.partition("=")
        if not sep:
            raise ValueError(
                f"bad engine spec item {item!r} in {spec!r} (want key=value)"
            )
        kw[key.strip()] = _coerce(val.strip())
    return cfg_cls(**kw)


def _coerce(v: str):
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            continue
    return v


# ---------------------------------------------------------------------------
# engine='auto' policy
# ---------------------------------------------------------------------------

DENSE_MAX_N = 20_000  # largest pool the dense (n, n) engines handle comfortably
SPARSE_MIN_N = 200_000  # past this, only O(n·k) memory is acceptable


def auto_engine_config(
    n: int, *, backend: str | None = None, mode: str = "budget"
) -> EngineConfig:
    """The documented ``engine='auto'`` policy (README §Engines).

    ======================  =========================================
    situation               chosen engine
    ======================  =========================================
    mode='cover'            matrix — the only cover-capable engine
    n ≤ 20 000              matrix — dense exact greedy fits; TPU-friendly
    20 000 < n ≤ 200 000    device on TPU (fused ``fl_gains_argmax``
                            sweeps — the refresh hot path), features
                            elsewhere (matrix-free blocked greedy)
    n > 200 000             sparse — O(n·k) memory, the million-point
                            engine
    ======================  =========================================

    Args:
      n: pool size the selection will run over.
      backend: jax backend name; defaults to ``jax.default_backend()``
        (explicit for the policy-table tests).
      mode: 'budget' | 'cover' (cover forces the matrix engine).
    """
    if backend is None:
        backend = jax.default_backend()
    if mode == "cover" or n <= DENSE_MAX_N:
        name = "matrix"
    elif n <= SPARSE_MIN_N:
        name = "device" if backend == "tpu" else "features"
    else:
        name = "sparse"
    return get_engine(name).config_cls()
