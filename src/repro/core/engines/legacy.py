"""Deprecation shims for the pre-registry flat engine-knob surface.

Before the SelectionEngine registry (PR 4), every engine hung its tuning
knobs directly off ``CraigConfig`` as engine-prefixed fields and
``distributed_select`` re-threaded them as keyword arguments.  This module
is the ONLY place in ``src/`` that still references those flat knob names
— enforced by ``tests/test_no_flat_engine_knobs.py`` — and its job is to
map them onto the typed ``EngineConfig``s with a single
``DeprecationWarning`` per resolution.

Migration guide (README §Engines has the full table)::

    engine='sparse', topk_k=64, topk_impl='pallas'
        -> engine=SparseConfig(k=64, impl='pallas')
    engine='device', device_q=16, device_stale_tol=0.8,
                     device_tile_dtype='bfloat16'
        -> engine=DeviceConfig(q=16, stale_tol=0.8, tile_dtype='bfloat16')
    engine='features', gains_impl='pallas'
        -> engine=FeaturesConfig(gains_impl='pallas')
    engine='stochastic', stochastic_delta=0.05
        -> engine=StochasticConfig(delta=0.05)
    engine='matrix' / 'lazy'
        -> engine=MatrixConfig() / LazyConfig()
"""
from __future__ import annotations

import dataclasses
import warnings

from repro.core.engines.base import EngineConfig
from repro.core.engines.registry import get_engine

__all__ = [
    "LegacyEngineKnobs",
    "resolve_engine_config",
    "resolve_distributed_engine",
]

_LEGACY_ENGINE_STRINGS = (
    "matrix", "lazy", "stochastic", "features", "sparse", "device",
)


@dataclasses.dataclass(frozen=True, kw_only=True)
class LegacyEngineKnobs:
    """Deprecated flat engine knobs, inherited by ``CraigConfig``.

    Kept so pre-PR-4 call sites (``CraigConfig(engine='sparse',
    topk_k=32)``) keep constructing; :func:`resolve_engine_config` is the
    only reader and maps them onto the typed configs.  New code sets
    ``CraigConfig.engine`` to an ``EngineConfig`` and never touches these.

    kw_only: inheriting would otherwise prepend these fields to
    ``CraigConfig``'s positional order and silently re-bind positional
    construction; keyword-only turns that into a loud ``TypeError``.
    """

    stochastic_delta: float = 0.01
    gains_impl: str = "jax"
    topk_k: int = 64
    topk_impl: str = "jax"
    device_q: int = 1
    device_stale_tol: float = 0.7
    device_tile_dtype: str = "float32"


def _map_legacy_string(cfg, engine: str) -> EngineConfig:
    """Legacy engine string + flat knobs → the equivalent typed config."""
    cfg_cls = get_engine(engine).config_cls
    if engine == "stochastic":
        return cfg_cls(delta=cfg.stochastic_delta)
    if engine == "features":
        return cfg_cls(gains_impl=cfg.gains_impl)
    if engine == "sparse":
        return cfg_cls(k=cfg.topk_k, impl=cfg.topk_impl)
    if engine == "device":
        return cfg_cls(
            q=cfg.device_q,
            stale_tol=cfg.device_stale_tol,
            tile_dtype=cfg.device_tile_dtype,
            gains_impl=cfg.gains_impl,
        )
    return cfg_cls()  # matrix / lazy — no knobs


def _nondefault_knobs(cfg) -> dict:
    """Flat knobs whose value differs from the LegacyEngineKnobs default."""
    return {
        f.name: getattr(cfg, f.name)
        for f in dataclasses.fields(LegacyEngineKnobs)
        if getattr(cfg, f.name) != f.default
    }


def resolve_engine_config(cfg, _stacklevel: int = 3) -> EngineConfig | None:
    """``CraigConfig.engine`` (str | EngineConfig) → typed EngineConfig.

    Returns None for ``'auto'`` — the caller resolves per pool via
    ``registry.auto_engine_config``.  Legacy strings map the flat knobs
    onto the typed config and emit one ``DeprecationWarning``.  Flat knobs
    combined with a typed config or ``'auto'`` have nothing to attach to;
    they are ignored with a loud warning (half-migrated call sites).
    ``_stacklevel`` points the warnings at the *user's* call site —
    wrappers that add a frame (``CraigSelector.resolve_engine``) bump it.
    """
    engine = cfg.engine
    if isinstance(engine, EngineConfig) or engine == "auto":
        stray = _nondefault_knobs(cfg)
        if stray:
            warnings.warn(
                f"CraigConfig(engine={engine!r}) ignores the legacy flat "
                f"engine knobs {stray} — set them on the typed EngineConfig "
                "instead (migration guide: README §Engines)",
                UserWarning,
                stacklevel=_stacklevel,
            )
        return engine if isinstance(engine, EngineConfig) else None
    if engine not in _LEGACY_ENGINE_STRINGS:
        raise ValueError(
            f"unknown engine {engine!r}: pass an EngineConfig, 'auto', or "
            f"one of {_LEGACY_ENGINE_STRINGS}"
        )
    typed = _map_legacy_string(cfg, engine)
    warnings.warn(
        f"CraigConfig(engine={engine!r}) with flat engine knobs is "
        f"deprecated; use CraigConfig(engine={typed!r}) "
        "(migration guide: README §Engines)",
        DeprecationWarning,
        stacklevel=_stacklevel,
    )
    return typed


_DISTRIBUTED_KNOBS = ("topk_k", "device_q", "device_stale_tol")


def resolve_distributed_engine(local_engine, knobs: dict) -> EngineConfig | None:
    """``distributed_select``'s legacy flat-kwarg surface → typed config.

    ``local_engine`` is a typed EngineConfig, ``'auto'`` (returns None —
    the caller resolves per shard via ``auto_engine_config``), or a legacy
    string combined with flat knob kwargs collected in ``knobs``.
    """
    unknown = set(knobs) - set(_DISTRIBUTED_KNOBS)
    if unknown:
        raise TypeError(
            f"distributed_select got unexpected kwargs {sorted(unknown)}"
        )
    if isinstance(local_engine, EngineConfig):
        if knobs:
            raise TypeError(
                "pass either a typed EngineConfig or legacy flat engine "
                "kwargs, not both"
            )
        return local_engine
    if local_engine == "auto":
        if knobs:
            raise TypeError(
                "legacy flat engine kwargs require a legacy local_engine "
                "string; with local_engine='auto' pass a typed EngineConfig"
            )
        return None
    if local_engine not in _LEGACY_ENGINE_STRINGS:
        raise ValueError(f"unknown local_engine {local_engine!r}")
    cfg_cls = get_engine(local_engine).config_cls
    if local_engine == "sparse":
        typed = cfg_cls(k=knobs.get("topk_k", 64))
    elif local_engine == "device":
        typed = cfg_cls(
            q=knobs.get("device_q", 1),
            stale_tol=knobs.get("device_stale_tol", 0.7),
            gains_impl="jax",  # shard_map bodies use the jnp sweep
        )
    else:
        typed = cfg_cls()
    warnings.warn(
        f"distributed_select(local_engine={local_engine!r}, ...) with flat "
        f"engine kwargs is deprecated; pass local_engine={typed!r} "
        "(migration guide: README §Engines)",
        DeprecationWarning,
        stacklevel=3,
    )
    return typed
