"""SelectionEngine protocol, typed per-engine configs, shared FL math.

The greedy facility-location engines (DESIGN.md §3) are first-class,
swappable strategy objects.  Each engine module under
``repro.core.engines`` defines three things:

  * a frozen ``EngineConfig`` dataclass — the engine's *complete* tuning
    surface.  Configs serialize via ``to_dict``/``from_dict`` so
    checkpointed sampler/refresher metadata records exactly which engine
    (and which settings) produced a selection, and restores it;
  * a ``SelectionEngine`` subclass implementing
    ``select(feats, budget, *, metric, init_selected, rng) -> FLResult``
    (plus ``select_cover`` where supported);
  * a ``Capabilities`` record — *what the engine can do* (exact vs
    approximate, matrix-free, jit-safe, cover mode, metrics) and a
    ``memory(n, d)`` footprint estimate.  Callers gate on capabilities
    instead of hard-coding engine names: ``CraigSelector`` rejects
    cover mode / metrics from them, ``auto_engine_config``
    (``registry.py``) picks engines from them.

CRAIG's guarantee (paper Thm. 1/2) is engine-independent: any greedy that
bounds the per-element gradient estimation error ε preserves the
convergence rate, so engines are freely swappable behind this protocol and
a new engine is a ~1-file plugin (subclass + ``@register_engine``).

Metrics: every engine speaks ``'l2'`` natively.  ``'cosine'`` is routed
through l2 on unit-normalized features for the matrix-free engines
(``normalize_for_metric``): on the unit sphere ‖x−y‖ = √(2·(1−cos θ)) is a
monotone transform of cosine distance, so similarity *orderings* — and
hence the medoid structure greedy recovers on clustered pools — are
preserved.  Their residual coverage is converted back to cosine-distance
units (``cosine_residual_coverage``) so ``coverage``/``epsilon_hat`` stay
engine-independent per metric.  The dense engines build the cosine
distance matrix directly (``pairwise_distances``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, ClassVar, NamedTuple

import jax
import jax.numpy as jnp


class FLResult(NamedTuple):
    """Result of a greedy facility-location run.

    Attributes:
      indices:  (r,) int32 — selected ground-set indices, in greedy order.
      gains:    (r,) float32 — marginal gain of each selection (non-increasing
                for exact greedy; approximately so for stochastic greedy).
      weights:  (r,) float32 — γ_j cluster sizes (paper Alg. 1 line 8);
                sum(weights) == n.
      coverage: () float32 — final L(S) = Σ_i min_{j∈S} d_ij, the paper's
                upper bound on the gradient estimation error (Eq. 8).
    """

    indices: jax.Array
    gains: jax.Array
    weights: jax.Array
    coverage: jax.Array


# ---------------------------------------------------------------------------
# Protocol: config, capabilities, engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Base of every typed engine config (frozen, fully defaulted).

    Subclasses set the class attribute ``name`` to their registry key and
    declare the engine's knobs as dataclass fields.  ``to_dict``/
    ``from_dict`` round-trip exactly (JSON-able), so a config can ride
    through checkpoint metadata and be restored.
    """

    name: ClassVar[str] = "?"

    def to_dict(self) -> dict:
        """JSON-able ``{"name": ..., **fields}`` snapshot."""
        return {"name": type(self).name, **dataclasses.asdict(self)}

    @classmethod
    def from_dict(cls, d: dict) -> "EngineConfig":
        """Inverse of :meth:`to_dict`; dispatches on ``d['name']``."""
        from repro.core.engines.registry import engine_config_from_dict

        return engine_config_from_dict(d)


@dataclasses.dataclass(frozen=True)
class Capabilities:
    """What a SelectionEngine can do — the registry's dispatch surface.

    Attributes:
      exact: selections reproduce exact greedy bit-for-bit at the engine's
        *default* config (stochastic/sparse trade exactness for speed;
        device is exact at its q=1 default and near-exact past it).
      matrix_free: never materializes the dense (n, n) similarity.
      jit_safe: ``select`` is jax.jit / shard_map traceable end to end
        (host-side engines — lazy heap, sparse CSC walk — are not).  Also
        the device-resident-handoff gate (DESIGN.md §9): trainer refreshes
        keep extracted features a ``jax.Array`` through
        ``CraigSelector.select`` when this is true, and materialize the
        one host copy the engine needs when it is false.
      supports_cover: implements submodular cover (grow until
        L(S) ≤ ε, paper Eq. 12).
      supports_metrics: accepted ``metric=`` values ('cosine' may be
        served via l2 on unit-normalized features, see module docstring).
      memory: ``memory(n, d) -> bytes`` peak-footprint estimate for an
        (n, d) pool at the engine's default config — what the
        ``engine='auto'`` policy reasons about.
    """

    exact: bool
    matrix_free: bool
    jit_safe: bool
    supports_cover: bool
    supports_metrics: tuple[str, ...]
    memory: Callable[[int, int], int]


class SelectionEngine:
    """A greedy facility-location maximizer behind the common protocol.

    Subclasses set ``name`` (registry key), ``config_cls`` (their
    EngineConfig), ``capabilities``, and implement :meth:`select`.
    Instances are cheap, stateless wrappers binding a config.
    """

    name: ClassVar[str]
    config_cls: ClassVar[type[EngineConfig]]
    capabilities: ClassVar[Capabilities]

    def __init__(self, config: EngineConfig | None = None):
        if config is None:
            config = self.config_cls()
        if not isinstance(config, self.config_cls):
            raise TypeError(
                f"engine {self.name!r} expects {self.config_cls.__name__}, "
                f"got {type(config).__name__}"
            )
        self.config = config

    def select(
        self,
        feats: jax.Array,
        budget: int,
        *,
        metric: str = "l2",
        init_selected=None,
        rng=None,
    ) -> FLResult:
        """Select ``budget`` medoids from (n, d) proxy features.

        Args:
          feats: (n, d) gradient-proxy features.
          budget: number of elements to select (static; callers clamp ≤ n).
          metric: dissimilarity, one of ``capabilities.supports_metrics``.
          init_selected: optional warm-start prefix (indices, greedy order)
            whose cover state is replayed before greedy resumes.
          rng: seed / PRNG key for stochastic engines (ignored by the
            deterministic ones).
        """
        raise NotImplementedError

    def select_cover(
        self, feats: jax.Array, epsilon: float, *, metric: str = "l2"
    ) -> FLResult:
        """Submodular cover (paper Eq. 12): grow S until L(S) ≤ epsilon.

        Only engines with ``capabilities.supports_cover`` implement this.
        """
        raise ValueError(
            f"engine {self.name!r} does not support mode='cover' "
            "(Capabilities.supports_cover is False)"
        )

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"{type(self).__name__}({self.config!r})"


# ---------------------------------------------------------------------------
# Shared similarity / objective math (used by every engine module)
# ---------------------------------------------------------------------------


def pairwise_distances(feats: jax.Array, metric: str = "l2") -> jax.Array:
    """Dense (n, n) proxy-gradient dissimilarity matrix d_ij (paper Eq. 7/9)."""
    feats = feats.astype(jnp.float32)
    if metric == "l2":
        sq = jnp.sum(feats * feats, axis=-1)
        d2 = sq[:, None] + sq[None, :] - 2.0 * feats @ feats.T
        return jnp.sqrt(jnp.maximum(d2, 0.0))
    if metric == "cosine":
        nf = feats / (jnp.linalg.norm(feats, axis=-1, keepdims=True) + 1e-12)
        return 1.0 - nf @ nf.T
    raise ValueError(f"unknown metric {metric!r}")


def normalize_for_metric(feats: jax.Array, metric: str) -> jax.Array:
    """Feature-space routing for the matrix-free engines.

    'l2' passes through; 'cosine' unit-normalizes rows so plain l2 greedy
    runs on the sphere (monotone-equivalent similarity ordering — see the
    module docstring).
    """
    if metric == "l2":
        return feats
    if metric == "cosine":
        feats = feats.astype(jnp.float32)
        return feats / (jnp.linalg.norm(feats, axis=-1, keepdims=True) + 1e-12)
    raise ValueError(f"unknown metric {metric!r}")


def cosine_residual_coverage(
    feats_normalized: jax.Array, indices: jax.Array
) -> jax.Array:
    """L(S) = Σ_i min_{j∈S} (1 − cos θ_ij) from unit-normalized features.

    The matrix-free engines select cosine pools via l2 on the sphere, where
    ‖x − m‖² = 2·(1 − cos θ); this converts their residual back to the
    dense engines' cosine-distance units so ``coverage``/``epsilon_hat``
    are engine-independent per metric (``engine='auto'`` must not change
    units when it crosses a pool-size threshold).  O(n·r) memory and
    jit-safe — fine for the features/device engines (their γ-assignment
    step already materializes (n, r)); the sparse engine uses a blocked
    equivalent to preserve its O(n·k) contract.
    """
    sel = feats_normalized[indices]  # (r, d)
    sq_x = jnp.sum(feats_normalized * feats_normalized, axis=-1)
    sq_s = jnp.sum(sel * sel, axis=-1)
    d2 = jnp.maximum(
        sq_x[:, None] + sq_s[None, :] - 2.0 * feats_normalized @ sel.T, 0.0
    )
    return jnp.sum(jnp.min(d2, axis=1)) / 2.0


def facility_location_value(sim: jax.Array, selected_mask: jax.Array) -> jax.Array:
    """F(S) = Σ_i max_{j∈S} s_ij with empty-set convention F(∅)=0 (s0 at 0).

    Args:
      sim: (n, n) similarity matrix (s_ij ≥ 0; s0 baseline already subtracted).
      selected_mask: (n,) bool.
    """
    neg = jnp.asarray(-jnp.inf, sim.dtype)
    masked = jnp.where(selected_mask[None, :], sim, neg)
    best = jnp.max(masked, axis=1)
    return jnp.sum(jnp.where(jnp.any(selected_mask), jnp.maximum(best, 0.0), 0.0))


def coverage_l(dist: jax.Array, indices: jax.Array) -> jax.Array:
    """L(S) = Σ_i min_{j∈S} d_ij  (paper Eq. 8) for selected ``indices``."""
    sub = dist[:, indices]  # (n, r)
    return jnp.sum(jnp.min(sub, axis=1))


def assign_and_weights(dist_to_sel: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Given (n, r) distances to selected medoids, return (assignment, γ)."""
    assign = jnp.argmin(dist_to_sel, axis=1)
    r = dist_to_sel.shape[1]
    weights = jnp.zeros((r,), jnp.float32).at[assign].add(1.0)
    return assign, weights


def _as_init_idx(init_selected, budget: int) -> jnp.ndarray:
    """Validate/normalize a warm-start prefix for the JAX engines.

    Returns a (r₀,) int32 array with r₀ ≤ budget; the length is static (it
    comes from the array shape), so ``budget − r₀`` remains a Python int
    under jit.
    """
    idx = jnp.asarray(init_selected, jnp.int32)
    if idx.ndim != 1:
        raise ValueError("init_selected must be 1-D")
    if idx.shape[0] > budget:
        raise ValueError(
            f"init_selected has {idx.shape[0]} elements > budget {budget}"
        )
    return idx


def _replay_prefix(init_selected, budget: int, n: int, col_fn, pw=None):
    """Replay a warm-start prefix's cover state (shared by the JAX engines).

    ``col_fn(e)`` returns the (n,) similarity column of element e; marginal
    gains are recorded in prefix order (optionally ``pw``-weighted), exactly
    as a cold greedy run would have produced them.

    Returns (init_idx (r₀,), init_gains (r₀,), cur_max (n,), chosen (n,)).
    """
    cur_max = jnp.zeros((n,), jnp.float32)
    chosen = jnp.zeros((n,), bool)
    if init_selected is None:
        return jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.float32), cur_max, chosen
    init_idx = _as_init_idx(init_selected, budget)

    def warm(cur, e):
        col = col_fn(e)
        gap = jnp.maximum(col - cur, 0.0)
        g = jnp.sum(gap) if pw is None else jnp.dot(pw, gap)
        return jnp.maximum(cur, col), g

    cur_max, init_gains = jax.lax.scan(warm, cur_max, init_idx)
    return init_idx, init_gains, cur_max, chosen.at[init_idx].set(True)


def _cluster_weights(
    sim: jax.Array, indices: jax.Array, point_weights: jax.Array | None = None
) -> jax.Array:
    """γ_j = Σ_{i : j = argmax_{s∈S} s_is} w_i (paper Alg. 1 line 8)."""
    sub = sim[:, indices]  # (n, r)
    assign = jnp.argmax(sub, axis=1)  # (n,) positions into S
    r = indices.shape[0]
    pw = (
        jnp.ones((sim.shape[0],), jnp.float32)
        if point_weights is None
        else point_weights.astype(jnp.float32)
    )
    return jnp.zeros((r,), jnp.float32).at[assign].add(pw)
