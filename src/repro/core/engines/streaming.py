"""Sieve-streaming facility-location engine (DESIGN.md §10).

The batch engines re-sweep the whole pool per refresh; under continuous
ingestion (ROADMAP north-star) that cost grows with the pool while the
information per refresh does not.  Sieve-streaming (Badanidiyuru et al.,
KDD'14) maintains a *geometric grid of threshold sieves* instead: for each
guess ``v = (1+eps)^j`` of OPT, a sieve greedily admits an arriving element
when its marginal gain clears ``(v/2 − f(S_v)) / (k − |S_v|)``.  One sieve's
guess lands within (1+eps) of OPT and its set achieves ``(1/2 − O(eps))·OPT``
— a one-pass, O(Δn·k)-per-delta guarantee with no re-sweep of prior data.

Adaptation to CRAIG's facility location: past points cannot be revisited, so
the objective is tracked as the running per-point mean coverage — each sieve
accumulates ``Σ_i max_{j∈S_v} s_ij`` over the deltas it has seen (``fval``),
marginal gains are estimated batch-locally on the arriving delta (CREST,
arXiv:2306.01244: selection over pool subsets arriving over time preserves
the data-efficiency guarantees when deltas are representative samples), and
the max-singleton estimate ``m`` that anchors the grid is the running max
*mean* similarity — scale-stable as the stream grows.  When ``m`` rises, the
live window of OPT guesses ``[m, 2km]`` shifts: each sieve slot holds an
absolute level and re-anchors by jumping a multiple of L levels (retiring its
selections), so the L slots always hold L consecutive levels of the current
window — a circular buffer over the geometric grid, O(L) per element.

With a single delta equal to the full pool, the estimates are exact and
``select`` *is* textbook sieve-streaming, hence the property-test gate
``F(S) ≥ (1/2 − eps)·F(greedy)`` (tests/test_selection_properties.py).

Three surfaces:

  * ``init_streaming_state`` / ``ingest_delta`` / ``streaming_result`` — the
    functional core.  ``StreamingState`` is an arrays-only NamedTuple (a
    pytree): ``ingest_delta`` is jit-compiled once per delta shape, and the
    state serializes losslessly for checkpoints (``StreamingSelector``).
  * ``StreamingEngine`` (``engine='streaming'``) — the registry plugin: a
    one-shot ``select`` (init → single-delta ingest → finalize) behind the
    common protocol; not exact, matrix-free, jit-safe.
  * ``StreamingSelector`` — the stateful host wrapper the coreset service
    builds on: sequential ``ingest(delta)`` calls, per-class stratified
    budgets (paper §5) apportioned at ``result`` time from observed class
    arrival counts, and a JSON-able ``state_dict`` that resumes
    bit-identically mid-stream.

Finalization (``streaming_result``) maps the best sieve back to a full
``FLResult``: it replays the warm prefix, takes the sieve's picks in
admission order, backfills any remaining budget with worst-covered points
(farthest-point traversal), and computes γ weights / residual coverage
against the pool — the only step that touches all n rows, and the only one
whose cost scales with the pool rather than the delta.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import ClassVar, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines.base import (
    Capabilities,
    EngineConfig,
    FLResult,
    SelectionEngine,
    _replay_prefix,
    cosine_residual_coverage,
    normalize_for_metric,
)
from repro.core.engines.registry import register_engine

__all__ = [
    "LVL_UNSET",
    "StreamingConfig",
    "StreamingEngine",
    "StreamingSelector",
    "StreamingState",
    "init_streaming_state",
    "ingest_delta",
    "num_sieves",
    "streaming_result",
    "streaming_result_blocked",
]

# Sentinel level for a sieve slot that has never been anchored (no element
# seen yet).  Any real absolute level ``floor(log m / log(1+eps))`` is far
# above it, so the first element cold-starts the whole grid.
LVL_UNSET = -(2**30)


class StreamingState(NamedTuple):
    """Serializable sieve-streaming state — arrays only, hence a pytree.

    Static meta (budget, eps) is *not* carried here: it is baked into the
    array shapes (L, k) at :func:`init_streaming_state` time and travels
    alongside in ``StreamingSelector.state_dict`` / engine configs.

    Attributes:
      n_seen: () int32 — points ingested so far.
      d_max: () float32 — similarity offset ``2·max‖x‖ + 1e-6``, frozen at
        the first ingest so sieve values stay comparable across deltas
        (later similarities clip at 0).
      m: () float32 — running max singleton *mean* similarity (grid anchor).
      lvl: (L,) int32 — absolute threshold level per sieve slot
        (``v = (1+eps)^lvl``); ``LVL_UNSET`` before the first element.
      count: (L,) int32 — elements admitted per sieve.
      fval: (L,) float32 — Σ coverage of past delta points at their ingest
        time, per sieve (the running objective estimate, in sum units).
      fval_pre: () float32 — same accumulator for the warm prefix alone;
        the O(1) reset value when a sieve retires.
      sel_idx: (L, k) int32 — admitted indices per sieve (-1 = empty slot).
      sel_feats: (L, k, d) float32 — their features (past points are gone;
        the sieves keep the only copy).
      pre_idx: (r0,) int32 — warm-start prefix indices (excluded from sieve
        admission; replayed at finalize).
      pre_feats: (r0, d) float32 — prefix features.
    """

    n_seen: jax.Array
    d_max: jax.Array
    m: jax.Array
    lvl: jax.Array
    count: jax.Array
    fval: jax.Array
    fval_pre: jax.Array
    sel_idx: jax.Array
    sel_feats: jax.Array
    pre_idx: jax.Array
    pre_feats: jax.Array

    @property
    def capacity(self) -> int:
        """k — sieve capacity (budget minus warm-prefix length)."""
        return self.sel_idx.shape[1]

    @property
    def num_levels(self) -> int:
        """L — number of sieve slots."""
        return self.lvl.shape[0]


def num_sieves(budget: int, eps: float, levels: int = 0) -> int:
    """Sieve-count default: span the OPT window ``[m, 2·budget·m]``.

    The geometric grid needs ``log(2k)/log(1+eps)`` levels to cover the
    window; capped at 64 (OPT sits far below ``k·m`` on real pools) and
    floored at 4.  ``levels > 0`` overrides.
    """
    if levels > 0:
        return int(levels)
    k = max(int(budget), 2)
    want = math.ceil(math.log(2.0 * k) / math.log1p(eps)) + 1
    return max(4, min(64, want))


def init_streaming_state(
    budget: int,
    dim: int,
    *,
    eps: float = 0.15,
    levels: int = 0,
    init_selected=None,
    init_feats=None,
) -> StreamingState:
    """Empty sieve grid for ``budget`` selections over ``dim``-d features.

    ``init_selected``/``init_feats`` seed a warm-start prefix: those
    elements are treated as already selected (every sieve's coverage starts
    from theirs; they are excluded from admission) and are replayed first at
    :func:`streaming_result`, preserving the warm-start-prefix contract of
    the batch engines.
    """
    budget = int(budget)
    if budget < 1:
        raise ValueError(f"budget must be ≥ 1, got {budget}")
    if init_selected is None:
        pre_idx = jnp.zeros((0,), jnp.int32)
        pre_feats = jnp.zeros((0, dim), jnp.float32)
    else:
        pre_idx = jnp.asarray(init_selected, jnp.int32).ravel()
        if init_feats is None:
            raise ValueError("init_selected needs init_feats (past rows are gone)")
        pre_feats = jnp.asarray(init_feats, jnp.float32).reshape(-1, dim)
        if pre_feats.shape[0] != pre_idx.shape[0]:
            raise ValueError(
                f"init_feats rows {pre_feats.shape[0]} != "
                f"init_selected length {pre_idx.shape[0]}"
            )
        if pre_idx.shape[0] > budget:
            raise ValueError(
                f"init_selected has {pre_idx.shape[0]} elements > budget {budget}"
            )
    k = budget - pre_idx.shape[0]
    L = num_sieves(budget, eps, levels)
    return StreamingState(
        n_seen=jnp.zeros((), jnp.int32),
        d_max=jnp.zeros((), jnp.float32),
        m=jnp.zeros((), jnp.float32),
        lvl=jnp.full((L,), LVL_UNSET, jnp.int32),
        count=jnp.zeros((L,), jnp.int32),
        fval=jnp.zeros((L,), jnp.float32),
        fval_pre=jnp.zeros((), jnp.float32),
        sel_idx=jnp.full((L, k), -1, jnp.int32),
        sel_feats=jnp.zeros((L, k, dim), jnp.float32),
        pre_idx=pre_idx,
        pre_feats=pre_feats,
    )


def _sim_to(feats: jax.Array, sq: jax.Array, x: jax.Array, d_max) -> jax.Array:
    """(Δn,) clipped similarity of every delta point to one element x."""
    d2 = sq + jnp.sum(x * x) - 2.0 * (feats @ x)
    return jnp.maximum(d_max - jnp.sqrt(jnp.maximum(d2, 0.0)), 0.0)


def _ingest_delta(state: StreamingState, feats, idx, eps) -> StreamingState:
    """One-pass sieve update over a megabatch delta (jit-compiled).

    Work is O(Δn·(Δn + L)·d′) with d′ the feature dim — independent of
    ``n_seen``: prior data is never revisited.
    """
    feats = jnp.asarray(feats, jnp.float32)
    dn, dim = feats.shape
    L, k = state.num_levels, state.capacity
    r0 = state.pre_idx.shape[0]
    idx = jnp.asarray(idx, jnp.int32)
    sq = jnp.sum(feats * feats, axis=-1)

    # freeze the similarity offset at first ingest (later sims clip at 0)
    d_max = jnp.where(
        state.n_seen == 0, 2.0 * jnp.sqrt(jnp.max(sq)) + 1e-6, state.d_max
    )

    # prefix coverage of the delta (the floor every sieve shares)
    if r0 > 0:
        psq = jnp.sum(state.pre_feats * state.pre_feats, axis=-1)
        d2p = sq[:, None] + psq[None, :] - 2.0 * (feats @ state.pre_feats.T)
        simp = jnp.maximum(d_max - jnp.sqrt(jnp.maximum(d2p, 0.0)), 0.0)
        cov_pre = jnp.max(simp, axis=1)
        is_pre = jnp.any(idx[:, None] == state.pre_idx[None, :], axis=1)
    else:
        cov_pre = jnp.zeros((dn,), jnp.float32)
        is_pre = jnp.zeros((dn,), bool)
    pre_sum = jnp.sum(cov_pre)

    if k == 0:  # budget == prefix: nothing to sieve, just account coverage
        return state._replace(
            n_seen=state.n_seen + dn,
            d_max=d_max,
            fval=state.fval + pre_sum,
            fval_pre=state.fval_pre + pre_sum,
        )

    # coverage of the delta by each sieve's existing selections
    ssq = jnp.sum(state.sel_feats * state.sel_feats, axis=-1)  # (L, k)
    dots = jnp.einsum("nd,lkd->lnk", feats, state.sel_feats)
    d2s = sq[None, :, None] + ssq[:, None, :] - 2.0 * dots
    sims = jnp.maximum(d_max - jnp.sqrt(jnp.maximum(d2s, 0.0)), 0.0)
    valid = jnp.arange(k)[None, None, :] < state.count[:, None, None]
    cov0 = jnp.max(jnp.where(valid, sims, 0.0), axis=2)  # (L, Δn)
    cov0 = jnp.maximum(cov0, cov_pre[None, :])

    n_seen_f = state.n_seen.astype(jnp.float32)
    log1p_eps = math.log1p(float(eps))
    slot_arange = jnp.arange(L, dtype=jnp.int32)

    # The scan carries only the O(L·Δn) cover rows and O(L) scalars; the
    # big (L, k[, d]) selection arrays are never read inside the body, so
    # they are reconstructed post-scan from the accept/retire history —
    # carrying them would copy L·k·d floats per element.
    def step(carry, xs):
        m, lvl, count, fval, covsum, cov = carry
        x, ispre = xs
        col = _sim_to(feats, sq, x, d_max)  # (Δn,)

        # grid anchor: running max singleton mean; re-anchor the window
        m = jnp.maximum(m, jnp.mean(col))
        j_lo = jnp.floor(jnp.log(m) / log1p_eps).astype(jnp.int32)
        unset = lvl == LVL_UNSET
        w = jnp.maximum(-((lvl - j_lo) // L), 0)
        lvl = jnp.where(unset, j_lo + slot_arange, lvl + w * L)
        retire = unset | (w > 0)
        count = jnp.where(retire, 0, count)
        cov = jnp.where(retire[:, None], cov_pre[None, :], cov)
        covsum = jnp.where(retire, pre_sum, covsum)
        fval = jnp.where(retire, state.fval_pre, fval)

        # threshold admission, vectorized over the L sieves
        v = jnp.exp(lvl.astype(jnp.float32) * log1p_eps)
        g = jnp.sum(jnp.maximum(col[None, :] - cov, 0.0), axis=1)  # (L,)
        g_mean = g / dn
        f_cur = (fval + covsum) / (n_seen_f + dn)
        thresh = (0.5 * v - f_cur) / jnp.maximum(k - count, 1).astype(jnp.float32)
        accept = (count < k) & (g_mean >= thresh) & (g_mean > 0.0) & (~ispre)

        count = count + accept.astype(jnp.int32)
        cov_new = jnp.maximum(cov, col[None, :])
        cov = jnp.where(accept[:, None], cov_new, cov)
        covsum = jnp.where(accept, jnp.sum(cov_new, axis=1), covsum)
        return (m, lvl, count, fval, covsum, cov), (accept, retire)

    carry0 = (
        state.m,
        state.lvl,
        state.count,
        state.fval,
        jnp.sum(cov0, axis=1),
        cov0,
    )
    (m, lvl, count, fval, covsum, _), (acc_hist, ret_hist) = jax.lax.scan(
        step, carry0, (feats, is_pre)
    )

    # Reconstruct (sel_idx, sel_feats) from the (Δn, L) histories: a sieve
    # keeps only admissions after its last retirement; those fill slots in
    # arrival order, starting at the pre-delta count for never-retired
    # sieves and at 0 otherwise.  One O(Δn·L·d) scatter, not Δn of them.
    t_col = jnp.arange(dn, dtype=jnp.int32)[:, None]
    last_ret = jnp.max(jnp.where(ret_hist, t_col, -1), axis=0)  # (L,)
    keep = acc_hist & (t_col >= last_ret[None, :])  # (Δn, L)
    retired = last_ret >= 0
    base = jnp.where(retired, 0, state.count)  # slot offset at (re)start
    slot = base[None, :] + jnp.cumsum(keep.astype(jnp.int32), axis=0) - 1
    slot_safe = jnp.where(keep, jnp.clip(slot, 0, k - 1), k)  # k = dump slot

    sel_idx = jnp.where(retired[:, None], -1, state.sel_idx)
    sel_feats = jnp.where(retired[:, None, None], 0.0, state.sel_feats)
    l_grid = jnp.broadcast_to(jnp.arange(L)[None, :], (dn, L))
    sel_idx = (
        jnp.concatenate([sel_idx, jnp.full((L, 1), -1, jnp.int32)], axis=1)
        .at[l_grid.ravel(), slot_safe.ravel()]
        .set(jnp.broadcast_to(idx[:, None], (dn, L)).ravel())[:, :k]
    )
    sel_feats = (
        jnp.concatenate([sel_feats, jnp.zeros((L, 1, dim), jnp.float32)], axis=1)
        .at[l_grid.ravel(), slot_safe.ravel()]
        .set(jnp.broadcast_to(feats[:, None, :], (dn, L, dim)).reshape(-1, dim))[
            :, :k
        ]
    )
    return state._replace(
        n_seen=state.n_seen + dn,
        d_max=d_max,
        m=m,
        lvl=lvl,
        count=count,
        fval=fval + covsum,
        fval_pre=state.fval_pre + pre_sum,
        sel_idx=sel_idx,
        sel_feats=sel_feats,
    )


ingest_delta = jax.jit(_ingest_delta, static_argnums=(3,))


def streaming_result(
    state: StreamingState, feats: jax.Array, budget: int, *, d_max=None
) -> FLResult:
    """Finalize: best sieve → full FLResult against the pool (dense sweep).

    ``feats`` is the (n,) pool the stored indices refer to (the service
    keeps it; the one-shot engine has it by construction).  Order: warm
    prefix (replayed), then the best sieve's picks in admission order, then
    worst-covered backfill (farthest-point) for any unfilled budget.  γ and
    coverage use this call's own offset (or the caller's ``d_max`` — the
    per-class selector passes one pool-wide offset so class coverages and
    gains share units), so the frozen ingest-time ``d_max`` never leaks
    into reported units.

    This is the jit-traceable reference path — one dense matvec per budget
    step plus an (n, budget) similarity materialization.  The host-side
    :func:`streaming_result_blocked` computes the same result with blocked
    tiles; CI asserts parity between the two.
    """
    feats = jnp.asarray(feats, jnp.float32)
    n, _ = feats.shape
    budget = int(min(int(budget), n))
    if budget < 1:
        raise ValueError(f"budget must be ≥ 1, got {budget}")
    k = state.capacity
    r0 = state.pre_idx.shape[0]
    if r0 > budget:
        raise ValueError(f"warm prefix {r0} exceeds finalize budget {budget}")

    sq = jnp.sum(feats * feats, axis=-1)
    if d_max is None:
        d_maxf = 2.0 * jnp.sqrt(jnp.max(sq)) + 1e-6
    else:
        d_maxf = jnp.asarray(d_max, jnp.float32)

    def sim_cols(e_arr: jax.Array) -> jax.Array:
        """(n, m) similarity of every pool point to elements ``e_arr``."""
        cf = feats[e_arr]
        d2 = sq[:, None] + jnp.sum(cf * cf, axis=-1)[None, :] - 2.0 * (feats @ cf.T)
        return d_maxf - jnp.sqrt(jnp.maximum(d2, 0.0))

    init_idx, init_gains, cur_max0, chosen0 = _replay_prefix(
        state.pre_idx if r0 > 0 else None,
        budget,
        n,
        lambda e: sim_cols(e[None])[:, 0],
    )

    best = jnp.argmax(state.fval)
    cand = jnp.clip(state.sel_idx[best], -1, n - 1)  # (k,)
    ccount = state.count[best]

    def step(carry, t):
        cur_max, chosen = carry
        resid = jnp.where(chosen, -jnp.inf, d_maxf - cur_max)
        back_e = jnp.argmax(resid).astype(jnp.int32)
        if k > 0:
            se = cand[jnp.clip(t, 0, k - 1)]
            se_safe = jnp.clip(se, 0, n - 1)
            use = (t < ccount) & (se >= 0) & (~chosen[se_safe])
            e = jnp.where(use, se_safe, back_e)
        else:
            e = back_e
        col = sim_cols(e[None])[:, 0]
        gain = jnp.sum(jnp.maximum(col - cur_max, 0.0))
        return (jnp.maximum(cur_max, col), chosen.at[e].set(True)), (
            e.astype(jnp.int32),
            gain,
        )

    (cur_max, _), (new_idx, new_gains) = jax.lax.scan(
        step, (cur_max0, chosen0), jnp.arange(budget - r0)
    )
    indices = jnp.concatenate([init_idx, new_idx])
    gains = jnp.concatenate([init_gains, new_gains]).astype(jnp.float32)

    sel_sim = sim_cols(indices)  # (n, budget)
    assign = jnp.argmax(sel_sim, axis=1)
    weights = jnp.zeros((budget,), jnp.float32).at[assign].add(1.0)
    coverage = jnp.sum(d_maxf - jnp.max(sel_sim, axis=1))
    return FLResult(indices, gains, weights, coverage)


# ---------------------------------------------------------------------------
# Blocked finalize: the host-side fast path (DESIGN.md §10)
# ---------------------------------------------------------------------------
#
# The dense ``streaming_result`` pays one O(n·d) matvec per budget step plus
# a final dense (n, budget) materialization.  The blocked path exploits a
# structural fact of the finalize scan: a backfill (farthest-point) step can
# only occur once the sieve's picks are exhausted (``t ≥ ccount``), and sieve
# picks are distinct and disjoint from the warm prefix, so the dense pick
# sequence decomposes into [prefix | sieve picks | backfill suffix].  The
# first two segments are known up front — a *blocked* sequential replay
# (one (block_n × block_m) similarity tile per matmul, prefix-cummax for the
# per-column cover state) replaces per-step matvecs — and only the short
# backfill suffix stays sequential.  γ assignment and coverage ride along as
# a running per-row (best value, best position) pair, so the (n, budget)
# similarity matrix is never materialized.


@functools.partial(jax.jit, static_argnames=("block_m",))
def _replay_blocked_jax(feats, sq, d_maxf, ef, esq, valid, cur0, block_m: int):
    """Blocked-jnp sequential replay (the CPU/GPU twin of ``kops.fl_replay``).

    ``ef``/``esq``/``valid`` are block-padded (m % block_m == 0); dead
    columns have valid=False.  Returns (gains (m,), cur (n,), best_v (n,),
    best_i (n,)) with the same semantics as the Pallas kernel.
    """
    n = feats.shape[0]
    nblk = ef.shape[0] // block_m
    ef_b = ef.reshape(nblk, block_m, -1)
    esq_b = esq.reshape(nblk, block_m)
    val_b = valid.reshape(nblk, block_m)

    def blk(carry, xs):
        cur, bv, bi, base = carry
        eb, eqb, vb = xs
        d2 = sq[:, None] + eqb[None, :] - 2.0 * (feats @ eb.T)
        s = d_maxf - jnp.sqrt(jnp.maximum(d2, 0.0))  # (n, bm)
        s_cov = jnp.where(vb[None, :], s, -1e30)
        run = jax.lax.cummax(s_cov, axis=1)
        prev = jnp.maximum(
            cur[:, None],
            jnp.concatenate(
                [jnp.full((n, 1), -1e30, jnp.float32), run[:, :-1]], axis=1
            ),
        )
        gains = jnp.sum(jnp.maximum(s_cov - prev, 0.0), axis=0)  # (bm,)
        cur = jnp.maximum(cur, run[:, -1])
        bvb = jnp.max(s_cov, axis=1)
        bib = jnp.argmax(s_cov, axis=1).astype(jnp.int32) + base
        upd = bvb > bv  # strict: earlier block wins ties, like jnp.argmax
        return (
            (cur, jnp.where(upd, bvb, bv), jnp.where(upd, bib, bi),
             base + block_m),
            gains,
        )

    carry0 = (
        cur0,
        jnp.full((n,), -1e30, jnp.float32),
        jnp.zeros((n,), jnp.int32),
        jnp.int32(0),
    )
    (cur, bv, bi, _), gs = jax.lax.scan(blk, carry0, (ef_b, esq_b, val_b))
    return gs.reshape(-1), cur, bv, bi


@jax.jit
def _backfill_step(feats, sq, d_maxf, cur, chosen, bv, bi, pos):
    """One farthest-point backfill pick + incremental γ/coverage update."""
    resid = jnp.where(chosen, -jnp.inf, d_maxf - cur)
    e = jnp.argmax(resid).astype(jnp.int32)
    x = feats[e]
    d2 = sq + jnp.sum(x * x) - 2.0 * (feats @ x)
    col = d_maxf - jnp.sqrt(jnp.maximum(d2, 0.0))
    gain = jnp.sum(jnp.maximum(col - cur, 0.0))
    upd = col > bv
    return (
        e,
        gain,
        jnp.maximum(cur, col),
        chosen.at[e].set(True),
        jnp.where(upd, col, bv),
        jnp.where(upd, pos, bi),
    )


def streaming_result_blocked(
    state: StreamingState,
    feats: jax.Array,
    budget: int,
    *,
    d_max=None,
    impl: str = "auto",
    block_m: int = 128,
) -> FLResult:
    """Blocked finalize: same result as :func:`streaming_result`, without
    the per-step dense sweep.  Host-side only (it pulls the best sieve's
    tiny metadata to plan the replay) — the jit-safe engine path keeps the
    dense reference.

    ``impl``: 'auto' (Pallas on TPU, blocked jnp elsewhere) | 'pallas' |
    'jax' | 'dense' (delegate to the reference path).
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jax"
    if impl == "dense":
        return streaming_result(state, feats, budget, d_max=d_max)
    if impl not in ("pallas", "jax"):
        raise ValueError(f"unknown finalize impl {impl!r}")
    feats = jnp.asarray(feats, jnp.float32)
    n, _ = feats.shape
    budget = int(min(int(budget), n))
    if budget < 1:
        raise ValueError(f"budget must be ≥ 1, got {budget}")
    k = state.capacity
    r0 = state.pre_idx.shape[0]
    if r0 > budget:
        raise ValueError(f"warm prefix {r0} exceeds finalize budget {budget}")

    # Host-static pick plan from the sieve's O(L + k) metadata.
    pre = np.asarray(state.pre_idx, np.int64)
    if k > 0:
        best = int(np.argmax(np.asarray(state.fval)))
        cand = np.clip(np.asarray(state.sel_idx[best], np.int64), -1, n - 1)
        ccount = int(np.asarray(state.count)[best])
    else:
        cand = np.zeros((0,), np.int64)
        ccount = 0
    u = max(0, min(ccount, budget - r0))
    ordered = np.concatenate([pre, cand[:u]])
    if len(ordered) and (
        (ordered < 0).any() or len(np.unique(ordered)) != len(ordered)
    ):
        # a sieve pick collides with the prefix or repeats — can only happen
        # on a malformed state; the dense scan's per-step guards handle it
        return streaming_result(state, feats, budget, d_max=d_max)

    sq = jnp.sum(feats * feats, axis=-1)
    if d_max is None:
        d_maxf = 2.0 * jnp.sqrt(jnp.max(sq)) + 1e-6
    else:
        d_maxf = jnp.asarray(d_max, jnp.float32)

    m = len(ordered)
    if m > 0:
        eidx = jnp.asarray(ordered, jnp.int32)
        ef = feats[eidx]
        if impl == "pallas":
            from repro.kernels import ops as kops  # lazy: keep import light

            gains_o, cur, bv, bi = kops.fl_replay(
                feats, ef, jnp.ones((m,), bool), jnp.zeros((n,), jnp.float32),
                d_maxf, block_m=block_m,
            )
        else:
            pad = (-m) % block_m
            ef_p = jnp.pad(ef, ((0, pad), (0, 0)))
            esq_p = jnp.pad(jnp.sum(ef * ef, axis=-1), (0, pad))
            val_p = jnp.pad(jnp.ones((m,), bool), (0, pad))
            gains_o, cur, bv, bi = _replay_blocked_jax(
                feats, sq, d_maxf, ef_p, esq_p, val_p,
                jnp.zeros((n,), jnp.float32), block_m,
            )
        gains_o = gains_o[:m]
        chosen = jnp.zeros((n,), bool).at[eidx].set(True)
    else:
        gains_o = jnp.zeros((0,), jnp.float32)
        cur = jnp.zeros((n,), jnp.float32)
        bv = jnp.full((n,), -1e30, jnp.float32)
        bi = jnp.zeros((n,), jnp.int32)
        chosen = jnp.zeros((n,), bool)

    back_idx, back_gains = [], []
    for t in range(budget - m):
        e, g, cur, chosen, bv, bi = _backfill_step(
            feats, sq, d_maxf, cur, chosen, bv, bi, jnp.int32(m + t)
        )
        back_idx.append(e)
        back_gains.append(g)

    indices = jnp.concatenate(
        [jnp.asarray(ordered, jnp.int32), jnp.stack(back_idx)]
        if back_idx
        else [jnp.asarray(ordered, jnp.int32)]
    )
    gains = jnp.concatenate(
        [gains_o, jnp.stack(back_gains)] if back_gains else [gains_o]
    ).astype(jnp.float32)
    weights = jnp.zeros((budget,), jnp.float32).at[bi].add(1.0)
    coverage = jnp.sum(d_maxf - bv)
    return FLResult(indices, gains, weights, coverage)


# ---------------------------------------------------------------------------
# Registry plugin: one-shot select behind the common protocol
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamingConfig(EngineConfig):
    """Sieve-streaming engine knobs.

    Attributes:
      eps: geometric grid density — thresholds are ``(1+eps)^j``.  Smaller
        eps → more sieves → tighter ``(1/2 − O(eps))`` guarantee, linearly
        more state and per-element work.
      levels: sieve-slot count override (0 = auto: span ``[m, 2·budget·m]``,
        capped at 64 — see :func:`num_sieves`).
      finalize_impl: blocked-finalize backend for ``StreamingSelector``
        ('auto' = Pallas on TPU / blocked jnp elsewhere; 'pallas' | 'jax' |
        'dense').  The one-shot jit-safe ``StreamingEngine.select`` always
        uses the dense reference path — it must stay traceable.
      finalize_block_m: candidate-block width of the blocked finalize.
    """

    name: ClassVar[str] = "streaming"
    eps: float = 0.15
    levels: int = 0
    finalize_impl: str = "auto"
    finalize_block_m: int = 128


@register_engine
class StreamingEngine(SelectionEngine):
    name = "streaming"
    config_cls = StreamingConfig
    capabilities = Capabilities(
        exact=False,  # (1/2 − eps) sieve guarantee, not exact greedy
        matrix_free=True,
        jit_safe=True,
        supports_cover=False,
        supports_metrics=("l2", "cosine"),  # cosine via normalized l2
        # state is L·k·d plus the pool row it sweeps: L≈48, k≈n/20 heuristic
        memory=lambda n, d: 4 * (n * d + 48 * d * max(n // 20, 64)),
    )

    def select(
        self, feats, budget, *, metric="l2", init_selected=None, rng=None
    ) -> FLResult:
        feats = normalize_for_metric(jnp.asarray(feats), metric)
        n = feats.shape[0]
        budget = int(min(int(budget), n))
        if init_selected is not None:
            init_idx = jnp.asarray(init_selected, jnp.int32).ravel()
            if init_idx.shape[0] > budget:
                raise ValueError(
                    f"init_selected has {init_idx.shape[0]} elements > "
                    f"budget {budget}"
                )
            state = init_streaming_state(
                budget,
                feats.shape[1],
                eps=self.config.eps,
                levels=self.config.levels,
                init_selected=init_idx,
                init_feats=feats[init_idx],
            )
        else:
            state = init_streaming_state(
                budget, feats.shape[1],
                eps=self.config.eps, levels=self.config.levels,
            )
        if state.capacity > 0:
            # the whole pool as ONE delta: estimates are exact — this is
            # textbook sieve-streaming over the pool in index order
            state = ingest_delta(
                state, feats, jnp.arange(n, dtype=jnp.int32), self.config.eps
            )
        res = streaming_result(state, feats, budget)
        if metric == "cosine":  # report L(S) in cosine-distance units
            res = res._replace(
                coverage=cosine_residual_coverage(feats, res.indices)
            )
        return res


# ---------------------------------------------------------------------------
# Stateful host wrapper: the coreset service's selection core
# ---------------------------------------------------------------------------

_FLAT = "__flat__"

_STATE_DTYPES = {
    "n_seen": np.int32, "d_max": np.float32, "m": np.float32,
    "lvl": np.int32, "count": np.int32, "fval": np.float32,
    "fval_pre": np.float32, "sel_idx": np.int32, "sel_feats": np.float32,
    "pre_idx": np.int32, "pre_feats": np.float32,
}


def _state_to_dict(state: StreamingState) -> dict:
    """JSON-able snapshot: shapes + flat lists (float32↔float round-trips
    exactly, so restores are bit-identical)."""
    out = {}
    for name in StreamingState._fields:
        arr = np.asarray(getattr(state, name))
        out[name] = {"shape": list(arr.shape), "data": arr.ravel().tolist()}
    return out


def _state_from_dict(d: dict) -> StreamingState:
    kw = {}
    for name in StreamingState._fields:
        spec = d[name]
        arr = np.asarray(spec["data"], _STATE_DTYPES[name]).reshape(spec["shape"])
        kw[name] = jnp.asarray(arr)
    return StreamingState(**kw)


class StreamingSelector:
    """Stateful sieve-streaming selection over a pool arriving in deltas.

    The contract mirrors ``CraigSelector`` where it can: γ sums to the pool
    size, per-class mode stratifies budgets ∝ observed class frequency
    (paper §5, apportioned with the same largest-remainder rule), and the
    warm-start prefix (flat mode) is preserved at the front of the result.
    The difference is lifecycle: ``ingest`` is called once per arriving
    megabatch (O(Δn·k) work, no re-sweep), and ``result`` finalizes against
    the accumulated pool on demand.

    Pool indexing: deltas are assigned positions in arrival order, so the
    ``feats`` passed to :meth:`result` must be the ingested deltas
    concatenated in ingest order (the coreset service maintains exactly
    that buffer).  With ``evict=True`` the positions are *live-pool*
    coordinates instead: :meth:`compact` drops every row no sieve
    references, the caller applies the same row selection to its buffer,
    and :attr:`live_ids` maps live positions back to global arrival order
    — memory becomes O(L·k·d) instead of O(n·d) for unbounded streams, and
    γ then sums to the live-pool size rather than ``n_seen``.

    ``state_dict`` / ``load_state_dict`` round-trip the full mid-stream
    state (JSON-able — rides ``CheckpointManager`` extras) bit-identically,
    including the compaction remap.
    """

    def __init__(
        self,
        budget: int,
        dim: int,
        *,
        config: StreamingConfig | None = None,
        metric: str = "l2",
        per_class: bool = False,
        evict: bool = False,
        init_selected=None,
        init_feats=None,
    ):
        config = config or StreamingConfig()
        caps = StreamingEngine.capabilities
        if metric not in caps.supports_metrics:
            raise ValueError(
                f"engine 'streaming' supports metrics {caps.supports_metrics}, "
                f"got {metric!r}"
            )
        if per_class and init_selected is not None:
            raise ValueError(
                "warm-start prefix is flat-mode only (per-class budgets are "
                "apportioned at result time, after arrival counts are known)"
            )
        self.budget = int(budget)
        self.dim = int(dim)
        self.config = config
        self.metric = metric
        self.per_class = bool(per_class)
        self.evict = bool(evict)
        self._n_seen = 0
        self._n_rows = 0  # live pool rows (== n_seen unless evict compacts)
        self._live = np.zeros((0,), np.int64)  # live pos -> global arrival id
        self._class_seen: dict = {}  # label -> total arrivals (pre-eviction)
        self._states: dict = {}
        self._rows: dict = {}  # label -> pool positions, class-arrival order
        if not per_class:
            init_feats = (
                None
                if init_feats is None
                else normalize_for_metric(
                    jnp.asarray(init_feats, jnp.float32), metric
                )
            )
            self._states[_FLAT] = init_streaming_state(
                self.budget, self.dim,
                eps=config.eps, levels=config.levels,
                init_selected=init_selected, init_feats=init_feats,
            )

    @property
    def n_seen(self) -> int:
        """Total points ingested so far (monotone; eviction never lowers it)."""
        return self._n_seen

    @property
    def n_rows(self) -> int:
        """Live pool rows the next :meth:`result` call expects."""
        return self._n_rows

    @property
    def live_ids(self) -> np.ndarray:
        """(n_rows,) int64 — global arrival id of each live pool position
        (the identity map unless ``evict=True`` has compacted)."""
        if not self.evict:
            return np.arange(self._n_rows, dtype=np.int64)
        return self._live.copy()

    def ingest(self, feats, labels=None) -> int:
        """Ingest one megabatch delta; returns the running pool size.

        O(Δn·(Δn + L)·d) — independent of the pool ingested so far.
        """
        feats = normalize_for_metric(jnp.asarray(feats, jnp.float32), self.metric)
        dn = feats.shape[0]
        if feats.ndim != 2 or feats.shape[1] != self.dim:
            raise ValueError(f"expected (Δn, {self.dim}) features, got {feats.shape}")
        if self.per_class:
            if labels is None:
                raise ValueError("per_class=True ingest needs labels")
            labels = np.asarray(labels).ravel()
            if labels.shape[0] != dn:
                raise ValueError(f"labels length {labels.shape[0]} != Δn {dn}")
            for c in np.unique(labels):
                key = int(c)
                mask = labels == c
                rows = self._rows.setdefault(key, [])
                if key not in self._states:
                    self._states[key] = init_streaming_state(
                        self.budget, self.dim,
                        eps=self.config.eps, levels=self.config.levels,
                    )
                local = len(rows) + np.arange(int(mask.sum()), dtype=np.int32)
                self._states[key] = ingest_delta(
                    self._states[key], feats[np.nonzero(mask)[0]],
                    jnp.asarray(local), self.config.eps,
                )
                rows.extend((self._n_rows + np.nonzero(mask)[0]).tolist())
                self._class_seen[key] = (
                    self._class_seen.get(key, 0) + int(mask.sum())
                )
        else:
            idx = self._n_rows + jnp.arange(dn, dtype=jnp.int32)
            self._states[_FLAT] = ingest_delta(
                self._states[_FLAT], feats, idx, self.config.eps
            )
        if self.evict:
            self._live = np.concatenate(
                [self._live, self._n_seen + np.arange(dn, dtype=np.int64)]
            )
        self._n_seen += int(dn)
        self._n_rows += int(dn)
        return self._n_seen

    def compact(self) -> np.ndarray:
        """Evict pool rows no sieve references (``evict=True`` only).

        Keeps exactly the rows referenced by any sieve's ``sel_idx`` or the
        warm prefix, remaps every stored index into the compacted
        coordinates, and returns the kept positions (into the
        pre-compaction pool order, ascending) — the caller MUST apply the
        same row selection to its pool buffer before the next
        :meth:`result`.  A no-op identity when ``evict=False``.
        """
        if not self.evict or self._n_rows == 0:
            return np.arange(self._n_rows, dtype=np.int64)
        if not self.per_class:
            st = self._states[_FLAT]
            sel = np.asarray(st.sel_idx, np.int64)
            pre = np.asarray(st.pre_idx, np.int64)
            keep = np.unique(np.concatenate([sel[sel >= 0].ravel(), pre]))
            new_sel = np.where(
                sel >= 0, np.searchsorted(keep, np.clip(sel, 0, None)), -1
            ).astype(np.int32)
            self._states[_FLAT] = st._replace(
                sel_idx=jnp.asarray(new_sel),
                pre_idx=jnp.asarray(np.searchsorted(keep, pre), jnp.int32),
            )
        else:
            keep_mask = np.zeros(self._n_rows, bool)
            kept_local: dict = {}
            for c, st in self._states.items():
                sel = np.asarray(st.sel_idx, np.int64)
                kl = np.unique(sel[sel >= 0].ravel())
                kept_local[c] = kl
                rows_c = np.asarray(self._rows[c], np.int64)
                keep_mask[rows_c[kl]] = True
            keep = np.nonzero(keep_mask)[0].astype(np.int64)
            pool_remap = np.full(self._n_rows, -1, np.int64)
            pool_remap[keep] = np.arange(len(keep))
            for c, st in self._states.items():
                kl = kept_local[c]
                sel = np.asarray(st.sel_idx, np.int64)
                new_sel = np.where(
                    sel >= 0, np.searchsorted(kl, np.clip(sel, 0, None)), -1
                ).astype(np.int32)
                self._states[c] = st._replace(sel_idx=jnp.asarray(new_sel))
                rows_c = np.asarray(self._rows[c], np.int64)
                self._rows[c] = pool_remap[rows_c[kl]].tolist()
        self._live = self._live[keep]
        self._n_rows = int(len(keep))
        return keep

    def result(self, feats) -> FLResult:
        """Finalize the current selection against the accumulated pool.

        ``feats`` must be the ingested deltas concatenated in arrival
        order (rows align with the positions ``ingest`` assigned); after a
        :meth:`compact`, the same row selection must have been applied.
        Indices in the result are pool positions — map through
        :attr:`live_ids` for global arrival ids when ``evict=True``.
        """
        feats = normalize_for_metric(jnp.asarray(feats, jnp.float32), self.metric)
        n = feats.shape[0]
        if n != self._n_rows:
            raise ValueError(
                f"pool has {n} rows but {self._n_rows} are live — result() "
                "needs the ingested deltas concatenated in order, compacted "
                "in lockstep with compact()"
            )
        if n == 0:
            raise ValueError("nothing ingested yet")
        impl = self.config.finalize_impl
        bm = self.config.finalize_block_m
        if not self.per_class:
            res = streaming_result_blocked(
                self._states[_FLAT], feats, min(self.budget, n),
                impl=impl, block_m=bm,
            )
            if self.metric == "cosine":
                res = res._replace(
                    coverage=cosine_residual_coverage(feats, res.indices)
                )
            return res

        # paper §5: stratified budgets ∝ observed class arrival counts
        from repro.core.craig import _apportion_budgets  # lazy: avoid cycle

        classes = sorted(self._states)
        counts = np.array(
            [self._class_seen.get(c, len(self._rows[c])) for c in classes],
            np.int64,
        )
        budgets = _apportion_budgets(counts, min(self.budget, n))
        # one pool-wide offset so per-class gains/coverages share units
        # (each subpool's own d_max would make classes incommensurable)
        sq = jnp.sum(feats * feats, axis=-1)
        d_max_pool = 2.0 * jnp.sqrt(jnp.max(sq)) + 1e-6
        all_idx, all_gains, all_w = [], [], []
        coverage = 0.0
        for c, b in zip(classes, budgets):
            b = int(min(b, len(self._rows[c])))
            if b == 0:
                continue
            rows = np.asarray(self._rows[c], np.int64)
            sub = feats[rows]
            r = streaming_result_blocked(
                self._states[c], sub, b,
                d_max=d_max_pool, impl=impl, block_m=bm,
            )
            all_idx.append(rows[np.asarray(r.indices, np.int64)])
            all_gains.append(np.asarray(r.gains, np.float32))
            all_w.append(np.asarray(r.weights, np.float32))
            coverage += float(
                cosine_residual_coverage(sub, r.indices)
                if self.metric == "cosine"
                else r.coverage
            )
        return FLResult(
            jnp.asarray(np.concatenate(all_idx), jnp.int32),
            jnp.asarray(np.concatenate(all_gains)),
            jnp.asarray(np.concatenate(all_w)),
            jnp.asarray(coverage, jnp.float32),
        )

    # -- serialization -------------------------------------------------------

    def state_dict(self) -> dict:
        """JSON-able full snapshot (config + per-class sieve states + the
        eviction remap)."""
        return {
            "budget": self.budget,
            "dim": self.dim,
            "metric": self.metric,
            "per_class": self.per_class,
            "evict": self.evict,
            "n_seen": self._n_seen,
            "n_rows": self._n_rows,
            "live": self._live.tolist(),
            "class_seen": {
                str(key): int(v) for key, v in self._class_seen.items()
            },
            "config": self.config.to_dict(),
            "states": {
                str(key): _state_to_dict(st) for key, st in self._states.items()
            },
            "rows": {str(key): list(rows) for key, rows in self._rows.items()},
        }

    def load_state_dict(self, d: dict) -> None:
        """Inverse of :meth:`state_dict` — resumes bit-identically."""
        cfg = EngineConfig.from_dict(d["config"])
        if not isinstance(cfg, StreamingConfig):
            raise ValueError(f"not a streaming state_dict: {d['config']!r}")
        self.budget = int(d["budget"])
        self.dim = int(d["dim"])
        self.metric = d["metric"]
        self.per_class = bool(d["per_class"])
        self.evict = bool(d.get("evict", False))
        self.config = cfg
        self._n_seen = int(d["n_seen"])
        self._n_rows = int(d.get("n_rows", d["n_seen"]))
        self._live = np.asarray(d.get("live", []), np.int64)
        self._states = {
            (key if key == _FLAT else int(key)): _state_from_dict(sd)
            for key, sd in d["states"].items()
        }
        self._rows = {int(key): list(rows) for key, rows in d["rows"].items()}
        self._class_seen = {
            int(key): int(v) for key, v in d.get("class_seen", {}).items()
        } or {c: len(r) for c, r in self._rows.items()}
