"""Sieve-streaming facility-location engine (DESIGN.md §10).

The batch engines re-sweep the whole pool per refresh; under continuous
ingestion (ROADMAP north-star) that cost grows with the pool while the
information per refresh does not.  Sieve-streaming (Badanidiyuru et al.,
KDD'14) maintains a *geometric grid of threshold sieves* instead: for each
guess ``v = (1+eps)^j`` of OPT, a sieve greedily admits an arriving element
when its marginal gain clears ``(v/2 − f(S_v)) / (k − |S_v|)``.  One sieve's
guess lands within (1+eps) of OPT and its set achieves ``(1/2 − O(eps))·OPT``
— a one-pass, O(Δn·k)-per-delta guarantee with no re-sweep of prior data.

Adaptation to CRAIG's facility location: past points cannot be revisited, so
the objective is tracked as the running per-point mean coverage — each sieve
accumulates ``Σ_i max_{j∈S_v} s_ij`` over the deltas it has seen (``fval``),
marginal gains are estimated batch-locally on the arriving delta (CREST,
arXiv:2306.01244: selection over pool subsets arriving over time preserves
the data-efficiency guarantees when deltas are representative samples), and
the max-singleton estimate ``m`` that anchors the grid is the running max
*mean* similarity — scale-stable as the stream grows.  When ``m`` rises, the
live window of OPT guesses ``[m, 2km]`` shifts: each sieve slot holds an
absolute level and re-anchors by jumping a multiple of L levels (retiring its
selections), so the L slots always hold L consecutive levels of the current
window — a circular buffer over the geometric grid, O(L) per element.

With a single delta equal to the full pool, the estimates are exact and
``select`` *is* textbook sieve-streaming, hence the property-test gate
``F(S) ≥ (1/2 − eps)·F(greedy)`` (tests/test_selection_properties.py).

Three surfaces:

  * ``init_streaming_state`` / ``ingest_delta`` / ``streaming_result`` — the
    functional core.  ``StreamingState`` is an arrays-only NamedTuple (a
    pytree): ``ingest_delta`` is jit-compiled once per delta shape, and the
    state serializes losslessly for checkpoints (``StreamingSelector``).
  * ``StreamingEngine`` (``engine='streaming'``) — the registry plugin: a
    one-shot ``select`` (init → single-delta ingest → finalize) behind the
    common protocol; not exact, matrix-free, jit-safe.
  * ``StreamingSelector`` — the stateful host wrapper the coreset service
    builds on: sequential ``ingest(delta)`` calls, per-class stratified
    budgets (paper §5) apportioned at ``result`` time from observed class
    arrival counts, and a JSON-able ``state_dict`` that resumes
    bit-identically mid-stream.

Finalization (``streaming_result``) maps the best sieve back to a full
``FLResult``: it replays the warm prefix, takes the sieve's picks in
admission order, backfills any remaining budget with worst-covered points
(farthest-point traversal), and computes γ weights / residual coverage
against the pool — the only step that touches all n rows, and the only one
whose cost scales with the pool rather than the delta.
"""
from __future__ import annotations

import dataclasses
import math
from typing import ClassVar, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines.base import (
    Capabilities,
    EngineConfig,
    FLResult,
    SelectionEngine,
    _replay_prefix,
    cosine_residual_coverage,
    normalize_for_metric,
)
from repro.core.engines.registry import register_engine

__all__ = [
    "LVL_UNSET",
    "StreamingConfig",
    "StreamingEngine",
    "StreamingSelector",
    "StreamingState",
    "init_streaming_state",
    "ingest_delta",
    "num_sieves",
    "streaming_result",
]

# Sentinel level for a sieve slot that has never been anchored (no element
# seen yet).  Any real absolute level ``floor(log m / log(1+eps))`` is far
# above it, so the first element cold-starts the whole grid.
LVL_UNSET = -(2**30)


class StreamingState(NamedTuple):
    """Serializable sieve-streaming state — arrays only, hence a pytree.

    Static meta (budget, eps) is *not* carried here: it is baked into the
    array shapes (L, k) at :func:`init_streaming_state` time and travels
    alongside in ``StreamingSelector.state_dict`` / engine configs.

    Attributes:
      n_seen: () int32 — points ingested so far.
      d_max: () float32 — similarity offset ``2·max‖x‖ + 1e-6``, frozen at
        the first ingest so sieve values stay comparable across deltas
        (later similarities clip at 0).
      m: () float32 — running max singleton *mean* similarity (grid anchor).
      lvl: (L,) int32 — absolute threshold level per sieve slot
        (``v = (1+eps)^lvl``); ``LVL_UNSET`` before the first element.
      count: (L,) int32 — elements admitted per sieve.
      fval: (L,) float32 — Σ coverage of past delta points at their ingest
        time, per sieve (the running objective estimate, in sum units).
      fval_pre: () float32 — same accumulator for the warm prefix alone;
        the O(1) reset value when a sieve retires.
      sel_idx: (L, k) int32 — admitted indices per sieve (-1 = empty slot).
      sel_feats: (L, k, d) float32 — their features (past points are gone;
        the sieves keep the only copy).
      pre_idx: (r0,) int32 — warm-start prefix indices (excluded from sieve
        admission; replayed at finalize).
      pre_feats: (r0, d) float32 — prefix features.
    """

    n_seen: jax.Array
    d_max: jax.Array
    m: jax.Array
    lvl: jax.Array
    count: jax.Array
    fval: jax.Array
    fval_pre: jax.Array
    sel_idx: jax.Array
    sel_feats: jax.Array
    pre_idx: jax.Array
    pre_feats: jax.Array

    @property
    def capacity(self) -> int:
        """k — sieve capacity (budget minus warm-prefix length)."""
        return self.sel_idx.shape[1]

    @property
    def num_levels(self) -> int:
        """L — number of sieve slots."""
        return self.lvl.shape[0]


def num_sieves(budget: int, eps: float, levels: int = 0) -> int:
    """Sieve-count default: span the OPT window ``[m, 2·budget·m]``.

    The geometric grid needs ``log(2k)/log(1+eps)`` levels to cover the
    window; capped at 64 (OPT sits far below ``k·m`` on real pools) and
    floored at 4.  ``levels > 0`` overrides.
    """
    if levels > 0:
        return int(levels)
    k = max(int(budget), 2)
    want = math.ceil(math.log(2.0 * k) / math.log1p(eps)) + 1
    return max(4, min(64, want))


def init_streaming_state(
    budget: int,
    dim: int,
    *,
    eps: float = 0.15,
    levels: int = 0,
    init_selected=None,
    init_feats=None,
) -> StreamingState:
    """Empty sieve grid for ``budget`` selections over ``dim``-d features.

    ``init_selected``/``init_feats`` seed a warm-start prefix: those
    elements are treated as already selected (every sieve's coverage starts
    from theirs; they are excluded from admission) and are replayed first at
    :func:`streaming_result`, preserving the warm-start-prefix contract of
    the batch engines.
    """
    budget = int(budget)
    if budget < 1:
        raise ValueError(f"budget must be ≥ 1, got {budget}")
    if init_selected is None:
        pre_idx = jnp.zeros((0,), jnp.int32)
        pre_feats = jnp.zeros((0, dim), jnp.float32)
    else:
        pre_idx = jnp.asarray(init_selected, jnp.int32).ravel()
        if init_feats is None:
            raise ValueError("init_selected needs init_feats (past rows are gone)")
        pre_feats = jnp.asarray(init_feats, jnp.float32).reshape(-1, dim)
        if pre_feats.shape[0] != pre_idx.shape[0]:
            raise ValueError(
                f"init_feats rows {pre_feats.shape[0]} != "
                f"init_selected length {pre_idx.shape[0]}"
            )
        if pre_idx.shape[0] > budget:
            raise ValueError(
                f"init_selected has {pre_idx.shape[0]} elements > budget {budget}"
            )
    k = budget - pre_idx.shape[0]
    L = num_sieves(budget, eps, levels)
    return StreamingState(
        n_seen=jnp.zeros((), jnp.int32),
        d_max=jnp.zeros((), jnp.float32),
        m=jnp.zeros((), jnp.float32),
        lvl=jnp.full((L,), LVL_UNSET, jnp.int32),
        count=jnp.zeros((L,), jnp.int32),
        fval=jnp.zeros((L,), jnp.float32),
        fval_pre=jnp.zeros((), jnp.float32),
        sel_idx=jnp.full((L, k), -1, jnp.int32),
        sel_feats=jnp.zeros((L, k, dim), jnp.float32),
        pre_idx=pre_idx,
        pre_feats=pre_feats,
    )


def _sim_to(feats: jax.Array, sq: jax.Array, x: jax.Array, d_max) -> jax.Array:
    """(Δn,) clipped similarity of every delta point to one element x."""
    d2 = sq + jnp.sum(x * x) - 2.0 * (feats @ x)
    return jnp.maximum(d_max - jnp.sqrt(jnp.maximum(d2, 0.0)), 0.0)


def _ingest_delta(state: StreamingState, feats, idx, eps) -> StreamingState:
    """One-pass sieve update over a megabatch delta (jit-compiled).

    Work is O(Δn·(Δn + L)·d′) with d′ the feature dim — independent of
    ``n_seen``: prior data is never revisited.
    """
    feats = jnp.asarray(feats, jnp.float32)
    dn, dim = feats.shape
    L, k = state.num_levels, state.capacity
    r0 = state.pre_idx.shape[0]
    idx = jnp.asarray(idx, jnp.int32)
    sq = jnp.sum(feats * feats, axis=-1)

    # freeze the similarity offset at first ingest (later sims clip at 0)
    d_max = jnp.where(
        state.n_seen == 0, 2.0 * jnp.sqrt(jnp.max(sq)) + 1e-6, state.d_max
    )

    # prefix coverage of the delta (the floor every sieve shares)
    if r0 > 0:
        psq = jnp.sum(state.pre_feats * state.pre_feats, axis=-1)
        d2p = sq[:, None] + psq[None, :] - 2.0 * (feats @ state.pre_feats.T)
        simp = jnp.maximum(d_max - jnp.sqrt(jnp.maximum(d2p, 0.0)), 0.0)
        cov_pre = jnp.max(simp, axis=1)
        is_pre = jnp.any(idx[:, None] == state.pre_idx[None, :], axis=1)
    else:
        cov_pre = jnp.zeros((dn,), jnp.float32)
        is_pre = jnp.zeros((dn,), bool)
    pre_sum = jnp.sum(cov_pre)

    if k == 0:  # budget == prefix: nothing to sieve, just account coverage
        return state._replace(
            n_seen=state.n_seen + dn,
            d_max=d_max,
            fval=state.fval + pre_sum,
            fval_pre=state.fval_pre + pre_sum,
        )

    # coverage of the delta by each sieve's existing selections
    ssq = jnp.sum(state.sel_feats * state.sel_feats, axis=-1)  # (L, k)
    dots = jnp.einsum("nd,lkd->lnk", feats, state.sel_feats)
    d2s = sq[None, :, None] + ssq[:, None, :] - 2.0 * dots
    sims = jnp.maximum(d_max - jnp.sqrt(jnp.maximum(d2s, 0.0)), 0.0)
    valid = jnp.arange(k)[None, None, :] < state.count[:, None, None]
    cov0 = jnp.max(jnp.where(valid, sims, 0.0), axis=2)  # (L, Δn)
    cov0 = jnp.maximum(cov0, cov_pre[None, :])

    n_seen_f = state.n_seen.astype(jnp.float32)
    log1p_eps = math.log1p(float(eps))
    slot_arange = jnp.arange(L, dtype=jnp.int32)

    # The scan carries only the O(L·Δn) cover rows and O(L) scalars; the
    # big (L, k[, d]) selection arrays are never read inside the body, so
    # they are reconstructed post-scan from the accept/retire history —
    # carrying them would copy L·k·d floats per element.
    def step(carry, xs):
        m, lvl, count, fval, covsum, cov = carry
        x, ispre = xs
        col = _sim_to(feats, sq, x, d_max)  # (Δn,)

        # grid anchor: running max singleton mean; re-anchor the window
        m = jnp.maximum(m, jnp.mean(col))
        j_lo = jnp.floor(jnp.log(m) / log1p_eps).astype(jnp.int32)
        unset = lvl == LVL_UNSET
        w = jnp.maximum(-((lvl - j_lo) // L), 0)
        lvl = jnp.where(unset, j_lo + slot_arange, lvl + w * L)
        retire = unset | (w > 0)
        count = jnp.where(retire, 0, count)
        cov = jnp.where(retire[:, None], cov_pre[None, :], cov)
        covsum = jnp.where(retire, pre_sum, covsum)
        fval = jnp.where(retire, state.fval_pre, fval)

        # threshold admission, vectorized over the L sieves
        v = jnp.exp(lvl.astype(jnp.float32) * log1p_eps)
        g = jnp.sum(jnp.maximum(col[None, :] - cov, 0.0), axis=1)  # (L,)
        g_mean = g / dn
        f_cur = (fval + covsum) / (n_seen_f + dn)
        thresh = (0.5 * v - f_cur) / jnp.maximum(k - count, 1).astype(jnp.float32)
        accept = (count < k) & (g_mean >= thresh) & (g_mean > 0.0) & (~ispre)

        count = count + accept.astype(jnp.int32)
        cov_new = jnp.maximum(cov, col[None, :])
        cov = jnp.where(accept[:, None], cov_new, cov)
        covsum = jnp.where(accept, jnp.sum(cov_new, axis=1), covsum)
        return (m, lvl, count, fval, covsum, cov), (accept, retire)

    carry0 = (
        state.m,
        state.lvl,
        state.count,
        state.fval,
        jnp.sum(cov0, axis=1),
        cov0,
    )
    (m, lvl, count, fval, covsum, _), (acc_hist, ret_hist) = jax.lax.scan(
        step, carry0, (feats, is_pre)
    )

    # Reconstruct (sel_idx, sel_feats) from the (Δn, L) histories: a sieve
    # keeps only admissions after its last retirement; those fill slots in
    # arrival order, starting at the pre-delta count for never-retired
    # sieves and at 0 otherwise.  One O(Δn·L·d) scatter, not Δn of them.
    t_col = jnp.arange(dn, dtype=jnp.int32)[:, None]
    last_ret = jnp.max(jnp.where(ret_hist, t_col, -1), axis=0)  # (L,)
    keep = acc_hist & (t_col >= last_ret[None, :])  # (Δn, L)
    retired = last_ret >= 0
    base = jnp.where(retired, 0, state.count)  # slot offset at (re)start
    slot = base[None, :] + jnp.cumsum(keep.astype(jnp.int32), axis=0) - 1
    slot_safe = jnp.where(keep, jnp.clip(slot, 0, k - 1), k)  # k = dump slot

    sel_idx = jnp.where(retired[:, None], -1, state.sel_idx)
    sel_feats = jnp.where(retired[:, None, None], 0.0, state.sel_feats)
    l_grid = jnp.broadcast_to(jnp.arange(L)[None, :], (dn, L))
    sel_idx = (
        jnp.concatenate([sel_idx, jnp.full((L, 1), -1, jnp.int32)], axis=1)
        .at[l_grid.ravel(), slot_safe.ravel()]
        .set(jnp.broadcast_to(idx[:, None], (dn, L)).ravel())[:, :k]
    )
    sel_feats = (
        jnp.concatenate([sel_feats, jnp.zeros((L, 1, dim), jnp.float32)], axis=1)
        .at[l_grid.ravel(), slot_safe.ravel()]
        .set(jnp.broadcast_to(feats[:, None, :], (dn, L, dim)).reshape(-1, dim))[
            :, :k
        ]
    )
    return state._replace(
        n_seen=state.n_seen + dn,
        d_max=d_max,
        m=m,
        lvl=lvl,
        count=count,
        fval=fval + covsum,
        fval_pre=state.fval_pre + pre_sum,
        sel_idx=sel_idx,
        sel_feats=sel_feats,
    )


ingest_delta = jax.jit(_ingest_delta, static_argnums=(3,))


def streaming_result(state: StreamingState, feats: jax.Array, budget: int) -> FLResult:
    """Finalize: best sieve → full FLResult against the pool.

    ``feats`` is the (n,) pool the stored indices refer to (the service
    keeps it; the one-shot engine has it by construction).  Order: warm
    prefix (replayed), then the best sieve's picks in admission order, then
    worst-covered backfill (farthest-point) for any unfilled budget.  γ and
    coverage use this call's own offset, so the frozen ingest-time ``d_max``
    never leaks into reported units.
    """
    feats = jnp.asarray(feats, jnp.float32)
    n, _ = feats.shape
    budget = int(min(int(budget), n))
    if budget < 1:
        raise ValueError(f"budget must be ≥ 1, got {budget}")
    k = state.capacity
    r0 = state.pre_idx.shape[0]
    if r0 > budget:
        raise ValueError(f"warm prefix {r0} exceeds finalize budget {budget}")

    sq = jnp.sum(feats * feats, axis=-1)
    d_maxf = 2.0 * jnp.sqrt(jnp.max(sq)) + 1e-6

    def sim_cols(e_arr: jax.Array) -> jax.Array:
        """(n, m) similarity of every pool point to elements ``e_arr``."""
        cf = feats[e_arr]
        d2 = sq[:, None] + jnp.sum(cf * cf, axis=-1)[None, :] - 2.0 * (feats @ cf.T)
        return d_maxf - jnp.sqrt(jnp.maximum(d2, 0.0))

    init_idx, init_gains, cur_max0, chosen0 = _replay_prefix(
        state.pre_idx if r0 > 0 else None,
        budget,
        n,
        lambda e: sim_cols(e[None])[:, 0],
    )

    best = jnp.argmax(state.fval)
    cand = jnp.clip(state.sel_idx[best], -1, n - 1)  # (k,)
    ccount = state.count[best]

    def step(carry, t):
        cur_max, chosen = carry
        resid = jnp.where(chosen, -jnp.inf, d_maxf - cur_max)
        back_e = jnp.argmax(resid).astype(jnp.int32)
        if k > 0:
            se = cand[jnp.clip(t, 0, k - 1)]
            se_safe = jnp.clip(se, 0, n - 1)
            use = (t < ccount) & (se >= 0) & (~chosen[se_safe])
            e = jnp.where(use, se_safe, back_e)
        else:
            e = back_e
        col = sim_cols(e[None])[:, 0]
        gain = jnp.sum(jnp.maximum(col - cur_max, 0.0))
        return (jnp.maximum(cur_max, col), chosen.at[e].set(True)), (
            e.astype(jnp.int32),
            gain,
        )

    (cur_max, _), (new_idx, new_gains) = jax.lax.scan(
        step, (cur_max0, chosen0), jnp.arange(budget - r0)
    )
    indices = jnp.concatenate([init_idx, new_idx])
    gains = jnp.concatenate([init_gains, new_gains]).astype(jnp.float32)

    sel_sim = sim_cols(indices)  # (n, budget)
    assign = jnp.argmax(sel_sim, axis=1)
    weights = jnp.zeros((budget,), jnp.float32).at[assign].add(1.0)
    coverage = jnp.sum(d_maxf - jnp.max(sel_sim, axis=1))
    return FLResult(indices, gains, weights, coverage)


# ---------------------------------------------------------------------------
# Registry plugin: one-shot select behind the common protocol
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamingConfig(EngineConfig):
    """Sieve-streaming engine knobs.

    Attributes:
      eps: geometric grid density — thresholds are ``(1+eps)^j``.  Smaller
        eps → more sieves → tighter ``(1/2 − O(eps))`` guarantee, linearly
        more state and per-element work.
      levels: sieve-slot count override (0 = auto: span ``[m, 2·budget·m]``,
        capped at 64 — see :func:`num_sieves`).
    """

    name: ClassVar[str] = "streaming"
    eps: float = 0.15
    levels: int = 0


@register_engine
class StreamingEngine(SelectionEngine):
    name = "streaming"
    config_cls = StreamingConfig
    capabilities = Capabilities(
        exact=False,  # (1/2 − eps) sieve guarantee, not exact greedy
        matrix_free=True,
        jit_safe=True,
        supports_cover=False,
        supports_metrics=("l2", "cosine"),  # cosine via normalized l2
        # state is L·k·d plus the pool row it sweeps: L≈48, k≈n/20 heuristic
        memory=lambda n, d: 4 * (n * d + 48 * d * max(n // 20, 64)),
    )

    def select(
        self, feats, budget, *, metric="l2", init_selected=None, rng=None
    ) -> FLResult:
        feats = normalize_for_metric(jnp.asarray(feats), metric)
        n = feats.shape[0]
        budget = int(min(int(budget), n))
        if init_selected is not None:
            init_idx = jnp.asarray(init_selected, jnp.int32).ravel()[:budget]
            state = init_streaming_state(
                budget,
                feats.shape[1],
                eps=self.config.eps,
                levels=self.config.levels,
                init_selected=init_idx,
                init_feats=feats[init_idx],
            )
        else:
            state = init_streaming_state(
                budget, feats.shape[1],
                eps=self.config.eps, levels=self.config.levels,
            )
        if state.capacity > 0:
            # the whole pool as ONE delta: estimates are exact — this is
            # textbook sieve-streaming over the pool in index order
            state = ingest_delta(
                state, feats, jnp.arange(n, dtype=jnp.int32), self.config.eps
            )
        res = streaming_result(state, feats, budget)
        if metric == "cosine":  # report L(S) in cosine-distance units
            res = res._replace(
                coverage=cosine_residual_coverage(feats, res.indices)
            )
        return res


# ---------------------------------------------------------------------------
# Stateful host wrapper: the coreset service's selection core
# ---------------------------------------------------------------------------

_FLAT = "__flat__"

_STATE_DTYPES = {
    "n_seen": np.int32, "d_max": np.float32, "m": np.float32,
    "lvl": np.int32, "count": np.int32, "fval": np.float32,
    "fval_pre": np.float32, "sel_idx": np.int32, "sel_feats": np.float32,
    "pre_idx": np.int32, "pre_feats": np.float32,
}


def _state_to_dict(state: StreamingState) -> dict:
    """JSON-able snapshot: shapes + flat lists (float32↔float round-trips
    exactly, so restores are bit-identical)."""
    out = {}
    for name in StreamingState._fields:
        arr = np.asarray(getattr(state, name))
        out[name] = {"shape": list(arr.shape), "data": arr.ravel().tolist()}
    return out


def _state_from_dict(d: dict) -> StreamingState:
    kw = {}
    for name in StreamingState._fields:
        spec = d[name]
        arr = np.asarray(spec["data"], _STATE_DTYPES[name]).reshape(spec["shape"])
        kw[name] = jnp.asarray(arr)
    return StreamingState(**kw)


class StreamingSelector:
    """Stateful sieve-streaming selection over a pool arriving in deltas.

    The contract mirrors ``CraigSelector`` where it can: γ sums to the pool
    size, per-class mode stratifies budgets ∝ observed class frequency
    (paper §5, apportioned with the same largest-remainder rule), and the
    warm-start prefix (flat mode) is preserved at the front of the result.
    The difference is lifecycle: ``ingest`` is called once per arriving
    megabatch (O(Δn·k) work, no re-sweep), and ``result`` finalizes against
    the accumulated pool on demand.

    Pool indexing: deltas are assigned positions in arrival order, so the
    ``feats`` passed to :meth:`result` must be the ingested deltas
    concatenated in ingest order (the coreset service maintains exactly
    that buffer).

    ``state_dict`` / ``load_state_dict`` round-trip the full mid-stream
    state (JSON-able — rides ``CheckpointManager`` extras) bit-identically.
    """

    def __init__(
        self,
        budget: int,
        dim: int,
        *,
        config: StreamingConfig | None = None,
        metric: str = "l2",
        per_class: bool = False,
        init_selected=None,
        init_feats=None,
    ):
        config = config or StreamingConfig()
        caps = StreamingEngine.capabilities
        if metric not in caps.supports_metrics:
            raise ValueError(
                f"engine 'streaming' supports metrics {caps.supports_metrics}, "
                f"got {metric!r}"
            )
        if per_class and init_selected is not None:
            raise ValueError(
                "warm-start prefix is flat-mode only (per-class budgets are "
                "apportioned at result time, after arrival counts are known)"
            )
        self.budget = int(budget)
        self.dim = int(dim)
        self.config = config
        self.metric = metric
        self.per_class = bool(per_class)
        self._n_seen = 0
        self._states: dict = {}
        self._rows: dict = {}  # label -> np.int64 global positions, arrival order
        if not per_class:
            init_feats = (
                None
                if init_feats is None
                else normalize_for_metric(
                    jnp.asarray(init_feats, jnp.float32), metric
                )
            )
            self._states[_FLAT] = init_streaming_state(
                self.budget, self.dim,
                eps=config.eps, levels=config.levels,
                init_selected=init_selected, init_feats=init_feats,
            )

    @property
    def n_seen(self) -> int:
        """Total points ingested so far."""
        return self._n_seen

    def ingest(self, feats, labels=None) -> int:
        """Ingest one megabatch delta; returns the running pool size.

        O(Δn·(Δn + L)·d) — independent of the pool ingested so far.
        """
        feats = normalize_for_metric(jnp.asarray(feats, jnp.float32), self.metric)
        dn = feats.shape[0]
        if feats.ndim != 2 or feats.shape[1] != self.dim:
            raise ValueError(f"expected (Δn, {self.dim}) features, got {feats.shape}")
        if self.per_class:
            if labels is None:
                raise ValueError("per_class=True ingest needs labels")
            labels = np.asarray(labels).ravel()
            if labels.shape[0] != dn:
                raise ValueError(f"labels length {labels.shape[0]} != Δn {dn}")
            for c in np.unique(labels):
                key = int(c)
                mask = labels == c
                rows = self._rows.setdefault(key, [])
                if key not in self._states:
                    self._states[key] = init_streaming_state(
                        self.budget, self.dim,
                        eps=self.config.eps, levels=self.config.levels,
                    )
                local = len(rows) + np.arange(int(mask.sum()), dtype=np.int32)
                self._states[key] = ingest_delta(
                    self._states[key], feats[np.nonzero(mask)[0]],
                    jnp.asarray(local), self.config.eps,
                )
                rows.extend((self._n_seen + np.nonzero(mask)[0]).tolist())
        else:
            idx = self._n_seen + jnp.arange(dn, dtype=jnp.int32)
            self._states[_FLAT] = ingest_delta(
                self._states[_FLAT], feats, idx, self.config.eps
            )
        self._n_seen += int(dn)
        return self._n_seen

    def result(self, feats) -> FLResult:
        """Finalize the current selection against the accumulated pool.

        ``feats`` must be the ingested deltas concatenated in arrival
        order (rows align with the positions ``ingest`` assigned).
        """
        feats = normalize_for_metric(jnp.asarray(feats, jnp.float32), self.metric)
        n = feats.shape[0]
        if n != self._n_seen:
            raise ValueError(
                f"pool has {n} rows but {self._n_seen} were ingested — "
                "result() needs the ingested deltas concatenated in order"
            )
        if n == 0:
            raise ValueError("nothing ingested yet")
        if not self.per_class:
            res = streaming_result(
                self._states[_FLAT], feats, min(self.budget, n)
            )
            if self.metric == "cosine":
                res = res._replace(
                    coverage=cosine_residual_coverage(feats, res.indices)
                )
            return res

        # paper §5: stratified budgets ∝ observed class arrival counts
        from repro.core.craig import _apportion_budgets  # lazy: avoid cycle

        classes = sorted(self._states)
        counts = np.array([len(self._rows[c]) for c in classes], np.int64)
        budgets = _apportion_budgets(counts, min(self.budget, n))
        all_idx, all_gains, all_w = [], [], []
        coverage = 0.0
        for c, b in zip(classes, budgets):
            if b == 0:
                continue
            rows = np.asarray(self._rows[c], np.int64)
            sub = feats[rows]
            r = streaming_result(self._states[c], sub, int(b))
            all_idx.append(rows[np.asarray(r.indices, np.int64)])
            all_gains.append(np.asarray(r.gains, np.float32))
            all_w.append(np.asarray(r.weights, np.float32))
            coverage += float(
                cosine_residual_coverage(sub, r.indices)
                if self.metric == "cosine"
                else r.coverage
            )
        return FLResult(
            jnp.asarray(np.concatenate(all_idx), jnp.int32),
            jnp.asarray(np.concatenate(all_gains)),
            jnp.asarray(np.concatenate(all_w)),
            jnp.asarray(coverage, jnp.float32),
        )

    # -- serialization -------------------------------------------------------

    def state_dict(self) -> dict:
        """JSON-able full snapshot (config + per-class sieve states)."""
        return {
            "budget": self.budget,
            "dim": self.dim,
            "metric": self.metric,
            "per_class": self.per_class,
            "n_seen": self._n_seen,
            "config": self.config.to_dict(),
            "states": {
                str(key): _state_to_dict(st) for key, st in self._states.items()
            },
            "rows": {str(key): list(rows) for key, rows in self._rows.items()},
        }

    def load_state_dict(self, d: dict) -> None:
        """Inverse of :meth:`state_dict` — resumes bit-identically."""
        cfg = EngineConfig.from_dict(d["config"])
        if not isinstance(cfg, StreamingConfig):
            raise ValueError(f"not a streaming state_dict: {d['config']!r}")
        self.budget = int(d["budget"])
        self.dim = int(d["dim"])
        self.metric = d["metric"]
        self.per_class = bool(d["per_class"])
        self.config = cfg
        self._n_seen = int(d["n_seen"])
        self._states = {
            (key if key == _FLAT else int(key)): _state_from_dict(sd)
            for key, sd in d["states"].items()
        }
        self._rows = {int(key): list(rows) for key, rows in d["rows"].items()}
