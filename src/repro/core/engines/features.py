"""Matrix-free blocked greedy engine from features (DESIGN.md §3.4).

Per greedy step, candidate gains are computed blockwise from features —
O(n²·d) per step but O(n·block) memory; the (n, n) similarity never
exists.  The Pallas ``fl_gains`` kernel accelerates the sweep on TPU.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.core.engines.base import (
    Capabilities,
    EngineConfig,
    FLResult,
    SelectionEngine,
    _replay_prefix,
    cosine_residual_coverage,
    normalize_for_metric,
)
from repro.core.engines.registry import register_engine

__all__ = ["FeaturesConfig", "FeaturesEngine", "greedy_fl_features"]


def greedy_fl_features(
    feats: jax.Array,
    budget: int,
    *,
    sim_fn: str = "neg_l2",
    gains_impl: str = "jax",
    block_n: int = 512,
    init_selected: jax.Array | None = None,
) -> FLResult:
    """Greedy FL directly from proxy features, never materializing (n, n).

    Per greedy step, candidate gains are computed blockwise from features —
    O(n²·d_eff) per step but O(n·block) memory.  ``gains_impl='pallas'`` uses
    the fused Pallas kernel (``repro.kernels.ops.fl_gains``) on TPU;
    ``'jax'`` is the pure-jnp fallback (identical math).

    Args:
      feats: (n, d) proxy features.
      budget: r.
      sim_fn: 'neg_l2' → s_ij = d_max − ‖x_i − x_j‖ (paper's metric) or 'dot'.
      gains_impl: 'jax' | 'pallas'.
      block_n: candidate block size for gain evaluation.
      init_selected: optional warm-start prefix (see ``greedy_fl_matrix``);
        each prefix element costs one O(n·d) similarity column, not a full
        O(n²·d) gain sweep.
    """
    from repro.kernels import ops as kops  # local import; kernels optional

    n, _ = feats.shape
    feats = feats.astype(jnp.float32)
    budget = int(min(budget, n))
    sq = jnp.sum(feats * feats, axis=-1)  # (n,)

    if sim_fn == "neg_l2":
        # d_max upper bound: max pairwise distance ≤ 2·max‖x‖ (triangle ineq.)
        d_max = 2.0 * jnp.sqrt(jnp.max(sq)) + 1e-6
    elif sim_fn == "dot":
        d_max = jnp.asarray(0.0, jnp.float32)
    else:
        raise ValueError(f"unknown sim_fn {sim_fn!r}")

    def sim_block(cand_idx: jax.Array) -> jax.Array:
        """(n, m) similarity of every point to the candidate block."""
        cf = feats[cand_idx]  # (m, d)
        if sim_fn == "dot":
            return feats @ cf.T
        d2 = sq[:, None] + sq[cand_idx][None, :] - 2.0 * (feats @ cf.T)
        return d_max - jnp.sqrt(jnp.maximum(d2, 0.0))

    n_blocks = (n + block_n - 1) // block_n
    pad_n = n_blocks * block_n
    all_idx = jnp.arange(pad_n) % n  # wrap padding onto valid rows

    def gains_all(cur_max: jax.Array) -> jax.Array:
        """Gains for every candidate in V, computed block by block."""

        def blk(carry, b):
            idx = jax.lax.dynamic_slice_in_dim(all_idx, b * block_n, block_n)
            if gains_impl == "pallas":
                g = kops.fl_gains(feats, feats[idx], cur_max, sq, sq[idx], d_max)
            else:
                s = sim_block(idx)
                g = jnp.sum(jnp.maximum(s - cur_max[:, None], 0.0), axis=0)
            return carry, g

        _, gs = jax.lax.scan(blk, None, jnp.arange(n_blocks))
        return gs.reshape(pad_n)[:n]

    init_idx, init_gains, cur_max0, chosen0 = _replay_prefix(
        init_selected, budget, n, lambda e: sim_block(e[None])[:, 0]
    )

    def step(state, _):
        cur_max, chosen = state
        g = gains_all(cur_max)
        g = jnp.where(chosen, -jnp.inf, g)
        e = jnp.argmax(g)
        s_e = sim_block(e[None])[:, 0]
        return (jnp.maximum(cur_max, s_e), chosen.at[e].set(True)), (
            e.astype(jnp.int32),
            g[e],
        )

    (cur_max, _), (new_idx, new_gains) = jax.lax.scan(
        step, (cur_max0, chosen0), None, length=budget - init_idx.shape[0]
    )
    indices = jnp.concatenate([init_idx, new_idx])
    gains = jnp.concatenate([init_gains, new_gains])

    # Weights: assign every i to its most-similar selected element.
    sel_sim = sim_block(indices)  # (n, r)
    assign = jnp.argmax(sel_sim, axis=1)
    weights = jnp.zeros((budget,), jnp.float32).at[assign].add(1.0)
    best = jnp.max(sel_sim, axis=1)
    if sim_fn == "neg_l2":
        coverage = jnp.sum(d_max - best)  # = L(S) = Σ_i min_{j∈S} ‖x_i − x_j‖
    else:
        coverage = -jnp.sum(best)  # dot-similarity residual (lower = better)
    return FLResult(indices, gains.astype(jnp.float32), weights, coverage)


@dataclasses.dataclass(frozen=True)
class FeaturesConfig(EngineConfig):
    """Matrix-free blocked greedy.

    Attributes:
      gains_impl: 'jax' (pure-jnp sweep) | 'pallas' (fused ``fl_gains``
        kernel; TPU, interpret mode elsewhere).
      block_n: candidate block size per gain-sweep tile.
    """

    name: ClassVar[str] = "features"
    gains_impl: str = "jax"
    block_n: int = 512


@register_engine
class FeaturesEngine(SelectionEngine):
    name = "features"
    config_cls = FeaturesConfig
    capabilities = Capabilities(
        exact=True,
        matrix_free=True,
        jit_safe=True,
        supports_cover=False,
        supports_metrics=("l2", "cosine"),  # cosine via normalized l2
        memory=lambda n, d: 4 * n * (d + 512),
    )

    def select(
        self, feats, budget, *, metric="l2", init_selected=None, rng=None
    ) -> FLResult:
        feats = normalize_for_metric(jnp.asarray(feats), metric)
        res = greedy_fl_features(
            feats,
            budget,
            gains_impl=self.config.gains_impl,
            block_n=self.config.block_n,
            init_selected=init_selected,
        )
        if metric == "cosine":  # report L(S) in cosine-distance units
            res = res._replace(
                coverage=cosine_residual_coverage(feats, res.indices)
            )
        return res
