"""Pipelined, device-resident proxy extraction (DESIGN.md §9).

CRAIG's refresh cost is extraction + selection (paper §3.4: the proxy is the
gradient of the loss w.r.t. the last layer's input, recomputed every refresh
because deep-net gradients drift with w).  The selection half has engine
tiers (DESIGN.md §3); this module is the extraction half: the sweep that
runs ``select_step`` over the candidate pool.  A naive host loop — one
jitted batch per dispatch, blocking on ``np.asarray`` per batch, features
bounced device→host→device before the jit-safe engines re-upload them — is
O(n_pool/B) python dispatches of pure overhead, and at scale the pool sweep
(not the greedy) dominates coreset cost (CREST, PAPERS.md).

``ProxyExtractor`` turns the sweep into a pipelined device program:

  * **Megabatch scan** — ``megabatch`` pool batches fold into ONE
    ``lax.scan`` dispatch over fixed-shape (M, B, ...) batches.  The tail is
    handled with a validity mask, not pad-then-drop: the last batch's index
    slots wrap around the pool (so batch *contents* match the per-batch
    baseline bit-for-bit), all padding lands at the flattened tail, and the
    invalid rows are cut with a device-side slice — the feature matrix never
    visits the host to be trimmed.
  * **Double-buffered host prefetch** — host batch assembly
    (``dataset.batch``) runs on a background thread
    (:class:`repro.data.pipeline.Prefetcher`, depth 2) so megabatch m+1 is
    assembled while the device runs megabatch m.
  * **Data-parallel shard_map** — with a ``mesh``, the (M, B, ...) batches
    shard over ``axis_name``, every shard scans its slice, and features
    all-gather ON DEVICE (``core.distributed.make_distributed_extract``) —
    the pool sweep scales with the data axis like the train step does.
  * **Device-resident handoff** — ``extract(..., device_resident=True)``
    (the default, and what the trainer always uses) returns a
    ``jax.Array``: with a jit-safe engine
    (``engines.Capabilities.jit_safe`` — matrix/features/device) features
    flow into ``CraigSelector.select`` without a single host transfer
    (tests/test_extract.py counts them); host-side engines pull to host
    only what their algorithm needs (the lazy heap its similarity matrix,
    the sparse walk its CSC graph), never the raw feature matrix.
    ``device_resident=False`` is for callers that genuinely want numpy.

Determinism contract: batch contents equal the per-batch baseline's, the
scan body is the same traced ``select_fn``, and the row order is the pool
order — so selections downstream are bit-identical to the per-batch path
for fixed params (benchmarks/bench_extract.py gates this).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import Prefetcher
from repro.faults import fault_value

__all__ = ["ProxyExtractor", "make_scan_extract"]


def make_scan_extract(select_fn):
    """The ONE megabatch scan body: ``fn(params, (M, B, ...) batches) →
    (M·B, D)`` features via a single ``lax.scan`` over ``select_fn``.
    Shared by the single-device extractor and the shard_map path
    (``core.distributed.make_distributed_extract``) so the two can never
    diverge numerically — the bit-parity invariant the tier-2 shard test
    guards."""

    def scan_extract(params, batches):
        def step(_, b):
            return None, select_fn(params, b)

        _, feats = jax.lax.scan(step, None, batches)  # (M, B, D)
        return feats.reshape(-1, feats.shape[-1])

    return scan_extract


class ProxyExtractor:
    """Runs ``select_fn(params, batch) → (B, D)`` over a candidate pool.

    Args:
      select_fn: uncompiled proxy forward (``train.make_select_step``); the
        extractor owns the compilation (one jitted scan program, or one
        shard_map program with a mesh).
      dataset: index-addressable dataset (``batch(idx) → dict``).
      batch_size: per-batch pool slice B (the select step's batch shape).
      megabatch: pool batches folded into one device dispatch.  1 degrades
        to per-batch dispatch (the pre-pipeline baseline, kept for the
        benchmark ladder); the trainer default folds the whole default pool
        into one program.
      prefetch: assemble the next megabatch on a background thread while
        the device runs the current one (no-op for single-dispatch pools).
      mesh / axis_name: optional data-parallel mesh — batches shard over
        ``axis_name`` and features all-gather on device (DESIGN.md §6
        composition: extraction shards exactly like round-1 selection).
    """

    def __init__(
        self,
        select_fn: Callable[[Any, dict], jax.Array],
        dataset,
        batch_size: int,
        *,
        megabatch: int = 8,
        prefetch: bool = True,
        mesh=None,
        axis_name: str = "data",
    ):
        if batch_size < 1:
            raise ValueError(f"batch_size must be ≥ 1, got {batch_size}")
        if megabatch < 1:
            raise ValueError(f"megabatch must be ≥ 1, got {megabatch}")
        self.dataset = dataset
        self.batch_size = int(batch_size)
        self.megabatch = int(megabatch)
        self.prefetch = bool(prefetch)
        self.mesh = mesh
        self.axis_name = axis_name
        if mesh is not None:
            from repro.core.distributed import make_distributed_extract

            self._n_shards = int(mesh.shape[axis_name])
            self._scan = make_distributed_extract(select_fn, mesh, axis_name)
        else:
            self._n_shards = 1
            self._scan = jax.jit(make_scan_extract(select_fn))

    # -- host-side megabatch assembly ------------------------------------

    def _plan(self, n_pool: int) -> list[tuple[int, int]]:
        """Dispatch plan: [(batch_lo, n_batches)] per device program.

        Every dispatch's batch count is a multiple of the shard count (the
        shard_map path needs an even split); only the last dispatch may be
        smaller than ``megabatch`` — at most two compiled shapes per pool
        size.
        """
        b = self.batch_size
        m_total = -(-n_pool // b)  # ceil: total B-sized batches incl. tail
        per = self.megabatch + (-self.megabatch) % self._n_shards
        plan = []
        lo = 0
        while lo < m_total:
            m = min(per, m_total - lo)
            m += (-m) % self._n_shards  # pad batch count up to a shard multiple
            plan.append((lo, m))
            lo += m
        return plan

    def _assemble(self, pool_idx: np.ndarray, lo: int, m: int) -> dict:
        """Host work: one (m, B, ...) megabatch from ``dataset.batch``.

        Index slots past the pool wrap around to its head — identical batch
        contents to the per-batch baseline's pad-then-drop, but the drop is
        a device-side slice of the flattened feature rows (the validity
        mask: row i valid ⇔ i < n_pool, all padding at the tail).
        """
        b = self.batch_size
        flat = np.arange(lo * b, lo * b + m * b) % len(pool_idx)
        batch = self.dataset.batch(np.asarray(pool_idx)[flat])
        return {
            k: np.asarray(v).reshape((m, b) + np.shape(v)[1:])
            for k, v in batch.items()
        }

    # -- public API -------------------------------------------------------

    def extract(
        self,
        params,
        pool_idx: np.ndarray,
        *,
        device_resident: bool = True,
    ) -> jax.Array | np.ndarray:
        """Proxy features (n_pool, D) for ``pool_idx``, in pool order.

        ``device_resident=True`` (default) returns a ``jax.Array`` — the
        zero-copy handoff into ``CraigSelector.select``; ``False``
        materializes a host copy for callers that want numpy.
        """
        pool_idx = np.asarray(pool_idx)
        n_pool = len(pool_idx)
        if n_pool == 0:
            raise ValueError("empty candidate pool")
        plan = self._plan(n_pool)
        outs = []
        if self.prefetch and len(plan) > 1:
            # double buffer: assemble megabatch m+1 while the device runs m.
            # Assembly errors are re-raised on this thread (a raw generator
            # exception would kill the Prefetcher worker silently and leave
            # the queue blocking forever).
            def _tagged():
                # Exception, not BaseException: a blanket catch would also
                # swallow the GeneratorExit thrown into the suspended
                # generator when an aborted extraction GCs it, and yielding
                # from that handler is a RuntimeError per PEP 342
                try:
                    for lo, m in plan:
                        yield None, self._assemble(pool_idx, lo, m)
                except Exception as e:  # re-raised on the caller's thread
                    yield e, None

            pf = Prefetcher(_tagged(), depth=2)
            try:
                for _ in plan:
                    err, mb = pf.next()
                    if err is not None:
                        raise err
                    outs.append(self._scan(params, mb))
            finally:
                # unblock/retire the worker even when the scan side raises —
                # an abandoned Prefetcher pins megabatch host memory in its
                # queue for the life of the process
                pf.close()
        else:
            for lo, m in plan:
                outs.append(self._scan(params, self._assemble(pool_idx, lo, m)))
        feats = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
        feats = feats[:n_pool]  # validity mask: cut padded tail rows on device
        # fault hook (DESIGN.md §12): lets tests corrupt extracted features
        # (kind='nan') to exercise the selector's validate_features guard
        feats = fault_value("extract.features", feats, n_pool=n_pool)
        return feats if device_resident else np.asarray(feats)
