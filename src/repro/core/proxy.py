"""Gradient-proxy feature extraction for CRAIG (paper Eq. 9 and Eq. 16).

The dissimilarity CRAIG needs is d_ij = max_w ‖∇f_i(w) − ∇f_j(w)‖ (Eq. 7).
The paper bounds it by quantities that never require full per-example
gradients:

* Convex models (Appendix B.1, Eq. 9):  d_ij ≤ const · ‖x_i − x_j‖ for
  same-label pairs → proxy feature = x_i, selection per class, as a
  *pre-processing* step (w-independent).

* Deep nets (§3.4, Eq. 16, Appendix B.2): d_ij is captured by the gradient of
  the loss w.r.t. the input of the last layer.  For softmax+CE the last-layer
  gradient is (p_i − y_i) — "no backward pass or extra storage".

* LMs (this framework's adaptation, DESIGN.md §2): per-token (p − y) is
  vocab-sized; the gradient w.r.t. the *input of the unembedding* is
  g_t = (p_t − y_t) @ W_unembedᵀ ∈ R^{d_model}; the per-sequence proxy is the
  mean over (non-padding) tokens.  Computed chunked over the sequence so the
  (T, V) softmax is never resident; on TPU the fused Pallas `ce_proxy` kernel
  performs (softmax(z)−y)@Wᵀ blockwise over the vocab.

Also provides exact per-example gradients (vmap(grad)) as the test oracle.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = [
    "convex_feature_proxy",
    "classifier_last_layer_proxy",
    "lm_unembed_input_proxy",
    "exact_per_example_grads",
]


def convex_feature_proxy(x: jax.Array, normalize: bool = False) -> jax.Array:
    """Proxy for convex losses (Eq. 9): the raw feature vectors.

    ‖∇f_i(w) − ∇f_j(w)‖ ≤ O(‖w‖)·‖x_i − x_j‖ for same-label pairs, so
    selection on x-space distances upper-bounds gradient distances up to a
    constant that scales ε but not the argmin subset.
    """
    x = jnp.asarray(x, jnp.float32)
    if normalize:
        x = x / (jnp.linalg.norm(x, axis=-1, keepdims=True) + 1e-12)
    return x


def classifier_last_layer_proxy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Softmax+CE last-layer gradient proxy (§3.4): p − y, per example.

    Args:
      logits: (n, num_classes).
      labels: (n,) int class ids.
    Returns:
      (n, num_classes) float32 proxy features.
    """
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    y = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    return p - y


@partial(jax.jit, static_argnames=("chunk", "valid_v", "compute_dtype"))
def lm_unembed_input_proxy(
    hidden: jax.Array,
    unembed: jax.Array,
    labels: jax.Array,
    mask: jax.Array | None = None,
    chunk: int = 512,
    valid_v: int | None = None,
    compute_dtype=jnp.float32,
) -> jax.Array:
    """Pooled gradient w.r.t. the unembedding input, per sequence.

    g_{b} = mean_t  (softmax(h_{b,t} Wᵀ) − onehot(y_{b,t})) @ W     ∈ R^{d}

    computed by scanning over sequence chunks so that logits (chunk, V) are
    transient.  This is exactly d loss_b / d h_{b,t} pooled over t (for mean-
    reduced CE), i.e. the paper's "gradient of the loss w.r.t. the input to
    the last layer" (Eq. 16) adapted to token streams.

    Args:
      hidden: (B, T, D) final hidden states (pre-unembedding).
      unembed: (D, V) unembedding matrix.
      labels: (B, T) int32 targets.
      mask: optional (B, T) {0,1} validity mask.
      chunk: sequence chunk length (static).
    Returns:
      (B, D) float32 proxy features.
    """
    B, T, D = hidden.shape
    V = unembed.shape[1]
    if mask is None:
        mask = jnp.ones((B, T), jnp.float32)
    mask = mask.astype(jnp.float32)
    n_chunks = (T + chunk - 1) // chunk
    pad = n_chunks * chunk - T
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hidden = hidden.reshape(B, n_chunks, chunk, D)
    labels = labels.reshape(B, n_chunks, chunk)
    mask = mask.reshape(B, n_chunks, chunk)

    pad_bias = None
    if valid_v is not None and valid_v < V:
        pad_bias = jnp.where(jnp.arange(V) < valid_v, 0.0, -1e30)

    def body(acc, xs):
        # the two big (c, V) matmuls run in compute_dtype (bf16 in the
        # production select path — §Perf iteration 3b); softmax and the
        # pooled accumulator stay fp32
        h, y, m = xs  # (B, c, D), (B, c), (B, c)
        logits = jnp.einsum(
            "bcd,dv->bcv", h.astype(compute_dtype), unembed.astype(compute_dtype)
        ).astype(jnp.float32)
        if pad_bias is not None:
            logits = logits + pad_bias[None, None]
        p = jax.nn.softmax(logits, axis=-1)
        delta = p - jax.nn.one_hot(y, V, dtype=jnp.float32)  # (B, c, V)
        g = jnp.einsum(
            "bcv,dv->bcd", delta.astype(compute_dtype), unembed.astype(compute_dtype)
        ).astype(jnp.float32)
        acc = acc + jnp.einsum("bcd,bc->bd", g, m)
        return acc, None

    acc0 = jnp.zeros((B, D), jnp.float32)
    acc, _ = jax.lax.scan(
        body,
        acc0,
        (
            jnp.moveaxis(hidden, 1, 0),
            jnp.moveaxis(labels, 1, 0),
            jnp.moveaxis(mask, 1, 0),
        ),
    )
    denom = jnp.maximum(jnp.sum(mask, axis=(1, 2)), 1.0)
    return acc / denom[:, None]


def exact_per_example_grads(
    loss_fn: Callable[[jax.Array, jax.Array, jax.Array], jax.Array],
    params: jax.Array,
    xs: jax.Array,
    ys: jax.Array,
) -> jax.Array:
    """Oracle: exact flattened per-example gradients via vmap(grad).

    Args:
      loss_fn: (params, x_i, y_i) → scalar loss for one example.
      params: pytree of parameters.
      xs, ys: batched examples.
    Returns:
      (n, P) float32 matrix of flattened per-example gradients.
    """

    def flat_grad(x, y):
        g = jax.grad(loss_fn)(params, x, y)
        leaves = jax.tree_util.tree_leaves(g)
        return jnp.concatenate([jnp.ravel(l) for l in leaves]).astype(jnp.float32)

    return jax.vmap(flat_grad)(xs, ys)
