"""CRAIG selector (paper Alg. 1 + §3.3 budgeted variant + §5 per-class mode).

Ties together proxy features → pairwise dissimilarity → greedy facility
location → (indices, γ weights, ε estimate).  Selection operates on *gradient
proxy features* produced by :mod:`repro.core.proxy`; for convex models these
are (scaled) input features per paper Eq. 9, for deep nets last-layer
gradients per Eq. 16.

Two stopping modes:
  * budget  (paper Eq. 14): |S| ≤ r, greedy (1−1/e) guarantee; ε read off the
    residual coverage (paper Eq. 15).
  * cover   (paper Eq. 12): grow S until L(S) ≤ ε_target.

Per-class selection (paper §5): subsets are selected independently per class
with budgets proportional to class frequency, then unioned — required for the
Eq. 9 bounds (they hold only for same-label pairs) and empirically better for
deep nets.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import facility_location as fl

__all__ = ["CraigConfig", "CoresetSelection", "CraigSelector", "pairwise_distances"]


def pairwise_distances(feats: jax.Array, metric: str = "l2") -> jax.Array:
    """Dense (n, n) proxy-gradient dissimilarity matrix d_ij (paper Eq. 7/9)."""
    feats = feats.astype(jnp.float32)
    if metric == "l2":
        sq = jnp.sum(feats * feats, axis=-1)
        d2 = sq[:, None] + sq[None, :] - 2.0 * feats @ feats.T
        return jnp.sqrt(jnp.maximum(d2, 0.0))
    if metric == "cosine":
        nf = feats / (jnp.linalg.norm(feats, axis=-1, keepdims=True) + 1e-12)
        return 1.0 - nf @ nf.T
    raise ValueError(f"unknown metric {metric!r}")


def _apportion_budgets(counts: np.ndarray, total_budget: int) -> np.ndarray:
    """Largest-remainder apportionment of ``total_budget`` across classes.

    Invariants (paper §5 stratification without overshoot):
      * Σ budgets == min(total_budget, Σ counts) — the union of per-class
        selections has exactly the requested size;
      * budgets ≤ counts — no class is asked for more elements than it has;
      * every class gets ≥ 1 while feasible (total ≥ n_classes); when not,
        the most frequent classes get the singletons.

    Overshoot from the ≥1 floor is reclaimed from the largest multi-element
    allocations (never dropping a class below 1).
    """
    counts = np.asarray(counts, np.int64)
    k = len(counts)
    total = int(min(int(total_budget), int(counts.sum())))
    budgets = np.zeros(k, np.int64)
    if total <= 0:
        return budgets
    if total < k:
        # can't give every class one element: most-frequent classes win
        # (ties → lower class index, deterministic)
        order = np.lexsort((np.arange(k), -counts))
        budgets[order[:total]] = 1
        return budgets
    raw = counts / counts.sum() * total
    budgets = np.minimum(np.maximum(np.floor(raw).astype(np.int64), 1), counts)
    # distribute any shortfall by largest fractional remainder, respecting
    # class sizes
    while budgets.sum() < total:
        room = budgets < counts
        frac = np.where(room, raw - budgets, -np.inf)
        budgets[int(np.argmax(frac))] += 1
    # reclaim overshoot (the ≥1 floor can push past the budget) from the
    # largest multi-element classes; terminates because total ≥ k
    while budgets.sum() > total:
        cand = np.where(budgets > 1, budgets, -1)
        budgets[int(np.argmax(cand))] -= 1
    return budgets


@dataclasses.dataclass(frozen=True)
class CraigConfig:
    """Configuration for CRAIG subset selection.

    Attributes:
      mode: 'budget' (|S| ≤ fraction·n, paper Eq. 14) or 'cover'
        (grow until L(S) ≤ epsilon, paper Eq. 12).
      fraction: subset fraction r/n for 'budget' mode.
      epsilon: target coverage for 'cover' mode (same units as d_ij).
      metric: dissimilarity in proxy space ('l2' per the paper; 'cosine').
      engine: 'matrix' (exact greedy, dense d matrix), 'lazy' (host lazy
        greedy), 'stochastic' (paper's O(n) stochastic greedy), 'features'
        (matrix-free blocked greedy; Pallas-accelerated on TPU), 'sparse'
        (top-k similarity graph + lazy greedy over CSR columns — O(n·k)
        memory, the engine for pools past ~10⁵ points), or 'device' (the
        fully jitted device-resident fused greedy loop — one kernel launch
        per round, block greedy ``device_q`` winners per round;
        README §Engines, DESIGN.md §3.6).
      per_class: stratified per-class selection (paper §5).
      stochastic_delta: δ for stochastic-greedy sample size (n/r)·ln(1/δ).
      gains_impl: 'jax' | 'pallas' — engine='features'; engine='device'
        also accepts 'auto' (pallas on TPU, jax elsewhere).  The config
        default is 'jax'; set 'auto' (or 'pallas') to engage the fused
        fl_gains_argmax kernel on TPU.
      topk_k: neighbors kept per point — only for engine='sparse'.  Larger k
        → closer to exact greedy (k == n is exact); memory scales as n·k.
      topk_impl: 'jax' | 'pallas' graph builder — only for engine='sparse'.
      device_q: engine='device' winners committed per fused sweep (block
        greedy); 1 = exact greedy, larger amortizes sweep cost at large
        budgets.
      device_stale_tol: lazy-commit floor for engine='device' in (0, 1];
        1.0 = exact Minoux rule (exact greedy at any q).
      device_tile_dtype: 'float32' | 'bfloat16' feature tiles for
        engine='device' (gains always accumulate fp32).
    """

    mode: Literal["budget", "cover"] = "budget"
    fraction: float = 0.1
    epsilon: float = 0.0
    metric: str = "l2"
    engine: Literal[
        "matrix", "lazy", "stochastic", "features", "sparse", "device"
    ] = "matrix"
    per_class: bool = True
    stochastic_delta: float = 0.01
    gains_impl: str = "jax"
    topk_k: int = 64
    topk_impl: str = "jax"
    device_q: int = 1
    device_stale_tol: float = 0.7
    device_tile_dtype: str = "float32"
    seed: int = 0


@dataclasses.dataclass
class CoresetSelection:
    """A selected weighted coreset.

    indices/weights are aligned; ``order`` is the greedy selection order
    (paper §3.2: early elements contribute most to the gradient estimate).
    ``epsilon_hat`` is the data-driven bound on the gradient estimation error
    from Eq. 15 (residual coverage); ``coverage`` is L(S).
    """

    indices: np.ndarray  # (r,) int64 into the pool
    weights: np.ndarray  # (r,) float32, sum == n
    order: np.ndarray  # (r,) — positions, greedy order
    coverage: float
    epsilon_hat: float
    per_class_sizes: dict[int, int] | None = None

    @property
    def size(self) -> int:
        return int(self.indices.shape[0])

    def normalized_weights(self) -> np.ndarray:
        """Weights scaled to mean 1 (γ_j · r / n) for weighted-loss training."""
        w = self.weights.astype(np.float64)
        return (w * (len(w) / max(w.sum(), 1e-12))).astype(np.float32)


class CraigSelector:
    """Selects weighted coresets from gradient-proxy features.

    Usage::

        sel = CraigSelector(CraigConfig(fraction=0.1, engine="matrix"))
        coreset = sel.select(proxy_feats, labels=labels)
        # train with per-element stepsizes coreset.weights (paper Eq. 20)
    """

    def __init__(self, config: CraigConfig):
        self.config = config

    # -- public API ---------------------------------------------------------

    def select(
        self,
        feats: jax.Array | np.ndarray,
        labels: np.ndarray | None = None,
        init_selected: np.ndarray | None = None,
    ) -> CoresetSelection:
        """Select a weighted coreset from (n, d) proxy features.

        Args:
          labels: optional (n,) integer class labels; required for
            ``per_class=True`` to actually stratify (paper §5) — without
            them selection falls back to flat mode with a warning.
          init_selected: optional warm-start medoids (indices into
            ``feats``, greedy order) from a previous refresh.  The prefix's
            cover state is replayed instead of recomputed; on unchanged
            features the warm result equals the cold one (prefix
            consistency), on drifted features it amortizes re-selection
            (DESIGN.md §4).
        """
        cfg = self.config
        feats = jnp.asarray(feats)
        n = feats.shape[0]
        init = self._clean_init(init_selected, n)
        if cfg.per_class:
            if labels is not None:
                return self._select_per_class(feats, np.asarray(labels), init)
            warnings.warn(
                "per_class=True but no labels were provided; falling back "
                "to flat (unstratified) selection — pass labels to "
                "CraigSelector.select for the paper-§5 per-class mode",
                UserWarning,
                stacklevel=2,
            )
        budget = self._budget(n)
        idx, w, gains, coverage = self._select_flat(feats, budget, init)
        eps_hat = float(coverage)
        return CoresetSelection(
            indices=np.asarray(idx, np.int64),
            weights=np.asarray(w, np.float32),
            order=np.arange(len(np.asarray(idx))),
            coverage=float(coverage),
            epsilon_hat=eps_hat,
        )

    def select_distributed(
        self, feats, mesh, axis_name: str = "data"
    ) -> CoresetSelection:
        """Two-round pod-scale selection (core.distributed) with the same
        output contract as :meth:`select`.  ``feats`` is the global (n, d)
        pool; budgets derive from ``config.fraction``.  With
        ``engine='sparse'`` round 1 runs the top-k graph greedy on every
        shard, so local pools never materialize dense (n_local, n_local);
        ``engine='device'`` runs the fused device greedy round 1 — also
        matrix-free, and exact at ``device_q=1``."""
        from repro.core.distributed import distributed_select

        n = feats.shape[0]
        n_shards = int(mesh.shape[axis_name])
        r_final = self._budget(n)
        r_local = max(1, min(n // n_shards, int(r_final * 2 / n_shards) + 1))
        if self.config.engine in ("sparse", "device"):
            local_engine = self.config.engine
            self._check_sparse_config()
        else:
            local_engine = "matrix"
        res = distributed_select(
            jnp.asarray(feats, jnp.float32), mesh,
            r_local=r_local, r_final=r_final, axis_name=axis_name,
            local_engine=local_engine, topk_k=self.config.topk_k,
            device_q=self.config.device_q,
            device_stale_tol=self.config.device_stale_tol,
        )
        return CoresetSelection(
            indices=np.asarray(res.indices, np.int64),
            weights=np.asarray(res.weights, np.float32),
            order=np.arange(r_final),
            coverage=float(res.coverage),
            epsilon_hat=float(res.coverage),
        )

    # -- internals ----------------------------------------------------------

    def _budget(self, n: int) -> int:
        return max(1, int(round(self.config.fraction * n)))

    @staticmethod
    def _clean_init(init_selected, n: int) -> np.ndarray | None:
        """Normalize a warm-start prefix: int64, unique (order-preserving),
        bounds-checked.  Returns None when empty."""
        if init_selected is None:
            return None
        init = np.asarray(init_selected, np.int64).ravel()
        if init.size == 0:
            return None
        if init.min() < 0 or init.max() >= n:
            raise ValueError(
                f"init_selected out of range [0, {n}): "
                f"[{init.min()}, {init.max()}]"
            )
        _, first = np.unique(init, return_index=True)
        return init[np.sort(first)]

    def _check_sparse_config(self) -> None:
        if self.config.metric != "l2":
            raise ValueError(
                f"engine={self.config.engine!r} supports metric='l2' only"
            )
        if self.config.mode == "cover":
            raise ValueError(
                "mode='cover' needs exact prefix coverages; use "
                "engine='matrix' (the only engine implementing Eq. 12)"
            )

    def _select_flat(
        self, feats: jax.Array, budget: int, init: np.ndarray | None = None
    ):
        cfg = self.config
        n = feats.shape[0]
        budget = min(budget, n)
        if init is not None:
            init = init[:budget]
        if cfg.engine == "features":
            res = fl.greedy_fl_features(
                feats, budget, gains_impl=cfg.gains_impl, init_selected=init
            )
            return self._checked(res.indices, res.weights, res.gains, res.coverage)
        if cfg.engine == "device":
            self._check_sparse_config()  # same constraints: l2 + budget mode
            res = fl.greedy_fl_device(
                feats,
                budget,
                q=cfg.device_q,
                gains_impl=cfg.gains_impl,
                tile_dtype=cfg.device_tile_dtype,
                stale_tol=cfg.device_stale_tol,
                init_selected=None if init is None else jnp.asarray(init),
            )
            return self._checked(res.indices, res.weights, res.gains, res.coverage)
        if cfg.engine == "sparse":
            self._check_sparse_config()
            res = fl.sparse_greedy_fl_features(
                feats,
                budget,
                k=cfg.topk_k,
                topk_impl=cfg.topk_impl,
                init_selected=init,
            )
            return self._checked(res.indices, res.weights, res.gains, res.coverage)

        dist = pairwise_distances(feats, cfg.metric)
        d_max = jnp.max(dist) + 1e-6
        sim = d_max - dist  # auxiliary element at distance d_max
        if cfg.engine == "matrix":
            if cfg.mode == "cover":
                # Cover mode grows a full-budget greedy and cuts the prefix
                # meeting ε; a warm prefix would skew that cut — ignore init.
                return self._checked(*self._cover_from_matrix(dist, sim))
            res = fl.greedy_fl_matrix(sim, budget, init_selected=init)
        elif cfg.engine == "lazy":
            res = fl.lazy_greedy_fl(np.asarray(sim), budget, init_selected=init)
        elif cfg.engine == "stochastic":
            m = max(1, int(np.ceil(n / budget * np.log(1.0 / cfg.stochastic_delta))))
            m = min(m, n)
            res = fl.stochastic_greedy_fl(
                sim, budget, jax.random.PRNGKey(cfg.seed), m, init_selected=init
            )
        else:
            raise ValueError(f"unknown engine {cfg.engine!r}")
        coverage = fl.coverage_l(dist, res.indices)
        return self._checked(res.indices, res.weights, res.gains, coverage)

    def _checked(self, idx, w, gains, coverage):
        """Invariant gate on every engine's output: unique indices."""
        idx_np = np.asarray(idx)
        if len(np.unique(idx_np)) != len(idx_np):
            raise AssertionError(
                f"engine {self.config.engine!r} selected duplicate indices "
                f"({len(idx_np) - len(np.unique(idx_np))} repeats)"
            )
        return idx, w, gains, coverage

    def _cover_from_matrix(self, dist: jax.Array, sim: jax.Array):
        """Submodular cover (paper Eq. 12): grow until L(S) ≤ ε target."""
        eps = self.config.epsilon
        n = dist.shape[0]
        # Greedy with the full budget, then cut at the first prefix whose
        # coverage meets eps (greedy order is nested, so prefixes are valid).
        res = fl.greedy_fl_matrix(sim, n)
        dist_sel = dist[:, res.indices]  # (n, n) in greedy order
        run_min = jax.lax.associative_scan(jnp.minimum, dist_sel, axis=1)
        cov_prefix = jnp.sum(run_min, axis=0)  # (n,) L(S_k) for k=1..n
        k = int(jnp.argmax(cov_prefix <= eps)) + 1
        if not bool(cov_prefix[k - 1] <= eps):
            k = n  # ε unreachable: keep everything
        idx = res.indices[:k]
        _, w = fl.assign_and_weights(dist[:, idx])
        return idx, w, res.gains[:k], cov_prefix[k - 1]

    def _select_per_class(
        self,
        feats: jax.Array,
        labels: np.ndarray,
        init: np.ndarray | None = None,
    ) -> CoresetSelection:
        """Paper §5: select within each class, budgets ∝ class frequency."""
        n = feats.shape[0]
        classes = np.unique(labels)
        total_budget = min(self._budget(n), n)
        all_idx: list[np.ndarray] = []
        all_w: list[np.ndarray] = []
        coverage = 0.0
        sizes: dict[int, int] = {}
        counts = np.array([(labels == c).sum() for c in classes], np.int64)
        if self.config.mode == "cover":
            # cover mode grows each class until L(S_c) ≤ ε — sizes are
            # ε-driven, not apportioned; no class is ever skipped
            budgets = counts
        else:
            budgets = _apportion_budgets(counts, total_budget)
        for c, b in zip(classes, budgets):
            sizes[int(c)] = 0
            if b == 0:  # infeasible to cover every class within the budget
                continue
            mask = labels == c
            pool = np.nonzero(mask)[0]
            sub_feats = feats[pool]
            init_c = None
            if init is not None:
                # map the global warm prefix to within-class positions
                # (pool is sorted, so searchsorted inverts the gather)
                own = init[np.isin(init, pool)]
                if own.size:
                    init_c = np.searchsorted(pool, own)
            idx, w, _, cov = self._select_flat(sub_feats, int(b), init_c)
            all_idx.append(pool[np.asarray(idx, np.int64)])
            all_w.append(np.asarray(w, np.float32))
            coverage += float(cov)
            sizes[int(c)] = int(np.asarray(idx).shape[0])
        indices = np.concatenate(all_idx)
        weights = np.concatenate(all_w)
        if self.config.mode == "budget":
            assert len(indices) == total_budget, (len(indices), total_budget)
        # Per-class γ sum to the class count; when the budget is too small
        # to cover every class, rescale so Σγ == n still holds (the γ-sum
        # invariant every consumer of a CoresetSelection relies on).
        if weights.sum() < n:
            weights = weights * (n / weights.sum())
        return CoresetSelection(
            indices=indices,
            weights=weights,
            order=np.arange(len(indices)),
            coverage=coverage,
            epsilon_hat=coverage,
            per_class_sizes=sizes,
        )
