"""CRAIG selector (paper Alg. 1 + §3.3 budgeted variant + §5 per-class mode).

Ties together proxy features → pairwise dissimilarity → greedy facility
location → (indices, γ weights, ε estimate).  Selection operates on *gradient
proxy features* produced by :mod:`repro.core.proxy`; for convex models these
are (scaled) input features per paper Eq. 9, for deep nets last-layer
gradients per Eq. 16.

The greedy maximizer itself is a pluggable :class:`SelectionEngine` from
:mod:`repro.core.engines` (DESIGN.md §3): ``CraigConfig.engine`` names it
either as a typed ``EngineConfig`` (``SparseConfig(k=64)``,
``DeviceConfig(q=16)``, …), as ``'auto'`` (the default — the documented
policy in ``engines.auto_engine_config`` picks from capabilities + pool
size + backend), or as a deprecated legacy string.  The selector never
branches on engine names: cover mode and metrics are gated on each
engine's ``Capabilities`` record.

Two stopping modes:
  * budget  (paper Eq. 14): |S| ≤ r, greedy (1−1/e) guarantee; ε read off the
    residual coverage (paper Eq. 15).
  * cover   (paper Eq. 12): grow S until L(S) ≤ ε_target (engines with
    ``Capabilities.supports_cover`` — the matrix engine).

Per-class selection (paper §5): subsets are selected independently per class
with budgets proportional to class frequency, then unioned — required for the
Eq. 9 bounds (they hold only for same-label pairs) and empirically better for
deep nets.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines import (
    EngineConfig,
    auto_engine_config,
    make_engine,
    normalize_for_metric,
    pairwise_distances,
)
from repro.core.engines.legacy import LegacyEngineKnobs, resolve_engine_config

__all__ = ["CraigConfig", "CoresetSelection", "CraigSelector", "pairwise_distances"]


def _apportion_budgets(counts: np.ndarray, total_budget: int) -> np.ndarray:
    """Largest-remainder apportionment of ``total_budget`` across classes.

    Invariants (paper §5 stratification without overshoot):
      * Σ budgets == min(total_budget, Σ counts) — the union of per-class
        selections has exactly the requested size;
      * budgets ≤ counts — no class is asked for more elements than it has;
      * every class gets ≥ 1 while feasible (total ≥ n_classes); when not,
        the most frequent classes get the singletons.

    Overshoot from the ≥1 floor is reclaimed from the largest multi-element
    allocations (never dropping a class below 1).
    """
    counts = np.asarray(counts, np.int64)
    k = len(counts)
    total = int(min(int(total_budget), int(counts.sum())))
    budgets = np.zeros(k, np.int64)
    if total <= 0:
        return budgets
    if total < k:
        # can't give every class one element: most-frequent classes win
        # (ties → lower class index, deterministic)
        order = np.lexsort((np.arange(k), -counts))
        budgets[order[:total]] = 1
        return budgets
    raw = counts / counts.sum() * total
    budgets = np.minimum(np.maximum(np.floor(raw).astype(np.int64), 1), counts)
    # distribute any shortfall by largest fractional remainder, respecting
    # class sizes
    while budgets.sum() < total:
        room = budgets < counts
        frac = np.where(room, raw - budgets, -np.inf)
        budgets[int(np.argmax(frac))] += 1
    # reclaim overshoot (the ≥1 floor can push past the budget) from the
    # largest multi-element classes; terminates because total ≥ k
    while budgets.sum() > total:
        cand = np.where(budgets > 1, budgets, -1)
        budgets[int(np.argmax(cand))] -= 1
    return budgets


@dataclasses.dataclass(frozen=True, kw_only=True)
class CraigConfig(LegacyEngineKnobs):
    """Configuration for CRAIG subset selection.

    Attributes:
      mode: 'budget' (|S| ≤ fraction·n, paper Eq. 14) or 'cover'
        (grow until L(S) ≤ epsilon, paper Eq. 12).
      fraction: subset fraction r/n for 'budget' mode.
      epsilon: target coverage for 'cover' mode (same units as d_ij).
      metric: dissimilarity in proxy space ('l2' per the paper; 'cosine' —
        served by the matrix-free engines via l2 on unit-normalized
        features, a monotone-equivalent ordering).
      engine: which greedy maximizer runs the selection —
        * ``'auto'`` (default): picked per pool from capabilities + pool
          size + backend by ``engines.auto_engine_config`` (dense exact
          greedy ≤ 20k points; device on TPU / features elsewhere above;
          sparse past 2·10⁵; matrix whenever mode='cover');
        * a typed ``EngineConfig`` — ``engines.MatrixConfig()``,
          ``SparseConfig(k=64)``, ``DeviceConfig(q=16)``, … — the
          first-class surface (README §Engines);
        * a legacy string ``'matrix'|'lazy'|'stochastic'|'features'|
          'sparse'|'device'`` — deprecated; together with the flat knobs
          inherited from :class:`LegacyEngineKnobs` it is shim-mapped onto
          the typed config with a ``DeprecationWarning``.
      per_class: stratified per-class selection (paper §5).
      seed: PRNG seed threaded to stochastic engines.
      validate_features: NaN/Inf guard on the selector path (DESIGN.md
        §12).  A single non-finite proxy row poisons the facility-location
        argmax silently (NaN compares false everywhere, so the row is
        never covered and every gain involving it is NaN).  ``'raise'``
        (default) fails with an informative error naming the bad rows;
        ``'drop'`` drops them and warns (the dropped count rides
        ``CoresetSelection.n_dropped`` into refresh meta); ``'off'``
        skips the check.
    """

    mode: Literal["budget", "cover"] = "budget"
    fraction: float = 0.1
    epsilon: float = 0.0
    metric: str = "l2"
    engine: str | EngineConfig = "auto"
    per_class: bool = True
    seed: int = 0
    validate_features: Literal["raise", "drop", "off"] = "raise"


@dataclasses.dataclass
class CoresetSelection:
    """A selected weighted coreset.

    indices/weights are aligned; ``order`` is the greedy selection order
    (paper §3.2: early elements contribute most to the gradient estimate).
    ``epsilon_hat`` is the data-driven bound on the gradient estimation error
    from Eq. 15 (residual coverage); ``coverage`` is L(S).  ``engine`` is
    the resolved ``EngineConfig.to_dict()`` provenance — JSON-able, rides
    through sampler/checkpoint metadata and restores via
    ``EngineConfig.from_dict``.
    """

    indices: np.ndarray  # (r,) int64 into the pool
    weights: np.ndarray  # (r,) float32, sum == n
    order: np.ndarray  # (r,) — positions, greedy order
    coverage: float
    epsilon_hat: float
    per_class_sizes: dict[int, int] | None = None
    engine: dict | None = None
    # rows dropped by the validate_features='drop' guard; indices are into
    # the ORIGINAL pool either way (Σγ == n − n_dropped after a drop)
    n_dropped: int = 0

    @property
    def size(self) -> int:
        return int(self.indices.shape[0])

    def normalized_weights(self) -> np.ndarray:
        """Weights scaled to mean 1 (γ_j · r / n) for weighted-loss training."""
        w = self.weights.astype(np.float64)
        return (w * (len(w) / max(w.sum(), 1e-12))).astype(np.float32)


class CraigSelector:
    """Selects weighted coresets from gradient-proxy features.

    Usage::

        sel = CraigSelector(CraigConfig(fraction=0.1))          # engine='auto'
        sel = CraigSelector(CraigConfig(fraction=0.01,
                                        engine=SparseConfig(k=64)))
        coreset = sel.select(proxy_feats, labels=labels)
        # train with per-element stepsizes coreset.weights (paper Eq. 20)
    """

    def __init__(self, config: CraigConfig):
        self.config = config

    # -- public API ---------------------------------------------------------

    def resolve_engine(self, n: int, *, _stacklevel: int = 2) -> EngineConfig:
        """The typed engine config a greedy run over ``n`` points uses.

        ``n`` is the pool one greedy invocation actually sweeps — the full
        pool for flat selection, the *largest class* for per-class mode
        (each class is selected independently, so that run bounds cost and
        memory).  Legacy strings are shim-mapped (one
        ``DeprecationWarning`` attributed to the caller's call site);
        ``'auto'`` resolves through the documented policy
        (``engines.auto_engine_config``)."""
        typed = resolve_engine_config(self.config, _stacklevel=_stacklevel + 1)
        if typed is None:
            typed = auto_engine_config(n, mode=self.config.mode)
        return typed

    def select(
        self,
        feats: jax.Array | np.ndarray,
        labels: np.ndarray | None = None,
        init_selected: np.ndarray | None = None,
    ) -> CoresetSelection:
        """Select a weighted coreset from (n, d) proxy features.

        ``feats`` may be a device-resident ``jax.Array`` (the
        ``ProxyExtractor`` handoff, DESIGN.md §9): with a jit-safe engine
        the feature matrix never crosses to the host — only the small
        index/weight outputs do.  Host numpy features work identically.

        Args:
          labels: optional (n,) integer class labels; required for
            ``per_class=True`` to actually stratify (paper §5) — without
            them selection falls back to flat mode with a warning.
          init_selected: optional warm-start medoids (indices into
            ``feats``, greedy order) from a previous refresh.  The prefix's
            cover state is replayed instead of recomputed; on unchanged
            features the warm result equals the cold one (prefix
            consistency), on drifted features it amortizes re-selection
            (DESIGN.md §4).
        """
        cfg = self.config
        feats = jnp.asarray(feats)
        n_orig = feats.shape[0]
        init = self._clean_init(init_selected, n_orig)
        feats, labels, init, keep_idx = self._validated(feats, labels, init)
        n = feats.shape[0]
        if cfg.per_class and labels is not None:
            labels = np.asarray(labels)
            # engine='auto' keys on the pool one greedy run sweeps —
            # here the largest class, not the union of all classes
            counts = np.unique(labels, return_counts=True)[1]
            engine_cfg = self.resolve_engine(int(counts.max()), _stacklevel=3)
            sel = self._select_per_class(feats, labels, init, engine_cfg)
        else:
            if cfg.per_class:
                warnings.warn(
                    "per_class=True but no labels were provided; falling "
                    "back to flat (unstratified) selection — pass labels to "
                    "CraigSelector.select for the paper-§5 per-class mode",
                    UserWarning,
                    stacklevel=2,
                )
            engine_cfg = self.resolve_engine(n, _stacklevel=3)
            budget = self._budget(n)
            idx, w, gains, coverage = self._select_flat(
                feats, budget, init, engine_cfg
            )
            sel = CoresetSelection(
                indices=np.asarray(idx, np.int64),
                weights=np.asarray(w, np.float32),
                order=np.arange(len(np.asarray(idx))),
                coverage=float(coverage),
                epsilon_hat=float(coverage),
                engine=engine_cfg.to_dict(),
            )
        if keep_idx is not None:
            # selection ran on the cleaned pool — map back to original rows
            sel.indices = keep_idx[np.asarray(sel.indices, np.int64)]
            sel.n_dropped = int(n_orig - len(keep_idx))
        return sel

    def select_distributed(
        self, feats, mesh, axis_name: str = "data"
    ) -> CoresetSelection:
        """Two-round pod-scale selection (core.distributed) with the same
        output contract as :meth:`select`.  ``feats`` is the global (n, d)
        pool; budgets derive from ``config.fraction``.  Round 1 runs
        whichever shard_map-safe engine the config resolves to
        (``ROUND1_ENGINES``) — ``engine='auto'`` picks per *shard* pool
        size, so dense shards stay on the exact matrix greedy while big
        shards go matrix-free.  Engines with no shard_map-safe round-1
        body (lazy, stochastic) are replaced by the auto pick for the
        shard size, with a warning.  ``metric='cosine'`` is served by
        unit-normalizing the pool up front (monotone-equivalent l2
        ordering), with coverage converted back to cosine-distance units
        (same invariant as :meth:`select`)."""
        from repro.core.distributed import (
            distributed_select,
            resolve_round1_config,
        )

        cfg = self.config
        if cfg.mode == "cover":
            raise ValueError(
                "select_distributed supports mode='budget' only — cover "
                "needs exact prefix coverages on the global pool"
            )
        feats = normalize_for_metric(
            jnp.asarray(feats, jnp.float32), cfg.metric
        )
        n = feats.shape[0]
        n_shards = int(mesh.shape[axis_name])
        r_final = self._budget(n)
        r_local = max(1, min(n // n_shards, int(r_final * 2 / n_shards) + 1))
        # the ONE round-1 resolve pipeline (shared with distributed_select):
        # legacy shim → 'auto' per shard size → non-round-1 fallback →
        # pinned to what the shard_map body runs, so the stamped provenance
        # (CoresetSelection.engine) records the real execution path
        typed = resolve_engine_config(cfg)
        engine_cfg = resolve_round1_config(
            "auto" if typed is None else typed, {}, n // n_shards
        )
        res = distributed_select(
            feats, mesh,
            r_local=r_local, r_final=r_final, axis_name=axis_name,
            local_engine=engine_cfg,
            # on the unit-normalized cosine pool, Σ min ‖x−m‖²/2 =
            # Σ min (1 − cos θ) — same units as the local engines report
            squared_coverage=cfg.metric == "cosine",
        )
        return CoresetSelection(
            indices=np.asarray(res.indices, np.int64),
            weights=np.asarray(res.weights, np.float32),
            order=np.arange(r_final),
            coverage=float(res.coverage),
            epsilon_hat=float(res.coverage),
            engine=engine_cfg.to_dict(),
        )

    def select_tree(
        self,
        feats,
        fanouts: tuple[int, ...],
        *,
        mesh=None,
        compress: str = "int8",
        r_node: int | None = None,
    ) -> CoresetSelection:
        """Hierarchical tree selection (distributed.tree_select) with the
        same output contract as :meth:`select`.  ``fanouts`` is the
        leaf→root merge tree (``(n_shards,)`` reproduces the two-round
        path bit for bit on the fp32 wire); ``mesh=None`` runs the
        single-process host driver (ragged pools fine), a level-axis mesh
        from ``tree_select.tree_mesh`` runs the one-program shard_map
        driver.  Candidate gathers ship int8 per-row payloads by default
        (``compress='none'`` is the fp32 escape hatch).

        Provenance: ``CoresetSelection.engine`` records the tree topology
        and wire mode with the resolved *leaf* engine nested under
        ``local`` (``TreeSelectConfig`` — restores via
        ``engine_config_from_dict`` like any engine dict)."""
        from repro.core.distributed import resolve_round1_config
        from repro.distributed.tree_select import (
            TreeSelectConfig,
            TreeTopology,
            tree_select_host,
            tree_select_mesh,
        )

        cfg = self.config
        if cfg.mode == "cover":
            raise ValueError(
                "select_tree supports mode='budget' only — cover needs "
                "exact prefix coverages on the global pool"
            )
        topology = TreeTopology(tuple(fanouts))
        feats = normalize_for_metric(
            jnp.asarray(feats, jnp.float32), cfg.metric
        )
        n = feats.shape[0]
        n_leaves = topology.n_leaves
        r_final = self._budget(n)
        r_local = max(1, min(n // n_leaves, int(r_final * 2 / n_leaves) + 1))
        typed = resolve_engine_config(cfg)
        engine_cfg = resolve_round1_config(
            "auto" if typed is None else typed, {}, n // n_leaves
        )
        kwargs = dict(
            r_node=r_node, local_engine=engine_cfg, compress=compress,
            # same cosine-units invariant as select_distributed
            squared_coverage=cfg.metric == "cosine",
        )
        if mesh is None:
            res = tree_select_host(feats, topology, r_local, r_final, **kwargs)
        else:
            res = tree_select_mesh(
                feats, mesh, topology, r_local, r_final, **kwargs
            )
        health = getattr(res, "health", None) or {}
        provenance = TreeSelectConfig(
            fanouts=topology.fanouts, compress=compress,
            local=engine_cfg.to_dict(),
            # degradation provenance (DESIGN.md §12): host/mesh drivers have
            # no process failure domain, so these stay at the clean defaults
            degraded=bool(health.get("degraded", False)),
            missing_pids=tuple(health.get("missing_pids", ())),
            quorum=float(health.get("quorum", 1.0)),
        )
        return CoresetSelection(
            indices=np.asarray(res.indices, np.int64),
            weights=np.asarray(res.weights, np.float32),
            order=np.arange(r_final),
            coverage=float(res.coverage),
            epsilon_hat=float(res.coverage),
            engine=provenance.to_dict(),
        )

    # -- internals ----------------------------------------------------------

    def _budget(self, n: int) -> int:
        return max(1, int(round(self.config.fraction * n)))

    def _validated(
        self,
        feats: jax.Array,
        labels: np.ndarray | None,
        init: np.ndarray | None,
    ):
        """NaN/Inf guard (``CraigConfig.validate_features``, DESIGN.md §12).

        Returns ``(feats, labels, init, keep_idx)`` where ``keep_idx`` is
        None when nothing was dropped.  Only the (n,) finite mask ever
        crosses to the host — never the (n, d) feature matrix, so the
        device-resident extraction handoff (DESIGN.md §9) is preserved.
        """
        mode = self.config.validate_features
        if mode == "off":
            return feats, labels, init, None
        if mode not in ("raise", "drop"):
            raise ValueError(
                f"validate_features={mode!r} is not a policy; expected "
                "'raise', 'drop' or 'off'"
            )
        finite = np.asarray(jnp.all(jnp.isfinite(feats), axis=1))
        if bool(finite.all()):
            return feats, labels, init, None
        bad = np.nonzero(~finite)[0]
        if mode == "raise":
            raise ValueError(
                f"{bad.size} of {finite.size} proxy feature rows contain "
                f"NaN/Inf (first bad rows: {bad[:8].tolist()}); a non-finite "
                "row silently poisons the facility-location argmax.  Fix the "
                "proxy/extraction (common causes: diverged params, fp16 "
                "overflow) or set CraigConfig(validate_features='drop') to "
                "drop-and-warn."
            )
        keep_idx = np.nonzero(finite)[0]
        if keep_idx.size == 0:
            raise ValueError(
                "every proxy feature row is NaN/Inf; nothing to select from"
            )
        warnings.warn(
            f"dropping {bad.size} NaN/Inf proxy feature rows before "
            f"selection (validate_features='drop'); first bad rows: "
            f"{bad[:8].tolist()}",
            UserWarning,
            stacklevel=3,
        )
        feats = feats[jnp.asarray(keep_idx)]
        if labels is not None:
            labels = np.asarray(labels)[keep_idx]
        if init is not None:
            # remap the warm-start prefix onto cleaned-pool positions,
            # dropping medoids that were themselves corrupted
            pos = np.full(finite.size, -1, np.int64)
            pos[keep_idx] = np.arange(keep_idx.size)
            init = pos[init]
            init = init[init >= 0]
            if init.size == 0:
                init = None
        return feats, labels, init, keep_idx

    @staticmethod
    def _clean_init(init_selected, n: int) -> np.ndarray | None:
        """Normalize a warm-start prefix: int64, unique (order-preserving),
        bounds-checked.  Returns None when empty."""
        if init_selected is None:
            return None
        init = np.asarray(init_selected, np.int64).ravel()
        if init.size == 0:
            return None
        if init.min() < 0 or init.max() >= n:
            raise ValueError(
                f"init_selected out of range [0, {n}): "
                f"[{init.min()}, {init.max()}]"
            )
        _, first = np.unique(init, return_index=True)
        return init[np.sort(first)]

    def _select_flat(
        self,
        feats: jax.Array,
        budget: int,
        init: np.ndarray | None,
        engine_cfg: EngineConfig,
    ):
        cfg = self.config
        n = feats.shape[0]
        budget = min(budget, n)
        if init is not None:
            init = init[:budget]
        engine = make_engine(engine_cfg)
        caps = engine.capabilities
        if cfg.metric not in caps.supports_metrics:
            raise ValueError(
                f"engine {engine_cfg.name!r} supports metrics "
                f"{caps.supports_metrics}, got {cfg.metric!r}"
            )
        if cfg.mode == "cover":
            if not caps.supports_cover:
                raise ValueError(
                    "mode='cover' needs exact prefix coverages (paper "
                    f"Eq. 12); engine {engine_cfg.name!r} does not support "
                    "it (Capabilities.supports_cover) — use "
                    "engines.MatrixConfig()"
                )
            # Cover mode grows a full-budget greedy and cuts the prefix
            # meeting ε; a warm prefix would skew that cut — no init.
            res = engine.select_cover(feats, cfg.epsilon, metric=cfg.metric)
        else:
            res = engine.select(
                feats, budget,
                metric=cfg.metric, init_selected=init, rng=cfg.seed,
            )
        return self._checked(
            engine_cfg.name, res.indices, res.weights, res.gains, res.coverage
        )

    @staticmethod
    def _checked(engine_name, idx, w, gains, coverage):
        """Invariant gate on every engine's output: unique indices."""
        idx_np = np.asarray(idx)
        if len(np.unique(idx_np)) != len(idx_np):
            raise AssertionError(
                f"engine {engine_name!r} selected duplicate indices "
                f"({len(idx_np) - len(np.unique(idx_np))} repeats)"
            )
        return idx, w, gains, coverage

    def _select_per_class(
        self,
        feats: jax.Array,
        labels: np.ndarray,
        init: np.ndarray | None,
        engine_cfg: EngineConfig,
    ) -> CoresetSelection:
        """Paper §5: select within each class, budgets ∝ class frequency."""
        n = feats.shape[0]
        classes = np.unique(labels)
        total_budget = min(self._budget(n), n)
        all_idx: list[np.ndarray] = []
        all_w: list[np.ndarray] = []
        coverage = 0.0
        sizes: dict[int, int] = {}
        counts = np.array([(labels == c).sum() for c in classes], np.int64)
        if self.config.mode == "cover":
            # cover mode grows each class until L(S_c) ≤ ε — sizes are
            # ε-driven, not apportioned; no class is ever skipped
            budgets = counts
        else:
            budgets = _apportion_budgets(counts, total_budget)
        for c, b in zip(classes, budgets):
            sizes[int(c)] = 0
            if b == 0:  # infeasible to cover every class within the budget
                continue
            mask = labels == c
            pool = np.nonzero(mask)[0]
            sub_feats = feats[pool]
            init_c = None
            if init is not None:
                # map the global warm prefix to within-class positions
                # (pool is sorted, so searchsorted inverts the gather)
                own = init[np.isin(init, pool)]
                if own.size:
                    init_c = np.searchsorted(pool, own)
            idx, w, _, cov = self._select_flat(
                sub_feats, int(b), init_c, engine_cfg
            )
            all_idx.append(pool[np.asarray(idx, np.int64)])
            all_w.append(np.asarray(w, np.float32))
            coverage += float(cov)
            sizes[int(c)] = int(np.asarray(idx).shape[0])
        indices = np.concatenate(all_idx)
        weights = np.concatenate(all_w)
        if self.config.mode == "budget":
            assert len(indices) == total_budget, (len(indices), total_budget)
        # Per-class γ sum to the class count; when the budget is too small
        # to cover every class, rescale so Σγ == n still holds (the γ-sum
        # invariant every consumer of a CoresetSelection relies on).
        if weights.sum() < n:
            weights = weights * (n / weights.sum())
        return CoresetSelection(
            indices=indices,
            weights=weights,
            order=np.arange(len(indices)),
            coverage=coverage,
            epsilon_hat=coverage,
            per_class_sizes=sizes,
            engine=engine_cfg.to_dict(),
        )
