"""Two-round distributed CRAIG selection (GreeDi-style, shard_map).

Pod-scale training cannot ship the whole candidate pool's proxy features to
one host.  Following the paper's own scaling references (Mirzasoleiman et al.
2015b, 2016 — distributed submodular cover/maximization), selection runs in
two rounds over the data-parallel mesh axis:

  Round 1 (local):  every data shard runs greedy facility location over its
      local partition of the pool, selecting ``r_local`` candidates with local
      γ weights.  (Per-class partitioning composes with this: the trainer
      shards each class across hosts.)  The round-1 body is picked by a typed
      ``EngineConfig`` (``repro.core.engines``) — any engine in
      ``ROUND1_ENGINES`` works, and ``local_engine='auto'`` (the default)
      resolves it per *shard* pool size via the documented policy:
      * ``MatrixConfig``   — dense exact greedy per shard (§3.1);
      * ``FeaturesConfig`` — matrix-free blocked greedy (§3.4);
      * ``SparseConfig``   — top-k graph greedy (``topk_graph`` +
        ``greedy_fl_topk``), O(n_local·k) round-1 footprint — the pod-scale
        path for shards past ~10⁵ points (DESIGN.md §6);
      * ``DeviceConfig``   — device-resident fused greedy (§3.6): matrix-free
        like sparse, exact like matrix, the whole round-1 loop jitted inside
        the shard_map body.

  Round 2 (merge):  candidate features and γ weights are all-gathered
      (r_total = shards·r_local ≪ n), and a *weighted* greedy FL — each
      candidate counts γ_c points — selects the final ``r_final`` medoids.
      This runs replicated on every shard (deterministic → identical result).

  Re-weighting:     every shard assigns its local points to the final medoids
      and the per-medoid counts are ``psum``-reduced, so the final γ weights
      cover the *entire* pool exactly (Σγ = n globally).

The approximation factor of the two-round scheme is (1−1/e)²/2-ish in the
worst case but empirically near-exact (GreeDi); tests verify parity with the
centralized selection on clustered data.
"""
from __future__ import annotations

import dataclasses
import warnings
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import facility_location as fl
from repro.core.engines import (
    DeviceConfig,
    EngineConfig,
    FeaturesConfig,
    MatrixConfig,
    SparseConfig,
    auto_engine_config,
)
from repro.core.engines.legacy import resolve_distributed_engine

__all__ = [
    "DistributedSelection",
    "distributed_select",
    "local_then_merge",
    "compat_shard_map",
    "make_distributed_extract",
    "ROUND1_ENGINES",
    "normalize_round1_config",
    "resolve_round1_config",
    "leaf_round",
    "merge_round",
    "check_candidate_counts",
    "check_even_shards",
]

# Engines with a jit/shard_map-safe round-1 body.  Host-side engines (lazy)
# and the sampled stochastic greedy have no distributed round 1; callers
# fall back to 'auto'.
ROUND1_ENGINES = ("matrix", "features", "sparse", "device")


def normalize_round1_config(ec: "EngineConfig") -> "EngineConfig":
    """Pin a round-1 config to what the shard_map body actually runs.

    Round-1 bodies always use the jnp kernels — Pallas launches inside
    shard_map are not supported — so the kernel-impl knobs
    (``gains_impl`` on features/device, ``impl`` on the sparse graph
    builder) are rewritten to 'jax' here rather than silently overridden
    in the body: provenance (``CoresetSelection.engine``, checkpoints,
    benches) then records the real execution path.  An explicit 'pallas'
    request warns; the device engine's 'auto' default is pinned silently
    (it means "whatever runs here").  All other knobs (q, stale_tol,
    tile_dtype, k, block sizes) are shard_map-safe and honored as given.
    """
    for attr in ("gains_impl", "impl"):
        val = getattr(ec, attr, "jax")
        if val == "jax":
            continue
        if val == "pallas":
            warnings.warn(
                f"distributed round 1 runs the jnp kernels; "
                f"{type(ec).__name__}({attr}='pallas') is pinned to 'jax' "
                "inside shard_map",
                UserWarning,
                stacklevel=3,
            )
        ec = dataclasses.replace(ec, **{attr: "jax"})
    return ec


def resolve_round1_config(
    local_engine, legacy_knobs: dict, n_local: int
) -> "EngineConfig":
    """The ONE resolve pipeline for round-1 engine configs.

    Shared by ``distributed_select``, ``local_then_merge``'s legacy
    surface, and ``CraigSelector.select_distributed`` so every entry point
    agrees: legacy strings/knobs shim-map with a ``DeprecationWarning``,
    ``'auto'`` resolves per shard pool size, engines with no
    shard_map-safe round-1 body (``lazy``, ``stochastic``) warn and fall
    back to the auto pick, and the result is pinned to what the body
    actually runs (``normalize_round1_config``).  Idempotent on an
    already-resolved config.
    """
    ec = resolve_distributed_engine(local_engine, legacy_knobs)
    if ec is None:  # 'auto': per-shard pool size drives the pick
        ec = auto_engine_config(max(1, n_local))
    elif ec.name not in ROUND1_ENGINES:
        replacement = auto_engine_config(max(1, n_local))
        warnings.warn(
            f"engine {ec.name!r} has no shard_map-safe round-1 body; "
            f"distributed round 1 uses {replacement!r} instead "
            f"(round-1 engines: {ROUND1_ENGINES})",
            UserWarning,
            stacklevel=3,
        )
        ec = replacement
    return normalize_round1_config(ec)


def compat_shard_map(body, *, mesh, in_specs, out_specs):
    """shard_map across jax versions, replication checks off (the mapped
    bodies initialize scan carries from constants).  The entry point moved
    (jax.experimental.shard_map → jax.shard_map) and the kwarg was renamed
    (check_rep → check_vma) in separate releases, so each is probed
    independently."""
    import inspect

    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    check_kw = (
        "check_vma"
        if "check_vma" in inspect.signature(sm).parameters
        else "check_rep"
    )
    return sm(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{check_kw: False},
    )


def make_distributed_extract(select_fn, mesh: Mesh, axis_name: str = "data"):
    """Data-parallel megabatch proxy extraction (DESIGN.md §9).

    Returns ``fn(params, batches) → (M·B, D)`` where ``batches`` is a
    megabatch pytree with leading dims (M, B, ...) and M divisible by the
    ``axis_name`` size: each shard ``lax.scan``s ``select_fn`` over its
    contiguous slice of the M batches, then features all-gather ON DEVICE
    (tiled, so contiguous leading-dim sharding restores pool order) — the
    pool sweep scales with the data axis and the gathered feature matrix
    never visits the host.  Params are replicated, like round-2 selection.

    The shard body is plain jnp (``select_fn`` must be shard_map-traceable
    — the train/select steps are; Pallas proxy kernels run in interpret
    mode off-TPU, same rule as ``normalize_round1_config``).
    """
    from repro.core.extract import make_scan_extract

    scan = make_scan_extract(select_fn)  # the ONE scan body (bit parity)

    def body(params, batches):
        return jax.lax.all_gather(scan(params, batches), axis_name, tiled=True)

    return jax.jit(
        compat_shard_map(
            body, mesh=mesh, in_specs=(P(), P(axis_name)), out_specs=P()
        )
    )


class DistributedSelection(NamedTuple):
    indices: jax.Array  # (r_final,) int32 — *global* pool indices
    weights: jax.Array  # (r_final,) float32 — Σ == n_global
    coverage: jax.Array  # () float32 — global L(S)


def check_candidate_counts(
    n_local: int,
    n_nodes: int,
    r_local: int,
    r_final: int,
    *,
    where: str = "distributed_select",
) -> None:
    """Static candidate-count invariants for a local-select → merge level.

    Greedy engines asked for a budget past their pool size silently select
    duplicates (the argmax of an all-(−inf) gains row re-picks element 0),
    which then poisons the merge round with padding artifacts — the audits
    below turn those silent truncation/duplication modes into errors at
    trace time, while every shape involved is still a Python int:

      * ``r_local ≤ n_local`` — a shard cannot yield more candidates than
        it has points;
      * ``n_nodes · r_local ≥ r_final`` — the merge must see at least
        ``r_final`` distinct candidates or the final greedy degenerates.
    """
    if r_final < 1 or r_local < 1:
        raise ValueError(
            f"{where}: budgets must be ≥ 1 (r_local={r_local}, "
            f"r_final={r_final})"
        )
    if r_local > n_local:
        raise ValueError(
            f"{where}: r_local={r_local} exceeds the shard pool size "
            f"n_local={n_local} — a greedy run past its pool size selects "
            f"duplicate candidates; lower r_local to ≤ {n_local} or use "
            "fewer/larger shards"
        )
    if n_nodes * r_local < r_final:
        raise ValueError(
            f"{where}: the merge round would see only "
            f"{n_nodes}×{r_local}={n_nodes * r_local} candidates, fewer "
            f"than r_final={r_final} — raise r_local to ≥ "
            f"{-(-r_final // n_nodes)} so the final greedy has enough "
            "distinct candidates"
        )


def check_even_shards(n: int, n_shards: int, *, where: str) -> None:
    """Ragged-shard audit: ``shard_map`` needs dim 0 divisible by the mesh
    axis, and a silent pad/truncate would fabricate or drop pool points —
    raise the informative error instead of jax's sharding complaint."""
    if n % n_shards != 0:
        raise ValueError(
            f"{where}: pool size n={n} is not divisible by the "
            f"{n_shards}-shard mesh axis — shard_map cannot split it "
            f"evenly and padding would fabricate phantom pool points.  "
            f"Trim the pool to {n - n % n_shards} or use "
            "repro.distributed.tree_select.tree_select_host, which "
            "supports ragged leaf shards"
        )


def _local_round(feats: jax.Array, r_local: int):
    """Round 1 on one shard: dense greedy FL over local features."""
    sq = jnp.sum(feats * feats, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * feats @ feats.T
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    d_max = jnp.max(dist) + 1e-6
    res = fl.greedy_fl_matrix(d_max - dist, r_local)
    return res.indices, res.weights


def _local_round_sparse(feats: jax.Array, r_local: int, cfg: SparseConfig):
    """Round 1 on one shard via the top-k graph — O(n_local·k) memory.

    Selection runs on the sparsified objective; γ weights are then exact:
    every local point is assigned to its nearest selected medoid from
    features (an (n_local, r_local) distance block, never (n, n)).  The
    config arrives with the graph builder pinned to the jnp scan
    (``normalize_round1_config``).
    """
    vals, idx = fl.topk_graph(feats, cfg.k, impl=cfg.impl, block_m=cfg.block_m)
    res = fl.greedy_fl_topk(vals, idx, r_local)
    sel = feats[res.indices]  # (r_local, d)
    sq = jnp.sum(feats * feats, axis=-1)
    sqs = jnp.sum(sel * sel, axis=-1)
    d2 = sq[:, None] + sqs[None, :] - 2.0 * feats @ sel.T
    _, weights = fl.assign_and_weights(jnp.maximum(d2, 0.0))
    return res.indices, weights


def _local_round_device(feats: jax.Array, r_local: int, cfg: DeviceConfig):
    """Round 1 on one shard via the device-resident fused greedy.

    Exact greedy selections (q=1 or stale_tol=1.0) without a dense
    (n_local, n_local) block; γ weights come straight from the engine's
    exact blocked assignment.  The config arrives pinned to the jnp sweep
    (``normalize_round1_config``) — shard_map-safe on every backend.
    """
    res = fl.greedy_fl_device(
        feats, r_local, q=cfg.q, gains_impl=cfg.gains_impl,
        stale_tol=cfg.stale_tol, tile_dtype=cfg.tile_dtype,
        block_n=cfg.block_n, block_m=cfg.block_m,
    )
    return res.indices, res.weights


def _local_round_features(feats: jax.Array, r_local: int, cfg: FeaturesConfig):
    """Round 1 on one shard via the matrix-free blocked greedy (§3.4);
    the config arrives pinned to the jnp sweep (``normalize_round1_config``)."""
    res = fl.greedy_fl_features(
        feats, r_local, gains_impl=cfg.gains_impl, block_n=cfg.block_n
    )
    return res.indices, res.weights


def leaf_round(feats: jax.Array, r_local: int, engine_config: "EngineConfig | None"):
    """One local selection: ``r_local`` candidates + local γ from ``feats``.

    The level-reusable round-1 body (DESIGN.md §6): ``local_then_merge``'s
    round 1 and every leaf of the hierarchical tree
    (``repro.distributed.tree_select``) dispatch through here, so a new
    shard_map-safe engine extends both paths at once.  ``engine_config``
    must be one of ``ROUND1_ENGINES`` (already normalized via
    ``normalize_round1_config``); ``None`` means the pre-registry default,
    the dense matrix round.

    Returns ``(local_idx (r_local,), local_w (r_local,))`` with
    Σ local_w == n_local.
    """
    ec = engine_config if engine_config is not None else MatrixConfig()
    if isinstance(ec, SparseConfig):
        return _local_round_sparse(feats, r_local, ec)
    if isinstance(ec, DeviceConfig):
        return _local_round_device(feats, r_local, ec)
    if isinstance(ec, FeaturesConfig):
        return _local_round_features(feats, r_local, ec)
    if isinstance(ec, MatrixConfig):
        return _local_round(feats, r_local)
    raise ValueError(
        f"engine {ec.name!r} has no shard_map-safe round-1 body; "
        f"round-1 engines: {ROUND1_ENGINES}"
    )


def merge_round(cand_feats: jax.Array, cand_w: jax.Array, budget: int):
    """One merge level: weighted greedy FL over a gathered candidate union.

    Level-reusable (DESIGN.md §6): the two-round path calls it once at the
    root; the hierarchical tree calls it at every non-leaf node with that
    node's children's candidates.  Each candidate counts γ_c points, so
    maximizing the weighted objective keeps the merged set representative
    of the *points* below it, not just of the candidate vectors.

    Returns the full weighted ``FLResult``: ``indices`` are positions into
    the candidate union, ``weights`` are the re-aggregated γ (every
    dropped candidate's mass moves to its nearest kept medoid —
    Σ weights == Σ cand_w, so γ conservation holds level over level).
    """
    sq = jnp.sum(cand_feats * cand_feats, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * cand_feats @ cand_feats.T
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    d_max = jnp.max(dist) + 1e-6
    return fl.greedy_fl_matrix(d_max - dist, budget, point_weights=cand_w)


def local_then_merge(
    feats_sharded: jax.Array,
    r_local: int,
    r_final: int,
    axis_name: str = "data",
    engine_config: EngineConfig | None = None,
    squared_coverage: bool = False,
    local_engine: str | None = None,
    **legacy_knobs,
):
    """shard_map body: runs on one shard with a mapped ``axis_name``.

    Args:
      feats_sharded: (n_local, d) this shard's proxy features (fp32).
      r_local: round-1 budget per shard.
      r_final: final global budget.
      engine_config: typed round-1 engine config (``ROUND1_ENGINES``);
        None means ``MatrixConfig()``.
      squared_coverage: report L(S) as Σ min ‖x−m‖²/2 instead of
        Σ min ‖x−m‖ — on unit-normalized pools that is Σ min (1 − cos θ),
        keeping cosine coverage units identical to the local engines'.
      local_engine / legacy flat knob kwargs: the pre-registry surface;
        shim-mapped with a ``DeprecationWarning``
        (``engines.legacy.resolve_distributed_engine``).
    Returns:
      (global_indices (r_final,), weights (r_final,), coverage ()).
    """
    if local_engine is not None or legacy_knobs:
        if engine_config is not None:
            raise TypeError(
                "pass engine_config or the legacy local_engine surface, "
                "not both"
            )
        engine_config = resolve_round1_config(
            # the pre-registry default was the dense matrix round 1
            "matrix" if local_engine is None else local_engine,
            legacy_knobs,
            feats_sharded.shape[0],
        )
    ec = engine_config if engine_config is not None else MatrixConfig()
    n_local, _ = feats_sharded.shape
    # psum of a Python literal constant-folds to the static axis size at
    # trace time (jax.lax.axis_size only exists on newer jax releases)
    n_shards = int(jax.lax.psum(1, axis_name))  # repro-lint: disable=jit-host-sync  # psum(1) is a static int at trace time, not a traced value
    check_candidate_counts(
        n_local, n_shards, r_local, r_final, where="local_then_merge"
    )
    shard_id = jax.lax.axis_index(axis_name)

    local_idx, local_w = leaf_round(feats_sharded, r_local, ec)
    local_global_idx = shard_id * n_local + local_idx

    # Gather candidate features / weights / global ids from all shards.
    cand_feats = jax.lax.all_gather(
        feats_sharded[local_idx], axis_name, tiled=True
    )  # (n_shards·r_local, d)
    cand_w = jax.lax.all_gather(local_w, axis_name, tiled=True)
    cand_gidx = jax.lax.all_gather(local_global_idx, axis_name, tiled=True)

    sel_pos = merge_round(cand_feats, cand_w, r_final).indices  # replicated
    sel_feats = cand_feats[sel_pos]  # (r_final, d)
    sel_gidx = cand_gidx[sel_pos]

    # Exact global re-weighting: assign local points to final medoids.
    sqx = jnp.sum(feats_sharded * feats_sharded, axis=-1)
    sqm = jnp.sum(sel_feats * sel_feats, axis=-1)
    d2 = sqx[:, None] + sqm[None, :] - 2.0 * feats_sharded @ sel_feats.T
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))  # (n_local, r_final)
    assign = jnp.argmin(dist, axis=1)
    local_counts = jnp.zeros((r_final,), jnp.float32).at[assign].add(1.0)
    weights = jax.lax.psum(local_counts, axis_name)
    min_dist = jnp.min(dist, axis=1)
    residual = jnp.square(min_dist) / 2.0 if squared_coverage else min_dist
    coverage = jax.lax.psum(jnp.sum(residual), axis_name)
    return sel_gidx.astype(jnp.int32), weights, coverage


def distributed_select(
    feats: jax.Array,
    mesh: Mesh,
    r_local: int,
    r_final: int,
    axis_name: str = "data",
    local_engine: str | EngineConfig = "auto",
    squared_coverage: bool = False,
    **legacy_knobs,
) -> DistributedSelection:
    """Run two-round distributed selection over ``mesh[axis_name]``.

    ``feats`` is (n, d) with n divisible by the axis size; it is sharded over
    the first dimension.  Output indices/weights are fully replicated.

    ``local_engine`` picks the round-1 body: a typed ``EngineConfig``
    (``MatrixConfig``/``FeaturesConfig``/``SparseConfig``/``DeviceConfig``),
    or ``'auto'`` (default) to resolve it per shard pool size via
    ``engines.auto_engine_config``.  Legacy engine strings plus flat knob
    kwargs still work through the deprecation shim
    (``engines.legacy.resolve_distributed_engine``) and warn.
    """
    n_shards = int(mesh.shape[axis_name])
    check_even_shards(feats.shape[0], n_shards, where="distributed_select")
    n_local = feats.shape[0] // n_shards
    check_candidate_counts(
        n_local, n_shards, r_local, r_final, where="distributed_select"
    )
    engine_config = resolve_round1_config(local_engine, legacy_knobs, n_local)
    body = partial(
        local_then_merge, r_local=r_local, r_final=r_final,
        axis_name=axis_name, engine_config=engine_config,
        squared_coverage=squared_coverage,
    )
    fn = compat_shard_map(
        body, mesh=mesh, in_specs=(P(axis_name, None),),
        out_specs=(P(), P(), P()),
    )
    idx, w, cov = fn(feats.astype(jnp.float32))
    return DistributedSelection(idx, w, cov)
