"""Two-round distributed CRAIG selection (GreeDi-style, shard_map).

Pod-scale training cannot ship the whole candidate pool's proxy features to
one host.  Following the paper's own scaling references (Mirzasoleiman et al.
2015b, 2016 — distributed submodular cover/maximization), selection runs in
two rounds over the data-parallel mesh axis:

  Round 1 (local):  every data shard runs greedy facility location over its
      local partition of the pool, selecting ``r_local`` candidates with local
      γ weights.  (Per-class partitioning composes with this: the trainer
      shards each class across hosts.)  ``local_engine='sparse'`` swaps the
      dense (n_local, n_local) greedy for the top-k graph greedy
      (``facility_location.topk_graph`` + ``greedy_fl_topk``), dropping the
      round-1 footprint to O(n_local·k) — the pod-scale path for shards past
      ~10⁵ points (DESIGN.md §6).  ``local_engine='device'`` runs the
      device-resident fused greedy (``greedy_fl_device``, DESIGN.md §3.6)
      instead: O(n_local·block) memory like sparse, exact selections like
      matrix, the whole round-1 loop jitted inside the shard_map body with
      no dense (n_local, n_local) similarity.

  Round 2 (merge):  candidate features and γ weights are all-gathered
      (r_total = shards·r_local ≪ n), and a *weighted* greedy FL — each
      candidate counts γ_c points — selects the final ``r_final`` medoids.
      This runs replicated on every shard (deterministic → identical result).

  Re-weighting:     every shard assigns its local points to the final medoids
      and the per-medoid counts are ``psum``-reduced, so the final γ weights
      cover the *entire* pool exactly (Σγ = n globally).

The approximation factor of the two-round scheme is (1−1/e)²/2-ish in the
worst case but empirically near-exact (GreeDi); tests verify parity with the
centralized selection on clustered data.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import facility_location as fl

__all__ = [
    "DistributedSelection",
    "distributed_select",
    "local_then_merge",
    "compat_shard_map",
]


def compat_shard_map(body, *, mesh, in_specs, out_specs):
    """shard_map across jax versions, replication checks off (the mapped
    bodies initialize scan carries from constants).  The entry point moved
    (jax.experimental.shard_map → jax.shard_map) and the kwarg was renamed
    (check_rep → check_vma) in separate releases, so each is probed
    independently."""
    import inspect

    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    check_kw = (
        "check_vma"
        if "check_vma" in inspect.signature(sm).parameters
        else "check_rep"
    )
    return sm(
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{check_kw: False},
    )


class DistributedSelection(NamedTuple):
    indices: jax.Array  # (r_final,) int32 — *global* pool indices
    weights: jax.Array  # (r_final,) float32 — Σ == n_global
    coverage: jax.Array  # () float32 — global L(S)


def _local_round(feats: jax.Array, r_local: int):
    """Round 1 on one shard: dense greedy FL over local features."""
    sq = jnp.sum(feats * feats, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * feats @ feats.T
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    d_max = jnp.max(dist) + 1e-6
    res = fl.greedy_fl_matrix(d_max - dist, r_local)
    return res.indices, res.weights


def _local_round_sparse(feats: jax.Array, r_local: int, topk_k: int):
    """Round 1 on one shard via the top-k graph — O(n_local·k) memory.

    Selection runs on the sparsified objective; γ weights are then exact:
    every local point is assigned to its nearest selected medoid from
    features (an (n_local, r_local) distance block, never (n, n)).
    """
    vals, idx = fl.topk_graph(feats, topk_k, impl="jax")
    res = fl.greedy_fl_topk(vals, idx, r_local)
    sel = feats[res.indices]  # (r_local, d)
    sq = jnp.sum(feats * feats, axis=-1)
    sqs = jnp.sum(sel * sel, axis=-1)
    d2 = sq[:, None] + sqs[None, :] - 2.0 * feats @ sel.T
    _, weights = fl.assign_and_weights(jnp.maximum(d2, 0.0))
    return res.indices, weights


def _local_round_device(
    feats: jax.Array, r_local: int, device_q: int, device_stale_tol: float
):
    """Round 1 on one shard via the device-resident fused greedy.

    Exact greedy selections (q=1 or stale_tol=1.0) without a dense
    (n_local, n_local) block; γ weights come straight from the engine's
    exact blocked assignment.  Uses the jnp sweep (shard_map-safe on every
    backend); flip to the Pallas path by jitting the outer shard_map on TPU
    with gains_impl='pallas'.
    """
    res = fl.greedy_fl_device(
        feats, r_local, q=device_q, gains_impl="jax",
        stale_tol=device_stale_tol,
    )
    return res.indices, res.weights


def _merge_round(
    cand_feats: jax.Array, cand_w: jax.Array, r_final: int
) -> jax.Array:
    """Round 2: weighted greedy FL over the gathered candidate union.

    Returns positions (r_final,) into the candidate union.
    """
    sq = jnp.sum(cand_feats * cand_feats, axis=-1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * cand_feats @ cand_feats.T
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    d_max = jnp.max(dist) + 1e-6
    res = fl.greedy_fl_matrix(d_max - dist, r_final, point_weights=cand_w)
    return res.indices


def local_then_merge(
    feats_sharded: jax.Array,
    r_local: int,
    r_final: int,
    axis_name: str = "data",
    local_engine: str = "matrix",
    topk_k: int = 64,
    device_q: int = 1,
    device_stale_tol: float = 0.7,
):
    """shard_map body: runs on one shard with a mapped ``axis_name``.

    Args:
      feats_sharded: (n_local, d) this shard's proxy features (fp32).
      r_local: round-1 budget per shard.
      r_final: final global budget.
      local_engine: 'matrix' (dense round-1), 'sparse' (top-k graph
        round-1, O(n_local·topk_k) memory), or 'device' (fused device
        greedy, exact + matrix-free).
      topk_k: neighbors per point for local_engine='sparse'.
      device_q: block-greedy winners per round for local_engine='device'.
      device_stale_tol: lazy-commit floor for local_engine='device'
        (1.0 = exact at any q).
    Returns:
      (global_indices (r_final,), weights (r_final,), coverage ()).
    """
    n_local, _ = feats_sharded.shape
    shard_id = jax.lax.axis_index(axis_name)

    if local_engine == "sparse":
        local_idx, local_w = _local_round_sparse(
            feats_sharded, r_local, topk_k
        )
    elif local_engine == "device":
        local_idx, local_w = _local_round_device(
            feats_sharded, r_local, device_q, device_stale_tol
        )
    elif local_engine == "matrix":
        local_idx, local_w = _local_round(feats_sharded, r_local)
    else:
        raise ValueError(f"unknown local_engine {local_engine!r}")
    local_global_idx = shard_id * n_local + local_idx

    # Gather candidate features / weights / global ids from all shards.
    cand_feats = jax.lax.all_gather(
        feats_sharded[local_idx], axis_name, tiled=True
    )  # (n_shards·r_local, d)
    cand_w = jax.lax.all_gather(local_w, axis_name, tiled=True)
    cand_gidx = jax.lax.all_gather(local_global_idx, axis_name, tiled=True)

    sel_pos = _merge_round(cand_feats, cand_w, r_final)  # replicated
    sel_feats = cand_feats[sel_pos]  # (r_final, d)
    sel_gidx = cand_gidx[sel_pos]

    # Exact global re-weighting: assign local points to final medoids.
    sqx = jnp.sum(feats_sharded * feats_sharded, axis=-1)
    sqm = jnp.sum(sel_feats * sel_feats, axis=-1)
    d2 = sqx[:, None] + sqm[None, :] - 2.0 * feats_sharded @ sel_feats.T
    dist = jnp.sqrt(jnp.maximum(d2, 0.0))  # (n_local, r_final)
    assign = jnp.argmin(dist, axis=1)
    local_counts = jnp.zeros((r_final,), jnp.float32).at[assign].add(1.0)
    weights = jax.lax.psum(local_counts, axis_name)
    coverage = jax.lax.psum(jnp.sum(jnp.min(dist, axis=1)), axis_name)
    return sel_gidx.astype(jnp.int32), weights, coverage


def distributed_select(
    feats: jax.Array,
    mesh: Mesh,
    r_local: int,
    r_final: int,
    axis_name: str = "data",
    local_engine: str = "matrix",
    topk_k: int = 64,
    device_q: int = 1,
    device_stale_tol: float = 0.7,
) -> DistributedSelection:
    """Run two-round distributed selection over ``mesh[axis_name]``.

    ``feats`` is (n, d) with n divisible by the axis size; it is sharded over
    the first dimension.  Output indices/weights are fully replicated.
    ``local_engine='sparse'`` keeps round 1 at O(n_local·topk_k) memory;
    ``local_engine='device'`` keeps it matrix-free *and* exact (the fused
    greedy of DESIGN.md §3.6).
    """
    body = partial(
        local_then_merge, r_local=r_local, r_final=r_final,
        axis_name=axis_name, local_engine=local_engine, topk_k=topk_k,
        device_q=device_q, device_stale_tol=device_stale_tol,
    )
    fn = compat_shard_map(
        body, mesh=mesh, in_specs=(P(axis_name, None),),
        out_specs=(P(), P(), P()),
    )
    idx, w, cov = fn(feats.astype(jnp.float32))
    return DistributedSelection(idx, w, cov)
