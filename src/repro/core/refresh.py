"""Asynchronous, warm-started coreset refresh (DESIGN.md §4, §12).

CRAIG's practical speedup (paper §5) requires periodic re-selection — deep-net
proxies drift with w (§3.4, Fig 5) — but a refresh that blocks the step loop
for the full proxy-extraction + greedy pass puts selection wall-clock straight
onto the training critical path.  This module moves it off:

    trigger boundary          install boundary (next epoch)
         │                          │
         ├─ snapshot params ───────►│
         │  (immutable device refs; │
         │   numpy leaves copied)   │
         │        background thread │
         │  proxy extract + greedy  │
         │  publish RefreshResult ─►│ atomic install into CoresetSampler
         │                          │
    training continues on the *stale* coreset in between (double buffering)

The selection inside a refresh is engine-agnostic: the refresher just runs
``work_fn``, and the trainer's work carries whatever typed ``EngineConfig``
its ``CraigConfig.engine`` resolves to (``'auto'`` by default — the
``repro.core.engines`` policy picks per pool size/backend; no
engine-specific kwargs are re-threaded here).  With
``engines.DeviceConfig`` the greedy loop is a single jitted device program
(DESIGN.md §3.6), so the worker thread spends its time in one XLA dispatch
instead of a per-round host loop — the cheapest engine to run concurrently
with training, since it never contends for the host between rounds.  The
resolved engine rides the published selection's metadata
(``CoresetSelection.engine``), so checkpoints record which engine produced
each staged/installed coreset.

``AsyncRefresher`` owns the worker thread and the publish slot; the trainer
owns the install points.  ``mode='sync'`` runs the identical lifecycle with
the work inline at submit time — same install boundaries, so sync and async
training are step-for-step deterministic replicas of each other
(tests/test_refresh.py), and the steps/s delta between the two modes is
exactly the selection wall-clock removed from the critical path
(benchmarks/bench_refresh.py).

At most one refresh is in flight (double buffering, not a queue): the stale
coreset is the front buffer, the in-flight selection the back buffer.
Checkpoint semantics: the trainer drains the refresher (``wait()``) before
capturing sampler state, so a published-but-not-installed selection
round-trips through ``CoresetSampler.state_dict()`` and an in-flight one
always materializes before the snapshot — a restart never loses a refresh.

Supervision (DESIGN.md §12): each job runs under a
:class:`~repro.faults.FailurePolicy` — per-attempt retry with exponential
backoff on the worker thread, then one of three exhaustion routes: re-raise
on the caller thread (``'raise'``, the default fail-fast contract),
abandon-and-log (``'keep_stale'`` — nothing publishes, the ``on_failure``
callback records the event, training keeps sampling the installed coreset),
or one inline re-run at the caller's next touch point
(``'sync_fallback'`` — degrade to a synchronous refresh instead of skipping
it).  Failure state is per *job*: an exhausted job never poisons the
refresher — the next submit/ingest runs normally.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Literal

import jax
import numpy as np

from repro.faults import FailurePolicy, fault_point

__all__ = ["AsyncRefresher", "RefreshResult"]


@dataclasses.dataclass
class RefreshResult:
    """A published refresh: whatever ``work_fn`` returned, plus provenance.

    ``version`` is a monotone counter assigned at submit time — the same
    counter the :class:`~repro.data.pipeline.CoresetSampler` uses for its
    staged/installed buffers, so logs, checkpoints, and benchmarks can
    correlate a selection with the params snapshot that produced it.
    ``attempts`` counts work attempts (1 = first try succeeded);
    ``fell_back`` marks a result produced by the ``'sync_fallback'`` inline
    re-run on the caller thread.
    """

    version: int
    value: Any
    wall_time_s: float
    error: BaseException | None = None
    attempts: int = 1
    fell_back: bool = False


class AsyncRefresher:
    """Runs ``work_fn(params_snapshot)`` off the training critical path.

    * ``mode='async'`` — ``submit`` snapshots params (immutable
      ``jax.Array`` leaves by reference — they stay device-resident for
      the worker's extraction scan; mutable numpy leaves by copy, since
      the live training params keep updating) and returns immediately;
      extraction + selection run on a background worker thread
      (non-daemon, so interpreter shutdown joins it rather than tearing
      down under an active XLA dispatch).
    * ``mode='sync'`` — the same lifecycle with the work inline in
      ``submit``; the deterministic on-critical-path baseline.

    One job in flight at a time (double buffering).  Results publish to a
    single slot, readable via :meth:`collect`; an optional ``on_complete``
    callback fires on the worker thread the moment a job succeeds (the
    trainer uses it to stage the selection into the sampler so checkpoints
    see it without polling).

    Failure handling is governed by ``failure_policy``
    (:class:`~repro.faults.FailurePolicy`): the worker retries the work
    with exponential backoff, and exhaustion routes to re-raise on the
    caller's thread at the next :meth:`wait`/:meth:`collect`/
    :meth:`submit` (``'raise'`` — a failed selection must fail training,
    not silently train on stale data forever), to abandon-and-log
    (``'keep_stale'`` — ``on_failure`` fires with the failed
    ``RefreshResult``; the refresher stays usable), or to one inline
    re-run at the caller's next touch point (``'sync_fallback'``).

    With an ``ingest_fn``, the refresher additionally serves the streaming
    path (DESIGN.md §10): :meth:`ingest` queues pool deltas and drains the
    queue as one coalesced ``ingest_fn(deltas)`` job whenever the worker
    is idle — same single job slot, same publish/install lifecycle, one
    version per drain.  The coreset service builds on this.
    """

    def __init__(
        self,
        work_fn: Callable[[Any], Any],
        mode: Literal["sync", "async"] = "async",
        on_complete: Callable[[RefreshResult], None] | None = None,
        ingest_fn: Callable[[list], Any] | None = None,
        failure_policy: FailurePolicy | None = None,
        on_failure: Callable[[RefreshResult], None] | None = None,
    ):
        if mode not in ("sync", "async"):
            raise ValueError(f"unknown refresh mode {mode!r}")
        self._work_fn = work_fn
        self._mode = mode
        self._on_complete = on_complete
        self._ingest_fn = ingest_fn
        self._policy = failure_policy or FailurePolicy()
        self._on_failure = on_failure
        self._version = 0
        self._thread: threading.Thread | None = None
        self._result: RefreshResult | None = None
        self._lock = threading.Lock()
        self._pending: list = []
        self._fallback: tuple[RefreshResult, Callable[[], Any]] | None = None
        self._last_failure: RefreshResult | None = None

    # -- state ---------------------------------------------------------------

    @property
    def mode(self) -> str:
        return self._mode

    @property
    def version(self) -> int:
        """Version of the most recently submitted refresh (0 = none yet)."""
        return self._version

    @property
    def busy(self) -> bool:
        t = self._thread
        return t is not None and t.is_alive()

    @property
    def failure_policy(self) -> FailurePolicy:
        return self._policy

    @property
    def last_failure(self) -> RefreshResult | None:
        """Most recent abandoned job (``on_exhaustion='keep_stale'`` only);
        informational — reading it does not consume anything."""
        with self._lock:
            return self._last_failure

    # -- lifecycle -----------------------------------------------------------

    def submit(self, params: Any, *, snapshot: bool = True) -> int:
        """Snapshot ``params`` and start (or run, in sync mode) the refresh.

        Returns the new version.  While a job is in flight ``submit`` is a
        *reject*, not a queue: it raises a ``RuntimeError`` naming the
        in-flight version — callers hold at most one back buffer, and a
        caller that wants coalescing wants the :meth:`ingest` path instead.
        A worker failure from a previous job is re-raised here first (as at
        :meth:`wait`/:meth:`collect`) — submitting new work must never
        silently overwrite an uncollected failure — and a pending
        ``sync_fallback`` re-run executes here first, for the same reason.

        Contract: ``jax.Array`` leaves are snapshotted by reference (they
        are immutable), so the caller's parameter *update* must not donate
        the submitted buffers (``jax.jit(donate_argnums=...)``) while a
        refresh is in flight — a donated update deletes them under the
        worker.  The trainer's ``train_step`` is jitted without donation
        for exactly this reason; callers that must donate should pass a
        ``jax.device_get`` copy instead.
        """
        self._run_fallback_if_pending()
        self._raise_if_failed()
        if self.busy:
            raise RuntimeError(
                f"refresh v{self._version} already in flight; collect it "
                "before submitting (use ingest() for coalescing semantics)"
            )
        self._version += 1
        version = self._version

        def snap_leaf(x):
            # jax.Arrays are immutable — holding the reference IS the
            # snapshot, and it keeps the params DEVICE-resident for the
            # worker's extraction scan (no device→host→device bounce of the
            # whole param tree per refresh; DESIGN.md §9).  Host numpy
            # leaves are mutable and must be copied, or the worker would
            # see the live training updates.
            if isinstance(x, np.ndarray):
                return x.copy()
            return x

        snap = jax.tree.map(snap_leaf, params) if snapshot else params

        def job() -> None:
            try:
                self._run_job(version, lambda: self._work_fn(snap))
            except BaseException as e:  # noqa: BLE001 — never die silently
                # _run_job routes everything through the policy; this outer
                # capture only exists so a bug in the routing itself still
                # surfaces on the caller thread instead of killing the
                # worker silently
                with self._lock:
                    self._result = RefreshResult(version, None, 0.0, error=e)

        if self._mode == "sync":
            job()
            self._run_fallback_if_pending()
            self._raise_if_failed()
        else:
            # non-daemon: the interpreter joins it at shutdown instead of
            # tearing down under a thread mid-XLA-dispatch (which aborts)
            self._thread = threading.Thread(
                target=job, name=f"craig-refresh-v{version}", daemon=False
            )
            self._thread.start()
        return version

    # -- supervised job runner -----------------------------------------------

    def _run_job(self, version: int, fn: Callable[[], Any]) -> None:
        """One supervised job: retry the work per the policy, publish, or
        route the exhausted failure.  Runs on the worker thread in async
        mode, inline in sync mode."""
        policy = self._policy
        t0 = time.time()
        error: BaseException | None = None
        attempts = 0
        for attempt in range(policy.max_retries + 1):
            attempts += 1
            try:
                fault_point("refresh.worker", version=version, attempt=attempt)
                value = fn()
            except BaseException as e:  # noqa: BLE001 — routed via policy
                error = e
                if attempt < policy.max_retries:
                    time.sleep(policy.backoff_s(attempt))
                continue
            res = RefreshResult(
                version, value, time.time() - t0, attempts=attempts
            )
            try:
                if self._on_complete is not None:
                    # a failed publish must surface at wait()/collect(), not
                    # vanish on the worker thread — but it is NOT retryable:
                    # the work succeeded, and re-running it could stage the
                    # same version twice
                    self._on_complete(res)
            except BaseException as e:  # noqa: BLE001 — routed via policy
                self._exhaust(
                    RefreshResult(
                        version, None, time.time() - t0, error=e,
                        attempts=attempts,
                    ),
                    fn,
                    retryable=False,
                )
                return
            with self._lock:
                self._result = res
            return
        self._exhaust(
            RefreshResult(
                version, None, time.time() - t0, error=error,
                attempts=attempts,
            ),
            fn,
            retryable=True,
        )

    def _exhaust(
        self,
        res: RefreshResult,
        fn: Callable[[], Any],
        *,
        retryable: bool,
    ) -> None:
        """Route a job whose every attempt failed per the policy's
        exhaustion mode.  ``retryable=False`` (a publish failure) always
        takes the raise route — re-running the work could double-stage."""
        mode = self._policy.on_exhaustion
        if mode == "sync_fallback" and retryable:
            with self._lock:
                self._fallback = (res, fn)
            return
        if mode == "keep_stale":
            with self._lock:
                self._last_failure = res
            cb = self._on_failure
            if cb is None:
                return
            try:
                cb(res)
            except BaseException as e:  # noqa: BLE001 — must not die silently
                with self._lock:
                    self._result = dataclasses.replace(res, error=e)
            return
        with self._lock:
            self._result = res

    def _run_fallback_if_pending(self) -> None:
        """Run an exhausted job's one-shot synchronous re-run inline on the
        calling thread (``on_exhaustion='sync_fallback'``).  Success
        publishes through the normal ``on_complete``/result path with
        ``fell_back=True``; a second failure is stored and re-raised like
        any worker failure."""
        with self._lock:
            pending, self._fallback = self._fallback, None
        if pending is None:
            return
        failed, fn = pending
        t0 = time.time()
        try:
            value = fn()
            res = RefreshResult(
                failed.version,
                value,
                failed.wall_time_s + time.time() - t0,
                attempts=failed.attempts + 1,
                fell_back=True,
            )
            if self._on_complete is not None:
                self._on_complete(res)
            with self._lock:
                self._result = res
        except BaseException as e:  # noqa: BLE001 — re-raised at wait()
            with self._lock:
                self._result = RefreshResult(
                    failed.version,
                    None,
                    failed.wall_time_s + time.time() - t0,
                    error=e,
                    attempts=failed.attempts + 1,
                    fell_back=True,
                )

    # -- streaming ingest (coalescing) ---------------------------------------

    @property
    def pending_deltas(self) -> int:
        """Deltas queued for the next coalesced ingest drain."""
        with self._lock:
            return len(self._pending)

    def ingest(self, *deltas: Any) -> int | None:
        """Queue pool deltas and drain them through ``ingest_fn``.

        The streaming counterpart of :meth:`submit` (DESIGN.md §10): where
        submit *rejects* while a job is in flight, ``ingest`` *coalesces* —
        deltas enqueue unconditionally, and whenever no job is in flight
        the whole queue drains as ONE job, ``ingest_fn(deltas)``,
        publishing a single ``RefreshResult`` through the same slot /
        ``on_complete`` path (one version per drain, not per delta).

        Returns the drained version, or ``None`` if the deltas were queued
        behind an in-flight job — they drain at the next
        ingest/:meth:`wait`/:meth:`collect` touch point.  Worker failures
        surface exactly like submit's: routed per the failure policy, with
        the ``'raise'`` mode re-raising on the caller's thread at the next
        drain attempt, ``wait``, or ``collect``.
        """
        if self._ingest_fn is None:
            raise RuntimeError(
                "this refresher has no ingest_fn; pass one at construction "
                "to use the streaming ingest path"
            )
        if not deltas:
            raise ValueError("ingest() needs at least one delta")
        with self._lock:
            self._pending.extend(deltas)
        return self._drain()

    def _drain(self) -> int | None:
        """Start one coalesced ingest job if idle and deltas are queued."""
        if self.busy:
            return None
        self._run_fallback_if_pending()
        self._raise_if_failed()
        with self._lock:
            if not self._pending:
                return None
            batch, self._pending = self._pending, []
        self._version += 1
        version = self._version

        def job() -> None:
            try:
                self._run_job(version, lambda: self._ingest_fn(batch))
            except BaseException as e:  # noqa: BLE001 — never die silently
                with self._lock:
                    self._result = RefreshResult(version, None, 0.0, error=e)

        if self._mode == "sync":
            job()
            self._run_fallback_if_pending()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(
                target=job, name=f"craig-ingest-v{version}", daemon=False
            )
            self._thread.start()
        return version

    def reset_version(self, version: int) -> None:
        """Fast-forward the version counter (monotonicity across restarts:
        a restored trainer seeds this from the checkpointed sampler state so
        post-restore refreshes never collide with already-staged/installed
        versions)."""
        if self.busy:
            raise RuntimeError("cannot reset version while a refresh runs")
        self._version = max(self._version, int(version))

    def wait(self, timeout: float | None = None) -> None:
        """Block until no job is in flight, no queued deltas remain and no
        sync fallback is pending; re-raise a worker failure.

        ``timeout`` is a TOTAL deadline across everything outstanding
        (thread join + any coalesced drains it unblocks), not a per-join
        budget.  On expiry a ``TimeoutError`` raises and the refresher
        stays fully usable: the in-flight job keeps running, ``busy`` stays
        true, and the job's eventual outcome — including a failure —
        surfaces exactly once at the next
        ``wait``/``collect``/``submit``/``ingest`` touch point
        (tests/test_refresh.py pins this regression).
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            t = self._thread
            if t is not None:
                remaining = (
                    None if deadline is None else deadline - time.monotonic()
                )
                if remaining is None or remaining > 0:
                    t.join(remaining)
                if t.is_alive():
                    raise TimeoutError(
                        f"refresh still running after {timeout}s"
                    )
                self._thread = None
            self._run_fallback_if_pending()
            self._raise_if_failed()
            if self._ingest_fn is not None and self._drain() is not None:
                continue
            return

    def collect(self, block: bool = False) -> RefreshResult | None:
        """Pop the published result, if any.  ``block=True`` waits first."""
        if block:
            self.wait()
        else:
            self._raise_if_failed()
        with self._lock:
            res, self._result = self._result, None
        return res

    def _raise_if_failed(self) -> None:
        with self._lock:
            res = self._result
            if res is not None and res.error is not None:
                self._result = None
            else:
                res = None
        if res is not None:
            raise RuntimeError(
                f"coreset refresh v{res.version} failed after "
                f"{res.attempts} attempt(s)"
            ) from res.error
