"""CRAIG core: facility-location greedy selection over gradient proxies.

The greedy engines live in :mod:`repro.core.engines` (SelectionEngine
protocol + typed configs + capability-driven registry); the most common
entry points are re-exported here.
"""
from repro.core.craig import CoresetSelection, CraigConfig, CraigSelector
from repro.core.engines import (
    Capabilities,
    EngineConfig,
    SelectionEngine,
    StreamingSelector,
    auto_engine_config,
    get_engine,
    list_engines,
    make_engine,
)
from repro.core.facility_location import (
    FLResult,
    facility_location_value,
    greedy_fl_features,
    greedy_fl_matrix,
    lazy_greedy_fl,
    stochastic_greedy_fl,
)
from repro.core.proxy import (
    classifier_last_layer_proxy,
    convex_feature_proxy,
    exact_per_example_grads,
    lm_unembed_input_proxy,
)
from repro.core.refresh import AsyncRefresher, RefreshResult

__all__ = [
    "CoresetSelection",
    "CraigConfig",
    "CraigSelector",
    "Capabilities",
    "EngineConfig",
    "SelectionEngine",
    "StreamingSelector",
    "auto_engine_config",
    "get_engine",
    "list_engines",
    "make_engine",
    "FLResult",
    "facility_location_value",
    "greedy_fl_features",
    "greedy_fl_matrix",
    "lazy_greedy_fl",
    "stochastic_greedy_fl",
    "classifier_last_layer_proxy",
    "convex_feature_proxy",
    "exact_per_example_grads",
    "lm_unembed_input_proxy",
    "AsyncRefresher",
    "RefreshResult",
]
