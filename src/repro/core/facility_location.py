"""Facility-location objective and greedy maximizers for CRAIG (paper §3.2).

CRAIG reduces gradient-approximation-error minimization (paper Eq. 8) to
submodular cover / budgeted maximization of the facility-location function

    F(S) = L({s0}) - L(S ∪ {s0}),        L(S) = sum_i min_{j∈S} d_ij

over a ground set V with pairwise dissimilarities ``d_ij`` in gradient-proxy
space.  Equivalently, with similarities ``s_ij = d_max - d_ij`` (the auxiliary
element s0 realizing ``d_{i,s0} = d_max``):

    F(S) = sum_i max_{j∈S} s_ij.

The greedy engines:

* ``greedy_fl_matrix``      — exact greedy over a precomputed similarity
                              matrix, pure JAX (``lax.fori_loop``), O(r·n²).
                              The production path for per-shard selection.
* ``lazy_greedy_fl``        — host-side lazy (Minoux 1978) exact greedy with a
                              priority queue; oracle + large-n CPU path.
* ``stochastic_greedy_fl``  — stochastic greedy (Mirzasoleiman et al. 2015a),
                              O(n log 1/δ) gain evaluations per step, pure JAX;
                              the paper's "O(|V|)" fast path (§3.2, §3.4).
* ``sparse_greedy_fl``      — lazy greedy over a top-k similarity graph
                              (apricot's ``select_next_sparse`` idiom,
                              vectorized): gains walk CSR *columns* of the
                              sparsified graph, O(nnz/n · evals) per step and
                              O(n·k) memory — the million-point engine
                              (DESIGN.md §3.5).
* ``greedy_fl_topk``        — the same sparsified objective in pure JAX
                              (scatter-add gains over the fixed-width top-k
                              rows), jit/shard_map-safe; powers the sparse
                              round-1 of ``core.distributed``.
* ``greedy_fl_device``      — device-resident fused greedy (DESIGN.md §3.6):
                              the whole selection loop lives in one jitted
                              ``while_loop``; a sweep round is a single fused
                              gains-sweep + per-block argmax kernel launch
                              (``fl_gains_argmax`` on TPU, a blockwise jnp
                              scan elsewhere), streaming feature tiles so the
                              (n, n) similarity never exists.  ``q > 1``
                              amortizes each sweep over up to q commits by
                              keeping the gains vector as device-resident
                              Minoux bounds: winners are re-checked against
                              the updated cover state before commit and the
                              engine falls back to a fresh sweep when the
                              bounds go stale.  Optional bf16 feature tiles
                              with fp32 gain accumulation.

``topk_graph`` builds the (n, k) neighbor structure blockwise — pure-jnp scan
or the Pallas ``topk_sim`` kernel — without materializing (n, n).

All JAX engines are jit-compatible and differentiable-free (selection is a
discrete pre-processing step, per the paper).

Warm starts: every engine accepts ``init_selected`` — a prefix of medoids to
install before greedy resumes.  The prefix's ``cur_max`` cover state is
replayed (O(r₀·n) instead of the O(r₀·n²) a cold run spends re-deriving it),
then the remaining ``budget − r₀`` elements are selected normally.  Because
exact greedy is nested (prefix-consistent, see
tests/test_craig.py::test_greedy_order_prefix_quality), warm-starting from a
prefix of the cold selection reproduces the cold selection exactly; the
refresh path exploits this by seeding each re-selection with the previous
refresh's high-gain prefix (DESIGN.md §4).
"""
from __future__ import annotations

import heapq
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "FLResult",
    "facility_location_value",
    "coverage_l",
    "greedy_fl_matrix",
    "lazy_greedy_fl",
    "stochastic_greedy_fl",
    "greedy_fl_features",
    "greedy_fl_device",
    "topk_graph",
    "greedy_fl_topk",
    "sparse_greedy_fl",
    "sparse_greedy_fl_features",
    "assign_and_weights",
]


class FLResult(NamedTuple):
    """Result of a greedy facility-location run.

    Attributes:
      indices:  (r,) int32 — selected ground-set indices, in greedy order.
      gains:    (r,) float32 — marginal gain of each selection (non-increasing
                for exact greedy; approximately so for stochastic greedy).
      weights:  (r,) float32 — γ_j cluster sizes (paper Alg. 1 line 8);
                sum(weights) == n.
      coverage: () float32 — final L(S) = Σ_i min_{j∈S} d_ij, the paper's
                upper bound on the gradient estimation error (Eq. 8).
    """

    indices: jax.Array
    gains: jax.Array
    weights: jax.Array
    coverage: jax.Array


def facility_location_value(sim: jax.Array, selected_mask: jax.Array) -> jax.Array:
    """F(S) = Σ_i max_{j∈S} s_ij with empty-set convention F(∅)=0 (s0 at 0).

    Args:
      sim: (n, n) similarity matrix (s_ij ≥ 0; s0 baseline already subtracted).
      selected_mask: (n,) bool.
    """
    neg = jnp.asarray(-jnp.inf, sim.dtype)
    masked = jnp.where(selected_mask[None, :], sim, neg)
    best = jnp.max(masked, axis=1)
    return jnp.sum(jnp.where(jnp.any(selected_mask), jnp.maximum(best, 0.0), 0.0))


def coverage_l(dist: jax.Array, indices: jax.Array) -> jax.Array:
    """L(S) = Σ_i min_{j∈S} d_ij  (paper Eq. 8) for selected ``indices``."""
    sub = dist[:, indices]  # (n, r)
    return jnp.sum(jnp.min(sub, axis=1))


# ---------------------------------------------------------------------------
# Exact greedy over a dense similarity matrix (JAX)
# ---------------------------------------------------------------------------


def _as_init_idx(init_selected, budget: int) -> jnp.ndarray:
    """Validate/normalize a warm-start prefix for the JAX engines.

    Returns a (r₀,) int32 array with r₀ ≤ budget; the length is static (it
    comes from the array shape), so ``budget − r₀`` remains a Python int
    under jit.
    """
    idx = jnp.asarray(init_selected, jnp.int32)
    if idx.ndim != 1:
        raise ValueError("init_selected must be 1-D")
    if idx.shape[0] > budget:
        raise ValueError(
            f"init_selected has {idx.shape[0]} elements > budget {budget}"
        )
    return idx


def _replay_prefix(init_selected, budget: int, n: int, col_fn, pw=None):
    """Replay a warm-start prefix's cover state (shared by the JAX engines).

    ``col_fn(e)`` returns the (n,) similarity column of element e; marginal
    gains are recorded in prefix order (optionally ``pw``-weighted), exactly
    as a cold greedy run would have produced them.

    Returns (init_idx (r₀,), init_gains (r₀,), cur_max (n,), chosen (n,)).
    """
    cur_max = jnp.zeros((n,), jnp.float32)
    chosen = jnp.zeros((n,), bool)
    if init_selected is None:
        return jnp.zeros((0,), jnp.int32), jnp.zeros((0,), jnp.float32), cur_max, chosen
    init_idx = _as_init_idx(init_selected, budget)

    def warm(cur, e):
        col = col_fn(e)
        gap = jnp.maximum(col - cur, 0.0)
        g = jnp.sum(gap) if pw is None else jnp.dot(pw, gap)
        return jnp.maximum(cur, col), g

    cur_max, init_gains = jax.lax.scan(warm, cur_max, init_idx)
    return init_idx, init_gains, cur_max, chosen.at[init_idx].set(True)


@partial(jax.jit, static_argnames=("budget",))
def greedy_fl_matrix(
    sim: jax.Array,
    budget: int,
    point_weights: jax.Array | None = None,
    init_selected: jax.Array | None = None,
) -> FLResult:
    """Exact greedy maximization of F over a dense (n, n) similarity matrix.

    Maintains cur_max_i = max_{j∈S} s_ij (0 for the auxiliary element), so the
    marginal gain of candidate e is Σ_i w_i·relu(s_ie − cur_max_i).  One
    ``scan`` step does an O(n²) relu-reduce; total O(r·n²) — matmul-shaped
    and MXU/VPU friendly on TPU.

    Args:
      sim: (n, n) float similarities, s_ij ≥ 0. sim[i, e] = benefit of e for i.
      budget: r, number of elements to select (static).
      point_weights: optional (n,) per-point multiplicities (weighted FL, used
        by the distributed two-round merge where each candidate represents a
        cluster of γ points).  Defaults to 1.
      init_selected: optional (r₀ ≤ r,) warm-start prefix.  Its elements are
        installed first (marginal gains replayed in order, O(r₀·n)), then
        greedy selects the remaining r − r₀.
    """
    n = sim.shape[0]
    sim = sim.astype(jnp.float32)
    pw = (
        jnp.ones((n,), jnp.float32)
        if point_weights is None
        else point_weights.astype(jnp.float32)
    )

    init_idx, init_gains, cur_max0, chosen0 = _replay_prefix(
        init_selected, budget, n, lambda e: sim[:, e], pw=pw
    )

    def step(state, _):
        cur_max, chosen_mask = state
        # gains[e] = sum_i w_i · relu(sim[i, e] - cur_max[i])
        gains = pw @ jnp.maximum(sim - cur_max[:, None], 0.0)
        gains = jnp.where(chosen_mask, -jnp.inf, gains)
        e = jnp.argmax(gains)
        new_max = jnp.maximum(cur_max, sim[:, e])
        return (new_max, chosen_mask.at[e].set(True)), (e.astype(jnp.int32), gains[e])

    (cur_max, _), (new_idx, new_gains) = jax.lax.scan(
        step, (cur_max0, chosen0), None, length=budget - init_idx.shape[0]
    )
    indices = jnp.concatenate([init_idx, new_idx])
    gains = jnp.concatenate([init_gains, new_gains])

    weights = _cluster_weights(sim, indices, pw)
    # L(S) in similarity space: Σ_i (s_max_i_possible − cur_max) is not
    # recoverable without d; callers with distances use coverage_l. Report the
    # residual un-covered mass Σ_i (max_col_i − cur_max_i) as coverage proxy.
    coverage = jnp.sum(jnp.max(sim, axis=1) - cur_max)
    return FLResult(indices, gains.astype(jnp.float32), weights, coverage)


def _cluster_weights(
    sim: jax.Array, indices: jax.Array, point_weights: jax.Array | None = None
) -> jax.Array:
    """γ_j = Σ_{i : j = argmax_{s∈S} s_is} w_i (paper Alg. 1 line 8)."""
    sub = sim[:, indices]  # (n, r)
    assign = jnp.argmax(sub, axis=1)  # (n,) positions into S
    r = indices.shape[0]
    pw = (
        jnp.ones((sim.shape[0],), jnp.float32)
        if point_weights is None
        else point_weights.astype(jnp.float32)
    )
    return jnp.zeros((r,), jnp.float32).at[assign].add(pw)


# ---------------------------------------------------------------------------
# Lazy greedy (host, exact, Minoux 1978) — oracle and large-n CPU path
# ---------------------------------------------------------------------------


def lazy_greedy_fl(
    sim: np.ndarray, budget: int, init_selected: np.ndarray | None = None
) -> FLResult:
    """Exact lazy greedy with a max-heap of stale upper bounds.

    Numerically identical selections to ``greedy_fl_matrix`` (ties broken by
    lowest index) but typically evaluates far fewer gains.  ``init_selected``
    warm-starts: the prefix is installed first (gains replayed in order) and
    the heap is built against the warmed cover state, so the O(n²) heap
    initialization prices in the prefix for free.
    """
    sim = np.asarray(sim, np.float64)
    n = sim.shape[0]
    budget = min(budget, n)
    cur_max = np.zeros(n)
    indices, gains = [], []
    if init_selected is not None:
        for e in np.asarray(init_selected, np.int64)[:budget]:
            e = int(e)
            indices.append(e)
            gains.append(float(np.maximum(sim[:, e] - cur_max, 0.0).sum()))
            cur_max = np.maximum(cur_max, sim[:, e])
    r0 = len(indices)
    in_init = set(indices)
    # heap of (-gain, index, stamp); stamp = |S| when the gain was computed
    heap = [
        (-float(np.maximum(sim[:, e] - cur_max, 0.0).sum()), e, r0)
        for e in range(n)
        if e not in in_init
    ]
    heapq.heapify(heap)
    for t in range(r0, budget):
        while True:
            neg_g, e, stamp = heapq.heappop(heap)
            if stamp == t:
                break
            g = float(np.maximum(sim[:, e] - cur_max, 0.0).sum())
            heapq.heappush(heap, (-g, e, t))
        indices.append(e)
        gains.append(-neg_g)
        cur_max = np.maximum(cur_max, sim[:, e])
    idx = jnp.asarray(np.array(indices, np.int32))
    sub = sim[:, np.array(indices)]
    assign = np.argmax(sub, axis=1)
    weights = np.bincount(assign, minlength=budget).astype(np.float32)
    coverage = float(np.sum(sim.max(axis=1) - cur_max))
    return FLResult(idx, jnp.asarray(np.array(gains, np.float32)),
                    jnp.asarray(weights), jnp.asarray(coverage, jnp.float32))


# ---------------------------------------------------------------------------
# Stochastic greedy (JAX) — paper's O(|V|) fast path
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("budget", "sample_size"))
def stochastic_greedy_fl(
    sim: jax.Array,
    budget: int,
    key: jax.Array,
    sample_size: int,
    init_selected: jax.Array | None = None,
) -> FLResult:
    """Stochastic greedy: each step evaluates gains on a random candidate set.

    With sample_size = (n/r)·log(1/δ) the result is a (1−1/e−δ) approximation
    in expectation (Mirzasoleiman et al., AAAI'15), with O(n·log 1/δ) total
    gain evaluations.

    When every sampled candidate is already selected (small pools, large
    budgets), the step falls back to the first unchosen element instead of
    re-selecting a masked candidate — selections are always unique.

    ``sample_size >= n`` is the δ→0 limit: the step sweeps every candidate
    deterministically (sampling n-of-n with replacement would still miss the
    argmax with probability ≈ 1/e) and the engine reduces to exact greedy.

    Args:
      sim: (n, n) similarities.
      budget: r (static); clamped to n.
      key: PRNG key for candidate sampling.
      sample_size: candidates per step (static).
      init_selected: optional warm-start prefix (see ``greedy_fl_matrix``).
    """
    n = sim.shape[0]
    budget = int(min(budget, n))
    sim = sim.astype(jnp.float32)

    init_idx, init_gains, cur_max0, chosen0 = _replay_prefix(
        init_selected, budget, n, lambda e: sim[:, e]
    )

    full_sweep = sample_size >= n  # δ→0: evaluate everything, exact greedy

    def step(state, key_t):
        cur_max, chosen_mask = state
        # Sample candidates (with replacement; collisions harmless), or the
        # whole ground set once the requested sample covers it.
        if full_sweep:
            cand = jnp.arange(n)
        else:
            cand = jax.random.randint(key_t, (sample_size,), 0, n)
        cand_sim = sim[:, cand]  # (n, m)
        gains = jnp.sum(jnp.maximum(cand_sim - cur_max[:, None], 0.0), axis=0)
        gains = jnp.where(chosen_mask[cand], -jnp.inf, gains)
        best = jnp.argmax(gains)
        # All candidates already chosen → every gain is −inf and argmax
        # would re-select cand[0]; take the first unchosen element instead
        # (one always exists while |S| < n).
        all_dup = ~jnp.isfinite(gains[best])
        fallback = jnp.argmin(chosen_mask)  # first False
        e = jnp.where(all_dup, fallback, cand[best])
        g = jnp.where(
            all_dup,
            jnp.sum(jnp.maximum(sim[:, fallback] - cur_max, 0.0)),
            gains[best],
        )
        new_max = jnp.maximum(cur_max, sim[:, e])
        return (new_max, chosen_mask.at[e].set(True)), (e.astype(jnp.int32), g)

    keys = jax.random.split(key, budget - init_idx.shape[0])
    (cur_max, _), (new_idx, new_gains) = jax.lax.scan(
        step, (cur_max0, chosen0), keys
    )
    indices = jnp.concatenate([init_idx, new_idx])
    gains = jnp.concatenate([init_gains, new_gains])
    weights = _cluster_weights(sim, indices)
    coverage = jnp.sum(jnp.max(sim, axis=1) - cur_max)
    return FLResult(indices, gains.astype(jnp.float32), weights, coverage)


# ---------------------------------------------------------------------------
# Matrix-free greedy from features (uses the Pallas fl_gains kernel)
# ---------------------------------------------------------------------------


def greedy_fl_features(
    feats: jax.Array,
    budget: int,
    *,
    sim_fn: str = "neg_l2",
    gains_impl: str = "jax",
    block_n: int = 512,
    init_selected: jax.Array | None = None,
) -> FLResult:
    """Greedy FL directly from proxy features, never materializing (n, n).

    Per greedy step, candidate gains are computed blockwise from features —
    O(n²·d_eff) per step but O(n·block) memory.  ``gains_impl='pallas'`` uses
    the fused Pallas kernel (``repro.kernels.ops.fl_gains``) on TPU;
    ``'jax'`` is the pure-jnp fallback (identical math).

    Args:
      feats: (n, d) proxy features.
      budget: r.
      sim_fn: 'neg_l2' → s_ij = d_max − ‖x_i − x_j‖ (paper's metric) or 'dot'.
      gains_impl: 'jax' | 'pallas'.
      block_n: candidate block size for gain evaluation.
      init_selected: optional warm-start prefix (see ``greedy_fl_matrix``);
        each prefix element costs one O(n·d) similarity column, not a full
        O(n²·d) gain sweep.
    """
    from repro.kernels import ops as kops  # local import; kernels optional

    n, _ = feats.shape
    feats = feats.astype(jnp.float32)
    budget = int(min(budget, n))
    sq = jnp.sum(feats * feats, axis=-1)  # (n,)

    if sim_fn == "neg_l2":
        # d_max upper bound: max pairwise distance ≤ 2·max‖x‖ (triangle ineq.)
        d_max = 2.0 * jnp.sqrt(jnp.max(sq)) + 1e-6
    elif sim_fn == "dot":
        d_max = jnp.asarray(0.0, jnp.float32)
    else:
        raise ValueError(f"unknown sim_fn {sim_fn!r}")

    def sim_block(cand_idx: jax.Array) -> jax.Array:
        """(n, m) similarity of every point to the candidate block."""
        cf = feats[cand_idx]  # (m, d)
        if sim_fn == "dot":
            return feats @ cf.T
        d2 = sq[:, None] + sq[cand_idx][None, :] - 2.0 * (feats @ cf.T)
        return d_max - jnp.sqrt(jnp.maximum(d2, 0.0))

    n_blocks = (n + block_n - 1) // block_n
    pad_n = n_blocks * block_n
    all_idx = jnp.arange(pad_n) % n  # wrap padding onto valid rows

    def gains_all(cur_max: jax.Array) -> jax.Array:
        """Gains for every candidate in V, computed block by block."""

        def blk(carry, b):
            idx = jax.lax.dynamic_slice_in_dim(all_idx, b * block_n, block_n)
            if gains_impl == "pallas":
                g = kops.fl_gains(feats, feats[idx], cur_max, sq, sq[idx], d_max)
            else:
                s = sim_block(idx)
                g = jnp.sum(jnp.maximum(s - cur_max[:, None], 0.0), axis=0)
            return carry, g

        _, gs = jax.lax.scan(blk, None, jnp.arange(n_blocks))
        return gs.reshape(pad_n)[:n]

    init_idx, init_gains, cur_max0, chosen0 = _replay_prefix(
        init_selected, budget, n, lambda e: sim_block(e[None])[:, 0]
    )

    def step(state, _):
        cur_max, chosen = state
        g = gains_all(cur_max)
        g = jnp.where(chosen, -jnp.inf, g)
        e = jnp.argmax(g)
        s_e = sim_block(e[None])[:, 0]
        return (jnp.maximum(cur_max, s_e), chosen.at[e].set(True)), (
            e.astype(jnp.int32),
            g[e],
        )

    (cur_max, _), (new_idx, new_gains) = jax.lax.scan(
        step, (cur_max0, chosen0), None, length=budget - init_idx.shape[0]
    )
    indices = jnp.concatenate([init_idx, new_idx])
    gains = jnp.concatenate([init_gains, new_gains])

    # Weights: assign every i to its most-similar selected element.
    sel_sim = sim_block(indices)  # (n, r)
    assign = jnp.argmax(sel_sim, axis=1)
    weights = jnp.zeros((budget,), jnp.float32).at[assign].add(1.0)
    best = jnp.max(sel_sim, axis=1)
    if sim_fn == "neg_l2":
        coverage = jnp.sum(d_max - best)  # = L(S) = Σ_i min_{j∈S} ‖x_i − x_j‖
    else:
        coverage = -jnp.sum(best)  # dot-similarity residual (lower = better)
    return FLResult(indices, gains.astype(jnp.float32), weights, coverage)


# ---------------------------------------------------------------------------
# Device-resident fused greedy (DESIGN.md §3.6) — one kernel launch per round
# ---------------------------------------------------------------------------


@partial(
    jax.jit,
    static_argnames=(
        "budget", "q", "gains_impl", "block_n", "block_m", "tile_dtype",
        "stale_tol",
    ),
)
def greedy_fl_device(
    feats: jax.Array,
    budget: int,
    *,
    q: int = 1,
    gains_impl: str = "auto",
    block_n: int = 512,
    block_m: int = 2048,
    tile_dtype: str = "float32",
    stale_tol: float = 0.7,
    init_selected: jax.Array | None = None,
) -> FLResult:
    """Fully jitted device-resident greedy FL from features (DESIGN.md §3.6).

    The entire selection loop is one ``lax.while_loop`` on device — no
    per-round host round-trip, no (n, n) similarity, no host-visible gains
    vector on the Pallas path.  A *sweep* round runs one fused
    gains + argmax pass over every candidate — on TPU a single
    ``fl_gains_argmax`` kernel launch (gains accumulate tile-by-tile in
    VMEM, the argmax epilogue is fused, chosen candidates are penalized
    in-kernel), elsewhere an equivalent blockwise jnp scan with identical
    tie semantics (lowest index within a block, lowest block across blocks
    — i.e. ``jnp.argmax`` order) — and commits the winner.

    Block-greedy mode (``q > 1``) amortizes that O(n²·d) sweep over up to
    ``q`` commits: the sweep's full gains vector stays resident as Minoux
    upper bounds.  Between sweeps the loop refreshes the top-P bounds
    against the *updated* cover state in one (n, d)×(d, P) matmul and
    commits the best refreshed winner iff its fresh gain retains at least
    ``stale_tol`` of the best outstanding bound (bounds only overestimate,
    so ``stale_tol=1.0`` is the exact Minoux acceptance rule — the winner
    is the true argmax; the 0.7 default admits near-argmax winners, which
    in practice keeps coverage within ~1% of exact while committing far
    more often).  A failed re-check writes the fresh gains back as new
    (tighter) bounds; once the refresh budget is spent — the bounds have
    gone uniformly stale under heavy cover overlap — the engine falls back
    to a fresh q=1-style sweep.

    ``q=1`` sweeps before every commit and is bit-faithful to
    ``greedy_fl_matrix``/``greedy_fl_features`` (same objective, same
    tie-breaking) regardless of ``stale_tol``.

    Args:
      feats: (n, d) proxy features.
      budget: r (static); clamped to n.
      q: max winners committed per sweep (static).  1 = sweep every round;
        larger values amortize sweeps at large budgets via the lazy bounds.
      gains_impl: 'auto' (pallas on TPU, jax elsewhere) | 'pallas' | 'jax'.
      block_n / block_m: pool/candidate tile sizes for the sweep.
      tile_dtype: 'float32' | 'bfloat16' feature tiles; gains always
        accumulate fp32.
      stale_tol: lazy-commit floor in (0, 1]; 1.0 = exact greedy at any q.
      init_selected: optional warm-start prefix (see ``greedy_fl_matrix``).
    """
    n, d = feats.shape
    feats = feats.astype(jnp.float32)
    budget = int(min(budget, n))
    if gains_impl == "auto":
        gains_impl = "pallas" if jax.default_backend() == "tpu" else "jax"
    if gains_impl not in ("pallas", "jax"):
        raise ValueError(f"unknown gains_impl {gains_impl!r}")
    if tile_dtype not in ("float32", "bfloat16"):
        raise ValueError(f"unsupported tile_dtype {tile_dtype!r}")
    td = jnp.dtype(tile_dtype)

    sq = jnp.sum(feats * feats, axis=-1)  # (n,)
    d_max = 2.0 * jnp.sqrt(jnp.max(sq)) + 1e-6

    def sim_cols(idx: jax.Array) -> jax.Array:
        """(n, m) similarity of every point to elements ``idx`` ((m,))."""
        cf = feats[idx]
        d2 = sq[:, None] + sq[idx][None, :] - 2.0 * (feats @ cf.T)
        return d_max - jnp.sqrt(jnp.maximum(d2, 0.0))

    def sim_col(e: jax.Array) -> jax.Array:
        """(n,) similarity of every point to element e."""
        return sim_cols(jnp.asarray(e)[None])[:, 0]

    bm = min(block_m, n)
    n_blocks = (n + bm - 1) // bm
    pad_m = n_blocks * bm
    if gains_impl == "jax":
        featp = jnp.pad(feats, ((0, pad_m - n), (0, 0)))
        sqp = jnp.pad(sq, (0, pad_m - n))
        featp_t = featp.astype(td)
        feats_t = feats.astype(td)

    def sweep(cur_max, chosen):
        """One fused pass: full gains vector + per-block (best_gain,
        best_idx) partials.  Blocks whose every candidate is chosen/padded
        report best_gain ≤ −1e29 (real gains are ≥ 0)."""
        if gains_impl == "pallas":
            from repro.kernels import ops as kops  # local; kernels optional

            return kops.fl_gains_argmax(
                feats, feats, cur_max, sq, sq, d_max, chosen,
                block_n=block_n, block_m=bm, tile_dtype=tile_dtype,
            )
        penp = jnp.where(
            jnp.pad(chosen, (0, pad_m - n), constant_values=True), -1e30, 0.0
        )

        def blk(carry, b):
            lo = b * bm
            cf = jax.lax.dynamic_slice_in_dim(featp_t, lo, bm)
            csq = jax.lax.dynamic_slice_in_dim(sqp, lo, bm)
            cpen = jax.lax.dynamic_slice_in_dim(penp, lo, bm)
            dots = jax.lax.dot_general(
                feats_t, cf, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # (n, bm)
            d2 = sq[:, None] + csq[None, :] - 2.0 * dots
            s = d_max - jnp.sqrt(jnp.maximum(d2, 0.0))
            g = jnp.sum(jnp.maximum(s - cur_max[:, None], 0.0), axis=0)
            gp = g + cpen
            p = jnp.argmax(gp)
            return carry, (g, gp[p], (lo + p).astype(jnp.int32))

        _, (g, pg, pi) = jax.lax.scan(blk, None, jnp.arange(n_blocks))
        return g.reshape(pad_m)[:n], pg, pi

    init_idx, init_gains, cur_max0, chosen0 = _replay_prefix(
        init_selected, budget, n, sim_col
    )
    r0 = init_idx.shape[0]
    q = max(1, int(q))
    # Between sweeps, stale bounds are refreshed P at a time (one
    # (n, d) × (d, P) matmul — ~P/n of a sweep, and one loop dispatch
    # instead of P).  The refresh budget caps the worst-case chew at ~1/4
    # sweep before falling back to a fresh full sweep.  Between two commits
    # each candidate can go stale at most once (a refreshed bound is exact),
    # so the loop terminates even without the fallback.
    refresh_p = min(128, n)
    max_fails = max(1, n // (4 * refresh_p))

    out_idx0 = jnp.zeros((budget,), jnp.int32).at[:r0].set(init_idx)
    out_g0 = jnp.zeros((budget,), jnp.float32).at[:r0].set(init_gains)
    neg = jnp.float32(-jnp.inf)

    # Carry: cover state, chosen mask, Minoux upper bounds (−inf = invalid /
    # chosen), commits since the last sweep, consecutive stale re-checks,
    # output buffers, count.  commits0 = q forces a sweep on entry.
    state0 = (
        cur_max0, chosen0, jnp.full((n,), neg), jnp.int32(q), jnp.int32(0),
        out_idx0, out_g0, jnp.int32(r0),
    )

    def cond(state):
        return state[7] < budget

    def body(state):
        cur_max, chosen, ub, commits, fails, out_idx, out_g, count = state
        need_sweep = (commits >= q) | (fails >= max_fails)

        def sweep_round(_):
            g, pg, pi = sweep(cur_max, chosen)
            e = pi[jnp.argmax(pg)]  # exact winner (jnp.argmax tie order)
            col = sim_col(e)
            fresh = jnp.sum(jnp.maximum(col - cur_max, 0.0))
            new_ub = jnp.where(chosen, neg, g).at[e].set(neg)
            return (
                jnp.maximum(cur_max, col),
                chosen.at[e].set(True),
                new_ub,
                jnp.int32(1),
                jnp.int32(0),
                out_idx.at[count].set(e),
                out_g.at[count].set(fresh),
                count + 1,
            )

        def lazy_round(_):
            # Refresh the top-P bounds in one matmul, then the tolerance-
            # scaled Minoux rule: the best refreshed (exact) gain commits
            # iff it retains ≥ stale_tol of the best bound outside the
            # batch; at stale_tol=1.0 the winner is the true argmax
            # (bounds only overestimate).
            tg, tp = jax.lax.top_k(ub, refresh_p)
            cols = sim_cols(tp)  # (n, P)
            fresh_p = jnp.sum(
                jnp.maximum(cols - cur_max[:, None], 0.0), axis=0
            )
            fresh_p = jnp.where(jnp.isfinite(tg), fresh_p, neg)  # chosen
            j = jnp.argmax(fresh_p)
            e = tp[j]
            fresh = fresh_p[j]
            col = cols[:, j]
            rest = jnp.max(ub.at[tp].set(neg))
            # Small slack absorbs the sweep-vs-column summation-order
            # difference.
            commit = fresh * (1.0 + 1e-5) + 1e-6 >= stale_tol * rest
            new_ub = ub.at[tp].set(fresh_p).at[e].set(
                jnp.where(commit, neg, fresh)
            )
            return (
                jnp.where(commit, jnp.maximum(cur_max, col), cur_max),
                chosen.at[e].set(chosen[e] | commit),
                new_ub,
                commits + commit.astype(jnp.int32),
                jnp.where(commit, 0, fails + 1).astype(jnp.int32),
                out_idx.at[count].set(jnp.where(commit, e, out_idx[count])),
                out_g.at[count].set(jnp.where(commit, fresh, out_g[count])),
                count + commit.astype(jnp.int32),
            )

        return jax.lax.cond(need_sweep, sweep_round, lazy_round, None)

    cur_max, _, _, _, _, indices, gains, _ = jax.lax.while_loop(
        cond, body, state0
    )

    # γ / coverage: exact assignment of every point to its nearest medoid.
    sel_sim = sim_cols(indices)  # (n, r)
    assign = jnp.argmax(sel_sim, axis=1)
    weights = jnp.zeros((budget,), jnp.float32).at[assign].add(1.0)
    coverage = jnp.sum(d_max - jnp.max(sel_sim, axis=1))
    return FLResult(indices, gains, weights, coverage)


# ---------------------------------------------------------------------------
# Sparse top-k engine (DESIGN.md §3.5) — O(n·k) memory, million-point pools
# ---------------------------------------------------------------------------


def topk_graph(
    feats: jax.Array,
    k: int,
    *,
    d_max: jax.Array | None = None,
    block_m: int = 2048,
    impl: str = "jax",
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Blockwise top-k similarity graph: (vals (n, k) desc, idx (n, k) int32).

    Streams (n × block_m) similarity tiles and folds each into a running
    per-row top-k, so peak memory is O(n·(k + block_m)) — the dense (n, n)
    matrix never exists.  ``impl='pallas'`` routes to the fused
    ``repro.kernels.ops.topk_sim`` kernel (tile compute + merge in VMEM);
    ``'jax'`` is the pure-jnp scan (identical output, lax.top_k merge) and
    is shard_map-safe for the distributed round-1 path.

    Args:
      feats: (n, d) proxy features.
      k: neighbors per row (clamped to n); every row's list includes itself.
      d_max: similarity offset s = d_max − dist.  Defaults to the
        2·max‖x‖ + ε distance upper bound (same as ``greedy_fl_features``).
      block_m: column tile width for the jnp path.
    """
    n, _ = feats.shape
    k = int(min(k, n))
    feats = feats.astype(jnp.float32)
    if impl == "pallas":
        from repro.kernels import ops as kops  # local import; kernels optional

        return kops.topk_sim(feats, k, d_max, interpret=interpret)
    if impl != "jax":
        raise ValueError(f"unknown topk impl {impl!r}")

    sq = jnp.sum(feats * feats, axis=-1)
    if d_max is None:
        d_max = 2.0 * jnp.sqrt(jnp.max(sq)) + 1e-6
    block_m = min(block_m, n)
    n_blocks = (n + block_m - 1) // block_m
    pad = n_blocks * block_m - n
    featp = jnp.pad(feats, ((0, pad), (0, 0)))
    sqp = jnp.pad(sq, (0, pad), constant_values=1e30)  # padded cols → sim ≪ 0

    def blk(carry, b):
        vals, idx = carry
        cf = jax.lax.dynamic_slice_in_dim(featp, b * block_m, block_m)
        csq = jax.lax.dynamic_slice_in_dim(sqp, b * block_m, block_m)
        d2 = sq[:, None] + csq[None, :] - 2.0 * feats @ cf.T
        sim = d_max - jnp.sqrt(jnp.maximum(d2, 0.0))  # (n, bm)
        cols = b * block_m + jnp.arange(block_m, dtype=jnp.int32)
        cat_v = jnp.concatenate([vals, sim], axis=1)
        cat_i = jnp.concatenate(
            [idx, jnp.broadcast_to(cols[None, :], sim.shape)], axis=1
        )
        new_v, pos = jax.lax.top_k(cat_v, k)
        new_i = jnp.take_along_axis(cat_i, pos, axis=1)
        return (new_v, new_i), None

    init = (
        jnp.full((n, k), -1e30, jnp.float32),
        jnp.zeros((n, k), jnp.int32),
    )
    (vals, idx), _ = jax.lax.scan(blk, init, jnp.arange(n_blocks))
    return vals, idx


@partial(jax.jit, static_argnames=("budget",))
def greedy_fl_topk(vals: jax.Array, idx: jax.Array, budget: int) -> FLResult:
    """Exact greedy over the *sparsified* FL objective, pure JAX.

    Maximizes F̂(S) = Σ_i max(max_{j∈S∩nbr(i)} ŝ_ij, 0) where ŝ is the top-k
    graph.  Per step, every entry (i, j) contributes relu(ŝ_ij − cur_max_i)
    to candidate j's gain via one (n, k) scatter-add — O(n·k) per step,
    O(r·n·k) total, no dense structure.  jit- and shard_map-compatible
    (used by the sparse round-1 of ``core.distributed``).

    Weights are graph-assigned (each point to its best selected neighbor;
    points whose neighbor list contains no selected element fall back to the
    first medoid).  Callers holding features can recompute exact γ with
    ``assign_and_weights``; Σγ == n either way.
    """
    n, k = vals.shape
    vals = vals.astype(jnp.float32)
    budget = int(min(budget, n))

    def step(state, _):
        cur_max, chosen = state
        contrib = jnp.maximum(vals - cur_max[:, None], 0.0)  # (n, k)
        gains = jnp.zeros((n,), jnp.float32).at[idx].add(contrib)
        gains = jnp.where(chosen, -jnp.inf, gains)
        e = jnp.argmax(gains)
        # cover update: rows that list e as a neighbor take max(cur, ŝ_ie)
        cov = jnp.max(jnp.where(idx == e, vals, -jnp.inf), axis=1)
        return (jnp.maximum(cur_max, cov), chosen.at[e].set(True)), (
            e.astype(jnp.int32),
            gains[e],
        )

    init = (jnp.zeros((n,), jnp.float32), jnp.zeros((n,), bool))
    (cur_max, chosen), (indices, gains) = jax.lax.scan(
        step, init, None, length=budget
    )

    # Graph-based γ: best selected neighbor per row.
    ent_sel = chosen[idx]  # (n, k)
    best = jnp.where(ent_sel, vals, -jnp.inf)
    bpos = jnp.argmax(best, axis=1)
    assigned = jnp.take_along_axis(idx, bpos[:, None], axis=1)[:, 0]
    orphan = ~jnp.isfinite(jnp.max(best, axis=1))
    assigned = jnp.where(orphan, indices[0], assigned)
    slot = jnp.zeros((n,), jnp.int32).at[indices].set(
        jnp.arange(budget, dtype=jnp.int32)
    )[assigned]
    weights = jnp.zeros((budget,), jnp.float32).at[slot].add(1.0)
    # Residual un-covered similarity mass, same convention as the dense
    # engines (callers with features recompute true L(S) via distances).
    coverage = jnp.sum(jnp.maximum(vals[:, 0] - cur_max, 0.0))
    return FLResult(indices, gains.astype(jnp.float32), weights, coverage)


def sparse_greedy_fl(
    vals: np.ndarray,
    idx: np.ndarray,
    budget: int,
    feats: np.ndarray | None = None,
    init_selected: np.ndarray | None = None,
) -> FLResult:
    """Host lazy greedy (Minoux) over the top-k graph, walking CSR columns.

    The (n, k) row structure is transposed once into a CSC layout — for each
    candidate c, the rows that list c as a neighbor — so a gain evaluation
    touches only that candidate's column (apricot's ``select_next_sparse``,
    vectorized over the column instead of a numba scalar loop).  With the
    Minoux priority queue most candidates are never re-evaluated; per-step
    cost is O(nnz/n · re-evals) instead of O(n²).

    Selections are identical to ``greedy_fl_topk`` (same objective, ties to
    the lowest index).  If ``feats`` is given, γ weights and coverage are
    computed by *exact* blocked assignment of every point to its nearest
    selected medoid (O(n·r), no (n, n)); otherwise graph assignment is used
    and coverage is the residual similarity mass.  ``init_selected``
    warm-starts from a previous selection's prefix — each prefix element
    costs one CSR-column walk, and the heap is initialized against the
    warmed cover state.
    """
    vals = np.asarray(vals, np.float64)
    idx = np.asarray(idx, np.int64)
    n, k = vals.shape
    budget = int(min(budget, n))

    # CSC transpose: entries sorted by candidate column.
    flat_v = vals.ravel()
    flat_c = idx.ravel()
    flat_r = np.repeat(np.arange(n, dtype=np.int64), k)
    valid = flat_v > -1e29  # drop builder padding
    flat_v, flat_c, flat_r = flat_v[valid], flat_c[valid], flat_r[valid]
    order = np.argsort(flat_c, kind="stable")
    col_vals = flat_v[order]
    col_rows = flat_r[order]
    sorted_c = flat_c[order]
    indptr = np.searchsorted(sorted_c, np.arange(n + 1))

    cur_max = np.zeros(n)
    indices: list[int] = []
    gains: list[float] = []
    if init_selected is not None:
        for c in np.asarray(init_selected, np.int64)[:budget]:
            c = int(c)
            lo, hi = indptr[c], indptr[c + 1]
            indices.append(c)
            gains.append(
                float(
                    np.maximum(
                        col_vals[lo:hi] - cur_max[col_rows[lo:hi]], 0.0
                    ).sum()
                )
            )
            np.maximum.at(cur_max, col_rows[lo:hi], col_vals[lo:hi])
    r0 = len(indices)
    in_init = set(indices)
    init_gain = np.zeros(n)
    np.add.at(
        init_gain, sorted_c, np.maximum(col_vals - cur_max[col_rows], 0.0)
    )
    heap = [(-g, c, r0) for c, g in enumerate(init_gain) if c not in in_init]
    heapq.heapify(heap)
    for t in range(r0, budget):
        while True:
            neg_g, c, stamp = heapq.heappop(heap)
            if stamp == t:
                break
            lo, hi = indptr[c], indptr[c + 1]
            g = float(
                np.maximum(col_vals[lo:hi] - cur_max[col_rows[lo:hi]], 0.0).sum()
            )
            heapq.heappush(heap, (-g, c, t))
        indices.append(c)
        gains.append(-neg_g)
        lo, hi = indptr[c], indptr[c + 1]
        np.maximum.at(cur_max, col_rows[lo:hi], col_vals[lo:hi])

    sel = np.array(indices, np.int64)
    if feats is not None:
        assign, mind = _blocked_assignment(np.asarray(feats), sel)
        weights = np.bincount(assign, minlength=budget).astype(np.float32)
        coverage = float(mind.sum())  # true L(S) = Σ_i min_{j∈S} d_ij
    else:
        in_sel = np.zeros(n, bool)
        in_sel[sel] = True
        slot_of = np.zeros(n, np.int64)
        slot_of[sel] = np.arange(budget)
        masked = np.where(in_sel[idx] & (vals > -1e29), vals, -np.inf)
        rows_hit = masked.max(axis=1) > -np.inf
        best_c = np.full(n, sel[0], np.int64)  # orphans → first medoid
        best_c[rows_hit] = idx[np.arange(n), masked.argmax(axis=1)][rows_hit]
        weights = np.bincount(slot_of[best_c], minlength=budget).astype(
            np.float32
        )
        coverage = float(np.maximum(vals[:, 0] - cur_max, 0.0).sum())
    return FLResult(
        jnp.asarray(sel.astype(np.int32)),
        jnp.asarray(np.array(gains, np.float32)),
        jnp.asarray(weights),
        jnp.asarray(coverage, jnp.float32),
    )


def _blocked_assignment(
    feats: np.ndarray, sel: np.ndarray, block: int = 65536
) -> tuple[np.ndarray, np.ndarray]:
    """Exact nearest-selected-medoid assignment, O(block·r) peak memory.

    Returns (assign (n,) positions into sel, min_dist (n,)).
    """
    feats = np.asarray(feats, np.float32)
    sf = feats[sel]  # (r, d)
    sq_s = (sf * sf).sum(axis=1)
    assign = np.empty(len(feats), np.int64)
    mind = np.empty(len(feats), np.float64)
    for lo in range(0, len(feats), block):
        chunk = feats[lo : lo + block]
        d2 = (
            (chunk * chunk).sum(axis=1)[:, None]
            + sq_s[None, :]
            - 2.0 * chunk @ sf.T
        )
        d2 = np.maximum(d2, 0.0)
        assign[lo : lo + block] = d2.argmin(axis=1)
        mind[lo : lo + block] = np.sqrt(d2.min(axis=1))
    return assign, mind


def sparse_greedy_fl_features(
    feats: jax.Array,
    budget: int,
    *,
    k: int = 64,
    d_max: jax.Array | None = None,
    topk_impl: str = "jax",
    block_m: int = 2048,
    init_selected: np.ndarray | None = None,
) -> FLResult:
    """End-to-end sparse engine: top-k graph build + host lazy greedy.

    O(n·k + n·block_m) peak memory — the production path for pools past the
    dense engines' ~10⁵-point ceiling.  Exact γ/coverage via blocked
    assignment (the ``feats`` are already in hand).
    """
    vals, idx = topk_graph(
        feats, k, d_max=d_max, block_m=block_m, impl=topk_impl
    )
    return sparse_greedy_fl(
        np.asarray(vals),
        np.asarray(idx),
        budget,
        feats=np.asarray(feats),
        init_selected=init_selected,
    )


def assign_and_weights(dist_to_sel: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Given (n, r) distances to selected medoids, return (assignment, γ)."""
    assign = jnp.argmin(dist_to_sel, axis=1)
    r = dist_to_sel.shape[1]
    weights = jnp.zeros((r,), jnp.float32).at[assign].add(1.0)
    return assign, weights
