"""Compatibility façade: the greedy engines moved to ``repro.core.engines``.

This module used to hold every greedy facility-location maximizer in one
~1000-line file.  PR 4 split it into the ``repro.core.engines`` package —
one module per engine behind the ``SelectionEngine`` protocol, a
capability-driven registry, and typed per-engine configs (DESIGN.md §3).
The functional API is unchanged and re-exported here so existing imports
(``from repro.core import facility_location as fl``) keep working:

* ``greedy_fl_matrix``      — engines.matrix (§3.1): exact greedy over a
                              dense similarity matrix, pure JAX.
* ``lazy_greedy_fl``        — engines.lazy (§3.2): host-side Minoux lazy
                              greedy; oracle + large-n CPU path.
* ``stochastic_greedy_fl``  — engines.stochastic (§3.3): the paper's
                              O(|V|) fast path.
* ``greedy_fl_features``    — engines.features (§3.4): matrix-free blocked
                              greedy (Pallas ``fl_gains`` on TPU).
* ``topk_graph`` / ``greedy_fl_topk`` / ``sparse_greedy_fl`` /
  ``sparse_greedy_fl_features`` — engines.sparse (§3.5): the O(n·k)
                              million-point engine.
* ``greedy_fl_device``      — engines.device (§3.6): device-resident fused
                              greedy (one ``fl_gains_argmax`` launch per
                              sweep, Minoux-bound block greedy at q > 1).
* ``init_streaming_state`` / ``ingest_delta`` / ``streaming_result``
                            — engines.streaming (§10): one-pass
                              sieve-streaming over arriving deltas.

New code should prefer the typed surface — ``repro.core.engines``'s
``EngineConfig`` subclasses, ``get_engine``/``list_engines``, and
``CraigConfig(engine=SparseConfig(k=64))`` — over these raw functions;
see README §Engines for the protocol and the migration guide.

Warm starts: every engine accepts ``init_selected`` — a prefix of medoids
installed before greedy resumes; the prefix's cover state is replayed in
O(r₀·n), and exact greedy's prefix consistency makes warm == cold on
unchanged features (DESIGN.md §4).
"""
from repro.core.engines.base import (
    FLResult,
    assign_and_weights,
    coverage_l,
    facility_location_value,
)
from repro.core.engines.device import greedy_fl_device
from repro.core.engines.features import greedy_fl_features
from repro.core.engines.lazy import lazy_greedy_fl
from repro.core.engines.matrix import greedy_fl_matrix
from repro.core.engines.sparse import (
    greedy_fl_topk,
    sparse_greedy_fl,
    sparse_greedy_fl_features,
    topk_graph,
)
from repro.core.engines.stochastic import stochastic_greedy_fl
from repro.core.engines.streaming import (
    StreamingState,
    init_streaming_state,
    ingest_delta,
    streaming_result,
)

__all__ = [
    "FLResult",
    "facility_location_value",
    "coverage_l",
    "greedy_fl_matrix",
    "lazy_greedy_fl",
    "stochastic_greedy_fl",
    "greedy_fl_features",
    "greedy_fl_device",
    "topk_graph",
    "greedy_fl_topk",
    "sparse_greedy_fl",
    "sparse_greedy_fl_features",
    "assign_and_weights",
    "StreamingState",
    "init_streaming_state",
    "ingest_delta",
    "streaming_result",
]
