"""xlstm-1.3b — sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM).

[arXiv:2405.04517; unverified] 48L d_model=2048 4H d_ff=0 (projections live
inside the xLSTM cells) vocab=50304.  48 = 6 full (7×mlstm, 1×slstm)
periods.  Fully recurrent → long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    d_head=512,  # inner dim = n_heads·d_head = d_model
    mlstm_chunk=256,
    source="arXiv:2405.04517 (xLSTM)",
)
