"""qwen2-7b — dense GQA with QKV bias.

[arXiv:2407.10671; hf] 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064, SwiGLU, QKV bias, rope theta 1e6.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18_944,
    vocab_size=152_064,
    qkv_bias=True,
    activation="silu",
    rope_theta=1e6,
    source="arXiv:2407.10671 / Qwen/Qwen2-7B",
)
