"""qwen3-1.7b — dense GQA with qk-norm.

[hf:Qwen/Qwen3-1.7B; hf] 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936, SwiGLU, qk_norm, head_dim=128, rope theta 1e6.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=6144,
    vocab_size=151_936,
    qk_norm=True,
    activation="silu",
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-1.7B",
)
