"""nemotron-4-15b — dense GQA with squared-ReLU FFN (no gating).

[arXiv:2402.16819; unverified] 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000, squared-ReLU.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24_576,
    vocab_size=256_000,
    activation="relu2",
    gated_ffn=False,
    rope_theta=10_000.0,
    source="arXiv:2402.16819 (Nemotron-4)",
)
