"""recurrentgemma-9b — Griffin: RG-LRU + local attention, 1:2 pattern.

[arXiv:2402.19427; unverified] 38L d_model=4096 16H (GQA kv=1, i.e. MQA on
the attention layers) d_ff=12288 vocab=256000, local window 2048.
38 = 12 full (rglru, rglru, local_attn) periods + 2 remainder rglru layers.
Sub-quadratic (no global attention) → long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "local_attn"),
    window=2048,
    d_rnn=4096,
    activation="gelu",  # GeGLU
    gated_ffn=True,
    rope_theta=10_000.0,
    source="arXiv:2402.19427 (Griffin) / google/recurrentgemma-9b",
)
