"""qwen2-vl-7b — Qwen2-7B backbone with M-RoPE; vision frontend stubbed.

[arXiv:2409.12191; hf] 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064; M-RoPE sections (16, 24, 24) over the 64 rotary slots;
dynamic-resolution ViT frontend is a STUB — ``input_specs`` provides
precomputed patch/token embeddings (B, T, d_model) and (B, 3, T) position
ids.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18_944,
    vocab_size=152_064,
    qkv_bias=True,
    activation="silu",
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    frontend="embeddings",
    source="arXiv:2409.12191 / Qwen/Qwen2-VL-7B",
)
