"""dbrx-132b — MoE, 16 experts top-4 (fine-grained).

[hf:databricks/dbrx-base; unverified] 40L d_model=6144 48H (GQA kv=8)
d_ff=10752 per expert, vocab=100352, 16 experts top-4.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10_752,
    vocab_size=100_352,
    n_experts=16,
    top_k=4,
    capacity_factor=1.0,
    activation="silu",
    blockwise_threshold=2048,
    rope_theta=500_000.0,
    source="hf:databricks/dbrx-base",
)
