"""Architecture configs (one module per assigned arch) + shapes + registry."""
from repro.configs.shapes import SHAPES, ShapeSpec

__all__ = ["SHAPES", "ShapeSpec"]
