"""moonshot-v1-16b-a3b — fine-grained MoE (Moonlight), 64 experts top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf] 48L d_model=2048 16H (MHA kv=16)
d_ff=1408 per expert, vocab=163840, 64 experts top-6 + 2 shared experts
(DeepSeek-V2-style fine-grained + shared).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=163_840,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    capacity_factor=1.25,
    activation="silu",
    source="hf:moonshotai/Moonlight-16B-A3B",
)
