"""Assigned input-shape set (identical across the 10 LM-family archs).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a KV
cache / recurrent state of length seq_len), NOT ``train_step``.
``long_500k`` requires sub-quadratic attention — only archs whose every layer
is non-global-attention run it (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses

__all__ = ["ShapeSpec", "SHAPES"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.global_batch * self.seq_len


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}
