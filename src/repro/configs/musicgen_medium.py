"""musicgen-medium — decoder-only LM over EnCodec tokens.

[arXiv:2306.05284; hf] 48L d_model=1536 24H (MHA, kv=24) d_ff=6144
vocab=2048 (per codebook), 4 codebooks with parallel heads (delay pattern's
per-stream heads).  The EnCodec frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, T, d_model).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    activation="gelu",
    gated_ffn=False,
    norm="layernorm",
    frontend="embeddings",
    n_codebooks=4,
    source="arXiv:2306.05284 / facebook/musicgen-medium",
)
