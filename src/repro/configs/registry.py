"""Architecture registry: full configs + reduced smoke variants.

``get_config(arch)`` returns the published-scale config; ``smoke_config``
shrinks the same family (fewer layers, narrow width, tiny vocab, few experts)
for CPU tests — the full configs are exercised only via the dry-run.
"""
from __future__ import annotations

import dataclasses

from repro.configs import (
    dbrx_132b,
    granite_3_8b,
    moonshot_v1_16b_a3b,
    musicgen_medium,
    nemotron_4_15b,
    qwen2_7b,
    qwen2_vl_7b,
    qwen3_1_7b,
    recurrentgemma_9b,
    xlstm_1_3b,
)
from repro.models.config import ModelConfig

__all__ = ["ARCHS", "get_config", "smoke_config"]

ARCHS: dict[str, ModelConfig] = {
    c.CONFIG.name: c.CONFIG
    for c in (
        recurrentgemma_9b,
        musicgen_medium,
        xlstm_1_3b,
        granite_3_8b,
        qwen2_7b,
        qwen3_1_7b,
        nemotron_4_15b,
        moonshot_v1_16b_a3b,
        dbrx_132b,
        qwen2_vl_7b,
    )
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(ARCHS)}")
    return ARCHS[arch]


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config: one period + remainder, tiny dims."""
    full = get_config(arch)
    period = len(full.block_pattern)
    n_layers = period + min(2, period)  # ≥1 full period + remainder layers
    d_model = 64
    n_heads = min(full.n_heads, 4)
    n_kv = max(1, min(full.n_kv_heads, n_heads))
    # keep the GQA ratio flavor: MQA stays MQA, MHA stays MHA
    if full.n_kv_heads == 1:
        n_kv = 1
    elif full.n_kv_heads == full.n_heads:
        n_kv = n_heads
    else:
        n_kv = max(1, n_heads // 2)
    return dataclasses.replace(
        full,
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        d_head=d_model // n_heads if full.d_head else 0,
        d_ff=128 if full.d_ff else 0,
        vocab_size=512,
        n_experts=4 if full.n_experts else 0,
        top_k=2 if full.n_experts else 0,
        n_shared_experts=1 if full.n_shared_experts else 0,
        d_rnn=d_model if full.d_rnn else 0,
        window=8 if full.window else None,
        mrope_sections=(4, 2, 2) if full.mrope_sections else None,
        mlstm_chunk=8,
        blockwise_threshold=64,
        attn_chunk_q=16,
        attn_chunk_kv=16,
        logit_chunk=16,
    )
