"""Human and JSON reporters for analysis results."""
from __future__ import annotations

import json

from repro.analysis.engine import AnalysisResult

JSON_SCHEMA_VERSION = 1


def render_human(result: AnalysisResult, verbose: bool = False) -> str:
    """One finding per line, grep-able, suppressed ones only with -v."""
    lines = [
        f.format()
        for f in result.findings
        if verbose or not f.suppressed
    ]
    active = result.active
    summary = (
        f"{len(active)} finding(s)"
        f" ({len(result.suppressed)} suppressed)"
        if result.suppressed
        else f"{len(active)} finding(s)"
    )
    if active or verbose:
        lines.append(summary)
    else:
        lines.append(f"clean — {summary}")
    return "\n".join(lines)


def render_json(result: AnalysisResult) -> str:
    """Machine-readable report (CI uploads this as an artifact).

    Suppressed findings are included with ``"suppressed": true`` so the
    artifact is an audit trail of every exemption, not just the failures.
    """
    by_rule: dict[str, int] = {}
    for f in result.active:
        by_rule[f.rule_id] = by_rule.get(f.rule_id, 0) + 1
    doc = {
        "schema": JSON_SCHEMA_VERSION,
        "findings": [f.to_dict() for f in result.findings],
        "counts": {
            "active": len(result.active),
            "suppressed": len(result.suppressed),
            "by_rule": by_rule,
        },
        "exit_code": result.exit_code,
    }
    return json.dumps(doc, indent=2, sort_keys=True)
