"""Narrow inline suppressions: ``# repro-lint: disable=RULE  # reason``.

A suppression silences findings of the named rule(s) on *its own line
only* — there is no file- or block-scope form, so a suppression can never
hide a regression introduced ten lines below it.  The trailing ``# reason``
is mandatory: a suppression without one is itself a finding
(``suppression-missing-reason``), because "why is this line exempt" is
exactly the information the next reader needs.

Syntax::

    risky_call()  # repro-lint: disable=jit-host-sync  # finalize runs on host

    two()  # repro-lint: disable=rule-a,rule-b  # one reason covers both
"""
from __future__ import annotations

import dataclasses
import re

from repro.analysis.findings import Finding

_PRAGMA = re.compile(
    r"#\s*repro-lint:\s*disable=(?P<rules>[A-Za-z0-9_,\-\s]+?)"
    r"(?:\s*#\s*(?P<reason>.*\S))?\s*$"
)
# A line is a pragma *candidate* only when 'repro-lint' appears after a
# comment hash; prose that merely mentions the tool is not a pragma.
_CANDIDATE = re.compile(r"#\s*repro-lint\b")

SUPPRESSION_RULE_ID = "suppression-missing-reason"


@dataclasses.dataclass(frozen=True)
class Suppression:
    line: int
    rules: frozenset[str]
    reason: str


def parse_suppressions(
    path: str, source_lines: list[str]
) -> tuple[dict[int, frozenset[str]], list[Finding]]:
    """Scan a file's lines for suppression pragmas.

    Returns ({line: rule_ids}, findings) where findings are the malformed
    pragmas (missing reason / empty rule list) — these are ordinary
    error-severity findings, so an unjustified suppression fails the gate
    it was trying to dodge.
    """
    by_line: dict[int, frozenset[str]] = {}
    findings: list[Finding] = []
    for lineno, text in enumerate(source_lines, start=1):
        if _CANDIDATE.search(text) is None:
            continue
        m = _PRAGMA.search(text)
        if m is None:
            findings.append(
                Finding(
                    path,
                    lineno,
                    SUPPRESSION_RULE_ID,
                    "malformed repro-lint pragma (want "
                    "'# repro-lint: disable=RULE  # reason')",
                )
            )
            continue
        rules = frozenset(
            r.strip() for r in m.group("rules").split(",") if r.strip()
        )
        reason = (m.group("reason") or "").strip()
        if not rules:
            findings.append(
                Finding(
                    path, lineno, SUPPRESSION_RULE_ID,
                    "suppression names no rules",
                )
            )
            continue
        if not reason:
            findings.append(
                Finding(
                    path,
                    lineno,
                    SUPPRESSION_RULE_ID,
                    "suppression has no justification; append "
                    "'# <reason>' after the rule list",
                )
            )
            continue
        by_line[lineno] = by_line.get(lineno, frozenset()) | rules
    return by_line, findings


def apply_suppressions(
    findings: list[Finding], by_path: dict[str, dict[int, frozenset[str]]]
) -> list[Finding]:
    """Mark findings whose (path, line) carries a matching pragma."""
    out = []
    for f in findings:
        rules = by_path.get(f.path, {}).get(f.line)
        if rules is not None and f.rule_id in rules:
            f = dataclasses.replace(f, suppressed=True)
        out.append(f)
    return out
