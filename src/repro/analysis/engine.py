"""Rule protocol and the analysis runner.

A ``Rule`` sees the whole :class:`~repro.analysis.index.FileIndex` (the
jit-safety pass needs cross-module reachability; per-file rules just loop
over ``index.modules``) and yields :class:`Finding`s.  The runner owns the
lifecycle: build index once → run every selected rule → apply inline
suppressions → fold in parse/pragma findings.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable

from repro.analysis.findings import Finding
from repro.analysis.index import FileIndex
from repro.analysis.suppress import apply_suppressions


class Rule:
    """One named check.  Subclasses set ``rule_ids`` (every id they may
    emit — the ``--rules`` filter and ``--list-rules`` read it) and
    implement :meth:`run`."""

    rule_ids: tuple[str, ...] = ()
    description: str = ""

    def run(self, index: FileIndex) -> Iterable[Finding]:
        raise NotImplementedError


def all_rules() -> list[Rule]:
    """The registered pass instances, in documentation order."""
    from repro.analysis.rules import ALL_RULES

    return [cls() for cls in ALL_RULES]


@dataclasses.dataclass
class AnalysisResult:
    findings: list[Finding]  # every finding, suppressed ones flagged

    @property
    def active(self) -> list[Finding]:
        return [f for f in self.findings if not f.suppressed]

    @property
    def suppressed(self) -> list[Finding]:
        return [f for f in self.findings if f.suppressed]

    @property
    def exit_code(self) -> int:
        return 1 if self.active else 0


def run_analysis(
    paths: list[str],
    rules: list[Rule] | None = None,
    rule_filter: set[str] | None = None,
) -> AnalysisResult:
    """Parse ``paths``, run the passes, apply suppressions.

    Args:
      paths: files/directories to analyze (directories recurse over *.py).
      rules: pass instances; defaults to :func:`all_rules`.
      rule_filter: when set, keep only findings whose rule_id is in it
        (parse errors and malformed pragmas always survive the filter —
        they mean the analysis itself is compromised).
    """
    index = FileIndex.build(paths)
    if rules is None:
        rules = all_rules()
    findings: list[Finding] = []
    for rule in rules:
        findings.extend(rule.run(index))
    if rule_filter is not None:
        findings = [f for f in findings if f.rule_id in rule_filter]
    findings.extend(index.parse_findings)
    findings.extend(index.pragma_findings)
    by_path = {m.path: m.suppressions for m in index.modules}
    findings = apply_suppressions(findings, by_path)
    findings.sort(key=lambda f: (f.path, f.line, f.rule_id))
    return AnalysisResult(findings=findings)
