"""Structured lint findings: what every rule emits and every reporter reads."""
from __future__ import annotations

import dataclasses

SEVERITIES = ("error", "warning")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One diagnostic, anchored to a source line.

    Attributes:
      path: file path as given to the runner (repo-relative in CI).
      line: 1-based line of the offending node (suppressions match here).
      rule_id: stable kebab-case id (``--rules`` filter / ``disable=`` key).
      message: human sentence; says what is wrong *and* which invariant it
        breaks, since the reader is usually three call frames from the
        context that makes the line a bug.
      severity: 'error' | 'warning'.  Both fail the CI gate unsuppressed;
        the split exists so downstream tooling can triage.
      suppressed: True once an inline suppression matched (kept in the
        JSON report for auditability; excluded from the exit-code count).
    """

    path: str
    line: int
    rule_id: str
    message: str
    severity: str = "error"
    suppressed: bool = False

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r} not in {SEVERITIES}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def format(self) -> str:
        tag = " [suppressed]" if self.suppressed else ""
        return (
            f"{self.path}:{self.line}: {self.severity}: "
            f"[{self.rule_id}] {self.message}{tag}"
        )
