"""Command-line entry point: ``python -m repro.analysis [paths...]``.

Exit codes: 0 = clean (suppressed findings allowed), 1 = unsuppressed
findings, 2 = usage/configuration error.  ``--format json`` emits the
machine-readable report CI uploads as an artifact.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import Sequence

from repro.analysis.engine import all_rules, run_analysis
from repro.analysis.report import render_human, render_json

EXIT_CLEAN = 0
EXIT_FINDINGS = 1
EXIT_USAGE = 2


def build_parser() -> argparse.ArgumentParser:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description=(
            "repro-lint: project-specific static analysis (jit-safety, "
            "Pallas contracts, concurrency discipline, API hygiene)"
        ),
    )
    parser.add_argument(
        "paths",
        nargs="*",
        default=["src"],
        help="files or directories to analyze (default: src)",
    )
    parser.add_argument(
        "--format",
        choices=("human", "json"),
        default="human",
        help="report format (default: human)",
    )
    parser.add_argument(
        "--rules",
        default=None,
        help="comma-separated rule_id filter (e.g. flat-engine-knob)",
    )
    parser.add_argument(
        "--list-rules",
        action="store_true",
        help="list every rule_id with its description and exit",
    )
    parser.add_argument(
        "--verbose",
        action="store_true",
        help="also show suppressed findings in human output",
    )
    return parser


def _known_rule_ids() -> dict[str, str]:
    out: dict[str, str] = {}
    for rule in all_rules():
        for rid in rule.rule_ids:
            out[rid] = rule.description
    return out


def main(argv: Sequence[str] | None = None) -> int:
    parser = build_parser()
    try:
        args = parser.parse_args(argv)
    except SystemExit as e:  # argparse exits 2 on bad flags already
        return EXIT_USAGE if e.code not in (0, None) else EXIT_CLEAN

    known = _known_rule_ids()
    if args.list_rules:
        for rid in sorted(known):
            print(f"{rid}: {known[rid]}")
        return EXIT_CLEAN

    rule_filter = None
    if args.rules:
        rule_filter = frozenset(
            r.strip() for r in args.rules.split(",") if r.strip()
        )
        unknown = rule_filter - set(known)
        if unknown:
            print(
                f"error: unknown rule id(s): {', '.join(sorted(unknown))} "
                f"(--list-rules shows all)",
                file=sys.stderr,
            )
            return EXIT_USAGE

    missing = [p for p in args.paths if not Path(p).exists()]
    if missing:
        print(
            f"error: no such file or directory: {', '.join(missing)}",
            file=sys.stderr,
        )
        return EXIT_USAGE

    try:
        result = run_analysis(args.paths, rule_filter=rule_filter)
    except (OSError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return EXIT_USAGE

    if args.format == "json":
        print(render_json(result))
    else:
        print(render_human(result, verbose=args.verbose))
    return EXIT_FINDINGS if result.active else EXIT_CLEAN


if __name__ == "__main__":  # pragma: no cover - exercised via __main__.py
    sys.exit(main())
