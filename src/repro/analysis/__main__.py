"""``python -m repro.analysis`` — run the repro-lint CLI."""
import sys

from repro.analysis.cli import main

sys.exit(main())
