"""Pass 2 — Pallas contract: every ``pl.pallas_call`` site self-consistent.

A mis-tiled ``pallas_call`` rarely fails loudly: an index_map whose arity
silently zips against the wrong grid axis, a kernel signature drifting out
of sync with its specs after an edit, or a bf16 accumulator all produce
*numbers* — wrong or slow ones — and CPU interpret-mode CI (DESIGN.md §8)
can't catch what only manifests as TPU-tile misalignment.  These are
checkable statically because the repo's kernels follow one shape
(kernels/*.py: literal ``grid=`` tuples, list-literal specs, lambda index
maps), so the pass enforces:

  * ``pallas-index-map-arity`` — each BlockSpec index_map lambda takes
    exactly grid-rank arguments, and returns a tuple of the block shape's
    rank;
  * ``pallas-kernel-arity`` — kernel positional parameters ==
    len(in_specs) + #outputs + len(scratch_shapes) (refs arrive in that
    order; ``functools.partial``-bound keywords and factory closures are
    resolved first);
  * ``pallas-accumulator-dtype`` — no bf16/fp16 ``ShapeDtypeStruct``
    outputs or VMEM scratch: tiles may be bf16, but running accumulators
    stay fp32 (the ``fl_gains``/``ce_proxy`` discipline, DESIGN.md §9);
  * ``pallas-dot-preferred-type`` — every ``dot_general``/``pl.dot``
    inside a kernel body passes ``preferred_element_type`` resolving to
    fp32, so MXU matmuls accumulate fp32 even on bf16 tiles.

Sites that don't match the recognized shape (computed spec lists, grids
the index can't resolve) are skipped, not guessed at.
"""
from __future__ import annotations

import ast
from typing import Iterable, Iterator

from repro.analysis.engine import Rule
from repro.analysis.findings import Finding
from repro.analysis.index import FileIndex, ModuleInfo, resolve_callable

INDEX_MAP_RULE = "pallas-index-map-arity"
KERNEL_ARITY_RULE = "pallas-kernel-arity"
ACCUM_DTYPE_RULE = "pallas-accumulator-dtype"
DOT_PREFERRED_RULE = "pallas-dot-preferred-type"

_PALLAS_CALL = "jax.experimental.pallas.pallas_call"
_BLOCKSPEC_SUFFIX = "BlockSpec"
_LOW_PRECISION = frozenset({"bfloat16", "float16"})
_DOT_CALLS = frozenset(
    {
        "jax.lax.dot_general",
        "jax.lax.dot",
        "jax.numpy.dot",
        "jax.numpy.matmul",
        "jax.numpy.einsum",
        "jax.experimental.pallas.dot",
    }
)


class PallasContractRule(Rule):
    rule_ids = (
        INDEX_MAP_RULE,
        KERNEL_ARITY_RULE,
        ACCUM_DTYPE_RULE,
        DOT_PREFERRED_RULE,
    )
    description = (
        "pallas_call sites: index_map arity vs grid rank, kernel signature "
        "vs BlockSpec/scratch counts, fp32 accumulators on bf16 tiles"
    )

    def run(self, index: FileIndex) -> Iterable[Finding]:
        findings: list[Finding] = []
        for mod in index.modules:
            for node in ast.walk(mod.tree):
                if (
                    isinstance(node, ast.Call)
                    and mod.qualify(node.func) == _PALLAS_CALL
                ):
                    findings.extend(_check_site(index, mod, node))
        return findings


def _check_site(
    index: FileIndex, mod: ModuleInfo, call: ast.Call
) -> Iterator[Finding]:
    kwargs = {kw.arg: kw.value for kw in call.keywords if kw.arg}
    grid_rank = _grid_rank(mod, kwargs.get("grid"), call)

    in_specs = _as_list(mod, kwargs.get("in_specs"), call)
    out_specs = _as_list(mod, kwargs.get("out_specs"), call)
    scratch = _as_list(mod, kwargs.get("scratch_shapes"), call)
    out_shape = _as_list(mod, kwargs.get("out_shape"), call)

    # --- index_map arity / return rank per BlockSpec -----------------------
    for spec in (in_specs or []) + (out_specs or []):
        yield from _check_blockspec(mod, spec, grid_rank)

    # --- kernel signature vs spec counts -----------------------------------
    # Skip (don't guess) when any count-bearing kwarg is present but its
    # value couldn't be resolved to a literal list.
    unresolved = any(
        kwargs.get(k) is not None and v is None
        for k, v in (
            ("in_specs", in_specs),
            ("out_specs", out_specs),
            ("scratch_shapes", scratch),
            ("out_shape", out_shape),
        )
    )
    if in_specs is not None and call.args and not unresolved:
        n_outs = (
            len(out_specs)
            if out_specs is not None
            else (len(out_shape) if out_shape is not None else 1)
        )
        n_scratch = len(scratch) if scratch is not None else 0
        expected = len(in_specs) + n_outs + n_scratch
        resolved = resolve_callable(index, mod, call.args[0], call)
        if resolved is not None:
            kmod, kdef = resolved
            got = _positional_arity(kdef)
            if got is not None and got != expected:
                yield Finding(
                    mod.path,
                    call.lineno,
                    KERNEL_ARITY_RULE,
                    f"kernel '{_kernel_name(kdef)}' takes {got} positional "
                    f"ref(s) but specs imply {expected} "
                    f"({len(in_specs)} in + {n_outs} out + {n_scratch} "
                    "scratch); refs arrive in exactly that order",
                )

    # --- accumulator dtypes -------------------------------------------------
    for struct in out_shape or []:
        yield from _check_struct_dtype(
            mod, struct, "out_shape output", ACCUM_DTYPE_RULE
        )
    for buf in scratch or []:
        yield from _check_struct_dtype(
            mod, buf, "VMEM scratch buffer", ACCUM_DTYPE_RULE
        )

    # --- dot precision inside the kernel ------------------------------------
    if call.args:
        resolved = resolve_callable(index, mod, call.args[0], call)
        if resolved is not None:
            kmod, kdef = resolved
            for dnode in ast.walk(kdef):
                if not isinstance(dnode, ast.Call):
                    continue
                fq = kmod.qualify(dnode.func)
                if fq not in _DOT_CALLS:
                    continue
                pref = next(
                    (
                        kw.value
                        for kw in dnode.keywords
                        if kw.arg == "preferred_element_type"
                    ),
                    None,
                )
                if pref is None:
                    yield Finding(
                        kmod.path,
                        dnode.lineno,
                        DOT_PREFERRED_RULE,
                        f"{fq.rpartition('.')[2]} in kernel "
                        f"'{_kernel_name(kdef)}' has no "
                        "preferred_element_type; bf16 tiles would "
                        "accumulate in bf16 on the MXU — pass "
                        "preferred_element_type=jnp.float32",
                    )
                else:
                    pq = kmod.qualify(pref) or ""
                    if pq.rpartition(".")[2] in _LOW_PRECISION:
                        yield Finding(
                            kmod.path,
                            dnode.lineno,
                            DOT_PREFERRED_RULE,
                            "preferred_element_type is low-precision; "
                            "accumulate fp32 (DESIGN.md §9 discipline)",
                        )


def _check_blockspec(
    mod: ModuleInfo, spec: ast.AST, grid_rank: int | None
) -> Iterator[Finding]:
    if not isinstance(spec, ast.Call):
        return
    fq = mod.qualify(spec.func) or ""
    if not fq.endswith(_BLOCKSPEC_SUFFIX):
        return
    shape = spec.args[0] if spec.args else None
    imap = None
    if len(spec.args) > 1:
        imap = spec.args[1]
    for kw in spec.keywords:
        if kw.arg == "index_map":
            imap = kw.value
    if not isinstance(imap, ast.Lambda):
        return
    arity = len(imap.args.args)
    if grid_rank is not None and arity != grid_rank:
        yield Finding(
            mod.path,
            imap.lineno,
            INDEX_MAP_RULE,
            f"index_map takes {arity} argument(s) but the grid has rank "
            f"{grid_rank}; each lambda parameter is one grid axis",
        )
    if isinstance(shape, ast.Tuple) and isinstance(imap.body, ast.Tuple):
        if len(imap.body.elts) != len(shape.elts):
            yield Finding(
                mod.path,
                imap.lineno,
                INDEX_MAP_RULE,
                f"index_map returns {len(imap.body.elts)} coordinate(s) "
                f"for a rank-{len(shape.elts)} block shape",
            )


def _check_struct_dtype(
    mod: ModuleInfo, node: ast.AST, what: str, rule_id: str
) -> Iterator[Finding]:
    """Flag bf16/fp16 dtypes on ShapeDtypeStruct / pltpu.VMEM constructors."""
    if not isinstance(node, ast.Call):
        return
    dtype = None
    if len(node.args) >= 2:
        dtype = node.args[1]
    for kw in node.keywords:
        if kw.arg == "dtype":
            dtype = kw.value
    if dtype is None:
        return
    dq = (mod.qualify(dtype) or "").rpartition(".")[2]
    if dq in _LOW_PRECISION:
        yield Finding(
            mod.path,
            node.lineno,
            rule_id,
            f"{what} is {dq}: accumulators must stay fp32 even when "
            "feature tiles are bf16 (fl_gains/ce_proxy discipline)",
        )


def _grid_rank(
    mod: ModuleInfo, grid: ast.AST | None, scope: ast.AST
) -> int | None:
    if grid is None:
        return None
    if isinstance(grid, ast.Name):
        grid = mod.resolve_local(grid.id, scope)
    if isinstance(grid, ast.Tuple):
        return len(grid.elts)
    if isinstance(grid, ast.Constant) and isinstance(grid.value, int):
        return 1
    return None


def _as_list(
    mod: ModuleInfo, node: ast.AST | None, scope: ast.AST
) -> list[ast.AST] | None:
    """Literal list/tuple → elements; single expression → [it]; a local
    name is first resolved to its binding; other shapes → None (unknown)."""
    if isinstance(node, ast.Name):
        node = mod.resolve_local(node.id, scope)
    if node is None:
        return None
    if isinstance(node, (ast.List, ast.Tuple)):
        return list(node.elts)
    if isinstance(node, ast.Call):
        return [node]
    return None


def _positional_arity(fn: ast.AST) -> int | None:
    if isinstance(fn, ast.Lambda) or isinstance(
        fn, (ast.FunctionDef, ast.AsyncFunctionDef)
    ):
        a = fn.args
        if a.vararg is not None:
            return None
        return len(a.posonlyargs) + len(a.args)
    return None


def _kernel_name(fn: ast.AST) -> str:
    return getattr(fn, "name", "<lambda>")
