"""Pass 4 — API hygiene: a declarative deprecated-name / forbidden-import
table, plus the engine-registration contract.

This subsumes the old ``tests/test_no_flat_engine_knobs.py`` grep (the
flat engine knobs that the SelectionEngine redesign confined to the legacy
shim) and generalizes it: each table row is one invariant with its own
rule_id, allowlist and rationale, so the next "this name must not escape
its module" guard is a one-line entry instead of a new test file.

Checks:
  * ``flat-engine-knob`` — the legacy flat CraigConfig knobs
    (``device_q``/``topk_k``/``device_stale_tol``) appear as identifiers
    only inside ``core/engines/legacy.py``.  AST-based, so prose in
    docstrings no longer trips the guard but re-threaded kwargs do.
  * ``forbidden-import`` — ``jax.experimental.pallas`` imports stay in
    ``repro/kernels/`` (every other module goes through the ops wrappers,
    which own padding/tiling/interpret-mode policy); the legacy shim is
    imported only by its two existing consumers (``core/craig.py``,
    ``core/distributed.py``) so deprecation debt cannot quietly spread.
  * ``engine-capabilities`` — every ``SelectionEngine`` subclass in
    ``repro/core/engines/`` declares a ``capabilities = Capabilities(...)``
    class attribute and is decorated ``@register_engine``: the registry's
    capability dispatch (and the jit-safety pass above) are only sound if
    no engine bypasses registration.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Iterable, Iterator

from repro.analysis.engine import Rule
from repro.analysis.findings import Finding
from repro.analysis.index import FileIndex, ModuleInfo

DEPRECATED_NAME_RULE = "flat-engine-knob"
FORBIDDEN_IMPORT_RULE = "forbidden-import"
ENGINE_CAPS_RULE = "engine-capabilities"


@dataclasses.dataclass(frozen=True)
class DeprecatedNames:
    """Identifiers that must not appear outside their allowlisted homes."""

    rule_id: str
    names: frozenset[str]
    allow_paths: tuple[str, ...]  # path suffixes where the names are legal
    hint: str


@dataclasses.dataclass(frozen=True)
class ForbiddenImport:
    """A module (prefix) importable only from allowlisted paths."""

    module: str
    allow_paths: tuple[str, ...]
    hint: str


# The declarative rule table.  Adding a guard == adding a row.
DEPRECATED_NAME_TABLE: tuple[DeprecatedNames, ...] = (
    DeprecatedNames(
        rule_id=DEPRECATED_NAME_RULE,
        names=frozenset({"device_q", "topk_k", "device_stale_tol"}),
        allow_paths=("repro/core/engines/legacy.py",),
        hint=(
            "legacy flat engine knob; use the typed EngineConfigs from "
            "repro.core.engines (the shim maps old names once, with a "
            "DeprecationWarning)"
        ),
    ),
)

FORBIDDEN_IMPORT_TABLE: tuple[ForbiddenImport, ...] = (
    ForbiddenImport(
        module="jax.experimental.pallas",
        allow_paths=("repro/kernels/",),
        hint=(
            "Pallas stays inside repro.kernels — call the ops wrappers, "
            "which own padding, tiling and interpret-mode policy"
        ),
    ),
    ForbiddenImport(
        module="repro.core.engines.legacy",
        allow_paths=(
            "repro/core/engines/",
            "repro/core/craig.py",
            "repro/core/distributed.py",
        ),
        hint=(
            "the legacy-knob shim has exactly two consumers; new code "
            "takes typed EngineConfigs instead of resurrecting flat knobs"
        ),
    ),
)

_ENGINES_DIR = "repro/core/engines/"
_ENGINE_EXEMPT = ("base.py", "registry.py", "legacy.py", "__init__.py")


class ApiHygieneRule(Rule):
    rule_ids = (
        DEPRECATED_NAME_RULE,
        FORBIDDEN_IMPORT_RULE,
        ENGINE_CAPS_RULE,
    )
    description = (
        "deprecated-name/forbidden-import table (incl. the flat-engine-"
        "knob guard) and the engine Capabilities registration contract"
    )

    def run(self, index: FileIndex) -> Iterable[Finding]:
        findings: list[Finding] = []
        for mod in index.modules:
            findings.extend(_check_deprecated_names(mod))
            findings.extend(_check_forbidden_imports(mod))
            findings.extend(_check_engine_registration(mod))
        return findings


def _allowed(mod: ModuleInfo, allow_paths: tuple[str, ...]) -> bool:
    p = str(mod.abspath).replace("\\", "/")
    return any(a in p for a in allow_paths)


# ---------------------------------------------------------------------------
# deprecated names
# ---------------------------------------------------------------------------


def _check_deprecated_names(mod: ModuleInfo) -> Iterator[Finding]:
    for row in DEPRECATED_NAME_TABLE:
        if _allowed(mod, row.allow_paths):
            continue
        for node in ast.walk(mod.tree):
            name = _identifier_of(node)
            if name in row.names:
                yield Finding(
                    mod.path,
                    getattr(node, "lineno", 1),
                    row.rule_id,
                    f"deprecated name '{name}': {row.hint}",
                )


def _identifier_of(node: ast.AST) -> str | None:
    """Identifier-position occurrences: names, attributes, keyword args,
    function parameters and annotated fields — not docstrings/comments."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.keyword):
        return node.arg
    if isinstance(node, ast.arg):
        return node.arg
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
        return node.name
    return None


# ---------------------------------------------------------------------------
# forbidden imports
# ---------------------------------------------------------------------------


def _check_forbidden_imports(mod: ModuleInfo) -> Iterator[Finding]:
    for row in FORBIDDEN_IMPORT_TABLE:
        if _allowed(mod, row.allow_paths):
            continue
        for node in ast.walk(mod.tree):
            hit = None
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == row.module or alias.name.startswith(
                        row.module + "."
                    ):
                        hit = alias.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                if node.module == row.module or node.module.startswith(
                    row.module + "."
                ):
                    hit = node.module
                elif any(
                    f"{node.module}.{a.name}" == row.module
                    for a in node.names
                ):
                    hit = row.module
            if hit is not None:
                yield Finding(
                    mod.path,
                    node.lineno,
                    FORBIDDEN_IMPORT_RULE,
                    f"import of '{hit}' is confined to "
                    f"{', '.join(row.allow_paths)}: {row.hint}",
                )


# ---------------------------------------------------------------------------
# engine registration contract
# ---------------------------------------------------------------------------


def _check_engine_registration(mod: ModuleInfo) -> Iterator[Finding]:
    p = str(mod.abspath).replace("\\", "/")
    if _ENGINES_DIR not in p or p.endswith(_ENGINE_EXEMPT):
        return
    for cls in mod.classes.values():
        if not _subclasses_selection_engine(mod, cls):
            continue
        has_caps = any(
            isinstance(stmt, ast.Assign)
            and any(
                isinstance(t, ast.Name) and t.id == "capabilities"
                for t in stmt.targets
            )
            for stmt in cls.body
        )
        registered = any(
            (mod.qualify(dec) or "").endswith("register_engine")
            for dec in cls.decorator_list
        )
        if not has_caps:
            yield Finding(
                mod.path,
                cls.lineno,
                ENGINE_CAPS_RULE,
                f"engine {cls.name} declares no 'capabilities = "
                "Capabilities(...)'; the registry's capability dispatch "
                "(and the jit-safety pass) need it",
            )
        if not registered:
            yield Finding(
                mod.path,
                cls.lineno,
                ENGINE_CAPS_RULE,
                f"engine {cls.name} is not decorated @register_engine; "
                "unregistered engines bypass capability gating and "
                "engine='auto'",
            )


def _subclasses_selection_engine(mod: ModuleInfo, cls: ast.ClassDef) -> bool:
    return any(
        (mod.qualify(b) or "").rpartition(".")[2] == "SelectionEngine"
        for b in cls.bases
    )
