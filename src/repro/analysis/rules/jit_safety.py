"""Pass 1 — jit-safety: no host round-trips on traced hot paths.

CRAIG's device-resident speedup (DESIGN.md §3.6/§9) dies silently: a
``.item()``, an ``np.asarray``, or a Python ``if`` on an array value inside
a jitted selection loop doesn't crash — it inserts a blocking device→host
transfer per greedy round and the 2–3x engine wins quietly evaporate (or,
under ``jax.jit``, a TracerConversionError only on the code path a test
happens to execute).  This pass finds them statically, repo-wide.

Roots — functions whose bodies are traced:
  * defs decorated ``@jax.jit`` / ``@functools.partial(jax.jit, ...)``;
  * callees handed to ``jax.jit(...)``, ``lax.scan``, ``lax.while_loop``,
    ``lax.fori_loop``, ``lax.cond``, ``lax.switch``, ``lax.map``,
    ``jax.vmap`` and ``shard_map`` (resolved through local defs, lambdas
    and factories);
  * ``select`` methods of engines whose registry ``Capabilities`` declare
    ``jit_safe=True`` — the capability *is* the contract the trainer's
    zero-copy handoff relies on, so the linter holds the method to it.
    (``select_cover`` is exempt: cover mode is data-dependently sized and
    documented host-side.)

From the roots the pass walks the project call graph (same-module calls,
``self.method``, and cross-module calls resolved through imports) and
flags, anywhere reachable:

  * ``.item()`` / ``.tolist()``                — host materialization;
  * ``jax.device_get``                         — explicit transfer;
  * ``np.asarray`` / ``np.array``              — host materialization;
  * ``float()``/``int()``/``bool()`` over an expression that contains a
    jax/jnp call or an array-reduction method — concretization sync;
  * ``if``/``while``/``assert``/ternary tests containing one — Python
    control flow on a traced value.

Static-config jax calls (``jax.default_backend()`` etc.) are exempt: they
return Python scalars at trace time.
"""
from __future__ import annotations

import ast
from typing import Iterable, Iterator

from repro.analysis.engine import Rule
from repro.analysis.findings import Finding
from repro.analysis.index import FileIndex, ModuleInfo, resolve_callable

RULE_ID = "jit-host-sync"

# Call sites whose function-valued arguments are traced.
_TRACING_CALLERS = frozenset(
    {
        "jax.jit",
        "jax.vmap",
        "jax.pmap",
        "jax.lax.scan",
        "jax.lax.while_loop",
        "jax.lax.fori_loop",
        "jax.lax.cond",
        "jax.lax.switch",
        "jax.lax.map",
        "jax.lax.associative_scan",
        "jax.experimental.shard_map.shard_map",
        "jax.shard_map",
    }
)

# jax.* calls that return host scalars/objects at trace time — NOT traced
# values, so branching on them is fine.
_STATIC_JAX_CALLS = frozenset(
    {
        "jax.default_backend",
        "jax.devices",
        "jax.local_devices",
        "jax.device_count",
        "jax.local_device_count",
        "jax.process_index",
        "jax.process_count",
        "jax.dtypes.canonicalize_dtype",
        "jax.numpy.dtype",
        "jax.eval_shape",
    }
)

# Array-producing namespaces: a call into one of these yields a traced value.
_TRACED_PREFIXES = ("jax.numpy.", "jax.lax.", "jax.random.", "jax.nn.")

# Methods that reduce/convert arrays — `bool(x.any())` style.
_ARRAY_METHODS = frozenset(
    {"sum", "max", "min", "mean", "prod", "any", "all", "argmax", "argmin",
     "dot", "astype"}
)

_HOST_METHODS = frozenset({"item", "tolist"})
_HOST_CALLS = {
    "jax.device_get": "jax.device_get forces a device->host transfer",
    "numpy.asarray": "np.asarray materializes a traced value on the host",
    "numpy.array": "np.array materializes a traced value on the host",
}


class JitSafetyRule(Rule):
    rule_ids = (RULE_ID,)
    description = (
        "host round-trips (.item, np.asarray, device_get, scalar coercion, "
        "Python branching on arrays) reachable from jit/scan/while_loop "
        "roots and jit_safe=True engine select paths"
    )

    def run(self, index: FileIndex) -> Iterable[Finding]:
        roots = _collect_roots(index)
        reachable = _reachable(index, roots)
        findings: list[Finding] = []
        seen: set[tuple[str, int, str]] = set()
        for mod, fn, why in reachable:
            for f in _scan_function(mod, fn, why):
                key = (f.path, f.line, f.message)
                if key not in seen:
                    seen.add(key)
                    findings.append(f)
        return findings


# ---------------------------------------------------------------------------
# Roots
# ---------------------------------------------------------------------------


def _collect_roots(
    index: FileIndex,
) -> list[tuple[ModuleInfo, ast.AST, str]]:
    roots: list[tuple[ModuleInfo, ast.AST, str]] = []
    for mod in index.modules:
        # 1. @jax.jit-decorated defs
        for fn in mod.functions.values():
            for dec in fn.decorator_list:
                if _is_jit_decorator(mod, dec):
                    roots.append((mod, fn, f"@jax.jit {mod.qualname_of(fn)}"))
                    break
        # 2. callees of tracing transforms
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            fq = mod.qualify(node.func)
            if fq not in _TRACING_CALLERS:
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, (ast.Name, ast.Lambda)) or (
                    isinstance(arg, ast.Call)
                ):
                    hit = resolve_callable(index, mod, arg, node)
                    if hit is not None:
                        roots.append(
                            (hit[0], hit[1], f"callee of {fq.split('.')[-1]}")
                        )
        # 3. select() of jit_safe=True engines
        for cls in mod.classes.values():
            if not _declares_jit_safe(mod, cls):
                continue
            for stmt in cls.body:
                if (
                    isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
                    and stmt.name == "select"
                ):
                    roots.append(
                        (mod, stmt,
                         f"{cls.name}.select (capabilities jit_safe=True)")
                    )
    return roots


def _is_jit_decorator(mod: ModuleInfo, dec: ast.AST) -> bool:
    if mod.qualify(dec) == "jax.jit":
        return True
    if isinstance(dec, ast.Call):
        fq = mod.qualify(dec.func)
        if fq == "jax.jit":
            return True
        if fq == "functools.partial" and dec.args:
            return mod.qualify(dec.args[0]) == "jax.jit"
    return False


def _declares_jit_safe(mod: ModuleInfo, cls: ast.ClassDef) -> bool:
    for stmt in cls.body:
        if not isinstance(stmt, ast.Assign):
            continue
        if not any(
            isinstance(t, ast.Name) and t.id == "capabilities"
            for t in stmt.targets
        ):
            continue
        call = stmt.value
        if not isinstance(call, ast.Call):
            continue
        fq = mod.qualify(call.func) or ""
        if not fq.endswith("Capabilities"):
            continue
        for kw in call.keywords:
            if kw.arg == "jit_safe" and isinstance(kw.value, ast.Constant):
                return bool(kw.value.value)
    return False


# ---------------------------------------------------------------------------
# Reachability
# ---------------------------------------------------------------------------


def _reachable(
    index: FileIndex, roots: list[tuple[ModuleInfo, ast.AST, str]]
) -> list[tuple[ModuleInfo, ast.AST, str]]:
    out: list[tuple[ModuleInfo, ast.AST, str]] = []
    visited: set[tuple[str, int]] = set()
    stack = list(roots)
    while stack:
        mod, fn, why = stack.pop()
        key = (mod.path, fn.lineno)
        if key in visited:
            continue
        visited.add(key)
        out.append((mod, fn, why))
        for cmod, callee, cname in _callees(index, mod, fn):
            stack.append(
                (cmod, callee, f"{why} -> {cname}")
            )
    return out


def _callees(
    index: FileIndex, mod: ModuleInfo, fn: ast.AST
) -> Iterator[tuple[ModuleInfo, ast.AST, str]]:
    """Project-internal functions ``fn``'s body may call."""
    encl_class = mod.enclosing_class(fn)
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        # self.method() / cls.method()
        if (
            isinstance(func, ast.Attribute)
            and isinstance(func.value, ast.Name)
            and func.value.id in ("self", "cls")
            and encl_class is not None
        ):
            target = mod.functions.get(f"{encl_class.name}.{func.attr}")
            if target is not None:
                yield mod, target, func.attr
            continue
        if isinstance(func, ast.Name):
            hit = resolve_callable(index, mod, func, node)
            if hit is not None:
                yield hit[0], hit[1], func.id
            continue
        fq = mod.qualify(func)
        if fq is None or not fq.startswith("repro."):
            continue
        target_mod, _, fn_name = fq.rpartition(".")
        hit = index.lookup_function(target_mod, fn_name)
        if hit is not None:
            yield hit[0], hit[1], fn_name


# ---------------------------------------------------------------------------
# Violation scan
# ---------------------------------------------------------------------------


def _scan_function(
    mod: ModuleInfo, fn: ast.AST, why: str
) -> Iterator[Finding]:
    ctx = f" [traced: {why}]"
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            func = node.func
            if (
                isinstance(func, ast.Attribute)
                and func.attr in _HOST_METHODS
                and not node.args
            ):
                yield Finding(
                    mod.path, node.lineno, RULE_ID,
                    f".{func.attr}() blocks on a device->host copy of a "
                    f"traced value{ctx}",
                )
                continue
            fq = mod.qualify(func)
            if fq in _HOST_CALLS:
                yield Finding(
                    mod.path, node.lineno, RULE_ID,
                    _HOST_CALLS[fq] + ctx,
                )
                continue
            if (
                isinstance(func, ast.Name)
                and func.id in ("float", "int", "bool")
                and func.id not in mod.imports
                and node.args
                and any(_contains_traced(mod, a) for a in node.args)
            ):
                yield Finding(
                    mod.path, node.lineno, RULE_ID,
                    f"{func.id}() concretizes a traced value (host sync); "
                    f"keep it an array or hoist it to static config{ctx}",
                )
        elif isinstance(node, (ast.If, ast.While, ast.IfExp)):
            if _contains_traced(mod, node.test):
                yield Finding(
                    mod.path, node.test.lineno, RULE_ID,
                    "Python control flow on a traced value (host sync); "
                    f"use lax.cond/jnp.where{ctx}",
                )
        elif isinstance(node, ast.Assert):
            if _contains_traced(mod, node.test):
                yield Finding(
                    mod.path, node.lineno, RULE_ID,
                    "assert on a traced value (host sync); use static "
                    f"shapes or checkify{ctx}",
                )


def _contains_traced(mod: ModuleInfo, expr: ast.AST) -> bool:
    """Does this expression contain a call that yields a traced array?"""
    for node in ast.walk(expr):
        if not isinstance(node, ast.Call):
            continue
        fq = mod.qualify(node.func)
        if fq is not None:
            if fq in _STATIC_JAX_CALLS:
                continue
            if fq.startswith(_TRACED_PREFIXES):
                return True
        if (
            isinstance(node.func, ast.Attribute)
            and node.func.attr in _ARRAY_METHODS
            and not _is_module_call(mod, node.func)
        ):
            return True
    return False


def _is_module_call(mod: ModuleInfo, func: ast.Attribute) -> bool:
    """True when the attribute chain's root name is an import — then the
    qualified-prefix test above is authoritative and the array-method
    heuristic must not fire (``np.prod`` on Python ints is host math, not
    a traced reduction)."""
    node: ast.AST = func
    while isinstance(node, ast.Attribute):
        node = node.value
    return isinstance(node, ast.Name) and node.id in mod.imports
