"""Pass 3 — concurrency: lock discipline and thread lifecycle.

The async refresh stack (``AsyncRefresher``, ``CoresetService``,
``CoresetSampler``'s staged double buffer, the extraction ``Prefetcher``)
is the one part of the codebase where two threads share mutable state.
Its safety argument is simple and must stay simple: *every write to a
shared attribute happens under the owning lock, and every spawned thread
has a join path and a failure-propagation path*.  This pass checks those
three properties per class:

  * ``lock-discipline`` — for any class that creates a
    ``threading.Lock``/``RLock`` attribute in ``__init__``, the set of
    *shared* attributes is inferred as "assigned under ``with self._lock``
    somewhere outside ``__init__``"; any write (plain, augmented, tuple
    or ``del``) to a shared attribute outside a with-lock block — in any
    method or worker closure except ``__init__`` — is flagged.  Reads are
    deliberately exempt: CPython reference loads are atomic and the
    staged→installed double-buffer protocol tolerates stale reads by
    design (DESIGN.md §4); the race class this rule targets is
    lost/torn *updates*.
  * ``thread-join`` — every ``threading.Thread(...)`` must be bound to a
    name/attribute (no fire-and-forget ``Thread(...).start()``), and its
    enclosing class (or module) must join a thread somewhere — otherwise
    shutdown can tear down the interpreter under a live worker mid-XLA-
    dispatch, and nothing ever observes the worker's fate.
  * ``thread-failure-propagation`` — the thread's ``target=`` function
    must contain a try/except that *does something* with the exception
    (stores, queues or re-raises it).  A bare worker loop means a failed
    selection dies silently on the worker thread and training continues
    on stale data forever — the exact failure mode
    ``AsyncRefresher._raise_if_failed`` exists to prevent.
  * ``kv-deadline`` — the raw ``blocking_key_value_get*`` client calls
    may appear only inside the designated wrapper
    (``process_tree._raw_get_bytes``): every other call site must go
    through ``_kv_get``, which bounds the wait with the configured
    deadline and wraps failures in a :class:`KVStoreError` naming the
    key, pid and tree level.  A bare blocking get is an unbounded,
    context-free hang waiting to happen (and rapid short-timeout gets
    segfault the coordination client — DESIGN.md §12).
"""
from __future__ import annotations

import ast
from typing import Iterable, Iterator

from repro.analysis.engine import Rule
from repro.analysis.findings import Finding
from repro.analysis.index import FileIndex, ModuleInfo

LOCK_RULE = "lock-discipline"
JOIN_RULE = "thread-join"
FAILURE_RULE = "thread-failure-propagation"
KV_RULE = "kv-deadline"

_LOCK_CTORS = frozenset(
    {"threading.Lock", "threading.RLock", "threading.Condition"}
)
_THREAD_CTOR = "threading.Thread"

# the only functions allowed to touch the raw blocking KV getters; all
# other call sites must use the deadline/error wrapper built on them
_KV_WRAPPERS = frozenset({"_raw_get_bytes"})


class ConcurrencyRule(Rule):
    rule_ids = (LOCK_RULE, JOIN_RULE, FAILURE_RULE, KV_RULE)
    description = (
        "shared attributes written only under the owning lock; spawned "
        "threads joined and their failures propagated; blocking KV gets "
        "confined to the deadline wrapper"
    )

    def run(self, index: FileIndex) -> Iterable[Finding]:
        findings: list[Finding] = []
        for mod in index.modules:
            for cls in mod.classes.values():
                findings.extend(_check_lock_discipline(mod, cls))
            findings.extend(_check_threads(mod))
            findings.extend(_check_kv_deadline(mod))
        return findings


# ---------------------------------------------------------------------------
# lock-discipline
# ---------------------------------------------------------------------------


def _check_lock_discipline(
    mod: ModuleInfo, cls: ast.ClassDef
) -> Iterator[Finding]:
    locks = _lock_attrs(mod, cls)
    if not locks:
        return
    shared = _shared_attrs(mod, cls, locks)
    if not shared:
        return
    for meth in _methods(cls):
        if meth.name == "__init__":
            continue  # pre-publication: no second thread can exist yet
        for node in ast.walk(meth):
            for attr in _written_self_attrs(node):
                if attr in shared and not _under_lock(mod, node, locks):
                    yield Finding(
                        mod.path,
                        node.lineno,
                        LOCK_RULE,
                        f"write to shared attribute 'self.{attr}' outside "
                        f"'with self.{next(iter(locks))}' "
                        f"({cls.name}.{meth.name}); it is lock-guarded "
                        "elsewhere, so this write races the other thread",
                    )


def _lock_attrs(mod: ModuleInfo, cls: ast.ClassDef) -> frozenset[str]:
    out = set()
    for node in ast.walk(cls):
        if not isinstance(node, ast.Assign):
            continue
        if (
            isinstance(node.value, ast.Call)
            and mod.qualify(node.value.func) in _LOCK_CTORS
        ):
            for t in node.targets:
                if _is_self_attr(t):
                    out.add(t.attr)
    return frozenset(out)


def _shared_attrs(
    mod: ModuleInfo, cls: ast.ClassDef, locks: frozenset[str]
) -> frozenset[str]:
    """Attributes assigned under a with-lock block anywhere in the class."""
    out = set()
    for node in ast.walk(cls):
        if not isinstance(node, ast.With):
            continue
        if not _with_takes_lock(mod, node, locks):
            continue
        for inner in ast.walk(node):
            for attr in _written_self_attrs(inner):
                out.add(attr)
    return frozenset(out - locks)


def _methods(cls: ast.ClassDef) -> list[ast.FunctionDef]:
    return [
        n
        for n in cls.body
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    ]


def _is_self_attr(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    )


def _written_self_attrs(node: ast.AST) -> list[str]:
    """Attribute names this single statement writes on ``self``."""
    targets: list[ast.AST] = []
    if isinstance(node, ast.Assign):
        targets = list(node.targets)
    elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        targets = [node.target]
    elif isinstance(node, ast.Delete):
        targets = list(node.targets)
    out = []
    for t in targets:
        if isinstance(t, (ast.Tuple, ast.List)):
            out.extend(a.attr for a in t.elts if _is_self_attr(a))
        elif _is_self_attr(t):
            out.append(t.attr)
    return out


def _with_takes_lock(
    mod: ModuleInfo, node: ast.With, locks: frozenset[str]
) -> bool:
    for item in node.items:
        expr = item.context_expr
        if _is_self_attr(expr) and expr.attr in locks:
            return True
    return False


def _under_lock(
    mod: ModuleInfo, node: ast.AST, locks: frozenset[str]
) -> bool:
    cur = mod.parents.get(node)
    while cur is not None:
        if isinstance(cur, ast.With) and _with_takes_lock(mod, cur, locks):
            return True
        cur = mod.parents.get(cur)
    return False


# ---------------------------------------------------------------------------
# thread-join / thread-failure-propagation
# ---------------------------------------------------------------------------


def _check_threads(mod: ModuleInfo) -> Iterator[Finding]:
    for node in ast.walk(mod.tree):
        if not (
            isinstance(node, ast.Call)
            and mod.qualify(node.func) == _THREAD_CTOR
        ):
            continue
        parent = mod.parents.get(node)
        if not isinstance(parent, (ast.Assign, ast.AnnAssign)):
            yield Finding(
                mod.path,
                node.lineno,
                JOIN_RULE,
                "threading.Thread is not bound to a name/attribute — "
                "nothing can ever join it or observe its fate",
            )
        else:
            scope = mod.enclosing_class(node) or mod.tree
            if not _scope_has_join(scope):
                owner = (
                    mod.enclosing_class(node).name
                    if mod.enclosing_class(node)
                    else "module"
                )
                yield Finding(
                    mod.path,
                    node.lineno,
                    JOIN_RULE,
                    f"{owner} spawns a thread but never joins one; add a "
                    "join path (wait()/close()) so shutdown and error "
                    "handling can retire the worker",
                )
        target = next(
            (kw.value for kw in node.keywords if kw.arg == "target"), None
        )
        if isinstance(target, ast.Name):
            tdef = mod.resolve_local(target.id, node)
            if isinstance(
                tdef, (ast.FunctionDef, ast.AsyncFunctionDef)
            ) and not _captures_failure(tdef):
                yield Finding(
                    mod.path,
                    tdef.lineno,
                    FAILURE_RULE,
                    f"thread target '{tdef.name}' has no try/except "
                    "capturing worker failure; an exception here dies "
                    "silently on the worker thread — store it and "
                    "re-raise on the consumer thread",
                )


def _scope_has_join(scope: ast.AST) -> bool:
    for node in ast.walk(scope):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "join"
        ):
            return True
    return False


# ---------------------------------------------------------------------------
# kv-deadline
# ---------------------------------------------------------------------------


def _check_kv_deadline(mod: ModuleInfo) -> Iterator[Finding]:
    for node in ast.walk(mod.tree):
        if not (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr.startswith("blocking_key_value_get")
        ):
            continue
        fn = _enclosing_function(mod, node)
        if fn is not None and fn.name in _KV_WRAPPERS:
            continue
        yield Finding(
            mod.path,
            node.lineno,
            KV_RULE,
            f"raw '{node.func.attr}' outside the deadline wrapper "
            f"({', '.join(sorted(_KV_WRAPPERS))}); call _kv_get instead so "
            "the wait is bounded by the configured deadline and failures "
            "name the key, pid and tree level",
        )


def _enclosing_function(
    mod: ModuleInfo, node: ast.AST
) -> ast.FunctionDef | ast.AsyncFunctionDef | None:
    cur = mod.parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = mod.parents.get(cur)
    return None


def _captures_failure(fn: ast.AST) -> bool:
    """try/except whose handler does more than pass (stores/raises)."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Try):
            continue
        for handler in node.handlers:
            meaningful = [
                s for s in handler.body if not isinstance(s, ast.Pass)
            ]
            if meaningful:
                return True
    return False
