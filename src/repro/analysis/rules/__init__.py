"""The four concrete repro-lint passes (DESIGN.md §11).

Registration order is documentation order; ``repro.analysis.engine.
all_rules`` instantiates this list.
"""
from repro.analysis.rules.jit_safety import JitSafetyRule
from repro.analysis.rules.pallas_contract import PallasContractRule
from repro.analysis.rules.concurrency import ConcurrencyRule
from repro.analysis.rules.api_hygiene import ApiHygieneRule

ALL_RULES = (
    JitSafetyRule,
    PallasContractRule,
    ConcurrencyRule,
    ApiHygieneRule,
)

__all__ = [
    "ALL_RULES",
    "JitSafetyRule",
    "PallasContractRule",
    "ConcurrencyRule",
    "ApiHygieneRule",
]
