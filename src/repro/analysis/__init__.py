"""repro-lint: project-specific static analysis (DESIGN.md §11).

CRAIG's speedup claim survives only while the selection/extraction hot
paths stay device-resident, the Pallas kernels keep their tiling/precision
contracts, and the async refresh machinery stays race-free.  Those are
*repo invariants*, not general Python style — so they are checked by a
project-owned rule engine over ``ast`` instead of an off-the-shelf linter:

  * :mod:`repro.analysis.index` — shared file/symbol index (one parse per
    file, import resolution, qualified-name lookup) every rule reads;
  * :mod:`repro.analysis.engine` — the ``Rule`` protocol and runner;
  * :mod:`repro.analysis.findings` / :mod:`repro.analysis.suppress` —
    structured ``Finding`` records and the narrow inline suppression
    syntax ``# repro-lint: disable=RULE  # reason``;
  * :mod:`repro.analysis.rules` — the four concrete passes: jit-safety,
    Pallas contract, concurrency, API hygiene;
  * :mod:`repro.analysis.report` — human and JSON reporters;
  * ``python -m repro.analysis`` — the CLI (exit 0 clean / 1 findings /
    2 usage or internal error) that CI gates on.
"""
from repro.analysis.engine import AnalysisResult, Rule, all_rules, run_analysis
from repro.analysis.findings import Finding, SEVERITIES
from repro.analysis.index import FileIndex, ModuleInfo

__all__ = [
    "AnalysisResult",
    "Rule",
    "all_rules",
    "run_analysis",
    "Finding",
    "SEVERITIES",
    "FileIndex",
    "ModuleInfo",
]
