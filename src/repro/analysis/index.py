"""Shared file/symbol index: one parse per file, queried by every rule.

The index is deliberately *syntactic*: it resolves what can be resolved
from imports and lexical structure (qualified call names, same-module and
cross-module function defs, ``self.method`` targets) and returns ``None``
for everything else.  Rules are written to degrade to silence on ``None``
— a project linter earns its keep by being precise on the project's own
idioms, not by approximating a type checker.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path

from repro.analysis.findings import Finding
from repro.analysis.suppress import parse_suppressions

PARSE_RULE_ID = "parse-error"


@dataclasses.dataclass
class ModuleInfo:
    """Everything the rules need about one parsed source file."""

    path: str  # as reported in findings (relative where possible)
    abspath: Path
    module: str  # dotted module name, best-effort ('' outside a package)
    source: str
    lines: list[str]
    tree: ast.Module
    imports: dict[str, str]  # local name -> fully qualified dotted target
    functions: dict[str, ast.FunctionDef | ast.AsyncFunctionDef]  # qualname
    classes: dict[str, ast.ClassDef]  # qualname -> node
    suppressions: dict[int, frozenset[str]]
    parents: dict[ast.AST, ast.AST]  # child -> parent, whole tree

    # -- name resolution ---------------------------------------------------

    def qualify(self, node: ast.AST) -> str | None:
        """Dotted name of a Name/Attribute chain with imports resolved.

        ``pl.pallas_call`` (after ``from jax.experimental import pallas as
        pl``) → ``'jax.experimental.pallas.pallas_call'``; unresolvable
        shapes (calls, subscripts) → None.
        """
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.imports.get(node.id, node.id)
        return ".".join([root] + list(reversed(parts)))

    def enclosing_function(
        self, node: ast.AST
    ) -> ast.FunctionDef | ast.AsyncFunctionDef | None:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return cur
            cur = self.parents.get(cur)
        return None

    def enclosing_class(self, node: ast.AST) -> ast.ClassDef | None:
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, ast.ClassDef):
                return cur
            cur = self.parents.get(cur)
        return None

    def qualname_of(self, fn: ast.AST) -> str:
        """Dotted qualname of a def/class within this module (no module
        prefix): ``Class.method``, ``outer.inner``."""
        names = [getattr(fn, "name", "<anon>")]
        cur = self.parents.get(fn)
        while cur is not None:
            if isinstance(
                cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)
            ):
                names.append(cur.name)
            cur = self.parents.get(cur)
        return ".".join(reversed(names))

    def resolve_local(self, name: str, scope: ast.AST) -> ast.AST | None:
        """Last assignment/def binding ``name`` lexically before use.

        Searches the enclosing function body (then module body) for
        ``name = <expr>`` or ``def name``; returns the value expression or
        the FunctionDef.  Good enough for the repo's idiom of binding a
        grid/kernel right above its ``pallas_call``.
        """
        bodies = []
        fn = scope if isinstance(
            scope, (ast.FunctionDef, ast.AsyncFunctionDef)
        ) else self.enclosing_function(scope)
        while fn is not None:
            bodies.append(fn)
            fn = self.enclosing_function(fn)
        bodies.append(self.tree)
        for holder in bodies:
            found: ast.AST | None = None
            for stmt in ast.walk(holder):
                if isinstance(stmt, ast.Assign):
                    for t in stmt.targets:
                        if isinstance(t, ast.Name) and t.id == name:
                            found = stmt.value
                elif isinstance(
                    stmt, (ast.FunctionDef, ast.AsyncFunctionDef)
                ) and stmt.name == name:
                    found = stmt
            if found is not None:
                return found
        return None


@dataclasses.dataclass
class FileIndex:
    """All parsed modules plus cross-module lookup tables."""

    modules: list[ModuleInfo]
    by_module: dict[str, ModuleInfo]
    parse_findings: list[Finding]
    pragma_findings: list[Finding]

    @classmethod
    def build(cls, paths: list[str | Path]) -> "FileIndex":
        files: list[Path] = []
        for p in paths:
            p = Path(p)
            if p.is_dir():
                files.extend(sorted(p.rglob("*.py")))
            elif p.suffix == ".py":
                files.append(p)
        modules: list[ModuleInfo] = []
        parse_findings: list[Finding] = []
        pragma_findings: list[Finding] = []
        cwd = Path.cwd()
        for f in files:
            abspath = f.resolve()
            try:
                rel = str(abspath.relative_to(cwd))
            except ValueError:
                rel = str(f)
            source = abspath.read_text(encoding="utf-8")
            try:
                tree = ast.parse(source, filename=rel)
            except SyntaxError as e:
                parse_findings.append(
                    Finding(rel, e.lineno or 1, PARSE_RULE_ID, str(e.msg))
                )
                continue
            lines = source.splitlines()
            supp, bad = parse_suppressions(rel, lines)
            pragma_findings.extend(bad)
            mod = ModuleInfo(
                path=rel,
                abspath=abspath,
                module=_module_name(abspath),
                source=source,
                lines=lines,
                tree=tree,
                imports=_collect_imports(tree),
                functions={},
                classes={},
                suppressions=supp,
                parents=_parent_map(tree),
            )
            _collect_defs(mod)
            modules.append(mod)
        return cls(
            modules=modules,
            by_module={m.module: m for m in modules if m.module},
            parse_findings=parse_findings,
            pragma_findings=pragma_findings,
        )

    def lookup_function(
        self, module: str, qualname: str
    ) -> tuple[ModuleInfo, ast.AST] | None:
        mod = self.by_module.get(module)
        if mod is None:
            return None
        fn = mod.functions.get(qualname)
        return None if fn is None else (mod, fn)


def resolve_callable(
    index: "FileIndex", mod: ModuleInfo, node: ast.AST, scope: ast.AST
) -> tuple[ModuleInfo, ast.AST] | None:
    """Best-effort: the function def an expression evaluates to.

    Handles the repo's idioms: a bare name (local def / module-level def /
    cross-module import), a lambda, ``functools.partial(f, ...)``, a local
    variable bound to one of those, and a kernel/body factory call —
    ``make_kernel(k)(...)`` resolves through the factory to the inner def
    it returns.  Anything else → None (rules stay silent).
    """
    for _ in range(8):  # bounded unwrapping: name -> assign -> call -> ...
        if node is None:
            return None
        if isinstance(node, ast.Lambda):
            return mod, node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return mod, node
        if isinstance(node, ast.Name):
            local = mod.resolve_local(node.id, scope)
            if local is not None:
                node = local
                continue
            qual = mod.imports.get(node.id)
            if qual and "." in qual:
                target_mod, _, fn_name = qual.rpartition(".")
                hit = index.lookup_function(target_mod, fn_name)
                if hit is not None:
                    return hit
            return None
        if isinstance(node, ast.Call):
            fq = mod.qualify(node.func)
            if fq == "functools.partial" and node.args:
                node = node.args[0]
                continue
            # factory call: resolve the factory def, then the def it returns
            factory = resolve_callable(index, mod, node.func, scope)
            if factory is None:
                return None
            fmod, fdef = factory
            if not isinstance(fdef, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return None
            for stmt in ast.walk(fdef):
                if isinstance(stmt, ast.Return) and isinstance(
                    stmt.value, ast.Name
                ):
                    for inner in ast.walk(fdef):
                        if (
                            isinstance(
                                inner,
                                (ast.FunctionDef, ast.AsyncFunctionDef),
                            )
                            and inner.name == stmt.value.id
                        ):
                            return fmod, inner
            return None
        return None
    return None


def _module_name(abspath: Path) -> str:
    """Dotted module path by walking up through __init__.py parents."""
    parts = [abspath.stem] if abspath.stem != "__init__" else []
    cur = abspath.parent
    while (cur / "__init__.py").exists():
        parts.append(cur.name)
        cur = cur.parent
    return ".".join(reversed(parts))


def _collect_imports(tree: ast.Module) -> dict[str, str]:
    imports: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.asname:
                    imports[alias.asname] = alias.name
                else:
                    top = alias.name.split(".")[0]
                    imports[top] = top
        elif isinstance(node, ast.ImportFrom) and node.module and not node.level:
            for alias in node.names:
                if alias.name == "*":
                    continue
                imports[alias.asname or alias.name] = (
                    f"{node.module}.{alias.name}"
                )
    return imports


def _parent_map(tree: ast.Module) -> dict[ast.AST, ast.AST]:
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def _collect_defs(mod: ModuleInfo) -> None:
    for node in ast.walk(mod.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            mod.functions[mod.qualname_of(node)] = node
        elif isinstance(node, ast.ClassDef):
            mod.classes[mod.qualname_of(node)] = node
