"""Deterministic, seedable fault injection (DESIGN.md §12).

Every failure path in the robustness stack — refresh retries, KV-store
deadline/quorum degradation, feature validation — must be testable without
real chaos (no killing CI runners, no flaky sleeps).  A :class:`FaultPlan`
is a declarative list of :class:`FaultSpec` records, each naming a *hook
site* and a *kind* of fault, installed process-wide and consulted by two
zero-cost hooks threaded through the production code:

* :func:`fault_point` — a point fault: may raise (:class:`FaultInjected`),
  sleep (``latency``), simulate a missing KV key (``drop_key``) or kill
  the process (``kill`` — ``SIGKILL``, the real preemption signal);
* :func:`fault_value` — a value fault: transforms the value flowing
  through the site (``nan`` corrupts feature rows).

Hook sites in production code (stable names — tests and ops tooling key
on them):

========================  ====================================================
``refresh.worker``        per-attempt, inside ``AsyncRefresher``'s retry loop
``extract.features``      value hook on ``ProxyExtractor.extract`` output
``service.ingest``        top of ``CoresetService``'s coalesced ingest drain
``kv.get``                every KV-store get in ``process_tree`` (ctx: key)
``tree.publish``          before a tree node announces its payload
========================  ====================================================

Determinism: firing is decided by per-site *call counters* (``on_calls`` /
``every``) or a per-spec seeded RNG (``p``) — two identical plans over the
same call sequence fire identically, and a plan serializes to/from JSON so
a parent process can arm a *subprocess* via the ``REPRO_FAULT_PLAN``
environment variable (the tier-2 chaos lane SIGKILLs a real tree-selection
leaf this way).

No plan installed → the hooks are attribute-read no-ops; production code
pays one module-global load per hook site.  Pure stdlib + numpy — no JAX
import, so the lint job and subprocess bootstraps can use it freely.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import random
import signal
import threading
import time

import numpy as np

__all__ = [
    "ENV_VAR",
    "FAULT_KINDS",
    "FaultInjected",
    "FaultPlan",
    "FaultSpec",
    "active_plan",
    "clear",
    "fault_point",
    "fault_value",
    "injected",
    "install",
    "install_from_env",
]

ENV_VAR = "REPRO_FAULT_PLAN"

FAULT_KINDS = ("raise", "latency", "drop_key", "nan", "kill")


class FaultInjected(RuntimeError):
    """An injected fault fired (kind='raise' or a matched 'drop_key')."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One declarative fault.

    Attributes:
      site: hook-site name this spec instruments (see module docstring).
      kind: one of :data:`FAULT_KINDS`.
      on_calls: 1-based call numbers at the site that fire (deterministic
        Nth-call faults).  ``None`` (with ``every``/``p`` also None) means
        *every* call fires.
      every: fire on calls 1, 1+every, 1+2·every, … (transient-failure
        patterns: ``every=2`` with one retry makes every job fail once and
        then succeed).
      p: per-call firing probability, drawn from the plan's seeded per-spec
        RNG — reproducible chaos.
      latency_s: sleep duration for kind='latency'.
      key_pattern: kind='drop_key' only fires when this substring occurs in
        the hook's ``key`` context (empty = every key).
      rows: kind='nan' corrupts the first ``rows`` rows of the value.
      message: carried in the raised ``FaultInjected``.
    """

    site: str
    kind: str
    on_calls: tuple[int, ...] | None = None
    every: int | None = None
    p: float | None = None
    latency_s: float = 0.0
    key_pattern: str = ""
    rows: int = 1
    message: str = "injected fault"

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{FAULT_KINDS}"
            )
        if self.on_calls is not None:
            object.__setattr__(
                self, "on_calls", tuple(int(c) for c in self.on_calls)
            )
            if any(c < 1 for c in self.on_calls):
                raise ValueError("on_calls are 1-based call numbers (≥ 1)")
        if self.every is not None and int(self.every) < 1:
            raise ValueError(f"every={self.every} must be ≥ 1")
        if self.p is not None and not 0.0 <= float(self.p) <= 1.0:
            raise ValueError(f"p={self.p} must be in [0, 1]")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["on_calls"] = None if self.on_calls is None else list(self.on_calls)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "FaultSpec":
        d = dict(d)
        oc = d.get("on_calls")
        if oc is not None:
            d["on_calls"] = tuple(int(c) for c in oc)
        return cls(**d)


class FaultPlan:
    """A set of :class:`FaultSpec` with deterministic firing state.

    Thread-safe: per-site call counters and the per-spec probability RNGs
    are advanced under one lock, so concurrent hook sites (refresh worker
    vs. caller thread) count deterministically per site.
    """

    def __init__(self, specs: list[FaultSpec] | tuple[FaultSpec, ...], seed: int = 0):
        self.specs = tuple(
            s if isinstance(s, FaultSpec) else FaultSpec.from_dict(s)
            for s in specs
        )
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._calls: dict[str, int] = {}
        # one independent RNG stream per spec: adding a spec never perturbs
        # another spec's draw sequence
        self._rngs = [
            random.Random(self.seed * 1_000_003 + i)
            for i in range(len(self.specs))
        ]

    # -- firing ------------------------------------------------------------

    def calls(self, site: str) -> int:
        """Calls observed at ``site`` so far."""
        with self._lock:
            return self._calls.get(site, 0)

    def _fires(self, i: int, spec: FaultSpec, n_call: int, ctx: dict) -> bool:
        if spec.kind == "drop_key" and spec.key_pattern:
            if spec.key_pattern not in str(ctx.get("key", "")):
                return False
        if spec.on_calls is not None:
            return n_call in spec.on_calls
        if spec.every is not None:
            return (n_call - 1) % int(spec.every) == 0
        if spec.p is not None:
            return self._rngs[i].random() < float(spec.p)
        return True

    def apply(self, site: str, value=None, **ctx):
        """Advance the site counter and apply every matching spec.

        Point kinds (raise/latency/drop_key/kill) take effect as side
        effects; 'nan' transforms and returns ``value``.
        """
        with self._lock:
            n_call = self._calls.get(site, 0) + 1
            self._calls[site] = n_call
            firing = [
                spec
                for i, spec in enumerate(self.specs)
                if spec.site == site and self._fires(i, spec, n_call, ctx)
            ]
        for spec in firing:
            if spec.kind == "latency":
                time.sleep(spec.latency_s)
            elif spec.kind == "kill":
                os.kill(os.getpid(), signal.SIGKILL)
            elif spec.kind in ("raise", "drop_key"):
                raise FaultInjected(
                    f"{site} (call {n_call}): {spec.message}"
                    + (f" [key={ctx['key']!r}]" if "key" in ctx else "")
                )
            elif spec.kind == "nan":
                value = _nan_rows(value, spec.rows)
        return value

    # -- serialization -----------------------------------------------------

    def to_dict(self) -> dict:
        return {"seed": self.seed, "specs": [s.to_dict() for s in self.specs]}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        return cls(
            [FaultSpec.from_dict(s) for s in d.get("specs", ())],
            seed=int(d.get("seed", 0)),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        return cls.from_dict(json.loads(s))


def _nan_rows(value, rows: int):
    """Corrupt the first ``rows`` rows of an array value with NaN.

    Returns the same family the value came in (numpy in → numpy out,
    jax.Array in → jax.Array out via a host round-trip — injection is a
    test path, not a hot path).
    """
    if value is None:
        return None
    arr = np.array(value, dtype=np.float32, copy=True)
    arr[: int(rows)] = np.nan
    if isinstance(value, np.ndarray):
        return arr
    try:  # jax.Array — re-wrap without importing jax at module scope
        import jax.numpy as jnp

        return jnp.asarray(arr)
    except ImportError:  # pragma: no cover - numpy-only environments
        return arr


# ---------------------------------------------------------------------------
# Process-wide installation + hooks
# ---------------------------------------------------------------------------

_ACTIVE: FaultPlan | None = None
_INSTALL_LOCK = threading.Lock()


def install(plan: FaultPlan) -> FaultPlan:
    """Install ``plan`` process-wide (replacing any previous plan)."""
    global _ACTIVE
    with _INSTALL_LOCK:
        _ACTIVE = plan
    return plan


def clear() -> None:
    """Remove the installed plan (hooks become no-ops again)."""
    global _ACTIVE
    with _INSTALL_LOCK:
        _ACTIVE = None


def active_plan() -> FaultPlan | None:
    return _ACTIVE


@contextlib.contextmanager
def injected(plan: FaultPlan):
    """Scoped installation: ``with injected(plan): ...`` (tests)."""
    prev = _ACTIVE
    install(plan)
    try:
        yield plan
    finally:
        if prev is None:
            clear()
        else:
            install(prev)


def install_from_env() -> FaultPlan | None:
    """Install the plan serialized in ``$REPRO_FAULT_PLAN``, if any.

    Subprocess arming: launch entry points (``repro.launch.tree``) call
    this before doing real work, so a parent can inject faults into one
    specific child by setting the variable in that child's environment.
    """
    raw = os.environ.get(ENV_VAR)
    if not raw:
        return None
    return install(FaultPlan.from_json(raw))


def fault_point(site: str, **ctx) -> None:
    """Point-fault hook: no-op unless an installed spec matches ``site``."""
    plan = _ACTIVE
    if plan is not None:
        plan.apply(site, **ctx)


def fault_value(site: str, value, **ctx):
    """Value-fault hook: returns ``value`` (possibly transformed)."""
    plan = _ACTIVE
    if plan is None:
        return value
    return plan.apply(site, value, **ctx)
