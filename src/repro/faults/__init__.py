"""Fault injection + failure policy (DESIGN.md §12).

``repro.faults`` is the robustness seam of the codebase: a deterministic,
seedable fault-injection registry (:class:`FaultPlan` consulted by the
:func:`fault_point`/:func:`fault_value` hooks threaded through the refresh
worker, the KV-store wire and feature extraction) plus the
:class:`FailurePolicy` record that ``AsyncRefresher``, the trainer and the
coreset service interpret when real work fails.  Pure stdlib + numpy — no
JAX import, so launch bootstraps and the lint job load it freely.
"""
from repro.faults.plan import (
    ENV_VAR,
    FAULT_KINDS,
    FaultInjected,
    FaultPlan,
    FaultSpec,
    active_plan,
    clear,
    fault_point,
    fault_value,
    injected,
    install,
    install_from_env,
)
from repro.faults.policy import EXHAUSTION_MODES, FailurePolicy

__all__ = [
    "ENV_VAR",
    "EXHAUSTION_MODES",
    "FAULT_KINDS",
    "FailurePolicy",
    "FaultInjected",
    "FaultPlan",
    "FaultSpec",
    "active_plan",
    "clear",
    "fault_point",
    "fault_value",
    "injected",
    "install",
    "install_from_env",
]
