"""Failure policy for supervised background work (DESIGN.md §12).

One :class:`FailurePolicy` record answers the three questions every
supervised job runner needs answered up front: *how many times to retry*,
*how long to back off between attempts*, and *what to do when retries are
exhausted*.  ``AsyncRefresher`` interprets it per job on the worker
thread; ``CoresetService`` and the trainer thread it through their
constructors (``TrainerConfig.refresh_failure_policy``).

Exhaustion modes:

* ``'raise'`` (default) — the failure is published and re-raised on the
  caller thread at the next ``wait()``/``collect()``/``submit()`` touch
  point; the legacy fail-fast contract.
* ``'keep_stale'`` — the job is abandoned: nothing publishes, the caller
  keeps using the previously installed result (CRAIG keeps sampling the
  stale coreset — still a valid (1−1/e) selection for slightly drifted
  proxies, the CREST observation), and an ``on_failure`` callback fires so
  the abandonment is *logged*, never silent.
* ``'sync_fallback'`` — the failed job re-runs once *inline* on the caller
  thread at the next ``wait()``/``submit()`` — degrade to synchronous
  refresh rather than skipping it; a second failure raises.
"""
from __future__ import annotations

import dataclasses

__all__ = ["EXHAUSTION_MODES", "FailurePolicy"]

EXHAUSTION_MODES = ("raise", "keep_stale", "sync_fallback")


@dataclasses.dataclass(frozen=True)
class FailurePolicy:
    """Retry/backoff/exhaustion knobs for one supervised job family.

    Attributes:
      max_retries: extra attempts after the first failure (0 = fail fast).
      backoff_base_s: sleep before retry k is ``base · 2^k``, capped.
      backoff_cap_s: upper bound on any single backoff sleep.
      on_exhaustion: what happens when every attempt failed (module
        docstring).
    """

    max_retries: int = 0
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 2.0
    on_exhaustion: str = "raise"

    def __post_init__(self):
        if int(self.max_retries) < 0:
            raise ValueError(f"max_retries={self.max_retries} must be ≥ 0")
        if self.backoff_base_s < 0 or self.backoff_cap_s < 0:
            raise ValueError("backoff durations must be ≥ 0")
        if self.on_exhaustion not in EXHAUSTION_MODES:
            raise ValueError(
                f"on_exhaustion={self.on_exhaustion!r} is not a mode; "
                f"expected one of {EXHAUSTION_MODES}"
            )

    def backoff_s(self, attempt: int) -> float:
        """Sleep before retrying after (0-based) failed attempt ``attempt``."""
        return min(self.backoff_cap_s, self.backoff_base_s * (2.0 ** attempt))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "FailurePolicy":
        return cls(**d)
