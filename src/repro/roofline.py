"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Workflow: ``python -m repro.launch.dryrun --all`` writes the artifacts,
``python -m repro.roofline [--markdown|--compare]`` reports on them, and
``scripts/finalize_experiments.py`` publishes the tables into
EXPERIMENTS.md between its ROOFLINE_TABLE markers.

Methodology
-----------
``compiled.cost_analysis()`` counts ``lax.scan``/while bodies **once**
(verified empirically: a 4-iteration scanned matmul reports 1× body cost), so
the production scanned-stack programs underreport per-step work.  The sweep
therefore also compiles, per (arch × shape), two UNROLLED reduced-depth
probes (1 and 2 pattern periods, microbatches=1) whose cost analysis is
exact, and extrapolates:

    X(full) ≈ X(p1) + (n_layers/period − 1) · (X(p2) − X(p1))

which is exact for the (homogeneous) layer stack and attributes embedding /
CE-head / optimizer / gradient-sync costs through the p1 intercept.  All
cost_analysis numbers are per-device (verified: sharded matmul reports
global/devices).

Roofline terms (v5e targets; per device, per step):

    compute    = HLO_FLOPs / 197e12            [bf16 MXU peak]
    memory     = HLO_bytes_accessed / 819e9    [HBM bw]
    collective = Σ collective payload bytes / 50e9   [ICI link bw]

collective bytes are parsed from the post-SPMD optimized HLO (per-device
shapes) in launch/dryrun.py.  MODEL_FLOPS = 6·N·D (train) or 2·N·D
(inference), N = active params — the useful-compute yardstick.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from dataclasses import dataclass

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link

ARTIFACT_DIR = os.path.join(
    os.path.dirname(__file__), "../../artifacts/dryrun"
)

__all__ = ["CellRoofline", "analyze_cell", "analyze_all", "main"]


@dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    step: str
    flops: float  # per device per step (extrapolated)
    hbm_bytes: float
    coll_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    dominant: str
    model_flops_per_dev: float
    useful_ratio: float  # MODEL_FLOPS / HLO_FLOPS (per device)
    mfu_bound: float  # model_flops/dev / (t_dominant · PEAK)
    fits_hbm: bool
    mem_gb: float
    note: str
    extrapolated: bool

    @property
    def t_dominant(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)


def _load(out_dir: str, arch: str, shape: str, mesh: str, probe: int = 0):
    suffix = f"__p{probe}" if probe else ""
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh}{suffix}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _coll_total(rec: dict) -> float:
    return float(rec.get("collective_bytes_total", 0))


def analyze_cell(
    out_dir: str, arch: str, shape: str, mesh: str = "16x16"
) -> CellRoofline | None:
    full = _load(out_dir, arch, shape, mesh)
    if full is None or full.get("status") != "ok":
        return None
    p1 = _load(out_dir, arch, shape, mesh, probe=1)
    p2 = _load(out_dir, arch, shape, mesh, probe=2)

    # period count for extrapolation
    from repro.configs.registry import get_config

    cfg = get_config(arch)
    periods = cfg.n_layers / len(cfg.block_pattern)

    extrapolated = False
    if p1 and p2 and p1.get("status") == "ok" and p2.get("status") == "ok":
        extrapolated = True

        def extrap(key_fn):
            a, b = key_fn(p1), key_fn(p2)
            return a + (periods - 1) * (b - a)

        flops = extrap(lambda r: r["cost"].get("flops", 0.0))
        hbm = extrap(lambda r: r["cost"].get("bytes accessed", 0.0))
        coll = extrap(_coll_total)
    else:
        flops = full["cost"].get("flops", 0.0)
        hbm = full["cost"].get("bytes accessed", 0.0)
        coll = _coll_total(full)

    n_dev = full["n_devices"]
    model_flops_dev = full["model_flops"] / n_dev
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_x = coll / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    t_dom = terms[dominant]
    useful = model_flops_dev / max(flops, 1e-9)
    mfu_bound = model_flops_dev / max(t_dom, 1e-12) / PEAK_FLOPS

    mem = full.get("memory", {})
    mem_bytes = mem.get("argument_size_in_bytes", 0) + mem.get(
        "temp_size_in_bytes", 0
    )
    mem_gb = mem_bytes / 2**30

    note = _note(dominant, terms, useful, full)
    return CellRoofline(
        arch=arch,
        shape=shape,
        mesh=mesh,
        step=full.get("meta", {}).get("step", "?"),
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=coll,
        t_compute=t_c,
        t_memory=t_m,
        t_collective=t_x,
        dominant=dominant,
        model_flops_per_dev=model_flops_dev,
        useful_ratio=useful,
        mfu_bound=mfu_bound,
        fits_hbm=mem_gb <= 16.0,
        mem_gb=mem_gb,
        note=note,
        extrapolated=extrapolated,
    )


def _note(dominant: str, terms: dict, useful: float, rec: dict) -> str:
    shape = rec["shape"]
    if dominant == "collective":
        kinds = rec.get("collectives", {})
        big = max(kinds, key=lambda k: kinds[k]["bytes"]) if kinds else "?"
        return (
            f"{big} dominates — reshard to cut cross-device activation "
            "traffic (TP all-reduce → reduce-scatter, or more DP less TP)"
        )
    if dominant == "memory":
        if "decode" in shape or "500k" in shape:
            return (
                "cache/weight streaming bound (expected for decode) — "
                "raise batch per chip or quantize KV to lift arithmetic "
                "intensity"
            )
        return (
            "HBM-traffic bound — increase fusion/remat so activations stay "
            "resident; check layout-change copies"
        )
    if useful < 0.35:
        return (
            "compute-bound but low useful ratio — remat recompute and "
            "non-matmul overhead dominate; relax remat policy or fuse"
        )
    return "compute-bound near the MXU roof — healthy; push layout/fusion"


def analyze_all(out_dir: str = None, mesh: str = "16x16") -> list[CellRoofline]:
    out_dir = out_dir or os.path.normpath(ARTIFACT_DIR)
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, f"*__{mesh}.json"))):
        base = os.path.basename(path)[: -len(f"__{mesh}.json")]
        arch, shape = base.split("__")
        cell = analyze_cell(out_dir, arch, shape, mesh)
        if cell:
            cells.append(cell)
    return cells


def to_markdown(cells: list[CellRoofline]) -> str:
    hdr = (
        "| arch | shape | step | compute s | memory s | collective s | "
        "dominant | useful | MFU-bound | mem GB/dev | fits |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for c in cells:
        rows.append(
            f"| {c.arch} | {c.shape} | {c.step} | {c.t_compute:.3e} | "
            f"{c.t_memory:.3e} | {c.t_collective:.3e} | **{c.dominant}** | "
            f"{c.useful_ratio:.2f} | {c.mfu_bound:.2f} | {c.mem_gb:.1f} | "
            f"{'✓' if c.fits_hbm else '✗'} |"
        )
    return hdr + "\n".join(rows)


def compare_markdown(base_dir: str, opt_dir: str, mesh: str = "16x16") -> str:
    """Baseline vs optimized side-by-side (per §Perf: both recorded)."""
    base = {(c.arch, c.shape): c for c in analyze_all(base_dir, mesh)}
    opt = {(c.arch, c.shape): c for c in analyze_all(opt_dir, mesh)}
    hdr = (
        "| arch | shape | dominant (base→opt) | t_dom base s | t_dom opt s | "
        "speedup | MFU-bound base | MFU-bound opt |\n"
        "|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for key in sorted(opt):
        o = opt[key]
        b = base.get(key)
        if b is None:
            continue
        rows.append(
            f"| {o.arch} | {o.shape} | {b.dominant}→{o.dominant} | "
            f"{b.t_dominant:.3e} | {o.t_dominant:.3e} | "
            f"**{b.t_dominant / max(o.t_dominant, 1e-12):.1f}x** | "
            f"{b.mfu_bound:.3f} | {o.mfu_bound:.3f} |"
        )
    return hdr + "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.normpath(ARTIFACT_DIR))
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument(
        "--compare", default=None,
        help="baseline artifact dir — emit baseline-vs-optimized markdown",
    )
    args = ap.parse_args()
    if args.compare:
        print(compare_markdown(args.compare, args.out, args.mesh))
        return
    cells = analyze_all(args.out, args.mesh)
    if args.markdown:
        print(to_markdown(cells))
        return
    for c in cells:
        print(
            f"{c.arch:24s} {c.shape:12s} {c.step:12s} "
            f"C={c.t_compute:.2e} M={c.t_memory:.2e} X={c.t_collective:.2e} "
            f"dom={c.dominant:10s} useful={c.useful_ratio:5.2f} "
            f"mfu≤{c.mfu_bound:5.2f} mem={c.mem_gb:6.1f}GB"
            f"{'' if c.extrapolated else ' (no-probe)'}"
        )
        print(f"{'':24s} → {c.note}")


if __name__ == "__main__":
    main()
