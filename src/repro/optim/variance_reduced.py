"""Variance-reduced IG methods: SAGA and SVRG (the paper's convex baselines).

The paper (§5.1) runs CRAIG under SGD, SVRG (Johnson & Zhang 2013) and SAGA
(Defazio et al. 2014) for L2-regularized logistic regression.  These are
full-fidelity implementations for the convex benchmark path (flat parameter
vectors, per-example gradient oracles), supporting the *weighted* IG step of
paper Eq. 20: w ← w − α·γ_j·∇f_j(w).

They are deliberately single-node (the paper's convex experiments are):
the LM-scale path uses optim/optimizers.py under pjit instead.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["saga_run", "svrg_run", "ig_run"]

GradFn = Callable[[jax.Array, int | jax.Array], jax.Array]
# grad_fn(w, i) → ∇f_i(w)  (single-example gradient, includes regularizer)


def ig_run(
    grad_fn: GradFn,
    w0: jax.Array,
    order: jax.Array,
    weights: jax.Array,
    schedule: Callable[[int], float],
    epochs: int,
) -> tuple[jax.Array, list[jax.Array]]:
    """Plain (weighted) incremental gradient descent, paper Eq. 20.

    order: (r,) element indices (CRAIG subset, greedy order); weights: (r,) γ.
    Returns final w and per-epoch iterates.
    """
    w = w0
    trace = []

    @jax.jit
    def epoch_body(w, alpha):
        def step(w, idx_gamma):
            idx, gamma = idx_gamma
            g = grad_fn(w, idx)
            return w - alpha * gamma * g, None

        w, _ = jax.lax.scan(step, w, (order, weights))
        return w

    for k in range(epochs):
        w = epoch_body(w, jnp.asarray(schedule(k), jnp.float32))
        trace.append(w)
    return w, trace


def saga_run(
    grad_fn: GradFn,
    w0: jax.Array,
    order: jax.Array,
    weights: jax.Array,
    schedule: Callable[[int], float],
    epochs: int,
) -> tuple[jax.Array, list[jax.Array]]:
    """SAGA over the weighted subset: gradient table over subset elements.

    Update: w ← w − α·γ_j·( ∇f_j(w) − table_j + mean(table) ).
    """
    r = order.shape[0]
    w = w0
    # gradient table initialized at w0
    table = jax.vmap(lambda i: grad_fn(w0, i))(order)
    mean_g = jnp.mean(table * weights[:, None], axis=0)
    trace = []

    @jax.jit
    def epoch_body(carry, alpha):
        w, table, mean_g = carry

        def step(c, pos):
            w, table, mean_g = c
            idx = order[pos]
            gamma = weights[pos]
            g = grad_fn(w, idx)
            old = table[pos]
            vr_g = g - old + mean_g
            w = w - alpha * gamma * vr_g
            # table update + running mean of weighted table
            mean_g = mean_g + gamma * (g - old) / r
            table = table.at[pos].set(g)
            return (w, table, mean_g), None

        (w, table, mean_g), _ = jax.lax.scan(
            step, (w, table, mean_g), jnp.arange(r)
        )
        return (w, table, mean_g)

    for k in range(epochs):
        (w, table, mean_g) = epoch_body(
            (w, table, mean_g), jnp.asarray(schedule(k), jnp.float32)
        )
        trace.append(w)
    return w, trace


def svrg_run(
    grad_fn: GradFn,
    w0: jax.Array,
    order: jax.Array,
    weights: jax.Array,
    schedule: Callable[[int], float],
    epochs: int,
) -> tuple[jax.Array, list[jax.Array]]:
    """SVRG: snapshot full (weighted-subset) gradient per epoch.

    μ = (1/r)Σ_j γ_j ∇f_j(w̃);  w ← w − α·(γ_j·(∇f_j(w) − ∇f_j(w̃)) + μ).

    μ is normalized per *step* (r steps per epoch), so an epoch's anchor mass
    equals the weighted-subset full gradient — consistent with the γ-scaled
    IG steps of paper Eq. 20 (γ=1, r=n recovers textbook SVRG).
    """
    r = order.shape[0]
    n_eff = jnp.asarray(r, jnp.float32)
    w = w0
    trace = []

    @jax.jit
    def epoch_body(w, alpha):
        snapshot = w
        full_g = (
            jax.vmap(lambda i, g_: g_ * grad_fn(snapshot, i))(
                order, weights
            ).sum(0)
            / n_eff
        )

        def step(w, idx_gamma):
            idx, gamma = idx_gamma
            g = grad_fn(w, idx)
            g_snap = grad_fn(snapshot, idx)
            return w - alpha * (gamma * (g - g_snap) + full_g), None

        w, _ = jax.lax.scan(step, w, (order, weights))
        return w

    for k in range(epochs):
        w = epoch_body(w, jnp.asarray(schedule(k), jnp.float32))
        trace.append(w)
    return w, trace
