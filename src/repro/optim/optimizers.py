"""Optimizers (pytree-native, optax-style but self-contained).

All optimizers support:
  * per-element stepsizes — CRAIG's γ weights enter either through the
    weighted loss (preferred, see train/loss) or through ``scale`` here;
  * mixed precision: fp32 master params/state, bf16 compute handled upstream;
  * global-norm clipping;
  * learning-rate schedules as callables step → lr (paper's exponential and
    k-inverse schedules provided, §5.1).

State layout mirrors params (shards identically under pjit).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = [
    "OptState",
    "Optimizer",
    "sgd",
    "momentum",
    "adamw",
    "global_norm",
    "clip_by_global_norm",
    "exponential_decay",
    "k_inverse",
    "constant",
    "warmup_cosine",
]

Schedule = Callable[[jax.Array], jax.Array]


class OptState(NamedTuple):
    step: jax.Array
    inner: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], tuple[Any, OptState]]
    """(grads, state, params) → (new_params, new_state)."""


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(tree: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale, tree), norm


# -- schedules (paper §5.1) --------------------------------------------------


def constant(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def exponential_decay(lr0: float, b: float) -> Schedule:
    """α_k = α0 · b^k (paper's best-performing schedule)."""
    return lambda step: jnp.asarray(lr0, jnp.float32) * jnp.power(b, step)


def k_inverse(lr0: float, b: float, tau: float = 1.0) -> Schedule:
    """α_k = α0 / (1 + b·k)^τ — the paper's theoretically covered schedule
    (Thm 1/2 diminishing stepsizes α/k^τ)."""
    return lambda step: jnp.asarray(lr0, jnp.float32) / jnp.power(
        1.0 + b * step, tau
    )


def warmup_cosine(lr0: float, warmup: int, total: int) -> Schedule:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = lr0 * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = lr0 * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return sched


# -- optimizers ---------------------------------------------------------------


def sgd(schedule: Schedule, clip: float | None = None) -> Optimizer:
    def init(params):
        return OptState(jnp.zeros((), jnp.int32), ())

    def update(grads, state, params):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        lr = schedule(state.step)
        new = jax.tree.map(
            lambda p, g: (p - lr * g.astype(jnp.float32)).astype(p.dtype),
            params,
            grads,
        )
        return new, OptState(state.step + 1, ())

    return Optimizer(init, update)


def momentum(
    schedule: Schedule, beta: float = 0.9, clip: float | None = None
) -> Optimizer:
    def init(params):
        m = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), m)

    def update(grads, state, params):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        lr = schedule(state.step)
        m = jax.tree.map(
            lambda m_, g: beta * m_ + g.astype(jnp.float32), state.inner, grads
        )
        new = jax.tree.map(lambda p, m_: (p - lr * m_).astype(p.dtype), params, m)
        return new, OptState(state.step + 1, m)

    return Optimizer(init, update)


def adamw(
    schedule: Schedule,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    clip: float | None = 1.0,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros_like(p, jnp.float32)
        return OptState(
            jnp.zeros((), jnp.int32),
            {
                "m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
            },
        )

    def update(grads, state, params):
        if clip is not None:
            grads, _ = clip_by_global_norm(grads, clip)
        step = state.step + 1
        lr = schedule(state.step)
        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state.inner["m"],
            grads,
        )
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.inner["v"],
            grads,
        )
        bc1 = 1 - b1**step.astype(jnp.float32)
        bc2 = 1 - b2**step.astype(jnp.float32)

        def upd(p, m_, v_):
            mhat = m_ / bc1
            vhat = v_ / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(
                jnp.float32
            )
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new = jax.tree.map(upd, params, m, v)
        return new, OptState(step, {"m": m, "v": v})

    return Optimizer(init, update)
