"""Optimizers: pjit-native IG variants + the paper's convex VR baselines."""
from repro.optim.optimizers import (
    Optimizer,
    OptState,
    adamw,
    clip_by_global_norm,
    constant,
    exponential_decay,
    global_norm,
    k_inverse,
    momentum,
    sgd,
    warmup_cosine,
)
from repro.optim.variance_reduced import ig_run, saga_run, svrg_run

__all__ = [
    "Optimizer", "OptState", "adamw", "clip_by_global_norm", "constant",
    "exponential_decay", "global_norm", "k_inverse", "momentum", "sgd",
    "warmup_cosine", "ig_run", "saga_run", "svrg_run",
]
