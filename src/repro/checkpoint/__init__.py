"""Fault-tolerant checkpointing (atomic, async, keep-k, elastic restore)."""
from repro.checkpoint.manager import CheckpointManager

__all__ = ["CheckpointManager"]
