"""Fault-tolerant checkpointing: atomic, async, keep-k, elastic restore.

Layout (one directory per step)::

    <root>/step_000123/
        manifest.json          # tree structure, dtypes, shapes, extras
        arrays/<leaf-id>.npy   # one file per pytree leaf (host numpy)
    <root>/LATEST              # atomic pointer file

Design points for the 1000-node posture (DESIGN.md §4):
  * **Atomicity**: writes go to ``step_X.tmp`` then ``os.replace`` — a
    preempted writer never corrupts the latest checkpoint.
  * **Async**: ``save(..., blocking=False)`` snapshots to host memory
    (device_get) and writes on a worker thread; training continues.
  * **Elastic restore**: arrays are stored *unsharded* (logical view); on
    restore the caller passes target shardings — resharding onto a
    different mesh shape (scale up/down) is just ``jax.device_put`` with
    the new NamedShardings.
  * **Keep-k** garbage collection.
  * Extras slot carries data-pipeline cursors + the active CRAIG coreset,
    so restart resumes the exact stream (tests/test_checkpoint.py).

On a real multi-host pod each host writes only its addressable shards
(process-local slice); this single-host implementation keeps the same API.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = []
    for path, _ in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            else:
                parts.append(str(p))
        paths.append("/".join(parts) or "leaf")
    return [(paths[i], flat[i][1]) for i in range(len(flat))], treedef


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # -- save ---------------------------------------------------------------

    def save(
        self,
        step: int,
        tree: Any,
        extras: dict | None = None,
        blocking: bool = True,
    ) -> None:
        """Snapshot ``tree`` (params/opt state pytree) + JSON-able extras."""
        # Snapshot to host memory synchronously (cheap vs. disk IO).
        leaves, _ = _flatten_with_paths(tree)
        host = [(p, np.asarray(jax.device_get(v))) for p, v in leaves]

        def write():
            try:
                final = os.path.join(self.root, f"step_{step:08d}")
                tmp = final + ".tmp"
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(os.path.join(tmp, "arrays"))
                manifest = {
                    "step": step, "leaves": [], "extras": extras or {}
                }
                for i, (path, arr) in enumerate(host):
                    fn = f"{i:05d}.npy"
                    np.save(os.path.join(tmp, "arrays", fn), arr)
                    manifest["leaves"].append(
                        {"path": path, "file": fn, "dtype": str(arr.dtype),
                         "shape": list(arr.shape)}
                    )
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.replace(tmp, final)
                # atomic LATEST pointer
                ptr_tmp = os.path.join(self.root, "LATEST.tmp")
                with open(ptr_tmp, "w") as f:
                    f.write(os.path.basename(final))
                os.replace(ptr_tmp, os.path.join(self.root, "LATEST"))
                self._gc()
            except BaseException as e:
                # Surface on the trainer thread at the next wait()/save():
                # a checkpoint that silently failed to land is worse than a
                # crashed run (restores would rewind arbitrarily far).
                self._error = e

        self.wait()
        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint write failed") from err

    def _gc(self) -> None:
        steps = sorted(
            d for d in os.listdir(self.root)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for d in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.root, d), ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def latest_step(self) -> int | None:
        ptr = os.path.join(self.root, "LATEST")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            name = f.read().strip()
        if not os.path.exists(os.path.join(self.root, name, "manifest.json")):
            return None
        return int(name.split("_")[1])

    def restore(
        self,
        template: Any,
        step: int | None = None,
        shardings: Any | None = None,
    ) -> tuple[Any, dict]:
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree of NamedShardings (same structure) —
        arrays are placed with ``jax.device_put`` so restoring onto a
        *different* mesh (elastic rescale) is transparent.
        Returns (tree, extras).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        d = os.path.join(self.root, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        by_path = {l["path"]: l for l in manifest["leaves"]}

        leaves, treedef = _flatten_with_paths(template)
        shard_leaves = (
            jax.tree_util.tree_leaves(shardings) if shardings is not None else None
        )
        out = []
        for i, (path, tmpl) in enumerate(leaves):
            rec = by_path[path]
            arr = np.load(os.path.join(d, "arrays", rec["file"]))
            if shard_leaves is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr))
        tree = jax.tree_util.tree_unflatten(treedef, out)
        return tree, manifest.get("extras", {})
