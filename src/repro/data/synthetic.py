"""Deterministic synthetic data: classification pools and LM token streams.

Everything is seeded and index-addressable (``batch_at(step)``), which is what
makes checkpoint-restart and straggler skip-ahead exact: a restarted worker
regenerates precisely the batches it would have seen (DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["GaussianMixture", "TokenStream", "make_classification"]


def make_classification(
    n: int, d: int, n_classes: int, seed: int = 0, spread: float = 5.0
) -> tuple[np.ndarray, np.ndarray]:
    """Clustered classification data (n, d) with integer labels.

    Multi-modal classes (2 clusters per class) so that coreset selection has
    real structure to exploit — matches the paper's covtype/Ijcnn1 regime
    where CRAIG beats random by finding per-class modes.
    """
    rng = np.random.default_rng(seed)
    centers = rng.normal(0, spread, (n_classes * 2, d))
    # Imbalanced classes (zipf-ish) and rare secondary modes (15%) — the
    # covtype-like regime where random subsets miss rare structure but
    # facility-location medoids cover it.
    pc = 1.0 / np.arange(1, n_classes + 1)
    pc /= pc.sum()
    y = rng.choice(n_classes, n, p=pc)
    mode = (rng.random(n) < 0.15).astype(np.int64)
    x = centers[y * 2 + mode] + rng.normal(0, 1.0, (n, d))
    return x.astype(np.float32), y.astype(np.int32)


@dataclasses.dataclass
class GaussianMixture:
    """Index-addressable classification pool."""

    n: int
    d: int
    n_classes: int
    seed: int = 0

    def __post_init__(self):
        self.x, self.y = make_classification(self.n, self.d, self.n_classes, self.seed)

    def subset(self, idx: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        return self.x[idx], self.y[idx]

    def class_labels(self, idx: np.ndarray) -> np.ndarray:
        """Per-example class ids — the stratification key for the trainer's
        per-class CRAIG refresh (paper §5)."""
        return self.y[np.asarray(idx)]


@dataclasses.dataclass
class TokenStream:
    """Deterministic synthetic LM corpus of ``n_docs`` sequences.

    Sequences are Zipf-ish token streams with per-document "topics" so that
    gradient proxies cluster (CRAIG's selection signal).  ``example(i)``
    returns (tokens, labels) for document i; every example is regenerated
    on demand from (seed, i) — no storage, exact restart.
    """

    n_docs: int
    seq_len: int
    vocab_size: int
    n_topics: int = 16
    seed: int = 0

    def example(self, i: int) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng((self.seed, i))
        topic = i % self.n_topics
        # topic-specific token distribution: zipf re-ranked by a topic perm
        topic_rng = np.random.default_rng((self.seed, 0x7091C, topic))
        perm = topic_rng.permutation(self.vocab_size)
        ranks = rng.zipf(1.3, size=self.seq_len + 1) % self.vocab_size
        toks = perm[ranks]
        return toks[:-1].astype(np.int32), toks[1:].astype(np.int32)

    def batch(self, idx: np.ndarray) -> dict[str, np.ndarray]:
        pairs = [self.example(int(i)) for i in idx]
        toks = np.stack([p[0] for p in pairs])
        labels = np.stack([p[1] for p in pairs])
        return {"tokens": toks, "labels": labels}

    def class_labels(self, idx: np.ndarray) -> np.ndarray:
        """Per-document topic ids — the class signal for per-class CRAIG
        selection on the LM path (gradient proxies cluster by topic)."""
        return (np.asarray(idx, np.int64) % self.n_topics).astype(np.int32)
