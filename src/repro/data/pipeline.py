"""Data pipeline: coreset-aware sampling, global-batch assembly, host
sharding, and background prefetch.

The pipeline composes three layers:

  CoresetSampler   — yields (indices, γ weights) per step.  In `full` mode it
                     is a plain shuffled epoch iterator (γ=1); after a CRAIG
                     refresh it iterates the weighted coreset (paper Eq. 20:
                     every epoch visits each selected element once, with its
                     per-element stepsize γ_j).  Refreshes install through
                     ``set_coreset_from_selection`` — engine-agnostic, so the
                     same path serves the dense engines and the O(n·k)
                     ``engine='sparse'`` selector that large pools need
                     (README §Engines).
  GlobalBatcher    — materializes {tokens, labels, weights} numpy batches
                     from an index-addressable dataset.
  Prefetcher       — background thread, depth-k queue (overlaps host data
                     work with device compute).

Determinism/fault-tolerance contract: state = (epoch, step_in_epoch,
coreset snapshot).  `state_dict()`/`load_state_dict()` round-trip exactly;
a restarted trainer sees the identical stream (tests/test_data.py).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

__all__ = ["CoresetSampler", "GlobalBatcher", "Prefetcher"]


class CoresetSampler:
    """Per-epoch index/weight sampler with optional active coreset."""

    def __init__(self, n: int, batch: int, seed: int = 0):
        self.n = n
        self.batch = batch
        self.seed = seed
        self.epoch = 0
        self.step_in_epoch = 0
        self._indices: np.ndarray | None = None  # active coreset (None=full)
        self._weights: np.ndarray | None = None

    # -- coreset management ---------------------------------------------

    def set_coreset(
        self,
        indices: np.ndarray,
        weights: np.ndarray,
        keep_order: bool = False,
    ) -> None:
        """keep_order=True preserves the greedy selection order (paper §3.2:
        early elements carry most of the gradient approximation — useful for
        curriculum-style first epochs); default canonicalizes by index."""
        if keep_order:
            self._indices = np.asarray(indices)
            self._weights = np.asarray(weights, np.float32)
        else:
            order = np.argsort(indices)
            self._indices = np.asarray(indices)[order]
            self._weights = np.asarray(weights, np.float32)[order]

    def set_coreset_from_selection(
        self,
        selection,
        pool_indices: np.ndarray | None = None,
        keep_order: bool = False,
    ) -> None:
        """Install a ``CoresetSelection`` as the active coreset.

        ``pool_indices`` maps selection positions back to corpus positions
        when selection ran over a strided/sampled candidate pool (the
        trainer's refresh path); None means the selection indexed the corpus
        directly.
        """
        idx = np.asarray(selection.indices)
        if pool_indices is not None:
            idx = np.asarray(pool_indices)[idx]
        self.set_coreset(idx, selection.weights, keep_order=keep_order)

    def clear_coreset(self) -> None:
        self._indices = self._weights = None

    @property
    def active_size(self) -> int:
        return self.n if self._indices is None else len(self._indices)

    @property
    def steps_per_epoch(self) -> int:
        return max(1, self.active_size // self.batch)

    # -- iteration --------------------------------------------------------

    def _epoch_perm(self) -> np.ndarray:
        rng = np.random.default_rng((self.seed, self.epoch))
        return rng.permutation(self.active_size)

    def next_batch(self) -> tuple[np.ndarray, np.ndarray]:
        """Returns (pool indices (B,), γ weights (B,)) and advances."""
        perm = self._epoch_perm()
        lo = self.step_in_epoch * self.batch
        sel = perm[lo : lo + self.batch]
        if len(sel) < self.batch:  # wrap within epoch (drop-last semantics)
            sel = np.concatenate([sel, perm[: self.batch - len(sel)]])
        if self._indices is None:
            idx = sel
            w = np.ones((self.batch,), np.float32)
        else:
            idx = self._indices[sel]
            w = self._weights[sel]
            # normalize weights to mean≈1 so the lr scale is comparable to
            # full-data training (γ sums to n over the coreset's r elements)
            w = w * (len(self._indices) / max(self._weights.sum(), 1e-9))
        self.step_in_epoch += 1
        if self.step_in_epoch >= self.steps_per_epoch:
            self.step_in_epoch = 0
            self.epoch += 1
        return idx, w.astype(np.float32)

    # -- fault tolerance ----------------------------------------------------

    def state_dict(self) -> dict:
        return {
            "epoch": self.epoch,
            "step_in_epoch": self.step_in_epoch,
            "indices": None if self._indices is None else self._indices.tolist(),
            "weights": None if self._weights is None else self._weights.tolist(),
        }

    def load_state_dict(self, s: dict) -> None:
        self.epoch = int(s["epoch"])
        self.step_in_epoch = int(s["step_in_epoch"])
        if s["indices"] is None:
            self.clear_coreset()
        else:
            self._indices = np.asarray(s["indices"], np.int64)
            self._weights = np.asarray(s["weights"], np.float32)

    def skip_to(self, epoch: int, step_in_epoch: int) -> None:
        """Straggler/restart skip-ahead: O(1), no data regeneration."""
        self.epoch = epoch
        self.step_in_epoch = step_in_epoch


class GlobalBatcher:
    """Assembles model-ready global batches from an indexable dataset."""

    def __init__(self, dataset, sampler: CoresetSampler):
        self.dataset = dataset
        self.sampler = sampler

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        while True:
            yield self.next()

    def next(self) -> dict[str, np.ndarray]:
        idx, w = self.sampler.next_batch()
        batch = self.dataset.batch(idx)
        batch["weights"] = w
        batch["indices"] = idx.astype(np.int64)
        return batch


class Prefetcher:
    """Depth-k background prefetch of host batches."""

    def __init__(self, it, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self._q.put(item)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
