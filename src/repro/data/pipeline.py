"""Data pipeline: coreset-aware sampling, global-batch assembly, host
sharding, and background prefetch.

The pipeline composes three layers:

  CoresetSampler   — yields (indices, γ weights) per step.  In `full` mode it
                     is a plain shuffled epoch iterator (γ=1); after a CRAIG
                     refresh it iterates the weighted coreset (paper Eq. 20:
                     every epoch visits each selected element once, with its
                     per-element stepsize γ_j).  Refreshes install through
                     ``set_coreset_from_selection`` — engine-agnostic behind
                     the ``repro.core.engines`` registry, so the same path
                     serves the dense engines and the O(n·k) sparse engine
                     (``engines.SparseConfig``) that large pools need; the
                     staged ``meta`` carries the resolved ``EngineConfig``
                     dict for provenance (README §Engines).  The async
                     refresh path (DESIGN.md
                     §4) is double-buffered: a background selection is
                     ``stage``d (versioned back buffer, any thread) and the
                     trainer ``install_pending``s it atomically at a step
                     boundary; both buffers round-trip through
                     ``state_dict``, so a checkpoint taken between publish
                     and install loses nothing.
  GlobalBatcher    — materializes {tokens, labels, weights} numpy batches
                     from an index-addressable dataset.
  Prefetcher       — background thread, depth-k queue (overlaps host data
                     work with device compute).

Determinism/fault-tolerance contract: state = (epoch, step_in_epoch,
installed coreset + version, staged coreset + version).  `state_dict()`/
`load_state_dict()` round-trip exactly; a restarted trainer sees the
identical stream (tests/test_data.py, tests/test_refresh.py).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

__all__ = ["CoresetSampler", "GlobalBatcher", "Prefetcher"]


class CoresetSampler:
    """Per-epoch index/weight sampler with optional active coreset."""

    def __init__(self, n: int, batch: int, seed: int = 0):
        self.n = n
        self.batch = batch
        self.seed = seed
        self.epoch = 0
        self.step_in_epoch = 0
        self.version = 0  # version of the installed coreset (0 = full data)
        self._indices: np.ndarray | None = None  # active coreset (None=full)
        self._weights: np.ndarray | None = None
        self._pending: dict | None = None  # staged back buffer (see stage())
        self._lock = threading.Lock()

    # -- coreset management ---------------------------------------------

    def set_coreset(
        self,
        indices: np.ndarray,
        weights: np.ndarray,
        keep_order: bool = False,
        version: int | None = None,
    ) -> None:
        """keep_order=True preserves the greedy selection order (paper §3.2:
        early elements carry most of the gradient approximation — useful for
        curriculum-style first epochs); default canonicalizes by index."""
        idx, w = self._canonicalize(indices, weights, keep_order)
        with self._lock:
            self._indices, self._weights = idx, w
            self.version = self.version + 1 if version is None else int(version)

    def set_coreset_from_selection(
        self,
        selection,
        pool_indices: np.ndarray | None = None,
        keep_order: bool = False,
    ) -> None:
        """Install a ``CoresetSelection`` as the active coreset.

        ``pool_indices`` maps selection positions back to corpus positions
        when selection ran over a strided/sampled candidate pool (the
        trainer's refresh path); None means the selection indexed the corpus
        directly.
        """
        idx = np.asarray(selection.indices)
        if pool_indices is not None:
            idx = np.asarray(pool_indices)[idx]
        self.set_coreset(idx, selection.weights, keep_order=keep_order)

    def clear_coreset(self) -> None:
        with self._lock:
            self._indices = self._weights = None
            self._pending = None
            self.version = 0

    # -- versioned double buffer (async refresh, DESIGN.md §4) ------------

    @staticmethod
    def _canonicalize(indices, weights, keep_order: bool):
        idx = np.asarray(indices)
        w = np.asarray(weights, np.float32)
        if not keep_order:
            order = np.argsort(idx)
            idx, w = idx[order], w[order]
        return idx, w

    def stage(
        self,
        indices: np.ndarray,
        weights: np.ndarray,
        version: int | None = None,
        meta: dict | None = None,
        keep_order: bool = False,
    ) -> int:
        """Publish a refresh into the back buffer (callable from any thread).

        The staged coreset does not affect iteration until the owner of the
        step loop calls :meth:`install_pending` at a step boundary.  ``meta``
        is an arbitrary JSON-able payload (ε̂, selection wall-clock, …) that
        rides along through checkpoints.  Returns the staged version.
        """
        idx, w = self._canonicalize(indices, weights, keep_order)
        with self._lock:
            if version is None:
                version = self.version + 1
            self._pending = {
                "version": int(version),
                "indices": idx,
                "weights": w,
                "meta": meta,
            }
            return int(version)

    @property
    def has_pending(self) -> bool:
        return self._pending is not None

    @property
    def pending_version(self) -> int | None:
        p = self._pending
        return None if p is None else p["version"]

    def install_pending(self) -> dict | None:
        """Atomically swap the staged back buffer in as the active coreset.

        Call only from the thread that owns iteration, at a step boundary
        (the cursor semantics of an epoch assume a fixed active set).
        Returns the installed record ({version, indices, weights, meta}) or
        None when nothing is staged.
        """
        with self._lock:
            if self._pending is None:
                return None
            p, self._pending = self._pending, None
            self._indices = p["indices"]
            self._weights = p["weights"]
            self.version = p["version"]
            return p

    @property
    def active_size(self) -> int:
        return self.n if self._indices is None else len(self._indices)

    @property
    def steps_per_epoch(self) -> int:
        return max(1, self.active_size // self.batch)

    # -- iteration --------------------------------------------------------

    def _epoch_perm(self) -> np.ndarray:
        rng = np.random.default_rng((self.seed, self.epoch))
        return rng.permutation(self.active_size)

    def next_batch(self) -> tuple[np.ndarray, np.ndarray]:
        """Returns (pool indices (B,), γ weights (B,)) and advances."""
        perm = self._epoch_perm()
        lo = self.step_in_epoch * self.batch
        sel = perm[lo : lo + self.batch]
        if len(sel) < self.batch:  # wrap within epoch (drop-last semantics)
            sel = np.concatenate([sel, perm[: self.batch - len(sel)]])
        if self._indices is None:
            idx = sel
            w = np.ones((self.batch,), np.float32)
        else:
            idx = self._indices[sel]
            w = self._weights[sel]
            # normalize weights to mean≈1 so the lr scale is comparable to
            # full-data training (γ sums to n over the coreset's r elements)
            w = w * (len(self._indices) / max(self._weights.sum(), 1e-9))
        self.step_in_epoch += 1
        if self.step_in_epoch >= self.steps_per_epoch:
            self.step_in_epoch = 0
            self.epoch += 1
        return idx, w.astype(np.float32)

    # -- fault tolerance ----------------------------------------------------

    def state_dict(self) -> dict:
        """JSON-able snapshot: cursor + installed front buffer + staged back
        buffer — a checkpoint between publish and install loses nothing."""
        with self._lock:
            pending = None
            if self._pending is not None:
                pending = {
                    "version": self._pending["version"],
                    "indices": self._pending["indices"].tolist(),
                    "weights": self._pending["weights"].tolist(),
                    "meta": self._pending["meta"],
                }
            return {
                "epoch": self.epoch,
                "step_in_epoch": self.step_in_epoch,
                "version": self.version,
                "indices": None if self._indices is None else self._indices.tolist(),
                "weights": None if self._weights is None else self._weights.tolist(),
                "pending": pending,
            }

    def load_state_dict(self, s: dict) -> None:
        self.epoch = int(s["epoch"])
        self.step_in_epoch = int(s["step_in_epoch"])
        if s["indices"] is None:
            self.clear_coreset()
        else:
            with self._lock:
                self._indices = np.asarray(s["indices"], np.int64)
                self._weights = np.asarray(s["weights"], np.float32)
        # version/pending are absent in pre-refresh checkpoints
        version = int(s.get("version", 0 if s["indices"] is None else 1))
        with self._lock:
            self.version = version
        p = s.get("pending")
        if p is not None:
            self.stage(
                np.asarray(p["indices"], np.int64),
                np.asarray(p["weights"], np.float32),
                version=int(p["version"]),
                meta=p.get("meta"),
                keep_order=True,  # already canonicalized when staged
            )
        else:
            with self._lock:
                self._pending = None

    def skip_to(self, epoch: int, step_in_epoch: int) -> None:
        """Straggler/restart skip-ahead: O(1), no data regeneration."""
        self.epoch = epoch
        self.step_in_epoch = step_in_epoch


class GlobalBatcher:
    """Assembles model-ready global batches from an indexable dataset."""

    def __init__(self, dataset, sampler: CoresetSampler):
        self.dataset = dataset
        self.sampler = sampler

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        while True:
            yield self.next()

    def next(self) -> dict[str, np.ndarray]:
        idx, w = self.sampler.next_batch()
        batch = self.dataset.batch(idx)
        batch["weights"] = w
        batch["indices"] = idx.astype(np.int64)
        return batch


class _WorkerFailed:
    """Queue sentinel carrying the prefetch worker's exception."""

    def __init__(self, exc: BaseException):
        self.exc = exc


class _WorkerDone:
    """Queue sentinel: the wrapped iterator is exhausted."""


class Prefetcher:
    """Depth-k background prefetch of host batches.

    Worker outcomes travel through the queue itself: an exception or
    exhaustion in the wrapped iterator is re-raised (or raises
    StopIteration) from ``next()`` on the consumer thread instead of dying
    silently on the worker and leaving ``next()`` blocked forever.
    """

    def __init__(self, it, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            try:
                for item in it:
                    if self._stop.is_set():
                        return
                    self._q.put(item)
                self._q.put(_WorkerDone())
            except BaseException as e:
                self._q.put(_WorkerFailed(e))

        self._t = threading.Thread(
            target=worker, name="prefetcher", daemon=True
        )
        self._t.start()

    def next(self):
        item = self._q.get()
        if isinstance(item, _WorkerFailed):
            raise RuntimeError("prefetch worker failed") from item.exc
        if isinstance(item, _WorkerDone):
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        # Drain until the worker (possibly blocked on a full queue) observes
        # the stop flag and exits; daemon status still covers a source
        # iterator wedged inside its own next().
        while self._t.is_alive():
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._t.join(timeout=0.1)
