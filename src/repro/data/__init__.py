"""Data substrate: synthetic corpora, coreset sampler, prefetch pipeline."""
from repro.data.pipeline import CoresetSampler, GlobalBatcher, Prefetcher
from repro.data.synthetic import GaussianMixture, TokenStream, make_classification

__all__ = [
    "CoresetSampler", "GlobalBatcher", "Prefetcher",
    "GaussianMixture", "TokenStream", "make_classification",
]
