"""Serving driver: batched greedy decoding against any registry arch, or
the coreset service behind a JSON-lines protocol.

Decode mode:

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 16 --new 32

Coreset-as-a-service mode (DESIGN.md §10) — one JSON request per stdin
line, one JSON response per stdout line:

    PYTHONPATH=src python -m repro.launch.serve --coreset --budget 32 --dim 8

    {"op": "delta", "feats": [[...], ...], "labels": [...]?}
        -> {"ok": true, "version": v, "n_seen": n}
    {"op": "coreset"}
        -> {"ok": true, "version": v, "indices": [...], "gamma": [...],
            "n_seen": n, "n_live": l, "coverage": c}
    {"op": "quit"}   -> {"ok": true, "bye": true}
    anything invalid -> {"ok": false, "error": "..."}   (service keeps running)

Pod-scale decode lowering (KV cache sharded per distributed/sharding.py)
is exercised by `launch/dryrun.py --shape decode_32k / long_500k`.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time

import jax
import numpy as np

from repro.configs.registry import ARCHS, get_config, smoke_config
from repro.models import init_params
from repro.serve import greedy_generate


def _serve_coreset(args, stdin=None, stdout=None) -> None:
    """JSON-lines loop over a CoresetService (sync mode: the response to a
    delta is only written once its drain has published)."""
    from repro.core.engines import StreamingConfig
    from repro.faults import FailurePolicy, install_from_env
    from repro.serve import CoresetService

    install_from_env()  # chaos tests arm the service via $REPRO_FAULT_PLAN
    stdin = sys.stdin if stdin is None else stdin
    stdout = sys.stdout if stdout is None else stdout
    svc = CoresetService(
        args.budget,
        args.dim,
        config=StreamingConfig(eps=args.eps, levels=args.levels),
        metric=args.metric,
        per_class=args.per_class,
        mode="sync",
        evict=args.evict,
        failure_policy=FailurePolicy(
            max_retries=args.ingest_retries,
            backoff_base_s=args.ingest_backoff_s,
            on_exhaustion=args.on_exhaustion,
        ),
    )

    def reply(obj: dict) -> None:
        stdout.write(json.dumps(obj) + "\n")
        stdout.flush()

    for line in stdin:
        line = line.strip()
        if not line:
            continue
        try:
            req = json.loads(line)
            op = req.get("op")
            if op == "delta":
                version = svc.submit_delta(req["feats"], req.get("labels"))
                failure = svc.pop_failure()
                if failure is not None:
                    # keep_stale abandonment: the drain was dropped, the
                    # installed selection is unchanged — tell the client
                    # explicitly instead of letting the version stall
                    reply({"ok": False, "n_seen": svc.n_seen, **failure})
                else:
                    reply(
                        {"ok": True, "version": version, "n_seen": svc.n_seen}
                    )
            elif op == "coreset":
                u = svc.coreset(block=True)
                if u is None:
                    reply({"ok": False, "error": "no deltas ingested yet"})
                else:
                    reply(
                        {
                            "ok": True,
                            "version": u.version,
                            "indices": u.indices.tolist(),
                            "gamma": u.weights.tolist(),
                            "n_seen": u.n_seen,
                            "n_live": u.n_live,
                            "coverage": u.coverage,
                        }
                    )
            elif op == "quit":
                reply({"ok": True, "bye": True})
                return
            else:
                reply({"ok": False, "error": f"unknown op {op!r}"})
        except Exception as e:  # noqa: BLE001 — protocol errors go to the client
            reply({"ok": False, "error": f"{type(e).__name__}: {e}"})


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=32)
    # coreset service mode
    ap.add_argument("--coreset", action="store_true",
                    help="run the JSON-lines coreset service instead of decode")
    ap.add_argument("--budget", type=int, default=32)
    ap.add_argument("--dim", type=int, default=8)
    ap.add_argument("--metric", default="l2", choices=("l2", "cosine"))
    ap.add_argument("--per-class", action="store_true")
    ap.add_argument("--eps", type=float, default=0.15)
    ap.add_argument("--levels", type=int, default=0)
    ap.add_argument("--evict", action="store_true",
                    help="bounded-memory mode: drop pool rows no sieve "
                         "references after every drain (O(L·k·d) state)")
    ap.add_argument("--ingest-retries", type=int, default=0,
                    help="retries per ingest drain before the exhaustion "
                         "policy applies (DESIGN.md §12)")
    ap.add_argument("--ingest-backoff-s", type=float, default=0.05,
                    help="base of the exponential retry backoff")
    ap.add_argument("--on-exhaustion", default="raise",
                    choices=("raise", "keep_stale"),
                    help="'raise' fails the request; 'keep_stale' keeps "
                         "serving the installed selection and replies with "
                         "a craig_refresh_failed event")
    args = ap.parse_args(argv)

    if args.coreset:
        _serve_coreset(args)
        return
    if args.arch is None:
        ap.error("--arch is required unless --coreset is given")

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.frontend != "tokens":
        cfg = dataclasses.replace(cfg, frontend="tokens")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    out = greedy_generate(params, cfg, prompts, max_new=args.new)
    dt = time.time() - t0
    n_tok = args.batch * (args.prompt_len + args.new)
    print(f"{cfg.name}: {out.shape} in {dt:.2f}s ({n_tok/dt:.0f} tok/s)")
    print("sample:", np.asarray(out[0, args.prompt_len:args.prompt_len + 12]))


if __name__ == "__main__":
    main()
