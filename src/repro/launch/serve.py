"""Serving driver: batched greedy decoding against any registry arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --smoke \
        --batch 4 --prompt-len 16 --new 32

Pod-scale decode lowering (KV cache sharded per distributed/sharding.py)
is exercised by `launch/dryrun.py --shape decode_32k / long_500k`.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.registry import ARCHS, get_config, smoke_config
from repro.models import init_params
from repro.serve import greedy_generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=32)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.frontend != "tokens":
        cfg = dataclasses.replace(cfg, frontend="tokens")
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    t0 = time.time()
    out = greedy_generate(params, cfg, prompts, max_new=args.new)
    dt = time.time() - t0
    n_tok = args.batch * (args.prompt_len + args.new)
    print(f"{cfg.name}: {out.shape} in {dt:.2f}s ({n_tok/dt:.0f} tok/s)")
    print("sample:", np.asarray(out[0, args.prompt_len:args.prompt_len + 12]))


if __name__ == "__main__":
    main()
