"""Multi-process entry point for hierarchical tree selection.

Bootstraps the ``jax.distributed`` coordination service (process mesh)
and runs one tree selection over a synthetic clustered pool — the
smallest end-to-end exercise of the multi-host path, and what the tier-2
multi-process CI lane launches (2 real processes on CPU).

Launch line (one per process)::

    PYTHONPATH=src python -m repro.launch.tree \
        --coordinator 127.0.0.1:8476 --num-processes 2 --process-id $i \
        --fanouts 2 --n 256 --d 32 --r-local 8 --r-final 10

On CPU the driver is ``tree_select_processes`` (KV-store wire — XLA CPU
has no cross-process collectives); pass ``--driver mesh`` on TPU/GPU
pods to run the single-program ``tree_select_mesh`` over the global
device mesh instead.
"""
from __future__ import annotations

import argparse
import json
import os

__all__ = ["initialize_distributed", "make_tree_mesh", "main"]


def initialize_distributed(
    coordinator_address: str | None = None,
    num_processes: int | None = None,
    process_id: int | None = None,
) -> None:
    """``jax.distributed.initialize`` with explicit-args-else-environment
    semantics (env: ``JAX_COORDINATOR_ADDRESS`` / ``JAX_NUM_PROCESSES`` /
    ``JAX_PROCESS_ID``, or a cloud auto-detect where jax supports one).
    Must run before any other jax call in every process; idempotence is
    delegated to jax (re-initialization raises there)."""
    import jax

    kwargs = {}
    if coordinator_address is not None:
        kwargs["coordinator_address"] = coordinator_address
    if num_processes is not None:
        kwargs["num_processes"] = num_processes
    if process_id is not None:
        kwargs["process_id"] = process_id
    jax.distributed.initialize(**kwargs)


def make_tree_mesh(fanouts: tuple[int, ...]):
    """Level-axis mesh over ALL devices (spans processes under
    ``jax.distributed``) for ``tree_select_mesh``."""
    from repro.distributed.tree_select import TreeTopology, tree_mesh

    return tree_mesh(TreeTopology(fanouts))


def _synthetic_pool(n: int, d: int, seed: int):
    """Deterministic clustered pool — identical on every process (same
    seed), so each process can slice its own shard without any I/O."""
    import jax
    import jax.numpy as jnp

    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    centers = jax.random.normal(k0, (8, d)) * 5.0
    assign = jax.random.randint(k1, (n,), 0, 8)
    return centers[assign] + jax.random.normal(k2, (n, d)) * 0.3


def main(argv: list[str] | None = None) -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--coordinator", default=None,
                   help="host:port of process 0 (else env/auto-detect)")
    p.add_argument("--num-processes", type=int, default=None)
    p.add_argument("--process-id", type=int, default=None)
    p.add_argument("--fanouts", default="2",
                   help="comma-separated leaf→root fan-outs, e.g. 4,2")
    p.add_argument("--n", type=int, default=256)
    p.add_argument("--d", type=int, default=32)
    p.add_argument("--r-local", type=int, default=8)
    p.add_argument("--r-final", type=int, default=10)
    p.add_argument("--compress", default="int8", choices=("int8", "none"))
    p.add_argument("--driver", default="processes",
                   choices=("processes", "mesh"))
    p.add_argument("--seed", type=int, default=0)
    # liveness/degradation knobs (processes driver; DESIGN.md §12)
    p.add_argument("--level-deadline-s", type=float, default=None,
                   help="per-level wait before a child subtree is declared "
                        "dead (default: $REPRO_KV_TIMEOUT_MS, 300 s)")
    p.add_argument("--min-quorum", type=float, default=1.0,
                   help="minimum surviving-leaf fraction; below it the "
                        "selection fails instead of degrading")
    p.add_argument("--heartbeat-interval-s", type=float, default=0.5)
    p.add_argument("--heartbeat-grace-s", type=float, default=5.0)
    args = p.parse_args(argv)

    # chaos lanes arm per-process faults via $REPRO_FAULT_PLAN — installed
    # before any selection work so injected kills hit the intended site
    from repro.faults import install_from_env

    install_from_env()

    initialize_distributed(args.coordinator, args.num_processes, args.process_id)

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.distributed.tree_select import TreeTopology

    topology = TreeTopology(tuple(int(f) for f in args.fanouts.split(",")))
    feats = _synthetic_pool(args.n, args.d, args.seed)

    if args.driver == "mesh":
        from repro.distributed.tree_select import tree_mesh, tree_select_mesh

        sel = tree_select_mesh(
            feats, tree_mesh(topology), topology, args.r_local, args.r_final,
            compress=args.compress,
        )
    else:
        from repro.distributed.process_tree import (
            HealthConfig,
            tree_select_processes,
        )

        pid, nproc = jax.process_index(), jax.process_count()
        shard = np.array_split(np.arange(args.n), nproc)[pid]
        sel = tree_select_processes(
            feats[jnp.asarray(shard)], topology, args.r_local, args.r_final,
            compress=args.compress,
            health=HealthConfig(
                level_deadline_s=args.level_deadline_s,
                min_quorum=args.min_quorum,
                heartbeat_interval_s=args.heartbeat_interval_s,
                heartbeat_grace_s=args.heartbeat_grace_s,
            ),
        )

    record = {
        "process": int(jax.process_index()),
        "driver": args.driver,
        "fanouts": list(topology.fanouts),
        "compress": args.compress,
        "indices": np.asarray(sel.indices).tolist(),
        "r_final": int(np.asarray(sel.indices).shape[0]),
        "weight_sum": float(jnp.sum(sel.weights)),
        "coverage": float(sel.coverage),
        "wire_bytes": sel.wire["gathered_feature_bytes"],
        "wire_reduction": round(sel.wire["reduction"], 3),
        "health": sel.health,
    }
    print("TREE_SELECT_RESULT " + json.dumps(record), flush=True)

    if record["health"] and record["health"].get("degraded"):
        # the jax.distributed shutdown barrier needs EVERY task to check
        # in, and a degraded run by definition has dead tasks — skip the
        # barrier (and the eventual missed-heartbeat abort) instead of
        # blocking the survivors on peers that can never arrive
        if int(jax.process_index()) == 0:
            # pid 0 hosts the coordination service; closing it while other
            # survivors still run aborts their error-polling threads, so
            # the leader exits last (survivors only have local printing
            # left after the selection returns — seconds, not deadlines)
            import time

            time.sleep(5.0)
        os._exit(0)


if __name__ == "__main__":
    main()
