"""Production mesh builders.

Single pod: v5e 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2 pods = 512 chips, axes ("pod", "data", "model") — the pod axis
crosses DCN (pure data parallelism; see distributed/sharding.py).

``make_production_mesh`` is a function (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run must set XLA_FLAGS before *any* jax initialization.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh", "compat_mesh"]


def compat_mesh(shape, axes) -> jax.sharding.Mesh:
    """jax.make_mesh across jax versions: AxisType.Auto exists only ≥ 0.6;
    older releases reject the kwarg (and are implicitly-auto anyway)."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_mesh(shape, axes)


def make_host_mesh() -> jax.sharding.Mesh:
    """1-device mesh with the production axis names (CPU tests)."""
    return compat_mesh((1, 1), ("data", "model"))
