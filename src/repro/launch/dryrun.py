import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver builds the real SPMD program — γ-weighted
train_step (AdamW, microbatched), prefill_step, or serve_step with a
seq_len-deep cache — against ShapeDtypeStruct inputs (no allocation),
compiles it for the 256-chip single-pod / 512-chip two-pod mesh, and
records:

  * ``memory_analysis``  — bytes per device (proves the cell fits HBM),
  * ``cost_analysis``    — HLO FLOPs / bytes-accessed (roofline numerator),
  * collective byte census parsed from the post-SPMD optimized HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) — the roofline's collective term,
  * analytic MODEL_FLOPS (6·N·D; 6·N_active·D for MoE) for the
    useful-compute ratio.

Artifacts go to ``artifacts/dryrun/<arch>__<shape>__<mesh>.json``; the
roofline report (repro/roofline.py, EXPERIMENTS.md §Roofline) reads them.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b \
        --shape select_pool --mesh single     # CRAIG select_step cell
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_config
from repro.configs.shapes import SHAPES, ShapeSpec
from repro.distributed import annotate
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models import init_params, init_serve_state
from repro.models.config import ModelConfig
from repro.optim import adamw, warmup_cosine
from repro.serve import make_prefill_step, make_serve_step
from repro.train import make_select_step, make_train_step

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")

# Collective opcode census over post-SPMD optimized HLO.
_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def collective_census(hlo_text: str) -> dict:
    """Sum payload bytes per collective kind from optimized HLO text."""
    out: dict[str, dict] = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        size = 1
        for d in dims.split(","):
            if d:
                size *= int(d)
        b = size * _DTYPE_BYTES[dtype]
        rec = out.setdefault(kind, {"count": 0, "bytes": 0})
        rec["count"] += 1
        rec["bytes"] += b
    return out


def microbatches_for(shape: ShapeSpec, cfg: ModelConfig) -> int:
    if shape.kind != "train":
        return 1
    # keep per-microbatch tokens small enough that the layer-scan activation
    # carry + MoE dispatch buffers fit HBM; wide-MoE models halve again
    # NB: global_batch/mb must stay divisible by the 16..32-way dp axis —
    # smaller microbatches REPLICATE the batch dim and blow memory up
    per_mb_target = 16 if (cfg.d_model >= 6144 and cfg.n_experts) else 32
    return max(1, shape.global_batch // per_mb_target)


def train_batch_struct(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    B, T = shape.global_batch, shape.seq_len
    batch: dict = {}
    if cfg.frontend == "tokens":
        batch["tokens"] = jax.ShapeDtypeStruct((B, T), jnp.int32)
    else:
        batch["embeddings"] = jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.bfloat16)
    if cfg.n_codebooks > 1:
        batch["labels"] = jax.ShapeDtypeStruct((B, T, cfg.n_codebooks), jnp.int32)
    else:
        batch["labels"] = jax.ShapeDtypeStruct((B, T), jnp.int32)
    if cfg.mrope_sections is not None:
        batch["positions"] = jax.ShapeDtypeStruct((B, 3, T), jnp.int32)
    batch["weights"] = jax.ShapeDtypeStruct((B,), jnp.float32)
    return batch


def infer_batch_struct(cfg: ModelConfig, shape: ShapeSpec, decode: bool) -> dict:
    B = shape.global_batch
    T = 1 if decode else shape.seq_len
    batch: dict = {}
    if cfg.frontend == "tokens":
        batch["tokens"] = jax.ShapeDtypeStruct((B, T), jnp.int32)
    else:
        batch["embeddings"] = jax.ShapeDtypeStruct((B, T, cfg.d_model), jnp.bfloat16)
    if cfg.mrope_sections is not None and not decode:
        batch["positions"] = jax.ShapeDtypeStruct((B, 3, T), jnp.int32)
    return batch


def _struct_tree(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree
    )


def build_cell(arch: str, shape_name: str, mesh, probe: int = 0):
    """Returns (fn, in_shardings, out_shardings, args_struct, donate, meta).

    probe > 0 builds a reduced-depth UNROLLED variant (probe = number of
    pattern periods, scan_layers=False, microbatches=1) whose cost_analysis
    is exact — XLA counts ``lax.scan``/while bodies once, so the production
    scanned program underreports FLOPs/collectives.  The roofline combines
    probe1/probe2 deltas with the full-depth compile (repro/roofline.py).
    """
    cfg = get_config(arch)
    if probe:
        cfg = dataclasses.replace(
            cfg,
            n_layers=probe * len(cfg.block_pattern),
            scan_layers=False,
        )
    if shape_name == "select_pool":
        shape = ShapeSpec("select_pool", 4096, 256, "select")
    else:
        shape = SHAPES[shape_name]

    if shape.name == "long_500k" and not cfg.is_subquadratic:
        raise SkipCell(
            f"{arch} is full-attention; long_500k requires sub-quadratic "
            "architecture (DESIGN.md §Arch-applicability)"
        )

    # abstract params + shardings
    params_struct = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg)
    )
    pspecs = shd.param_specs(params_struct, mesh)
    psh = jax.tree.map(lambda s: jax.NamedSharding(mesh, s), pspecs)

    if shape.kind == "train":
        opt = adamw(warmup_cosine(3e-4, 2000, 100_000))
        opt_struct = jax.eval_shape(opt.init, params_struct)
        osh = shd.state_shardings(opt_struct, pspecs, mesh)
        mb = 1 if probe else microbatches_for(shape, cfg)
        fn = make_train_step(cfg, opt, microbatches=mb)
        batch = train_batch_struct(cfg, shape)
        bsh = {
            k: jax.NamedSharding(mesh, s)
            for k, s in shd.batch_specs(mesh, batch).items()
        }
        return dict(
            fn=fn,
            in_shardings=(psh, osh, bsh),
            out_shardings=(psh, osh, None),
            args=(params_struct, opt_struct, batch),
            donate=(0, 1),
            meta={"microbatches": mb, "step": "train_step"},
            cfg=cfg,
            shape=shape,
        )

    if shape.kind == "prefill":
        fn = make_prefill_step(cfg)
        batch = infer_batch_struct(cfg, shape, decode=False)
        bsh = {
            k: jax.NamedSharding(mesh, s)
            for k, s in shd.batch_specs(mesh, batch).items()
        }
        return dict(
            fn=fn,
            in_shardings=(psh, bsh),
            out_shardings=None,
            args=(params_struct, batch),
            donate=(),
            meta={"step": "prefill_step"},
            cfg=cfg,
            shape=shape,
        )

    if shape.kind == "decode":
        # Serving params: TP-only sharding (no ZeRO-3 — no optimizer state
        # to amortize; per-layer weight gathers would sit on the decode
        # critical path: §Perf iteration 1c).  Exception: batch < |data|
        # (long_500k batch=1) — there is no data-parallel replica to
        # amortize replicated weights, so ZeRO-3 storage stays cheaper.
        B = shape.global_batch
        if B >= mesh.shape.get("data", 1):
            pspecs = shd.serve_param_specs(params_struct, mesh)
            psh = jax.tree.map(lambda s: jax.NamedSharding(mesh, s), pspecs)
        fn = make_serve_step(cfg)
        state_struct = jax.eval_shape(
            lambda: init_serve_state(cfg, B, shape.seq_len)
        )
        ssh = shd.serve_state_specs(state_struct, mesh, B)
        batch = infer_batch_struct(cfg, shape, decode=True)
        bsh = {
            k: jax.NamedSharding(mesh, s)
            for k, s in shd.batch_specs(mesh, batch).items()
        }
        return dict(
            fn=fn,
            in_shardings=(psh, ssh, bsh),
            out_shardings=(None, ssh),
            args=(params_struct, state_struct, batch),
            donate=(1,),
            meta={"step": "serve_step", "cache_len": shape.seq_len},
            cfg=cfg,
            shape=shape,
        )

    if shape.kind == "select":
        # CRAIG selection forward: proxy features over a candidate pool.
        # Dense archs run in dp_over_model mode: the whole mesh acts as data
        # parallelism with ZeRO-3 weight gathers — for a forward-only
        # throughput program this beats TP by ~4x on collective bytes
        # (§Perf iteration 3).  MoE archs keep expert parallelism (gathering
        # E experts/layer/device would dwarf the activation traffic).
        dp_mode = cfg.n_experts == 0
        fn = make_select_step(cfg)
        batch = train_batch_struct(cfg, shape)
        batch.pop("weights")
        bsh = {
            k: jax.NamedSharding(mesh, s)
            for k, s in shd.batch_specs(
                mesh, batch, dp_over_model=dp_mode
            ).items()
        }
        return dict(
            fn=fn,
            in_shardings=(psh, bsh),
            out_shardings=None,
            args=(params_struct, batch),
            donate=(),
            meta={
                "step": "select_step",
                "mode": "dp_over_model" if dp_mode else "tp",
            },
            cfg=cfg,
            shape=shape,
            dp_over_model=dp_mode,
        )
    raise ValueError(shape.kind)


class SkipCell(Exception):
    pass


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Analytic MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference)."""
    n = cfg.active_param_count()
    d = shape.tokens_per_step
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n * d


def run_cell(
    arch: str, shape_name: str, mesh_kind: str, out_dir: str, probe: int = 0
) -> dict:
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": f"{'2x16x16' if multi else '16x16'}",
        "probe": probe,
        "n_devices": int(np.prod(list(mesh.shape.values()))),
        "status": "unknown",
    }
    t0 = time.time()
    try:
        cell = build_cell(arch, shape_name, mesh, probe=probe)
        annotate.set_mesh(mesh, dp_over_model=cell.get("dp_over_model", False))
        with mesh:
            jitted = jax.jit(
                cell["fn"],
                in_shardings=cell["in_shardings"],
                out_shardings=cell["out_shardings"],
                donate_argnums=cell["donate"],
            )
            lowered = jitted.lower(*cell["args"])
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
        annotate.set_mesh(None)
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_census(hlo)
        cfg, shape = cell["cfg"], cell["shape"]
        rec.update(
            status="ok",
            meta=cell["meta"],
            lower_s=round(t_lower - t0, 1),
            compile_s=round(t_compile - t_lower, 1),
            memory={
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(mem, k)
            },
            cost={
                k: float(cost.get(k, 0.0))
                for k in ("flops", "bytes accessed", "transcendentals")
                if cost
            },
            collectives=coll,
            collective_bytes_total=int(sum(c["bytes"] for c in coll.values())),
            model_flops=model_flops(cfg, shape),
            params=cfg.param_count(),
            active_params=cfg.active_param_count(),
            tokens_per_step=shape.tokens_per_step,
            hlo_lines=hlo.count("\n"),
        )
    except SkipCell as e:
        rec.update(status="skip", reason=str(e))
    except Exception as e:  # noqa: BLE001 — record the failure, don't crash the sweep
        rec.update(
            status="error",
            error=f"{type(e).__name__}: {e}",
            traceback=traceback.format_exc()[-4000:],
        )
    finally:
        annotate.set_mesh(None)
    rec["wall_s"] = round(time.time() - t0, 1)
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__p{probe}" if probe else ""
    path = os.path.join(
        out_dir, f"{arch}__{shape_name}__{rec['mesh']}{suffix}.json"
    )
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS) + ["all"], default="all")
    ap.add_argument(
        "--shape", choices=list(SHAPES) + ["select_pool", "all"], default="all"
    )
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default=os.path.normpath(ARTIFACT_DIR))
    ap.add_argument("--force", action="store_true", help="recompute existing")
    ap.add_argument(
        "--probes",
        action="store_true",
        help="also build 1- and 2-period unrolled probe cells (single mesh)",
    )
    ap.add_argument("--probes-only", action="store_true")
    args = ap.parse_args()

    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                mesh_tag = "2x16x16" if mesh_kind == "multi" else "16x16"
                probes = [0]
                if args.probes and mesh_kind == "single":
                    probes = [0, 1, 2]
                if args.probes_only:
                    probes = [1, 2] if mesh_kind == "single" else []
                for probe in probes:
                    suffix = f"__p{probe}" if probe else ""
                    path = os.path.join(
                        args.out, f"{arch}__{shape}__{mesh_tag}{suffix}.json"
                    )
                    if os.path.exists(path) and not args.force:
                        with open(path) as f:
                            prev = json.load(f)
                        if prev.get("status") in ("ok", "skip"):
                            print(
                                f"[cached] {arch} {shape} {mesh_tag}"
                                f"{suffix}: {prev['status']}",
                                flush=True,
                            )
                            continue
                    rec = run_cell(arch, shape, mesh_kind, args.out, probe=probe)
                    line = (
                        f"[{rec['status']:5s}] {arch} {shape} {mesh_tag}"
                        f"{suffix} wall={rec['wall_s']}s"
                    )
                    if rec["status"] == "ok":
                        line += (
                            f" flops={rec['cost'].get('flops', 0):.3g}"
                            f" coll={rec['collective_bytes_total']:.3g}B"
                            f" hlo={rec['hlo_lines']}"
                        )
                    elif rec["status"] == "error":
                        line += f" {rec['error'][:160]}"
                        failures += 1
                    print(line, flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
