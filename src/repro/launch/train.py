"""Production training driver.

Single-host usage (CPU smoke / debugging):

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
        --steps 20 --batch 8 --seq 64

On a pod, the same driver runs under the production mesh: every jitted step
is sharded via the rules in distributed/sharding.py; `--dry-run` lowers and
compiles the full-scale program instead of executing (see launch/dryrun.py
for the batched sweep).

Features wired in: CRAIG per-epoch coreset refresh (--craig-fraction),
microbatched grad accumulation, checkpoint/restart (--ckpt), preemption
(SIGTERM → emergency save), deterministic restart stream.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs.registry import ARCHS, get_config, smoke_config
from repro.core.craig import CraigConfig
from repro.data.synthetic import TokenStream
from repro.models import init_params
from repro.optim import adamw, warmup_cosine
from repro.train import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--docs", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--craig-fraction", type=float, default=0.5)
    ap.add_argument("--no-craig", action="store_true")
    ap.add_argument("--select-every", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.frontend != "tokens":
        # stub-frontend archs train over precomputed embeddings; the
        # synthetic stream provides tokens — swap to token frontend for the
        # driver (backbone identical), as the modality stub is data-side.
        cfg = dataclasses.replace(cfg, frontend="tokens")
    print(f"arch={cfg.name} ({'smoke' if args.smoke else 'full'}) "
          f"params≈{cfg.param_count()/1e6:.1f}M layers={cfg.n_layers}")

    ds = TokenStream(n_docs=args.docs, seq_len=args.seq,
                     vocab_size=cfg.vocab_size, n_topics=16)
    tcfg = TrainerConfig(
        batch_size=args.batch,
        select_every_epochs=0 if args.no_craig else args.select_every,
        use_craig=not args.no_craig,
        craig=CraigConfig(fraction=args.craig_fraction, per_class=False),
        proxy_pool_batches=max(1, args.docs // args.batch),
        checkpoint_dir=args.ckpt,
        microbatches=args.microbatches,
    )
    trainer = Trainer(
        cfg, tcfg, ds, adamw(warmup_cosine(args.lr, 10, args.steps)),
        lambda: init_params(jax.random.PRNGKey(0), cfg),
    )
    trainer.install_signal_handler()
    if trainer.restore_or_init():
        print(f"restored at step {trainer.step}")
    t0 = time.time()
    log = trainer.run(args.steps)
    steps = [m for m in log if m["event"] == "step"]
    print(f"{len(steps)} steps in {time.time()-t0:.1f}s; "
          f"loss {steps[0]['loss']:.3f} → {np.mean([s['loss'] for s in steps[-5:]]):.3f}")


if __name__ == "__main__":
    main()
