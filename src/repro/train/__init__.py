"""Training substrate: pjit step factories + host trainer loop."""
from repro.train.train_step import make_select_step, make_train_step
from repro.train.trainer import Trainer, TrainerConfig

__all__ = ["make_select_step", "make_train_step", "Trainer", "TrainerConfig"]
