"""Trainer: host loop tying CRAIG selection into the training schedule.

Responsibilities (DESIGN.md §4):
  * CRAIG refresh every ``select_every`` epochs (paper §3.4: deep-net proxies
    drift with w, so the subset is re-selected periodically; Fig 5 sweeps
    per-1 and per-5-epoch refresh);
  * weighted-batch training between refreshes (γ weights ride in the batch);
  * checkpoint/restart: params + opt state + sampler cursor + active coreset
    are one atomic unit; ``Trainer.restore_or_init`` resumes the exact
    stream, optionally onto a different mesh (elastic);
  * preemption: SIGTERM triggers an emergency checkpoint at the next step
    boundary (CPU-testable via ``request_preempt()``);
  * straggler policy: per-step wall-clock watchdog — on the single-host
    harness it only records violations; on a pod it feeds the
    restart-from-checkpoint path.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.craig import CraigConfig, CraigSelector
from repro.data.pipeline import CoresetSampler
from repro.models.config import ModelConfig
from repro.optim.optimizers import Optimizer
from repro.train.train_step import make_select_step, make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    batch_size: int = 8
    eval_every: int = 0  # steps between held-out evals (0 = never)
    eval_batches: int = 2
    select_every_epochs: int = 1  # CRAIG refresh cadence (0 = never)
    craig: CraigConfig = dataclasses.field(
        default_factory=lambda: CraigConfig(fraction=0.5, per_class=False)
    )
    use_craig: bool = True
    proxy_pool_batches: int = 8  # batches of the pool scanned per refresh
    checkpoint_every: int = 50
    checkpoint_dir: str | None = None
    keep_checkpoints: int = 3
    step_timeout_s: float | None = None  # straggler watchdog
    microbatches: int = 1
    seed: int = 0


class Trainer:
    """Single-controller trainer (CPU-testable; sharding-transparent)."""

    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainerConfig,
        dataset,
        optimizer: Optimizer,
        init_params_fn: Callable[[], Any],
        eval_dataset=None,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.dataset = dataset
        self.eval_dataset = eval_dataset
        self.optimizer = optimizer
        self.sampler = CoresetSampler(dataset.n_docs, tcfg.batch_size, tcfg.seed)
        self.train_step = jax.jit(
            make_train_step(cfg, optimizer, microbatches=tcfg.microbatches)
        )
        self.select_step = jax.jit(make_select_step(cfg))
        self.params = init_params_fn()
        self.opt_state = optimizer.init(self.params)
        self.step = 0
        self.metrics_log: list[dict] = []
        self.straggler_events: list[int] = []
        self._preempt = False
        self.ckpt = (
            CheckpointManager(tcfg.checkpoint_dir, tcfg.keep_checkpoints)
            if tcfg.checkpoint_dir
            else None
        )
        self._last_epoch_selected = -1
        from repro.models import loss_fn as _loss_fn

        self._eval_loss = jax.jit(
            lambda p, b: _loss_fn(p, cfg, b)[1]["loss"]
        )

    # -- preemption -----------------------------------------------------------

    def install_signal_handler(self) -> None:
        signal.signal(signal.SIGTERM, lambda *_: self.request_preempt())

    def request_preempt(self) -> None:
        self._preempt = True

    # -- CRAIG refresh ---------------------------------------------------------

    def _refresh_coreset(self) -> None:
        """Extract proxies over a candidate pool and re-select the coreset."""
        t0 = time.time()
        n_pool = min(
            self.dataset.n_docs,
            self.tcfg.proxy_pool_batches * self.tcfg.batch_size,
        )
        # deterministic pool: stride over the corpus
        stride = max(1, self.dataset.n_docs // n_pool)
        pool_idx = np.arange(0, self.dataset.n_docs, stride)[:n_pool]
        feats = []
        bs = self.tcfg.batch_size
        for lo in range(0, len(pool_idx), bs):
            chunk = pool_idx[lo : lo + bs]
            if len(chunk) < bs:  # pad, then drop
                chunk = np.concatenate([chunk, pool_idx[: bs - len(chunk)]])
            batch = self.dataset.batch(chunk)
            f = self.select_step(self.params, batch)
            feats.append(np.asarray(f))
        feats = np.concatenate(feats)[: len(pool_idx)]
        sel = CraigSelector(self.tcfg.craig).select(feats)
        self.sampler.set_coreset_from_selection(sel, pool_indices=pool_idx)
        self.metrics_log.append(
            {
                "event": "craig_refresh",
                "step": self.step,
                "coreset_size": sel.size,
                "epsilon_hat": sel.epsilon_hat,
                "select_time_s": time.time() - t0,
            }
        )

    # -- evaluation ------------------------------------------------------------

    def evaluate(self) -> float:
        """Mean held-out loss over ``eval_batches`` deterministic batches."""
        ds = self.eval_dataset or self.dataset
        bs = self.tcfg.batch_size
        total = 0.0
        for b in range(self.tcfg.eval_batches):
            idx = (np.arange(bs) + b * bs) % ds.n_docs
            batch = ds.batch(idx)
            batch.pop("indices", None)
            total += float(self._eval_loss(self.params, batch))
        loss = total / max(self.tcfg.eval_batches, 1)
        self.metrics_log.append(
            {"event": "eval", "step": self.step, "eval_loss": loss}
        )
        return loss

    # -- checkpoint -------------------------------------------------------------

    def _save(self, blocking: bool = True) -> None:
        if self.ckpt is None:
            return
        tree = {"params": self.params, "opt": self.opt_state}
        extras = {
            "step": self.step,
            "sampler": self.sampler.state_dict(),
            "last_epoch_selected": self._last_epoch_selected,
        }
        self.ckpt.save(self.step, tree, extras, blocking=blocking)

    def restore_or_init(self, shardings: Any | None = None) -> bool:
        """Returns True if restored from checkpoint."""
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        template = {"params": self.params, "opt": self.opt_state}
        tree, extras = self.ckpt.restore(template, shardings=shardings)
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.step = int(extras["step"])
        self.sampler.load_state_dict(extras["sampler"])
        self._last_epoch_selected = int(extras["last_epoch_selected"])
        return True

    # -- main loop ----------------------------------------------------------------

    def run(self, n_steps: int) -> list[dict]:
        tc = self.tcfg
        for _ in range(n_steps):
            # CRAIG refresh at epoch boundaries
            epoch = self.sampler.epoch
            if (
                tc.use_craig
                and tc.select_every_epochs > 0
                and self.sampler.step_in_epoch == 0
                and epoch != self._last_epoch_selected
                and epoch % tc.select_every_epochs == 0
            ):
                self._refresh_coreset()
                self._last_epoch_selected = epoch

            idx, w = self.sampler.next_batch()
            batch = self.dataset.batch(idx)
            batch["weights"] = w
            batch.pop("indices", None)
            t0 = time.time()
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch
            )
            dt = time.time() - t0
            if tc.step_timeout_s is not None and dt > tc.step_timeout_s:
                self.straggler_events.append(self.step)
            self.step += 1
            self.metrics_log.append(
                {
                    "event": "step",
                    "step": self.step,
                    "loss": float(metrics["loss"]),
                    "epoch": epoch,
                    "time_s": dt,
                }
            )
            if tc.eval_every and self.step % tc.eval_every == 0:
                self.evaluate()
            if self.ckpt is not None and self.step % tc.checkpoint_every == 0:
                self._save(blocking=False)
            if self._preempt:
                self._save(blocking=True)
                break
        if self.ckpt is not None:
            self.ckpt.wait()
        return self.metrics_log
