"""Trainer: host loop tying CRAIG selection into the training schedule.

Responsibilities (DESIGN.md §4):
  * CRAIG refresh every ``select_every`` epochs (paper §3.4: deep-net proxies
    drift with w, so the subset is re-selected periodically; Fig 5 sweeps
    per-1 and per-5-epoch refresh), run *off the critical path*: params are
    snapshotted at the trigger boundary, proxy extraction + greedy selection
    run on a background thread (``core.refresh.AsyncRefresher``), and the
    published selection installs atomically at the next epoch boundary while
    training continues on the stale coreset (double buffering).
    ``refresh_mode='sync'`` runs the identical lifecycle inline — same
    install boundaries, so the two modes are step-for-step deterministic
    replicas and their steps/s delta is exactly the selection wall-clock
    removed from the critical path (benchmarks/bench_refresh.py);
  * warm-started selection: each refresh seeds the greedy engines with the
    previous selection's high-gain prefix (``warm_start_fraction``), whose
    cover state is replayed in O(r₀·n) instead of re-derived from scratch —
    every registered engine honors the prefix, including the
    device-resident fused greedy (``engines.DeviceConfig``, DESIGN.md
    §3.6), whose whole re-selection runs as one jitted device program on
    the worker thread.  The engine itself comes from ``craig.engine`` —
    a typed ``EngineConfig`` or ``'auto'`` (default), in which case the
    ``repro.core.engines`` policy picks per refresh-pool size/backend, and
    the resolved config is stamped into the refresh metadata/checkpoints;
  * pipelined, device-resident proxy extraction (``core.extract``,
    DESIGN.md §9): the pool sweep folds into O(1) ``lax.scan`` programs
    (``extract_megabatch``) with double-buffered host prefetch
    (``extract_prefetch``); features hand off to ``CraigSelector.select``
    as a ``jax.Array`` — with a jit-safe engine
    (``engines.Capabilities.jit_safe``) the feature matrix never visits
    the host, and host copies exist only for labels/provenance;
  * per-class stratification (paper §5): pool class labels are extracted
    alongside proxies (``dataset.class_labels``) and threaded into
    ``CraigSelector.select`` whenever ``craig.per_class=True``;
  * weighted-batch training between refreshes (γ weights ride in the batch);
  * checkpoint/restart: params + opt state + sampler cursor + active coreset
    + any published-but-not-installed refresh are one atomic unit
    (``_save`` drains the refresher first, so an in-flight selection always
    materializes into the sampler's back buffer before state capture);
    ``Trainer.restore_or_init`` resumes the exact stream, optionally onto a
    different mesh (elastic);
  * preemption: SIGTERM triggers an emergency checkpoint at the next step
    boundary (CPU-testable via ``request_preempt()``);
  * straggler policy: per-step wall-clock watchdog — on the single-host
    harness it only records violations; on a pod it feeds the
    restart-from-checkpoint path.
"""
from __future__ import annotations

import dataclasses
import signal
import time
import warnings
from typing import Any, Callable, Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.craig import CraigConfig, CraigSelector
from repro.core.extract import ProxyExtractor
from repro.core.refresh import AsyncRefresher, RefreshResult
from repro.data.pipeline import CoresetSampler
from repro.faults import FailurePolicy
from repro.models.config import ModelConfig
from repro.optim.optimizers import Optimizer
from repro.train.train_step import make_select_step, make_train_step

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    batch_size: int = 8
    eval_every: int = 0  # steps between held-out evals (0 = never)
    eval_batches: int = 2
    select_every_epochs: int = 1  # CRAIG refresh cadence (0 = never)
    craig: CraigConfig = dataclasses.field(
        default_factory=lambda: CraigConfig(fraction=0.5, per_class=False)
    )
    use_craig: bool = True
    proxy_pool_batches: int = 8  # batches of the pool scanned per refresh
    proxy_impl: str = "auto"  # select-step CE head: auto|einsum|pallas
    extract_megabatch: int = 0  # pool batches per extraction dispatch
    # (0 = the whole pool in ONE lax.scan program — DESIGN.md §9)
    extract_prefetch: bool = True  # double-buffered host batch assembly
    refresh_mode: Literal["sync", "async"] = "async"  # DESIGN.md §4 lifecycle
    warm_start_fraction: float = 0.5  # share of the budget warm-started from
    # the previous refresh's high-gain prefix (0 = cold every refresh)
    streaming_ingest: bool = False  # grow-only corpora: feed docs appended
    # since the last boundary through AsyncRefresher.ingest (sieve-streaming,
    # O(Δn·k) per delta) instead of re-extracting the full pool per refresh
    # (DESIGN.md §10).  Budget is fixed at craig.fraction × the first delta.
    streaming_evict: bool = True  # bounded-memory sieve pool: drop rows no
    # sieve references after every drain (O(L·k·d) instead of O(n·d))
    checkpoint_every: int = 50
    checkpoint_dir: str | None = None
    keep_checkpoints: int = 3
    step_timeout_s: float | None = None  # straggler watchdog
    microbatches: int = 1
    seed: int = 0
    # Supervision for the refresh worker (DESIGN.md §12): retry/backoff per
    # job, then raise (default) / keep sampling the stale coreset
    # ('keep_stale' — the failure is logged as a craig_refresh_failed event)
    # / degrade to an inline synchronous refresh ('sync_fallback').
    refresh_failure_policy: FailurePolicy | None = None


class Trainer:
    """Single-controller trainer (CPU-testable; sharding-transparent)."""

    def __init__(
        self,
        cfg: ModelConfig,
        tcfg: TrainerConfig,
        dataset,
        optimizer: Optimizer,
        init_params_fn: Callable[[], Any],
        eval_dataset=None,
    ):
        self.cfg = cfg
        self.tcfg = tcfg
        self.dataset = dataset
        self.eval_dataset = eval_dataset
        self.optimizer = optimizer
        self.sampler = CoresetSampler(dataset.n_docs, tcfg.batch_size, tcfg.seed)
        # No donate_argnums here: the AsyncRefresher snapshots params by
        # reference (immutable jax.Arrays), so a donating update would
        # delete the worker's snapshot mid-refresh (core/refresh.py).
        self.train_step = jax.jit(
            make_train_step(cfg, optimizer, microbatches=tcfg.microbatches)
        )
        # Pipelined pool sweep (DESIGN.md §9): O(1) scan programs, prefetch,
        # device-resident features.  The extractor owns the select-step
        # compilation; megabatch 0 folds the whole default pool into one.
        self.extractor = ProxyExtractor(
            make_select_step(cfg, proxy_impl=tcfg.proxy_impl),
            dataset,
            tcfg.batch_size,
            megabatch=tcfg.extract_megabatch or max(1, tcfg.proxy_pool_batches),
            prefetch=tcfg.extract_prefetch,
        )
        self.params = init_params_fn()
        self.opt_state = optimizer.init(self.params)
        self.step = 0
        self.metrics_log: list[dict] = []
        self.straggler_events: list[int] = []
        self._preempt = False
        self.ckpt = (
            CheckpointManager(tcfg.checkpoint_dir, tcfg.keep_checkpoints)
            if tcfg.checkpoint_dir
            else None
        )
        self._last_epoch_selected = -1
        if tcfg.use_craig and tcfg.streaming_ingest:
            # Streaming lifecycle (DESIGN.md §10): refreshes are coalesced
            # ingest drains — only docs appended since the last boundary are
            # extracted, and the sieve state absorbs them in O(Δn·k).
            self.refresher = AsyncRefresher(
                self._refresh_work,
                mode=tcfg.refresh_mode,
                on_complete=self._publish_stream,
                ingest_fn=self._stream_ingest_job,
                failure_policy=tcfg.refresh_failure_policy,
                on_failure=self._refresh_failed,
            )
        else:
            self.refresher = AsyncRefresher(
                self._refresh_work,
                mode=tcfg.refresh_mode,
                on_complete=self._publish_refresh,
                failure_policy=tcfg.refresh_failure_policy,
                on_failure=self._refresh_failed,
            )
        # Streaming-ingest state (streaming_ingest=True only): the selector
        # is built lazily at the first drain (budget = fraction × first
        # delta), and the pool/doc-id buffers are compacted in lockstep with
        # StreamingSelector.compact() when streaming_evict drops dead rows.
        self._stream_cursor = 0  # docs ingested so far (dataset prefix)
        self._stream_sel = None
        self._stream_pool: np.ndarray | None = None
        self._stream_doc_ids = np.zeros((0,), np.int64)
        # previous refresh's selection in pool coordinates (the pool is a
        # deterministic stride, identical across refreshes) — warm-start seed
        self._prev_selection = None
        if (
            tcfg.use_craig
            and tcfg.craig.per_class
            and not hasattr(dataset, "class_labels")
        ):
            warnings.warn(
                "craig.per_class=True but the dataset exposes no "
                "class_labels(idx); refreshes will fall back to flat "
                "(unstratified) selection",
                UserWarning,
                stacklevel=2,
            )
        from repro.models import loss_fn as _loss_fn

        self._eval_loss = jax.jit(
            lambda p, b: _loss_fn(p, cfg, b)[1]["loss"]
        )

    # -- preemption -----------------------------------------------------------

    def install_signal_handler(self) -> None:
        signal.signal(signal.SIGTERM, lambda *_: self.request_preempt())

    def request_preempt(self) -> None:
        self._preempt = True

    # -- CRAIG refresh ---------------------------------------------------------

    def _pool_indices(self) -> np.ndarray:
        """Deterministic candidate pool: stride over the corpus.  Depends
        only on (corpus size, config), so pool coordinates are stable across
        refreshes — which is what makes warm-start prefixes transferable."""
        n_pool = min(
            self.dataset.n_docs,
            self.tcfg.proxy_pool_batches * self.tcfg.batch_size,
        )
        stride = max(1, self.dataset.n_docs // n_pool)
        return np.arange(0, self.dataset.n_docs, stride)[:n_pool]

    def _pool_labels(self, pool_idx: np.ndarray) -> np.ndarray | None:
        """Class labels for the pool (host-side; the stratification key)."""
        if self.tcfg.craig.per_class and hasattr(self.dataset, "class_labels"):
            return np.asarray(self.dataset.class_labels(pool_idx))
        return None

    def _refresh_work(self, params):
        """Extraction + selection; runs on the refresher's worker thread in
        async mode (params is a snapshot — live params keep training).

        Device-resident handoff: features stay a ``jax.Array`` end to end
        through ``CraigSelector.select`` — with a jit-safe engine
        (``Capabilities.jit_safe``) the feature matrix never crosses to the
        host at all, and the host-side engines pull to host only what their
        algorithm needs (a pre-emptive numpy copy here would just be
        re-uploaded by the selector's ``jnp.asarray``)."""
        pool_idx = self._pool_indices()
        labels = self._pool_labels(pool_idx)
        selector = CraigSelector(self.tcfg.craig)
        feats = self.extractor.extract(params, pool_idx)
        init = None
        prev = self._prev_selection
        if self.tcfg.warm_start_fraction > 0 and prev is not None:
            r0 = int(round(self.tcfg.warm_start_fraction * prev.size))
            if r0 > 0:
                init = np.asarray(prev.indices[:r0])
        sel = selector.select(feats, labels=labels, init_selected=init)
        self._prev_selection = sel
        return sel, pool_idx

    def _publish_refresh(self, result: RefreshResult) -> None:
        """on_complete hook: stage the selection into the sampler's back
        buffer (worker thread in async mode).  Installation happens on the
        main thread at the next epoch boundary."""
        sel, pool_idx = result.value
        self.sampler.stage(
            np.asarray(pool_idx)[np.asarray(sel.indices)],
            sel.weights,
            version=result.version,
            meta={
                "coreset_size": sel.size,
                "epsilon_hat": float(sel.epsilon_hat),
                "select_time_s": result.wall_time_s,
                "per_class_sizes": sel.per_class_sizes,
                # resolved EngineConfig dict (provenance; restorable via
                # engines.EngineConfig.from_dict)
                "engine": sel.engine,
                # rows the validate_features='drop' guard removed (0 unless
                # the guard fired — surfaced so degraded refreshes are
                # visible in the metrics log, never silent)
                "dropped_rows": sel.n_dropped,
            },
        )

    def _refresh_failed(self, result: RefreshResult) -> None:
        """on_failure hook (``on_exhaustion='keep_stale'`` only): the job
        was abandoned — nothing staged, training keeps sampling the
        installed coreset.  Log it so the degradation is observable."""
        err = result.error
        self.metrics_log.append(
            {
                "event": "craig_refresh_failed",
                "step": self.step,
                "version": result.version,
                "attempts": result.attempts,
                "error": f"{type(err).__name__}: {err}",
            }
        )

    # -- streaming ingest (DESIGN.md §10) --------------------------------------

    def _stream_submit(self) -> None:
        """Refresh-boundary trigger in streaming mode: queue the docs the
        dataset grew by since the last boundary as one ingest delta.  A
        boundary with no new docs is a no-op — training continues on the
        installed coreset without re-selection (the sieve state is already
        a (1−ε)/2-approximation of what it has seen)."""
        n = self.dataset.n_docs
        if n <= self._stream_cursor:
            return
        new_idx = np.arange(self._stream_cursor, n, dtype=np.int64)
        self._stream_cursor = n
        # Same snapshot contract as submit(): jax.Array leaves by reference
        # (immutable; train_step does not donate), numpy leaves by copy.
        snap = jax.tree.map(
            lambda x: x.copy() if isinstance(x, np.ndarray) else x, self.params
        )
        self.refresher.ingest((snap, new_idx))

    def _stream_ingest_job(self, deltas: list):
        """One coalesced drain (refresher worker thread): extract proxies
        for the NEW docs only, feed them to the sieve, evict dead pool
        rows, finalize.  O(Δn) extraction instead of the submit path's
        full-pool re-extraction."""
        # Coalesced deltas: newest params snapshot wins, doc ranges concat
        # in arrival order (they are disjoint, cursor-ordered by _stream_submit)
        params = deltas[-1][0]
        new_idx = np.concatenate([np.asarray(d[1], np.int64) for d in deltas])
        feats = np.asarray(
            jax.device_get(self.extractor.extract(params, new_idx)), np.float32
        )
        labels = self._pool_labels(new_idx)
        if self._stream_sel is None:
            from repro.core.engines.streaming import StreamingSelector

            k = max(1, int(round(self.tcfg.craig.fraction * new_idx.size)))
            self._stream_sel = StreamingSelector(
                k,
                feats.shape[1],
                metric=self.tcfg.craig.metric,
                per_class=labels is not None,
                evict=self.tcfg.streaming_evict,
            )
            self._stream_pool = np.zeros((0, feats.shape[1]), np.float32)
        self._stream_sel.ingest(feats, labels=labels)
        self._stream_pool = np.concatenate([self._stream_pool, feats], axis=0)
        self._stream_doc_ids = np.concatenate([self._stream_doc_ids, new_idx])
        if self.tcfg.streaming_evict:
            keep = self._stream_sel.compact()
            self._stream_pool = np.ascontiguousarray(self._stream_pool[keep])
            self._stream_doc_ids = self._stream_doc_ids[keep]
        res = self._stream_sel.result(self._stream_pool)
        doc_ids = self._stream_doc_ids[np.asarray(res.indices, np.int64)]
        return (
            doc_ids,
            np.asarray(res.weights, np.float32),
            float(res.coverage),
            self._stream_sel.n_rows,
        )

    def _publish_stream(self, result: RefreshResult) -> None:
        """on_complete hook for ingest drains: same staging path as
        :meth:`_publish_refresh`, streaming provenance in the metadata."""
        doc_ids, weights, coverage, n_live = result.value
        self.sampler.stage(
            doc_ids,
            weights,
            version=result.version,
            meta={
                "coreset_size": int(doc_ids.size),
                "select_time_s": result.wall_time_s,
                "coverage": coverage,
                "n_seen": self._stream_sel.n_seen,
                "n_live": n_live,
                "engine": self._stream_sel.config.to_dict(),
            },
        )

    def _install_refresh(self) -> None:
        """Epoch-boundary install point: wait out any in-flight selection
        (the deterministic deadline — normally it finished an epoch ago) and
        atomically swap the staged coreset in."""
        t0 = time.time()
        self.refresher.wait()
        stall = time.time() - t0
        p = self.sampler.install_pending()
        if p is None:
            return
        meta = p.get("meta") or {}
        self.metrics_log.append(
            {
                "event": "craig_refresh",
                "step": self.step,
                "version": p["version"],
                "mode": self.tcfg.refresh_mode,
                "coreset_size": len(p["indices"]),
                "epsilon_hat": meta.get("epsilon_hat", float("nan")),
                "select_time_s": meta.get("select_time_s", float("nan")),
                "install_stall_s": stall,
                "engine": meta.get("engine"),
            }
        )

    # -- evaluation ------------------------------------------------------------

    def evaluate(self) -> float:
        """Mean held-out loss over ``eval_batches`` deterministic batches."""
        ds = self.eval_dataset or self.dataset
        bs = self.tcfg.batch_size
        total = 0.0
        for b in range(self.tcfg.eval_batches):
            idx = (np.arange(bs) + b * bs) % ds.n_docs
            batch = ds.batch(idx)
            batch.pop("indices", None)
            total += float(self._eval_loss(self.params, batch))
        loss = total / max(self.tcfg.eval_batches, 1)
        self.metrics_log.append(
            {"event": "eval", "step": self.step, "eval_loss": loss}
        )
        return loss

    # -- checkpoint -------------------------------------------------------------

    def _save(self, blocking: bool = True) -> None:
        if self.ckpt is None:
            return
        # An in-flight refresh must materialize before sampler state is
        # captured: a staged selection round-trips through state_dict(), a
        # running thread doesn't.  Bounded by one selection wall-clock.
        self.refresher.wait()
        tree = {"params": self.params, "opt": self.opt_state}
        prev = self._prev_selection  # warm-start seed (pool coordinates)
        extras = {
            "step": self.step,
            "sampler": self.sampler.state_dict(),
            "last_epoch_selected": self._last_epoch_selected,
            "prev_selection": None
            if prev is None
            else {
                "indices": np.asarray(prev.indices).tolist(),
                "weights": np.asarray(prev.weights).tolist(),
                "coverage": float(prev.coverage),
                "epsilon_hat": float(prev.epsilon_hat),
                # provenance must survive restart: the resolved EngineConfig
                # dict and the per-class stratification record (JSON keys
                # stringify; restore re-ints them)
                "engine": prev.engine,
                "per_class_sizes": None
                if prev.per_class_sizes is None
                else {str(k): int(v) for k, v in prev.per_class_sizes.items()},
            },
        }
        if self.tcfg.streaming_ingest:
            # Bounded by O(L·k·d) with streaming_evict: every drain compacts
            # the pool buffer before this snapshot can observe it.
            extras["stream"] = {
                "cursor": self._stream_cursor,
                "selector": None
                if self._stream_sel is None
                else self._stream_sel.state_dict(),
                "doc_ids": self._stream_doc_ids.tolist(),
                "pool": None
                if self._stream_pool is None
                else self._stream_pool.tolist(),
            }
        self.ckpt.save(self.step, tree, extras, blocking=blocking)

    def restore_or_init(self, shardings: Any | None = None) -> bool:
        """Returns True if restored from checkpoint."""
        if self.ckpt is None or self.ckpt.latest_step() is None:
            return False
        template = {"params": self.params, "opt": self.opt_state}
        tree, extras = self.ckpt.restore(template, shardings=shardings)
        self.params = tree["params"]
        self.opt_state = tree["opt"]
        self.step = int(extras["step"])
        self.sampler.load_state_dict(extras["sampler"])
        self._last_epoch_selected = int(extras["last_epoch_selected"])
        # version monotonicity: _save drains the refresher, so the highest
        # version ever assigned is visible as installed-or-pending state
        self.refresher.reset_version(
            max(self.sampler.version, self.sampler.pending_version or 0)
        )
        ps = extras.get("prev_selection")
        if ps is not None:
            from repro.core.craig import CoresetSelection

            pcs = ps.get("per_class_sizes")
            self._prev_selection = CoresetSelection(
                indices=np.asarray(ps["indices"], np.int64),
                weights=np.asarray(ps["weights"], np.float32),
                order=np.arange(len(ps["indices"])),
                coverage=float(ps["coverage"]),
                epsilon_hat=float(ps["epsilon_hat"]),
                per_class_sizes=None
                if pcs is None
                else {int(k): int(v) for k, v in pcs.items()},
                engine=ps.get("engine"),
            )
        st = extras.get("stream")
        if st is not None:
            self._stream_cursor = int(st["cursor"])
            self._stream_doc_ids = np.asarray(st["doc_ids"], np.int64)
            if st["selector"] is not None:
                from repro.core.engines.streaming import StreamingSelector

                sd = st["selector"]
                self._stream_sel = StreamingSelector(sd["budget"], sd["dim"])
                self._stream_sel.load_state_dict(sd)
                self._stream_pool = np.asarray(st["pool"], np.float32).reshape(
                    -1, int(sd["dim"])
                )
        return True

    # -- main loop ----------------------------------------------------------------

    def run(self, n_steps: int) -> list[dict]:
        tc = self.tcfg
        for _ in range(n_steps):
            epoch = self.sampler.epoch
            # Refresh lifecycle, both modes at the same boundaries:
            # install the previous trigger's selection at this epoch
            # boundary, then (on cadence) snapshot params and kick off the
            # next selection — async: in the background while this epoch
            # trains on the stale coreset; sync: inline, blocking here.
            if (
                tc.use_craig
                and tc.select_every_epochs > 0
                and self.sampler.step_in_epoch == 0
            ):
                self._install_refresh()
                if (
                    epoch % tc.select_every_epochs == 0
                    and epoch != self._last_epoch_selected
                ):
                    if tc.streaming_ingest:
                        self._stream_submit()
                    else:
                        self.refresher.submit(self.params)
                    self._last_epoch_selected = epoch

            idx, w = self.sampler.next_batch()
            batch = self.dataset.batch(idx)
            batch["weights"] = w
            batch.pop("indices", None)
            t0 = time.time()
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch
            )
            dt = time.time() - t0
            if tc.step_timeout_s is not None and dt > tc.step_timeout_s:
                self.straggler_events.append(self.step)
            self.step += 1
            self.metrics_log.append(
                {
                    "event": "step",
                    "step": self.step,
                    "loss": float(metrics["loss"]),
                    "epoch": epoch,
                    "time_s": dt,
                }
            )
            if tc.eval_every and self.step % tc.eval_every == 0:
                self.evaluate()
            if self.ckpt is not None and self.step % tc.checkpoint_every == 0:
                self._save(blocking=False)
            if self._preempt:
                self._save(blocking=True)
                break
        if self.ckpt is not None:
            self.ckpt.wait()
        return self.metrics_log
