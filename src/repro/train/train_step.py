"""pjit train/select step factories.

``make_train_step``  — γ-weighted loss → grad → optimizer update, with
optional microbatched gradient accumulation (overlaps the per-microbatch
DCN all-reduce with compute under the XLA scheduler) and optional int8
gradient compression on the pure-DP ``pod`` axis.

``make_select_step`` — CRAIG selection forward: proxy features for a
candidate pool batch (the technique's own SPMD program; lowered in the
dry-run alongside train/serve).

Both return pure functions ready for ``jax.jit(..., in_shardings=...)``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import loss_fn as model_loss_fn
from repro.models import proxy_features
from repro.models.config import ModelConfig
from repro.optim.optimizers import Optimizer, OptState

__all__ = ["make_train_step", "make_select_step", "TrainState"]

TrainState = tuple  # (params, OptState)


def make_train_step(
    cfg: ModelConfig,
    optimizer: Optimizer,
    microbatches: int = 1,
    grad_transform: Callable[[Any], Any] | None = None,
) -> Callable:
    """Returns train_step(params, opt_state, batch) → (params', opt', metrics).

    microbatches > 1 splits the global batch along dim 0 and accumulates
    gradients with a ``lax.scan`` (sequential microbatches — the standard
    accumulation trick that also caps activation memory).
    ``grad_transform`` hooks gradient compression (distributed/compression).
    """

    def loss_wrapper(params, batch):
        total, metrics = model_loss_fn(params, cfg, batch)
        return total, metrics

    grad_fn = jax.value_and_grad(loss_wrapper, has_aux=True)

    def single(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def accumulated(params, batch):
        def micro(carry, mb):
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(params, mb)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), metrics

        split = jax.tree.map(
            lambda x: x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:]),
            batch,
        )
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), metrics = jax.lax.scan(
            micro, (zeros, jnp.zeros((), jnp.float32)), split
        )
        grads = jax.tree.map(lambda g: g / microbatches, grads)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / microbatches, metrics, grads

    def train_step(params, opt_state: OptState, batch):
        if microbatches > 1:
            loss, metrics, grads = accumulated(params, batch)
        else:
            loss, metrics, grads = single(params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        out_metrics = {
            "loss": loss,
            "aux_loss": metrics.get("aux_loss", jnp.zeros(())),
            "step": new_opt.step,
        }
        return new_params, new_opt, out_metrics

    return train_step


def make_select_step(
    cfg: ModelConfig,
    proxy_impl: str = "auto",
    compute_dtype=None,
) -> Callable:
    """select_step(params, batch) → (B, D) proxy features (fp32).

    The trainer's ``ProxyExtractor`` (core/extract.py) scans this over the
    candidate pool, then hands features to CraigSelector /
    core.distributed.distributed_select.

    Args:
      proxy_impl: which CE-backward head computes the unembed-input proxy —
        * ``'auto'`` (default): ``'pallas'`` on TPU, ``'einsum'`` elsewhere;
        * ``'einsum'``: chunked ``lax.scan`` path
          (``core.proxy.lm_unembed_input_proxy``) — the shard_map-safe body;
        * ``'pallas'``: fused flash-style ``ce_proxy`` kernel
          (kernels/ce_proxy.py; interpret mode off-TPU, so CI exercises it).
      compute_dtype: matmul dtype override for the pallas path (fp32
        accumulation either way); None keeps the model's COMPUTE_DTYPE
        (bf16) — mirroring ``lm_unembed_input_proxy``.
    """
    if proxy_impl == "auto":
        proxy_impl = "pallas" if jax.default_backend() == "tpu" else "einsum"
    if proxy_impl == "pallas":
        from repro.models import proxy_features_fused

        kw = {} if compute_dtype is None else {"compute_dtype": compute_dtype}

        def select_step(params, batch):
            return proxy_features_fused(params, cfg, batch, **kw)

        return select_step
    if proxy_impl != "einsum":
        raise ValueError(
            f"unknown proxy_impl {proxy_impl!r} (want 'auto'|'einsum'|'pallas')"
        )

    def select_step(params, batch):
        return proxy_features(params, cfg, batch)

    return select_step
